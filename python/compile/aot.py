"""AOT: lower every L2 entry to HLO *text* artifacts for the rust runtime.

Interchange is HLO text, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the rust side uniformly unpacks result tuples.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (or via
``make artifacts``). Also writes ``manifest.txt`` — one line per artifact:
``name;in=<shape,shape,...>;out=<shape,...>`` — which the rust runtime
uses to synthesise correctly-shaped inputs without a JSON dependency.
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _fmt_shapes(avals) -> str:
    return ",".join("x".join(str(d) for d in a.shape) for a in avals)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of entry names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = args.only or sorted(model.ENTRIES)
    manifest = []
    for name in names:
        lowered = model.lower_entry(name)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        in_shapes = _fmt_shapes(model.ENTRIES[name][1])
        out_avals = lowered.out_info
        out_shapes = ",".join(
            "x".join(str(d) for d in o.shape) for o in jax_tree_leaves(out_avals)
        )
        manifest.append(f"{name};in={in_shapes};out={out_shapes}")
        print(f"wrote {path} ({len(text)} chars)")

    # Merge with any existing manifest so `--only` refreshes single
    # entries without dropping the rest.
    mpath = os.path.join(args.out_dir, "manifest.txt")
    merged = {}
    if os.path.exists(mpath):
        with open(mpath) as f:
            for line in f:
                if line.strip():
                    merged[line.split(";")[0]] = line.strip()
    for line in manifest:
        merged[line.split(";")[0]] = line
    with open(mpath, "w") as f:
        f.write("\n".join(merged[k] for k in sorted(merged)) + "\n")
    print(f"wrote manifest for {len(merged)} artifacts ({len(names)} refreshed)")


def jax_tree_leaves(x):
    import jax

    return jax.tree_util.tree_leaves(x)


if __name__ == "__main__":
    main()

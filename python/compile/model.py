"""L2: JAX compute graphs for every workload kernel MGB schedules.

One entry per Rodinia/Darknet analogue (DESIGN.md §1 substitution table).
Each entry is a jit-able function plus example input shapes; ``aot.py``
lowers each to HLO text in ``artifacts/`` and the rust runtime executes
them via PJRT whenever the simulator runs in ``--compute real`` mode.

The GEMM-shaped entries call the L1 Pallas kernels
(``kernels.matmul_tiled``); the stencil entries call
``kernels.srad_stencil``. Everything stays f32 and uses shapes small
enough that the interpret-mode Pallas path is fast on CPU — the
*simulated* problem sizes (GBs of footprint) live in the rust workload
profiles, not here.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.haar_dwt import haar2d
from .kernels.matmul_tiled import matmul
from .kernels.srad_stencil import srad_step
from .kernels import ref

# ---------------------------------------------------------------------------
# Rodinia analogues
# ---------------------------------------------------------------------------


def backprop(x, w1, w2, y):
    """Rodinia backprop: one fwd+bwd of a 2-layer MLP (layerforward +
    adjust_weights kernels). Hidden activations via the Pallas matmul."""
    h = jnp.tanh(matmul(x, w1))
    out = jnp.tanh(matmul(h, w2))
    err = out - y
    # adjust_weights: manual backward pass (matches the CUDA kernel pair).
    d_out = err * (1.0 - out * out)
    d_w2 = matmul(h.T, d_out)
    d_h = matmul(d_out, w2.T) * (1.0 - h * h)
    d_w1 = matmul(x.T, d_h)
    lr = 0.3
    return (w1 - lr * d_w1, w2 - lr * d_w2, 0.5 * jnp.sum(err * err)[None])


def srad(img):
    """srad_v1/srad_v2: two diffusion iterations (2 kernel launches/iter
    in the CUDA code; here one fused Pallas stencil per iteration)."""
    img = srad_step(img, band=32)
    img = srad_step(img, band=32)
    return (img,)


def lavamd(pos, charge):
    """lavaMD: pairwise force accumulation inside a neighbourhood box.

    pos: [n, 3], charge: [n]. O(n^2) distance/force kernel — the CUDA
    version tiles by boxes; XLA fuses the broadcast-reduce chain.
    """
    diff = pos[:, None, :] - pos[None, :, :]  # [n, n, 3]
    d2 = jnp.sum(diff * diff, axis=-1) + 1e-3
    inv = charge[None, :] / (d2 * jnp.sqrt(d2))
    force = jnp.sum(diff * inv[:, :, None], axis=1)
    return (force,)


def needle(seq_scores, penalty):
    """needle (Needleman-Wunsch): wavefront DP over the score matrix.

    seq_scores: [n, n] similarity matrix; penalty: scalar gap penalty.
    The CUDA kernel sweeps anti-diagonals with one launch per diagonal;
    here a row-wise lax.scan carries the DP frontier (same dependence
    structure, one scan step per row).
    """
    n = seq_scores.shape[0]
    gap = penalty[0]
    init_row = jnp.arange(1, n + 1, dtype=jnp.float32) * gap  # h[0][1..n]

    def row_step(prev_row, xs):
        sim_row, row_idx = xs
        left_init = row_idx * gap

        def col_step(left, xs2):
            up, diag, sim = xs2
            best = jnp.maximum(jnp.maximum(diag + sim, up + gap), left + gap)
            return best, best

        diag_row = jnp.concatenate([jnp.array([left_init - gap]), prev_row[:-1]])
        _, row = jax.lax.scan(col_step, left_init, (prev_row, diag_row, sim_row))
        return row, row

    rows_idx = jnp.arange(1, n + 1, dtype=jnp.float32)
    last, _ = jax.lax.scan(row_step, init_row, (seq_scores, rows_idx))
    return (last,)


def dwt2d(img):
    """dwt2d: one level of a 2-D Haar wavelet transform (L1 Pallas
    kernel; `ref.haar2d` is the pytest oracle)."""
    return (haar2d(img),)


def bfs(adj, frontier):
    """bfs: one level expansion as adj^T @ frontier with binarisation.

    adj: [n, n] dense 0/1 adjacency (the simulated sizes use CSR cost
    models in rust; numerics here validate the level semantics).
    """
    nxt = matmul(adj, frontier)
    return ((nxt > 0).astype(jnp.float32),)


def hotspot(temp, power):
    """hotspot-style thermal stencil (extra workload for mixes): one
    Jacobi step with source term."""
    n_ = jnp.roll(temp, 1, 0).at[0, :].set(temp[0, :])
    s_ = jnp.roll(temp, -1, 0).at[-1, :].set(temp[-1, :])
    w_ = jnp.roll(temp, 1, 1).at[:, 0].set(temp[:, 0])
    e_ = jnp.roll(temp, -1, 1).at[:, -1].set(temp[:, -1])
    return (temp + 0.2 * (n_ + s_ + w_ + e_ - 4.0 * temp) + 0.01 * power,)


# ---------------------------------------------------------------------------
# Darknet analogues (§V-E neural-network workloads)
# ---------------------------------------------------------------------------


def _conv_as_matmul(x, w):
    """3x3 same-conv via im2col + Pallas matmul. x: [h, w, cin] -> [h, w, cout],
    weights: [9 * cin, cout]. h*w and channel dims padded to tile sizes by
    the callers' shape choices."""
    h, wd, cin = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    cols = [xp[i : i + h, j : j + wd, :] for i in range(3) for j in range(3)]
    patches = jnp.concatenate(cols, axis=-1).reshape(h * wd, 9 * cin)
    # 9*cin = 144 here: tile K by 72 (two K steps) — K tiles need not be
    # 128-aligned for the MXU as long as the lane dim (bn) is.
    out = matmul(patches, w, bm=128, bn=128, bk=72)
    return out.reshape(h, wd, -1)


def darknet_predict(img, w_conv, w_fc):
    """Image classification fwd (Darknet19-style head): conv -> GAP -> fc
    -> softmax logits."""
    feat = jax.nn.relu(_conv_as_matmul(img, w_conv))
    pooled = jnp.mean(feat, axis=(0, 1))[None, :]  # [1, c]
    logits = matmul(jnp.tile(pooled, (128, 1)), w_fc)[:1]
    return (jax.nn.softmax(logits, axis=-1),)


def darknet_train(img, w_conv, w_fc, label):
    """CIFAR-style train step: fwd, cross-entropy, SGD update on the fc
    weights (conv treated as frozen backbone — keeps the artifact small
    while exercising fwd+bwd)."""

    def loss_fn(w_fc_):
        feat = jax.nn.relu(_conv_as_matmul(img, w_conv))
        pooled = jnp.mean(feat, axis=(0, 1))[None, :]
        logits = matmul(jnp.tile(pooled, (128, 1)), w_fc_)[:1]
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.sum(logp * label)

    loss, grad = jax.value_and_grad(loss_fn)(w_fc)
    return (w_fc - 0.01 * grad, loss[None])


def darknet_detect(img, w_conv, w_box):
    """yolov3-tiny-style detection fwd: conv backbone + 1x1 box head."""
    feat = jax.nn.relu(_conv_as_matmul(img, w_conv))
    h, wd, c = feat.shape
    boxes = matmul(feat.reshape(h * wd, c), w_box)
    return (jax.nn.sigmoid(boxes),)


def darknet_rnn(h0, x_seq, w_xh, w_hh):
    """char-RNN generate: scan a tanh RNN cell over the sequence."""

    def cell(h, x):
        h = jnp.tanh(matmul(x, w_xh) + matmul(h, w_hh))
        return h, h

    h_last, ys = jax.lax.scan(cell, h0, x_seq)
    return (h_last, ys[-1])


# ---------------------------------------------------------------------------
# Artifact catalogue: name -> (fn, example input ShapeDtypeStructs)
# ---------------------------------------------------------------------------

F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


ENTRIES = {
    "backprop": (backprop, [_s(128, 256), _s(256, 128), _s(128, 128), _s(128, 128)]),
    "srad": (srad, [_s(128, 128)]),
    "lavamd": (lavamd, [_s(192, 3), _s(192)]),
    "needle": (needle, [_s(96, 96), _s(1)]),
    "dwt2d": (dwt2d, [_s(128, 128)]),
    "bfs": (bfs, [_s(128, 128), _s(128, 128)]),
    "hotspot": (hotspot, [_s(128, 128), _s(128, 128)]),
    "darknet_predict": (darknet_predict, [_s(16, 16, 16), _s(144, 128), _s(128, 128)]),
    "darknet_train": (darknet_train, [_s(16, 16, 16), _s(144, 128), _s(128, 128), _s(1, 128)]),
    "darknet_detect": (darknet_detect, [_s(16, 16, 16), _s(144, 128), _s(128, 128)]),
    "darknet_rnn": (darknet_rnn, [_s(128, 128), _s(4, 128, 128), _s(128, 128), _s(128, 128)]),
}


def lower_entry(name):
    """jit + lower one catalogue entry at its example shapes."""
    fn, specs = ENTRIES[name]
    return jax.jit(fn).lower(*specs)

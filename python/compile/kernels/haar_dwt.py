"""L1 Pallas kernel: one level of a blocked 2-D Haar wavelet transform.

Rodinia's dwt2d stages image tiles through shared memory, one CUDA
threadblock per tile. TPU adaptation: a Pallas grid over (rows/2bh,
cols/2bw) input tiles; each step holds one (2bh, 2bw) tile in VMEM,
computes the four quarter-resolution subbands with strided VPU
element-wise ops, and writes them to four separate output buffers (LL,
LH, HL, HH) — the L2 wrapper lays them out in the standard
[[LL, LH], [HL, HH]] quadrant arrangement to match ``ref.haar2d``.

interpret=True only — see matmul_tiled.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _haar_kernel(x_ref, ll_ref, lh_ref, hl_ref, hh_ref):
    x = x_ref[...]
    a = x[0::2, 0::2]
    b = x[0::2, 1::2]
    c = x[1::2, 0::2]
    d = x[1::2, 1::2]
    ll_ref[...] = (a + b + c + d) * 0.5
    lh_ref[...] = (a - b + c - d) * 0.5
    hl_ref[...] = (a + b - c - d) * 0.5
    hh_ref[...] = (a - b - c + d) * 0.5


def haar2d_subbands(img, *, bh: int = 32, bw: int = 128):
    """The four subbands of ``img`` (each half-resolution).

    ``img`` must have even dims; tiles are clamped to the image and must
    divide it evenly.
    """
    rows, cols = img.shape
    assert rows % 2 == 0 and cols % 2 == 0, f"odd image {img.shape}"
    bh, bw = min(bh, rows // 2), min(bw, cols // 2)
    assert (rows // 2) % bh == 0 and (cols // 2) % bw == 0, (
        f"{img.shape} does not tile by ({bh},{bw}) subband blocks"
    )
    grid = (rows // 2 // bh, cols // 2 // bw)
    sub = jax.ShapeDtypeStruct((rows // 2, cols // 2), img.dtype)
    return pl.pallas_call(
        _haar_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((2 * bh, 2 * bw), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bh, bw), lambda i, j: (i, j))] * 4,
        out_shape=[sub] * 4,
        interpret=True,
    )(img)


def haar2d(img, *, bh: int = 32, bw: int = 128):
    """Quadrant layout [[LL, LH], [HL, HH]], exactly like ``ref.haar2d``."""
    ll, lh, hl, hh = haar2d_subbands(img, bh=bh, bw=bw)
    top = jnp.concatenate([ll, lh], axis=1)
    bot = jnp.concatenate([hl, hh], axis=1)
    return jnp.concatenate([top, bot], axis=0)


def vmem_bytes(bh: int = 32, bw: int = 128, dtype_bytes: int = 4):
    """Per-step VMEM: input tile + 4 subband tiles."""
    return (4 * bh * bw + 4 * bh * bw) * dtype_bytes

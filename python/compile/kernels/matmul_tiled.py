"""L1 Pallas kernel: VMEM-tiled matmul targeting the MXU.

Hardware adaptation (paper GPU -> TPU, see DESIGN.md §2): Rodinia/Darknet
express their GEMMs with CUDA threadblocks staging through shared memory;
here the HBM<->VMEM schedule is expressed with a Pallas grid over
(M/bm, N/bn, K/bk) tiles. Each grid step keeps one (bm, bk) x (bk, bn)
pair resident in VMEM and accumulates into a VMEM scratch tile in f32 —
the MXU-native contraction — flushing to the output on the last K step.

Runs under ``interpret=True`` everywhere in this repo: the CPU PJRT
client cannot execute Mosaic custom-calls. Real-TPU efficiency for the
chosen block shapes is estimated in DESIGN.md/EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: acc += x_tile @ y_tile; flush on last k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _div(d: int, target: int = 128) -> int:
    """Largest divisor of ``d`` that is at most ``target``."""
    if d % target == 0:
        return target
    for t in range(min(target, d), 0, -1):
        if d % t == 0:
            return t
    return 1


def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """``x @ y`` with (bm, bn, bk) VMEM tiles, f32 accumulation.

    Differentiable: the VJP lowers to two more Pallas matmuls
    (dL/dx = g @ y^T, dL/dy = x^T @ g) so train-step artifacts stay on
    the L1 kernel path end to end.

    Requested block sizes are shrunk to the largest divisor of the
    corresponding dim when they do not divide it (e.g. n=192 with the
    default bn=128 tiles as bn=96 or 64).
    """
    (m, k), (_, n) = x.shape, y.shape
    return _matmul_vjp(x, y, _div(m, bm), _div(n, bn), _div(k, bk))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _matmul_vjp(x, y, bm, bn, bk):
    return _matmul_impl(x, y, bm, bn, bk)


def _matmul_vjp_fwd(x, y, bm, bn, bk):
    return _matmul_impl(x, y, bm, bn, bk), (x, y)


def _matmul_vjp_bwd(bm, bn, bk, res, g):
    x, y = res
    (m, k), (_, n) = x.shape, y.shape
    dx = _matmul_impl(g, y.T, _div(m), _div(k), _div(n))
    dy = _matmul_impl(x.T, g, _div(k), _div(n), _div(m))
    return dx, dy


_matmul_vjp.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def _matmul_impl(x, y, bm: int, bn: int, bk: int):
    """The raw pallas_call; shapes must tile evenly by the block sizes."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"({m},{k})x({k},{n}) does not tile by ({bm},{bn},{bk})"
    )
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        # f32 accumulator tile resident in VMEM across the K loop.
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, y)


def vmem_bytes(bm: int = 128, bn: int = 128, bk: int = 128, dtype_bytes: int = 4):
    """VMEM footprint of one grid step (x tile + y tile + out + acc).

    Used by the §Perf analysis: the default 128³ f32 config is
    4 * 128 * 128 * 4B = 256 KiB, far under the ~16 MiB VMEM budget, and
    feeds the 128x128 MXU with aligned, full-width tiles.
    """
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes + bm * bn * 4

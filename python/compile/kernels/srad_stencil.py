"""L1 Pallas kernel: SRAD diffusion step as a VMEM-tiled 5-point stencil.

Rodinia's srad_v1/srad_v2 launch one CUDA threadblock per image tile with
halo loads staged through shared memory. TPU adaptation (DESIGN.md §2): a
Pallas grid over row *bands*; each band plus a two-row halo is resident in
VMEM per grid step, and both stencil passes (diffusion coefficient, then
divergence) are computed in-register as VPU element-wise work — the
second halo row exists precisely so the coefficient of the south
neighbour can be recomputed locally instead of a second HBM round-trip.

The kernel is numerically *exact* w.r.t. ``ref.srad_step`` (pytest
asserts allclose over a hypothesis sweep). interpret=True only — the CPU
PJRT client cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _srad_kernel(win_ref, o_ref, *, lam: float, rows_total: int, band: int):
    """One row-band step. ``win_ref``: [1, band + 4, cols] haloed window."""
    i = pl.program_id(0)
    x = win_ref[0]
    cols = x.shape[1]

    # Centre rows for the coefficient pass: band + 2 rows (one halo row on
    # each side of the output band), global ids i*band - 1 .. i*band + band.
    xc = x[1:-1, :]
    north = x[:-2, :]
    south = x[2:, :]
    west = jnp.concatenate([xc[:, :1], xc[:, :-1]], axis=1)
    east = jnp.concatenate([xc[:, 1:], xc[:, -1:]], axis=1)

    # Neumann boundaries at the global image edges.
    ids = jax.lax.broadcasted_iota(jnp.int32, (band + 2, cols), 0) + i * band - 1
    north = jnp.where(ids == 0, xc, north)
    south = jnp.where(ids == rows_total - 1, xc, south)

    dn, ds, dw, de = north - xc, south - xc, west - xc, east - xc
    g2 = (dn * dn + ds * ds + dw * dw + de * de) / (xc * xc + 1e-8)
    l_ = (dn + ds + dw + de) / (xc + 1e-8)
    num = 0.5 * g2 - 0.0625 * l_ * l_
    den = (1.0 + 0.25 * l_) ** 2
    q = num / (den + 1e-8)
    c = jnp.clip(1.0 / (1.0 + q), 0.0, 1.0)

    # Divergence pass over the band rows proper (middle band rows of xc).
    c_mid = c[1:-1, :]
    cs = c[2:, :]  # south neighbour's coefficient — from the halo row.
    ce = jnp.concatenate([c_mid[:, 1:], c_mid[:, -1:]], axis=1)
    d = c_mid * dn[1:-1, :] + cs * ds[1:-1, :] + c_mid * dw[1:-1, :] + ce * de[1:-1, :]
    o_ref[...] = xc[1:-1, :] + (lam / 4.0) * d


def srad_step(img, lam: float = 0.05, band: int = 32):
    """One SRAD update over ``img``; rows must tile by ``band``.

    The overlapping haloed windows are materialised host-side here
    because interpret-mode BlockSpecs index in block units; on real TPU
    the same schedule is one element-indexed BlockSpec
    (``pl.BlockSpec((band + 4, cols), lambda i: (i * band - 2, 0))``)
    with no duplication.
    """
    rows, cols = img.shape
    band = min(band, rows)
    assert rows % band == 0, f"{rows} rows do not tile by band={band}"
    grid = rows // band

    padded = jnp.concatenate([img[:1], img[:1], img, img[-1:], img[-1:]], axis=0)
    windows = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(padded, i * band, band + 4, 0) for i in range(grid)]
    )

    return pl.pallas_call(
        functools.partial(_srad_kernel, lam=lam, rows_total=rows, band=band),
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, band + 4, cols), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((band, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), img.dtype),
        interpret=True,
    )(windows)


def vmem_bytes(band: int = 32, cols: int = 2048, dtype_bytes: int = 4):
    """Per-step VMEM: haloed window + output band (+ ~10 temporaries)."""
    return ((band + 4) * cols + band * cols) * dtype_bytes

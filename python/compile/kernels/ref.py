"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact jnp counterpart here;
pytest (python/tests/) asserts allclose between the two across a
hypothesis-driven sweep of shapes and dtypes. These references are also
what the L2 models in ``model.py`` were derived from, so kernel == ref ==
model-semantics.
"""

import jax.numpy as jnp


def matmul(x, y):
    """Plain matmul in f32 accumulation (the MXU-friendly contract)."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def srad_step(img, lam=0.05):
    """One SRAD (speckle-reducing anisotropic diffusion) update.

    Follows Rodinia's srad_v1 structure: 4-neighbour gradients, a
    diffusion coefficient from the instantaneous coefficient of variation,
    then a divergence update. Neumann (clamped) boundaries, like the
    benchmark's edge handling.
    """
    n = jnp.roll(img, 1, axis=0).at[0, :].set(img[0, :])
    s = jnp.roll(img, -1, axis=0).at[-1, :].set(img[-1, :])
    w = jnp.roll(img, 1, axis=1).at[:, 0].set(img[:, 0])
    e = jnp.roll(img, -1, axis=1).at[:, -1].set(img[:, -1])
    dn, ds, dw, de = n - img, s - img, w - img, e - img
    g2 = (dn * dn + ds * ds + dw * dw + de * de) / (img * img + 1e-8)
    l_ = (dn + ds + dw + de) / (img + 1e-8)
    num = 0.5 * g2 - 0.0625 * l_ * l_
    den = (1.0 + 0.25 * l_) ** 2
    q = num / (den + 1e-8)
    c = 1.0 / (1.0 + q)
    c = jnp.clip(c, 0.0, 1.0)
    cs = jnp.roll(c, -1, axis=0).at[-1, :].set(c[-1, :])
    ce = jnp.roll(c, -1, axis=1).at[:, -1].set(c[:, -1])
    d = c * dn + cs * ds + c * dw + ce * de
    return img + (lam / 4.0) * d


def haar2d(img):
    """One level of a 2-D Haar wavelet transform (dwt2d analogue).

    Returns the four half-resolution subbands stacked as
    [[LL, LH], [HL, HH]] in a single array of the input shape.
    """
    a = img[0::2, 0::2]
    b = img[0::2, 1::2]
    c = img[1::2, 0::2]
    d = img[1::2, 1::2]
    ll = (a + b + c + d) * 0.5
    lh = (a - b + c - d) * 0.5
    hl = (a + b - c - d) * 0.5
    hh = (a - b - c + d) * 0.5
    top = jnp.concatenate([ll, lh], axis=1)
    bot = jnp.concatenate([hl, hh], axis=1)
    return jnp.concatenate([top, bot], axis=0)

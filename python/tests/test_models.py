"""L2 model semantics + shape contracts for every artifact entry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(key, shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


def args_for(name):
    _, specs = model.ENTRIES[name]
    return [rand(i + 1, s.shape) for i, s in enumerate(specs)]


@pytest.mark.parametrize("name", sorted(model.ENTRIES))
def test_entry_traces_and_output_shapes_stable(name):
    """Every catalogue entry jits at its example shapes, and its outputs
    match the abstract eval (what the manifest records)."""
    fn, specs = model.ENTRIES[name]
    abstract = jax.eval_shape(fn, *specs)
    concrete = jax.jit(fn)(*args_for(name))
    flat_a = jax.tree_util.tree_leaves(abstract)
    flat_c = jax.tree_util.tree_leaves(concrete)
    assert len(flat_a) == len(flat_c)
    for a, c in zip(flat_a, flat_c):
        assert a.shape == c.shape, f"{name}: {a.shape} != {c.shape}"
        assert not bool(jnp.any(jnp.isnan(c))), f"{name}: NaNs in output"


def test_backprop_reduces_loss():
    x, w1, w2, y = args_for("backprop")
    w1n, w2n, loss0 = model.backprop(x, w1, w2, y)
    _, _, loss1 = model.backprop(x, w1n, w2n, y)
    assert float(loss1[0]) < float(loss0[0])


def test_needle_matches_dense_dp():
    """Scan-based NW equals a straightforward O(n^2) python DP."""
    n = 16
    sim = np.asarray(rand(3, (n, n)))
    gap = -0.4
    h = np.zeros((n + 1, n + 1), np.float32)
    h[0, :] = np.arange(n + 1) * gap
    h[:, 0] = np.arange(n + 1) * gap
    for i in range(1, n + 1):
        for j in range(1, n + 1):
            h[i, j] = max(h[i - 1, j - 1] + sim[i - 1, j - 1], h[i - 1, j] + gap, h[i, j - 1] + gap)
    (last,) = model.needle(jnp.asarray(sim), jnp.asarray([gap], jnp.float32))
    np.testing.assert_allclose(last, h[n, 1:], rtol=1e-5, atol=1e-6)


def test_bfs_level_expansion():
    n = 128
    adj = np.zeros((n, n), np.float32)
    adj[0, 1] = adj[1, 2] = adj[2, 3] = 1.0  # a path graph
    frontier = np.zeros((n, n), np.float32)
    frontier[1, 0] = 1.0  # frontier encoded in column 0
    (nxt,) = model.bfs(jnp.asarray(adj).T, jnp.asarray(frontier))
    # node 2 reachable from node 1
    assert nxt[2, 0] == 1.0
    assert nxt[3, 0] == 0.0


def test_lavamd_forces_antisymmetric_for_pair():
    pos = jnp.asarray([[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]] + [[10.0 + i, 0, 0] for i in range(190)])
    charge = jnp.ones((192,))
    (force,) = model.lavamd(pos, charge)
    # pair 0-1 dominates; forces roughly opposite in x
    assert float(force[0, 0]) * float(force[1, 0]) < 0.0


def test_dwt2d_equals_ref():
    (out,) = model.dwt2d(rand(5, (128, 128)))
    np.testing.assert_allclose(out, ref.haar2d(rand(5, (128, 128))), rtol=1e-6)


def test_darknet_predict_is_distribution():
    (probs,) = model.darknet_predict(*args_for("darknet_predict"))
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-5)
    assert float(jnp.min(probs)) >= 0.0


def test_darknet_train_reduces_loss():
    img, w_conv, w_fc, label = args_for("darknet_train")
    label = jax.nn.one_hot(jnp.array([3]), 128)[0][None, :]
    w1, loss0 = model.darknet_train(img, w_conv, w_fc, label)
    for _ in range(5):
        w1, loss = model.darknet_train(img, w_conv, w1, label)
    assert float(loss[0]) < float(loss0[0])


def test_darknet_rnn_state_evolves_and_bounded():
    h_last, y = model.darknet_rnn(*args_for("darknet_rnn"))
    assert float(jnp.max(jnp.abs(h_last))) <= 1.0  # tanh cell
    assert float(jnp.max(jnp.abs(h_last - args_for("darknet_rnn")[0]))) > 1e-3


def test_hotspot_converges_toward_uniform():
    temp = rand(9, (128, 128), lo=0.0, hi=1.0)
    power = jnp.zeros((128, 128))
    out = temp
    for _ in range(10):
        (out,) = model.hotspot(out, power)
    assert float(jnp.std(out)) < float(jnp.std(temp))

"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes/dtypes/block sizes; this is the core correctness
signal for the kernels the AOT artifacts embed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul_tiled import matmul, _matmul_impl, vmem_bytes
from compile.kernels.srad_stencil import srad_step


def rand(key, shape, dtype=jnp.float32, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, dtype, lo, hi)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

dims = st.sampled_from([16, 32, 48, 64, 128, 192, 256])
blocks = st.sampled_from([16, 32, 64, 128])


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**16))
def test_matmul_matches_ref_across_shapes(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 1, (k, n))
    got = matmul(x, y)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(bm=blocks, bn=blocks, bk=blocks, seed=st.integers(0, 2**16))
def test_matmul_block_shape_invariance(bm, bn, bk, seed):
    """The result must not depend on the VMEM tiling."""
    x = rand(seed, (128, 128))
    y = rand(seed + 1, (128, 128))
    got = _matmul_impl(x, y, bm, bn, bk)
    np.testing.assert_allclose(got, ref.matmul(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_untileable_shapes():
    x, y = jnp.ones((100, 128)), jnp.ones((128, 128))
    with pytest.raises(AssertionError):
        _matmul_impl(x, y, 64, 64, 64)


def test_matmul_bf16_inputs_f32_accumulation():
    x = rand(3, (128, 128)).astype(jnp.bfloat16)
    y = rand(4, (128, 128)).astype(jnp.bfloat16)
    got = matmul(x, y)
    assert got.dtype == jnp.bfloat16
    want = jnp.matmul(
        x, y, preferred_element_type=jnp.float32
    ).astype(jnp.bfloat16)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), rtol=2e-2
    )


def test_matmul_grad_matches_jnp_grad():
    """custom_vjp (backward = two more Pallas matmuls) vs jnp autodiff."""
    x = rand(5, (64, 64))
    y = rand(6, (64, 64))

    def f_pallas(x, y):
        return jnp.sum(jnp.tanh(matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.tanh(x @ y))

    gx, gy = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gy, gy_r, rtol=1e-4, atol=1e-5)


def test_matmul_vmem_budget():
    """Default 128^3 f32 tiling stays far under a 16 MiB VMEM budget."""
    assert vmem_bytes(128, 128, 128) < 1 << 20  # 256 KiB + acc


# ---------------------------------------------------------------------------
# srad stencil
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([32, 64, 96, 128]),
    cols=st.sampled_from([16, 64, 128]),
    band=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_srad_matches_ref_across_shapes(rows, cols, band, seed):
    if rows % band:
        band = rows
    img = rand(seed, (rows, cols), lo=0.5, hi=1.5)
    got = srad_step(img, band=band)
    want = ref.srad_step(img)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_srad_band_invariance():
    """Band decomposition must not change the numerics (exact halo)."""
    img = rand(7, (128, 64), lo=0.5, hi=1.5)
    a = srad_step(img, band=8)
    b = srad_step(img, band=64)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_srad_constant_image_is_fixed_point():
    img = jnp.full((64, 64), 2.0)
    out = srad_step(img)
    np.testing.assert_allclose(out, img, rtol=1e-6)


def test_srad_smooths_noise():
    """Diffusion must reduce total variation on a noisy image."""
    img = rand(11, (64, 64), lo=0.5, hi=1.5)

    def tv(a):
        return float(jnp.sum(jnp.abs(jnp.diff(a, axis=0))) + jnp.sum(jnp.abs(jnp.diff(a, axis=1))))

    out = img
    for _ in range(4):
        out = srad_step(out)
    assert tv(out) < tv(img)


# ---------------------------------------------------------------------------
# haar (used by dwt2d model entry)
# ---------------------------------------------------------------------------


def test_haar_energy_preservation():
    """Orthonormal Haar: total energy is preserved."""
    img = rand(13, (64, 64))
    out = ref.haar2d(img)
    np.testing.assert_allclose(
        jnp.sum(img * img), jnp.sum(out * out), rtol=1e-5
    )


def test_haar_constant_image_concentrates_in_ll():
    img = jnp.full((32, 32), 1.0)
    out = ref.haar2d(img)
    np.testing.assert_allclose(out[:16, :16], 2.0, rtol=1e-6)
    assert float(jnp.max(jnp.abs(out[16:, :]))) < 1e-6
    assert float(jnp.max(jnp.abs(out[:, 16:]))) < 1e-6


# ---------------------------------------------------------------------------
# haar dwt
# ---------------------------------------------------------------------------

from compile.kernels.haar_dwt import haar2d as haar2d_pallas, haar2d_subbands


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([32, 64, 128, 192]),
    cols=st.sampled_from([32, 64, 128, 256]),
    bh=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_haar_pallas_matches_ref_across_shapes(rows, cols, bh, seed):
    if (rows // 2) % bh:
        bh = rows // 2
    img = rand(seed, (rows, cols))
    got = haar2d_pallas(img, bh=bh)
    np.testing.assert_allclose(got, ref.haar2d(img), rtol=1e-6, atol=1e-7)


def test_haar_pallas_tile_invariance():
    img = rand(21, (128, 128))
    a = haar2d_pallas(img, bh=8, bw=16)
    b = haar2d_pallas(img, bh=64, bw=64)
    np.testing.assert_allclose(a, b, rtol=1e-7)


def test_haar_pallas_subbands_energy_sums():
    img = rand(22, (64, 64))
    ll, lh, hl, hh = haar2d_subbands(img)
    total = sum(float(jnp.sum(s * s)) for s in (ll, lh, hl, hh))
    np.testing.assert_allclose(total, float(jnp.sum(img * img)), rtol=1e-5)


def test_haar_pallas_rejects_odd_images():
    with pytest.raises(AssertionError):
        haar2d_pallas(jnp.ones((33, 64)))

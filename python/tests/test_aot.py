"""AOT artifact contract: HLO text exists, parses, and the manifest is
consistent with the catalogue. (The rust side re-checks executability in
rust/tests/runtime_roundtrip.rs.)"""

import os

import jax
import pytest

from compile import model
from compile.aot import to_hlo_text

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.txt")),
    reason="run `make artifacts` first",
)


def test_hlo_text_has_entry_computation():
    lowered = model.lower_entry("dwt2d")
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[128,128]" in text


def test_hlo_text_returns_tuple():
    """return_tuple=True is load-bearing for the rust unpacker."""
    text = to_hlo_text(model.lower_entry("dwt2d"))
    root_lines = [l for l in text.splitlines() if "ROOT" in l and "ENTRY" not in l]
    assert any("tuple" in l or "(f32" in l for l in root_lines), root_lines


@needs_artifacts
def test_manifest_covers_all_entries():
    with open(os.path.join(ARTIFACTS, "manifest.txt")) as f:
        names = {line.split(";")[0] for line in f if line.strip()}
    assert names == set(model.ENTRIES)


@needs_artifacts
def test_manifest_shapes_match_catalogue():
    with open(os.path.join(ARTIFACTS, "manifest.txt")) as f:
        for line in f:
            if not line.strip():
                continue
            name, ins, _outs = line.strip().split(";")
            want = ",".join(
                "x".join(str(d) for d in s.shape) for s in model.ENTRIES[name][1]
            )
            assert ins == f"in={want}", f"{name}: {ins} != in={want}"


@needs_artifacts
def test_artifact_files_nonempty():
    for name in model.ENTRIES:
        p = os.path.join(ARTIFACTS, f"{name}.hlo.txt")
        assert os.path.getsize(p) > 500, name


def test_pallas_kernel_lowered_into_hlo_not_custom_call():
    """interpret=True must lower the Pallas kernels to plain HLO ops the
    CPU PJRT client can run (no mosaic custom-calls)."""
    text = to_hlo_text(model.lower_entry("srad"))
    assert "custom-call" not in text or "mosaic" not in text.lower()

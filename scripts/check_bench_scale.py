#!/usr/bin/env python3
"""Gate the `bench scale` sweep (BENCH_SCALE.json) in CI.

Three checks, per rust/src/bench_harness/scale.rs:

1. In-run backend gate (always on): every row's calendar-queue
   events/sec must be >= MIN_SPEEDUP x the BinaryHeap reference
   measured in the *same* run — same machine, same binary, so no
   calibration is needed. The calendar queue exists to be faster; a
   row where it drops below the reference heap is a regression in the
   queue itself.

2. Committed-baseline gate (arms itself once a *measured* baseline is
   committed): each row's calibration-normalised events/sec
   (events_per_s / calibration_events_per_s) must be >= (1 - TOLERANCE)
   of the committed row's. Normalising by the shared heap-backend
   calibration row cancels host-CPU speed, so the gate compares code
   across commits, not runners. A committed file whose provenance is
   not "measured" (the bootstrap placeholder, hand-estimated before
   the first toolchain run) only produces a notice: commit the freshly
   measured file to arm the gate.

3. Compiled-replay gate (rows that carry non-null compile_* columns,
   i.e. the sweep points run with `--compile-traces on`): the
   compile-on run must process the same simulated workload at >=
   MIN_COMPILE_RATIO x the compile-off events/sec of the same run
   (compile_events_per_s uses the compile-OFF event count over the
   compile-on wall time, so the ratio is a pure wall-clock measure —
   the raw fired count shrinks under macro-stepping). In-run, same
   machine, no calibration needed. The row's observable_events column
   must also be present: the engine asserts observable-stream
   invariance between the modes at bench time, and this script
   re-checks the column against the committed baseline's when both
   record it (observable counts are simulated, machine-independent).

Usage:
  check_bench_scale.py --fresh BENCH_SCALE.json [--committed baseline.json]

Exit 0 = pass, 1 = regression, 2 = malformed input.
"""

import argparse
import json
import sys

SCHEMA = "mgb-bench-scale-v1"
# Gate 1: calendar must beat (or at worst approach) the in-run heap
# reference. 0.8 leaves headroom for timing noise on loaded runners;
# the sweep's committed trajectory shows multiples, not fractions.
MIN_SPEEDUP = 0.8
# Gate 2: >20% drop of normalised events/sec vs the committed baseline
# fails the build (the ISSUE's regression threshold).
TOLERANCE = 0.20
# Gate 3: compiled replay must not slow the same workload down — the
# issue's contract is a hard >= 1.0x on the rows that measure it (both
# sides of the ratio are measured back-to-back in one process, so the
# usual cross-runner noise allowance does not apply).
MIN_COMPILE_RATIO = 1.0


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench_scale: cannot read {path}: {e}")
        sys.exit(2)
    if doc.get("schema") != SCHEMA:
        print(f"check_bench_scale: {path}: schema {doc.get('schema')!r} != {SCHEMA!r}")
        sys.exit(2)
    for key in ("provenance", "calibration_events_per_s", "rows"):
        if key not in doc:
            print(f"check_bench_scale: {path}: missing key {key!r}")
            sys.exit(2)
    for row in doc["rows"]:
        for key in ("label", "nodes", "events", "peak_events",
                    "baseline_events_per_s", "events_per_s"):
            if key not in row:
                print(f"check_bench_scale: {path}: row missing {key!r}: {row}")
                sys.exit(2)
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="BENCH_SCALE.json written by this run")
    ap.add_argument("--committed",
                    help="baseline BENCH_SCALE.json from git (omit to skip gate 2)")
    args = ap.parse_args()

    fresh = load(args.fresh)
    failures = []

    # -- gate 1: in-run calendar-vs-heap ------------------------------
    for row in fresh["rows"]:
        base = row["baseline_events_per_s"]
        cur = row["events_per_s"]
        speedup = cur / base if base > 0 else 0.0
        mark = "ok" if speedup >= MIN_SPEEDUP else "FAIL"
        print(f"  [{mark}] {row['label']:<12} heap={base:>12.0f} ev/s  "
              f"calendar={cur:>12.0f} ev/s  speedup={speedup:6.2f}x  "
              f"peak_events={row['peak_events']}")
        if speedup < MIN_SPEEDUP:
            failures.append(
                f"{row['label']}: calendar {cur:.0f} ev/s < "
                f"{MIN_SPEEDUP}x heap reference {base:.0f} ev/s")

    # -- gate 3: compiled replay vs compile-off, in-run ---------------
    for row in fresh["rows"]:
        ceps = row.get("compile_events_per_s")
        if ceps is None:
            continue
        cur = row["events_per_s"]
        ratio = ceps / cur if cur > 0 else 0.0
        mark = "ok" if ratio >= MIN_COMPILE_RATIO else "FAIL"
        print(f"  [{mark}] {row['label']:<12} compile-off={cur:>12.0f} ev/s  "
              f"compile-on={ceps:>12.0f} ev/s  ratio={ratio:6.2f}x  "
              f"compile_events={row.get('compile_events')}")
        if ratio < MIN_COMPILE_RATIO:
            failures.append(
                f"{row['label']}: compiled replay {ceps:.0f} ev/s < "
                f"{MIN_COMPILE_RATIO}x compile-off {cur:.0f} ev/s")
        if row.get("observable_events") is None:
            failures.append(
                f"{row['label']}: compile columns present but "
                f"observable_events missing — cannot audit the "
                f"observable-stream invariance")

    # -- gate 2: normalised trajectory vs committed baseline ----------
    if args.committed:
        committed = load(args.committed)
        if committed.get("provenance") != "measured":
            print(f"  committed baseline provenance is "
                  f"{committed.get('provenance')!r} (not 'measured'); "
                  f"regression gate not armed — commit a freshly measured "
                  f"BENCH_SCALE.json to arm it")
        else:
            calib_new = fresh["calibration_events_per_s"]
            calib_old = committed["calibration_events_per_s"]
            if calib_new <= 0 or calib_old <= 0:
                print("check_bench_scale: non-positive calibration")
                sys.exit(2)
            old_rows = {r["label"]: r for r in committed["rows"]}
            for row in fresh["rows"]:
                old = old_rows.get(row["label"])
                if old is None:
                    print(f"  [new ] {row['label']}: no committed row, skipping")
                    continue
                norm_new = row["events_per_s"] / calib_new
                norm_old = old["events_per_s"] / calib_old
                ratio = norm_new / norm_old if norm_old > 0 else 0.0
                mark = "ok" if ratio >= 1.0 - TOLERANCE else "FAIL"
                print(f"  [{mark}] {row['label']:<12} normalised "
                      f"{norm_old:8.3f} -> {norm_new:8.3f}  ({ratio:6.2%})")
                if ratio < 1.0 - TOLERANCE:
                    failures.append(
                        f"{row['label']}: normalised events/sec fell "
                        f"{1.0 - ratio:.1%} vs committed baseline "
                        f"(tolerance {TOLERANCE:.0%})")
                # Simulated columns are machine-independent: a changed
                # event count against the same committed workload means
                # the engine's behaviour changed, which belongs in the
                # golden-trace diff, not a silent perf delta.
                if row["events"] != old["events"]:
                    failures.append(
                        f"{row['label']}: fired {row['events']} events, "
                        f"committed baseline fired {old['events']} "
                        f"(determinism drift)")
                # The observable subset is likewise simulated and
                # machine-independent; older baselines predate the
                # column, so only compare when both sides record it.
                if (row.get("observable_events") is not None
                        and old.get("observable_events") is not None
                        and row["observable_events"] != old["observable_events"]):
                    failures.append(
                        f"{row['label']}: {row['observable_events']} "
                        f"observable events, committed baseline "
                        f"{old['observable_events']} (observable-stream "
                        f"drift)")

    if failures:
        print("\ncheck_bench_scale: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\ncheck_bench_scale: pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())

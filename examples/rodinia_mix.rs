//! Rodinia batch scheduling across all four schedulers on one workload
//! (paper §V-C/V-D for a single W): shows throughput, turnaround, crash
//! and slowdown side by side.
//!
//! ```bash
//! cargo run --release --example rodinia_mix [W1..W8]
//! ```

use mgb::bench_harness::{best_cg, mgb_workers, DEFAULT_SEED};
use mgb::coordinator::{run_batch, RunConfig, SchedMode};
use mgb::gpu::NodeSpec;
use mgb::workloads::Workload;

fn main() {
    let wid = std::env::args().nth(1).unwrap_or_else(|| "W2".to_string());
    let workload = Workload::by_id(&wid).unwrap_or_else(|| {
        eprintln!("unknown workload {wid}, use W1..W8");
        std::process::exit(2);
    });
    let node = NodeSpec::v100x4();
    let jobs = workload.jobs(DEFAULT_SEED);
    println!(
        "{}: {} jobs ({} large : {} small) on {}",
        workload.id,
        jobs.len(),
        jobs.iter().filter(|j| j.class == mgb::coordinator::JobClass::Large).count(),
        jobs.iter().filter(|j| j.class == mgb::coordinator::JobClass::Small).count(),
        node.name
    );
    println!(
        "\n{:<10} {:>9} {:>12} {:>12} {:>9} {:>10}",
        "scheduler", "workers", "makespan", "throughput", "crashed", "slowdown"
    );

    let sa = run_batch(RunConfig { node: node.clone(), mode: SchedMode::Sa, workers: 0 }, jobs.clone());
    let (cg_w, cg) = best_cg(&node, &jobs);
    let workers = mgb_workers(&node);
    let rows = vec![
        ("SA", sa.workers, sa),
        ("CG", cg_w, cg),
        (
            "MGB-Alg2",
            workers,
            run_batch(
                RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb2"), workers },
                jobs.clone(),
            ),
        ),
        (
            "MGB-Alg3",
            workers,
            run_batch(
                RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb3"), workers },
                jobs.clone(),
            ),
        ),
        (
            "schedGPU",
            workers,
            run_batch(RunConfig { node, mode: SchedMode::Policy("schedgpu"), workers }, jobs),
        ),
    ];
    let sa_tp = rows[0].2.throughput();
    for (name, w, r) in rows {
        println!(
            "{:<10} {:>9} {:>10.1}s {:>8.4} j/s {:>8}% {:>9.2}%   ({:.2}x SA)",
            name,
            w,
            r.makespan,
            r.throughput(),
            r.crash_pct() as u32,
            r.kernel_slowdown_pct(),
            r.throughput() / sa_tp
        );
    }
}

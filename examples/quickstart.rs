//! Quickstart: author a CUDA-like host program, run the compiler pass,
//! inspect the GPU task + probe it produces, and schedule it on a
//! simulated 4-GPU node.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mgb::compiler::compile;
use mgb::coordinator::{run_batch, JobClass, JobSpec, RunConfig, SchedMode};
use mgb::gpu::NodeSpec;
use mgb::ir::{Expr, ProgramBuilder};
use mgb::lazy::interpret;

fn main() {
    // 1. The vector-add application from the paper's Fig. 3, as IR.
    let mut pb = ProgramBuilder::new();
    pb.func("main", 1, |f| {
        let n = f.param(0);
        let sz = f.assign(Expr::v(n).mul(Expr::c(4))); // N f32 elements
        let d_a = f.malloc(sz);
        let d_b = f.malloc(sz);
        let d_c = f.malloc(sz);
        f.h2d(d_a, sz);
        f.h2d(d_b, sz);
        let grid = f.assign(Expr::v(n).ceil_div(Expr::c(128)));
        let block = f.c(128);
        let work = f.c(250_000); // 0.25 s of V100 work
        f.launch("VecAdd", grid, block, &[d_a, d_b, d_c], work);
        f.d2h(d_c, sz);
        f.free(d_a);
        f.free(d_b);
        f.free(d_c);
    });
    let program = pb.finish();
    println!("--- host IR ---\n{program}");

    // 2. Compiler pass: task construction (Alg. 1) + probe insertion.
    let compiled = compile(&program);
    for t in &compiled.tasks {
        println!(
            "GPU task {}: {} kernel launch(es), {} memory object(s), lazy={}",
            t.id,
            t.launches.len(),
            t.mem_objs.len(),
            t.lazy
        );
        println!("  probe conveys: mem = {}, grid = {}, block = {}", t.mem_bytes, t.grid, t.block);
    }

    // 3. Lazy runtime: interpret with N = 64M floats -> schedulable trace.
    let trace = interpret(&compiled, &[64 << 20]).expect("interpret");
    println!(
        "\ntrace: {} events, {} task(s), peak reserved {} MiB",
        trace.events.len(),
        trace.n_tasks(),
        trace.peak_reserved_bytes() >> 20
    );

    // 4. Schedule 12 copies on a 4xV100 node under MGB (Alg. 3).
    let jobs: Vec<JobSpec> = (0..12)
        .map(|i| JobSpec {
            name: format!("vecadd-{i}"),
            class: JobClass::Small,
            trace: trace.clone(),
            arrival: 0.0,
            slo: None,
        })
        .collect();
    let result = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 8 },
        jobs,
    );
    println!(
        "\nMGB: {} jobs in {:.2}s ({:.2} jobs/s), kernel slowdown {:.2}%",
        result.completed(),
        result.makespan,
        result.throughput(),
        result.kernel_slowdown_pct()
    );
}

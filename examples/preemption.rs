//! Checkpoint/restart preemption demo: a long-running light "hog"
//! holds 12 of a V100's 16 GB while short heavy jobs arrive late. The
//! admit-or-wait scheduler (the paper's) makes every heavy wait out the
//! hog; with preemption enabled the hog is checkpointed, the heavies
//! run immediately, and the hog restores afterwards — heavy turnaround
//! collapses at the price of a bounded amount of wasted work.
//!
//! ```bash
//! cargo run --release --example preemption [ckpt_base_seconds]
//! ```

use mgb::coordinator::{run_cluster, ClusterConfig, JobClass, SchedMode};
use mgb::gpu::{ClusterSpec, GpuSpec, NodeSpec};
use mgb::sched::PreemptConfig;
use mgb::workloads::synthetic_job;

fn main() {
    let ckpt_base: f64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.05);
    let node = NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
    let jobs = vec![
        synthetic_job("light-hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
        synthetic_job("heavy-0", JobClass::Large, 12 << 30, 8_000_000, 5.0),
        synthetic_job("heavy-1", JobClass::Large, 12 << 30, 8_000_000, 35.0),
        synthetic_job("heavy-2", JobClass::Large, 12 << 30, 8_000_000, 65.0),
    ];
    let cfg = |preempt: Option<PreemptConfig>| ClusterConfig {
        cluster: ClusterSpec::single(node.clone()),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "rr",
        preempt,
        latency: mgb::gpu::LatencyModel::off(),
    };
    println!(
        "1xV100 (16 GB): 120s hog holding 12 GB vs three 8s heavies \
         arriving late (ckpt base cost {ckpt_base}s)\n"
    );
    println!(
        "{:<14} {:>12} {:>12} {:>10} {:>8} {:>9} {:>10}",
        "preempt", "heavy_turn", "light_turn", "makespan", "evicts", "wasted", "overhead"
    );
    // Budget 3: each heavy may claim one eviction of the hog.
    let policies: Vec<(&str, Option<PreemptConfig>)> = vec![
        ("off", None),
        (
            "min-progress",
            Some(PreemptConfig {
                policy: "min-progress",
                ckpt_base_s: ckpt_base,
                max_preemptions: 3,
                ..Default::default()
            }),
        ),
        (
            "max-mem",
            Some(PreemptConfig {
                policy: "max-mem",
                ckpt_base_s: ckpt_base,
                max_preemptions: 3,
                ..Default::default()
            }),
        ),
    ];
    for (label, p) in policies {
        let r = run_cluster(cfg(p), jobs.clone());
        println!(
            "{:<14} {:>11.1}s {:>11.1}s {:>9.1}s {:>8} {:>8.1}s {:>9.1}s",
            label,
            r.mean_turnaround_of(JobClass::Large),
            r.mean_turnaround_of(JobClass::Small),
            r.makespan,
            r.preemptions,
            r.wasted_work_s,
            r.ckpt_overhead_s
        );
        for j in &r.jobs {
            if j.preemptions > 0 {
                println!(
                    "    {} preempted {}x, {:.1}s of kernel progress lost",
                    j.name, j.preemptions, j.wasted_s
                );
            }
        }
    }
    println!(
        "\n(the hog pays with a longer turnaround; every heavy stops \
         waiting out a 120s kernel it cannot share memory with)"
    );
}

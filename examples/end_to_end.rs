//! End-to-end driver: the full three-layer system on a real workload.
//!
//! All layers compose here:
//!   L1/L2 — the JAX/Pallas kernels were AOT-lowered to HLO text
//!           (`make artifacts`); this binary loads them via PJRT and
//!           **executes real numerics** for the kernels the scheduler
//!           places (first launch of each artifact per job; repeats are
//!           counted — re-running identical numerics adds no signal).
//!   L3   — a 20-job Rodinia+Darknet batch is authored as host IR,
//!           compiled (task construction + probes), interpreted by the
//!           lazy runtime, and scheduled by MGB (Alg. 3) on a simulated
//!           4xV100 node; SA runs the same batch as the baseline.
//!
//! Reports the paper's headline metric (throughput vs SA) plus the
//! real-compute validation. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use mgb::coordinator::{run_batch_with_hook, RunConfig, SchedMode};
use mgb::gpu::NodeSpec;
use mgb::runtime::KernelRegistry;
use mgb::workloads::{NN_TASKS, COMBOS};
use std::collections::HashMap;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let reg = KernelRegistry::new(&dir)?;
    if reg.available().is_empty() {
        anyhow::bail!("no artifacts in {dir}/ — run `make artifacts` first");
    }

    // The batch: one job per Rodinia combo (17) + one per NN task (4).
    let mut jobs = Vec::new();
    for c in &COMBOS {
        jobs.push(c.job_spec());
    }
    for t in NN_TASKS {
        jobs.push(t.job_spec());
    }
    println!("batch: {} jobs (every Rodinia combo + every NN task)", jobs.len());

    // Real-compute hook: run each distinct artifact's numerics once,
    // verify outputs are finite, count every placed launch.
    let mut executed: HashMap<String, u64> = HashMap::new();
    let mut checked = 0usize;
    {
        let mut hook = |artifact: &str| {
            let n = executed.entry(artifact.to_string()).or_insert(0);
            *n += 1;
            if *n == 1 {
                match reg.run_synthetic(artifact) {
                    Ok(outs) => {
                        checked += 1;
                        println!(
                            "  PJRT {:<18} executed: {} output tensor(s), all finite",
                            artifact,
                            outs.len()
                        );
                    }
                    Err(e) => panic!("real compute failed for {artifact}: {e}"),
                }
            }
        };

        let node = NodeSpec::v100x4();
        println!("\n-- MGB (Alg. 3), 16 workers, real compute --");
        let mgb = run_batch_with_hook(
            RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb3"), workers: 16 },
            jobs.clone(),
            Some(&mut hook),
        );

        println!("\n-- SA baseline --");
        let sa = run_batch_with_hook(
            RunConfig { node, mode: SchedMode::Sa, workers: 0 },
            jobs,
            None,
        );

        let total_launches: u64 = executed.values().sum();
        println!("\n=== end-to-end result ===");
        println!(
            "real compute: {} distinct kernels validated via PJRT, {} launches placed",
            checked, total_launches
        );
        println!(
            "SA : makespan {:>7.1}s  throughput {:.4} j/s  crashed {}",
            sa.makespan,
            sa.throughput(),
            sa.crashed()
        );
        println!(
            "MGB: makespan {:>7.1}s  throughput {:.4} j/s  crashed {}  kernel slowdown {:.2}%",
            mgb.makespan,
            mgb.throughput(),
            mgb.crashed(),
            mgb.kernel_slowdown_pct()
        );
        let speedup = mgb.throughput() / sa.throughput();
        println!("headline: MGB {speedup:.2}x SA throughput (paper: ~2x on 4xV100)");
        assert!(mgb.crashed() == 0, "MGB must be memory-safe");
        assert!(speedup > 1.3, "expected >1.3x, got {speedup:.2}");
    }
    Ok(())
}

//! The paper's Fig. 1 motivating example, executable.
//!
//! Two applications with two parallel kernels each, on a 2-GPU node
//! (16 GB per device). Each app, written as if it owned the node,
//! statically maps its first kernel to device0 and its second to
//! device1. Shared, that mapping puts k1+k3 (SM-heavy) together on
//! device0 — overload and slowdown — and k2+k4 (memory-heavy, 10+9 GB)
//! together on device1 — OOM crash. MGB's dynamic, resource-aware
//! placement finds the k1+k4 / k2+k3 packing: nothing crashes, nothing
//! slows down.
//!
//! ```bash
//! cargo run --release --example motivation
//! ```

use mgb::coordinator::{run_batch, JobClass, JobSpec, RunConfig, SchedMode};
use mgb::gpu::NodeSpec;
use mgb::lazy::{JobTrace, TaskResources, TraceEvent};

/// One kernel of Fig. 1 as a schedulable unit (warps fraction of a P100,
/// GiB of device memory, 20 s of work).
fn kernel(name: &str, warps: u64, mem_gib: u64) -> JobSpec {
    let res = TaskResources { static_dev: None, mem_bytes: mem_gib << 30, heap_bytes: 0, grid: warps, block: 32 };
    JobSpec {
        name: name.into(),
        class: JobClass::Large,
        arrival: 0.0,
        slo: None,
        trace: JobTrace {
            events: vec![
                TraceEvent::TaskBegin { task: 0, res },
                TraceEvent::Malloc { task: 0, bytes: res.mem_bytes },
                TraceEvent::H2D { task: 0, bytes: res.mem_bytes / 4 },
                TraceEvent::Launch {
                    task: 0,
                    kernel: name.into(),
                    artifact: None,
                    grid: warps,
                    block: 32,
                    work_us: 20_000_000,
                },
                TraceEvent::Free { task: 0, bytes: res.mem_bytes },
                TraceEvent::TaskEnd { task: 0 },
            ],
        },
    }
}

fn main() {
    let node = NodeSpec::p100x2();
    let cap = node.gpus[0].warp_capacity();
    // Fig. 1 shapes: k1/k3 SM-heavy with modest memory, k2/k4 the
    // reverse. In job order k1, k2, k3, k4 the static mapping (4 pinned
    // workers, round-robin) puts k1+k3 on dev0, k2+k4 on dev1.
    let jobs = vec![
        kernel("app1-k1", cap * 9 / 10, 4),
        kernel("app1-k2", cap * 2 / 10, 10),
        kernel("app2-k3", cap * 85 / 100, 5),
        kernel("app2-k4", cap * 3 / 10, 9),
    ];

    println!("-- static per-app mapping (each app assumes a dedicated node) --");
    let cg = run_batch(
        RunConfig { node: node.clone(), mode: SchedMode::Cg, workers: 4 },
        jobs.clone(),
    );
    for j in &cg.jobs {
        println!(
            "  {:<9} {}  slowdown {:+.1}%",
            j.name,
            if j.crashed { "CRASHED (OOM)" } else { "ok           " },
            100.0 * j.kernel_slowdown()
        );
    }
    println!(
        "  completed {}, crashed {}, kernel slowdown {:.1}%",
        cg.completed(),
        cg.crashed(),
        cg.kernel_slowdown_pct()
    );

    println!("\n-- MGB dynamic placement (probes + Alg. 3) --");
    let mgb = run_batch(
        RunConfig { node, mode: SchedMode::Policy("mgb3"), workers: 4 },
        jobs,
    );
    for j in &mgb.jobs {
        println!(
            "  {:<9} {}  slowdown {:+.1}%",
            j.name,
            if j.crashed { "CRASHED (OOM)" } else { "ok           " },
            100.0 * j.kernel_slowdown()
        );
    }
    println!(
        "  completed {}, crashed {}, kernel slowdown {:.1}%",
        mgb.completed(),
        mgb.crashed(),
        mgb.kernel_slowdown_pct()
    );
    assert_eq!(mgb.crashed(), 0, "MGB must be memory-safe");
    assert!(cg.crashed() > 0, "the static mapping must OOM (k2+k4 = 19 GB)");
}

//! Multi-node cluster dispatch: drive a sustained Rodinia stream (W6,
//! Poisson arrivals) across an N-node cluster of 4xV100 machines and
//! compare the three dispatchers side by side. Per-node scheduling is
//! the paper's MGB Alg. 3 in every row — only the cluster-level routing
//! changes.
//!
//! ```bash
//! cargo run --release --example cluster_dispatch [nodes] [rate_jobs_per_s]
//! ```

use mgb::bench_harness::{mgb_workers, DEFAULT_SEED};
use mgb::coordinator::{run_cluster, ClusterConfig, SchedMode};
use mgb::gpu::{ClusterSpec, NodeSpec};
use mgb::workloads::{poisson_arrivals, Workload};

fn main() {
    let nodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let rate: f64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.35 * nodes as f64);
    let node = NodeSpec::v100x4();
    let w6 = Workload::by_id("W6").unwrap();

    // One W6 mix per node, stamped with one shared Poisson process.
    let mut jobs = Vec::new();
    for k in 0..nodes as u64 {
        jobs.extend(w6.jobs(DEFAULT_SEED.wrapping_add(k)));
    }
    poisson_arrivals(&mut jobs, rate, DEFAULT_SEED);
    println!(
        "{} jobs over {} nodes ({} GPUs), Poisson {:.2} jobs/s, last arrival {:.1}s\n",
        jobs.len(),
        nodes,
        ClusterSpec::homogeneous(node.clone(), nodes).total_gpus(),
        rate,
        jobs.last().map(|j| j.arrival).unwrap_or(0.0)
    );
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>9}   per-node jobs",
        "dispatch", "makespan", "throughput", "turnaround", "crashed"
    );
    for dispatch in ["rr", "least", "mem"] {
        let cfg = ClusterConfig {
            cluster: ClusterSpec::homogeneous(node.clone(), nodes),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: mgb_workers(&node),
            dispatch,
            preempt: None,
            latency: mgb::gpu::LatencyModel::off(),
        };
        let r = run_cluster(cfg, jobs.clone());
        println!(
            "{:<10} {:>10.1}s {:>9.4}j/s {:>10.1}s {:>9}   {:?}",
            dispatch,
            r.makespan,
            r.throughput(),
            r.mean_turnaround(),
            r.crashed(),
            r.jobs_per_node()
        );
    }
    println!("\n(per-node placement: mgb3; only the cluster-level dispatcher varies)");
}

//! Darknet workloads (§V-E): schedGPU vs MGB on homogeneous NN batches,
//! plus real PJRT execution of the NN models — prediction produces a
//! probability distribution and a train step reduces the loss.
//!
//! ```bash
//! make artifacts && cargo run --release --example darknet_serve
//! ```

use mgb::coordinator::{run_batch, RunConfig, SchedMode};
use mgb::gpu::NodeSpec;
use mgb::runtime::KernelRegistry;
use mgb::workloads::{nn_homogeneous, NN_TASKS};

fn main() -> anyhow::Result<()> {
    // --- real model numerics through PJRT ---------------------------
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    if let Ok(reg) = KernelRegistry::new(&dir) {
        if reg.available().iter().any(|n| n == "darknet_predict") {
            let outs = reg.run_synthetic("darknet_predict")?;
            let probs = &outs[0];
            let sum: f32 = probs.iter().sum();
            println!(
                "darknet_predict: softmax over {} classes sums to {:.5} (want 1.0)",
                probs.len(),
                sum
            );
            assert!((sum - 1.0).abs() < 1e-3);

            // Train: run three SGD steps on a one-hot label, feeding the
            // updated fc weights back in; the cross-entropy must fall.
            let manifest = reg.manifest()?;
            let shapes = &manifest.iter().find(|(n, _)| n == "darknet_train").unwrap().1;
            let mk = |i: usize| -> Vec<f32> {
                let n: usize = shapes[i].iter().product();
                (0..n).map(|j| 0.55 + 0.4 * ((j as f32 * 0.137 + i as f32).sin())).collect()
            };
            let (img, w_conv) = (mk(0), mk(1));
            let mut w_fc = mk(2);
            let mut label = vec![0.0f32; shapes[3].iter().product()];
            label[3] = 1.0; // class 3
            let exe = reg.get("darknet_train")?;
            let mut losses = Vec::new();
            for _ in 0..3 {
                let outs = exe.run_f32(&[
                    (&img, &shapes[0]),
                    (&w_conv, &shapes[1]),
                    (&w_fc, &shapes[2]),
                    (&label, &shapes[3]),
                ])?;
                losses.push(outs[1][0]);
                w_fc = outs[0].clone();
            }
            println!("darknet_train: loss over 3 SGD steps: {losses:?}");
            assert!(losses[2] < losses[0], "training must reduce the loss");
        }
    } else {
        println!("(no artifacts/ — skipping real-compute validation)");
    }

    // --- Fig. 6 scheduling comparison --------------------------------
    let node = NodeSpec::v100x4();
    println!("\n{:<12} {:>14} {:>12} {:>8}", "task", "schedGPU (j/s)", "MGB (j/s)", "ratio");
    for t in NN_TASKS {
        let jobs = nn_homogeneous(t);
        let sg = run_batch(
            RunConfig { node: node.clone(), mode: SchedMode::Policy("schedgpu"), workers: 8 },
            jobs.clone(),
        );
        let mgb = run_batch(
            RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb3"), workers: 8 },
            jobs,
        );
        println!(
            "{:<12} {:>14.4} {:>12.4} {:>7.2}x",
            t.profile().name,
            sg.throughput(),
            mgb.throughput(),
            mgb.throughput() / sg.throughput()
        );
    }
    Ok(())
}

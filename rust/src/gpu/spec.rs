//! Device and node specifications (P100 / V100 presets from §V).
//!
//! Paper map: §V-A's two platforms — the Chameleon 2×P100 node and the
//! AWS p3.8xlarge 4×V100 node (Table I) — plus the warp/TB capacity
//! arithmetic Algorithms 2 and 3 reason in (§IV). [`ClusterSpec`] is
//! the beyond-paper scale-out target: N possibly-heterogeneous nodes
//! under one dispatcher, each node's relative speed summarised by
//! [`NodeSpec::compute_capacity`] for capability-normalised routing.

/// Static description of one GPU.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    /// Streaming multiprocessors.
    pub sms: u32,
    /// Max resident warps per SM (2048 threads / 32).
    pub warps_per_sm: u32,
    /// Max resident thread blocks per SM.
    pub tbs_per_sm: u32,
    /// Global memory, bytes.
    pub mem_bytes: u64,
    /// Relative compute speed; 1.0 = V100 (the `work_us` reference).
    pub speed: f64,
}

impl GpuSpec {
    /// NVIDIA P100: 56 SMs, 3584 cores, 16 GB.
    pub fn p100() -> Self {
        GpuSpec {
            sms: 56,
            warps_per_sm: 64,
            tbs_per_sm: 32,
            mem_bytes: 16 << 30,
            speed: 3584.0 / 5120.0,
        }
    }

    /// NVIDIA V100: 80 SMs, 5120 cores, 16 GB (the work-unit reference).
    pub fn v100() -> Self {
        GpuSpec {
            sms: 80,
            warps_per_sm: 64,
            tbs_per_sm: 32,
            mem_bytes: 16 << 30,
            speed: 1.0,
        }
    }

    /// Total warp slots (the compute capacity the schedulers reason in).
    pub fn warp_capacity(&self) -> u64 {
        self.sms as u64 * self.warps_per_sm as u64
    }

    /// Total thread-block slots.
    pub fn tb_capacity(&self) -> u64 {
        self.sms as u64 * self.tbs_per_sm as u64
    }

    /// Max thread blocks of `warps_per_tb`-warp TBs resident at once on
    /// an otherwise-empty device (both TB-slot and warp limited).
    pub fn resident_tb_limit(&self, warps_per_tb: u64) -> u64 {
        if warps_per_tb == 0 {
            return self.tb_capacity();
        }
        let per_sm = (self.warps_per_sm as u64 / warps_per_tb).min(self.tbs_per_sm as u64);
        per_sm * self.sms as u64
    }
}

/// One multi-GPU compute node.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub gpus: Vec<GpuSpec>,
    /// Host CPU worker slots available for the worker pool sweep (the
    /// paper's nodes: 12-core Xeon for 2×P100, 32-core for 4×V100).
    pub cpu_cores: u32,
    pub name: String,
}

impl NodeSpec {
    /// The paper's Chameleon node: 2×P100 + 12-core Xeon E5-2670.
    pub fn p100x2() -> Self {
        NodeSpec { gpus: vec![GpuSpec::p100(); 2], cpu_cores: 12, name: "2xP100".into() }
    }

    /// The paper's AWS p3.8xlarge: 4×V100 + 32 vCPU.
    pub fn v100x4() -> Self {
        NodeSpec { gpus: vec![GpuSpec::v100(); 4], cpu_cores: 32, name: "4xV100".into() }
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// Relative compute capability of the node: the sum of its GPUs'
    /// speeds in V100 units (one V100 == 1.0). A 4×V100 node is 4.0, a
    /// 2×P100 node 1.4 — the normaliser heterogeneous-aware dispatch
    /// divides outstanding work by.
    pub fn compute_capacity(&self) -> f64 {
        self.gpus.iter().map(|g| g.speed).sum()
    }
}

/// A cluster of compute nodes — the beyond-paper scale-out target. The
/// dispatcher layer (`sched::dispatch`) routes jobs across `nodes`;
/// each node keeps its own devices, worker pool, and policy instance.
/// Nodes may be heterogeneous (e.g. a P100 node next to V100 nodes).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
    pub name: String,
}

impl ClusterSpec {
    /// A one-node cluster: the paper's deployments. Keeps the node's
    /// name so single-node results read identically to `run_batch`.
    pub fn single(node: NodeSpec) -> Self {
        let name = node.name.clone();
        ClusterSpec { nodes: vec![node], name }
    }

    /// `n` identical nodes.
    pub fn homogeneous(node: NodeSpec, n: usize) -> Self {
        assert!(n > 0, "a cluster needs at least one node");
        let name = format!("{}x[{}]", n, node.name);
        ClusterSpec { nodes: vec![node; n], name }
    }

    /// An explicit (possibly heterogeneous) node list, e.g. a P100 node
    /// next to V100 nodes. The name concatenates the member names.
    pub fn of(nodes: Vec<NodeSpec>) -> Self {
        assert!(!nodes.is_empty(), "a cluster needs at least one node");
        let name = nodes.iter().map(|n| n.name.as_str()).collect::<Vec<_>>().join("+");
        ClusterSpec { nodes, name }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.n_gpus()).sum()
    }
}

/// PCIe gen3 x16 effective host<->device bandwidth (B/s).
pub const PCIE_BYTES_PER_SEC: f64 = 12.0e9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cluster_keeps_node_name() {
        let c = ClusterSpec::single(NodeSpec::v100x4());
        assert_eq!(c.n_nodes(), 1);
        assert_eq!(c.name, "4xV100");
        assert_eq!(c.total_gpus(), 4);
    }

    #[test]
    fn homogeneous_cluster_replicates_nodes() {
        let c = ClusterSpec::homogeneous(NodeSpec::p100x2(), 3);
        assert_eq!(c.n_nodes(), 3);
        assert_eq!(c.total_gpus(), 6);
        assert!(c.name.contains("2xP100"));
    }

    #[test]
    fn mixed_cluster_and_capability() {
        let c = ClusterSpec::of(vec![NodeSpec::p100x2(), NodeSpec::v100x4()]);
        assert_eq!(c.n_nodes(), 2);
        assert_eq!(c.total_gpus(), 6);
        assert_eq!(c.name, "2xP100+4xV100");
        let p100 = c.nodes[0].compute_capacity();
        let v100 = c.nodes[1].compute_capacity();
        assert!((p100 - 2.0 * (3584.0 / 5120.0)).abs() < 1e-12);
        assert!((v100 - 4.0).abs() < 1e-12);
    }
}

//! Device and node specifications (P100 / V100 presets from §V).
//!
//! Paper map: §V-A's two platforms — the Chameleon 2×P100 node and the
//! AWS p3.8xlarge 4×V100 node (Table I) — plus the warp/TB capacity
//! arithmetic Algorithms 2 and 3 reason in (§IV). [`ClusterSpec`] is
//! the beyond-paper scale-out target: N possibly-heterogeneous nodes
//! under one dispatcher, each node's relative speed summarised by
//! [`NodeSpec::compute_capacity`] for capability-normalised routing.

/// Per-kernel resource-pressure profile — the interference vector of
/// arXiv 2501.16909, which shows GPU co-residency contention is
/// *resource-specific* rather than a flat co-residency tax. Each
/// component is the fraction of the corresponding device resource the
/// kernel demands when running dedicated (0 = does not touch it,
/// 1 = saturates it alone). The all-zero profile (the `Default`) is the
/// pre-interference idealisation: kernels carrying it neither slow
/// others down nor are slowed beyond the processor-sharing model, so
/// zero-vector runs stay bit-identical to the legacy device model
/// (enforced by the golden traces and the zero-vector property test).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InterferenceProfile {
    /// DRAM bandwidth share demanded (fraction of device bandwidth).
    pub mem_bw: f64,
    /// L2 footprint class (fraction of L2 capacity the working set
    /// wants resident; evictions past 1.0 aggregate demand hurt).
    pub l2: f64,
    /// SM issue-slot occupancy pressure (fraction of issue bandwidth).
    pub sm: f64,
}

impl InterferenceProfile {
    /// The all-zero profile: no modeled interference at all.
    pub const ZERO: InterferenceProfile = InterferenceProfile { mem_bw: 0.0, l2: 0.0, sm: 0.0 };

    pub fn new(mem_bw: f64, l2: f64, sm: f64) -> Self {
        InterferenceProfile { mem_bw, l2, sm }
    }

    /// True iff every component is exactly zero — the device model's
    /// fast path selector (zero aggregate pressure must take the exact
    /// legacy code path, not a `x / 1.0` detour).
    pub fn is_zero(&self) -> bool {
        self.mem_bw == 0.0 && self.l2 == 0.0 && self.sm == 0.0
    }

    /// Copy with every component clamped to [0, 1]: a dedicated kernel
    /// cannot demand more than the whole device, and negative pressure
    /// would subtract slowdown from co-residents.
    pub fn sanitized(&self) -> Self {
        let c = |x: f64| x.clamp(0.0, 1.0);
        InterferenceProfile { mem_bw: c(self.mem_bw), l2: c(self.l2), sm: c(self.sm) }
    }

    /// Componentwise sum (aggregate pressure of co-residents).
    pub fn add(&self, o: &InterferenceProfile) -> Self {
        InterferenceProfile {
            mem_bw: self.mem_bw + o.mem_bw,
            l2: self.l2 + o.l2,
            sm: self.sm + o.sm,
        }
    }

    /// Componentwise subtraction clamped at zero (uncharging a job
    /// from a node's aggregate without floating-point underflow going
    /// negative).
    pub fn sub_clamped(&self, o: &InterferenceProfile) -> Self {
        InterferenceProfile {
            mem_bw: (self.mem_bw - o.mem_bw).max(0.0),
            l2: (self.l2 - o.l2).max(0.0),
            sm: (self.sm - o.sm).max(0.0),
        }
    }

    /// Componentwise max (a trace's peak profile over its tasks).
    pub fn max(&self, o: &InterferenceProfile) -> Self {
        InterferenceProfile {
            mem_bw: self.mem_bw.max(o.mem_bw),
            l2: self.l2.max(o.l2),
            sm: self.sm.max(o.sm),
        }
    }

    /// Largest single component — the bottleneck resource's pressure.
    pub fn max_component(&self) -> f64 {
        self.mem_bw.max(self.l2).max(self.sm)
    }
}

/// How a device's kernels respond to aggregate resource pressure: a
/// piecewise-linear slowdown per resource with the max taken across
/// resources (a kernel is only as slow as its most-contended resource
/// makes it — the roofline view of arXiv 2501.16909).
///
/// For one resource with the kernel's own demand `own` and co-resident
/// aggregate demand `others`:
///
/// ```text
/// slowdown = 1                                   if own+others <= knee
///          = 1 + slope * own * (own+others-knee) otherwise
/// ```
///
/// Below the knee the resource is undersubscribed and co-residency is
/// free; past it the kernel degrades linearly in the overflow, scaled
/// by how much the kernel itself depends on the resource (`own` — a
/// kernel that never touches DRAM cannot be slowed by bandwidth hogs).
/// The final slowdown is `max` over resources, capped at
/// `max_slowdown`, so every kernel's rate stays within
/// `[rate / max_slowdown, rate]` of its interference-free rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct InterferenceResponse {
    /// Aggregate-demand knee per resource: total demand at or below it
    /// is contention-free (1.0 = the resource's full capacity).
    pub knee: f64,
    /// Slowdown per unit of overflow past the knee.
    pub slope: f64,
    /// Hard cap on the per-kernel interference slowdown (>= 1).
    pub max_slowdown: f64,
}

impl Default for InterferenceResponse {
    fn default() -> Self {
        InterferenceResponse { knee: 1.0, slope: 1.0, max_slowdown: 4.0 }
    }
}

impl InterferenceResponse {
    /// Interference slowdown (>= 1) of a kernel with profile `own`
    /// co-resident with aggregate pressure `others`. Monotone
    /// non-decreasing in every component of `others`, exactly 1.0 when
    /// `own` is all-zero, and capped at `max_slowdown`.
    pub fn slowdown(&self, own: &InterferenceProfile, others: &InterferenceProfile) -> f64 {
        let per = |o: f64, rest: f64| {
            let excess = (o + rest - self.knee).max(0.0);
            1.0 + self.slope * o * excess
        };
        let s = per(own.mem_bw, others.mem_bw)
            .max(per(own.l2, others.l2))
            .max(per(own.sm, others.sm));
        s.clamp(1.0, self.max_slowdown.max(1.0))
    }
}

/// Static description of one GPU.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    /// Streaming multiprocessors.
    pub sms: u32,
    /// Max resident warps per SM (2048 threads / 32).
    pub warps_per_sm: u32,
    /// Max resident thread blocks per SM.
    pub tbs_per_sm: u32,
    /// Global memory, bytes.
    pub mem_bytes: u64,
    /// Relative compute speed; 1.0 = V100 (the `work_us` reference).
    pub speed: f64,
    /// Piecewise-linear response to co-resident resource pressure (see
    /// [`InterferenceResponse`]); only consulted when a resident kernel
    /// carries a nonzero [`InterferenceProfile`].
    pub interference: InterferenceResponse,
}

impl GpuSpec {
    /// NVIDIA P100: 56 SMs, 3584 cores, 16 GB.
    pub fn p100() -> Self {
        GpuSpec {
            sms: 56,
            warps_per_sm: 64,
            tbs_per_sm: 32,
            mem_bytes: 16 << 30,
            speed: 3584.0 / 5120.0,
            interference: InterferenceResponse::default(),
        }
    }

    /// NVIDIA V100: 80 SMs, 5120 cores, 16 GB (the work-unit reference).
    pub fn v100() -> Self {
        GpuSpec {
            sms: 80,
            warps_per_sm: 64,
            tbs_per_sm: 32,
            mem_bytes: 16 << 30,
            speed: 1.0,
            interference: InterferenceResponse::default(),
        }
    }

    /// All `k` static MIG-style slices of the device, largest first:
    /// 1/`k` of the SMs, memory, and speed each, with per-SM limits
    /// unchanged (arXiv 2105.10312's partition-then-allocate
    /// alternative to sharing). Slices are *isolation domains*: each
    /// becomes its own [`Device`], so kernels on different slices of
    /// one physical GPU never co-reside and never interfere — the
    /// predictability-for-peak-throughput trade `--dispatch partition`
    /// measures. When `sms` or `mem_bytes` isn't divisible by `k` the
    /// remainder is spread one unit at a time across the *first*
    /// slices, so the slices always sum back to the whole device —
    /// truncating instead (the pre-fix behaviour) silently shrank
    /// partitioned capacity and biased `bench interference` against
    /// `--dispatch partition`. Speed follows each slice's SM share, so
    /// total speed is conserved too. `k = 0` is treated as 1 (no
    /// slicing).
    ///
    /// [`Device`]: super::Device
    pub fn slices(&self, k: usize) -> Vec<GpuSpec> {
        let k = k.max(1);
        let sm_base = self.sms / k as u32;
        let sm_extra = (self.sms % k as u32) as usize;
        let mem_base = self.mem_bytes / k as u64;
        let mem_extra = (self.mem_bytes % k as u64) as usize;
        (0..k)
            .map(|i| {
                let sms = (sm_base + (i < sm_extra) as u32).max(1);
                GpuSpec {
                    sms,
                    mem_bytes: mem_base + (i < mem_extra) as u64,
                    speed: self.speed * sms as f64 / self.sms.max(1) as f64,
                    ..*self
                }
            })
            .collect()
    }

    /// The first (largest) of the device's `k` slices — see
    /// [`GpuSpec::slices`] for the remainder-distribution rule.
    pub fn slice(&self, k: usize) -> Self {
        self.slices(k)[0]
    }

    /// Total warp slots (the compute capacity the schedulers reason in).
    pub fn warp_capacity(&self) -> u64 {
        self.sms as u64 * self.warps_per_sm as u64
    }

    /// Total thread-block slots.
    pub fn tb_capacity(&self) -> u64 {
        self.sms as u64 * self.tbs_per_sm as u64
    }

    /// Max thread blocks of `warps_per_tb`-warp TBs resident at once on
    /// an otherwise-empty device (both TB-slot and warp limited).
    pub fn resident_tb_limit(&self, warps_per_tb: u64) -> u64 {
        if warps_per_tb == 0 {
            return self.tb_capacity();
        }
        let per_sm = (self.warps_per_sm as u64 / warps_per_tb).min(self.tbs_per_sm as u64);
        per_sm * self.sms as u64
    }
}

/// One multi-GPU compute node.
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub gpus: Vec<GpuSpec>,
    /// Host CPU worker slots available for the worker pool sweep (the
    /// paper's nodes: 12-core Xeon for 2×P100, 32-core for 4×V100).
    pub cpu_cores: u32,
    pub name: String,
}

impl NodeSpec {
    /// The paper's Chameleon node: 2×P100 + 12-core Xeon E5-2670.
    pub fn p100x2() -> Self {
        NodeSpec { gpus: vec![GpuSpec::p100(); 2], cpu_cores: 12, name: "2xP100".into() }
    }

    /// The paper's AWS p3.8xlarge: 4×V100 + 32 vCPU.
    pub fn v100x4() -> Self {
        NodeSpec { gpus: vec![GpuSpec::v100(); 4], cpu_cores: 32, name: "4xV100".into() }
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// The node with every GPU statically partitioned into `k`
    /// MIG-style slices ([`GpuSpec::slices`]), in GPU order (slices of
    /// GPU 0 first, largest slice of each GPU first). `k <= 1` returns
    /// the node unchanged, so the unpartitioned path stays
    /// bit-identical.
    pub fn sliced(&self, k: usize) -> Self {
        if k <= 1 {
            return self.clone();
        }
        NodeSpec {
            gpus: self.gpus.iter().flat_map(|g| g.slices(k)).collect(),
            cpu_cores: self.cpu_cores,
            name: format!("{}/{k}", self.name),
        }
    }

    /// Relative compute capability of the node: the sum of its GPUs'
    /// speeds in V100 units (one V100 == 1.0). A 4×V100 node is 4.0, a
    /// 2×P100 node 1.4 — the normaliser heterogeneous-aware dispatch
    /// divides outstanding work by.
    pub fn compute_capacity(&self) -> f64 {
        self.gpus.iter().map(|g| g.speed).sum()
    }
}

/// A cluster of compute nodes — the beyond-paper scale-out target. The
/// dispatcher layer (`sched::dispatch`) routes jobs across `nodes`;
/// each node keeps its own devices, worker pool, and policy instance.
/// Nodes may be heterogeneous (e.g. a P100 node next to V100 nodes).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub nodes: Vec<NodeSpec>,
    pub name: String,
}

impl ClusterSpec {
    /// A one-node cluster: the paper's deployments. Keeps the node's
    /// name so single-node results read identically to `run_batch`.
    pub fn single(node: NodeSpec) -> Self {
        let name = node.name.clone();
        ClusterSpec { nodes: vec![node], name }
    }

    /// `n` identical nodes.
    pub fn homogeneous(node: NodeSpec, n: usize) -> Self {
        assert!(n > 0, "a cluster needs at least one node");
        let name = format!("{}x[{}]", n, node.name);
        ClusterSpec { nodes: vec![node; n], name }
    }

    /// An explicit (possibly heterogeneous) node list, e.g. a P100 node
    /// next to V100 nodes. The name concatenates the member names.
    pub fn of(nodes: Vec<NodeSpec>) -> Self {
        assert!(!nodes.is_empty(), "a cluster needs at least one node");
        let name = nodes.iter().map(|n| n.name.as_str()).collect::<Vec<_>>().join("+");
        ClusterSpec { nodes, name }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes.iter().map(|n| n.n_gpus()).sum()
    }
}

/// PCIe gen3 x16 effective host<->device bandwidth (B/s).
pub const PCIE_BYTES_PER_SEC: f64 = 12.0e9;

/// 10 GbE effective node-to-node bandwidth (B/s) — the default link a
/// migrating checkpoint image crosses when a preemption victim is
/// restored on a different node (`sched::PreemptConfig::migrate`);
/// also what the `wan` latency preset prices its dispatch payload at.
pub const NIC_BYTES_PER_SEC: f64 = 1.25e9;

/// Frontend latency model (beyond-paper; ROADMAP "Per-node probe
/// latency model"). The paper's probes are host-side RPCs to a
/// scheduler daemon; a cluster adds a dispatch hop in front. This
/// model prices those RPCs so open-system results reflect frontend
/// overheads instead of assuming free routing:
///
/// * **probe RTT** — round-trip of one probe RPC (task probe to the
///   node's scheduler daemon, or the dispatcher's load probe), per
///   node: [`LatencyModel::per_node_rtt_s`] overrides the uniform
///   [`LatencyModel::probe_rtt_s`] per node index.
/// * **dispatch cost** — shipping a routed job to its node, affine in
///   the job's payload: `dispatch_base_s + payload_bytes *
///   dispatch_s_per_byte` (set the per-byte term to 0 for a constant
///   model).
/// * **frontend queueing** — each RPC occupies the (single-server,
///   FIFO) frontend for `frontend_service_s`; simultaneous arrivals
///   serialise, modelling daemon-side queueing delay.
///
/// Two protocol knobs refine how the engine *reacts* to those delays:
///
/// * **timeout + re-probe** — when a routed job's landing delay
///   (RTT + dispatch cost) exceeds [`LatencyModel::reprobe_after_s`],
///   the frontend re-snapshots the cluster at the staleness bound and
///   may re-route before the job lands, up to
///   [`LatencyModel::reprobe_budget`] times per job (bounded, so
///   routing always terminates). Inert while every delay term is zero
///   — there is no staleness to chase on a free frontend.
/// * **probe coalescing** — with
///   [`LatencyModel::coalesce_window_s`] > 0 a node's scheduler daemon
///   holds successful task-probe replies for that window and sends one
///   shared `ProbeAck` for every probe decided inside it (Nagle-style
///   reply batching: bursty probes pay one reply instead of a staggered
///   reply each). This *is* a delay term: it turns the model on.
///
/// The all-zero model ([`LatencyModel::off`], the `Default`) is the
/// paper's free-frontend idealisation: the engine takes the exact
/// pre-latency code paths and pushes no probe/dispatch events, keeping
/// zero-latency runs bit-identical (enforced by the golden-trace
/// tests).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LatencyModel {
    /// Uniform probe round-trip time, seconds.
    pub probe_rtt_s: f64,
    /// Per-node RTT overrides (index = node index); nodes beyond the
    /// vector fall back to `probe_rtt_s`. Empty = uniform.
    pub per_node_rtt_s: Vec<f64>,
    /// Fixed dispatch (job-shipping) latency, seconds.
    pub dispatch_base_s: f64,
    /// Affine-in-payload dispatch term, seconds per payload byte (the
    /// payload is the job's estimated peak reservation — its shipped
    /// inputs/image). 0 = constant dispatch cost.
    pub dispatch_s_per_byte: f64,
    /// Frontend service time per RPC, seconds (FIFO queueing delay).
    pub frontend_service_s: f64,
    /// Staleness bound for routed-but-not-landed jobs, seconds: if a
    /// job's landing delay (RTT + dispatch cost) exceeds this, the
    /// frontend re-probes at `decision time + reprobe_after_s` and may
    /// re-route. 0 (the default) = never re-probe. Inert when every
    /// delay term is zero (does not turn the model on by itself), and
    /// over load-oblivious dispatchers (`Dispatcher::load_based` is
    /// false — a round-robin pick cannot go stale).
    pub reprobe_after_s: f64,
    /// Max re-probes per job (each fired re-probe consumes one, whether
    /// or not it changes the route). 0 disables re-probing even with a
    /// nonzero `reprobe_after_s` — the bound that guarantees routing
    /// terminates.
    pub reprobe_budget: u32,
    /// Daemon reply-batching window, seconds: successful task probes on
    /// one node decided within an open window share a single `ProbeAck`
    /// that departs when the window closes. 0 = one ack per probe
    /// (PR-3 behaviour). Nonzero turns the model on — it is a real
    /// delay term, unlike the re-probe knobs.
    pub coalesce_window_s: f64,
}

impl LatencyModel {
    /// The zero-latency idealisation (the default): no modeled
    /// frontend at all.
    pub fn off() -> Self {
        LatencyModel::default()
    }

    /// Uniform constant model: every probe costs `rtt_s` round-trip,
    /// dispatch and queueing are free.
    pub fn constant(rtt_s: f64) -> Self {
        LatencyModel { probe_rtt_s: rtt_s, ..LatencyModel::default() }
    }

    /// Same-rack datacenter preset: 200 us probe RTT, 1 ms constant
    /// dispatch, 20 us frontend service.
    pub fn lan() -> Self {
        LatencyModel {
            probe_rtt_s: 200e-6,
            dispatch_base_s: 1e-3,
            frontend_service_s: 20e-6,
            ..LatencyModel::default()
        }
    }

    /// Cross-site preset: 5 ms probe RTT, 20 ms dispatch base plus an
    /// affine payload term at ~10 GbE, 100 us frontend service.
    pub fn wan() -> Self {
        LatencyModel {
            probe_rtt_s: 5e-3,
            dispatch_base_s: 20e-3,
            dispatch_s_per_byte: 1.0 / NIC_BYTES_PER_SEC,
            frontend_service_s: 100e-6,
            ..LatencyModel::default()
        }
    }

    /// Copy of the model with every term clamped to >= 0. The engine
    /// applies this at construction: a negative term would schedule
    /// events into the past and silently corrupt the virtual clock,
    /// so sub-zero configurations (hand-built models; the CLI already
    /// clamps) degrade to their zero form instead.
    pub fn sanitized(&self) -> Self {
        LatencyModel {
            probe_rtt_s: self.probe_rtt_s.max(0.0),
            per_node_rtt_s: self.per_node_rtt_s.iter().map(|r| r.max(0.0)).collect(),
            dispatch_base_s: self.dispatch_base_s.max(0.0),
            dispatch_s_per_byte: self.dispatch_s_per_byte.max(0.0),
            frontend_service_s: self.frontend_service_s.max(0.0),
            reprobe_after_s: self.reprobe_after_s.max(0.0),
            reprobe_budget: self.reprobe_budget,
            coalesce_window_s: self.coalesce_window_s.max(0.0),
        }
    }

    /// True iff every *delay* term is zero — the engine then takes the
    /// exact pre-latency code paths (no probe/dispatch events at all).
    /// The re-probe knobs are protocol modifiers, not delays: they are
    /// inert on a free frontend (zero landing delay means nothing can
    /// go stale) and so do not turn the model on. The coalescing window
    /// *is* a delay (the daemon holds replies for it) and does.
    pub fn is_off(&self) -> bool {
        self.probe_rtt_s == 0.0
            && self.per_node_rtt_s.iter().all(|&r| r == 0.0)
            && self.dispatch_base_s == 0.0
            && self.dispatch_s_per_byte == 0.0
            && self.frontend_service_s == 0.0
            && self.coalesce_window_s == 0.0
    }

    /// True iff the timeout + re-probe protocol is enabled: a nonzero
    /// staleness bound with budget left to spend.
    pub fn reprobe_enabled(&self) -> bool {
        self.reprobe_after_s > 0.0 && self.reprobe_budget > 0
    }

    /// Probe round-trip time to `node`.
    pub fn probe_rtt(&self, node: usize) -> f64 {
        self.per_node_rtt_s.get(node).copied().unwrap_or(self.probe_rtt_s)
    }

    /// Latency of shipping a routed job whose payload is
    /// `payload_bytes` to its node.
    pub fn dispatch_latency(&self, payload_bytes: u64) -> f64 {
        self.dispatch_base_s + payload_bytes as f64 * self.dispatch_s_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_cluster_keeps_node_name() {
        let c = ClusterSpec::single(NodeSpec::v100x4());
        assert_eq!(c.n_nodes(), 1);
        assert_eq!(c.name, "4xV100");
        assert_eq!(c.total_gpus(), 4);
    }

    #[test]
    fn homogeneous_cluster_replicates_nodes() {
        let c = ClusterSpec::homogeneous(NodeSpec::p100x2(), 3);
        assert_eq!(c.n_nodes(), 3);
        assert_eq!(c.total_gpus(), 6);
        assert!(c.name.contains("2xP100"));
    }

    #[test]
    fn latency_model_off_and_per_node_lookup() {
        assert!(LatencyModel::off().is_off());
        assert!(LatencyModel::default().is_off());
        assert!(!LatencyModel::constant(0.01).is_off());
        assert!(!LatencyModel::lan().is_off());
        assert!(!LatencyModel::wan().is_off());
        // A per-node override alone turns the model on.
        let m = LatencyModel { per_node_rtt_s: vec![0.0, 0.002], ..LatencyModel::off() };
        assert!(!m.is_off());
        assert_eq!(m.probe_rtt(0), 0.0);
        assert_eq!(m.probe_rtt(1), 0.002);
        // Past the override vector: fall back to the uniform RTT.
        let m = LatencyModel { probe_rtt_s: 0.5, per_node_rtt_s: vec![0.1], ..LatencyModel::off() };
        assert_eq!(m.probe_rtt(0), 0.1);
        assert_eq!(m.probe_rtt(7), 0.5);
    }

    #[test]
    fn sanitized_clamps_negative_terms_to_zero() {
        let m = LatencyModel {
            probe_rtt_s: -1.0,
            per_node_rtt_s: vec![-0.5, 0.25],
            dispatch_base_s: -2.0,
            dispatch_s_per_byte: -1e-9,
            frontend_service_s: -0.1,
            reprobe_after_s: -0.2,
            reprobe_budget: 3,
            coalesce_window_s: -0.3,
        }
        .sanitized();
        assert_eq!(m.probe_rtt_s, 0.0);
        assert_eq!(m.per_node_rtt_s, vec![0.0, 0.25]);
        assert_eq!(m.dispatch_base_s, 0.0);
        assert_eq!(m.dispatch_s_per_byte, 0.0);
        assert_eq!(m.frontend_service_s, 0.0);
        assert_eq!(m.reprobe_after_s, 0.0);
        assert_eq!(m.reprobe_budget, 3, "the budget is a count, not a delay");
        assert_eq!(m.coalesce_window_s, 0.0);
        // An all-negative model degrades to off, not to time travel.
        let all_neg = LatencyModel {
            probe_rtt_s: -1.0,
            per_node_rtt_s: vec![-1.0],
            dispatch_base_s: -1.0,
            dispatch_s_per_byte: -1.0,
            frontend_service_s: -1.0,
            ..LatencyModel::off()
        };
        assert!(all_neg.sanitized().is_off());
        // Valid models pass through unchanged.
        assert_eq!(LatencyModel::wan().sanitized(), LatencyModel::wan());
    }

    #[test]
    fn reprobe_knobs_are_inert_for_is_off_but_coalescing_is_not() {
        // Re-probe settings alone leave the model off: with zero delays
        // nothing can go stale, so the engine keeps the exact
        // pre-latency paths (and the zero-latency golden traces).
        let m = LatencyModel { reprobe_after_s: 1.0, reprobe_budget: 2, ..LatencyModel::off() };
        assert!(m.is_off());
        assert!(m.reprobe_enabled());
        // Either half of the pair missing disables the protocol.
        let m = LatencyModel { reprobe_after_s: 1.0, ..LatencyModel::off() };
        assert!(!m.reprobe_enabled(), "budget 0 = never re-probe");
        let m = LatencyModel { reprobe_budget: 5, ..LatencyModel::off() };
        assert!(!m.reprobe_enabled(), "no staleness bound = never re-probe");
        // The coalescing window is a real delay: it turns the model on.
        let m = LatencyModel { coalesce_window_s: 0.01, ..LatencyModel::off() };
        assert!(!m.is_off());
    }

    #[test]
    fn dispatch_latency_is_affine_in_payload() {
        let m = LatencyModel {
            dispatch_base_s: 0.01,
            dispatch_s_per_byte: 1e-9,
            ..LatencyModel::off()
        };
        assert!((m.dispatch_latency(0) - 0.01).abs() < 1e-15);
        assert!((m.dispatch_latency(1_000_000) - 0.011).abs() < 1e-12);
        // Constant model: payload does not matter.
        let c = LatencyModel::constant(0.1);
        assert_eq!(c.dispatch_latency(0), c.dispatch_latency(1 << 30));
    }

    #[test]
    fn interference_profile_algebra() {
        assert!(InterferenceProfile::ZERO.is_zero());
        assert!(InterferenceProfile::default().is_zero());
        let a = InterferenceProfile::new(0.5, 0.2, 0.8);
        assert!(!a.is_zero());
        let b = InterferenceProfile::new(0.3, 0.9, 0.1);
        let s = a.add(&b);
        assert_eq!(s, InterferenceProfile::new(0.8, 1.1, 0.9));
        assert_eq!(s.sub_clamped(&a), b);
        // Over-subtraction clamps at zero instead of going negative.
        assert_eq!(a.sub_clamped(&s), InterferenceProfile::ZERO);
        assert_eq!(a.max(&b), InterferenceProfile::new(0.5, 0.9, 0.8));
        assert_eq!(s.max_component(), 1.1);
        // Sanitize clamps into [0, 1] per component.
        let wild = InterferenceProfile::new(-0.5, 2.0, 0.7).sanitized();
        assert_eq!(wild, InterferenceProfile::new(0.0, 1.0, 0.7));
    }

    #[test]
    fn interference_response_is_piecewise_linear_max_across_resources() {
        let r = InterferenceResponse::default();
        let zero = InterferenceProfile::ZERO;
        // A zero-profile kernel is never slowed, whatever the others do.
        assert_eq!(r.slowdown(&zero, &InterferenceProfile::new(1.0, 1.0, 1.0)), 1.0);
        // Below the knee co-residency is free.
        let own = InterferenceProfile::new(0.4, 0.1, 0.2);
        assert_eq!(r.slowdown(&own, &InterferenceProfile::new(0.5, 0.5, 0.5)), 1.0);
        // Past the knee: 1 + slope * own * excess on the worst resource.
        let others = InterferenceProfile::new(0.9, 0.0, 0.0);
        let want = 1.0 + 1.0 * 0.4 * (0.4 + 0.9 - 1.0);
        assert!((r.slowdown(&own, &others) - want).abs() < 1e-12);
        // Max across resources: saturating SM pressure dominates.
        let others = InterferenceProfile::new(0.9, 0.0, 1.0);
        let sm_w = 1.0 + 1.0 * 0.2 * (0.2 + 1.0 - 1.0);
        assert!((r.slowdown(&own, &others) - want.max(sm_w)).abs() < 1e-12);
        // Monotone in co-resident pressure, and capped at max_slowdown.
        let mut prev = 1.0;
        for i in 0..50 {
            let p = i as f64 * 0.2;
            let s = r.slowdown(
                &InterferenceProfile::new(1.0, 1.0, 1.0),
                &InterferenceProfile::new(p, p, p),
            );
            assert!(s >= prev, "monotone: {s} after {prev}");
            assert!(s <= r.max_slowdown);
            prev = s;
        }
        assert_eq!(prev, r.max_slowdown, "deep oversubscription hits the cap");
    }

    #[test]
    fn gpu_slices_partition_sm_memory_and_speed() {
        let v = GpuSpec::v100();
        let half = v.slice(2);
        assert_eq!(half.sms, 40);
        assert_eq!(half.mem_bytes, 8 << 30);
        assert!((half.speed - 0.5).abs() < 1e-12);
        assert_eq!(half.warps_per_sm, v.warps_per_sm, "per-SM limits unchanged");
        assert_eq!(half.tbs_per_sm, v.tbs_per_sm);
        assert_eq!(half.warp_capacity(), v.warp_capacity() / 2);
        // k = 0/1 are the identity.
        assert_eq!(v.slice(0), v);
        assert_eq!(v.slice(1), v);
        // Odd split on the P100: 56 = 19 + 19 + 18 — the two remainder
        // SMs land on the first slices, speed follows each SM share.
        let p = GpuSpec::p100();
        let thirds = p.slices(3);
        assert_eq!(thirds.iter().map(|s| s.sms).collect::<Vec<_>>(), vec![19, 19, 18]);
        assert_eq!(p.slice(3).sms, 19, "slice(k) is the first (largest) slice");
        assert!((thirds[2].speed - p.speed * 18.0 / 56.0).abs() < 1e-12);
    }

    #[test]
    fn slices_conserve_the_whole_device() {
        // The regression the bugfix sweep closes: `sms / k` and
        // `mem_bytes / k` truncated, so slices of an indivisible device
        // summed to less than the whole — partitioned capacity silently
        // shrank. Totals (SMs, bytes, speed) must now be exact.
        for spec in [GpuSpec::p100(), GpuSpec::v100()] {
            for k in [2usize, 3] {
                let parts = spec.slices(k);
                assert_eq!(parts.len(), k);
                assert_eq!(parts.iter().map(|s| s.sms).sum::<u32>(), spec.sms, "SMs, k={k}");
                assert_eq!(
                    parts.iter().map(|s| s.mem_bytes).sum::<u64>(),
                    spec.mem_bytes,
                    "bytes, k={k}"
                );
                let speed: f64 = parts.iter().map(|s| s.speed).sum();
                assert!((speed - spec.speed).abs() < 1e-12, "speed, k={k}");
                // Largest-first: monotone non-increasing SM counts.
                assert!(parts.windows(2).all(|w| w[0].sms >= w[1].sms));
            }
        }
        // And at the node level, for the shapes `--dispatch partition`
        // actually builds: sliced(k) totals equal the unsliced node's.
        for node in [NodeSpec::p100x2(), NodeSpec::v100x4()] {
            for k in [2usize, 3] {
                let s = node.sliced(k);
                assert_eq!(s.n_gpus(), node.n_gpus() * k);
                assert_eq!(
                    s.gpus.iter().map(|g| g.sms).sum::<u32>(),
                    node.gpus.iter().map(|g| g.sms).sum::<u32>()
                );
                assert_eq!(
                    s.gpus.iter().map(|g| g.mem_bytes).sum::<u64>(),
                    node.gpus.iter().map(|g| g.mem_bytes).sum::<u64>()
                );
                assert!((s.compute_capacity() - node.compute_capacity()).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn sliced_node_is_an_isolation_domain_list() {
        let n = NodeSpec::v100x4();
        let s = n.sliced(2);
        assert_eq!(s.n_gpus(), 8, "4 GPUs x 2 slices");
        assert_eq!(s.name, "4xV100/2");
        assert!(s.gpus.iter().all(|g| g.mem_bytes == 8 << 30));
        // Capacity is conserved (up to SM-count flooring): 8 x 0.5.
        assert!((s.compute_capacity() - 4.0).abs() < 1e-12);
        assert_eq!(n.sliced(1).n_gpus(), 4, "k <= 1 is the identity");
        assert_eq!(n.sliced(0).name, n.name);
    }

    #[test]
    fn mixed_cluster_and_capability() {
        let c = ClusterSpec::of(vec![NodeSpec::p100x2(), NodeSpec::v100x4()]);
        assert_eq!(c.n_nodes(), 2);
        assert_eq!(c.total_gpus(), 6);
        assert_eq!(c.name, "2xP100+4xV100");
        let p100 = c.nodes[0].compute_capacity();
        let v100 = c.nodes[1].compute_capacity();
        assert!((p100 - 2.0 * (3584.0 / 5120.0)).abs() < 1e-12);
        assert!((v100 - 4.0).abs() < 1e-12);
    }
}

//! One simulated GPU: memory accounting + processor-shared compute.
//!
//! Kernels are advanced lazily: the device records, per resident
//! kernel, the remaining dedicated-seconds of work and the current
//! progress rate (device speed / oversubscription). `advance_to` folds
//! elapsed virtual time into remaining work; membership changes
//! (kernel added/removed) change every resident kernel's rate, so the
//! engine re-queries finish times afterwards.
//!
//! On top of the warp-capacity waterfill, each resident kernel may
//! carry an [`InterferenceProfile`] (memory-bandwidth / L2 / SM
//! pressure). When any resident profile is nonzero, every kernel's
//! rate is further divided by the device's piecewise-linear
//! [`InterferenceResponse`](super::spec::InterferenceResponse) to its
//! co-residents' aggregate pressure. All-zero profiles skip that pass
//! entirely, keeping the legacy processor-sharing rates bit-identical.

use super::spec::{GpuSpec, InterferenceProfile};

/// Identifies a resident kernel on a device.
pub type KernelHandle = usize;

/// Per-co-resident-kernel MPS overhead (see `Device::mps_overhead`).
pub const MPS_PER_NEIGHBOUR: f64 = 0.028;

/// Warp residency does not equal issue-slot utilisation: Rodinia-class
/// kernels are largely memory-bound, so co-resident kernels' *throughput*
/// demands contend only past this headroom over the warp capacity.
/// (This is precisely the slack Alg. 3 exploits and Alg. 2's residency
/// accounting leaves on the table — §V-B.)
pub const COMPUTE_HEADROOM: f64 = 1.5;

#[derive(Clone, Debug)]
struct ResidentKernel {
    handle: KernelHandle,
    /// Dedicated-V100-seconds of work left.
    remaining: f64,
    /// Warps the kernel keeps resident (capped at device capacity).
    warps: u64,
    /// Current progress rate (work-seconds per wall-second): max-min
    /// share of the warp capacity x device speed / MPS overhead,
    /// divided by the interference slowdown when profiles are nonzero.
    rate: f64,
    /// Resource-pressure profile (sanitized; ZERO = no interference).
    iv: InterferenceProfile,
}

/// Mutable device state.
#[derive(Clone, Debug)]
pub struct Device {
    pub spec: GpuSpec,
    /// Free global memory (bytes) — reservations and raw allocations
    /// both come out of this single pool.
    pub free_mem: u64,
    kernels: Vec<ResidentKernel>,
    /// Virtual time of the last progress fold.
    last_advance: f64,
    next_handle: KernelHandle,
}

impl Device {
    pub fn new(spec: GpuSpec) -> Self {
        Device {
            free_mem: spec.mem_bytes,
            spec,
            kernels: Vec::new(),
            last_advance: 0.0,
            next_handle: 0,
        }
    }

    /// Allocate `bytes`; `Err` = OOM (the calling job crashes).
    pub fn alloc(&mut self, bytes: u64) -> Result<(), u64> {
        if bytes > self.free_mem {
            return Err(self.free_mem);
        }
        self.free_mem -= bytes;
        Ok(())
    }

    /// Release `bytes` back to the pool. Releasing more than is
    /// outstanding (a double release, or releasing bytes never
    /// allocated) is an accounting bug upstream: the old
    /// unconditional clamp silently swallowed it, letting the ledger
    /// and the device drift apart. Debug builds now fail loudly; the
    /// clamp remains as the release-build backstop so a production
    /// run degrades to the old masking behaviour instead of
    /// overflowing `free_mem` past capacity.
    pub fn release(&mut self, bytes: u64) {
        debug_assert!(
            bytes <= self.spec.mem_bytes - self.free_mem,
            "released {bytes} B with only {} B outstanding (double release?)",
            self.spec.mem_bytes - self.free_mem
        );
        self.free_mem = (self.free_mem + bytes).min(self.spec.mem_bytes);
    }

    /// Warps currently resident (for metrics; capped per kernel).
    pub fn resident_warps(&self) -> u64 {
        self.kernels.iter().map(|k| k.warps).sum()
    }

    /// Current oversubscription factor (>= 1).
    pub fn oversubscription(&self) -> f64 {
        let cap = self.spec.warp_capacity() as f64;
        (self.resident_warps() as f64 / cap).max(1.0)
    }

    /// MPS co-residency overhead: kernels from independent processes
    /// sharing a device pay a small per-neighbour cost (scheduling /
    /// cache + DRAM interference below the warp-capacity roofline).
    /// Calibrated so Alg. 2's strictly-capacity-safe co-residency still
    /// shows the ~1.8% average kernel slowdown Table IV measures.
    fn mps_overhead(&self) -> f64 {
        1.0 + MPS_PER_NEIGHBOUR * (self.kernels.len().saturating_sub(1) as f64)
    }

    /// Fold progress up to virtual time `now` into remaining work.
    /// Rates only change on membership changes (start/remove recompute
    /// them), so folding is a pure O(kernels) pass with no sort.
    pub fn advance_to(&mut self, now: f64) {
        let dt = now - self.last_advance;
        if dt > 0.0 {
            for k in &mut self.kernels {
                k.remaining = (k.remaining - dt * k.rate).max(0.0);
            }
        }
        self.last_advance = now;
    }

    /// Max-min (waterfilling) share of the warp capacity: when the
    /// summed demand exceeds capacity, kernels below the fair share keep
    /// full speed (the hardware dispatcher drains their TBs every wave)
    /// and saturating kernels absorb the remaining capacity. Work
    /// conserving; equal demands degrade uniformly.
    fn recompute_rates(&mut self) {
        let cap = self.spec.warp_capacity() as f64 * COMPUTE_HEADROOM;
        let total: f64 = self.kernels.iter().map(|k| k.warps as f64).sum();
        let base = self.spec.speed / self.mps_overhead();
        if total <= cap {
            for k in &mut self.kernels {
                k.rate = base;
            }
            self.apply_interference();
            return;
        }
        // Waterfill: ascending demand, small kernels take their full
        // demand while it is under the running fair share. Sorting the
        // resident list in place avoids a per-change index allocation
        // (handles carry identity; no caller depends on order).
        self.kernels.sort_unstable_by_key(|k| k.warps);
        let mut remaining_cap = cap;
        let mut remaining_n = self.kernels.len();
        for k in &mut self.kernels {
            let fair = remaining_cap / remaining_n as f64;
            let w = k.warps as f64;
            let share = w.min(fair);
            k.rate = base * (share / w).min(1.0);
            remaining_cap -= share;
            remaining_n -= 1;
        }
        self.apply_interference();
    }

    /// Divide each resident kernel's waterfilled rate by its
    /// interference slowdown — a function of its co-residents'
    /// *aggregate* pressure through the spec's piecewise-linear
    /// response. When every resident profile is all-zero (the legacy
    /// model, and every pre-interference workload) this returns before
    /// touching any rate, so those runs stay bit-identical to the pure
    /// processor-sharing device.
    fn apply_interference(&mut self) {
        let mut agg = InterferenceProfile::ZERO;
        for k in &self.kernels {
            agg = agg.add(&k.iv);
        }
        if agg.is_zero() {
            return;
        }
        let resp = self.spec.interference;
        for k in &mut self.kernels {
            let others = agg.sub_clamped(&k.iv);
            let slow = resp.slowdown(&k.iv, &others);
            if slow != 1.0 {
                k.rate /= slow;
            }
        }
    }

    /// Add a kernel with `work` dedicated-V100-seconds and a warp demand
    /// (will be capped at device capacity for residency). Callers must
    /// `advance_to(now)` first. Returns the handle. Equivalent to
    /// [`Device::start_kernel_with`] with the all-zero profile — the
    /// legacy processor-sharing-only entry point.
    pub fn start_kernel(&mut self, now: f64, work: f64, warps: u64) -> KernelHandle {
        self.start_kernel_with(now, work, warps, InterferenceProfile::ZERO)
    }

    /// [`Device::start_kernel`] with an explicit resource-pressure
    /// profile. The profile is sanitized (clamped into [0, 1] per
    /// component) before residency, so a corrupt workload vector can
    /// degrade neighbours but never speed anyone up or push the
    /// slowdown past the spec's cap.
    pub fn start_kernel_with(
        &mut self,
        now: f64,
        work: f64,
        warps: u64,
        iv: InterferenceProfile,
    ) -> KernelHandle {
        debug_assert!((now - self.last_advance).abs() < 1e-9);
        let handle = self.next_handle;
        self.next_handle += 1;
        let resident = warps.min(self.spec.warp_capacity()).max(1);
        self.kernels.push(ResidentKernel {
            handle,
            remaining: work,
            warps: resident,
            rate: 0.0,
            iv: iv.sanitized(),
        });
        self.recompute_rates();
        handle
    }

    /// Remove a finished (or crashed) kernel.
    pub fn remove_kernel(&mut self, now: f64, handle: KernelHandle) {
        self.advance_to(now);
        self.kernels.retain(|k| k.handle != handle);
        self.recompute_rates();
    }

    /// Remaining work of a kernel (post-`advance_to`).
    pub fn remaining(&self, handle: KernelHandle) -> Option<f64> {
        self.kernels.iter().find(|k| k.handle == handle).map(|k| k.remaining)
    }

    /// Remaining work of a kernel projected to virtual time `now`
    /// *without* folding progress in — a read-only peek used by the
    /// preemption layer to cost victims before deciding to touch the
    /// device. Equals `advance_to(now)` + `remaining(handle)`.
    pub fn remaining_at(&self, now: f64, handle: KernelHandle) -> Option<f64> {
        let k = self.kernels.iter().find(|k| k.handle == handle)?;
        let dt = (now - self.last_advance).max(0.0);
        Some((k.remaining - dt * k.rate).max(0.0))
    }

    /// Wall-clock seconds until `handle` completes at its current rate,
    /// projected to `now` without mutating (the read-only companion of
    /// [`Device::finish_time`]). Unlike [`Device::remaining_at`] this is
    /// in wall time, not dedicated-work units — what a preemption guard
    /// must compare against a (wall-clock) checkpoint cost on slow or
    /// co-scheduled devices.
    pub fn eta_at(&self, now: f64, handle: KernelHandle) -> Option<f64> {
        let k = self.kernels.iter().find(|k| k.handle == handle)?;
        let dt = (now - self.last_advance).max(0.0);
        Some((k.remaining - dt * k.rate).max(0.0) / k.rate)
    }

    /// Projected finish time of `handle` given the current membership.
    pub fn finish_time(&self, now: f64, handle: KernelHandle) -> Option<f64> {
        let k = self.kernels.iter().find(|k| k.handle == handle)?;
        Some(now + k.remaining / k.rate)
    }

    /// Earliest projected kernel completion on this device.
    pub fn next_completion(&self, now: f64) -> Option<(f64, KernelHandle)> {
        self.kernels
            .iter()
            .map(|k| (now + k.remaining / k.rate, k.handle))
            .min_by(|a, b| a.0.total_cmp(&b.0))
    }

    pub fn n_kernels(&self) -> usize {
        self.kernels.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device::new(GpuSpec::v100())
    }

    #[test]
    fn alloc_release_accounting() {
        let mut d = dev();
        let cap = d.spec.mem_bytes;
        assert!(d.alloc(cap).is_ok());
        assert_eq!(d.free_mem, 0);
        assert!(d.alloc(1).is_err());
        d.release(cap);
        assert_eq!(d.free_mem, cap);
    }

    #[test]
    fn oom_reports_available() {
        let mut d = dev();
        d.alloc(10 << 30).unwrap();
        match d.alloc(8 << 30) {
            Err(avail) => assert_eq!(avail, (16u64 << 30) - (10 << 30)),
            Ok(_) => panic!("should OOM"),
        }
    }

    #[test]
    fn dedicated_kernel_runs_at_full_speed() {
        let mut d = dev();
        d.advance_to(0.0);
        let h = d.start_kernel(0.0, 2.0, 1000);
        assert_eq!(d.finish_time(0.0, h), Some(2.0));
    }

    #[test]
    fn two_small_kernels_do_not_interfere() {
        let mut d = dev();
        d.advance_to(0.0);
        let cap = d.spec.warp_capacity();
        let h1 = d.start_kernel(0.0, 2.0, cap / 4);
        let h2 = d.start_kernel(0.0, 2.0, cap / 4);
        // No capacity contention: only the small MPS co-residency cost.
        let ov = 1.0 + MPS_PER_NEIGHBOUR;
        assert!((d.finish_time(0.0, h1).unwrap() - 2.0 * ov).abs() < 1e-9);
        assert!((d.finish_time(0.0, h2).unwrap() - 2.0 * ov).abs() < 1e-9);
    }

    #[test]
    fn oversubscription_slows_everyone_proportionally() {
        let mut d = dev();
        d.advance_to(0.0);
        let cap = d.spec.warp_capacity();
        let h1 = d.start_kernel(0.0, 1.0, cap);
        let h2 = d.start_kernel(0.0, 1.0, cap);
        // Demand 2x capacity vs 1.5x headroom: each runs at 0.75 speed.
        let ov = 1.0 + MPS_PER_NEIGHBOUR;
        let want = 2.0 / COMPUTE_HEADROOM * ov;
        assert!((d.finish_time(0.0, h1).unwrap() - want).abs() < 1e-9);
        assert!((d.finish_time(0.0, h2).unwrap() - want).abs() < 1e-9);
    }

    #[test]
    fn departure_speeds_up_survivors() {
        let mut d = dev();
        d.advance_to(0.0);
        let cap = d.spec.warp_capacity();
        let h1 = d.start_kernel(0.0, 1.0, cap);
        let h2 = d.start_kernel(0.0, 1.0, cap);
        // At t=1 both ran at headroom-shared rate 0.75/ov.
        let ov = 1.0 + MPS_PER_NEIGHBOUR;
        let rate = COMPUTE_HEADROOM / 2.0 / ov;
        d.remove_kernel(1.0, h1); // h1 leaves early (its job crashed, say)
        let left = 1.0 - rate;
        assert!((d.remaining(h2).unwrap() - left).abs() < 1e-9);
        // Now dedicated: full speed for the rest.
        assert!((d.finish_time(1.0, h2).unwrap() - (1.0 + left)).abs() < 1e-9);
    }

    #[test]
    fn remaining_at_matches_advancing_without_mutation() {
        let mut d = dev();
        d.advance_to(0.0);
        let h = d.start_kernel(0.0, 2.0, 1000);
        // Read-only projection at t=0.5: 0.5 work-seconds folded.
        assert!((d.remaining_at(0.5, h).unwrap() - 1.5).abs() < 1e-12);
        // The peek did not mutate: stored remaining is still 2.0.
        assert_eq!(d.remaining(h), Some(2.0));
        d.advance_to(0.5);
        assert!((d.remaining(h).unwrap() - 1.5).abs() < 1e-12);
        // Past the finish time the projection clamps at zero.
        assert_eq!(d.remaining_at(10.0, h), Some(0.0));
        assert_eq!(d.remaining_at(0.5, 999), None);
    }

    #[test]
    fn eta_is_wall_clock_not_work_units() {
        // P100 (speed 0.7): 1.4 work-seconds remaining take 2.0 wall
        // seconds — eta_at must report the latter.
        let mut d = Device::new(GpuSpec::p100());
        d.advance_to(0.0);
        let h = d.start_kernel(0.0, 1.4, 100);
        let speed = 3584.0 / 5120.0;
        assert!((d.eta_at(0.0, h).unwrap() - 1.4 / speed).abs() < 1e-9);
        // Projection folds elapsed wall time before dividing.
        let eta_later = d.eta_at(1.0, h).unwrap();
        assert!((eta_later - (1.4 / speed - 1.0)).abs() < 1e-9);
        assert_eq!(d.eta_at(0.0, 999), None);
    }

    #[test]
    fn p100_is_slower_than_v100() {
        let mut d = Device::new(GpuSpec::p100());
        d.advance_to(0.0);
        let h = d.start_kernel(0.0, 1.0, 100);
        let t = d.finish_time(0.0, h).unwrap();
        assert!(t > 1.4 && t < 1.45, "3584/5120 cores -> ~1.43x, got {t}");
    }

    #[test]
    fn huge_kernel_warps_are_capped_for_residency() {
        let mut d = dev();
        d.advance_to(0.0);
        let cap = d.spec.warp_capacity();
        let _h = d.start_kernel(0.0, 1.0, cap * 10);
        assert_eq!(d.resident_warps(), cap);
        assert!((d.oversubscription() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn work_conservation_under_churn() {
        // Total work done == sum of kernel works regardless of arrival
        // pattern: finish times must reflect conserved throughput.
        let mut d = dev();
        d.advance_to(0.0);
        let cap = d.spec.warp_capacity();
        let h1 = d.start_kernel(0.0, 3.0, cap);
        d.advance_to(1.0);
        let h2 = d.start_kernel(1.0, 1.0, cap);
        // t in [1, ?]: both at rate r = HEADROOM/2/ov (shared).
        let ov = 1.0 + MPS_PER_NEIGHBOUR;
        let r = COMPUTE_HEADROOM / 2.0 / ov;
        let (t2, h) = d.next_completion(1.0).unwrap();
        assert_eq!(h, h2);
        assert!((t2 - (1.0 + 1.0 / r)).abs() < 1e-9);
        d.remove_kernel(t2, h2);
        let t1 = d.finish_time(t2, h1).unwrap();
        // h1: 1.0 done dedicated + 1.0 shared; 1.0 left at full speed.
        assert!((t1 - (t2 + 1.0)).abs() < 1e-9, "got {t1}");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double release")]
    fn double_release_panics_in_debug() {
        // Regression: the old unconditional `.min(mem_bytes)` clamp let
        // a double release pass silently, leaving the engine's ledger
        // and the device permanently out of sync.
        let mut d = dev();
        d.alloc(4 << 30).unwrap();
        d.release(4 << 30);
        d.release(4 << 30);
    }

    #[test]
    fn zero_profiles_are_bit_identical_to_legacy_sharing() {
        // A co-residency scenario driven twice — once through the
        // legacy entry point, once through start_kernel_with + ZERO —
        // must produce *bit-identical* rates and finish times at every
        // membership change (the golden-trace compatibility contract).
        let mut a = dev();
        let mut b = dev();
        a.advance_to(0.0);
        b.advance_to(0.0);
        let cap = a.spec.warp_capacity();
        let scenario: &[(f64, u64)] = &[(3.0, cap), (1.0, cap / 2), (2.0, cap * 2)];
        let mut ha = Vec::new();
        let mut hb = Vec::new();
        for &(work, warps) in scenario {
            ha.push(a.start_kernel(0.0, work, warps));
            hb.push(b.start_kernel_with(0.0, work, warps, InterferenceProfile::ZERO));
        }
        for (&x, &y) in ha.iter().zip(&hb) {
            // Exact equality on purpose: identical f64 bit patterns.
            assert_eq!(a.finish_time(0.0, x), b.finish_time(0.0, y));
        }
        let (ta, ka) = a.next_completion(0.0).unwrap();
        let (tb, kb) = b.next_completion(0.0).unwrap();
        assert_eq!(ta, tb);
        a.remove_kernel(ta, ka);
        b.remove_kernel(tb, kb);
        assert_eq!(a.next_completion(ta), b.next_completion(tb));
    }

    #[test]
    fn nonzero_profiles_slow_coresidents_down() {
        // Same warp footprint, but one run carries memory-bandwidth
        // pressure past the knee: both residents must finish strictly
        // later than the interference-free run.
        let mut free = dev();
        let mut hot = dev();
        free.advance_to(0.0);
        hot.advance_to(0.0);
        let cap = free.spec.warp_capacity();
        let f1 = free.start_kernel(0.0, 2.0, cap / 4);
        let f2 = free.start_kernel(0.0, 2.0, cap / 4);
        let iv = InterferenceProfile::new(0.9, 0.2, 0.3);
        let h1 = hot.start_kernel_with(0.0, 2.0, cap / 4, iv);
        let h2 = hot.start_kernel_with(0.0, 2.0, cap / 4, iv);
        for (f, h) in [(f1, h1), (f2, h2)] {
            let tf = free.finish_time(0.0, f).unwrap();
            let th = hot.finish_time(0.0, h).unwrap();
            assert!(th > tf, "interference must cost wall time: {th} <= {tf}");
        }
        // A single kernel, however hot, has no co-residents to fight:
        // `others` is zero but `own + rest` can still cross the knee —
        // the response only charges for pressure the kernel *shares* in
        // creating, so dedicated runs are charged iff own pressure alone
        // exceeds the knee (0.9 + 0.2 + 0.3 each < knee=1.0: free).
        let mut solo = dev();
        solo.advance_to(0.0);
        let hs = solo.start_kernel_with(0.0, 2.0, cap / 4, iv);
        assert_eq!(solo.finish_time(0.0, hs), Some(2.0));
    }

    #[test]
    fn slowdown_is_monotone_and_bounded() {
        // Holding the probe kernel fixed, adding hotter neighbours
        // never speeds it up, and its rate never drops below
        // dedicated-rate / max_slowdown.
        let cap = dev().spec.warp_capacity();
        let probe_iv = InterferenceProfile::new(0.6, 0.4, 0.5);
        let dedicated_rate = {
            let mut d = dev();
            d.advance_to(0.0);
            let h = d.start_kernel_with(0.0, 1.0, cap / 8, probe_iv);
            1.0 / d.eta_at(0.0, h).unwrap()
        };
        let max_slow = dev().spec.interference.max_slowdown;
        let mut last_eta = 0.0;
        for n in 0..6 {
            let mut d = dev();
            d.advance_to(0.0);
            let h = d.start_kernel_with(0.0, 1.0, cap / 8, probe_iv);
            for _ in 0..n {
                d.start_kernel_with(0.0, 10.0, 1, InterferenceProfile::new(0.9, 0.9, 0.9));
            }
            let eta = d.eta_at(0.0, h).unwrap();
            assert!(
                eta >= last_eta - 1e-12,
                "eta must be monotone in neighbour pressure: {eta} < {last_eta} at n={n}"
            );
            // Strip the MPS overhead (orthogonal to interference) before
            // checking the interference bound.
            let mps = 1.0 + MPS_PER_NEIGHBOUR * n as f64;
            let rate = 1.0 / eta * mps;
            assert!(
                rate >= dedicated_rate / max_slow - 1e-9,
                "rate {rate} fell below dedicated {dedicated_rate} / max_slowdown {max_slow}"
            );
            assert!(rate <= dedicated_rate + 1e-9);
            last_eta = eta;
        }
    }
}

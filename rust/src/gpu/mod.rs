//! Multi-GPU node simulator — the execution substrate the paper ran on
//! real P100/V100 nodes.
//!
//! Models, per device: global-memory accounting with OOM **crash**
//! semantics (memory is the hard constraint), MPS-style co-residency of
//! kernels from independent jobs, the hardware thread-block dispatcher's
//! capacity limits (SMs × TB/warp caps), and compute interference as
//! work-conserving processor sharing — co-resident kernels whose summed
//! resident warps exceed the device's warp capacity all slow down by the
//! oversubscription factor, kernels under capacity run at full speed.
//! That asymmetry (memory crashes, compute degrades) is exactly what
//! separates the paper's Alg. 2 / Alg. 3 / CG / schedGPU behaviours.

pub mod device;
pub mod spec;

pub use device::{Device, KernelHandle};
pub use spec::{
    ClusterSpec, GpuSpec, InterferenceProfile, InterferenceResponse, LatencyModel, NodeSpec,
    NIC_BYTES_PER_SEC, PCIE_BYTES_PER_SEC,
};

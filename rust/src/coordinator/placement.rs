//! Placement & accounting layer of the coordinator: per-node device
//! state, probe-driven reservations, raw (crashable) allocations, the
//! placement wait queue, and the worker pool's idle bookkeeping.
//!
//! One [`NodePlacement`] exists per cluster node. It owns the node's
//! simulated [`Device`]s and its task-granular [`Policy`] instance (in
//! policy modes), and exposes the memory-safety contract the paper
//! builds on: `place` reserves a task's memory up front and can say
//! "wait", while `raw allocations` (pinned/static modes) go straight to
//! the device and crash the job on OOM — that asymmetry is enforced by
//! the engine via [`TaskLedger`].
//!
//! Paper map: `place` is the probe protocol of §III-B/§IV handing a
//! `TaskReq` to the node's policy; the wait queue realises "the task
//! waits until a release". Checkpoint/restart preemption reuses exactly
//! these primitives — a victim's eviction is `release_task` +
//! `release_policy` per open task, and its restore is a fresh `place`
//! of the saved requests — so the memory-safety contract (reservations
//! precede execution) survives eviction unchanged.

use super::engine::SchedMode;
use crate::gpu::{Device, NodeSpec};
use crate::sched::{make_policy, DeviceView, Policy, TaskKey, TaskReq};
use std::collections::VecDeque;

/// "Empty slot" sentinel in the ledger's dense device columns.
const NO_SLOT: u32 = u32::MAX;

/// Static MIG-style slices per physical device when the partition
/// dispatcher is active (`--dispatch partition`): the engine builds
/// each node from `NodeSpec::sliced(PARTITION_SLICES)`, so every
/// physical GPU becomes this many half-size isolation domains — its
/// own [`Device`] with its own memory pool and waterfill, on which
/// only one partition's kernels ever co-reside. Two is the coarsest
/// (and most portable) MIG geometry; the slicing math in
/// `GpuSpec::slice` supports any count if a finer geometry is wanted
/// later.
pub const PARTITION_SLICES: usize = 2;

/// Per-job memory ledger: what each open task holds, split into the
/// probe's up-front reservation (memory-safe) and raw allocations
/// (crashable). Owned by the engine's per-job runtime state; the
/// release path lives here so reservation/allocation semantics stay in
/// one module.
///
/// Storage is dense by task id (task ids are dense per job, sized up
/// front via `with_tasks` and grown on demand for stragglers): the
/// membership checks the stepping loop performs on every Malloc/Free/
/// TaskBegin are single indexed loads, where the HashMap pair this
/// replaces hashed the task id each time.
#[derive(Debug, Default)]
pub(crate) struct TaskLedger {
    /// task -> (device | NO_SLOT, bytes) reserved via probe (policy
    /// modes).
    reserved: Vec<(u32, u64)>,
    /// task -> (device | NO_SLOT, bytes) raw-allocated (pinned/static
    /// modes). The entry survives `free_alloc` even at 0 bytes — it
    /// marks the task open — and only `release_task` clears it.
    alloc: Vec<(u32, u64)>,
}

impl TaskLedger {
    /// An empty ledger pre-sized for task ids `0..n_tasks`.
    pub fn with_tasks(n_tasks: usize) -> Self {
        TaskLedger {
            reserved: vec![(NO_SLOT, 0); n_tasks],
            alloc: vec![(NO_SLOT, 0); n_tasks],
        }
    }

    fn ensure(&mut self, task: usize) {
        if self.reserved.len() <= task {
            self.reserved.resize(task + 1, (NO_SLOT, 0));
            self.alloc.resize(task + 1, (NO_SLOT, 0));
        }
    }

    /// Record `task`'s probe reservation of `bytes` on `dev`.
    pub fn reserve(&mut self, task: usize, dev: usize, bytes: u64) {
        self.ensure(task);
        self.reserved[task] = (dev as u32, bytes);
    }

    /// Whether `task` holds a live probe reservation.
    #[inline]
    pub fn has_reservation(&self, task: usize) -> bool {
        self.reserved.get(task).is_some_and(|&(d, _)| d != NO_SLOT)
    }

    /// Add `bytes` of raw allocation for `task` on `dev` (the first
    /// allocation pins the task's device; later ones accumulate bytes).
    pub fn add_alloc(&mut self, task: usize, dev: usize, bytes: u64) {
        self.ensure(task);
        let e = &mut self.alloc[task];
        if e.0 == NO_SLOT {
            *e = (dev as u32, bytes);
        } else {
            e.1 += bytes;
        }
    }

    /// A `cudaFree` of `bytes` by `task`: shrinks the task's raw
    /// allocation and returns the device to hand the bytes back to.
    /// `None` when the task's memory is covered by a probe reservation
    /// (reservations release only at TaskEnd) or it holds no raw
    /// allocation at all — in both cases the caller frees nothing.
    pub fn free_alloc(&mut self, task: usize, bytes: u64) -> Option<usize> {
        if self.has_reservation(task) {
            return None;
        }
        let e = self.alloc.get_mut(task)?;
        if e.0 == NO_SLOT {
            return None;
        }
        e.1 = e.1.saturating_sub(bytes);
        Some(e.0 as usize)
    }

    /// Live probe reservations as `(device, bytes)` pairs (any order;
    /// callers reduce commutatively).
    pub fn reserved_entries(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.reserved.iter().filter(|&&(d, _)| d != NO_SLOT).map(|&(d, b)| (d as usize, b))
    }

    /// Total bytes held under probe reservations.
    pub fn reserved_bytes_total(&self) -> u64 {
        self.reserved_entries().map(|(_, b)| b).sum()
    }

    /// Every byte this ledger holds against the node's devices:
    /// probe reservations plus raw allocations. This is the quantity
    /// the engine sanitizer sums across jobs to check conservation
    /// (`free + Σ held == total`), so it must mirror exactly what
    /// `release_task` would hand back.
    pub fn held_bytes_total(&self) -> u64 {
        let raw: u64 =
            self.alloc.iter().filter(|&&(d, _)| d != NO_SLOT).map(|&(_, b)| b).sum();
        self.reserved_bytes_total() + raw
    }

    /// Distinct tasks still holding memory, in stable ascending order
    /// (dense storage iterates in task-id order by construction).
    pub fn open_tasks(&self) -> Vec<usize> {
        (0..self.reserved.len())
            .filter(|&t| self.reserved[t].0 != NO_SLOT || self.alloc[t].0 != NO_SLOT)
            .collect()
    }

    /// Drop `task`'s reservation and leftover raw allocations back into
    /// the node's devices. Returns whether any bytes were released.
    pub fn release_task(&mut self, devices: &mut [Device], task: usize) -> bool {
        let mut released = false;
        if task >= self.reserved.len() {
            return false;
        }
        let (dev, bytes) = std::mem::replace(&mut self.reserved[task], (NO_SLOT, 0));
        if dev != NO_SLOT {
            devices[dev as usize].release(bytes);
            released = true;
        }
        let (dev, bytes) = std::mem::replace(&mut self.alloc[task], (NO_SLOT, 0));
        if dev != NO_SLOT && bytes > 0 {
            devices[dev as usize].release(bytes);
            released = true;
        }
        released
    }
}

/// One cluster node's placement state: devices, policy, job/wait
/// queues, and the worker pool.
pub(crate) struct NodePlacement {
    pub devices: Vec<Device>,
    pub policy: Option<Box<dyn Policy>>,
    /// Jobs dispatched to this node, waiting for a worker.
    pub job_q: VecDeque<usize>,
    /// Jobs whose pending task placement did not fit; retried after the
    /// next release on this node.
    wait_q: Vec<usize>,
    /// O(1) wait-queue membership flags mirroring `wait_q`, indexed by
    /// job and grown on demand (the node does not know the batch size
    /// at construction). The `Vec::contains` dedup it replaces made
    /// `push_waiter` O(n) per call — O(n²) across a burst of blocked
    /// jobs, and every failed probe retry pushes. Same pattern as
    /// `is_idle`/`idle_stack`; insertion order is untouched.
    in_wait_q: Vec<bool>,
    /// Worker -> pinned device (SA/CG) or None (policy/static modes).
    pub worker_pin: Vec<Option<usize>>,
    /// Idle workers, most recently idled on top (wakeup pops the top).
    idle_stack: Vec<usize>,
    /// O(1) idleness flags mirroring `idle_stack` membership.
    is_idle: Vec<bool>,
    /// cudaSetDevice semantics: place on res.static_dev.unwrap_or(0),
    /// raw (crashable) memory accounting.
    pub static_mode: bool,
    /// Relative compute capability ([`NodeSpec::compute_capacity`],
    /// cached at construction): the single source the dispatcher's
    /// capability-normalised load views draw from.
    pub compute_capacity: f64,
    /// Total device memory, cached at construction (device capacities
    /// never change mid-run): the dispatcher reads `total_mem` for
    /// every node on every routing decision, and re-summing it was the
    /// one O(devices) scan left on that path.
    total_mem_bytes: u64,
    /// Reused policy-snapshot buffer for `place`: refilled in place
    /// instead of allocating a fresh `Vec<DeviceView>` per probe.
    views_scratch: Vec<DeviceView>,
}

impl NodePlacement {
    pub fn new(spec: &NodeSpec, mode: &SchedMode, workers_per_node: usize) -> Self {
        let n_gpus = spec.n_gpus();
        let workers = match mode {
            SchedMode::Sa => n_gpus,
            _ => workers_per_node.max(1),
        };
        let worker_pin: Vec<Option<usize>> = (0..workers)
            .map(|w| match mode {
                SchedMode::Sa | SchedMode::Cg => Some(w % n_gpus),
                SchedMode::Policy(_) | SchedMode::Static => None,
            })
            .collect();
        let policy = match mode {
            SchedMode::Policy(name) => Some(make_policy(name, n_gpus)),
            _ => None,
        };
        let devices: Vec<Device> = spec.gpus.iter().map(|&g| Device::new(g)).collect();
        NodePlacement {
            total_mem_bytes: devices.iter().map(|d| d.spec.mem_bytes).sum(),
            views_scratch: Vec::with_capacity(devices.len()),
            devices,
            policy,
            job_q: VecDeque::new(),
            wait_q: Vec::new(),
            in_wait_q: Vec::new(),
            worker_pin,
            idle_stack: Vec::new(),
            is_idle: vec![false; workers],
            static_mode: matches!(mode, SchedMode::Static),
            compute_capacity: spec.compute_capacity(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.worker_pin.len()
    }

    /// Probe placement: ask the policy for a device and reserve the
    /// task's memory on it. `None` = nothing fits; the caller queues
    /// the job as a waiter.
    pub fn place(&mut self, key: TaskKey, req: &TaskReq) -> Option<usize> {
        self.views_scratch.clear();
        self.views_scratch
            .extend(self.devices.iter().map(|d| DeviceView { spec: d.spec, free_mem: d.free_mem }));
        let policy = self.policy.as_mut().expect("policy mode");
        let dev = policy.place(key, req, &self.views_scratch)?;
        self.devices[dev]
            .alloc(req.mem_bytes)
            .expect("policy admitted within free_mem");
        Some(dev)
    }

    /// Tell the policy a placed task finished (no-op in pinned modes).
    pub fn release_policy(&mut self, key: TaskKey) {
        if let Some(p) = self.policy.as_mut() {
            p.release(key);
        }
    }

    pub fn has_policy(&self) -> bool {
        self.policy.is_some()
    }

    /// Queue `job` to retry placement after the next release here.
    /// Duplicate-free in O(1) via the `in_wait_q` flags (no scan).
    pub fn push_waiter(&mut self, job: usize) {
        if self.in_wait_q.len() <= job {
            self.in_wait_q.resize(job + 1, false);
        }
        if !self.in_wait_q[job] {
            self.in_wait_q[job] = true;
            self.wait_q.push(job);
        }
    }

    /// Whether any job is queued to retry placement on this node. The
    /// compiled-replay layer refuses macro entry on a node with waiters
    /// under preemption: fine-grained stepping wakes them at every
    /// kernel launch, and a macro segment would skip those instants.
    pub fn has_waiters(&self) -> bool {
        !self.wait_q.is_empty()
    }

    /// Drain the wait queue (the engine turns these into Wake events).
    pub fn take_waiters(&mut self) -> Vec<usize> {
        for &job in &self.wait_q {
            self.in_wait_q[job] = false;
        }
        std::mem::take(&mut self.wait_q)
    }

    /// Mark a worker idle; O(1) via the `is_idle` flags (no scan).
    pub fn mark_idle(&mut self, worker: usize) {
        if !self.is_idle[worker] {
            self.is_idle[worker] = true;
            self.idle_stack.push(worker);
        }
    }

    /// Pop the most recently idled worker, if any.
    pub fn pop_idle(&mut self) -> Option<usize> {
        let w = self.idle_stack.pop()?;
        self.is_idle[w] = false;
        Some(w)
    }

    /// Free memory summed across the node's devices.
    pub fn free_mem(&self) -> u64 {
        self.devices.iter().map(|d| d.free_mem).sum()
    }

    /// Total memory across the node's devices (cached: capacities are
    /// fixed at construction).
    pub fn total_mem(&self) -> u64 {
        self.total_mem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::InterferenceProfile;

    fn node() -> NodePlacement {
        NodePlacement::new(&NodeSpec::v100x4(), &SchedMode::Policy("mgb3"), 4)
    }

    #[test]
    fn idle_tracking_is_duplicate_free_lifo() {
        let mut n = node();
        n.mark_idle(1);
        n.mark_idle(3);
        n.mark_idle(1); // duplicate ignored
        assert_eq!(n.pop_idle(), Some(3), "most recently idled first");
        assert_eq!(n.pop_idle(), Some(1));
        assert_eq!(n.pop_idle(), None);
        // Re-idling after a pop works again.
        n.mark_idle(1);
        assert_eq!(n.pop_idle(), Some(1));
    }

    #[test]
    fn waiters_are_deduplicated_and_drained() {
        let mut n = node();
        n.push_waiter(7);
        n.push_waiter(7);
        n.push_waiter(2);
        assert_eq!(n.take_waiters(), vec![7, 2]);
        assert!(n.take_waiters().is_empty());
        // Draining resets membership: the same jobs can wait again (a
        // retried probe that fails again), in fresh insertion order.
        n.push_waiter(2);
        n.push_waiter(7);
        n.push_waiter(2);
        assert_eq!(n.take_waiters(), vec![2, 7]);
        // Sparse job indices grow the flag mirror on demand.
        n.push_waiter(1000);
        n.push_waiter(0);
        n.push_waiter(1000);
        assert_eq!(n.take_waiters(), vec![1000, 0]);
    }

    #[test]
    fn place_reserves_memory_on_the_chosen_device() {
        let mut n = node();
        let req = TaskReq {
            mem_bytes: 4 << 30,
            tbs: 100,
            warps_per_tb: 4,
            slo: None,
            iv: InterferenceProfile::ZERO,
        };
        let dev = n.place((0, 0), &req).expect("fits");
        assert_eq!(n.devices[dev].free_mem, (16u64 << 30) - (4 << 30));
        let before = n.free_mem();
        n.release_policy((0, 0));
        assert_eq!(n.free_mem(), before, "policy release does not free device bytes");
    }

    #[test]
    fn ledger_release_returns_bytes_once() {
        let mut n = node();
        let mut ledger = TaskLedger::default();
        n.devices[0].alloc(1 << 30).unwrap();
        ledger.add_alloc(0, 0, 1 << 30);
        assert!(ledger.release_task(&mut n.devices, 0));
        assert_eq!(n.devices[0].free_mem, 16 << 30);
        assert!(!ledger.release_task(&mut n.devices, 0), "second release is a no-op");
        assert_eq!(ledger.open_tasks(), Vec::<usize>::new());
    }

    #[test]
    fn ledger_dense_storage_keeps_hashmap_semantics() {
        let mut n = node();
        let mut ledger = TaskLedger::with_tasks(2);
        // Reservation membership is what gates Malloc/Free semantics.
        ledger.reserve(1, 2, 4 << 30);
        assert!(ledger.has_reservation(1));
        assert!(!ledger.has_reservation(0));
        assert!(!ledger.has_reservation(99), "out-of-range ids are simply absent");
        // A reserved task never frees through free_alloc.
        assert_eq!(ledger.free_alloc(1, 1 << 30), None);
        // Raw allocations accumulate on the first device used.
        ledger.add_alloc(0, 3, 1 << 30);
        ledger.add_alloc(0, 0, 1 << 30); // later dev ignored, bytes added
        assert_eq!(ledger.free_alloc(0, 3 << 30), Some(3), "frees report the pinned device");
        // Over-free saturates; the entry stays open until release_task.
        assert_eq!(ledger.open_tasks(), vec![0, 1]);
        assert_eq!(ledger.reserved_bytes_total(), 4 << 30);
        assert_eq!(ledger.reserved_entries().collect::<Vec<_>>(), vec![(2, 4 << 30)]);
        // Over-freed raw entry holds 0 bytes but stays open; held ==
        // reservation only.
        assert_eq!(ledger.held_bytes_total(), 4 << 30);
        // Growth on demand past the pre-sized bound.
        ledger.reserve(7, 0, 1 << 20);
        assert_eq!(ledger.open_tasks(), vec![0, 1, 7], "ascending task order");
        // Releasing a fully-freed raw task releases no bytes.
        let before = n.free_mem();
        assert!(!ledger.release_task(&mut n.devices, 0), "0-byte leftover frees nothing");
        assert_eq!(n.free_mem(), before);
        assert_eq!(ledger.open_tasks(), vec![1, 7]);
    }

    #[test]
    fn burst_of_1000_waiters_stays_duplicate_free_in_order() {
        // Regression guard for the O(1) flag-mirror path: an eviction
        // storm parks a burst of blocked jobs on one node, and every
        // failed probe retry re-pushes its job. Membership, insertion
        // order, and drain-reset semantics must all survive the burst.
        let mut n = node();
        for round in 0..3 {
            for j in 0..1000 {
                n.push_waiter(j);
                n.push_waiter(j); // immediate duplicate
            }
            for j in 0..1000 {
                n.push_waiter(j); // late duplicate after the full burst
            }
            let drained = n.take_waiters();
            assert_eq!(drained.len(), 1000, "round {round}: duplicates collapsed");
            assert_eq!(drained, (0..1000).collect::<Vec<_>>(), "insertion order kept");
            assert!(n.take_waiters().is_empty());
        }
    }

    #[test]
    fn sa_mode_pins_one_worker_per_gpu() {
        let n = NodePlacement::new(&NodeSpec::v100x4(), &SchedMode::Sa, 99);
        assert_eq!(n.n_workers(), 4);
        assert_eq!(n.worker_pin, vec![Some(0), Some(1), Some(2), Some(3)]);
        assert!(!n.has_policy());
    }

    #[test]
    fn partitioned_node_is_built_from_device_slices() {
        // The engine hands NodePlacement a pre-sliced NodeSpec when the
        // partition dispatcher is active; the placement layer treats
        // each slice as an independent device — policy arity, memory
        // pools, and capability all follow the slice geometry.
        let sliced = NodeSpec::v100x4().sliced(PARTITION_SLICES);
        let n = NodePlacement::new(&sliced, &SchedMode::Policy("mgb3"), 4);
        assert_eq!(n.devices.len(), 4 * PARTITION_SLICES);
        assert_eq!(n.devices[0].spec.mem_bytes, (16u64 << 30) / PARTITION_SLICES as u64);
        assert_eq!(n.total_mem(), 64 << 30, "slicing conserves total memory");
        assert!((n.compute_capacity - 4.0).abs() < 1e-12, "and total capability");
        // A reservation that fits a whole V100 no longer fits a slice.
        let req = TaskReq {
            mem_bytes: 12 << 30,
            tbs: 100,
            warps_per_tb: 4,
            slo: None,
            iv: InterferenceProfile::ZERO,
        };
        let mut n = n;
        assert!(n.place((0, 0), &req).is_none(), "12 GB cannot fit an 8 GB slice");
        let small = TaskReq { mem_bytes: 6 << 30, ..req };
        assert!(n.place((0, 0), &small).is_some());
    }
}

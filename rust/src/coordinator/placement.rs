//! Placement & accounting layer of the coordinator: per-node device
//! state, probe-driven reservations, raw (crashable) allocations, the
//! placement wait queue, and the worker pool's idle bookkeeping.
//!
//! One [`NodePlacement`] exists per cluster node. It owns the node's
//! simulated [`Device`]s and its task-granular [`Policy`] instance (in
//! policy modes), and exposes the memory-safety contract the paper
//! builds on: `place` reserves a task's memory up front and can say
//! "wait", while `raw allocations` (pinned/static modes) go straight to
//! the device and crash the job on OOM — that asymmetry is enforced by
//! the engine via [`TaskLedger`].
//!
//! Paper map: `place` is the probe protocol of §III-B/§IV handing a
//! `TaskReq` to the node's policy; the wait queue realises "the task
//! waits until a release". Checkpoint/restart preemption reuses exactly
//! these primitives — a victim's eviction is `release_task` +
//! `release_policy` per open task, and its restore is a fresh `place`
//! of the saved requests — so the memory-safety contract (reservations
//! precede execution) survives eviction unchanged.

use super::engine::SchedMode;
use crate::gpu::{Device, NodeSpec};
use crate::sched::{make_policy, DeviceView, Policy, TaskKey, TaskReq};
use std::collections::{HashMap, VecDeque};

/// Per-job memory ledger: what each open task holds, split into the
/// probe's up-front reservation (memory-safe) and raw allocations
/// (crashable). Owned by the engine's per-job runtime state; the
/// release path lives here so reservation/allocation semantics stay in
/// one module.
#[derive(Debug, Default)]
pub(crate) struct TaskLedger {
    /// task -> (device, bytes) reserved via probe (policy modes).
    pub reserved: HashMap<usize, (usize, u64)>,
    /// task -> (device, bytes) raw-allocated (pinned/static modes).
    pub alloc: HashMap<usize, (usize, u64)>,
}

impl TaskLedger {
    /// Distinct tasks still holding memory, in stable (sorted) order.
    pub fn open_tasks(&self) -> Vec<usize> {
        self.reserved
            .keys()
            .chain(self.alloc.keys())
            .copied()
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect()
    }

    /// Drop `task`'s reservation and leftover raw allocations back into
    /// the node's devices. Returns whether any bytes were released.
    pub fn release_task(&mut self, devices: &mut [Device], task: usize) -> bool {
        let mut released = false;
        if let Some((dev, bytes)) = self.reserved.remove(&task) {
            devices[dev].release(bytes);
            released = true;
        }
        if let Some((dev, bytes)) = self.alloc.remove(&task) {
            if bytes > 0 {
                devices[dev].release(bytes);
                released = true;
            }
        }
        released
    }
}

/// One cluster node's placement state: devices, policy, job/wait
/// queues, and the worker pool.
pub(crate) struct NodePlacement {
    pub devices: Vec<Device>,
    pub policy: Option<Box<dyn Policy>>,
    /// Jobs dispatched to this node, waiting for a worker.
    pub job_q: VecDeque<usize>,
    /// Jobs whose pending task placement did not fit; retried after the
    /// next release on this node.
    wait_q: Vec<usize>,
    /// O(1) wait-queue membership flags mirroring `wait_q`, indexed by
    /// job and grown on demand (the node does not know the batch size
    /// at construction). The `Vec::contains` dedup it replaces made
    /// `push_waiter` O(n) per call — O(n²) across a burst of blocked
    /// jobs, and every failed probe retry pushes. Same pattern as
    /// `is_idle`/`idle_stack`; insertion order is untouched.
    in_wait_q: Vec<bool>,
    /// Worker -> pinned device (SA/CG) or None (policy/static modes).
    pub worker_pin: Vec<Option<usize>>,
    /// Idle workers, most recently idled on top (wakeup pops the top).
    idle_stack: Vec<usize>,
    /// O(1) idleness flags mirroring `idle_stack` membership.
    is_idle: Vec<bool>,
    /// cudaSetDevice semantics: place on res.static_dev.unwrap_or(0),
    /// raw (crashable) memory accounting.
    pub static_mode: bool,
    /// Relative compute capability ([`NodeSpec::compute_capacity`],
    /// cached at construction): the single source the dispatcher's
    /// capability-normalised load views draw from.
    pub compute_capacity: f64,
}

impl NodePlacement {
    pub fn new(spec: &NodeSpec, mode: &SchedMode, workers_per_node: usize) -> Self {
        let n_gpus = spec.n_gpus();
        let workers = match mode {
            SchedMode::Sa => n_gpus,
            _ => workers_per_node.max(1),
        };
        let worker_pin: Vec<Option<usize>> = (0..workers)
            .map(|w| match mode {
                SchedMode::Sa | SchedMode::Cg => Some(w % n_gpus),
                SchedMode::Policy(_) | SchedMode::Static => None,
            })
            .collect();
        let policy = match mode {
            SchedMode::Policy(name) => Some(make_policy(name, n_gpus)),
            _ => None,
        };
        NodePlacement {
            devices: spec.gpus.iter().map(|&g| Device::new(g)).collect(),
            policy,
            job_q: VecDeque::new(),
            wait_q: Vec::new(),
            in_wait_q: Vec::new(),
            worker_pin,
            idle_stack: Vec::new(),
            is_idle: vec![false; workers],
            static_mode: matches!(mode, SchedMode::Static),
            compute_capacity: spec.compute_capacity(),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.worker_pin.len()
    }

    /// Probe placement: ask the policy for a device and reserve the
    /// task's memory on it. `None` = nothing fits; the caller queues
    /// the job as a waiter.
    pub fn place(&mut self, key: TaskKey, req: &TaskReq) -> Option<usize> {
        let views: Vec<DeviceView> = self
            .devices
            .iter()
            .map(|d| DeviceView { spec: d.spec, free_mem: d.free_mem })
            .collect();
        let policy = self.policy.as_mut().expect("policy mode");
        let dev = policy.place(key, req, &views)?;
        self.devices[dev]
            .alloc(req.mem_bytes)
            .expect("policy admitted within free_mem");
        Some(dev)
    }

    /// Tell the policy a placed task finished (no-op in pinned modes).
    pub fn release_policy(&mut self, key: TaskKey) {
        if let Some(p) = self.policy.as_mut() {
            p.release(key);
        }
    }

    pub fn has_policy(&self) -> bool {
        self.policy.is_some()
    }

    /// Queue `job` to retry placement after the next release here.
    /// Duplicate-free in O(1) via the `in_wait_q` flags (no scan).
    pub fn push_waiter(&mut self, job: usize) {
        if self.in_wait_q.len() <= job {
            self.in_wait_q.resize(job + 1, false);
        }
        if !self.in_wait_q[job] {
            self.in_wait_q[job] = true;
            self.wait_q.push(job);
        }
    }

    /// Drain the wait queue (the engine turns these into Wake events).
    pub fn take_waiters(&mut self) -> Vec<usize> {
        for &job in &self.wait_q {
            self.in_wait_q[job] = false;
        }
        std::mem::take(&mut self.wait_q)
    }

    /// Mark a worker idle; O(1) via the `is_idle` flags (no scan).
    pub fn mark_idle(&mut self, worker: usize) {
        if !self.is_idle[worker] {
            self.is_idle[worker] = true;
            self.idle_stack.push(worker);
        }
    }

    /// Pop the most recently idled worker, if any.
    pub fn pop_idle(&mut self) -> Option<usize> {
        let w = self.idle_stack.pop()?;
        self.is_idle[w] = false;
        Some(w)
    }

    /// Free memory summed across the node's devices.
    pub fn free_mem(&self) -> u64 {
        self.devices.iter().map(|d| d.free_mem).sum()
    }

    /// Total memory summed across the node's devices.
    pub fn total_mem(&self) -> u64 {
        self.devices.iter().map(|d| d.spec.mem_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> NodePlacement {
        NodePlacement::new(&NodeSpec::v100x4(), &SchedMode::Policy("mgb3"), 4)
    }

    #[test]
    fn idle_tracking_is_duplicate_free_lifo() {
        let mut n = node();
        n.mark_idle(1);
        n.mark_idle(3);
        n.mark_idle(1); // duplicate ignored
        assert_eq!(n.pop_idle(), Some(3), "most recently idled first");
        assert_eq!(n.pop_idle(), Some(1));
        assert_eq!(n.pop_idle(), None);
        // Re-idling after a pop works again.
        n.mark_idle(1);
        assert_eq!(n.pop_idle(), Some(1));
    }

    #[test]
    fn waiters_are_deduplicated_and_drained() {
        let mut n = node();
        n.push_waiter(7);
        n.push_waiter(7);
        n.push_waiter(2);
        assert_eq!(n.take_waiters(), vec![7, 2]);
        assert!(n.take_waiters().is_empty());
        // Draining resets membership: the same jobs can wait again (a
        // retried probe that fails again), in fresh insertion order.
        n.push_waiter(2);
        n.push_waiter(7);
        n.push_waiter(2);
        assert_eq!(n.take_waiters(), vec![2, 7]);
        // Sparse job indices grow the flag mirror on demand.
        n.push_waiter(1000);
        n.push_waiter(0);
        n.push_waiter(1000);
        assert_eq!(n.take_waiters(), vec![1000, 0]);
    }

    #[test]
    fn place_reserves_memory_on_the_chosen_device() {
        let mut n = node();
        let req = TaskReq { mem_bytes: 4 << 30, tbs: 100, warps_per_tb: 4, slo: None };
        let dev = n.place((0, 0), &req).expect("fits");
        assert_eq!(n.devices[dev].free_mem, (16u64 << 30) - (4 << 30));
        let before = n.free_mem();
        n.release_policy((0, 0));
        assert_eq!(n.free_mem(), before, "policy release does not free device bytes");
    }

    #[test]
    fn ledger_release_returns_bytes_once() {
        let mut n = node();
        let mut ledger = TaskLedger::default();
        n.devices[0].alloc(1 << 30).unwrap();
        ledger.alloc.insert(0, (0, 1 << 30));
        assert!(ledger.release_task(&mut n.devices, 0));
        assert_eq!(n.devices[0].free_mem, 16 << 30);
        assert!(!ledger.release_task(&mut n.devices, 0), "second release is a no-op");
        assert_eq!(ledger.open_tasks(), Vec::<usize>::new());
    }

    #[test]
    fn sa_mode_pins_one_worker_per_gpu() {
        let n = NodePlacement::new(&NodeSpec::v100x4(), &SchedMode::Sa, 99);
        assert_eq!(n.n_workers(), 4);
        assert_eq!(n.worker_pin, vec![Some(0), Some(1), Some(2), Some(3)]);
        assert!(!n.has_policy());
    }
}

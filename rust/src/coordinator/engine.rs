//! The batch coordinator: a discrete-event simulation of the paper's
//! deployment — a queue of jobs, a worker pool, the probe protocol, a
//! scheduling policy, and the multi-GPU node.
//!
//! Jobs are [`JobTrace`]s (produced by the compiler + lazy runtime).
//! A pool of workers drains the queue (§V-A: "each worker dequeues a
//! job, runs it, and then pulls another"); the worker count and its
//! device pinning encode the baseline schedulers:
//!
//! * **SA** — one worker per GPU, pinned: each job gets a dedicated
//!   device for its lifetime (Slurm-style, memory-safe, underutilised).
//! * **CG** — N workers pinned round-robin across GPUs (the CG ratio =
//!   workers / GPUs): MPS-style packing with *no* knowledge of memory
//!   needs, so `cudaMalloc` can OOM and crash the job.
//! * **MGB / schedGPU** — unpinned workers; every `TaskBegin` probe asks
//!   the [`Policy`] for a device, reserving the task's memory up front
//!   (memory-safe by construction); tasks wait when nothing fits.
//!
//! Virtual time is f64 seconds. Kernel execution uses the device model's
//! processor sharing; completions are tracked with one pending event per
//! device plus a generation counter (membership changes invalidate the
//! stale event).

use super::metrics::{JobClass, JobOutcome, RunResult};
use crate::gpu::{Device, NodeSpec, PCIE_BYTES_PER_SEC};
use crate::lazy::{JobTrace, TraceEvent};
use crate::sched::{make_policy, DeviceView, Policy, TaskReq};
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// Scheduler selection for a batch run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Single-assignment: workers == GPUs, worker i pinned to device i.
    Sa,
    /// Core-to-GPU with `workers` total workers pinned round-robin.
    Cg,
    /// Task-granular policy by name: "mgb3" (default MGB), "mgb2",
    /// "schedgpu".
    Policy(&'static str),
    /// Honour the application's own cudaSetDevice bindings (device 0
    /// when it never called it — the CUDA default, §II-B). No memory
    /// management at all: the unmanaged-sharing baseline.
    Static,
}

/// Batch-run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub node: NodeSpec,
    pub mode: SchedMode,
    /// Worker-pool size (ignored for SA, which always uses one per GPU).
    pub workers: usize,
}

/// One job of the batch.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub class: JobClass,
    pub trace: JobTrace,
    /// Queue-arrival time. The paper's batch experiments queue all jobs
    /// at t = 0 (§V-A); open-system experiments (ablation) stagger it.
    pub arrival: f64,
}

/// Called on every kernel launch that names a PJRT artifact — the
/// `--compute real` hook (validates numerics; virtual time is modeled).
pub type LaunchHook<'a> = &'a mut dyn FnMut(&str);

/// Compact, `Copy` trace event for the hot loop: artifact names are
/// interned at batch start so stepping a job never clones a String.
/// (EXPERIMENTS.md §Perf: the naive `TraceEvent::clone()` per step cost
/// two heap allocations per kernel launch.)
#[derive(Clone, Copy, Debug)]
enum CEv {
    TaskBegin { task: usize, res: crate::lazy::TaskResources },
    Malloc { task: usize, bytes: u64 },
    Xfer { bytes: u64 },
    Launch { task: usize, artifact: u32, grid: u64, block: u64, work_us: u64 },
    Free { task: usize, bytes: u64 },
    TaskEnd { task: usize },
    Host { micros: u64 },
    Nop,
}

const NO_ARTIFACT: u32 = u32::MAX;

fn compact_trace(trace: &JobTrace, intern: &mut Vec<String>) -> Vec<CEv> {
    trace
        .events
        .iter()
        .map(|e| match e {
            TraceEvent::TaskBegin { task, res } => CEv::TaskBegin { task: *task, res: *res },
            TraceEvent::Malloc { task, bytes } => CEv::Malloc { task: *task, bytes: *bytes },
            TraceEvent::H2D { bytes, .. } | TraceEvent::D2H { bytes, .. } => {
                CEv::Xfer { bytes: *bytes }
            }
            TraceEvent::Memset { .. } => CEv::Nop,
            TraceEvent::Launch { task, artifact, grid, block, work_us, .. } => {
                let a = match artifact {
                    None => NO_ARTIFACT,
                    Some(name) => match intern.iter().position(|n| n == name) {
                        Some(i) => i as u32,
                        None => {
                            intern.push(name.clone());
                            (intern.len() - 1) as u32
                        }
                    },
                };
                CEv::Launch { task: *task, artifact: a, grid: *grid, block: *block, work_us: *work_us }
            }
            TraceEvent::Free { task, bytes } => CEv::Free { task: *task, bytes: *bytes },
            TraceEvent::TaskEnd { task } => CEv::TaskEnd { task: *task },
            TraceEvent::Host { micros } => CEv::Host { micros: *micros },
        })
        .collect()
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum EvKind {
    Wake { job: usize },
    DevCompletion { dev: usize, gen: u64 },
    /// A job enters the queue (open-system arrivals).
    Arrive { job: usize },
}

#[derive(Clone, Copy, Debug)]
struct Event {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Event {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Min-heap via reverse: earliest time, then FIFO by seq.
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

#[derive(Debug, Default)]
struct JobRt {
    pc: usize,
    /// runtime task id -> device.
    task_dev: HashMap<usize, usize>,
    /// task -> (device, bytes) reserved via probe (policy modes).
    reserved: HashMap<usize, (usize, u64)>,
    /// task -> (device, bytes) raw-allocated (pinned modes).
    alloc: HashMap<usize, (usize, u64)>,
    pinned_dev: Option<usize>,
    worker: usize,
    started: f64,
    ended: f64,
    crashed: bool,
    done: bool,
    waiting_placement: bool,
    ded_s: f64,
    act_s: f64,
    n_kernels: u64,
    kernel_started: f64,
    kernel_ded: f64,
}

struct Engine<'h> {
    cfg: RunConfig,
    jobs: Vec<JobSpec>,
    /// Compacted traces (one per job) + interned artifact names.
    compact: Vec<Vec<CEv>>,
    artifact_names: Vec<String>,
    rt: Vec<JobRt>,
    devices: Vec<Device>,
    dev_gen: Vec<u64>,
    /// (device, kernel handle) -> job.
    kernel_owner: HashMap<(usize, usize), usize>,
    policy: Option<Box<dyn Policy>>,
    events: BinaryHeap<Event>,
    seq: u64,
    job_q: VecDeque<usize>,
    wait_q: Vec<usize>,
    worker_pin: Vec<Option<usize>>,
    idle_workers: Vec<usize>,
    /// cudaSetDevice semantics: place on res.static_dev.unwrap_or(0),
    /// raw (crashable) memory accounting.
    static_mode: bool,
    hook: Option<LaunchHook<'h>>,
}

/// Run a batch of jobs under `cfg`; all jobs are queued at t = 0.
pub fn run_batch(cfg: RunConfig, jobs: Vec<JobSpec>) -> RunResult {
    run_batch_with_hook(cfg, jobs, None)
}

/// `run_batch` plus a real-compute hook invoked per artifact launch.
pub fn run_batch_with_hook(
    cfg: RunConfig,
    jobs: Vec<JobSpec>,
    hook: Option<LaunchHook<'_>>,
) -> RunResult {
    let n_gpus = cfg.node.n_gpus();
    let workers = match cfg.mode {
        SchedMode::Sa => n_gpus,
        _ => cfg.workers.max(1),
    };
    let worker_pin: Vec<Option<usize>> = (0..workers)
        .map(|w| match cfg.mode {
            SchedMode::Sa | SchedMode::Cg => Some(w % n_gpus),
            SchedMode::Policy(_) | SchedMode::Static => None,
        })
        .collect();
    let policy = match cfg.mode {
        SchedMode::Policy(name) => Some(make_policy(name, n_gpus)),
        _ => None,
    };
    let static_mode = cfg.mode == SchedMode::Static;
    let devices: Vec<Device> = cfg.node.gpus.iter().map(|&g| Device::new(g)).collect();
    let n_jobs = jobs.len();
    let mut artifact_names = Vec::new();
    let compact: Vec<Vec<CEv>> =
        jobs.iter().map(|j| compact_trace(&j.trace, &mut artifact_names)).collect();
    let mut eng = Engine {
        compact,
        artifact_names,
        rt: (0..n_jobs).map(|_| JobRt::default()).collect(),
        dev_gen: vec![0; n_gpus],
        kernel_owner: HashMap::new(),
        policy,
        events: BinaryHeap::new(),
        seq: 0,
        job_q: VecDeque::new(),
        wait_q: Vec::new(),
        worker_pin,
        idle_workers: Vec::new(),
        static_mode,
        devices,
        cfg,
        jobs,
        hook,
    };
    eng.run()
}

impl<'h> Engine<'h> {
    fn push(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.events.push(Event { t, seq: self.seq, kind });
    }

    fn run(&mut self) -> RunResult {
        for j in 0..self.jobs.len() {
            let arr = self.jobs[j].arrival;
            if arr <= 0.0 {
                self.job_q.push_back(j);
            } else {
                self.push(arr, EvKind::Arrive { job: j });
            }
        }
        let workers = self.worker_pin.len();
        for w in 0..workers {
            self.start_next_job(w, 0.0);
        }
        let mut last_t = 0.0f64;
        loop {
            while let Some(ev) = self.events.pop() {
                last_t = ev.t;
                match ev.kind {
                    EvKind::Wake { job } => {
                        if !self.rt[job].done {
                            self.step_job(job, ev.t);
                        }
                    }
                    EvKind::DevCompletion { dev, gen } => {
                        if gen == self.dev_gen[dev] {
                            self.handle_completions(dev, ev.t);
                        }
                    }
                    EvKind::Arrive { job } => {
                        self.job_q.push_back(job);
                        if let Some(w) = self.idle_workers.pop() {
                            self.start_next_job(w, ev.t);
                        }
                    }
                }
            }
            // Queue drained but some jobs never finished: their resource
            // requests can never be satisfied on this node (e.g. a task
            // bigger than any GPU). Fail one and keep draining — the
            // real scheduler would reject such a request up front; the
            // failure may unblock (or start) other jobs.
            match (0..self.rt.len()).find(|&j| !self.rt[j].done) {
                Some(j) => self.finish_job(j, last_t, true),
                None => break,
            }
        }
        self.collect()
    }

    fn start_next_job(&mut self, worker: usize, t: f64) {
        let Some(job) = self.job_q.pop_front() else {
            if !self.idle_workers.contains(&worker) {
                self.idle_workers.push(worker);
            }
            return;
        };
        let rt = &mut self.rt[job];
        rt.worker = worker;
        rt.started = t;
        rt.pinned_dev = self.worker_pin[worker];
        self.step_job(job, t);
    }

    /// Process the job's trace from its pc until it blocks or finishes.
    fn step_job(&mut self, job: usize, t: f64) {
        loop {
            if self.rt[job].done {
                return;
            }
            if self.rt[job].pc >= self.compact[job].len() {
                self.finish_job(job, t, false);
                return;
            }
            let ev = self.compact[job][self.rt[job].pc];
            match ev {
                CEv::Nop => {
                    self.rt[job].pc += 1;
                }
                CEv::TaskBegin { task, res } => {
                    if self.static_mode {
                        // §II-B: the app's cudaSetDevice (or device 0).
                        let dev = (res.static_dev.unwrap_or(0) as usize)
                            .min(self.devices.len() - 1);
                        self.rt[job].task_dev.insert(task, dev);
                        self.rt[job].pc += 1;
                        continue;
                    }
                    if let Some(dev) = self.rt[job].pinned_dev {
                        self.rt[job].task_dev.insert(task, dev);
                        self.rt[job].pc += 1;
                        continue;
                    }
                    let req = TaskReq {
                        mem_bytes: res.reserve_bytes(),
                        tbs: res.thread_blocks(),
                        warps_per_tb: res.warps_per_tb(),
                    };
                    let views: Vec<DeviceView> = self
                        .devices
                        .iter()
                        .map(|d| DeviceView { spec: d.spec, free_mem: d.free_mem })
                        .collect();
                    let policy = self.policy.as_mut().expect("policy mode");
                    match policy.place((job, task), &req, &views) {
                        Some(dev) => {
                            self.devices[dev]
                                .alloc(req.mem_bytes)
                                .expect("policy admitted within free_mem");
                            let rt = &mut self.rt[job];
                            rt.reserved.insert(task, (dev, req.mem_bytes));
                            rt.task_dev.insert(task, dev);
                            rt.waiting_placement = false;
                            rt.pc += 1;
                        }
                        None => {
                            if !self.rt[job].waiting_placement {
                                self.rt[job].waiting_placement = true;
                                self.wait_q.push(job);
                            } else if !self.wait_q.contains(&job) {
                                self.wait_q.push(job);
                            }
                            return;
                        }
                    }
                }
                CEv::Malloc { task, bytes } => {
                    let rt = &mut self.rt[job];
                    if rt.reserved.contains_key(&task) {
                        rt.pc += 1; // covered by the probe's reservation
                        continue;
                    }
                    let dev = *rt.task_dev.get(&task).expect("task placed");
                    match self.devices[dev].alloc(bytes) {
                        Ok(()) => {
                            let e = self.rt[job].alloc.entry(task).or_insert((dev, 0));
                            e.1 += bytes;
                            self.rt[job].pc += 1;
                        }
                        Err(_avail) => {
                            // OOM: the CUDA runtime returns an error the
                            // (unmodified) app does not handle — crash.
                            self.finish_job(job, t, true);
                            return;
                        }
                    }
                }
                CEv::Xfer { bytes } => {
                    self.rt[job].pc += 1;
                    let dt = bytes as f64 / PCIE_BYTES_PER_SEC;
                    self.push(t + dt, EvKind::Wake { job });
                    return;
                }
                CEv::Launch { task, artifact, grid, block, work_us } => {
                    let dev = *self.rt[job].task_dev.get(&task).expect("task placed");
                    if artifact != NO_ARTIFACT {
                        if let Some(hook) = self.hook.as_mut() {
                            hook(&self.artifact_names[artifact as usize]);
                        }
                    }
                    let warps = grid * block.div_ceil(32);
                    let work_s = work_us as f64 * 1e-6;
                    self.devices[dev].advance_to(t);
                    let h = self.devices[dev].start_kernel(t, work_s, warps);
                    self.kernel_owner.insert((dev, h), job);
                    let rt = &mut self.rt[job];
                    rt.kernel_started = t;
                    rt.kernel_ded = work_s / self.devices[dev].spec.speed;
                    self.resched_dev(dev, t);
                    return; // job sleeps until DevCompletion wakes it
                }
                CEv::Free { task, bytes } => {
                    let rt = &mut self.rt[job];
                    if !rt.reserved.contains_key(&task) {
                        if let Some(e) = rt.alloc.get_mut(&task) {
                            let dev = e.0;
                            e.1 = e.1.saturating_sub(bytes);
                            self.devices[dev].release(bytes);
                        }
                    }
                    self.rt[job].pc += 1;
                }
                CEv::TaskEnd { task } => {
                    self.release_task(job, task, t);
                    self.rt[job].pc += 1;
                }
                CEv::Host { micros } => {
                    self.rt[job].pc += 1;
                    self.push(t + micros as f64 * 1e-6, EvKind::Wake { job });
                    return;
                }
            }
        }
    }

    /// Release a task's reservation / leftover allocations and let the
    /// policy + waiters know capacity freed up.
    fn release_task(&mut self, job: usize, task: usize, t: f64) {
        let mut released = false;
        if let Some((dev, bytes)) = self.rt[job].reserved.remove(&task) {
            self.devices[dev].release(bytes);
            released = true;
        }
        if let Some((dev, bytes)) = self.rt[job].alloc.remove(&task) {
            if bytes > 0 {
                self.devices[dev].release(bytes);
                released = true;
            }
        }
        if let Some(p) = self.policy.as_mut() {
            p.release((job, task));
        }
        if released || self.policy.is_some() {
            self.wake_waiters(t);
        }
    }

    fn wake_waiters(&mut self, t: f64) {
        let waiters = std::mem::take(&mut self.wait_q);
        for j in waiters {
            self.push(t, EvKind::Wake { job: j });
        }
    }

    /// Kernel completions on `dev` at time `t`.
    fn handle_completions(&mut self, dev: usize, t: f64) {
        self.devices[dev].advance_to(t);
        // Collect all kernels that are done (remaining ~ 0).
        let mut finished = Vec::new();
        while let Some((tf, h)) = self.devices[dev].next_completion(t) {
            if tf - t > 1e-9 {
                break;
            }
            self.devices[dev].remove_kernel(t, h);
            finished.push(h);
        }
        for h in finished {
            let job = self.kernel_owner.remove(&(dev, h)).expect("owned kernel");
            let rt = &mut self.rt[job];
            rt.act_s += t - rt.kernel_started;
            rt.ded_s += rt.kernel_ded;
            rt.n_kernels += 1;
            rt.pc += 1; // past the Launch event
            self.step_job(job, t);
        }
        self.resched_dev(dev, t);
    }

    /// Invalidate the device's pending completion event and push a fresh
    /// one for the (new) earliest finisher.
    fn resched_dev(&mut self, dev: usize, t: f64) {
        self.dev_gen[dev] += 1;
        let gen = self.dev_gen[dev];
        if let Some((tf, _)) = self.devices[dev].next_completion(t) {
            self.push(tf.max(t), EvKind::DevCompletion { dev, gen });
        }
    }

    fn finish_job(&mut self, job: usize, t: f64, crashed: bool) {
        {
            let rt = &mut self.rt[job];
            if rt.done {
                return;
            }
            rt.done = true;
            rt.crashed = crashed;
            rt.ended = t;
        }
        // Release everything the job still holds.
        let tasks: Vec<usize> = self.rt[job]
            .reserved
            .keys()
            .chain(self.rt[job].alloc.keys())
            .copied()
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        for task in tasks {
            self.release_task(job, task, t);
        }
        self.wake_waiters(t);
        let worker = self.rt[job].worker;
        self.start_next_job(worker, t);
    }

    fn collect(&mut self) -> RunResult {
        let jobs: Vec<JobOutcome> = self
            .jobs
            .iter()
            .zip(&self.rt)
            .map(|(spec, rt)| JobOutcome {
                name: spec.name.clone(),
                class: spec.class,
                arrival: spec.arrival,
                started: rt.started,
                ended: rt.ended,
                crashed: rt.crashed,
                kernel_dedicated_s: rt.ded_s,
                kernel_actual_s: rt.act_s,
                n_kernels: rt.n_kernels,
            })
            .collect();
        let makespan = jobs.iter().map(|j| j.ended).fold(0.0, f64::max);
        let scheduler = match self.cfg.mode {
            SchedMode::Sa => "sa".to_string(),
            SchedMode::Cg => "cg".to_string(),
            SchedMode::Static => "static".to_string(),
            SchedMode::Policy(p) => p.to_string(),
        };
        RunResult {
            scheduler,
            node: self.cfg.node.name.clone(),
            workers: self.worker_pin.len(),
            jobs,
            makespan,
        }
    }
}

//! The batch coordinator engine: a discrete-event simulation of the
//! paper's deployment — a queue of jobs, a worker pool, the probe
//! protocol, a scheduling policy, and one or more multi-GPU nodes.
//!
//! The engine is the thin stepping layer over three modules:
//!
//! * `events` — the virtual clock, the event heap, and per-device
//!   generation counters (nothing job- or memory-aware);
//! * `placement` — per-node devices, probe reservations, raw
//!   allocations, wait queues, and worker idleness;
//! * `sched::dispatch` — the cluster layer routing each arriving job
//!   to a node; per-node [`Policy`](crate::sched::Policy) instances
//!   place tasks beneath it.
//!
//! Jobs are [`JobTrace`]s (produced by the compiler + lazy runtime).
//! A pool of workers per node drains that node's queue (§V-A: "each
//! worker dequeues a job, runs it, and then pulls another"); the worker
//! count and its device pinning encode the baseline schedulers:
//!
//! * **SA** — one worker per GPU, pinned: each job gets a dedicated
//!   device for its lifetime (Slurm-style, memory-safe, underutilised).
//! * **CG** — N workers pinned round-robin across GPUs (the CG ratio =
//!   workers / GPUs): MPS-style packing with *no* knowledge of memory
//!   needs, so `cudaMalloc` can OOM and crash the job.
//! * **MGB / schedGPU** — unpinned workers; every `TaskBegin` probe asks
//!   the policy for a device, reserving the task's memory up front
//!   (memory-safe by construction); tasks wait when nothing fits.
//!
//! Virtual time is f64 seconds. Kernel execution uses the device model's
//! processor sharing; completions are tracked with one pending event per
//! device plus a generation counter (membership changes invalidate the
//! stale event). A single-node cluster reproduces the paper's setup
//! bit-for-bit; `run_cluster` scales the same engine to N nodes.
//!
//! Paper map: the worker pool, probe protocol, and the SA/CG/static
//! baselines realise §V-A's deployment; the policy layer beneath is
//! §IV. Clusters, open-system arrivals, and preemption are beyond-paper
//! scale-out (ROADMAP).
//!
//! **Checkpoint/restart preemption** (opt-in via
//! [`ClusterConfig::preempt`]; policy modes only). When a probe finds
//! no device for a task, the engine — in addition to queueing the job
//! as a waiter exactly as before — offers the configured
//! `sched::PreemptPolicy` the running victims whose eviction would make
//! the request fit. Preempting a victim kills its in-flight kernel
//! (the lost progress is the *wasted work* metric), writes a checkpoint
//! image of its reservations at the configured cost model
//! (`CkptBegin`→`CkptDone`), releases its memory to the waiters, and
//! re-queues it; on its next worker pickup the victim re-places its
//! saved reservations all-or-nothing, pays the symmetric restore cost,
//! and resumes from the killed kernel (`Restart`). With `preempt: None`
//! no preemption event is ever pushed and every decision point is
//! unchanged, so disabled runs stay bit-identical to the admit-or-wait
//! engine — enforced by exact-equality regression tests.
//!
//! With `PreemptConfig::migrate = "cluster"` the restore *migrates*:
//! after `CkptDone` the victim's saved reservation set re-enters the
//! cluster frontend as a first-class restore job, routed by the active
//! dispatcher on a live load snapshot (under a nonzero latency model it
//! queues, probes, and pays RTT + dispatch cost like any arrival,
//! re-probe guard included), pays the checkpoint-image transfer
//! (`held_bytes / migrate_bytes_per_s`) when it lands on a node other
//! than its home, and re-places its reservations there
//! (`MigrateArrive`). `migrate: "off"` (the default) never pushes a
//! migration event and keeps the home-node restore path byte-identical.
//! Victim selection can additionally be SLO-aware: each job's optional
//! `SloClass` is threaded through every task probe, and the `slo`
//! policy never evicts a tighter class for a looser arrival.
//!
//! **Probe/dispatch latency** (opt-in via [`ClusterConfig::latency`];
//! see [`LatencyModel`]). The paper's probes are host-side RPCs to a
//! scheduler daemon; with a nonzero model the engine prices them:
//!
//! * an arriving job queues at the cluster frontend (FIFO single
//!   server), its routing probe fires as `ProbeSent`, and the
//!   dispatcher routes **on the load snapshot at probe time** — by the
//!   time the job lands (`ProbeAck` after the node's RTT, then
//!   `DispatchArrive` after the affine-in-payload dispatch cost) the
//!   loads may have changed, and by default the engine deliberately
//!   does not re-route (stale-snapshot semantics, locked by tests).
//!   With `LatencyModel::reprobe_enabled` the frontend instead guards
//!   each *load-based* routing decision (`Dispatcher::load_based`;
//!   round-robin's picks cannot go stale and are never guarded) whose
//!   landing delay exceeds the staleness
//!   bound `reprobe_after_s`: a `ReProbe` fires at the bound, queues
//!   for a frontend FIFO slot like any other RPC, the
//!   cluster is re-snapshotted, and the in-flight job is redirected if
//!   the dispatcher now picks a different node (a confirmation commits
//!   the original landing time unchanged). Each served re-probe spends
//!   one unit of the per-job `reprobe_budget`, so routing always
//!   terminates; budget exhaustion commits whatever route is current;
//! * each task probe (`TaskBegin` in policy modes) becomes an RPC to
//!   the node's scheduler daemon: the placement decision — and the
//!   reservation's visibility to every later probe — happens daemon-side
//!   when `ProbeSent` fires, but the job only resumes stepping when the
//!   ack lands a round-trip later; a probe that finds nothing blocks
//!   server-side and retries on releases at no extra round-trip.
//!   With `LatencyModel::coalesce_window_s` > 0 the daemon batches its
//!   replies Nagle-style: the first successful placement opens a
//!   per-node window, every further success inside it joins the batch,
//!   and one shared `ProbeAck` (carrying the first member) departs at
//!   window close — bursty probes pay one held reply instead of a
//!   staggered reply each.
//!   Checkpoint *restore* re-placement is deliberately exempt: the
//!   victim is already resident on the node and its reservations are
//!   re-placed by the daemon itself (no client RPC), with the data
//!   movement priced by the checkpoint cost model instead.
//!
//! With the all-zero model (the default) none of these events is ever
//! pushed and every decision point is byte-identical to the free-
//! frontend engine — enforced by the golden-trace harness.
//!
//! **Overload governance** (opt-in via [`ClusterConfig::admit`] /
//! [`ClusterConfig::frontend_q`]; see `sched::admission`). At sustained
//! arrival rate > capacity the ungoverned open system grows queues
//! without bound; with an [`AdmissionConfig`] the frontend gates every
//! *arrival* (restores and re-probes are already-admitted work and are
//! never re-gated): a token bucket or utilization threshold decides
//! whether the arrival is pressured, and pressured arrivals take the
//! reject-or-degrade lattice — latency-sensitive admitted unchanged
//! (protected, never charged a token), batch demoted to best-effort,
//! best-effort/classless turned away with a terminal `AdmitReject`
//! (ends rejected, not crashed, at its arrival instant; never consumes
//! frontend service, a worker, or a reservation). Under a nonzero
//! latency model `frontend_q` additionally replaces the frontend's
//! FIFO backlog with per-class service (`"prio"` strict priority,
//! `"wfq"` stride-scheduled weighted fair queueing) drained via
//! `FrontendServe` events. With `admit: None` (or policy "off") and
//! `frontend_q: "fifo"` neither event is ever pushed and every
//! decision point is byte-identical — the same contract as the
//! preemption and latency layers, enforced by the same goldens.

use super::events::{DevGens, EvKind, EventQueue};
use super::metrics::{JobClass, JobOutcome, RunResult};
use super::placement::{NodePlacement, TaskLedger};
use crate::gpu::{ClusterSpec, InterferenceProfile, LatencyModel, NodeSpec, PCIE_BYTES_PER_SEC};
use crate::lazy::{JobTrace, TraceEvent, TraceProgram};
use crate::sched::{
    canonical_dispatch, canonical_frontend_q, decide_under_pressure, make_dispatcher,
    make_preempt_policy, AdmissionConfig, AdmitDecision, Dispatcher, FrontendQueue, JobInfo,
    NodeLoadView, PreemptConfig, PreemptPolicy, SloClass, TaskReq, TokenBucket, VictimView,
};
use std::collections::HashMap;

/// Scheduler selection for a batch run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Single-assignment: workers == GPUs, worker i pinned to device i.
    Sa,
    /// Core-to-GPU with `workers` total workers pinned round-robin.
    Cg,
    /// Task-granular policy by name: "mgb3" (default MGB), "mgb2",
    /// "schedgpu".
    Policy(&'static str),
    /// Honour the application's own cudaSetDevice bindings (device 0
    /// when it never called it — the CUDA default, §II-B). No memory
    /// management at all: the unmanaged-sharing baseline.
    Static,
}

/// Single-node batch-run configuration (the paper's deployments).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub node: NodeSpec,
    pub mode: SchedMode,
    /// Worker-pool size (ignored for SA, which always uses one per GPU).
    pub workers: usize,
}

/// Multi-node batch-run configuration: the same per-node machinery,
/// replicated across a [`ClusterSpec`], with a dispatcher on top.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub cluster: ClusterSpec,
    pub mode: SchedMode,
    /// Worker-pool size per node (ignored for SA: one per GPU).
    pub workers_per_node: usize,
    /// Dispatcher name: "rr" | "least" | "mem" (see `sched::dispatch`).
    pub dispatch: &'static str,
    /// Checkpoint/restart preemption (see `sched::preempt`). `None`
    /// disables it and keeps the run bit-identical to the admit-or-wait
    /// engine; only policy modes honour it.
    pub preempt: Option<PreemptConfig>,
    /// Probe/dispatch latency model (see `gpu::LatencyModel`). The
    /// all-zero model (`LatencyModel::off()`, the default) keeps the
    /// run bit-identical to the free-frontend engine.
    pub latency: LatencyModel,
    /// Frontend admission control (see `sched::admission`). `None` —
    /// or `Some` with policy "off" — disables overload governance and
    /// keeps the run bit-identical to the ungoverned frontend.
    pub admit: Option<AdmissionConfig>,
    /// Frontend queueing discipline: "fifo" | "prio" | "wfq" (see
    /// `sched::FrontendQueue`). Only meaningful under a nonzero latency
    /// model (a zero-latency frontend never queues); "fifo" keeps the
    /// PR-3 single-server path byte-identical.
    pub frontend_q: &'static str,
    /// Compiled trace replay (`--compile-traces`): macro-step compiled
    /// steady-state trace segments (see `lazy::compile`) as one
    /// calendar-queue event each instead of one event per kernel /
    /// transfer / host sleep. The replay contract is exactness, not
    /// approximation — metrics and the observable event subset are
    /// byte-identical to fine-grained stepping, enforced by equivalence
    /// tests. `false` (the default) never consults the compiler and
    /// replays today's paths bit-for-bit.
    pub compile_traces: bool,
}

/// One job of the batch.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub class: JobClass,
    pub trace: JobTrace,
    /// Queue-arrival time. The paper's batch experiments queue all jobs
    /// at t = 0 (§V-A); open-system experiments (Poisson arrivals via
    /// `workloads::poisson_arrivals`) stagger it.
    pub arrival: f64,
    /// Optional SLO class (beyond-paper; `workloads::assign_slo` stamps
    /// one per `JobClass`, the `--slo` CLI mapping). Threaded into
    /// every task probe so SLO-aware victim selection can compare the
    /// blocked task's class against its candidates'; `None` = no SLO.
    pub slo: Option<SloClass>,
}

/// Called on every kernel launch that names a PJRT artifact — the
/// `--compute real` hook (validates numerics; virtual time is modeled).
pub type LaunchHook<'a> = &'a mut dyn FnMut(&str);

/// Compact, `Copy` trace event for the hot loop: artifact names are
/// interned at batch start so stepping a job never clones a String.
/// (EXPERIMENTS.md §Perf: the naive `TraceEvent::clone()` per step cost
/// two heap allocations per kernel launch.)
#[derive(Clone, Copy, Debug)]
enum CEv {
    TaskBegin { task: usize, res: crate::lazy::TaskResources },
    Malloc { task: usize, bytes: u64 },
    Xfer { bytes: u64 },
    Launch { task: usize, artifact: u32, grid: u64, block: u64, work_us: u64 },
    Free { task: usize, bytes: u64 },
    TaskEnd { task: usize },
    Host { micros: u64 },
    Nop,
}

const NO_ARTIFACT: u32 = u32::MAX;

/// "No device" sentinel in the per-job `task_dev` slab.
const NO_DEV: u32 = u32::MAX;

/// Compact one trace, interning artifact names through a hash map (a
/// linear rescan of `names` per launch was O(n²) across a batch). All
/// string work happens here, once per batch — the stepping loop only
/// ever touches `u32` artifact ids. Also returns the job's task-id
/// bound (max task id + 1): runtime task ids are dense by construction
/// (static tasks first, dynamic ids appended in order), so the bound
/// sizes the per-job task slabs (`task_dev` / `task_req` / the ledger)
/// that replace per-event `HashMap` lookups with direct indexing.
fn compact_trace(
    trace: &JobTrace,
    names: &mut Vec<String>,
    intern: &mut HashMap<String, u32>,
) -> (Vec<CEv>, usize) {
    let compact: Vec<CEv> = trace
        .events
        .iter()
        .map(|e| match e {
            TraceEvent::TaskBegin { task, res } => CEv::TaskBegin { task: *task, res: *res },
            TraceEvent::Malloc { task, bytes } => CEv::Malloc { task: *task, bytes: *bytes },
            TraceEvent::H2D { bytes, .. } | TraceEvent::D2H { bytes, .. } => {
                CEv::Xfer { bytes: *bytes }
            }
            TraceEvent::Memset { .. } => CEv::Nop,
            TraceEvent::Launch { task, artifact, grid, block, work_us, .. } => {
                let a = match artifact {
                    None => NO_ARTIFACT,
                    Some(name) => match intern.get(name) {
                        Some(&i) => i,
                        None => {
                            let i = names.len() as u32;
                            names.push(name.clone());
                            intern.insert(name.clone(), i);
                            i
                        }
                    },
                };
                CEv::Launch { task: *task, artifact: a, grid: *grid, block: *block, work_us: *work_us }
            }
            TraceEvent::Free { task, bytes } => CEv::Free { task: *task, bytes: *bytes },
            TraceEvent::TaskEnd { task } => CEv::TaskEnd { task: *task },
            TraceEvent::Host { micros } => CEv::Host { micros: *micros },
        })
        .collect();
    let n_tasks = compact
        .iter()
        .map(|e| match e {
            CEv::TaskBegin { task, .. }
            | CEv::Malloc { task, .. }
            | CEv::Launch { task, .. }
            | CEv::Free { task, .. }
            | CEv::TaskEnd { task } => task + 1,
            _ => 0,
        })
        .max()
        .unwrap_or(0);
    (compact, n_tasks)
}

/// The probe resource vector a `TaskBegin` conveys (§III-B) — built in
/// one place so the synchronous and daemon-side probe paths agree. The
/// owning job's SLO class rides along for the preemption layer.
fn probe_req(res: &crate::lazy::TaskResources, slo: Option<SloClass>) -> TaskReq {
    TaskReq {
        mem_bytes: res.reserve_bytes(),
        tbs: res.thread_blocks(),
        warps_per_tb: res.warps_per_tb(),
        slo,
        iv: res.iv,
    }
}

/// Checkpoint/restart lifecycle of one job. Always `Normal` when
/// preemption is disabled — the other states are only ever entered from
/// `try_preempt`, which requires `Engine::preempt`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
enum JPhase {
    #[default]
    Normal,
    /// Selected as a victim; kernel killed (or about to be), image copy
    /// in flight. Quiesced: step_job ignores it until `CkptDone`.
    Checkpointing,
    /// Image written, reservations released, re-queued. The next step
    /// attempt routes into `try_restore`.
    Preempted,
    /// Reservations re-placed; sleeping out the restore cost.
    Restoring,
}

/// What one trace event inside a macro segment does when replayed.
#[derive(Clone, Copy, Debug)]
enum MacroItemKind {
    /// A kernel launch: occupies the device from `start` to `end`.
    /// Carries exactly the arguments the fine-grained Launch arm would
    /// hand `Device::start_kernel_with`, plus the precomputed
    /// dedicated-V100 seconds for the metrics credit.
    Kernel { work_s: f64, warps: u64, ded: f64 },
    /// A pure sleep (PCIe transfer or host compute): the job is off the
    /// device from `start` to `end` and resumes past the event.
    Sleep,
    /// A zero-time pc step (reservation-covered Malloc/Free, Memset
    /// Nop): no clock movement, no shared-state change.
    Skip,
}

/// One trace event of an in-flight macro segment, with the virtual
/// interval the dry run computed for it.
#[derive(Clone, Copy, Debug)]
struct MacroItem {
    /// Index into the job's compacted trace (== raw-trace index).
    pc: usize,
    start: f64,
    end: f64,
    kind: MacroItemKind,
}

/// An in-flight compiled macro segment (`--compile-traces on` only).
///
/// Built by `try_enter_macro`'s dry run: a scratch *clone* of the (then
/// idle) target device is driven through the exact call sequence the
/// fine-grained path would make, recording each event's interval. The
/// segment then rests as ONE pending `MacroSegment` event; firing it —
/// or any side-exit decompiling it early — replays the same calls on
/// the real device, which therefore lands in the bit-identical state
/// (same floats, same kernel handles) fine-grained stepping would have
/// produced.
#[derive(Clone, Debug)]
struct MacroRt {
    node: usize,
    dev: usize,
    /// pc to resume fine-grained stepping at after a full replay
    /// (the segment's exclusive end).
    end_pc: usize,
    /// The owning task's probe interference vector (every launch in a
    /// segment belongs to one task, so one vector covers them all).
    iv: InterferenceProfile,
    items: Vec<MacroItem>,
}

#[derive(Debug, Default)]
struct JobRt {
    pc: usize,
    /// Cluster node the dispatcher routed this job to.
    node: usize,
    /// Runtime task id -> device (on the job's node), dense by task id
    /// (`NO_DEV` = unplaced). Task ids are dense per job, so a slab
    /// replaces the HashMap the hot loop hashed on every Launch/Malloc.
    task_dev: Vec<u32>,
    /// Memory held per open task (reservations + raw allocations).
    ledger: TaskLedger,
    pinned_dev: Option<usize>,
    worker: usize,
    started: f64,
    ended: f64,
    crashed: bool,
    done: bool,
    /// Dispatch-time load estimates (kernel + host us, peak bytes).
    est_work_us: u64,
    est_mem_bytes: u64,
    /// Dispatch-time interference estimate: componentwise max over the
    /// job's task probes (`JobTrace::peak_interference`). All-zero for
    /// legacy workloads — which keeps every interference-aware branch
    /// on its bit-identical off path.
    est_iv: InterferenceProfile,
    /// Per-task probe interference vectors, dense by task id; recorded
    /// at TaskBegin so the Launch arm can hand the task's pressure to
    /// `Device::start_kernel_with` without re-walking the trace.
    task_iv: Vec<InterferenceProfile>,
    ded_s: f64,
    act_s: f64,
    n_kernels: u64,
    kernel_started: f64,
    kernel_ded: f64,
    /// (device, handle) of the in-flight kernel, if any.
    inflight: Option<(usize, usize)>,
    /// Dedicated-V100 work of the in-flight kernel (for wasted-work
    /// accounting when it is killed).
    kernel_work_s: f64,
    /// Checkpoint/restart lifecycle (Normal unless preemption fires).
    phase: JPhase,
    /// Probe resource vectors of open placed tasks, dense by task id —
    /// written only in preemption mode, so a checkpointed task can be
    /// re-placed.
    task_req: Vec<Option<TaskReq>>,
    /// Checkpointed open tasks awaiting restore.
    saved: Vec<(usize, TaskReq)>,
    /// Times this job has been preempted (bounds cascading).
    n_preempted: u32,
    /// Dedicated-work seconds lost to killed kernels.
    wasted_s: f64,
    /// The dispatcher has routed this job (its load is counted in the
    /// node's outstanding bookkeeping). Always true once queued in the
    /// zero-latency paths; set at probe-decision time under latency.
    dispatched: bool,
    /// The job has physically landed on its node (latency mode: after
    /// the dispatch hop; meaningless with the model off).
    arrived: bool,
    /// A task probe RPC is in flight for the TaskBegin at `pc`: either
    /// blocked at the node daemon (placement pending) or placed with
    /// the ack still travelling back. Latency mode only.
    probe_inflight: bool,
    /// Re-probes this job may still fire (`LatencyModel::reprobe_budget`
    /// at start; each served re-probe spends one). 0 = the route is
    /// committed. Latency mode with re-probing enabled only.
    reprobe_left: u32,
    /// The in-flight `ReProbe` already claimed its FIFO slot at the
    /// cluster frontend (it fired while the server was busy and was
    /// deferred to its service instant): the next firing decides
    /// without re-admitting.
    reprobe_served: bool,
    /// A `ReProbe` event belonging to the job's *current* journey is
    /// outstanding. Armed when the guard is set, disarmed when the
    /// re-probe is served — and force-disarmed by `begin_migration`,
    /// which starts a new journey: a deferred arrival re-probe still
    /// sitting in the queue (its landing overtook it) must fire as a
    /// no-op, not spend the restore's budget or double-uncharge its
    /// node.
    reprobe_armed: bool,
    /// Virtual time the current route's journey lands
    /// (`decision + RTT + dispatch cost`), recorded while a `ReProbe`
    /// guards the decision: a confirming re-probe commits the landing
    /// at exactly this instant (the re-probe rode along; it never
    /// delays a route it does not change).
    landing_at: f64,
    /// Home node of a cluster-migrating restore in flight: set when the
    /// checkpointed victim re-enters the cluster frontend, cleared when
    /// its `MigrateArrive` lands. Landing on any *other* node pays the
    /// image-transfer term and counts as a migration. `None` always
    /// with `migrate: "off"` — the flag the landing paths branch on.
    migrating_from: Option<usize>,
    /// The job currently occupies worker `worker` on node `node`: set
    /// at every worker pickup, relinquished at `CkptDone` (the captured
    /// slot is recycled by the `Restart` event instead). `finish_job`
    /// only hands a worker back when this is set — a checkpointed or
    /// migrating victim force-failed before its next pickup holds no
    /// worker, and recycling its stale index would hand another node's
    /// (or another job's) worker to the queue.
    holds_worker: bool,
    /// The admission controller turned this job away at arrival
    /// (`AdmitReject`): terminal like `done`, but distinct from
    /// `crashed` — the job never ran, never routed, and never held
    /// anything. Always false with admission off.
    rejected: bool,
    /// The in-flight macro segment, if the job is macro-stepping
    /// (`--compile-traces on` only; always `None` otherwise). While
    /// set, `step_job` refuses to step the job — the pending
    /// `MacroSegment` event (or an early decompile) owns its progress.
    macro_rt: Option<MacroRt>,
    /// Generation counter for this job's `MacroSegment` events: bumped
    /// at every decompile, so the event a decompile orphans fires as a
    /// stale no-op (the same pattern as `DevGens` for completions).
    macro_gen: u32,
}

struct Engine<'h> {
    mode: SchedMode,
    cluster_name: String,
    jobs: Vec<JobSpec>,
    /// Compacted traces (one per job) + interned artifact names.
    compact: Vec<Vec<CEv>>,
    artifact_names: Vec<String>,
    rt: Vec<JobRt>,
    nodes: Vec<NodePlacement>,
    gens: DevGens,
    /// Kernel handle -> owning job, one slab per flat device (indexed
    /// by the shared `DevGens::flat` layout). Each slab holds only the
    /// device's *resident* kernels (a handful), so a linear scan plus
    /// `swap_remove` replaces hashing a (node, dev, handle) 3-tuple on
    /// every launch and completion.
    kernel_owner: Vec<Vec<(usize, u32)>>,
    evq: EventQueue,
    dispatcher: Box<dyn Dispatcher>,
    /// Reused dispatcher-snapshot buffer: `dispatch_job` refills it in
    /// place instead of allocating a fresh `Vec<NodeLoadView>` per
    /// routing decision (one per arrival / re-probe / migration —
    /// O(jobs · nodes) allocation traffic at fleet scale).
    views_scratch: Vec<NodeLoadView>,
    /// Per-node dispatched-but-unfinished load (dispatcher bookkeeping).
    outstanding_us: Vec<u64>,
    outstanding_mem: Vec<u64>,
    /// Per-node summed interference estimates of dispatched-but-
    /// unfinished jobs — the `NodeLoadView::pressure` source. Stays
    /// all-zero whenever every job's profile is zero.
    outstanding_iv: Vec<InterferenceProfile>,
    /// Checkpoint/restart machinery; `None` = preemption disabled.
    preempt: Option<PreemptRt>,
    /// Checkpoints currently in flight per node (mirrors the set of
    /// jobs in `JPhase::Checkpointing`): O(1) eviction-storm guard for
    /// `try_preempt`, which runs on every failed probe retry.
    ckpt_inflight: Vec<u32>,
    /// Probe/dispatch latency model (sanitized: no negative terms).
    latency: LatencyModel,
    /// Cached `latency.is_off()` — invariant for the whole run, and
    /// checked on every Arrive/TaskBegin; `true` selects the exact
    /// pre-latency code paths everywhere.
    latency_off: bool,
    /// Cluster-frontend FIFO server: virtual time it frees up.
    frontend_busy: f64,
    /// Per-node scheduler-daemon FIFO servers (task probes).
    daemon_busy: Vec<f64>,
    /// Per-node close time of the currently-open ack-coalescing window
    /// (see `LatencyModel::coalesce_window_s`); a success at t joins
    /// the open batch iff `t < ack_close[node]`.
    ack_close: Vec<f64>,
    /// Per-node FIFO of in-flight ack batches: the front batch belongs
    /// to the next shared `ProbeAck` to land on that node (acks to one
    /// node depart in order and fly the same RTT, so FIFO holds). Each
    /// batch lists its member jobs, carrier first.
    ack_batch: Vec<std::collections::VecDeque<Vec<usize>>>,
    /// Frontend admission controller; `None` = ungoverned (the off
    /// path, structurally identical to the pre-admission engine).
    admit: Option<AdmissionRt>,
    /// Per-class frontend backlog (`--frontend-q prio|wfq` under a
    /// nonzero latency model only); `None` = the PR-3 FIFO server.
    fe_queue: Option<FrontendQueue>,
    /// A `FrontendServe` event is outstanding for the current busy
    /// span. Invariant: whenever `fe_queue` is non-empty, this is set —
    /// the queue can never strand a job.
    fe_serve_armed: bool,
    /// Compiled trace replay is armed: `compile_traces` on and no
    /// launch hook (a hook must observe every individual launch, so
    /// macro-stepping is disabled under `--compute real`). `false`
    /// keeps every macro branch off its bit-identical legacy path.
    macro_ok: bool,
    /// Per-job compiled trace programs (`lazy::compile`), shared with
    /// the memoizing `JobTrace` via `Arc` — cloned specs of one
    /// distinct trace compile once. Empty when `macro_ok` is false.
    programs: Vec<std::sync::Arc<TraceProgram>>,
    /// Per flat device (the `DevGens::flat` layout): the job currently
    /// macro-stepping on it, if any. A macro segment's kernels are not
    /// resident on the real device until replay, so this — not
    /// `Device::n_kernels` — is the occupancy check that keeps two
    /// macro segments (or a macro and a fine-grained launch) from
    /// unknowingly sharing a device.
    macro_on_dev: Vec<Option<usize>>,
    /// Fired events on the observable subset (`EvKind::is_observable`)
    /// — the stream the compiled-replay contract holds invariant, so
    /// `bench scale` can cross-check it per row without arming the
    /// (allocation-heavy) trace recorder.
    observable_events: u64,
    hook: Option<LaunchHook<'h>>,
    /// Debug sanitizer (`--sanitize`); `None` = unchecked (the default,
    /// one branch per event away from the plain engine).
    sanitizer: Option<SanitizerRt>,
}

/// Runtime state of the frontend admission controller (`--admit`).
struct AdmissionRt {
    cfg: AdmissionConfig,
    /// Token state for the "token" policy (idle under "util").
    bucket: TokenBucket,
    /// Batch arrivals demoted to best-effort under pressure.
    degraded: u64,
}

/// Runtime state of the preemption layer.
struct PreemptRt {
    cfg: PreemptConfig,
    policy: Box<dyn PreemptPolicy>,
    /// Evictions actually performed (aborted checkpoints not counted).
    preemptions: u64,
    /// Virtual seconds spent writing + restoring checkpoint images.
    overhead_s: f64,
    /// Restores that landed on a node other than the victim's home
    /// (cluster migration only; same-node re-placements not counted).
    migrations: u64,
    /// Checkpoint-image bytes shipped across nodes by those restores.
    migrate_bytes: u64,
}

/// One invariant breach observed by the engine sanitizer.
#[derive(Debug, Clone)]
pub struct SanitizerViolation {
    /// Virtual time of the event after which the breach was observed.
    pub t: f64,
    /// Human-readable description of the broken invariant.
    pub what: String,
}

/// Result of a `--sanitize` run: the engine's conservation invariants,
/// re-checked after every fired event. A clean report is a machine-
/// checked proof that the run never double-released device memory,
/// never handed one worker slot to two jobs, and never ran its virtual
/// clock backwards — the properties the golden traces witness only
/// indirectly.
#[derive(Debug, Default)]
pub struct SanitizerReport {
    /// Events the sanitizer inspected (one check per fired event plus
    /// one per drain-fallback force-finish).
    pub events_checked: u64,
    /// Observed breaches, in firing order (capped; see `suppressed`).
    pub violations: Vec<SanitizerViolation>,
    /// Violations beyond the recording cap. The first breach usually
    /// cascades — broken conservation stays broken on every later
    /// event — so the tail carries no extra signal.
    pub suppressed: u64,
}

impl SanitizerReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.suppressed == 0
    }
}

/// Runtime state of the `--sanitize` debug layer. `None` on the engine
/// costs one branch per event; armed, every check is observational —
/// it reads engine state and never writes it, so a sanitized run's
/// scheduling decisions (and trace) are identical to a plain run's.
#[derive(Default)]
struct SanitizerRt {
    /// Latest event time seen (events start at t >= 0).
    last_t: f64,
    report: SanitizerReport,
}

impl SanitizerRt {
    /// Recording cap: keep the report bounded when an invariant breaks
    /// early in a fleet-scale run and every later event re-reports it.
    const MAX_VIOLATIONS: usize = 100;

    fn fail(&mut self, t: f64, what: String) {
        if self.report.violations.len() < Self::MAX_VIOLATIONS {
            self.report.violations.push(SanitizerViolation { t, what });
        } else {
            self.report.suppressed += 1;
        }
    }
}

/// Run a batch of jobs under `cfg`; all jobs are queued at t = 0.
pub fn run_batch(cfg: RunConfig, jobs: Vec<JobSpec>) -> RunResult {
    run_batch_with_hook(cfg, jobs, None)
}

/// `run_batch` plus a real-compute hook invoked per artifact launch.
pub fn run_batch_with_hook(
    cfg: RunConfig,
    jobs: Vec<JobSpec>,
    hook: Option<LaunchHook<'_>>,
) -> RunResult {
    let cluster_cfg = ClusterConfig {
        cluster: ClusterSpec::single(cfg.node),
        mode: cfg.mode,
        workers_per_node: cfg.workers,
        dispatch: "rr",
        preempt: None,
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    run_cluster_with_hook(cluster_cfg, jobs, hook)
}

/// Run a batch across a multi-node cluster: the dispatcher routes each
/// job to a node at arrival; per-node policies place its tasks. With a
/// single-node cluster this is exactly `run_batch`.
pub fn run_cluster(cfg: ClusterConfig, jobs: Vec<JobSpec>) -> RunResult {
    run_cluster_with_hook(cfg, jobs, None)
}

/// `run_cluster` with the event-core's trace recorder armed: returns
/// the result plus one serialised line per *fired* event, in firing
/// order. The golden-trace test harness compares these streams
/// byte-for-byte across runs and against committed fixtures.
pub fn run_cluster_traced(cfg: ClusterConfig, jobs: Vec<JobSpec>) -> (RunResult, Vec<String>) {
    let (result, trace, _) = run_cluster_inner(cfg, jobs, None, true, false, false);
    (result, trace)
}

/// `run_cluster` on an explicitly named event-queue backend: `"heap"`
/// selects the pre-overhaul `BinaryHeap` reference backend, any other
/// name the default calendar queue. Both realise the same (t, seq)
/// total order — `bench scale` runs every sweep row on each so the
/// overhaul's speedup is measured in one binary rather than asserted,
/// and the golden-trace tests replay the two byte-for-byte.
pub fn run_cluster_on_backend(cfg: ClusterConfig, jobs: Vec<JobSpec>, backend: &str) -> RunResult {
    run_cluster_inner(cfg, jobs, None, false, backend == "heap", false).0
}

/// [`run_cluster_traced`] on a named event-queue backend
/// (see [`run_cluster_on_backend`]).
pub fn run_cluster_traced_on_backend(
    cfg: ClusterConfig,
    jobs: Vec<JobSpec>,
    backend: &str,
) -> (RunResult, Vec<String>) {
    let (result, trace, _) = run_cluster_inner(cfg, jobs, None, true, backend == "heap", false);
    (result, trace)
}

/// `run_cluster` plus a real-compute hook invoked per artifact launch.
pub fn run_cluster_with_hook(
    cfg: ClusterConfig,
    jobs: Vec<JobSpec>,
    hook: Option<LaunchHook<'_>>,
) -> RunResult {
    run_cluster_inner(cfg, jobs, hook, false, false, false).0
}

/// `run_cluster` with the debug sanitizer armed (`--sanitize`): after
/// every fired event the engine re-checks its conservation invariants
/// — per-node device memory is never negative and always equals
/// capacity minus the sum of resident reservations/allocations, a
/// worker slot is held by at most one live job, and event times are
/// monotone. The checks are observational (read-only), so the run's
/// results and trace are identical to `run_cluster`'s.
pub fn run_cluster_sanitized(
    cfg: ClusterConfig,
    jobs: Vec<JobSpec>,
) -> (RunResult, SanitizerReport) {
    let (result, _, report) = run_cluster_inner(cfg, jobs, None, false, false, true);
    (result, report)
}

fn run_cluster_inner(
    cfg: ClusterConfig,
    jobs: Vec<JobSpec>,
    hook: Option<LaunchHook<'_>>,
    record_trace: bool,
    heap_backend: bool,
    sanitize: bool,
) -> (RunResult, Vec<String>, SanitizerReport) {
    // Partition-then-allocate: under the partition dispatcher every
    // physical device is split into PARTITION_SLICES static MIG-style
    // isolation domains before the placement layer ever sees it — the
    // dispatcher's contention-aware allocation is over sliced nodes.
    // Keyed off the canonical dispatcher name so `ClusterConfig` needs
    // no new field and every other dispatcher builds bit-identically.
    let slices = if canonical_dispatch(cfg.dispatch) == Some("partition") {
        super::placement::PARTITION_SLICES
    } else {
        1
    };
    let nodes: Vec<NodePlacement> = cfg
        .cluster
        .nodes
        .iter()
        .map(|n| NodePlacement::new(&n.sliced(slices), &cfg.mode, cfg.workers_per_node))
        .collect();
    let devs_per_node: Vec<usize> = nodes.iter().map(|n| n.devices.len()).collect();
    let gens = DevGens::new(&devs_per_node);
    let n_devs = gens.n_devs();
    let mut artifact_names = Vec::new();
    let mut intern: HashMap<String, u32> = HashMap::new();
    let mut compact = Vec::with_capacity(jobs.len());
    let mut task_bound = Vec::with_capacity(jobs.len());
    for j in &jobs {
        let (c, n_tasks) = compact_trace(&j.trace, &mut artifact_names, &mut intern);
        compact.push(c);
        task_bound.push(n_tasks);
    }
    let n_nodes = nodes.len();
    // Clamp negative latency terms: they would schedule events into
    // the past and silently run the virtual clock backwards. An
    // effectively-zero model then takes the off path like any other.
    let latency = cfg.latency.sanitized();
    let rt: Vec<JobRt> = jobs
        .iter()
        .zip(&task_bound)
        .map(|(j, &n_tasks)| {
            // One memoized summary read per job: cloned specs of one
            // distinct trace share the computed-once walk.
            let s = *j.trace.summary();
            JobRt {
                est_work_us: s.total_work_us + s.total_host_us,
                est_mem_bytes: s.peak_reserved_bytes,
                est_iv: s.peak_interference,
                task_iv: vec![InterferenceProfile::ZERO; n_tasks],
                reprobe_left: latency.reprobe_budget,
                task_dev: vec![NO_DEV; n_tasks],
                task_req: vec![None; n_tasks],
                ledger: TaskLedger::with_tasks(n_tasks),
                ..JobRt::default()
            }
        })
        .collect();
    // Compiled trace replay: compile once per distinct trace (the
    // `JobTrace` memoizes the program behind an `Arc`), and only when
    // the layer is armed — an off run never invokes the compiler. A
    // launch hook disarms it: the hook must see every single launch.
    let macro_ok = cfg.compile_traces && hook.is_none();
    let programs: Vec<std::sync::Arc<TraceProgram>> = if macro_ok {
        jobs.iter().map(|j| j.trace.compiled().clone()).collect()
    } else {
        Vec::new()
    };
    let mut eng = Engine {
        mode: cfg.mode,
        cluster_name: cfg.cluster.name.clone(),
        compact,
        artifact_names,
        rt,
        gens,
        kernel_owner: vec![Vec::new(); n_devs],
        evq: if heap_backend { EventQueue::with_heap_backend() } else { EventQueue::new() },
        dispatcher: make_dispatcher(cfg.dispatch),
        views_scratch: Vec::with_capacity(n_nodes),
        outstanding_us: vec![0; n_nodes],
        outstanding_mem: vec![0; n_nodes],
        outstanding_iv: vec![InterferenceProfile::ZERO; n_nodes],
        // Sanitize the preemption cost model like the latency model: a
        // zero/negative checkpoint bandwidth would push CkptDone at an
        // inf/NaN time and poison the event heap's ordering.
        preempt: cfg.preempt.map(|c| {
            let pc = c.sanitized();
            PreemptRt {
                policy: make_preempt_policy(pc.policy),
                cfg: pc,
                preemptions: 0,
                overhead_s: 0.0,
                migrations: 0,
                migrate_bytes: 0,
            }
        }),
        ckpt_inflight: vec![0; n_nodes],
        latency_off: latency.is_off(),
        // Sanitize the admission knobs like the other opt-in layers; an
        // off policy builds no runtime at all, so the ungoverned path
        // is an is-none check away from the pre-admission engine.
        admit: cfg
            .admit
            .map(|a| a.sanitized())
            .filter(|a| a.enabled())
            .map(|a| AdmissionRt { bucket: TokenBucket::new(&a), cfg: a, degraded: 0 }),
        // A frontend queue only exists where frontend queueing can:
        // under a nonzero latency model with a non-FIFO discipline.
        fe_queue: {
            let q = canonical_frontend_q(cfg.frontend_q).unwrap_or_else(|| {
                panic!("unknown frontend queue discipline '{}'", cfg.frontend_q)
            });
            if q != "fifo" && !latency.is_off() {
                Some(FrontendQueue::new(q))
            } else {
                None
            }
        },
        fe_serve_armed: false,
        macro_ok,
        programs,
        macro_on_dev: vec![None; n_devs],
        observable_events: 0,
        latency,
        frontend_busy: 0.0,
        daemon_busy: vec![0.0; n_nodes],
        ack_close: vec![0.0; n_nodes],
        ack_batch: vec![std::collections::VecDeque::new(); n_nodes],
        nodes,
        jobs,
        hook,
        sanitizer: sanitize.then(SanitizerRt::default),
    };
    if record_trace {
        eng.evq.record_trace();
    }
    let result = eng.run();
    let report = eng.sanitizer.take().map(|s| s.report).unwrap_or_default();
    (result, eng.evq.take_trace(), report)
}

impl<'h> Engine<'h> {
    /// Route `job` to a node (cluster layer) and record its estimated
    /// load against that node. The load views are snapshotted at `t` —
    /// the *probe* time: under a nonzero latency model the job lands
    /// a round-trip plus dispatch cost later, so this snapshot is
    /// exactly the stale one a real frontend acts on. Only the timeout
    /// + re-probe guard (`handle_reprobe`) ever revisits the decision.
    /// Returns the node index.
    fn dispatch_job(&mut self, job: usize, t: f64) -> usize {
        let dispatch_cost_s = self.latency.dispatch_latency(self.rt[job].est_mem_bytes);
        // Refill the reused snapshot buffer (taken out of `self` so the
        // closure below can borrow the other fields freely).
        let mut views = std::mem::take(&mut self.views_scratch);
        views.clear();
        views.extend(self.nodes.iter().enumerate().map(|(i, nd)| NodeLoadView {
            queued_jobs: nd.job_q.len(),
            outstanding_work_us: self.outstanding_us[i],
            outstanding_mem_bytes: self.outstanding_mem[i],
            free_mem: nd.free_mem(),
            total_mem: nd.total_mem(),
            n_gpus: nd.devices.len(),
            compute_capacity: nd.compute_capacity,
            taken_at: t,
            probe_rtt_s: self.latency.probe_rtt(i),
            dispatch_cost_s,
            pressure: self.outstanding_iv[i],
        }));
        let info = JobInfo {
            est_work_us: self.rt[job].est_work_us,
            peak_mem_bytes: self.rt[job].est_mem_bytes,
            iv: self.rt[job].est_iv,
        };
        let mut node = self.dispatcher.route(&info, &views);
        self.views_scratch = views;
        debug_assert!(node < self.nodes.len(), "dispatcher routed off-cluster");
        if let Some(home) = self.rt[job].migrating_from {
            // A memory-oblivious dispatcher (rr, least) may route a
            // migrating restore to a node that can never hold its saved
            // reservation set — where the all-or-nothing re-place would
            // strand it until the drain fallback misreports a crash.
            // Restores are not allowed to die to routing: fall back to
            // the home node, which held the set before the eviction.
            if !self.restore_can_ever_fit(job, node) {
                node = home;
            }
        }
        self.rt[job].node = node;
        self.rt[job].dispatched = true;
        self.outstanding_us[node] += self.rt[job].est_work_us;
        self.outstanding_mem[node] += self.rt[job].est_mem_bytes;
        self.outstanding_iv[node] = self.outstanding_iv[node].add(&self.rt[job].est_iv);
        node
    }

    /// `job` lands on its routed node and joins the worker queue; an
    /// idle worker picks it up immediately. Shared by the zero-latency
    /// `Arrive` arm and the `DispatchArrive` handler so the two landing
    /// paths cannot drift apart.
    fn land_job(&mut self, job: usize, t: f64) {
        let n = self.rt[job].node;
        self.nodes[n].job_q.push_back(job);
        if let Some(w) = self.nodes[n].pop_idle() {
            self.start_next_job(n, w, t);
        }
    }

    /// FIFO single-server queueing at the cluster frontend: an RPC
    /// arriving at `t` is served at max(t, busy-until) and holds the
    /// server for one service time. Returns the service instant.
    fn admit_frontend(&mut self, t: f64) -> f64 {
        let s = t.max(self.frontend_busy);
        self.frontend_busy = s + self.latency.frontend_service_s;
        s
    }

    /// Same FIFO queueing at `node`'s scheduler daemon (task probes).
    fn admit_daemon(&mut self, node: usize, t: f64) -> f64 {
        let s = t.max(self.daemon_busy[node]);
        self.daemon_busy[node] = s + self.latency.frontend_service_s;
        s
    }

    /// The frontend's admission verdict for `job` arriving at `t`:
    /// `true` admits (possibly with the job demoted a class), `false`
    /// rejects — the terminal `AdmitReject` is already pushed and the
    /// caller must not route, queue, or serve the job. Ungoverned runs
    /// (`admit: None`) return `true` unconditionally without touching
    /// any state, keeping the off path bit-identical.
    fn admit_arrival(&mut self, job: usize, t: f64) -> bool {
        let Some(ad) = self.admit.as_mut() else {
            return true;
        };
        let slo = self.jobs[job].slo;
        let pressured = match ad.cfg.policy {
            "token" => {
                if SloClass::looseness(slo) == 0 {
                    // Protected: latency-sensitive arrivals are neither
                    // shed nor charged a token — they cannot starve the
                    // bucket the looser classes are metered by.
                    false
                } else {
                    !ad.bucket.try_take(t)
                }
            }
            _ => {
                // "util": pressured when the cluster's outstanding
                // backlog exceeds the bound, in seconds of dedicated
                // work per unit of compute capacity.
                let backlog_s: f64 =
                    self.outstanding_us.iter().map(|&u| u as f64 * 1e-6).sum();
                let cap: f64 = self.nodes.iter().map(|n| n.compute_capacity).sum();
                backlog_s / cap.max(1e-12) > ad.cfg.util_threshold_s
            }
        };
        if !pressured {
            return true;
        }
        match decide_under_pressure(slo) {
            AdmitDecision::Admit => true,
            AdmitDecision::Degrade => {
                // Demoted one class: the job keeps running, but every
                // later consumer of its SLO — task probes, SLO-aware
                // victim selection, per-class attainment — sees
                // best-effort from here on.
                self.jobs[job].slo = Some(SloClass::BestEffort);
                self.admit.as_mut().expect("admission on").degraded += 1;
                true
            }
            AdmitDecision::Reject => {
                self.evq.push(t, EvKind::AdmitReject { job });
                false
            }
        }
    }

    /// Terminal admission rejection: the job ends at its arrival
    /// instant, rejected (not crashed). It was never dispatched, never
    /// landed, and never held a worker or reservation, so there is
    /// nothing to release or recycle — `finish_job`'s machinery is
    /// deliberately bypassed.
    fn handle_admit_reject(&mut self, job: usize, t: f64) {
        let rt = &mut self.rt[job];
        debug_assert!(!rt.dispatched && !rt.holds_worker, "rejected jobs hold nothing");
        if rt.done {
            return; // force-failed by the drain fallback first
        }
        rt.done = true;
        rt.rejected = true;
        rt.ended = t;
    }

    /// An admitted arrival at the cluster frontend (latency mode): FIFO
    /// runs claim a server slot immediately (the PR-3 path); under a
    /// per-class discipline a busy server queues the job by class
    /// instead, to be served at the next `FrontendServe`.
    fn frontend_admit_or_queue(&mut self, job: usize, t: f64) {
        if self.fe_queue.is_none() || t >= self.frontend_busy {
            // Idle server (or FIFO): serve now. Under a discipline the
            // backlog must be empty whenever the server is idle (the
            // FrontendServe invariant), so serving directly cannot
            // overtake a queued job.
            let t_send = self.admit_frontend(t);
            self.evq.push(t_send, EvKind::ProbeSent { job });
        } else {
            let slo = self.jobs[job].slo;
            self.fe_queue.as_mut().expect("discipline active").push(job, slo);
            if !self.fe_serve_armed {
                self.fe_serve_armed = true;
                self.evq.push(self.frontend_busy, EvKind::FrontendServe);
            }
        }
    }

    /// The frontend server freed up with a per-class backlog waiting:
    /// serve the next routing probe by discipline. A FIFO-claiming RPC
    /// (re-probe, migrating restore) may have extended the busy span
    /// past this firing — re-arm at the new free instant rather than
    /// double-booking the server.
    fn handle_frontend_serve(&mut self, t: f64) {
        self.fe_serve_armed = false;
        if t < self.frontend_busy {
            if self.fe_queue.as_ref().is_some_and(|q| !q.is_empty()) {
                self.fe_serve_armed = true;
                self.evq.push(self.frontend_busy, EvKind::FrontendServe);
            }
            return;
        }
        let job = loop {
            match self.fe_queue.as_mut().and_then(|q| q.pop()) {
                // Force-failed by the drain fallback while queued:
                // nothing to route.
                Some(j) if self.rt[j].done => continue,
                Some(j) => break j,
                None => return,
            }
        };
        let t_send = self.admit_frontend(t); // server free: serves at t
        self.evq.push(t_send, EvKind::ProbeSent { job });
        if self.fe_queue.as_ref().is_some_and(|q| !q.is_empty()) {
            self.fe_serve_armed = true;
            self.evq.push(self.frontend_busy, EvKind::FrontendServe);
        }
    }

    /// A job enters the system: admission verdict first (a rejection is
    /// terminal and consumes nothing), then the zero-latency path
    /// routes and lands it inline while the latency path sends it
    /// through the frontend — the routing decision happens when its
    /// probe is served (`ProbeSent`), not now.
    fn handle_arrive(&mut self, job: usize, t: f64) {
        if !self.admit_arrival(job, t) {
            return;
        }
        if self.latency_off {
            self.dispatch_job(job, t);
            self.land_job(job, t);
        } else {
            self.frontend_admit_or_queue(job, t);
        }
    }

    /// A probe RPC reached its server (latency mode only): the cluster
    /// frontend's routing probe if the job is not yet dispatched, else
    /// the task probe at the job's node daemon.
    fn handle_probe_sent(&mut self, job: usize, t: f64) {
        if self.rt[job].done {
            return;
        }
        if !self.rt[job].dispatched {
            // Route NOW, on the load the frontend sees now; the ack
            // travels back over the chosen node's round-trip.
            let node = self.dispatch_job(job, t);
            self.launch_journey(job, node, t);
        } else {
            self.daemon_try_place(job, t);
        }
    }

    /// Start (or restart, after a redirect) the routed job's journey to
    /// `node`, decided at `t`. If re-probing is enabled and the landing
    /// delay exceeds the staleness bound — with budget left to spend —
    /// the decision is guarded by a `ReProbe` at the bound instead of
    /// committing: the landing instant is recorded and the `ProbeAck` /
    /// `DispatchArrive` chain is deferred to the re-probe's verdict.
    /// Otherwise the journey commits exactly as PR-3 shipped it.
    fn launch_journey(&mut self, job: usize, node: usize, t: f64) {
        let rtt = self.latency.probe_rtt(node);
        let mut landing_delay = rtt + self.latency.dispatch_latency(self.rt[job].est_mem_bytes);
        if self.rt[job].migrating_from.is_some() {
            // The checkpoint-image transfer is part of a migrating
            // restore's journey: a restore dominated by a 10 s image
            // copy is exactly as stale-prone at landing as a far
            // dispatch, so it arms the same guard and commits the same
            // full landing instant.
            landing_delay += self.migrate_xfer_s(job);
        }
        // Guard only load-based routing: a load-oblivious decision
        // (round-robin) cannot go stale, and re-asking a stateful
        // router would fake a redirect on every firing.
        let guarded = self.latency.reprobe_enabled()
            && self.dispatcher.load_based()
            && self.rt[job].reprobe_left > 0
            && self.latency.reprobe_after_s < landing_delay;
        if guarded {
            self.rt[job].landing_at = t + landing_delay;
            self.rt[job].reprobe_armed = true;
            self.evq.push(t + self.latency.reprobe_after_s, EvKind::ReProbe { job });
        } else {
            self.evq.push(t + rtt, EvKind::ProbeAck { job });
        }
    }

    /// The staleness timeout fired for a routed-but-not-landed job: the
    /// re-probe is an RPC like any other, so it first claims a FIFO
    /// slot at the cluster frontend (a busy server defers the decision
    /// to the claimed service instant — re-probe traffic competes with
    /// arrival probes instead of queue-jumping them). When served, the
    /// frontend re-snapshots the cluster (with the job's own load taken
    /// back off its current node, so the comparison is unbiased) and
    /// routes again. A *confirmation* commits the original journey at
    /// its already-recorded landing instant — the re-probe rode along
    /// and adds nothing to a route it does not change (unless frontend
    /// congestion pushed the decision past the planned landing, which
    /// then happens at the decision instant). A *redirect* re-charges
    /// the job to the new node and restarts the journey from now, which
    /// may itself be guarded again while budget remains. Every served
    /// re-probe spends one unit of budget, so routing terminates.
    fn handle_reprobe(&mut self, job: usize, t: f64) {
        if self.rt[job].done || self.rt[job].arrived {
            return;
        }
        if !self.rt[job].reprobe_armed {
            // A deferred re-probe from a journey this job no longer
            // travels (its arrival landed, then a migration began): the
            // event is stale and owns nothing — firing it would spend
            // the new journey's budget and double-uncharge its node.
            return;
        }
        if self.rt[job].reprobe_served {
            self.rt[job].reprobe_served = false;
        } else {
            let s = self.admit_frontend(t);
            if s > t {
                self.rt[job].reprobe_served = true;
                self.evq.push(s, EvKind::ReProbe { job });
                return;
            }
        }
        debug_assert!(self.rt[job].dispatched, "re-probe for an unrouted job");
        debug_assert!(self.rt[job].reprobe_left > 0, "re-probe past its budget");
        // Served: this journey's outstanding re-probe is consumed (a
        // redirect's launch_journey may arm a fresh one).
        self.rt[job].reprobe_armed = false;
        self.rt[job].reprobe_left -= 1;
        let old = self.rt[job].node;
        self.outstanding_us[old] =
            self.outstanding_us[old].saturating_sub(self.rt[job].est_work_us);
        self.outstanding_mem[old] =
            self.outstanding_mem[old].saturating_sub(self.rt[job].est_mem_bytes);
        self.outstanding_iv[old] = self.outstanding_iv[old].sub_clamped(&self.rt[job].est_iv);
        self.rt[job].dispatched = false;
        let node = self.dispatch_job(job, t); // re-snapshot + re-charge
        if node == old {
            // Frontend congestion can defer the decision past the
            // planned landing; the job then lands at the (late)
            // confirmation itself.
            let landing_at = self.rt[job].landing_at.max(t);
            self.push_landing(job, landing_at);
        } else {
            self.launch_journey(job, node, t);
        }
    }

    /// Land the routed job at `t_land` — the *full* journey end, image
    /// transfer included for a migrating restore (the journey entry
    /// points `handle_probe_ack`/`launch_journey`/`begin_migration`
    /// fold `migrate_xfer_s` in, so a guarded restore's recorded
    /// `landing_at` already covers the transfer and a confirming
    /// re-probe commits it unchanged). Ordinary jobs land as
    /// `DispatchArrive`; a migrating restore as `MigrateArrive`.
    fn push_landing(&mut self, job: usize, t_land: f64) {
        if self.rt[job].migrating_from.is_some() {
            self.evq.push(t_land, EvKind::MigrateArrive { job });
        } else {
            self.evq.push(t_land, EvKind::DispatchArrive { job });
        }
    }

    /// Checkpoint-image bytes a migrating restore ships: the saved
    /// reservation set (what the checkpoint wrote out).
    fn saved_bytes(&self, job: usize) -> u64 {
        self.rt[job].saved.iter().map(|&(_, req)| req.mem_bytes).sum()
    }

    /// Whether `node` could re-place the migrating restore's saved
    /// reservations on an otherwise-empty node, decided by actually
    /// packing them first-fit in descending size over the node's device
    /// capacities. A success is its own witness (some placement
    /// exists), so this can never answer "feasible" for a node the set
    /// genuinely cannot fit — the direction that would strand the
    /// restore. A false "infeasible" (first-fit-decreasing is not a
    /// complete bin-packing decision procedure) merely takes the
    /// conservative home fallback.
    fn restore_can_ever_fit(&self, job: usize, node: usize) -> bool {
        // Under compute-hard placement (Alg2: all thread blocks must be
        // resident at once) a task whose footprint exceeds an *empty*
        // device is refused forever, whatever the memory situation —
        // the other policies treat compute as soft. Tasks that fit
        // individually but not simultaneously remain a (bin-packing)
        // blind spot here, as on the memory side below.
        let compute_hard =
            matches!(self.mode, SchedMode::Policy(p) if p == "mgb2" || p == "alg2");
        if compute_hard {
            let fits_somewhere = |req: &TaskReq| {
                self.nodes[node].devices.iter().any(|d| {
                    req.warps_per_tb <= d.spec.warps_per_sm as u64
                        && req.tbs <= d.spec.resident_tb_limit(req.warps_per_tb)
                })
            };
            if !self.rt[job].saved.iter().all(|(_, req)| fits_somewhere(req)) {
                return false;
            }
        }
        let mut free: Vec<u64> =
            self.nodes[node].devices.iter().map(|d| d.spec.mem_bytes).collect();
        let mut reqs: Vec<u64> =
            self.rt[job].saved.iter().map(|&(_, req)| req.mem_bytes).collect();
        reqs.sort_unstable_by(|a, b| b.cmp(a));
        'pack: for r in reqs {
            for f in free.iter_mut() {
                if *f >= r {
                    *f -= r;
                    continue 'pack;
                }
            }
            return false;
        }
        true
    }

    /// Image-transfer time of the migrating restore's *current* route:
    /// zero when it lands back home (the image never left the node).
    fn migrate_xfer_s(&self, job: usize) -> f64 {
        let home = self.rt[job].migrating_from.expect("migration in flight");
        if self.rt[job].node == home {
            0.0
        } else {
            let bw = self.preempt.as_ref().expect("migration in preempt mode").cfg;
            self.saved_bytes(job) as f64 / bw.migrate_bytes_per_s
        }
    }

    /// A probe's reply landed back at its client (latency mode only):
    /// a routed-but-not-landed job starts its dispatch hop; a placed
    /// task's job resumes stepping past its `TaskBegin`. Under
    /// coalescing a task ack is a *shared* reply: it resumes every
    /// member of its node's front ack batch, carrier first.
    fn handle_probe_ack(&mut self, job: usize, t: f64) {
        if !self.rt[job].done && !self.rt[job].arrived {
            let mut dt = self.latency.dispatch_latency(self.rt[job].est_mem_bytes);
            if self.rt[job].migrating_from.is_some() {
                dt += self.migrate_xfer_s(job);
            }
            self.push_landing(job, t + dt);
            return;
        }
        if self.latency.coalesce_window_s > 0.0 && self.rt[job].arrived {
            // Batches to one node depart in order and fly the same RTT,
            // so this ack is exactly the front batch's shared reply.
            let node = self.rt[job].node;
            let batch = self.ack_batch[node].pop_front().expect("ack batch for carrier");
            debug_assert_eq!(batch.first(), Some(&job), "carrier fronts its batch");
            for j in batch {
                if !self.rt[j].done {
                    self.rt[j].probe_inflight = false;
                    self.step_job(j, t);
                }
            }
            return;
        }
        if self.rt[job].done {
            return;
        }
        self.rt[job].probe_inflight = false;
        self.step_job(job, t);
    }

    /// Ask `job`'s node to place `task` with `req`; on success record
    /// the reservation in the job's ledger (and its request vector when
    /// preemption is on). On failure queue the job as a waiter and
    /// offer the preemption policy its victims. Returns whether the
    /// placement succeeded. Shared by the synchronous probe (latency
    /// off) and the daemon-side probe service (latency on) so the two
    /// paths cannot drift apart — latency mode is the same decisions
    /// plus delays, never different bookkeeping.
    fn probe_place(&mut self, job: usize, task: usize, req: &TaskReq, t: f64) -> bool {
        let node = self.rt[job].node;
        match self.nodes[node].place((job, task), req) {
            Some(dev) => {
                let preempt_on = self.preempt.is_some();
                let rt = &mut self.rt[job];
                rt.ledger.reserve(task, dev, req.mem_bytes);
                rt.task_dev[task] = dev as u32;
                if preempt_on {
                    rt.task_req[task] = Some(*req);
                }
                true
            }
            None => {
                self.nodes[node].push_waiter(job);
                if self.preempt.is_some() {
                    // Side-exit: under preemption, fine-grained
                    // stepping wakes waiters at every kernel launch —
                    // instants a macro segment would skip. Decompile
                    // the node's macros (the waiter just queued keeps
                    // them from re-entering), then scan for victims
                    // over the reconstructed in-flight kernels.
                    self.decompile_node_macros(node, t);
                    self.try_preempt(node, job, req, t);
                }
                false
            }
        }
    }

    /// A task probe is at `job`'s node daemon — first arrival, or a
    /// release-retry while the RPC blocks server-side. Try the
    /// placement now: success records the reservation immediately
    /// (visible to every later probe on the node) and sends the ack
    /// after the node's round-trip; failure queues the job as a waiter
    /// exactly like the synchronous path (the blocked RPC retries on
    /// the next release at no extra round-trip).
    fn daemon_try_place(&mut self, job: usize, t: f64) {
        debug_assert!(self.rt[job].probe_inflight, "no probe in flight");
        // A probe can only be in flight while pc rests on its TaskBegin
        // (the ack path is the only thing that advances pc past it).
        // Fail loudly if that invariant ever breaks: silently returning
        // would strand the job (no ack ever comes) and misreport it as
        // a crash via the drain fallback.
        let CEv::TaskBegin { task, res } = self.compact[job][self.rt[job].pc] else {
            unreachable!("job {job}: probe in flight away from its TaskBegin");
        };
        let req = probe_req(&res, self.jobs[job].slo);
        if self.probe_place(job, task, &req, t) {
            // pc advances when the ack lands (ProbeAck -> step_job).
            self.send_task_ack(job, t);
        }
    }

    /// Depart the daemon's reply for a successfully placed task probe.
    /// Without coalescing the ack leaves immediately and lands one RTT
    /// later (PR-3 behaviour). With `coalesce_window_s` > 0 the daemon
    /// holds replies Nagle-style: the first success opens a per-node
    /// window and schedules ONE shared `ProbeAck` at window close +
    /// RTT; every further success inside the window joins that batch
    /// and sends nothing — a burst of probes pays one held reply
    /// instead of a staggered reply each.
    fn send_task_ack(&mut self, job: usize, t: f64) {
        let node = self.rt[job].node;
        let w = self.latency.coalesce_window_s;
        if w == 0.0 {
            let rtt = self.latency.probe_rtt(node);
            self.evq.push(t + rtt, EvKind::ProbeAck { job });
        } else if t < self.ack_close[node] {
            let batch = self.ack_batch[node].back_mut().expect("open window has a batch");
            batch.push(job);
        } else {
            self.ack_close[node] = t + w;
            self.ack_batch[node].push_back(vec![job]);
            let rtt = self.latency.probe_rtt(node);
            self.evq.push(t + w + rtt, EvKind::ProbeAck { job });
        }
    }

    fn run(&mut self) -> RunResult {
        let latency_on = !self.latency_off;
        for j in 0..self.jobs.len() {
            let arr = self.jobs[j].arrival;
            if latency_on {
                // Every job reaches the cluster through the frontend:
                // Arrive -> (queueing) ProbeSent -> ProbeAck ->
                // DispatchArrive. Batch jobs arrive at t = 0. The
                // admission verdict waits for the Arrive firing.
                self.evq.push(arr.max(0.0), EvKind::Arrive { job: j });
            } else if arr <= 0.0 {
                // Inline t=0 seeding: the admission gate applies here
                // too (a rejected job is never dispatched or queued).
                if self.admit_arrival(j, 0.0) {
                    let n = self.dispatch_job(j, 0.0);
                    self.nodes[n].job_q.push_back(j);
                }
            } else {
                self.evq.push(arr, EvKind::Arrive { job: j });
            }
        }
        for n in 0..self.nodes.len() {
            for w in 0..self.nodes[n].n_workers() {
                self.start_next_job(n, w, 0.0);
            }
        }
        loop {
            while let Some(ev) = self.evq.pop() {
                if ev.kind.is_observable() {
                    self.observable_events += 1;
                }
                match ev.kind {
                    EvKind::Wake { job } => {
                        if !self.rt[job].done {
                            self.step_job(job, ev.t);
                        }
                    }
                    EvKind::DevCompletion { node, dev, gen } => {
                        if gen == self.gens.current(node, dev) {
                            self.handle_completions(node, dev, ev.t);
                        }
                    }
                    EvKind::Arrive { job } => self.handle_arrive(job, ev.t),
                    EvKind::ProbeSent { job } => self.handle_probe_sent(job, ev.t),
                    EvKind::ProbeAck { job } => self.handle_probe_ack(job, ev.t),
                    EvKind::ReProbe { job } => self.handle_reprobe(job, ev.t),
                    EvKind::DispatchArrive { job } => {
                        // The routed job lands on its node: admission
                        // was delayed by RTT + dispatch cost, and the
                        // routing decision was *not* revisited.
                        if !self.rt[job].done {
                            self.rt[job].arrived = true;
                            self.land_job(job, ev.t);
                        }
                    }
                    EvKind::CkptBegin { job } => self.handle_ckpt_begin(job, ev.t),
                    EvKind::CkptDone { job } => self.handle_ckpt_done(job, ev.t),
                    EvKind::Restart { job: _, node, worker } => {
                        // Recycle the worker the victim relinquished at
                        // CkptDone, now that the waiters its eviction
                        // unblocked have re-placed. The payload carries
                        // both node and worker: a same-instant pickup
                        // may already have assigned the victim a
                        // different worker, and a cluster-migrating
                        // victim may already be routed off its home node
                        // entirely. Unconditional — this event owns the
                        // captured slot whatever became of the victim
                        // (finish_job only recycles workers a job still
                        // holds).
                        self.start_next_job(node, worker, ev.t);
                    }
                    EvKind::MigrateArrive { job } => self.handle_migrate_arrive(job, ev.t),
                    EvKind::AdmitReject { job } => self.handle_admit_reject(job, ev.t),
                    EvKind::FrontendServe => self.handle_frontend_serve(ev.t),
                    EvKind::MacroSegment { job, gen } => {
                        self.handle_macro_segment(job, gen, ev.t);
                    }
                }
                if self.sanitizer.is_some() {
                    self.sanitize_event(ev.t);
                }
            }
            // Queue drained but some jobs never finished: their resource
            // requests can never be satisfied on their node (e.g. a task
            // bigger than any GPU). Fail one and keep draining — the
            // real scheduler would reject such a request up front; the
            // failure may unblock (or start) other jobs.
            match (0..self.rt.len()).find(|&j| !self.rt[j].done) {
                Some(j) => {
                    let t = self.evq.now();
                    self.finish_job(j, t, true);
                    if self.sanitizer.is_some() {
                        self.sanitize_event(t);
                    }
                }
                None => break,
            }
        }
        self.collect()
    }

    /// Re-check the engine's conservation invariants after one fired
    /// event (`--sanitize`). Strictly observational: every check reads
    /// engine state and none writes it, so an armed run's scheduling
    /// decisions — and its event trace — are bit-identical to a plain
    /// run's.
    fn sanitize_event(&mut self, t: f64) {
        let san = self.sanitizer.as_mut().expect("sanitizer armed");
        san.report.events_checked += 1;
        // (1) The virtual clock never runs backwards: the event queue's
        // (t, seq) total order must survive both backends.
        if t < san.last_t {
            san.fail(t, format!("event time ran backwards: {t} fired after {}", san.last_t));
        }
        san.last_t = san.last_t.max(t);
        // (2) Per-node device-memory conservation: every byte missing
        // from the free pool is held by exactly one job's ledger, and
        // the free pool never exceeds capacity (a double release would
        // mint memory out of thin air; a leaked reservation would lose
        // it). Ledger attribution by `rt.node` is sound at event
        // boundaries: a job's memory is fully released before any
        // reroute (eviction, migration) changes its node.
        for (n, node) in self.nodes.iter().enumerate() {
            let free = node.free_mem();
            let total = node.total_mem();
            if free > total {
                san.fail(
                    t,
                    format!("node {n}: free memory {free} exceeds capacity {total}"),
                );
            }
            let held: u64 = self
                .rt
                .iter()
                .filter(|r| r.node == n)
                .map(|r| r.ledger.held_bytes_total())
                .sum();
            if free.saturating_add(held) != total {
                san.fail(
                    t,
                    format!(
                        "node {n}: memory conservation broken: \
                         free {free} + held {held} != capacity {total}"
                    ),
                );
            }
        }
        // (3) A (node, worker) slot is owned by at most one live job.
        let mut owners: Vec<(usize, usize, usize)> = Vec::new();
        for (j, r) in self.rt.iter().enumerate() {
            if !r.holds_worker || r.done {
                continue;
            }
            match owners.iter().find(|&&(n, w, _)| n == r.node && w == r.worker) {
                Some(&(_, _, other)) => san.fail(
                    t,
                    format!(
                        "jobs {other} and {j} both hold worker {}.{}",
                        r.node, r.worker
                    ),
                ),
                None => owners.push((r.node, r.worker, j)),
            }
        }
    }

    fn start_next_job(&mut self, node: usize, worker: usize, t: f64) {
        let job = loop {
            let Some(j) = self.nodes[node].job_q.pop_front() else {
                self.nodes[node].mark_idle(worker);
                return;
            };
            if !self.rt[j].done {
                break j;
            }
            // Force-failed while still queued (drain fallback): there
            // is nothing to run — keep popping rather than binding the
            // worker to a dead job and starving the rest of the queue.
        };
        let pin = self.nodes[node].worker_pin[worker];
        let rt = &mut self.rt[job];
        rt.worker = worker;
        rt.holds_worker = true;
        if rt.phase == JPhase::Preempted {
            // Re-queued by checkpoint/restart: keep the original start
            // time and saved pc; step_job routes into the restore path.
            self.step_job(job, t);
            return;
        }
        rt.started = t;
        rt.pinned_dev = pin;
        self.step_job(job, t);
    }

    /// Process the job's trace from its pc until it blocks or finishes.
    fn step_job(&mut self, job: usize, t: f64) {
        if self.rt[job].done {
            // A force-failed job can still be popped from job_q; it must
            // not restore (or step) — a dead job re-placing its saved
            // reservations would leak them forever.
            return;
        }
        if self.rt[job].macro_rt.is_some() {
            // Macro-stepping: the pending MacroSegment event (or an
            // early decompile) owns this job's progress; a stray Wake
            // stepping it here would replay trace events twice.
            return;
        }
        match self.rt[job].phase {
            JPhase::Normal => {}
            // Quiesced mid-checkpoint; CkptDone re-queues it.
            JPhase::Checkpointing => return,
            // Checkpointed: re-place reservations before any stepping.
            JPhase::Preempted => {
                self.try_restore(job, t);
                return;
            }
            // Restore cost paid — resume from the killed kernel.
            JPhase::Restoring => self.rt[job].phase = JPhase::Normal,
        }
        loop {
            if self.rt[job].done {
                return;
            }
            if self.rt[job].pc >= self.compact[job].len() {
                self.finish_job(job, t, false);
                return;
            }
            if self.macro_ok && self.try_enter_macro(job, t) {
                return; // segment entered; its MacroSegment event wakes us
            }
            let node = self.rt[job].node;
            let ev = self.compact[job][self.rt[job].pc];
            match ev {
                CEv::Nop => {
                    self.rt[job].pc += 1;
                }
                CEv::TaskBegin { task, res } => {
                    // Record the probe's pressure vector for the Launch
                    // arm whatever placement path runs below (idempotent
                    // across probe retries/re-entries).
                    self.rt[job].task_iv[task] = res.iv;
                    if self.nodes[node].static_mode {
                        // §II-B: the app's cudaSetDevice (or device 0).
                        let dev = (res.static_dev.unwrap_or(0) as usize)
                            .min(self.nodes[node].devices.len() - 1);
                        let rt = &mut self.rt[job];
                        rt.task_dev[task] = dev as u32;
                        rt.pc += 1;
                        continue;
                    }
                    if let Some(dev) = self.rt[job].pinned_dev {
                        let rt = &mut self.rt[job];
                        rt.task_dev[task] = dev as u32;
                        rt.pc += 1;
                        continue;
                    }
                    if !self.latency_off {
                        // Async probe protocol: the RPC outcome arrives
                        // via ProbeSent/ProbeAck events, never inline.
                        // "Placed" is keyed on the live reservation —
                        // not task_dev, whose entries outlive TaskEnd —
                        // so a re-begun task id re-probes exactly like
                        // the synchronous path would.
                        if self.rt[job].ledger.has_reservation(task) {
                            if self.rt[job].probe_inflight {
                                return; // placed; ack still travelling
                            }
                            self.rt[job].pc += 1; // ack delivered
                            continue;
                        }
                        if self.rt[job].probe_inflight {
                            // Woken by a release while blocked at the
                            // daemon: retry the placement server-side.
                            self.daemon_try_place(job, t);
                            return;
                        }
                        self.rt[job].probe_inflight = true;
                        let t_send = self.admit_daemon(node, t);
                        self.evq.push(t_send, EvKind::ProbeSent { job });
                        return;
                    }
                    let req = probe_req(&res, self.jobs[job].slo);
                    if self.probe_place(job, task, &req, t) {
                        self.rt[job].pc += 1;
                    } else {
                        return;
                    }
                }
                CEv::Malloc { task, bytes } => {
                    let rt = &mut self.rt[job];
                    if rt.ledger.has_reservation(task) {
                        rt.pc += 1; // covered by the probe's reservation
                        continue;
                    }
                    let dev = rt.task_dev[task];
                    debug_assert_ne!(dev, NO_DEV, "task placed");
                    let dev = dev as usize;
                    match self.nodes[node].devices[dev].alloc(bytes) {
                        Ok(()) => {
                            let rt = &mut self.rt[job];
                            rt.ledger.add_alloc(task, dev, bytes);
                            rt.pc += 1;
                        }
                        Err(_avail) => {
                            // OOM: the CUDA runtime returns an error the
                            // (unmodified) app does not handle — crash.
                            self.finish_job(job, t, true);
                            return;
                        }
                    }
                }
                CEv::Xfer { bytes } => {
                    self.rt[job].pc += 1;
                    let dt = bytes as f64 / PCIE_BYTES_PER_SEC;
                    self.evq.push(t + dt, EvKind::Wake { job });
                    return;
                }
                CEv::Launch { task, artifact, grid, block, work_us } => {
                    let dev = self.rt[job].task_dev[task];
                    debug_assert_ne!(dev, NO_DEV, "task placed");
                    let dev = dev as usize;
                    // Side-exit: launching onto a macro-occupied device
                    // is a membership change its dry run did not price.
                    // Decompile the occupant first — its in-flight
                    // kernel becomes resident, and the sharing math
                    // below sees exactly the fine-grained device.
                    if let Some(occ) = self.macro_on_dev[self.gens.flat(node, dev)] {
                        if occ != job {
                            // Suppress macro re-entry while the
                            // occupant unwinds: if its replay completes
                            // at exactly `t` it steps on inline, and
                            // re-entering a fresh segment on this
                            // device would race the launch below.
                            let ok = self.macro_ok;
                            self.macro_ok = false;
                            self.decompile_macro(occ, t);
                            self.macro_ok = ok;
                        }
                    }
                    if artifact != NO_ARTIFACT {
                        if let Some(hook) = self.hook.as_mut() {
                            hook(&self.artifact_names[artifact as usize]);
                        }
                    }
                    let warps = grid * block.div_ceil(32);
                    let work_s = work_us as f64 * 1e-6;
                    let iv = self.rt[job].task_iv[task];
                    let d = &mut self.nodes[node].devices[dev];
                    d.advance_to(t);
                    let h = d.start_kernel_with(t, work_s, warps, iv);
                    let speed = d.spec.speed;
                    let fi = self.gens.flat(node, dev);
                    self.kernel_owner[fi].push((h, job as u32));
                    let rt = &mut self.rt[job];
                    rt.kernel_started = t;
                    rt.kernel_ded = work_s / speed;
                    rt.kernel_work_s = work_s;
                    rt.inflight = Some((dev, h));
                    self.resched_dev(node, dev, t);
                    // A launch creates an eviction opportunity (only
                    // kernel-running jobs are checkpointable): let any
                    // blocked probe on the node reconsider. Skipped
                    // entirely with preemption off, so the disabled
                    // path pushes no extra events.
                    if self.preempt.is_some() {
                        self.wake_waiters(node, t);
                    }
                    return; // job sleeps until DevCompletion wakes it
                }
                CEv::Free { task, bytes } => {
                    if let Some(dev) = self.rt[job].ledger.free_alloc(task, bytes) {
                        self.nodes[node].devices[dev].release(bytes);
                    }
                    self.rt[job].pc += 1;
                }
                CEv::TaskEnd { task } => {
                    self.release_task(job, task, t);
                    self.rt[job].pc += 1;
                }
                CEv::Host { micros } => {
                    self.rt[job].pc += 1;
                    self.evq.push(t + micros as f64 * 1e-6, EvKind::Wake { job });
                    return;
                }
            }
        }
    }

    /// Release a task's reservation / leftover allocations and let the
    /// node's policy + waiters know capacity freed up.
    fn release_task(&mut self, job: usize, task: usize, t: f64) {
        let node = self.rt[job].node;
        let nd = &mut self.nodes[node];
        let released = self.rt[job].ledger.release_task(&mut nd.devices, task);
        nd.release_policy((job, task));
        if let Some(req) = self.rt[job].task_req.get_mut(task) {
            *req = None;
        }
        if released || nd.has_policy() {
            self.wake_waiters(node, t);
        }
    }

    fn wake_waiters(&mut self, node: usize, t: f64) {
        for j in self.nodes[node].take_waiters() {
            self.evq.push(t, EvKind::Wake { job: j });
        }
    }

    /// `blocked`'s probe found no device on `node`: offer the preempt
    /// policy the running victims whose eviction would make `req` fit.
    /// Selecting one starts its checkpoint; the blocked job is already
    /// queued as a waiter and is woken by the eviction's `CkptDone`.
    fn try_preempt(&mut self, node: usize, blocked: usize, req: &TaskReq, t: f64) {
        if !self.nodes[node].has_policy() {
            return; // checkpoint/restart is defined for probe modes only
        }
        // One eviction in flight per node: blocked probes retry on every
        // release, and stacking checkpoints before the first image
        // finishes would over-evict (unbounded wasted work).
        if self.ckpt_inflight[node] > 0 {
            return;
        }
        // Eviction reclaims *memory*; preempt only memory-blocked waits.
        // If some device already has room, the probe failed on another
        // constraint (alg2's compute fit) and evicting a memory holder
        // would burn checkpoints without unblocking the task.
        if self.nodes[node].devices.iter().any(|d| d.free_mem >= req.mem_bytes) {
            return;
        }
        let cfg = self.preempt.as_ref().expect("try_preempt needs preempt cfg").cfg;
        // O(jobs) candidate scan — acceptable because the guards above
        // make this the cold path (memory-blocked probes with no
        // checkpoint in flight on the node).
        let mut victims: Vec<VictimView> = Vec::new();
        for v in 0..self.rt.len() {
            let rt = &self.rt[v];
            if v == blocked || rt.done || rt.node != node || rt.phase != JPhase::Normal {
                continue;
            }
            if rt.n_preempted >= cfg.max_preemptions {
                continue; // preemption budget spent: no cascades
            }
            let Some((dev, handle)) = rt.inflight else {
                continue; // only kernel-running jobs are checkpointable
            };
            // Bytes the eviction would hand back, per device.
            let mut freed = vec![0u64; self.nodes[node].devices.len()];
            for (d, bytes) in rt.ledger.reserved_entries() {
                freed[d] += bytes;
            }
            let held_bytes: u64 = freed.iter().sum();
            let free_after_best = self.nodes[node]
                .devices
                .iter()
                .zip(&freed)
                .map(|(dv, &f)| dv.free_mem + f)
                .max()
                .unwrap_or(0);
            if free_after_best < req.mem_bytes {
                continue; // evicting this job still would not fit the task
            }
            let d = &self.nodes[node].devices[dev];
            let remaining_s = d.remaining_at(t, handle).unwrap_or(0.0);
            let eta_s = d.eta_at(t, handle).unwrap_or(0.0);
            let est_ckpt_s = cfg.ckpt_seconds(held_bytes);
            if eta_s <= est_ckpt_s {
                // Completes before its own checkpoint image would be
                // written: evicting can only lose to waiting. Enforced
                // here so the invariant holds for *every* policy — the
                // built-ins keep their own (unit-tested) guard, but a
                // policy that forgets it must not regress the engine.
                continue;
            }
            victims.push(VictimView {
                job: v,
                dev,
                held_bytes,
                free_after_best,
                progress_s: (rt.kernel_work_s - remaining_s).max(0.0),
                remaining_s,
                eta_s,
                est_ckpt_s,
                times_preempted: rt.n_preempted,
                slo: self.jobs[v].slo,
            });
        }
        if victims.is_empty() {
            return;
        }
        let p = self.preempt.as_mut().expect("preempt cfg");
        let Some(i) = p.policy.select_victim(req, &victims) else {
            return;
        };
        let victim = victims[i].job;
        // Mark immediately so a second blocked probe in the same cascade
        // cannot select the same victim twice.
        self.rt[victim].phase = JPhase::Checkpointing;
        self.ckpt_inflight[node] += 1;
        self.evq.push(t, EvKind::CkptBegin { job: victim });
    }

    /// Checkpoint start: kill the victim's in-flight kernel (its partial
    /// progress is the wasted work) and schedule `CkptDone` after the
    /// image-copy cost. Aborts if the kernel completed in this same
    /// instant (its `DevCompletion` carried an earlier sequence number).
    fn handle_ckpt_begin(&mut self, victim: usize, t: f64) {
        if self.rt[victim].done || self.rt[victim].phase != JPhase::Checkpointing {
            return;
        }
        let Some((dev, handle)) = self.rt[victim].inflight else {
            // "Checkpointing exactly when it would complete": the kernel
            // finished first, so there is nothing to evict. Cancel, and
            // re-step the victim — its completion step was swallowed by
            // the Checkpointing quiesce.
            self.rt[victim].phase = JPhase::Normal;
            self.ckpt_inflight[self.rt[victim].node] -= 1;
            self.step_job(victim, t);
            return;
        };
        let node = self.rt[victim].node;
        let lost = {
            let d = &mut self.nodes[node].devices[dev];
            d.advance_to(t);
            let rem = d.remaining(handle).unwrap_or(0.0);
            d.remove_kernel(t, handle);
            (self.rt[victim].kernel_work_s - rem).max(0.0)
        };
        let _ = self.take_kernel_owner(node, dev, handle);
        self.resched_dev(node, dev, t);
        let held: u64 = self.rt[victim].ledger.reserved_bytes_total();
        let rt = &mut self.rt[victim];
        rt.inflight = None;
        rt.wasted_s += lost;
        rt.n_preempted += 1;
        let p = self.preempt.as_mut().expect("ckpt in preempt mode");
        p.preemptions += 1;
        let ckpt_s = p.cfg.ckpt_seconds(held);
        p.overhead_s += ckpt_s;
        self.evq.push(t + ckpt_s, EvKind::CkptDone { job: victim });
    }

    /// Checkpoint image written: release every reservation the victim
    /// holds (saving enough to re-place it), hand the freed memory to
    /// the node's waiters, and re-queue the victim for a worker.
    fn handle_ckpt_done(&mut self, victim: usize, t: f64) {
        if self.rt[victim].done || self.rt[victim].phase != JPhase::Checkpointing {
            return; // force-failed while the image was being written
        }
        let node = self.rt[victim].node;
        let open = self.rt[victim].ledger.open_tasks();
        let mut saved = Vec::with_capacity(open.len());
        for task in open {
            if let Some(req) = self.rt[victim].task_req[task].take() {
                saved.push((task, req));
            }
            let nd = &mut self.nodes[node];
            self.rt[victim].ledger.release_task(&mut nd.devices, task);
            nd.release_policy((victim, task));
            self.rt[victim].task_dev[task] = NO_DEV;
        }
        let rt = &mut self.rt[victim];
        rt.saved = saved;
        rt.phase = JPhase::Preempted;
        // Capture the worker slot now: a same-instant pickup can assign
        // the victim a different worker before the Restart fires. The
        // victim relinquishes it here — the Restart event owns the
        // recycle from this point on.
        let worker = rt.worker;
        rt.holds_worker = false;
        self.ckpt_inflight[node] -= 1;
        // Waiters first (their Wake events carry earlier sequence
        // numbers than the landing/Restart below), so the job the
        // eviction was for re-places before the victim can reclaim its
        // memory.
        self.wake_waiters(node, t);
        let migrate = self.preempt.as_ref().is_some_and(|p| p.cfg.migrate_on());
        if migrate {
            // Cluster-wide restore: the saved reservation set re-enters
            // the cluster frontend as a first-class restore job instead
            // of re-queuing where the contention that evicted it lives.
            self.begin_migration(victim, t);
        } else {
            self.nodes[node].job_q.push_back(victim);
        }
        self.evq.push(t, EvKind::Restart { job: victim, node, worker });
    }

    /// Send a checkpointed victim back through the cluster frontend
    /// (`migrate: "cluster"` only). Its estimated load is taken off the
    /// home node — the re-dispatch re-charges wherever it routes — and
    /// the restore job then travels exactly like an arriving job: with
    /// the latency model off it is routed now and lands after only the
    /// image transfer; with the model on it queues for a frontend slot,
    /// is routed at `ProbeSent` by the active dispatcher on a live
    /// snapshot (re-probe guard included), and pays the probe RTT +
    /// dispatch cost before the transfer. Either way the landing is a
    /// `MigrateArrive`, and restore re-placement on the landed node
    /// still goes through `try_restore` — the reservation contract
    /// travels with the job.
    fn begin_migration(&mut self, victim: usize, t: f64) {
        let home = self.rt[victim].node;
        self.outstanding_us[home] =
            self.outstanding_us[home].saturating_sub(self.rt[victim].est_work_us);
        self.outstanding_mem[home] =
            self.outstanding_mem[home].saturating_sub(self.rt[victim].est_mem_bytes);
        self.outstanding_iv[home] =
            self.outstanding_iv[home].sub_clamped(&self.rt[victim].est_iv);
        let rt = &mut self.rt[victim];
        rt.dispatched = false;
        rt.arrived = false;
        // A deferred arrival re-probe that fired after landing leaves
        // its claimed-slot flag set (and possibly a stale ReProbe event
        // still queued); the restore journey is a fresh RPC that must
        // queue at the frontend like one, and the stale event must fire
        // as a no-op — disarm both.
        rt.reprobe_served = false;
        rt.reprobe_armed = false;
        rt.migrating_from = Some(home);
        if self.latency_off {
            self.dispatch_job(victim, t);
            let xfer = self.migrate_xfer_s(victim);
            self.push_landing(victim, t + xfer);
        } else {
            let t_send = self.admit_frontend(t);
            self.evq.push(t_send, EvKind::ProbeSent { job: victim });
        }
    }

    /// A migrating restore lands on its routed node: count the
    /// migration (and the shipped image bytes) when the node is not the
    /// victim's home, then join the worker queue like any landing job —
    /// the next pickup routes into `try_restore` on this node.
    fn handle_migrate_arrive(&mut self, job: usize, t: f64) {
        if self.rt[job].done {
            // Force-failed while the restore was in flight; the ledger
            // was already drained by finish_job.
            self.rt[job].migrating_from = None;
            return;
        }
        let home = self.rt[job].migrating_from.take().expect("migration in flight");
        if self.rt[job].node != home {
            let bytes = self.saved_bytes(job);
            let p = self.preempt.as_mut().expect("migration in preempt mode");
            p.migrations += 1;
            p.migrate_bytes += bytes;
        }
        self.rt[job].arrived = true;
        self.land_job(job, t);
    }

    /// Re-place a checkpointed job's saved reservations all-or-nothing,
    /// then sleep out the restore cost before resuming from the killed
    /// kernel. On failure the job waits for the next release — it never
    /// preempts anybody itself (the other half of the no-cascade rule).
    fn try_restore(&mut self, job: usize, t: f64) {
        let node = self.rt[job].node;
        let saved = std::mem::take(&mut self.rt[job].saved);
        let mut placed: Vec<(usize, usize, u64)> = Vec::new(); // (task, dev, bytes)
        let mut all_fit = true;
        for &(task, req) in &saved {
            match self.nodes[node].place((job, task), &req) {
                Some(dev) => placed.push((task, dev, req.mem_bytes)),
                None => {
                    all_fit = false;
                    break;
                }
            }
        }
        if !all_fit {
            // Roll back this attempt so a half-restored job cannot
            // deadlock another; retry after the next release here.
            for &(task, dev, bytes) in &placed {
                self.nodes[node].devices[dev].release(bytes);
                self.nodes[node].release_policy((job, task));
            }
            self.rt[job].saved = saved;
            self.nodes[node].push_waiter(job);
            // try_restore only runs in preempt mode: the new waiter
            // must see fine-grained launches (see probe_place).
            self.decompile_node_macros(node, t);
            return;
        }
        let mut held = 0u64;
        let rt = &mut self.rt[job];
        for &(task, dev, bytes) in &placed {
            rt.ledger.reserve(task, dev, bytes);
            rt.task_dev[task] = dev as u32;
            held += bytes;
        }
        for &(task, req) in &saved {
            rt.task_req[task] = Some(req);
        }
        rt.phase = JPhase::Restoring;
        let p = self.preempt.as_mut().expect("restore in preempt mode");
        let restore_s = p.cfg.ckpt_seconds(held);
        p.overhead_s += restore_s;
        self.evq.push(t + restore_s, EvKind::Wake { job });
    }

    /// Kernel completions on `(node, dev)` at time `t`.
    fn handle_completions(&mut self, node: usize, dev: usize, t: f64) {
        let mut finished = Vec::new();
        {
            let d = &mut self.nodes[node].devices[dev];
            d.advance_to(t);
            // Collect all kernels that are done (remaining ~ 0).
            while let Some((tf, h)) = d.next_completion(t) {
                if tf - t > 1e-9 {
                    break;
                }
                d.remove_kernel(t, h);
                finished.push(h);
            }
        }
        for h in finished {
            let job = self.take_kernel_owner(node, dev, h).expect("owned kernel");
            let rt = &mut self.rt[job];
            rt.act_s += t - rt.kernel_started;
            rt.ded_s += rt.kernel_ded;
            rt.n_kernels += 1;
            rt.inflight = None;
            rt.pc += 1; // past the Launch event
            self.step_job(job, t);
        }
        self.resched_dev(node, dev, t);
    }

    /// Detach and return the owner of kernel `h` on `(node, dev)`, if
    /// the kernel is still owned (a checkpoint may race a same-instant
    /// completion; whichever fires first takes the entry).
    fn take_kernel_owner(&mut self, node: usize, dev: usize, h: usize) -> Option<usize> {
        let fi = self.gens.flat(node, dev);
        let slab = &mut self.kernel_owner[fi];
        let i = slab.iter().position(|&(hh, _)| hh == h)?;
        Some(slab.swap_remove(i).1 as usize)
    }

    /// Invalidate the device's pending completion event and push a fresh
    /// one for the (new) earliest finisher.
    fn resched_dev(&mut self, node: usize, dev: usize, t: f64) {
        let gen = self.gens.bump(node, dev);
        if let Some((tf, _)) = self.nodes[node].devices[dev].next_completion(t) {
            self.evq.push(tf.max(t), EvKind::DevCompletion { node, dev, gen });
        }
    }

    /// Try to macro-step the job from its current pc (`--compile-traces
    /// on` only): if the compiled program has a steady-state segment
    /// starting here and the runtime conditions hold — task placed,
    /// memory ops covered by a live reservation, target device idle and
    /// not already macro-occupied, and (under preemption) no waiters on
    /// the node whose per-launch wakes a macro would skip — dry-run the
    /// segment on a scratch clone of the device and rest the whole run
    /// as ONE pending `MacroSegment` event. Returns whether a segment
    /// was entered (the caller must stop stepping).
    ///
    /// The dry run drives the clone through the *exact* call sequence
    /// the fine-grained loop would make (`advance_to` /
    /// `start_kernel_with` / `next_completion` / `remove_kernel`), so
    /// the recorded intervals — and the replay of the same calls on the
    /// real device at decompile time — are bit-identical to fine-
    /// grained stepping by construction, including the device model's
    /// self-interference knee that a closed-form `work/speed` sum would
    /// get wrong.
    fn try_enter_macro(&mut self, job: usize, t: f64) -> bool {
        let pc = self.rt[job].pc;
        let prog = self.programs[job].clone();
        let Some(seg) = prog.segment_starting_at(pc) else {
            return false;
        };
        let node = self.rt[job].node;
        let task = seg.task;
        let dev = match self.rt[job].task_dev.get(task) {
            Some(&d) if d != NO_DEV => d as usize,
            _ => return false,
        };
        // Malloc/Free replay as zero-time pc steps only under a live
        // probe reservation; raw allocations touch device free_mem and
        // can OOM-crash — fine-grained territory.
        if seg.has_memops && !self.rt[job].ledger.has_reservation(task) {
            return false;
        }
        let fi = self.gens.flat(node, dev);
        if self.macro_on_dev[fi].is_some() || self.nodes[node].devices[dev].n_kernels() != 0 {
            // Shared device: processor-sharing rates depend on the
            // co-resident membership at every completion — step it
            // fine-grained.
            return false;
        }
        if self.preempt.is_some() && self.nodes[node].has_waiters() {
            // Fine-grained launches wake this node's waiters (eviction
            // opportunities, §try_preempt); a macro would skip those
            // instants.
            return false;
        }
        let iv = self.rt[job].task_iv[task];
        let mut scratch = self.nodes[node].devices[dev].clone();
        let mut items: Vec<MacroItem> = Vec::with_capacity(seg.len());
        let mut cursor = t;
        for pc2 in seg.start..seg.end {
            match self.compact[job][pc2] {
                CEv::Launch { grid, block, work_us, .. } => {
                    let warps = grid * block.div_ceil(32);
                    let work_s = work_us as f64 * 1e-6;
                    scratch.advance_to(cursor);
                    let h = scratch.start_kernel_with(cursor, work_s, warps, iv);
                    let ded = work_s / scratch.spec.speed;
                    let Some((tf, _)) = scratch.next_completion(cursor) else {
                        return false; // unreachable: the kernel is resident
                    };
                    let end = tf.max(cursor);
                    scratch.advance_to(end);
                    scratch.remove_kernel(end, h);
                    items.push(MacroItem {
                        pc: pc2,
                        start: cursor,
                        end,
                        kind: MacroItemKind::Kernel { work_s, warps, ded },
                    });
                    cursor = end;
                }
                CEv::Xfer { bytes } => {
                    let end = cursor + bytes as f64 / PCIE_BYTES_PER_SEC;
                    items.push(MacroItem {
                        pc: pc2,
                        start: cursor,
                        end,
                        kind: MacroItemKind::Sleep,
                    });
                    cursor = end;
                }
                CEv::Host { micros } => {
                    let end = cursor + micros as f64 * 1e-6;
                    items.push(MacroItem {
                        pc: pc2,
                        start: cursor,
                        end,
                        kind: MacroItemKind::Sleep,
                    });
                    cursor = end;
                }
                CEv::Malloc { .. } | CEv::Free { .. } | CEv::Nop => {
                    items.push(MacroItem {
                        pc: pc2,
                        start: cursor,
                        end: cursor,
                        kind: MacroItemKind::Skip,
                    });
                }
                // compile_trace never puts TaskBegin/TaskEnd/etc inside
                // a segment; refuse rather than trust it.
                _ => return false,
            }
        }
        let gen = self.rt[job].macro_gen;
        self.evq.push(cursor, EvKind::MacroSegment { job, gen });
        self.macro_on_dev[fi] = Some(job);
        self.rt[job].macro_rt = Some(MacroRt { node, dev, end_pc: seg.end, iv, items });
        true
    }

    /// A macro segment ran to its end undisturbed: replay it in full
    /// and resume fine-grained stepping. Stale firings (an early
    /// side-exit already decompiled the segment and bumped the
    /// generation) are no-ops, like stale `DevCompletion`s.
    fn handle_macro_segment(&mut self, job: usize, gen: u32, t: f64) {
        if self.rt[job].done || gen != self.rt[job].macro_gen {
            return;
        }
        debug_assert!(self.rt[job].macro_rt.is_some(), "live gen implies a live segment");
        self.decompile_macro(job, t);
    }

    /// Replay the job's macro segment onto the real device up to `t`,
    /// reconstructing exactly the state fine-grained stepping would
    /// have at this instant, then drop back to fine-grained. The dry
    /// run made these same device calls on a clone starting from the
    /// same state, so every float and kernel handle matches:
    ///
    /// * items ending at or before `t` replay as launch + advance +
    ///   remove, crediting the same `act_s`/`ded_s`/`n_kernels` deltas
    ///   the fine-grained completion arm would have;
    /// * a kernel in flight at `t` replays its launch, re-registers
    ///   with the kernel-owner slab, and re-enters the normal
    ///   `DevCompletion` machinery (pc resting on its Launch event);
    /// * a pending sleep re-arms its `Wake` with pc past the event —
    ///   exactly the fine-grained Xfer/Host arm;
    /// * with everything replayed (`t` is the segment's own
    ///   `MacroSegment` instant), pc jumps to the segment end and the
    ///   job steps on inline, matching the fine-grained continuation.
    fn decompile_macro(&mut self, job: usize, t: f64) {
        let Some(m) = self.rt[job].macro_rt.take() else {
            return;
        };
        // Orphan the pending MacroSegment event.
        self.rt[job].macro_gen = self.rt[job].macro_gen.wrapping_add(1);
        let MacroRt { node, dev, end_pc, iv, items } = m;
        let fi = self.gens.flat(node, dev);
        self.macro_on_dev[fi] = None;
        for item in &items {
            match item.kind {
                MacroItemKind::Kernel { work_s, warps, ded } => {
                    let d = &mut self.nodes[node].devices[dev];
                    d.advance_to(item.start);
                    let h = d.start_kernel_with(item.start, work_s, warps, iv);
                    if item.end <= t {
                        d.advance_to(item.end);
                        d.remove_kernel(item.end, h);
                        let rt = &mut self.rt[job];
                        rt.act_s += item.end - item.start;
                        rt.ded_s += ded;
                        rt.n_kernels += 1;
                    } else {
                        self.kernel_owner[fi].push((h, job as u32));
                        let rt = &mut self.rt[job];
                        rt.kernel_started = item.start;
                        rt.kernel_ded = ded;
                        rt.kernel_work_s = work_s;
                        rt.inflight = Some((dev, h));
                        rt.pc = item.pc;
                        let gen = self.gens.bump(node, dev);
                        self.evq.push(item.end, EvKind::DevCompletion { node, dev, gen });
                        return;
                    }
                }
                MacroItemKind::Sleep => {
                    if item.end > t {
                        self.rt[job].pc = item.pc + 1;
                        self.evq.push(item.end, EvKind::Wake { job });
                        return;
                    }
                }
                MacroItemKind::Skip => {}
            }
        }
        self.rt[job].pc = end_pc;
        self.step_job(job, t);
    }

    /// Decompile every macro segment on `node` — the waiter-creation
    /// side-exit under preemption: the victim scan needs the in-flight
    /// kernels resident (a macro-stepping job has `inflight: None` and
    /// would be invisibly unpreemptable), and every later launch must
    /// wake the new waiter fine-grained.
    fn decompile_node_macros(&mut self, node: usize, t: f64) {
        for dev in 0..self.nodes[node].devices.len() {
            if let Some(occ) = self.macro_on_dev[self.gens.flat(node, dev)] {
                self.decompile_macro(occ, t);
            }
        }
    }

    fn finish_job(&mut self, job: usize, t: f64, crashed: bool) {
        {
            let rt = &mut self.rt[job];
            if rt.done {
                return;
            }
            rt.done = true;
            rt.crashed = crashed;
            rt.ended = t;
        }
        // Defensive: a macro-stepping job cannot normally reach here
        // (its pending MacroSegment keeps the queue non-empty and
        // step_job refuses it), but if it ever does, drop the segment
        // without replay — its kernels were never resident — and free
        // the device slot.
        if let Some(m) = self.rt[job].macro_rt.take() {
            self.rt[job].macro_gen = self.rt[job].macro_gen.wrapping_add(1);
            self.macro_on_dev[self.gens.flat(m.node, m.dev)] = None;
        }
        if self.rt[job].phase == JPhase::Checkpointing {
            // Force-failed mid-checkpoint (drain fallback): the pending
            // CkptDone will see `done` and bail, so release the per-node
            // in-flight slot here.
            self.ckpt_inflight[self.rt[job].node] -= 1;
        }
        // Release everything the job still holds.
        for task in self.rt[job].ledger.open_tasks() {
            self.release_task(job, task, t);
        }
        let node = self.rt[job].node;
        self.wake_waiters(node, t);
        if self.rt[job].dispatched {
            // Un-routed jobs (latency mode: probe chain still in
            // flight) were never charged to a node's outstanding load.
            self.outstanding_us[node] =
                self.outstanding_us[node].saturating_sub(self.rt[job].est_work_us);
            self.outstanding_mem[node] =
                self.outstanding_mem[node].saturating_sub(self.rt[job].est_mem_bytes);
            self.outstanding_iv[node] =
                self.outstanding_iv[node].sub_clamped(&self.rt[job].est_iv);
        }
        let worker = self.rt[job].worker;
        // Only hand back a worker the job actually occupies: a
        // checkpointed (possibly migrating) victim force-failed before
        // its next pickup relinquished its slot to the Restart event,
        // and its stale index may even belong to another node's pool —
        // recycling it here would double-assign a worker another job
        // holds.
        if self.rt[job].holds_worker {
            self.start_next_job(node, worker, t);
        }
    }

    fn collect(&mut self) -> RunResult {
        let jobs: Vec<JobOutcome> = self
            .jobs
            .iter()
            .zip(&self.rt)
            .map(|(spec, rt)| JobOutcome {
                name: spec.name.clone(),
                class: spec.class,
                slo: spec.slo,
                arrival: spec.arrival,
                node: rt.node,
                started: rt.started,
                ended: rt.ended,
                crashed: rt.crashed,
                rejected: rt.rejected,
                kernel_dedicated_s: rt.ded_s,
                kernel_actual_s: rt.act_s,
                n_kernels: rt.n_kernels,
                preemptions: rt.n_preempted,
                wasted_s: rt.wasted_s,
            })
            .collect();
        let makespan = jobs.iter().map(|j| j.ended).fold(0.0, f64::max);
        let scheduler = match &self.mode {
            SchedMode::Sa => "sa".to_string(),
            SchedMode::Cg => "cg".to_string(),
            SchedMode::Static => "static".to_string(),
            SchedMode::Policy(p) => p.to_string(),
        };
        RunResult {
            scheduler,
            node: self.cluster_name.clone(),
            workers: self.nodes.iter().map(|n| n.n_workers()).sum(),
            n_nodes: self.nodes.len(),
            dispatcher: self.dispatcher.name().to_string(),
            jobs,
            makespan,
            preemptions: self.preempt.as_ref().map_or(0, |p| p.preemptions),
            wasted_work_s: self.rt.iter().map(|r| r.wasted_s).sum(),
            ckpt_overhead_s: self.preempt.as_ref().map_or(0.0, |p| p.overhead_s),
            migrations: self.preempt.as_ref().map_or(0, |p| p.migrations),
            migrate_bytes: self.preempt.as_ref().map_or(0, |p| p.migrate_bytes),
            rejected: self.rt.iter().filter(|r| r.rejected).count() as u64,
            degraded: self.admit.as_ref().map_or(0, |a| a.degraded),
            events_fired: self.evq.events_fired(),
            peak_events: self.evq.peak_len(),
            observable_events: self.observable_events,
        }
    }
}

//! The batch coordinator engine: a discrete-event simulation of the
//! paper's deployment — a queue of jobs, a worker pool, the probe
//! protocol, a scheduling policy, and one or more multi-GPU nodes.
//!
//! The engine is the thin stepping layer over three modules:
//!
//! * `events` — the virtual clock, the event heap, and per-device
//!   generation counters (nothing job- or memory-aware);
//! * `placement` — per-node devices, probe reservations, raw
//!   allocations, wait queues, and worker idleness;
//! * `sched::dispatch` — the cluster layer routing each arriving job
//!   to a node; per-node [`Policy`](crate::sched::Policy) instances
//!   place tasks beneath it.
//!
//! Jobs are [`JobTrace`]s (produced by the compiler + lazy runtime).
//! A pool of workers per node drains that node's queue (§V-A: "each
//! worker dequeues a job, runs it, and then pulls another"); the worker
//! count and its device pinning encode the baseline schedulers:
//!
//! * **SA** — one worker per GPU, pinned: each job gets a dedicated
//!   device for its lifetime (Slurm-style, memory-safe, underutilised).
//! * **CG** — N workers pinned round-robin across GPUs (the CG ratio =
//!   workers / GPUs): MPS-style packing with *no* knowledge of memory
//!   needs, so `cudaMalloc` can OOM and crash the job.
//! * **MGB / schedGPU** — unpinned workers; every `TaskBegin` probe asks
//!   the policy for a device, reserving the task's memory up front
//!   (memory-safe by construction); tasks wait when nothing fits.
//!
//! Virtual time is f64 seconds. Kernel execution uses the device model's
//! processor sharing; completions are tracked with one pending event per
//! device plus a generation counter (membership changes invalidate the
//! stale event). A single-node cluster reproduces the paper's setup
//! bit-for-bit; `run_cluster` scales the same engine to N nodes.

use super::events::{DevGens, EvKind, EventQueue};
use super::metrics::{JobClass, JobOutcome, RunResult};
use super::placement::{NodePlacement, TaskLedger};
use crate::gpu::{ClusterSpec, NodeSpec, PCIE_BYTES_PER_SEC};
use crate::lazy::{JobTrace, TraceEvent};
use crate::sched::{make_dispatcher, Dispatcher, JobInfo, NodeLoadView, TaskReq};
use std::collections::HashMap;

/// Scheduler selection for a batch run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Single-assignment: workers == GPUs, worker i pinned to device i.
    Sa,
    /// Core-to-GPU with `workers` total workers pinned round-robin.
    Cg,
    /// Task-granular policy by name: "mgb3" (default MGB), "mgb2",
    /// "schedgpu".
    Policy(&'static str),
    /// Honour the application's own cudaSetDevice bindings (device 0
    /// when it never called it — the CUDA default, §II-B). No memory
    /// management at all: the unmanaged-sharing baseline.
    Static,
}

/// Single-node batch-run configuration (the paper's deployments).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub node: NodeSpec,
    pub mode: SchedMode,
    /// Worker-pool size (ignored for SA, which always uses one per GPU).
    pub workers: usize,
}

/// Multi-node batch-run configuration: the same per-node machinery,
/// replicated across a [`ClusterSpec`], with a dispatcher on top.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub cluster: ClusterSpec,
    pub mode: SchedMode,
    /// Worker-pool size per node (ignored for SA: one per GPU).
    pub workers_per_node: usize,
    /// Dispatcher name: "rr" | "least" | "mem" (see `sched::dispatch`).
    pub dispatch: &'static str,
}

/// One job of the batch.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub class: JobClass,
    pub trace: JobTrace,
    /// Queue-arrival time. The paper's batch experiments queue all jobs
    /// at t = 0 (§V-A); open-system experiments (Poisson arrivals via
    /// `workloads::poisson_arrivals`) stagger it.
    pub arrival: f64,
}

/// Called on every kernel launch that names a PJRT artifact — the
/// `--compute real` hook (validates numerics; virtual time is modeled).
pub type LaunchHook<'a> = &'a mut dyn FnMut(&str);

/// Compact, `Copy` trace event for the hot loop: artifact names are
/// interned at batch start so stepping a job never clones a String.
/// (EXPERIMENTS.md §Perf: the naive `TraceEvent::clone()` per step cost
/// two heap allocations per kernel launch.)
#[derive(Clone, Copy, Debug)]
enum CEv {
    TaskBegin { task: usize, res: crate::lazy::TaskResources },
    Malloc { task: usize, bytes: u64 },
    Xfer { bytes: u64 },
    Launch { task: usize, artifact: u32, grid: u64, block: u64, work_us: u64 },
    Free { task: usize, bytes: u64 },
    TaskEnd { task: usize },
    Host { micros: u64 },
    Nop,
}

const NO_ARTIFACT: u32 = u32::MAX;

/// Compact one trace, interning artifact names through a hash map (a
/// linear rescan of `names` per launch was O(n²) across a batch).
fn compact_trace(
    trace: &JobTrace,
    names: &mut Vec<String>,
    intern: &mut HashMap<String, u32>,
) -> Vec<CEv> {
    trace
        .events
        .iter()
        .map(|e| match e {
            TraceEvent::TaskBegin { task, res } => CEv::TaskBegin { task: *task, res: *res },
            TraceEvent::Malloc { task, bytes } => CEv::Malloc { task: *task, bytes: *bytes },
            TraceEvent::H2D { bytes, .. } | TraceEvent::D2H { bytes, .. } => {
                CEv::Xfer { bytes: *bytes }
            }
            TraceEvent::Memset { .. } => CEv::Nop,
            TraceEvent::Launch { task, artifact, grid, block, work_us, .. } => {
                let a = match artifact {
                    None => NO_ARTIFACT,
                    Some(name) => match intern.get(name) {
                        Some(&i) => i,
                        None => {
                            let i = names.len() as u32;
                            names.push(name.clone());
                            intern.insert(name.clone(), i);
                            i
                        }
                    },
                };
                CEv::Launch { task: *task, artifact: a, grid: *grid, block: *block, work_us: *work_us }
            }
            TraceEvent::Free { task, bytes } => CEv::Free { task: *task, bytes: *bytes },
            TraceEvent::TaskEnd { task } => CEv::TaskEnd { task: *task },
            TraceEvent::Host { micros } => CEv::Host { micros: *micros },
        })
        .collect()
}

#[derive(Debug, Default)]
struct JobRt {
    pc: usize,
    /// Cluster node the dispatcher routed this job to.
    node: usize,
    /// runtime task id -> device (on the job's node).
    task_dev: HashMap<usize, usize>,
    /// Memory held per open task (reservations + raw allocations).
    ledger: TaskLedger,
    pinned_dev: Option<usize>,
    worker: usize,
    started: f64,
    ended: f64,
    crashed: bool,
    done: bool,
    /// Dispatch-time load estimates (kernel + host us, peak bytes).
    est_work_us: u64,
    est_mem_bytes: u64,
    ded_s: f64,
    act_s: f64,
    n_kernels: u64,
    kernel_started: f64,
    kernel_ded: f64,
}

struct Engine<'h> {
    mode: SchedMode,
    cluster_name: String,
    jobs: Vec<JobSpec>,
    /// Compacted traces (one per job) + interned artifact names.
    compact: Vec<Vec<CEv>>,
    artifact_names: Vec<String>,
    rt: Vec<JobRt>,
    nodes: Vec<NodePlacement>,
    gens: DevGens,
    /// (node, device, kernel handle) -> job.
    kernel_owner: HashMap<(usize, usize, usize), usize>,
    evq: EventQueue,
    dispatcher: Box<dyn Dispatcher>,
    /// Per-node dispatched-but-unfinished load (dispatcher bookkeeping).
    outstanding_us: Vec<u64>,
    outstanding_mem: Vec<u64>,
    hook: Option<LaunchHook<'h>>,
}

/// Run a batch of jobs under `cfg`; all jobs are queued at t = 0.
pub fn run_batch(cfg: RunConfig, jobs: Vec<JobSpec>) -> RunResult {
    run_batch_with_hook(cfg, jobs, None)
}

/// `run_batch` plus a real-compute hook invoked per artifact launch.
pub fn run_batch_with_hook(
    cfg: RunConfig,
    jobs: Vec<JobSpec>,
    hook: Option<LaunchHook<'_>>,
) -> RunResult {
    let cluster_cfg = ClusterConfig {
        cluster: ClusterSpec::single(cfg.node),
        mode: cfg.mode,
        workers_per_node: cfg.workers,
        dispatch: "rr",
    };
    run_cluster_with_hook(cluster_cfg, jobs, hook)
}

/// Run a batch across a multi-node cluster: the dispatcher routes each
/// job to a node at arrival; per-node policies place its tasks. With a
/// single-node cluster this is exactly `run_batch`.
pub fn run_cluster(cfg: ClusterConfig, jobs: Vec<JobSpec>) -> RunResult {
    run_cluster_with_hook(cfg, jobs, None)
}

/// `run_cluster` plus a real-compute hook invoked per artifact launch.
pub fn run_cluster_with_hook(
    cfg: ClusterConfig,
    jobs: Vec<JobSpec>,
    hook: Option<LaunchHook<'_>>,
) -> RunResult {
    let nodes: Vec<NodePlacement> = cfg
        .cluster
        .nodes
        .iter()
        .map(|n| NodePlacement::new(n, &cfg.mode, cfg.workers_per_node))
        .collect();
    let devs_per_node: Vec<usize> = nodes.iter().map(|n| n.devices.len()).collect();
    let mut artifact_names = Vec::new();
    let mut intern: HashMap<String, u32> = HashMap::new();
    let compact: Vec<Vec<CEv>> = jobs
        .iter()
        .map(|j| compact_trace(&j.trace, &mut artifact_names, &mut intern))
        .collect();
    let rt: Vec<JobRt> = jobs
        .iter()
        .map(|j| JobRt {
            est_work_us: j.trace.total_work_us() + j.trace.total_host_us(),
            est_mem_bytes: j.trace.peak_reserved_bytes(),
            ..JobRt::default()
        })
        .collect();
    let n_nodes = nodes.len();
    let mut eng = Engine {
        mode: cfg.mode,
        cluster_name: cfg.cluster.name.clone(),
        compact,
        artifact_names,
        rt,
        gens: DevGens::new(&devs_per_node),
        kernel_owner: HashMap::new(),
        evq: EventQueue::new(),
        dispatcher: make_dispatcher(cfg.dispatch),
        outstanding_us: vec![0; n_nodes],
        outstanding_mem: vec![0; n_nodes],
        nodes,
        jobs,
        hook,
    };
    eng.run()
}

impl<'h> Engine<'h> {
    /// Route `job` to a node (cluster layer) and record its estimated
    /// load against that node. Returns the node index.
    fn dispatch_job(&mut self, job: usize) -> usize {
        let views: Vec<NodeLoadView> = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, nd)| NodeLoadView {
                queued_jobs: nd.job_q.len(),
                outstanding_work_us: self.outstanding_us[i],
                outstanding_mem_bytes: self.outstanding_mem[i],
                free_mem: nd.free_mem(),
                total_mem: nd.total_mem(),
                n_gpus: nd.devices.len(),
            })
            .collect();
        let info = JobInfo {
            est_work_us: self.rt[job].est_work_us,
            peak_mem_bytes: self.rt[job].est_mem_bytes,
        };
        let node = self.dispatcher.route(&info, &views);
        debug_assert!(node < self.nodes.len(), "dispatcher routed off-cluster");
        self.rt[job].node = node;
        self.outstanding_us[node] += self.rt[job].est_work_us;
        self.outstanding_mem[node] += self.rt[job].est_mem_bytes;
        node
    }

    fn run(&mut self) -> RunResult {
        for j in 0..self.jobs.len() {
            let arr = self.jobs[j].arrival;
            if arr <= 0.0 {
                let n = self.dispatch_job(j);
                self.nodes[n].job_q.push_back(j);
            } else {
                self.evq.push(arr, EvKind::Arrive { job: j });
            }
        }
        for n in 0..self.nodes.len() {
            for w in 0..self.nodes[n].n_workers() {
                self.start_next_job(n, w, 0.0);
            }
        }
        loop {
            while let Some(ev) = self.evq.pop() {
                match ev.kind {
                    EvKind::Wake { job } => {
                        if !self.rt[job].done {
                            self.step_job(job, ev.t);
                        }
                    }
                    EvKind::DevCompletion { node, dev, gen } => {
                        if gen == self.gens.current(node, dev) {
                            self.handle_completions(node, dev, ev.t);
                        }
                    }
                    EvKind::Arrive { job } => {
                        let n = self.dispatch_job(job);
                        self.nodes[n].job_q.push_back(job);
                        if let Some(w) = self.nodes[n].pop_idle() {
                            self.start_next_job(n, w, ev.t);
                        }
                    }
                }
            }
            // Queue drained but some jobs never finished: their resource
            // requests can never be satisfied on their node (e.g. a task
            // bigger than any GPU). Fail one and keep draining — the
            // real scheduler would reject such a request up front; the
            // failure may unblock (or start) other jobs.
            match (0..self.rt.len()).find(|&j| !self.rt[j].done) {
                Some(j) => self.finish_job(j, self.evq.now(), true),
                None => break,
            }
        }
        self.collect()
    }

    fn start_next_job(&mut self, node: usize, worker: usize, t: f64) {
        let Some(job) = self.nodes[node].job_q.pop_front() else {
            self.nodes[node].mark_idle(worker);
            return;
        };
        let pin = self.nodes[node].worker_pin[worker];
        let rt = &mut self.rt[job];
        rt.worker = worker;
        rt.started = t;
        rt.pinned_dev = pin;
        self.step_job(job, t);
    }

    /// Process the job's trace from its pc until it blocks or finishes.
    fn step_job(&mut self, job: usize, t: f64) {
        loop {
            if self.rt[job].done {
                return;
            }
            if self.rt[job].pc >= self.compact[job].len() {
                self.finish_job(job, t, false);
                return;
            }
            let node = self.rt[job].node;
            let ev = self.compact[job][self.rt[job].pc];
            match ev {
                CEv::Nop => {
                    self.rt[job].pc += 1;
                }
                CEv::TaskBegin { task, res } => {
                    if self.nodes[node].static_mode {
                        // §II-B: the app's cudaSetDevice (or device 0).
                        let dev = (res.static_dev.unwrap_or(0) as usize)
                            .min(self.nodes[node].devices.len() - 1);
                        let rt = &mut self.rt[job];
                        rt.task_dev.insert(task, dev);
                        rt.pc += 1;
                        continue;
                    }
                    if let Some(dev) = self.rt[job].pinned_dev {
                        let rt = &mut self.rt[job];
                        rt.task_dev.insert(task, dev);
                        rt.pc += 1;
                        continue;
                    }
                    let req = TaskReq {
                        mem_bytes: res.reserve_bytes(),
                        tbs: res.thread_blocks(),
                        warps_per_tb: res.warps_per_tb(),
                    };
                    match self.nodes[node].place((job, task), &req) {
                        Some(dev) => {
                            let rt = &mut self.rt[job];
                            rt.ledger.reserved.insert(task, (dev, req.mem_bytes));
                            rt.task_dev.insert(task, dev);
                            rt.pc += 1;
                        }
                        None => {
                            self.nodes[node].push_waiter(job);
                            return;
                        }
                    }
                }
                CEv::Malloc { task, bytes } => {
                    let rt = &mut self.rt[job];
                    if rt.ledger.reserved.contains_key(&task) {
                        rt.pc += 1; // covered by the probe's reservation
                        continue;
                    }
                    let dev = *rt.task_dev.get(&task).expect("task placed");
                    match self.nodes[node].devices[dev].alloc(bytes) {
                        Ok(()) => {
                            let rt = &mut self.rt[job];
                            let e = rt.ledger.alloc.entry(task).or_insert((dev, 0));
                            e.1 += bytes;
                            rt.pc += 1;
                        }
                        Err(_avail) => {
                            // OOM: the CUDA runtime returns an error the
                            // (unmodified) app does not handle — crash.
                            self.finish_job(job, t, true);
                            return;
                        }
                    }
                }
                CEv::Xfer { bytes } => {
                    self.rt[job].pc += 1;
                    let dt = bytes as f64 / PCIE_BYTES_PER_SEC;
                    self.evq.push(t + dt, EvKind::Wake { job });
                    return;
                }
                CEv::Launch { task, artifact, grid, block, work_us } => {
                    let dev = *self.rt[job].task_dev.get(&task).expect("task placed");
                    if artifact != NO_ARTIFACT {
                        if let Some(hook) = self.hook.as_mut() {
                            hook(&self.artifact_names[artifact as usize]);
                        }
                    }
                    let warps = grid * block.div_ceil(32);
                    let work_s = work_us as f64 * 1e-6;
                    let d = &mut self.nodes[node].devices[dev];
                    d.advance_to(t);
                    let h = d.start_kernel(t, work_s, warps);
                    let speed = d.spec.speed;
                    self.kernel_owner.insert((node, dev, h), job);
                    let rt = &mut self.rt[job];
                    rt.kernel_started = t;
                    rt.kernel_ded = work_s / speed;
                    self.resched_dev(node, dev, t);
                    return; // job sleeps until DevCompletion wakes it
                }
                CEv::Free { task, bytes } => {
                    let rt = &mut self.rt[job];
                    if !rt.ledger.reserved.contains_key(&task) {
                        if let Some(e) = rt.ledger.alloc.get_mut(&task) {
                            let dev = e.0;
                            e.1 = e.1.saturating_sub(bytes);
                            self.nodes[node].devices[dev].release(bytes);
                        }
                    }
                    self.rt[job].pc += 1;
                }
                CEv::TaskEnd { task } => {
                    self.release_task(job, task, t);
                    self.rt[job].pc += 1;
                }
                CEv::Host { micros } => {
                    self.rt[job].pc += 1;
                    self.evq.push(t + micros as f64 * 1e-6, EvKind::Wake { job });
                    return;
                }
            }
        }
    }

    /// Release a task's reservation / leftover allocations and let the
    /// node's policy + waiters know capacity freed up.
    fn release_task(&mut self, job: usize, task: usize, t: f64) {
        let node = self.rt[job].node;
        let nd = &mut self.nodes[node];
        let released = self.rt[job].ledger.release_task(&mut nd.devices, task);
        nd.release_policy((job, task));
        if released || nd.has_policy() {
            self.wake_waiters(node, t);
        }
    }

    fn wake_waiters(&mut self, node: usize, t: f64) {
        for j in self.nodes[node].take_waiters() {
            self.evq.push(t, EvKind::Wake { job: j });
        }
    }

    /// Kernel completions on `(node, dev)` at time `t`.
    fn handle_completions(&mut self, node: usize, dev: usize, t: f64) {
        let mut finished = Vec::new();
        {
            let d = &mut self.nodes[node].devices[dev];
            d.advance_to(t);
            // Collect all kernels that are done (remaining ~ 0).
            while let Some((tf, h)) = d.next_completion(t) {
                if tf - t > 1e-9 {
                    break;
                }
                d.remove_kernel(t, h);
                finished.push(h);
            }
        }
        for h in finished {
            let job = self.kernel_owner.remove(&(node, dev, h)).expect("owned kernel");
            let rt = &mut self.rt[job];
            rt.act_s += t - rt.kernel_started;
            rt.ded_s += rt.kernel_ded;
            rt.n_kernels += 1;
            rt.pc += 1; // past the Launch event
            self.step_job(job, t);
        }
        self.resched_dev(node, dev, t);
    }

    /// Invalidate the device's pending completion event and push a fresh
    /// one for the (new) earliest finisher.
    fn resched_dev(&mut self, node: usize, dev: usize, t: f64) {
        let gen = self.gens.bump(node, dev);
        if let Some((tf, _)) = self.nodes[node].devices[dev].next_completion(t) {
            self.evq.push(tf.max(t), EvKind::DevCompletion { node, dev, gen });
        }
    }

    fn finish_job(&mut self, job: usize, t: f64, crashed: bool) {
        {
            let rt = &mut self.rt[job];
            if rt.done {
                return;
            }
            rt.done = true;
            rt.crashed = crashed;
            rt.ended = t;
        }
        // Release everything the job still holds.
        for task in self.rt[job].ledger.open_tasks() {
            self.release_task(job, task, t);
        }
        let node = self.rt[job].node;
        self.wake_waiters(node, t);
        self.outstanding_us[node] =
            self.outstanding_us[node].saturating_sub(self.rt[job].est_work_us);
        self.outstanding_mem[node] =
            self.outstanding_mem[node].saturating_sub(self.rt[job].est_mem_bytes);
        let worker = self.rt[job].worker;
        self.start_next_job(node, worker, t);
    }

    fn collect(&mut self) -> RunResult {
        let jobs: Vec<JobOutcome> = self
            .jobs
            .iter()
            .zip(&self.rt)
            .map(|(spec, rt)| JobOutcome {
                name: spec.name.clone(),
                class: spec.class,
                arrival: spec.arrival,
                node: rt.node,
                started: rt.started,
                ended: rt.ended,
                crashed: rt.crashed,
                kernel_dedicated_s: rt.ded_s,
                kernel_actual_s: rt.act_s,
                n_kernels: rt.n_kernels,
            })
            .collect();
        let makespan = jobs.iter().map(|j| j.ended).fold(0.0, f64::max);
        let scheduler = match &self.mode {
            SchedMode::Sa => "sa".to_string(),
            SchedMode::Cg => "cg".to_string(),
            SchedMode::Static => "static".to_string(),
            SchedMode::Policy(p) => p.to_string(),
        };
        RunResult {
            scheduler,
            node: self.cluster_name.clone(),
            workers: self.nodes.iter().map(|n| n.n_workers()).sum(),
            n_nodes: self.nodes.len(),
            dispatcher: self.dispatcher.name().to_string(),
            jobs,
            makespan,
        }
    }
}

//! Event-core of the coordinator: the virtual clock, the discrete-event
//! queue, and the per-device generation counters.
//!
//! This layer knows nothing about jobs, memory, or policies — it only
//! orders [`EvKind`] values in virtual time (f64 seconds) with FIFO
//! tie-breaking, and tracks one generation counter per (node, device)
//! so a stale completion event (pushed before a membership change on
//! the device) can be recognised and dropped by the engine.
//!
//! The queue itself is an epoch-indexed calendar queue
//! ([`CalendarQueue`]): events hash into time buckets by an integer
//! epoch computed *once* at push, so the hot pop path scans one small
//! bucket instead of paying `BinaryHeap`'s log-depth sift on every
//! operation. The pre-overhaul `BinaryHeap` survives as a selectable
//! reference backend ([`EventQueue::with_heap_backend`]) — the
//! order-equivalence property tests pit the two against each other on
//! identical streams, and `bench scale` reports both so the speedup is
//! measured, not asserted. Both backends realise the *same* total
//! order: earliest `t` first (`f64::total_cmp`), FIFO by `seq` on
//! same-instant ties — which is why committed golden traces are
//! byte-identical under either.
//!
//! Paper map: the discrete-event clock realises the virtual timeline of
//! the §V-A deployments (batch at t=0, Poisson arrivals beyond-paper).
//! The checkpoint/restart kinds ([`EvKind::CkptBegin`] /
//! [`EvKind::CkptDone`] / [`EvKind::Restart`] /
//! [`EvKind::MigrateArrive`]) carry the beyond-paper
//! preemption protocol (ROADMAP "Job preemption"); the probe/dispatch
//! kinds ([`EvKind::ProbeSent`] / [`EvKind::ProbeAck`] /
//! [`EvKind::DispatchArrive`] / [`EvKind::ReProbe`]) carry the
//! beyond-paper frontend latency
//! protocol (ROADMAP "Per-node probe latency model"). None of them is
//! ever pushed unless its feature is enabled, which keeps disabled
//! runs bit-identical — provable via the trace-recorder hook
//! ([`EventQueue::record_trace`]), which serialises every fired event
//! for the golden-trace harness.

use std::collections::BinaryHeap;

/// What happens when an event fires. `node`/`dev` index into the
/// cluster; `job` indexes the batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum EvKind {
    /// Resume stepping a blocked job (transfer/host phase done, or a
    /// waiter retrying placement after a release).
    Wake { job: usize },
    /// The earliest kernel on `(node, dev)` may have finished. Stale if
    /// `gen` no longer matches the device's current generation.
    DevCompletion { node: usize, dev: usize, gen: u64 },
    /// A job enters the system. With the latency model off this is
    /// pushed only for open-system arrivals (t > 0) and the dispatcher
    /// routes the job when it fires; with the model on, *every* job
    /// arrives through the cluster frontend this way and routing is
    /// deferred to its `ProbeSent`.
    Arrive { job: usize },
    /// Checkpoint of preemption victim `job` begins: its in-flight
    /// kernel is killed (partial progress becomes wasted work) and the
    /// image copy starts. Aborts harmlessly if the kernel completed in
    /// the same instant under an earlier sequence number.
    CkptBegin { job: usize },
    /// Victim `job`'s checkpoint image is written: its reservations are
    /// released to the node's waiters, its progress saved, and it
    /// re-queues for a worker.
    CkptDone { job: usize },
    /// Recycle the checkpointed job's worker slot on its *home* node
    /// (both captured at `CkptDone`: a same-instant pickup can
    /// re-assign the job a different worker before this fires, and a
    /// cluster-migrating victim may already have been re-routed off the
    /// node whose worker it held). Fired after `CkptDone`'s waiter
    /// wake-ups so the job the eviction unblocked re-places first.
    Restart { job: usize, node: usize, worker: usize },
    /// A probe RPC reaches its server (latency mode only): the cluster
    /// frontend's routing probe if `job` is not yet dispatched, else
    /// the task probe arriving at the job's node scheduler daemon. The
    /// decision is made *now*, on the load the server sees now — the
    /// stale-snapshot semantics the latency model exists to expose.
    ProbeSent { job: usize },
    /// The probe's reply lands back at the client after the modeled
    /// round-trip: a routed job starts its dispatch hop, a placed task
    /// resumes stepping. Never pushed when the latency model is off.
    ProbeAck { job: usize },
    /// A dispatched job physically arrives at its node (after the
    /// dispatch-cost delay) and joins the node's worker queue. Never
    /// pushed when the latency model is off.
    DispatchArrive { job: usize },
    /// The frontend's staleness timeout for a routed-but-not-landed
    /// job: fired `reprobe_after_s` after a routing decision whose
    /// landing delay exceeds that bound. The frontend re-snapshots the
    /// cluster and may re-route the in-flight job; each firing consumes
    /// one unit of the job's bounded re-probe budget, so routing always
    /// terminates. Never pushed when the latency model is off or
    /// re-probing is disabled (`LatencyModel::reprobe_enabled`).
    ReProbe { job: usize },
    /// A checkpointed preemption victim's *restore job* lands on its
    /// routed node (cluster-wide migration only,
    /// `sched::PreemptConfig::migrate = "cluster"`): the landing
    /// instant already includes the probe RTT + dispatch cost of the
    /// journey plus the checkpoint-image transfer when the node is not
    /// the victim's home. Replaces `DispatchArrive` for migrating
    /// restores so traces distinguish migration landings; never pushed
    /// with migration off, which keeps `--migrate off` byte-identical.
    MigrateArrive { job: usize },
    /// The frontend admission controller turned `job` away at the door
    /// (`sched::AdmissionConfig`, `--admit token|util`): a *terminal*
    /// verdict fired at the arrival instant. The job never consumes
    /// frontend service, never routes, and never holds a worker or a
    /// reservation — it ends rejected (not crashed) with `ended ==
    /// arrival`. Never pushed when admission is off, which keeps
    /// `--admit off` byte-identical to every committed golden.
    AdmitReject { job: usize },
    /// The cluster frontend's single server freed up with a per-class
    /// backlog waiting (`--frontend-q prio|wfq` only): serve the next
    /// queued routing probe by the configured discipline. Never pushed
    /// under the FIFO discipline (or with the latency model off, where
    /// no frontend queue can form), which keeps `--frontend-q fifo`
    /// byte-identical to the PR-3 frontend.
    FrontendServe,
    /// A compiled steady-state segment of `job`'s trace runs to its end
    /// (`--compile-traces on` only): the engine macro-stepped a run of
    /// launches/sleeps as this single event instead of one event per
    /// trace op. Stale — the segment was *decompiled* back to
    /// fine-grained stepping by a side-exit (preemption scan, another
    /// job launching onto the segment's device) — if `gen` no longer
    /// matches the job's macro generation. Never pushed with
    /// compilation off, which keeps `--compile-traces off` runs
    /// byte-identical to every committed golden.
    MacroSegment { job: usize, gen: u32 },
}

impl EvKind {
    /// Whether this event belongs to the *observable* stream: the
    /// protocol-level events a real deployment could watch on the wire
    /// (arrivals, probe/dispatch RPCs, the preemption protocol,
    /// admission verdicts, frontend service). `Wake`, `DevCompletion`
    /// and `MacroSegment` are engine timers — how the simulator chooses
    /// to advance the clock, not something the cluster does. The
    /// compiled-replay equivalence contract is stated over this subset:
    /// `--compile-traces on` must fire the identical observable stream
    /// (same kinds, times, payloads, order) as off, while the timer
    /// events it fires may differ — collapsing them is the whole point.
    pub fn is_observable(&self) -> bool {
        !matches!(
            self,
            EvKind::Wake { .. } | EvKind::DevCompletion { .. } | EvKind::MacroSegment { .. }
        )
    }
}

#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub t: f64,
    pub seq: u64,
    pub kind: EvKind,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Event {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Min-heap via reverse: earliest time, then FIFO by seq.
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

/// `a` pops strictly before `b`: earliest `t` (`total_cmp`), FIFO by
/// `seq` on ties. The one ordering both backends implement.
#[inline]
fn earlier(a: &Event, b: &Event) -> bool {
    match a.t.total_cmp(&b.t) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.seq < b.seq,
    }
}

const MIN_BUCKETS: usize = 16; // power of two; `& mask` replaces `%`
const MIN_WIDTH: f64 = 1e-9;

/// An event plus its bucket epoch, computed once at insertion. Epochs
/// are compared by *integer* equality on the pop path — no float
/// arithmetic can disagree between push and pop about which epoch a
/// slot belongs to, so bucket membership can never reorder events.
#[derive(Clone, Copy, Debug)]
struct Slot {
    ev: Event,
    epoch: u64,
}

/// Bucketed calendar queue (Brown 1988, adapted): epoch `e` covers
/// virtual times `[e*width, (e+1)*width)` and maps to bucket
/// `e & (n_buckets-1)`. Pops scan the current epoch's bucket for the
/// (t, seq)-minimum; empty epochs advance the epoch cursor, and after
/// a fruitless full lap the cursor jumps straight to the global
/// minimum (the queue is sparse far ahead of the clock). Bucket count
/// doubles/halves with occupancy and the width recalibrates to the
/// live span on each rebuild, keeping O(1) amortised push/pop for the
/// engine's near-monotone event streams.
///
/// Correctness is width-independent: `floor(t/width)` is monotone in
/// `t`, every remaining slot's epoch is >= the cursor (pushes clamp to
/// the cursor, so even a push into the past stays visible and pops in
/// exact (t, seq) order), and ties within a bucket resolve by the same
/// `total_cmp`/seq rule as the heap. Width and bucket count only move
/// *performance*.
#[derive(Debug)]
struct CalendarQueue {
    buckets: Vec<Vec<Slot>>,
    /// Epoch width in virtual seconds; recalibrated on rebuild.
    width: f64,
    /// The epoch cursor: no remaining slot has a smaller epoch.
    cur_epoch: u64,
    /// Time of the last popped event; seeds the cursor after rebuilds.
    floor_t: f64,
    len: usize,
}

impl CalendarQueue {
    fn new() -> Self {
        CalendarQueue {
            buckets: vec![Vec::new(); MIN_BUCKETS],
            width: 1.0,
            cur_epoch: 0,
            floor_t: 0.0,
            len: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    /// `floor(t / width)` as an integer epoch. The `as u64` cast
    /// saturates for astronomically late events, which degrades those
    /// to one shared bucket ordered by (t, seq) — still correct.
    #[inline]
    fn epoch_of(&self, t: f64) -> u64 {
        if t <= 0.0 {
            0
        } else {
            (t / self.width) as u64
        }
    }

    fn push(&mut self, ev: Event) {
        debug_assert!(!ev.t.is_nan(), "event times must not be NaN");
        // Clamp to the cursor: a slot behind the cursor would be
        // invisible to the epoch scan. The clamped slot lands in the
        // bucket scanned next and wins there by its small (t, seq).
        let epoch = self.epoch_of(ev.t).max(self.cur_epoch);
        let mask = self.buckets.len() - 1;
        self.buckets[(epoch as usize) & mask].push(Slot { ev, epoch });
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            let n = self.buckets.len() * 2;
            self.rebuild(n);
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len();
        let mask = n - 1;
        for _lap in 0..n {
            let bucket = &self.buckets[(self.cur_epoch as usize) & mask];
            let mut best: Option<usize> = None;
            for (i, s) in bucket.iter().enumerate() {
                if s.epoch != self.cur_epoch {
                    continue; // same bucket, later lap of the calendar
                }
                if best.is_none_or(|j| earlier(&s.ev, &bucket[j].ev)) {
                    best = Some(i);
                }
            }
            if let Some(i) = best {
                return Some(self.take((self.cur_epoch as usize) & mask, i));
            }
            // Saturating: once epochs saturate every remaining slot
            // shares epoch u64::MAX and one bucket orders them all.
            self.cur_epoch = self.cur_epoch.saturating_add(1);
        }
        // A full lap proved epochs [cur, cur+n) empty: the next event
        // is far ahead of the clock. Jump the cursor straight to the
        // global (t, seq) minimum — O(len), amortised rare.
        let mut best: Option<(usize, usize)> = None;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, s) in bucket.iter().enumerate() {
                if best.is_none_or(|(pb, pi)| earlier(&s.ev, &self.buckets[pb][pi].ev)) {
                    best = Some((b, i));
                }
            }
        }
        let (b, i) = best.expect("len > 0");
        self.cur_epoch = self.buckets[b][i].epoch;
        Some(self.take(b, i))
    }

    /// Remove and return slot `i` of bucket `b`, shrinking if sparse.
    fn take(&mut self, b: usize, i: usize) -> Event {
        let slot = self.buckets[b].swap_remove(i);
        self.len -= 1;
        self.floor_t = slot.ev.t;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 2 {
            let n = self.buckets.len() / 2;
            self.rebuild(n);
        }
        slot.ev
    }

    /// Re-bucket every slot into `n_buckets` buckets, recalibrating the
    /// epoch width so the live events spread over ~len/3 epochs (the
    /// classic calendar-queue target: a few slots per visited bucket).
    fn rebuild(&mut self, n_buckets: usize) {
        let slots: Vec<Slot> = self.buckets.iter_mut().flat_map(std::mem::take).collect();
        if slots.len() >= 2 {
            let (mut min_t, mut max_t) = (f64::INFINITY, f64::NEG_INFINITY);
            for s in &slots {
                min_t = min_t.min(s.ev.t);
                max_t = max_t.max(s.ev.t);
            }
            let span = max_t - min_t;
            if span.is_finite() && span > 0.0 {
                self.width = (3.0 * span / slots.len() as f64).max(MIN_WIDTH);
            }
        }
        self.buckets = vec![Vec::new(); n_buckets.max(MIN_BUCKETS)];
        self.cur_epoch = self.epoch_of(self.floor_t);
        let mask = self.buckets.len() - 1;
        for s in slots {
            let epoch = self.epoch_of(s.ev.t).max(self.cur_epoch);
            self.buckets[(epoch as usize) & mask].push(Slot { ev: s.ev, epoch });
        }
    }
}

/// The pluggable ordering structure behind [`EventQueue`].
#[derive(Debug)]
enum Backend {
    /// The calendar queue: the default, O(1) amortised.
    Calendar(CalendarQueue),
    /// The pre-overhaul binary heap, kept as the reference backend:
    /// the property tests replay identical streams through both, and
    /// `bench scale` runs every sweep row on each so the before/after
    /// events/sec columns are measured in the same binary.
    Heap(BinaryHeap<Event>),
}

/// The event queue plus the virtual clock: `now()` is the time of the
/// most recently popped event (0.0 before the first pop).
#[derive(Debug)]
pub(crate) struct EventQueue {
    backend: Backend,
    seq: u64,
    now: f64,
    /// Total events fired (popped) — the numerator of events/sec.
    fired: u64,
    /// High-water mark of queue length — the "peak heap size" column.
    peak: usize,
    /// Trace-recorder hook: when armed, every *fired* (popped) event is
    /// serialised into one stable line — the golden-trace harness
    /// compares these streams byte-for-byte across runs and against
    /// committed fixtures. `None` (the default) costs the hot loop one
    /// branch and zero allocations.
    trace: Option<Vec<String>>,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

/// Out-of-line so the untraced pop path stays lean; only traced runs
/// (golden-trace harness) ever enter here.
#[cold]
#[inline(never)]
fn record_line(tr: &mut Vec<String>, e: &Event) {
    // {:?} on f64 prints the shortest round-trip decimal, so
    // bit-identical runs serialise to identical strings.
    tr.push(format!("t={:?} seq={} {:?}", e.t, e.seq, e.kind));
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            backend: Backend::Calendar(CalendarQueue::new()),
            seq: 0,
            now: 0.0,
            fired: 0,
            peak: 0,
            trace: None,
        }
    }

    /// The legacy `BinaryHeap` reference backend (identical ordering
    /// contract). Selected by the property tests and by `bench scale`'s
    /// baseline rows via `run_cluster_on_backend("heap")`.
    pub fn with_heap_backend() -> Self {
        EventQueue { backend: Backend::Heap(BinaryHeap::new()), ..EventQueue::new() }
    }

    /// Arm the trace recorder: subsequent pops are serialised.
    pub fn record_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Take the recorded trace (empty if recording was never armed).
    pub fn take_trace(&mut self) -> Vec<String> {
        self.trace.take().unwrap_or_default()
    }

    pub fn push(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        let ev = Event { t, seq: self.seq, kind };
        match &mut self.backend {
            Backend::Calendar(c) => c.push(ev),
            Backend::Heap(h) => h.push(ev),
        }
        let len = self.len();
        if len > self.peak {
            self.peak = len;
        }
    }

    pub fn pop(&mut self) -> Option<Event> {
        let ev = match &mut self.backend {
            Backend::Calendar(c) => c.pop(),
            Backend::Heap(h) => h.pop(),
        };
        if let Some(e) = &ev {
            self.now = e.t;
            self.fired += 1;
            if let Some(tr) = &mut self.trace {
                record_line(tr, e);
            }
        }
        ev
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Calendar(c) => c.len(),
            Backend::Heap(h) => h.len(),
        }
    }

    /// Virtual time of the last popped event.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events fired so far (monotone; survives draining).
    pub fn events_fired(&self) -> u64 {
        self.fired
    }

    /// High-water mark of queue length over the queue's lifetime.
    pub fn peak_len(&self) -> usize {
        self.peak
    }
}

/// One generation counter per (node, device), stored flat: node
/// strides are prefix sums, so `current` is two indexed loads instead
/// of chasing a nested `Vec<Vec<_>>`'s second indirection on every
/// completion event. The flat index is shared with the engine's
/// per-device slabs (kernel ownership) so every per-device table uses
/// one layout.
#[derive(Debug)]
pub(crate) struct DevGens {
    /// One counter per device, nodes concatenated in cluster order.
    gens: Vec<u64>,
    /// `base[n]` = flat index of node n's device 0; `base[n_nodes]` =
    /// total device count.
    base: Vec<usize>,
}

impl DevGens {
    /// `devs_per_node[n]` = number of devices on node `n`.
    pub fn new(devs_per_node: &[usize]) -> Self {
        let mut base = Vec::with_capacity(devs_per_node.len() + 1);
        let mut total = 0;
        for &d in devs_per_node {
            base.push(total);
            total += d;
        }
        base.push(total);
        DevGens { gens: vec![0; total], base }
    }

    /// Flat slab index of `(node, dev)`.
    #[inline]
    pub fn flat(&self, node: usize, dev: usize) -> usize {
        debug_assert!(dev < self.base[node + 1] - self.base[node], "device off node");
        self.base[node] + dev
    }

    /// Total device count across the cluster (the slab length).
    pub fn n_devs(&self) -> usize {
        self.gens.len()
    }

    /// Advance the counter and return the new generation.
    pub fn bump(&mut self, node: usize, dev: usize) -> u64 {
        let i = self.flat(node, dev);
        self.gens[i] += 1;
        self.gens[i]
    }

    pub fn current(&self, node: usize, dev: usize) -> u64 {
        self.gens[self.flat(node, dev)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::rng::Rng;

    #[test]
    fn pops_in_time_order_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, EvKind::Wake { job: 0 });
        q.push(1.0, EvKind::Wake { job: 1 });
        q.push(1.0, EvKind::Wake { job: 2 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EvKind::Wake { job } => job,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 0], "earliest first, FIFO on equal t");
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn clock_tracks_last_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.now(), 0.0);
        q.push(3.5, EvKind::Arrive { job: 0 });
        q.pop();
        assert_eq!(q.now(), 3.5);
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 3.5, "draining does not rewind the clock");
    }

    #[test]
    fn checkpoint_events_interleave_fifo_with_completions() {
        // The protocol relies on FIFO tie-breaking: a completion pushed
        // before a same-instant CkptBegin must fire first (the "victim
        // finishes exactly when checkpointed" race), and CkptDone's
        // waiter Wake must fire before the victim's Restart.
        let mut q = EventQueue::new();
        q.push(5.0, EvKind::DevCompletion { node: 0, dev: 0, gen: 1 });
        q.push(5.0, EvKind::CkptBegin { job: 3 });
        q.push(5.0, EvKind::Wake { job: 9 });
        q.push(5.0, EvKind::Restart { job: 3, node: 0, worker: 1 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::DevCompletion { .. }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::CkptBegin { job: 3 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 9 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Restart { job: 3, node: 0, worker: 1 }));
        // CkptDone is ordered by its (cost-model) time like any event.
        q.push(7.0, EvKind::CkptDone { job: 3 });
        q.push(6.0, EvKind::Wake { job: 1 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 1 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::CkptDone { job: 3 }));
        // A migrating restore's landing rides the same FIFO: pushed
        // after CkptDone's waiter wakes and before the Restart, it must
        // fire between them at an equal instant.
        q.push(9.0, EvKind::Wake { job: 1 });
        q.push(9.0, EvKind::MigrateArrive { job: 3 });
        q.push(9.0, EvKind::Restart { job: 3, node: 1, worker: 0 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 1 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::MigrateArrive { job: 3 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Restart { job: 3, node: 1, worker: 0 }));
    }

    #[test]
    fn probe_events_order_fifo_with_the_rest() {
        // The latency protocol leans on the same FIFO tie-break: a
        // ProbeSent pushed before a same-instant Wake must fire first
        // (the daemon decides before the woken waiter re-probes), and
        // ProbeAck/DispatchArrive order by their modeled delays.
        let mut q = EventQueue::new();
        q.push(1.0, EvKind::ProbeSent { job: 0 });
        q.push(1.0, EvKind::Wake { job: 1 });
        q.push(1.2, EvKind::ProbeAck { job: 0 });
        q.push(1.1, EvKind::DispatchArrive { job: 2 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::ProbeSent { job: 0 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 1 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::DispatchArrive { job: 2 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::ProbeAck { job: 0 }));
    }

    #[test]
    fn trace_recorder_serialises_fired_events() {
        let mut q = EventQueue::new();
        q.record_trace();
        q.push(2.0, EvKind::Wake { job: 3 });
        q.push(1.0, EvKind::Arrive { job: 0 });
        while q.pop().is_some() {}
        let tr = q.take_trace();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0], "t=1.0 seq=2 Arrive { job: 0 }");
        assert_eq!(tr[1], "t=2.0 seq=1 Wake { job: 3 }");
        // Taking the trace disarms the recorder.
        q.push(3.0, EvKind::Wake { job: 0 });
        q.pop();
        assert!(q.take_trace().is_empty());
    }

    #[test]
    fn unarmed_recorder_records_nothing() {
        let mut q = EventQueue::new();
        q.push(1.0, EvKind::Wake { job: 0 });
        q.pop();
        assert!(q.take_trace().is_empty());
    }

    #[test]
    fn fired_and_peak_counters_track_queue_pressure() {
        let mut q = EventQueue::new();
        assert_eq!(q.events_fired(), 0);
        assert_eq!(q.peak_len(), 0);
        q.push(1.0, EvKind::Wake { job: 0 });
        q.push(2.0, EvKind::Wake { job: 1 });
        q.push(3.0, EvKind::Wake { job: 2 });
        assert_eq!(q.peak_len(), 3);
        q.pop();
        q.push(4.0, EvKind::Wake { job: 3 });
        assert_eq!(q.peak_len(), 3, "pop+push stays at the high-water mark");
        while q.pop().is_some() {}
        assert_eq!(q.events_fired(), 4);
        assert_eq!(q.peak_len(), 3, "draining does not reset the peak");
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn generations_invalidate_stale_events() {
        let mut g = DevGens::new(&[2, 1]);
        assert_eq!(g.current(0, 1), 0);
        let gen = g.bump(0, 1);
        assert_eq!(gen, 1);
        assert_eq!(g.current(0, 1), 1);
        // A second bump makes an event carrying `gen` stale.
        g.bump(0, 1);
        assert_ne!(g.current(0, 1), gen);
        // Other devices are unaffected.
        assert_eq!(g.current(0, 0), 0);
        assert_eq!(g.current(1, 0), 0);
    }

    #[test]
    fn flat_indexing_spans_heterogeneous_nodes() {
        // 2 + 1 + 3 devices: the flat slab is [n0d0, n0d1, n1d0, n2d0,
        // n2d1, n2d2] and bumps on one node never alias another.
        let mut g = DevGens::new(&[2, 1, 3]);
        assert_eq!(g.n_devs(), 6);
        assert_eq!(g.flat(0, 0), 0);
        assert_eq!(g.flat(0, 1), 1);
        assert_eq!(g.flat(1, 0), 2);
        assert_eq!(g.flat(2, 0), 3);
        assert_eq!(g.flat(2, 2), 5);
        g.bump(0, 1);
        g.bump(2, 0);
        g.bump(2, 0);
        assert_eq!(g.current(0, 1), 1);
        assert_eq!(g.current(2, 0), 2);
        // Flat neighbours of the bumped devices stay untouched — the
        // stride math does not bleed across node boundaries.
        assert_eq!(g.current(0, 0), 0);
        assert_eq!(g.current(1, 0), 0, "node 1 sits between the bumped devices");
        assert_eq!(g.current(2, 1), 0);
        assert_eq!(g.current(2, 2), 0);
    }

    fn assert_same_pop(a: Option<Event>, b: Option<Event>) {
        match (a, b) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.t.to_bits(), y.t.to_bits(), "time diverged: {} vs {}", x.t, y.t);
                assert_eq!(x.seq, y.seq, "FIFO tie-break diverged at t={}", x.t);
                assert_eq!(x.kind, y.kind);
            }
            (x, y) => panic!("one backend drained early: {x:?} vs {y:?}"),
        }
    }

    #[test]
    fn calendar_pops_exactly_like_the_heap_on_10k_random_events() {
        // The determinism contract of the overhaul: on 10k random
        // (time, seq) events — including bursts of same-instant ties —
        // the calendar queue's pop stream is *identical* to the binary
        // heap's, element for element, under interleaved pushes and
        // pops (which exercise the epoch cursor, lap skips, and both
        // resize directions mid-stream).
        let mut rng = Rng::new(0xCA1E5DA2);
        let mut cal = EventQueue::new();
        let mut heap = EventQueue::with_heap_backend();
        let mut pushed = 0usize;
        while pushed < 10_000 {
            let burst = (1 + rng.below(8)).min(10_000 - pushed);
            for _ in 0..burst {
                // Engine contract: never schedule into the past. Times
                // mix a coarse grid (many exact ties), µs-scale jitter,
                // and rare far-future outliers that force epoch laps.
                let dt = match rng.below(10) {
                    0..=3 => rng.below(16) as f64 * 0.25,
                    4..=6 => rng.below(1_000) as f64 * 1e-3,
                    7 | 8 => rng.below(1_000_000) as f64 * 1e-6,
                    _ => rng.below(4) as f64 * 1e4,
                };
                let job = rng.below(64);
                cal.push(cal.now() + dt, EvKind::Wake { job });
                heap.push(heap.now() + dt, EvKind::Wake { job });
                pushed += 1;
            }
            for _ in 0..rng.below(6) {
                assert_same_pop(cal.pop(), heap.pop());
            }
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            let done = a.is_none();
            assert_same_pop(a, b);
            if done {
                break;
            }
        }
        assert_eq!(cal.now().to_bits(), heap.now().to_bits());
        assert_eq!(cal.events_fired(), 10_000);
        assert_eq!(heap.events_fired(), 10_000);
        assert_eq!(cal.peak_len(), heap.peak_len(), "lengths tracked identically");
    }

    #[test]
    fn calendar_matches_heap_even_for_pushes_behind_the_clock() {
        // The engine never schedules into the past, but the queue must
        // not *depend* on that: a push below `now` is clamped into the
        // current epoch (where its small (t, seq) wins the bucket scan)
        // and the pop stream still matches the heap exactly.
        let mut rng = Rng::new(0x0DD0_EA57);
        let mut cal = EventQueue::new();
        let mut heap = EventQueue::with_heap_backend();
        for round in 0..2_000 {
            // Absolute times, uncorrelated with the clock — roughly half
            // land behind `now` once pops begin.
            let t = rng.below(1_000) as f64 * 0.125;
            cal.push(t, EvKind::Wake { job: round });
            heap.push(t, EvKind::Wake { job: round });
            if rng.below(3) == 0 {
                assert_same_pop(cal.pop(), heap.pop());
            }
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            let done = a.is_none();
            assert_same_pop(a, b);
            if done {
                break;
            }
        }
    }

    #[test]
    fn heap_backend_preserves_the_same_contract() {
        let mut q = EventQueue::with_heap_backend();
        q.record_trace();
        q.push(2.0, EvKind::Wake { job: 3 });
        q.push(1.0, EvKind::Arrive { job: 0 });
        while q.pop().is_some() {}
        let tr = q.take_trace();
        assert_eq!(tr[0], "t=1.0 seq=2 Arrive { job: 0 }");
        assert_eq!(tr[1], "t=2.0 seq=1 Wake { job: 3 }");
        assert_eq!(q.events_fired(), 2);
        assert_eq!(q.peak_len(), 2);
    }
}

//! Event-core of the coordinator: the virtual clock, the discrete-event
//! heap, and the per-device generation counters.
//!
//! This layer knows nothing about jobs, memory, or policies — it only
//! orders [`EvKind`] values in virtual time (f64 seconds) with FIFO
//! tie-breaking, and tracks one generation counter per (node, device)
//! so a stale completion event (pushed before a membership change on
//! the device) can be recognised and dropped by the engine.
//!
//! Paper map: the discrete-event clock realises the virtual timeline of
//! the §V-A deployments (batch at t=0, Poisson arrivals beyond-paper).
//! The checkpoint/restart kinds ([`EvKind::CkptBegin`] /
//! [`EvKind::CkptDone`] / [`EvKind::Restart`] /
//! [`EvKind::MigrateArrive`]) carry the beyond-paper
//! preemption protocol (ROADMAP "Job preemption"); the probe/dispatch
//! kinds ([`EvKind::ProbeSent`] / [`EvKind::ProbeAck`] /
//! [`EvKind::DispatchArrive`] / [`EvKind::ReProbe`]) carry the
//! beyond-paper frontend latency
//! protocol (ROADMAP "Per-node probe latency model"). None of them is
//! ever pushed unless its feature is enabled, which keeps disabled
//! runs bit-identical — provable via the trace-recorder hook
//! ([`EventQueue::record_trace`]), which serialises every fired event
//! for the golden-trace harness.

use std::collections::BinaryHeap;

/// What happens when an event fires. `node`/`dev` index into the
/// cluster; `job` indexes the batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum EvKind {
    /// Resume stepping a blocked job (transfer/host phase done, or a
    /// waiter retrying placement after a release).
    Wake { job: usize },
    /// The earliest kernel on `(node, dev)` may have finished. Stale if
    /// `gen` no longer matches the device's current generation.
    DevCompletion { node: usize, dev: usize, gen: u64 },
    /// A job enters the system. With the latency model off this is
    /// pushed only for open-system arrivals (t > 0) and the dispatcher
    /// routes the job when it fires; with the model on, *every* job
    /// arrives through the cluster frontend this way and routing is
    /// deferred to its `ProbeSent`.
    Arrive { job: usize },
    /// Checkpoint of preemption victim `job` begins: its in-flight
    /// kernel is killed (partial progress becomes wasted work) and the
    /// image copy starts. Aborts harmlessly if the kernel completed in
    /// the same instant under an earlier sequence number.
    CkptBegin { job: usize },
    /// Victim `job`'s checkpoint image is written: its reservations are
    /// released to the node's waiters, its progress saved, and it
    /// re-queues for a worker.
    CkptDone { job: usize },
    /// Recycle the checkpointed job's worker slot on its *home* node
    /// (both captured at `CkptDone`: a same-instant pickup can
    /// re-assign the job a different worker before this fires, and a
    /// cluster-migrating victim may already have been re-routed off the
    /// node whose worker it held). Fired after `CkptDone`'s waiter
    /// wake-ups so the job the eviction unblocked re-places first.
    Restart { job: usize, node: usize, worker: usize },
    /// A probe RPC reaches its server (latency mode only): the cluster
    /// frontend's routing probe if `job` is not yet dispatched, else
    /// the task probe arriving at the job's node scheduler daemon. The
    /// decision is made *now*, on the load the server sees now — the
    /// stale-snapshot semantics the latency model exists to expose.
    ProbeSent { job: usize },
    /// The probe's reply lands back at the client after the modeled
    /// round-trip: a routed job starts its dispatch hop, a placed task
    /// resumes stepping. Never pushed when the latency model is off.
    ProbeAck { job: usize },
    /// A dispatched job physically arrives at its node (after the
    /// dispatch-cost delay) and joins the node's worker queue. Never
    /// pushed when the latency model is off.
    DispatchArrive { job: usize },
    /// The frontend's staleness timeout for a routed-but-not-landed
    /// job: fired `reprobe_after_s` after a routing decision whose
    /// landing delay exceeds that bound. The frontend re-snapshots the
    /// cluster and may re-route the in-flight job; each firing consumes
    /// one unit of the job's bounded re-probe budget, so routing always
    /// terminates. Never pushed when the latency model is off or
    /// re-probing is disabled (`LatencyModel::reprobe_enabled`).
    ReProbe { job: usize },
    /// A checkpointed preemption victim's *restore job* lands on its
    /// routed node (cluster-wide migration only,
    /// `sched::PreemptConfig::migrate = "cluster"`): the landing
    /// instant already includes the probe RTT + dispatch cost of the
    /// journey plus the checkpoint-image transfer when the node is not
    /// the victim's home. Replaces `DispatchArrive` for migrating
    /// restores so traces distinguish migration landings; never pushed
    /// with migration off, which keeps `--migrate off` byte-identical.
    MigrateArrive { job: usize },
}

#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    pub t: f64,
    pub seq: u64,
    pub kind: EvKind,
}

impl PartialEq for Event {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Event {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Min-heap via reverse: earliest time, then FIFO by seq.
        o.t.total_cmp(&self.t).then(o.seq.cmp(&self.seq))
    }
}

/// The event heap plus the virtual clock: `now()` is the time of the
/// most recently popped event (0.0 before the first pop).
#[derive(Debug, Default)]
pub(crate) struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: u64,
    now: f64,
    /// Trace-recorder hook: when armed, every *fired* (popped) event is
    /// serialised into one stable line — the golden-trace harness
    /// compares these streams byte-for-byte across runs and against
    /// committed fixtures. `None` (the default) costs the hot loop one
    /// branch.
    trace: Option<Vec<String>>,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Arm the trace recorder: subsequent pops are serialised.
    pub fn record_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Take the recorded trace (empty if recording was never armed).
    pub fn take_trace(&mut self) -> Vec<String> {
        self.trace.take().unwrap_or_default()
    }

    pub fn push(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Event { t, seq: self.seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop();
        if let Some(e) = &ev {
            self.now = e.t;
            if let Some(tr) = &mut self.trace {
                // {:?} on f64 prints the shortest round-trip decimal, so
                // bit-identical runs serialise to identical strings.
                tr.push(format!("t={:?} seq={} {:?}", e.t, e.seq, e.kind));
            }
        }
        ev
    }

    /// Virtual time of the last popped event.
    pub fn now(&self) -> f64 {
        self.now
    }
}

/// One generation counter per (node, device). Bumping invalidates every
/// completion event pushed under an older generation.
#[derive(Debug)]
pub(crate) struct DevGens(Vec<Vec<u64>>);

impl DevGens {
    /// `devs_per_node[n]` = number of devices on node `n`.
    pub fn new(devs_per_node: &[usize]) -> Self {
        DevGens(devs_per_node.iter().map(|&d| vec![0; d]).collect())
    }

    /// Advance the counter and return the new generation.
    pub fn bump(&mut self, node: usize, dev: usize) -> u64 {
        self.0[node][dev] += 1;
        self.0[node][dev]
    }

    pub fn current(&self, node: usize, dev: usize) -> u64 {
        self.0[node][dev]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order_fifo_on_ties() {
        let mut q = EventQueue::new();
        q.push(2.0, EvKind::Wake { job: 0 });
        q.push(1.0, EvKind::Wake { job: 1 });
        q.push(1.0, EvKind::Wake { job: 2 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EvKind::Wake { job } => job,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 0], "earliest first, FIFO on equal t");
        assert_eq!(q.now(), 2.0);
    }

    #[test]
    fn clock_tracks_last_pop() {
        let mut q = EventQueue::new();
        assert_eq!(q.now(), 0.0);
        q.push(3.5, EvKind::Arrive { job: 0 });
        q.pop();
        assert_eq!(q.now(), 3.5);
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 3.5, "draining does not rewind the clock");
    }

    #[test]
    fn checkpoint_events_interleave_fifo_with_completions() {
        // The protocol relies on FIFO tie-breaking: a completion pushed
        // before a same-instant CkptBegin must fire first (the "victim
        // finishes exactly when checkpointed" race), and CkptDone's
        // waiter Wake must fire before the victim's Restart.
        let mut q = EventQueue::new();
        q.push(5.0, EvKind::DevCompletion { node: 0, dev: 0, gen: 1 });
        q.push(5.0, EvKind::CkptBegin { job: 3 });
        q.push(5.0, EvKind::Wake { job: 9 });
        q.push(5.0, EvKind::Restart { job: 3, node: 0, worker: 1 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::DevCompletion { .. }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::CkptBegin { job: 3 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 9 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Restart { job: 3, node: 0, worker: 1 }));
        // CkptDone is ordered by its (cost-model) time like any event.
        q.push(7.0, EvKind::CkptDone { job: 3 });
        q.push(6.0, EvKind::Wake { job: 1 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 1 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::CkptDone { job: 3 }));
        // A migrating restore's landing rides the same FIFO: pushed
        // after CkptDone's waiter wakes and before the Restart, it must
        // fire between them at an equal instant.
        q.push(9.0, EvKind::Wake { job: 1 });
        q.push(9.0, EvKind::MigrateArrive { job: 3 });
        q.push(9.0, EvKind::Restart { job: 3, node: 1, worker: 0 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 1 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::MigrateArrive { job: 3 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Restart { job: 3, node: 1, worker: 0 }));
    }

    #[test]
    fn probe_events_order_fifo_with_the_rest() {
        // The latency protocol leans on the same FIFO tie-break: a
        // ProbeSent pushed before a same-instant Wake must fire first
        // (the daemon decides before the woken waiter re-probes), and
        // ProbeAck/DispatchArrive order by their modeled delays.
        let mut q = EventQueue::new();
        q.push(1.0, EvKind::ProbeSent { job: 0 });
        q.push(1.0, EvKind::Wake { job: 1 });
        q.push(1.2, EvKind::ProbeAck { job: 0 });
        q.push(1.1, EvKind::DispatchArrive { job: 2 });
        assert!(matches!(q.pop().unwrap().kind, EvKind::ProbeSent { job: 0 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::Wake { job: 1 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::DispatchArrive { job: 2 }));
        assert!(matches!(q.pop().unwrap().kind, EvKind::ProbeAck { job: 0 }));
    }

    #[test]
    fn trace_recorder_serialises_fired_events() {
        let mut q = EventQueue::new();
        q.record_trace();
        q.push(2.0, EvKind::Wake { job: 3 });
        q.push(1.0, EvKind::Arrive { job: 0 });
        while q.pop().is_some() {}
        let tr = q.take_trace();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0], "t=1.0 seq=2 Arrive { job: 0 }");
        assert_eq!(tr[1], "t=2.0 seq=1 Wake { job: 3 }");
        // Taking the trace disarms the recorder.
        q.push(3.0, EvKind::Wake { job: 0 });
        q.pop();
        assert!(q.take_trace().is_empty());
    }

    #[test]
    fn unarmed_recorder_records_nothing() {
        let mut q = EventQueue::new();
        q.push(1.0, EvKind::Wake { job: 0 });
        q.pop();
        assert!(q.take_trace().is_empty());
    }

    #[test]
    fn generations_invalidate_stale_events() {
        let mut g = DevGens::new(&[2, 1]);
        assert_eq!(g.current(0, 1), 0);
        let gen = g.bump(0, 1);
        assert_eq!(gen, 1);
        assert_eq!(g.current(0, 1), 1);
        // A second bump makes an event carrying `gen` stale.
        g.bump(0, 1);
        assert_ne!(g.current(0, 1), gen);
        // Other devices are unaffected.
        assert_eq!(g.current(0, 0), 0);
        assert_eq!(g.current(1, 0), 0);
    }
}

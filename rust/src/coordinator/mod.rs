//! The MGB coordinator: probe protocol + worker pool + batch engine.

pub mod engine;
pub mod metrics;

pub use engine::{run_batch, run_batch_with_hook, JobSpec, RunConfig, SchedMode};
pub use metrics::{JobClass, JobOutcome, RunResult};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::NodeSpec;
    use crate::lazy::{JobTrace, TaskResources, TraceEvent};

    /// A synthetic one-task job: reserve `mem`, run one kernel of
    /// `work_us` with `warps` warps (as grid x 32-thread blocks).
    fn job(name: &str, mem: u64, warps: u64, work_us: u64) -> JobSpec {
        let res = TaskResources { static_dev: None, mem_bytes: mem, heap_bytes: 0, grid: warps, block: 32 };
        JobSpec {
            name: name.into(),
            class: JobClass::Small,
            arrival: 0.0,
            trace: JobTrace {
                events: vec![
                    TraceEvent::TaskBegin { task: 0, res },
                    TraceEvent::Malloc { task: 0, bytes: mem },
                    TraceEvent::H2D { task: 0, bytes: mem },
                    TraceEvent::Launch {
                        task: 0,
                        kernel: "k".into(),
                        artifact: None,
                        grid: warps,
                        block: 32,
                        work_us,
                    },
                    TraceEvent::D2H { task: 0, bytes: mem },
                    TraceEvent::Free { task: 0, bytes: mem },
                    TraceEvent::TaskEnd { task: 0 },
                ],
            },
        }
    }

    fn v100x4() -> NodeSpec {
        NodeSpec::v100x4()
    }

    #[test]
    fn sa_serialises_on_device_count() {
        // 8 identical 10s jobs, 4 GPUs: SA takes ~2 rounds.
        let jobs: Vec<JobSpec> = (0..8).map(|i| job(&format!("j{i}"), 1 << 30, 1000, 10_000_000)).collect();
        let r = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Sa, workers: 99 },
            jobs,
        );
        assert_eq!(r.workers, 4, "SA pins one worker per GPU");
        assert_eq!(r.completed(), 8);
        assert_eq!(r.crashed(), 0);
        // Two sequential rounds of ~10s each (plus transfers).
        assert!(r.makespan > 19.9 && r.makespan < 21.0, "makespan {}", r.makespan);
        // Dedicated runs: no kernel slowdown.
        assert!(r.kernel_slowdown_pct().abs() < 0.01);
    }

    #[test]
    fn mgb3_packs_underutilised_jobs() {
        // Each job needs 25% of a V100's warps: MGB packs 2 jobs/device
        // with 8 workers and finishes in ~1 round.
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> =
            (0..8).map(|i| job(&format!("j{i}"), 1 << 30, cap / 4, 10_000_000)).collect();
        let sa = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Sa, workers: 4 },
            jobs.clone(),
        );
        let mgb = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 8 },
            jobs,
        );
        assert_eq!(mgb.completed(), 8);
        let speedup = mgb.throughput() / sa.throughput();
        assert!(speedup > 1.8, "expected ~2x, got {speedup}");
        // No capacity contention: only the small MPS co-residency cost.
        assert!(mgb.kernel_slowdown_pct() < 5.0, "{}", mgb.kernel_slowdown_pct());
    }

    #[test]
    fn mgb3_is_memory_safe_where_cg_crashes() {
        // 12 jobs of 9 GB on 4x16GB GPUs. CG with 3 workers/GPU blindly
        // co-locates 3 x 9GB = 27GB > 16GB: crashes. MGB reserves and
        // waits instead.
        let jobs: Vec<JobSpec> =
            (0..12).map(|i| job(&format!("j{i}"), 9 << 30, 1000, 5_000_000)).collect();
        let cg = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Cg, workers: 12 },
            jobs.clone(),
        );
        assert!(cg.crashed() > 0, "CG must crash on 2x9GB > 16GB");
        let mgb = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 12 },
            jobs,
        );
        assert_eq!(mgb.crashed(), 0, "MGB is memory-safe");
        assert_eq!(mgb.completed(), 12);
    }

    #[test]
    fn oversubscription_shows_up_as_kernel_slowdown() {
        // Two full-device-warp jobs forced onto one device (schedgpu
        // memory-first piles them on dev0): both slow ~2x.
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> =
            (0..2).map(|i| job(&format!("j{i}"), 1 << 30, cap, 10_000_000)).collect();
        let r = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("schedgpu"), workers: 2 },
            jobs,
        );
        assert_eq!(r.completed(), 2);
        // Demand 2x capacity vs the 1.5x memory-bound headroom: ~33%
        // slowdown plus the MPS co-residency cost.
        assert!(
            r.kernel_slowdown_pct() > 25.0,
            "2x piled -> ~36% slowdown, got {}",
            r.kernel_slowdown_pct()
        );
    }

    #[test]
    fn mgb3_spreads_what_schedgpu_piles() {
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> =
            (0..4).map(|i| job(&format!("j{i}"), 1 << 30, cap / 2, 10_000_000)).collect();
        let sg = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("schedgpu"), workers: 4 },
            jobs.clone(),
        );
        let mgb = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 4 },
            jobs,
        );
        assert!(
            mgb.throughput() > 1.2 * sg.throughput(),
            "mgb {} vs schedgpu {}",
            mgb.throughput(),
            sg.throughput()
        );
    }

    #[test]
    fn waiting_task_proceeds_after_release() {
        // 3 x 12GB jobs, 1 GPU: strictly sequential under MGB, no crash.
        let node = NodeSpec { gpus: vec![crate::gpu::GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
        let jobs: Vec<JobSpec> =
            (0..3).map(|i| job(&format!("j{i}"), 12 << 30, 100, 1_000_000)).collect();
        let r = run_batch(
            RunConfig { node, mode: SchedMode::Policy("mgb3"), workers: 3 },
            jobs,
        );
        assert_eq!(r.completed(), 3);
        assert_eq!(r.crashed(), 0);
        // Serialised: makespan ~ 3 x (1s + transfers)
        assert!(r.makespan > 3.0, "makespan {}", r.makespan);
    }

    #[test]
    fn alg2_holds_jobs_alg3_admits_optimistically() {
        // Jobs each demanding the full device's warps. Alg2 runs them
        // one-per-device; Alg3 admits all (compute soft).
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let node = NodeSpec { gpus: vec![crate::gpu::GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
        let jobs: Vec<JobSpec> =
            (0..2).map(|i| job(&format!("j{i}"), 1 << 30, cap, 2_000_000)).collect();
        let a2 = run_batch(
            RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb2"), workers: 2 },
            jobs.clone(),
        );
        let a3 = run_batch(
            RunConfig { node, mode: SchedMode::Policy("mgb3"), workers: 2 },
            jobs,
        );
        // Alg2: no co-residency -> zero slowdown; Alg3 admits both
        // (demand 2x vs headroom 1.5x -> each ~36% slower)...
        assert!(a2.kernel_slowdown_pct() < 0.1);
        assert!(a3.kernel_slowdown_pct() > 25.0);
        // ...but the memory-bound overlap means Alg3 finishes the batch
        // sooner — the paper's Fig. 4 mechanism in miniature.
        assert!(a3.makespan < a2.makespan, "a3 {} vs a2 {}", a3.makespan, a2.makespan);
    }

    #[test]
    fn deterministic_replay() {
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> = (0..16)
            .map(|i| job(&format!("j{i}"), (1 + i % 5) << 30, cap / 3, 3_000_000 + i * 77_000))
            .collect();
        let cfg = RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 10 };
        let a = run_batch(cfg.clone(), jobs.clone());
        let b = run_batch(cfg, jobs);
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.ended, y.ended);
        }
    }
}

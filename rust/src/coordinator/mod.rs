//! The MGB coordinator: probe protocol + worker pool + batch engine,
//! layered as an event-core / placement / policy stack.
//!
//! * `events` (private) — the **event-core**: virtual clock,
//!   discrete-event heap with FIFO tie-breaking, and per-(node, device)
//!   generation counters that invalidate stale completion events. Knows
//!   nothing about jobs or memory.
//! * `placement` (private) — **placement & accounting**, one
//!   instance per cluster node: simulated devices, probe reservations
//!   (memory-safe, may wait), raw allocations (crash on OOM), the
//!   placement wait queue, and O(1) worker-idleness tracking.
//! * [`engine`] — the stepping layer that walks each job's compacted
//!   trace and glues the two together with the scheduling stack: a
//!   cluster-level `sched::Dispatcher` routes arriving jobs to nodes,
//!   and each node's `sched::Policy` places tasks beneath it.
//!
//! `run_batch` runs the paper's single-node deployments (a one-node
//! cluster — bit-identical to the pre-cluster engine); `run_cluster`
//! scales the same engine across a `gpu::ClusterSpec`, optionally under
//! open-system Poisson traffic (`workloads::poisson_arrivals`), with
//! checkpoint/restart preemption (`ClusterConfig::preempt` — a
//! `sched::PreemptPolicy` may evict a running victim to admit a blocked
//! task, with optional SLO-aware victim selection over per-job
//! [`SloClass`]es and optional cluster-wide restore migration through
//! the frontend; off by default, and the disabled path is
//! bit-identical), and
//! with a probe/dispatch latency model (`ClusterConfig::latency` — see
//! `gpu::LatencyModel`; the all-zero default is likewise
//! bit-identical), including its timeout + re-probe guard on stale
//! routing decisions and daemon-side probe-reply coalescing.
//! `run_cluster_traced` arms the event-core's trace
//! recorder and returns the serialised fired-event stream alongside the
//! result — the backbone of the golden-trace test harness.

pub mod engine;
mod events;
pub mod metrics;
mod placement;

pub use crate::sched::{AdmissionConfig, PreemptConfig, SloClass};
pub use engine::{
    run_batch, run_batch_with_hook, run_cluster, run_cluster_on_backend, run_cluster_sanitized,
    run_cluster_traced, run_cluster_traced_on_backend, run_cluster_with_hook, ClusterConfig,
    JobSpec, RunConfig, SanitizerReport, SanitizerViolation, SchedMode,
};
pub use metrics::{JobClass, JobOutcome, RunResult};
pub use placement::PARTITION_SLICES;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{LatencyModel, NodeSpec};
    use crate::lazy::{JobTrace, TaskResources, TraceEvent};

    /// A synthetic one-task job: reserve `mem`, run one kernel of
    /// `work_us` with `warps` warps (as grid x 32-thread blocks).
    fn job(name: &str, mem: u64, warps: u64, work_us: u64) -> JobSpec {
        let res = TaskResources {
            static_dev: None,
            mem_bytes: mem,
            heap_bytes: 0,
            grid: warps,
            block: 32,
            written_bytes: 2 * mem,
            iv: crate::gpu::InterferenceProfile::ZERO,
        };
        JobSpec {
            name: name.into(),
            class: JobClass::Small,
            arrival: 0.0,
            slo: None,
            trace: JobTrace::new(vec![
                TraceEvent::TaskBegin { task: 0, res },
                TraceEvent::Malloc { task: 0, bytes: mem },
                TraceEvent::H2D { task: 0, bytes: mem },
                TraceEvent::Launch {
                    task: 0,
                    kernel: "k".into(),
                    artifact: None,
                    grid: warps,
                    block: 32,
                    work_us,
                },
                TraceEvent::D2H { task: 0, bytes: mem },
                TraceEvent::Free { task: 0, bytes: mem },
                TraceEvent::TaskEnd { task: 0 },
            ]),
        }
    }

    fn v100x4() -> NodeSpec {
        NodeSpec::v100x4()
    }

    #[test]
    fn sa_serialises_on_device_count() {
        // 8 identical 10s jobs, 4 GPUs: SA takes ~2 rounds.
        let jobs: Vec<JobSpec> = (0..8).map(|i| job(&format!("j{i}"), 1 << 30, 1000, 10_000_000)).collect();
        let r = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Sa, workers: 99 },
            jobs,
        );
        assert_eq!(r.workers, 4, "SA pins one worker per GPU");
        assert_eq!(r.completed(), 8);
        assert_eq!(r.crashed(), 0);
        // Two sequential rounds of ~10s each (plus transfers).
        assert!(r.makespan > 19.9 && r.makespan < 21.0, "makespan {}", r.makespan);
        // Dedicated runs: no kernel slowdown.
        assert!(r.kernel_slowdown_pct().abs() < 0.01);
    }

    #[test]
    fn mgb3_packs_underutilised_jobs() {
        // Each job needs 25% of a V100's warps: MGB packs 2 jobs/device
        // with 8 workers and finishes in ~1 round.
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> =
            (0..8).map(|i| job(&format!("j{i}"), 1 << 30, cap / 4, 10_000_000)).collect();
        let sa = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Sa, workers: 4 },
            jobs.clone(),
        );
        let mgb = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 8 },
            jobs,
        );
        assert_eq!(mgb.completed(), 8);
        let speedup = mgb.throughput() / sa.throughput();
        assert!(speedup > 1.8, "expected ~2x, got {speedup}");
        // No capacity contention: only the small MPS co-residency cost.
        assert!(mgb.kernel_slowdown_pct() < 5.0, "{}", mgb.kernel_slowdown_pct());
    }

    #[test]
    fn mgb3_is_memory_safe_where_cg_crashes() {
        // 12 jobs of 9 GB on 4x16GB GPUs. CG with 3 workers/GPU blindly
        // co-locates 3 x 9GB = 27GB > 16GB: crashes. MGB reserves and
        // waits instead.
        let jobs: Vec<JobSpec> =
            (0..12).map(|i| job(&format!("j{i}"), 9 << 30, 1000, 5_000_000)).collect();
        let cg = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Cg, workers: 12 },
            jobs.clone(),
        );
        assert!(cg.crashed() > 0, "CG must crash on 2x9GB > 16GB");
        let mgb = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 12 },
            jobs,
        );
        assert_eq!(mgb.crashed(), 0, "MGB is memory-safe");
        assert_eq!(mgb.completed(), 12);
    }

    #[test]
    fn oversubscription_shows_up_as_kernel_slowdown() {
        // Two full-device-warp jobs forced onto one device (schedgpu
        // memory-first piles them on dev0): both slow ~2x.
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> =
            (0..2).map(|i| job(&format!("j{i}"), 1 << 30, cap, 10_000_000)).collect();
        let r = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("schedgpu"), workers: 2 },
            jobs,
        );
        assert_eq!(r.completed(), 2);
        // Demand 2x capacity vs the 1.5x memory-bound headroom: ~33%
        // slowdown plus the MPS co-residency cost.
        assert!(
            r.kernel_slowdown_pct() > 25.0,
            "2x piled -> ~36% slowdown, got {}",
            r.kernel_slowdown_pct()
        );
    }

    #[test]
    fn mgb3_spreads_what_schedgpu_piles() {
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> =
            (0..4).map(|i| job(&format!("j{i}"), 1 << 30, cap / 2, 10_000_000)).collect();
        let sg = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("schedgpu"), workers: 4 },
            jobs.clone(),
        );
        let mgb = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 4 },
            jobs,
        );
        assert!(
            mgb.throughput() > 1.2 * sg.throughput(),
            "mgb {} vs schedgpu {}",
            mgb.throughput(),
            sg.throughput()
        );
    }

    #[test]
    fn waiting_task_proceeds_after_release() {
        // 3 x 12GB jobs, 1 GPU: strictly sequential under MGB, no crash.
        let node = NodeSpec { gpus: vec![crate::gpu::GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
        let jobs: Vec<JobSpec> =
            (0..3).map(|i| job(&format!("j{i}"), 12 << 30, 100, 1_000_000)).collect();
        let r = run_batch(
            RunConfig { node, mode: SchedMode::Policy("mgb3"), workers: 3 },
            jobs,
        );
        assert_eq!(r.completed(), 3);
        assert_eq!(r.crashed(), 0);
        // Serialised: makespan ~ 3 x (1s + transfers)
        assert!(r.makespan > 3.0, "makespan {}", r.makespan);
    }

    #[test]
    fn alg2_holds_jobs_alg3_admits_optimistically() {
        // Jobs each demanding the full device's warps. Alg2 runs them
        // one-per-device; Alg3 admits all (compute soft).
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let node = NodeSpec { gpus: vec![crate::gpu::GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
        let jobs: Vec<JobSpec> =
            (0..2).map(|i| job(&format!("j{i}"), 1 << 30, cap, 2_000_000)).collect();
        let a2 = run_batch(
            RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb2"), workers: 2 },
            jobs.clone(),
        );
        let a3 = run_batch(
            RunConfig { node, mode: SchedMode::Policy("mgb3"), workers: 2 },
            jobs,
        );
        // Alg2: no co-residency -> zero slowdown; Alg3 admits both
        // (demand 2x vs headroom 1.5x -> each ~36% slower)...
        assert!(a2.kernel_slowdown_pct() < 0.1);
        assert!(a3.kernel_slowdown_pct() > 25.0);
        // ...but the memory-bound overlap means Alg3 finishes the batch
        // sooner — the paper's Fig. 4 mechanism in miniature.
        assert!(a3.makespan < a2.makespan, "a3 {} vs a2 {}", a3.makespan, a2.makespan);
    }

    #[test]
    fn deterministic_replay() {
        let cap = crate::gpu::GpuSpec::v100().warp_capacity();
        let jobs: Vec<JobSpec> = (0..16)
            .map(|i| job(&format!("j{i}"), (1 + i % 5) << 30, cap / 3, 3_000_000 + i * 77_000))
            .collect();
        let cfg = RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 10 };
        let a = run_batch(cfg.clone(), jobs.clone());
        let b = run_batch(cfg, jobs);
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.ended, y.ended);
        }
    }

    fn v100x1() -> NodeSpec {
        NodeSpec { gpus: vec![crate::gpu::GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() }
    }

    #[test]
    fn arrive_event_wakes_idle_worker() {
        // Nothing queued at t=0: both workers go idle; the job arriving
        // at t=5 must be picked up exactly then.
        let mut late = job("late", 1 << 30, 100, 1_000_000);
        late.arrival = 5.0;
        let r = run_batch(
            RunConfig { node: v100x1(), mode: SchedMode::Policy("mgb3"), workers: 2 },
            vec![late],
        );
        assert_eq!(r.completed(), 1);
        assert_eq!(r.crashed(), 0);
        let o = &r.jobs[0];
        assert_eq!(o.started, 5.0, "idle worker picks the job up at arrival");
        // 1s kernel + two ~0.09s 1GB transfers.
        assert!(o.ended > 6.0 && o.ended < 6.5, "ended {}", o.ended);
        assert!((o.turnaround() - (o.ended - 5.0)).abs() < 1e-12);
    }

    #[test]
    fn staggered_arrivals_start_at_their_time() {
        // 12GB jobs on one 16GB GPU arriving far apart: each finds the
        // device free and starts exactly at its own arrival.
        let jobs: Vec<JobSpec> = (0..4)
            .map(|i| {
                let mut j = job(&format!("j{i}"), 12 << 30, 100, 1_000_000);
                j.arrival = i as f64 * 20.0;
                j
            })
            .collect();
        let r = run_batch(
            RunConfig { node: v100x1(), mode: SchedMode::Policy("mgb3"), workers: 4 },
            jobs,
        );
        assert_eq!(r.completed(), 4);
        assert_eq!(r.crashed(), 0);
        for (i, o) in r.jobs.iter().enumerate() {
            assert_eq!(o.arrival, i as f64 * 20.0);
            assert_eq!(o.started, o.arrival, "job {i} started {}", o.started);
            assert!(o.ended > o.arrival && o.ended < o.arrival + 10.0);
        }
    }

    #[test]
    fn contended_arrivals_wait_for_release() {
        // Two 12GB jobs arriving 1s apart on one 16GB GPU: the second's
        // probe must wait for the first's TaskEnd, not crash.
        let mut a = job("a", 12 << 30, 100, 5_000_000);
        a.arrival = 0.0;
        let mut b = job("b", 12 << 30, 100, 5_000_000);
        b.arrival = 1.0;
        let r = run_batch(
            RunConfig { node: v100x1(), mode: SchedMode::Policy("mgb3"), workers: 2 },
            vec![a, b],
        );
        assert_eq!(r.crashed(), 0, "MGB is memory-safe under arrivals");
        assert_eq!(r.completed(), 2);
        let (a, b) = (&r.jobs[0], &r.jobs[1]);
        assert!(b.ended > a.ended, "b serialises behind a");
        assert!(b.ended - b.arrival > a.ended - a.arrival, "b waited on a's memory");
    }

    use crate::gpu::ClusterSpec;

    #[test]
    fn single_node_cluster_matches_run_batch_exactly() {
        // Acceptance: cluster_size == 1 is bit-identical to the
        // single-node engine, whatever the dispatcher.
        let jobs = crate::workloads::Workload::by_id("W2").unwrap().jobs(7);
        let a = run_batch(
            RunConfig { node: v100x4(), mode: SchedMode::Policy("mgb3"), workers: 16 },
            jobs.clone(),
        );
        for dispatch in ["rr", "least", "mem", "latency"] {
            let b = run_cluster(
                ClusterConfig {
                    cluster: ClusterSpec::single(v100x4()),
                    mode: SchedMode::Policy("mgb3"),
                    workers_per_node: 16,
                    dispatch,
                    preempt: None,
                    latency: LatencyModel::off(),
                    admit: None,
                    frontend_q: "fifo",
                    compile_traces: false,
                },
                jobs.clone(),
            );
            assert_eq!(a.node, b.node);
            assert_eq!(a.workers, b.workers);
            assert_eq!(a.makespan, b.makespan, "dispatch={dispatch}");
            for (x, y) in a.jobs.iter().zip(&b.jobs) {
                assert_eq!(x.started, y.started);
                assert_eq!(x.ended, y.ended);
                assert_eq!(x.crashed, y.crashed);
                assert_eq!(y.node, 0);
            }
        }
    }

    #[test]
    fn round_robin_splits_a_batch_evenly() {
        let jobs: Vec<JobSpec> =
            (0..8).map(|i| job(&format!("j{i}"), 1 << 30, 1000, 1_000_000)).collect();
        let r = run_cluster(
            ClusterConfig {
                cluster: ClusterSpec::homogeneous(v100x4(), 2),
                mode: SchedMode::Policy("mgb3"),
                workers_per_node: 4,
                dispatch: "rr",
                preempt: None,
                latency: LatencyModel::off(),
                admit: None,
                frontend_q: "fifo",
                compile_traces: false,
            },
            jobs,
        );
        assert_eq!(r.n_nodes, 2);
        assert_eq!(r.dispatcher, "rr");
        assert_eq!(r.jobs_per_node(), vec![4, 4]);
        assert_eq!(r.completed(), 8);
    }

    #[test]
    fn least_loaded_beats_round_robin_on_heterogeneous_rodinia_mix() {
        // Acceptance: an alternating heavy/light Rodinia stream is
        // adversarial for round-robin (all heavies land on node 0);
        // least-loaded balances by estimated outstanding work.
        use crate::workloads::COMBOS;
        let heavy = COMBOS
            .iter()
            .max_by(|a, b| (a.gpu_s + a.host_s).total_cmp(&(b.gpu_s + b.host_s)))
            .unwrap();
        let light = COMBOS
            .iter()
            .min_by(|a, b| (a.gpu_s + a.host_s).total_cmp(&(b.gpu_s + b.host_s)))
            .unwrap();
        let mut jobs = Vec::new();
        for i in 0..8 {
            let mut h = heavy.job_spec();
            h.name = format!("h{i}-{}", h.name);
            jobs.push(h);
            let mut l = light.job_spec();
            l.name = format!("l{i}-{}", l.name);
            jobs.push(l);
        }
        let cluster = ClusterSpec::homogeneous(v100x4(), 2);
        let run = |dispatch: &'static str, jobs: Vec<JobSpec>| {
            run_cluster(
                ClusterConfig {
                    cluster: cluster.clone(),
                    mode: SchedMode::Policy("mgb3"),
                    workers_per_node: 8,
                    dispatch,
                    preempt: None,
                    latency: LatencyModel::off(),
                    admit: None,
                    frontend_q: "fifo",
                    compile_traces: false,
                },
                jobs,
            )
        };
        let rr = run("rr", jobs.clone());
        let ll = run("least", jobs);
        assert_eq!(rr.crashed(), 0);
        assert_eq!(ll.crashed(), 0);
        assert!(
            ll.makespan < 0.9 * rr.makespan,
            "least-loaded {} vs round-robin {}",
            ll.makespan,
            rr.makespan
        );
        assert!(ll.throughput() > rr.throughput());
    }

    #[test]
    fn cluster_replay_is_deterministic_under_open_traffic() {
        let mut jobs = crate::workloads::Workload::by_id("W5").unwrap().jobs(3);
        crate::workloads::poisson_arrivals(&mut jobs, 0.5, 9);
        assert!(jobs.iter().all(|j| j.arrival > 0.0));
        let cfg = ClusterConfig {
            cluster: ClusterSpec::homogeneous(v100x4(), 2),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: 8,
            dispatch: "least",
            preempt: None,
            latency: LatencyModel::off(),
            admit: None,
            frontend_q: "fifo",
            compile_traces: false,
        };
        let a = run_cluster(cfg.clone(), jobs.clone());
        let b = run_cluster(cfg, jobs);
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.ended, y.ended);
            assert_eq!(x.node, y.node);
            assert!(x.started >= x.arrival);
        }
        assert_eq!(a.completed(), a.jobs.len());
    }

    #[test]
    fn heterogeneous_least_loaded_favours_the_faster_node() {
        // Mixed P100/V100 cluster (ROADMAP open item): capability-
        // normalised least-loaded must route most of an identical-job
        // stream to the 4xV100 node (capacity 4.0 vs 1.4), not split it
        // 50/50 the way raw outstanding-work comparison did.
        let cluster = ClusterSpec::of(vec![NodeSpec::p100x2(), NodeSpec::v100x4()]);
        let jobs: Vec<JobSpec> =
            (0..12).map(|i| job(&format!("j{i}"), 2 << 30, 1000, 2_000_000)).collect();
        let r = run_cluster(
            ClusterConfig {
                cluster,
                mode: SchedMode::Policy("mgb3"),
                workers_per_node: 6,
                dispatch: "least",
                preempt: None,
                latency: LatencyModel::off(),
                admit: None,
                frontend_q: "fifo",
                compile_traces: false,
            },
            jobs,
        );
        assert_eq!(r.crashed(), 0);
        assert_eq!(r.completed(), 12);
        let per_node = r.jobs_per_node();
        assert!(
            per_node[1] >= 2 * per_node[0],
            "V100 node should take the bulk: {per_node:?}"
        );
        assert!(per_node[0] >= 1, "slow node still serves its share: {per_node:?}");
    }

    // ---- checkpoint/restart preemption ----------------------------------

    fn v100x1_cluster() -> crate::gpu::ClusterSpec {
        ClusterSpec::single(v100x1())
    }

    fn preempt_cfg(policy: &'static str) -> PreemptConfig {
        PreemptConfig { policy, ..PreemptConfig::default() }
    }

    fn contended_cluster_cfg(preempt: Option<PreemptConfig>) -> ClusterConfig {
        ClusterConfig {
            cluster: v100x1_cluster(),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: 3,
            dispatch: "rr",
            preempt,
            latency: LatencyModel::off(),
            admit: None,
            frontend_q: "fifo",
            compile_traces: false,
        }
    }

    /// A 12 GB hog running `work_us` + a 12 GB heavy arriving at `t_h`:
    /// on one 16 GB GPU the heavy can only run by evicting the hog.
    fn hog_and_heavy(work_hog_us: u64, work_heavy_us: u64, t_h: f64) -> Vec<JobSpec> {
        use crate::workloads::synthetic_job;
        vec![
            synthetic_job("light-hog", JobClass::Small, 12 << 30, work_hog_us, 0.0),
            synthetic_job("heavy-late", JobClass::Large, 12 << 30, work_heavy_us, t_h),
        ]
    }

    #[test]
    fn preempt_never_matches_disabled_exactly() {
        // The preemption plumbing enabled-but-declining must leave every
        // observable bit of the run identical to the disabled path (the
        // acceptance regression for "no-preemption is bit-identical").
        let mut jobs: Vec<JobSpec> =
            (0..6).map(|i| job(&format!("j{i}"), 12 << 30, 200, 3_000_000)).collect();
        for (i, j) in jobs.iter_mut().enumerate() {
            j.arrival = i as f64 * 0.5; // staggered, heavily contended
        }
        let a = run_cluster(contended_cluster_cfg(None), jobs.clone());
        let b = run_cluster(contended_cluster_cfg(Some(preempt_cfg("never"))), jobs);
        assert_eq!(a.preemptions, 0);
        assert_eq!(b.preemptions, 0);
        assert_eq!(a.wasted_work_s, 0.0);
        assert_eq!(b.wasted_work_s, 0.0);
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.started, y.started);
            assert_eq!(x.ended, y.ended);
            assert_eq!(x.crashed, y.crashed);
            assert_eq!(x.preemptions, 0);
            assert_eq!(y.preemptions, 0);
        }
    }

    #[test]
    fn preemption_reclaims_device_for_heavy_late_arrival() {
        // The ISSUE's pathology: a 100s light hog holds 12 GB; a 20s
        // heavy job arrives at t=5 and, without preemption, waits ~97s.
        let jobs = hog_and_heavy(100_000_000, 20_000_000, 5.0);
        let off = run_cluster(contended_cluster_cfg(None), jobs.clone());
        let on =
            run_cluster(contended_cluster_cfg(Some(preempt_cfg("min-progress"))), jobs);
        assert_eq!(off.completed(), 2);
        assert_eq!(on.completed(), 2);
        let heavy_off = off.mean_turnaround_of(JobClass::Large);
        let heavy_on = on.mean_turnaround_of(JobClass::Large);
        assert!(heavy_off > 100.0, "baseline heavy waits out the hog: {heavy_off}");
        assert!(heavy_on < 30.0, "preemption admits the heavy promptly: {heavy_on}");
        assert_eq!(on.preemptions, 1);
        // Wasted work = the hog's ~3.9s of killed kernel progress
        // (launched after its 12 GB transfer, evicted at t=5); overhead
        // = one checkpoint + one restore of a 12 GB image (~1.12s each).
        assert!(on.wasted_work_s > 3.5 && on.wasted_work_s < 4.5, "{}", on.wasted_work_s);
        assert!(
            on.ckpt_overhead_s > 2.0 && on.ckpt_overhead_s < 2.5,
            "{}",
            on.ckpt_overhead_s
        );
        let hog = &on.jobs[0];
        assert_eq!(hog.preemptions, 1);
        assert!(hog.wasted_s > 3.5);
        // The hog restarts after the heavy finishes and still completes;
        // it pays for the eviction with a longer turnaround.
        assert!(hog.ended > off.jobs[0].ended);
        assert!(on.makespan < 140.0, "{}", on.makespan);
    }

    #[test]
    fn victim_completing_at_the_blocked_instant_is_never_evicted() {
        // The heavy arrives at the exact instant the hog's kernel
        // completes. Since the max-mem wall-clock guard (bugfix sweep)
        // a zero-eta victim is spared at *selection* time — killing it
        // can only lose to waiting — so no checkpoint starts at all:
        // no eviction, no wasted work, timings identical to disabled.
        let xfer = (12u64 << 30) as f64 / crate::gpu::PCIE_BYTES_PER_SEC;
        let t_h = xfer + 10.0; // hog launches after its H2D, runs 10s
        let jobs = hog_and_heavy(10_000_000, 5_000_000, t_h);
        let off = run_cluster(contended_cluster_cfg(None), jobs.clone());
        let on = run_cluster(contended_cluster_cfg(Some(preempt_cfg("max-mem"))), jobs);
        assert_eq!(on.preemptions, 0, "nearly-finished victim spared, nothing counted");
        assert_eq!(on.wasted_work_s, 0.0);
        assert_eq!(on.completed(), 2);
        assert_eq!(on.makespan, off.makespan);
        for (x, y) in on.jobs.iter().zip(&off.jobs) {
            assert_eq!(x.started, y.started);
            assert_eq!(x.ended, y.ended);
        }
    }

    #[test]
    fn cascading_preemption_is_disallowed_by_default() {
        // H1 evicts the hog; after the hog restarts, H2 arrives. With
        // the default budget of one preemption per job the restarted hog
        // cannot be evicted again, so H2 waits out its full 200s run.
        let mut jobs = hog_and_heavy(200_000_000, 10_000_000, 5.0);
        jobs.push(crate::workloads::synthetic_job(
            "heavy-late-2",
            JobClass::Large,
            12 << 30,
            10_000_000,
            30.0,
        ));
        let once =
            run_cluster(contended_cluster_cfg(Some(preempt_cfg("min-progress"))), jobs.clone());
        assert_eq!(once.completed(), 3);
        assert_eq!(once.preemptions, 1, "budget 1: second eviction refused");
        let h2_once = once.jobs[2].turnaround();
        assert!(h2_once > 150.0, "H2 had to wait out the restarted hog: {h2_once}");
        // Raising the budget to 2 lets H2 evict the hog a second time.
        let cfg2 = PreemptConfig { max_preemptions: 2, ..preempt_cfg("min-progress") };
        let twice = run_cluster(contended_cluster_cfg(Some(cfg2)), jobs);
        assert_eq!(twice.completed(), 3);
        assert_eq!(twice.preemptions, 2);
        let h2_twice = twice.jobs[2].turnaround();
        assert!(h2_twice < 50.0, "H2 admitted promptly on the second eviction: {h2_twice}");
        assert!(twice.wasted_work_s > once.wasted_work_s);
    }

    #[test]
    fn sanitized_run_is_clean_and_matches_plain_run() {
        // The sanitizer is observational: armed, it must report zero
        // violations on a healthy engine and leave every observable
        // output identical to the unarmed run. Exercised on the
        // preemption scenario — eviction + restore is the hardest path
        // for the memory-conservation invariant (release + re-place).
        let jobs = hog_and_heavy(100_000_000, 20_000_000, 5.0);
        let cfg = contended_cluster_cfg(Some(preempt_cfg("min-progress")));
        let plain = run_cluster(cfg.clone(), jobs.clone());
        let (sanitized, report) = run_cluster_sanitized(cfg, jobs);
        assert!(report.is_clean(), "violations: {:?}", report.violations);
        assert!(report.events_checked > 0);
        assert_eq!(report.suppressed, 0);
        assert_eq!(plain.makespan, sanitized.makespan);
        assert_eq!(plain.preemptions, sanitized.preemptions);
        for (x, y) in plain.jobs.iter().zip(&sanitized.jobs) {
            assert_eq!(x.started, y.started);
            assert_eq!(x.ended, y.ended);
            assert_eq!(x.crashed, y.crashed);
        }
    }

    #[test]
    fn preemption_enabled_cluster_replay_is_deterministic() {
        // Two 1xV100 nodes under least-loaded dispatch, four 60s hogs
        // and six staggered heavies: preemptions fire on both nodes and
        // the whole run must replay bit-for-bit.
        let mut jobs: Vec<JobSpec> = Vec::new();
        for i in 0..4 {
            jobs.push(job(&format!("hog{i}"), 12 << 30, 100, 60_000_000));
        }
        for i in 0..6 {
            let mut h = job(&format!("heavy{i}"), 12 << 30, 100, 5_000_000);
            h.arrival = 3.0 + i as f64 * 1.5;
            jobs.push(h);
        }
        let cfg = ClusterConfig {
            cluster: ClusterSpec::homogeneous(v100x1(), 2),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: 4,
            dispatch: "least",
            preempt: Some(preempt_cfg("min-progress")),
            latency: LatencyModel::off(),
            admit: None,
            frontend_q: "fifo",
            compile_traces: false,
        };
        let a = run_cluster(cfg.clone(), jobs.clone());
        let b = run_cluster(cfg, jobs);
        assert!(a.preemptions > 0, "scenario must actually preempt");
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.wasted_work_s, b.wasted_work_s);
        assert_eq!(a.ckpt_overhead_s, b.ckpt_overhead_s);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.completed(), a.jobs.len(), "nobody is lost to eviction");
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.started, y.started);
            assert_eq!(x.ended, y.ended);
            assert_eq!(x.node, y.node);
            assert_eq!(x.preemptions, y.preemptions);
            assert_eq!(x.wasted_s, y.wasted_s);
        }
    }
}

//! Batch-run outcomes and the paper's macro-measures (§V-A): system
//! throughput, job turnaround, crash percentage, kernel slowdown —
//! plus the beyond-paper preemption measures (preemption count, wasted
//! work, checkpoint overhead) the `bench preempt` experiment reports,
//! the migration/SLO measures (migration count, shipped image bytes,
//! per-class SLO attainment) `bench migrate` reports, and the
//! overload-governance measures (rejections, degradations, goodput)
//! `bench overload` reports. Rejected jobs are terminal but distinct
//! from crashes: they never ran, so they are excluded from every
//! completion-derived measure (throughput/goodput, turnaround means,
//! SLO attainment denominators) rather than counted as zero-cost
//! successes.

use crate::sched::SloClass;

/// Workload class, for mix bookkeeping (large: >4 GB footprint).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobClass {
    Large,
    Small,
    Nn,
}

/// Per-job result.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub class: JobClass,
    /// SLO class the job carried, if any (`JobSpec::slo`).
    pub slo: Option<SloClass>,
    /// Queue-arrival time (0 for the paper's batch experiments).
    pub arrival: f64,
    /// Cluster node the dispatcher routed the job to (0 on one node).
    pub node: usize,
    /// Virtual time the job left the queue (a worker picked it up).
    pub started: f64,
    /// Virtual completion (or crash) time; jobs arrive at t = 0.
    pub ended: f64,
    pub crashed: bool,
    /// The frontend admission controller turned the job away at
    /// arrival (`--admit token|util` under pressure): terminal, with
    /// `ended == arrival`, but the job never ran — neither a completion
    /// nor a crash. Always false with admission off.
    pub rejected: bool,
    /// Sum of dedicated kernel durations on the assigned device type.
    pub kernel_dedicated_s: f64,
    /// Sum of actual (co-scheduled) kernel durations.
    pub kernel_actual_s: f64,
    pub n_kernels: u64,
    /// Times this job was checkpoint/restart-preempted (0 unless
    /// preemption is enabled).
    pub preemptions: u32,
    /// Dedicated-work seconds lost to killed in-flight kernels.
    pub wasted_s: f64,
}

impl JobOutcome {
    /// Interval between completion and queue arrival (arrival is t=0
    /// for the paper's batch experiments).
    pub fn turnaround(&self) -> f64 {
        self.ended - self.arrival
    }

    /// Per-job kernel slowdown fraction (0.01 == 1% slower than
    /// dedicated execution).
    pub fn kernel_slowdown(&self) -> f64 {
        if self.kernel_dedicated_s <= 0.0 {
            0.0
        } else {
            self.kernel_actual_s / self.kernel_dedicated_s - 1.0
        }
    }
}

/// Whole-batch result.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub scheduler: String,
    /// Node name (single node) or cluster name (multi-node runs).
    pub node: String,
    /// Total workers across the cluster.
    pub workers: usize,
    /// Cluster size (1 for the paper's single-node deployments).
    pub n_nodes: usize,
    /// Dispatcher that routed jobs to nodes ("rr" on a single node,
    /// where routing is trivial).
    pub dispatcher: String,
    pub jobs: Vec<JobOutcome>,
    /// Time the last job finished (the batch makespan).
    pub makespan: f64,
    /// Checkpoint/restart evictions performed (0 with preemption off).
    pub preemptions: u64,
    /// Dedicated-work seconds lost across all killed in-flight kernels.
    pub wasted_work_s: f64,
    /// Virtual seconds spent writing/restoring checkpoint images.
    pub ckpt_overhead_s: f64,
    /// Checkpointed victims restored on a node other than their home
    /// (0 unless `PreemptConfig::migrate = "cluster"`).
    pub migrations: u64,
    /// Checkpoint-image bytes those migrations shipped across nodes.
    pub migrate_bytes: u64,
    /// Arrivals the admission controller turned away (0 with `--admit
    /// off`).
    pub rejected: u64,
    /// Batch arrivals the admission controller demoted to best-effort
    /// under pressure (0 with `--admit off`).
    pub degraded: u64,
    /// Discrete events the run's event queue fired — the numerator of
    /// `bench scale`'s events/sec column (wall time is measured by the
    /// harness; the engine itself never reads a host clock).
    pub events_fired: u64,
    /// High-water mark of the event queue's length over the run (the
    /// peak-heap-size column of `bench scale`).
    pub peak_events: usize,
    /// Fired events on the *observable* subset (arrivals, probe and
    /// dispatch RPCs, preemption protocol, admission verdicts) —
    /// everything except the engine's own timers (`Wake`,
    /// `DevCompletion`, `MacroSegment`). Invariant across
    /// `--compile-traces` on/off by the compiled-replay contract, where
    /// `events_fired` deliberately is not.
    pub observable_events: u64,
}

impl RunResult {
    /// Jobs that actually finished their trace: neither crashed nor
    /// turned away by admission (a rejected job never ran — counting it
    /// here would let a shedding frontend inflate its own score).
    pub fn completed(&self) -> usize {
        self.jobs.iter().filter(|j| !j.crashed && !j.rejected).count()
    }

    pub fn crashed(&self) -> usize {
        self.jobs.iter().filter(|j| j.crashed).count()
    }

    pub fn crash_pct(&self) -> f64 {
        100.0 * self.crashed() as f64 / self.jobs.len().max(1) as f64
    }

    /// Fraction of arrivals the admission controller turned away.
    pub fn reject_rate(&self) -> f64 {
        self.rejected as f64 / self.jobs.len().max(1) as f64
    }

    /// Jobs completed per second of makespan — the figure the paper
    /// normalises against SA. Under admission control this is the
    /// *goodput*: rejected arrivals are offered load that was never
    /// served, so they count in the denominator of [`reject_rate`] but
    /// never in the numerator here.
    ///
    /// [`reject_rate`]: RunResult::reject_rate
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.completed() as f64 / self.makespan
        }
    }

    /// Jobs dispatched to each node (len == `n_nodes`).
    pub fn jobs_per_node(&self) -> Vec<usize> {
        let mut v = vec![0; self.n_nodes];
        for j in &self.jobs {
            if j.node < v.len() {
                v[j.node] += 1;
            }
        }
        v
    }

    /// Mean turnaround over *completed* jobs.
    pub fn mean_turnaround(&self) -> f64 {
        self.mean_turnaround_where(|_| true)
    }

    /// Mean turnaround over completed jobs of one class — how `bench
    /// preempt` separates the heavy late arrivals from the light hogs.
    pub fn mean_turnaround_of(&self, class: JobClass) -> f64 {
        self.mean_turnaround_where(|j| j.class == class)
    }

    /// Mean turnaround over completed jobs of one SLO class.
    pub fn mean_turnaround_of_slo(&self, class: SloClass) -> f64 {
        self.mean_turnaround_where(|j| j.slo == Some(class))
    }

    /// SLO attainment of one class: the fraction of its jobs that
    /// completed with turnaround within `SloClass::stretch_bound()`
    /// times their dedicated kernel seconds (crashed jobs count as
    /// missed; jobs that ran no kernel only attain the unbounded
    /// best-effort class). Admission-rejected jobs are excluded from
    /// the denominator entirely: they were shed, not served — without
    /// the exclusion a rejected best-effort job would "attain" its
    /// unbounded SLO with zero turnaround. `None` when no admitted job
    /// carries the class, so a classless run prints nothing rather
    /// than a vacuous 100%.
    pub fn slo_attainment(&self, class: SloClass) -> Option<f64> {
        let (mut n, mut met) = (0u32, 0u32);
        for j in self.jobs.iter().filter(|j| j.slo == Some(class) && !j.rejected) {
            n += 1;
            let bound = class.stretch_bound() * j.kernel_dedicated_s.max(1e-9);
            if !j.crashed && j.turnaround() <= bound {
                met += 1;
            }
        }
        if n == 0 {
            None
        } else {
            Some(met as f64 / n as f64)
        }
    }

    /// Mean turnaround over completed jobs matching `keep`; 0.0 when
    /// none match (the shared crash-filter/empty-set convention).
    /// Rejected jobs never completed, so they are excluded like
    /// crashes — their zero "turnaround" would otherwise drag the mean
    /// toward whatever the frontend shed.
    fn mean_turnaround_where(&self, keep: impl Fn(&JobOutcome) -> bool) -> f64 {
        let (mut sum, mut n) = (0.0, 0u32);
        for j in self.jobs.iter().filter(|&j| !j.crashed && !j.rejected && keep(j)) {
            sum += j.turnaround();
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Kernel slowdown (%) vs dedicated execution, weighted by each
    /// job's dedicated kernel time (macro-measure of Table IV).
    pub fn kernel_slowdown_pct(&self) -> f64 {
        let (mut ded, mut act) = (0.0, 0.0);
        for j in self.jobs.iter().filter(|j| !j.crashed) {
            ded += j.kernel_dedicated_s;
            act += j.kernel_actual_s;
        }
        if ded <= 0.0 {
            0.0
        } else {
            100.0 * (act / ded - 1.0)
        }
    }

    /// Worst per-job kernel slowdown (%) over completed jobs — the
    /// paper's "individual kernel performance degradation at most
    /// 2.5%" claim as a measured tail statistic rather than the
    /// time-weighted mean [`RunResult::kernel_slowdown_pct`] reports.
    /// 0.0 when no job completed (the empty-set convention).
    pub fn worst_kernel_slowdown_pct(&self) -> f64 {
        self.jobs
            .iter()
            .filter(|j| !j.crashed)
            .map(|j| 100.0 * j.kernel_slowdown())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(ended: f64, crashed: bool, ded: f64, act: f64) -> JobOutcome {
        JobOutcome {
            name: "j".into(),
            class: JobClass::Small,
            slo: None,
            arrival: 0.0,
            node: 0,
            started: 0.0,
            ended,
            crashed,
            rejected: false,
            kernel_dedicated_s: ded,
            kernel_actual_s: act,
            n_kernels: 1,
            preemptions: 0,
            wasted_s: 0.0,
        }
    }

    fn rr(jobs: Vec<JobOutcome>, makespan: f64) -> RunResult {
        RunResult {
            scheduler: "t".into(),
            node: "n".into(),
            workers: 1,
            n_nodes: 1,
            dispatcher: "rr".into(),
            jobs,
            makespan,
            preemptions: 0,
            wasted_work_s: 0.0,
            ckpt_overhead_s: 0.0,
            migrations: 0,
            migrate_bytes: 0,
            rejected: 0,
            degraded: 0,
            events_fired: 0,
            peak_events: 0,
            observable_events: 0,
        }
    }

    /// A rejected-at-the-door outcome: ended == arrival, never ran.
    fn rejected_job() -> JobOutcome {
        JobOutcome { rejected: true, n_kernels: 0, ..job(0.0, false, 0.0, 0.0) }
    }

    #[test]
    fn throughput_excludes_crashes() {
        let r = rr(vec![job(10.0, false, 1.0, 1.0), job(5.0, true, 1.0, 1.0)], 10.0);
        assert_eq!(r.completed(), 1);
        assert!((r.throughput() - 0.1).abs() < 1e-12);
        assert!((r.crash_pct() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn slowdown_is_time_weighted() {
        let r = rr(
            vec![job(1.0, false, 10.0, 11.0), job(1.0, false, 1.0, 1.0)],
            1.0,
        );
        // (12 / 11 - 1) ≈ 9.09%
        assert!((r.kernel_slowdown_pct() - 100.0 * (12.0 / 11.0 - 1.0)).abs() < 1e-9);
    }

    #[test]
    fn worst_slowdown_is_the_uncrashed_tail_not_the_mean() {
        let r = rr(
            vec![
                job(1.0, false, 10.0, 10.5), // 5%
                job(1.0, false, 1.0, 1.2),   // 20% — the tail
                job(1.0, true, 1.0, 9.0),    // crashed: excluded
                job(1.0, false, 0.0, 0.0),   // no kernels: 0%
            ],
            1.0,
        );
        assert!((r.worst_kernel_slowdown_pct() - 20.0).abs() < 1e-9);
        // Empty set (all crashed) reports 0, like the other measures.
        let r = rr(vec![job(1.0, true, 1.0, 2.0)], 1.0);
        assert_eq!(r.worst_kernel_slowdown_pct(), 0.0);
    }

    #[test]
    fn jobs_per_node_counts_dispatch() {
        let mut a = job(1.0, false, 0.0, 0.0);
        let mut b = job(2.0, false, 0.0, 0.0);
        let c = job(3.0, false, 0.0, 0.0);
        a.node = 1;
        b.node = 1;
        let mut r = rr(vec![a, b, c], 3.0);
        r.n_nodes = 2;
        assert_eq!(r.jobs_per_node(), vec![1, 2]);
    }

    #[test]
    fn turnaround_mean_over_completed() {
        let r = rr(vec![job(4.0, false, 0.0, 0.0), job(8.0, false, 0.0, 0.0)], 8.0);
        assert!((r.mean_turnaround() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn slo_attainment_applies_the_stretch_bound_per_class() {
        // Latency-sensitive bound is 4x dedicated seconds: a 10 s job
        // finishing at 30 s attains (stretch 3), at 50 s it misses.
        let mut met = job(30.0, false, 10.0, 10.0);
        met.slo = Some(SloClass::LatencySensitive);
        let mut missed = job(50.0, false, 10.0, 10.0);
        missed.slo = Some(SloClass::LatencySensitive);
        // Crashes count as missed whatever the timing...
        let mut crashed = job(1.0, true, 10.0, 10.0);
        crashed.slo = Some(SloClass::LatencySensitive);
        // ...while best-effort attains by completing at all.
        let mut be = job(10_000.0, false, 1.0, 1.0);
        be.slo = Some(SloClass::BestEffort);
        let unclassed = job(10.0, false, 1.0, 1.0);
        let r = rr(vec![met, missed, crashed, be, unclassed], 10_000.0);
        let a = r.slo_attainment(SloClass::LatencySensitive).expect("class present");
        assert!((a - 1.0 / 3.0).abs() < 1e-12, "1 of 3 attained: {a}");
        assert_eq!(r.slo_attainment(SloClass::BestEffort), Some(1.0));
        assert_eq!(r.slo_attainment(SloClass::Batch), None, "empty class -> None");
        // Per-SLO-class turnaround means filter like the JobClass ones.
        assert!((r.mean_turnaround_of_slo(SloClass::LatencySensitive) - 40.0).abs() < 1e-12);
        assert_eq!(r.mean_turnaround_of_slo(SloClass::Batch), 0.0);
    }

    #[test]
    fn rejected_jobs_are_neither_completions_nor_crashes() {
        // A shed arrival must not inflate goodput (its zero-cost
        // "completion"), drag turnaround means toward zero, or attain
        // its SLO with zero turnaround.
        let mut shed = rejected_job();
        shed.slo = Some(SloClass::BestEffort);
        let mut served = job(10.0, false, 1.0, 1.0);
        served.slo = Some(SloClass::BestEffort);
        let mut r = rr(vec![served, shed], 10.0);
        r.rejected = 1;
        assert_eq!(r.completed(), 1, "rejected is not completed");
        assert_eq!(r.crashed(), 0, "rejected is not crashed");
        assert!((r.throughput() - 0.1).abs() < 1e-12, "goodput counts served jobs only");
        assert!((r.reject_rate() - 0.5).abs() < 1e-12);
        assert!((r.mean_turnaround() - 10.0).abs() < 1e-12, "shed job excluded from the mean");
        assert_eq!(
            r.slo_attainment(SloClass::BestEffort),
            Some(1.0),
            "shed job excluded from the attainment denominator"
        );
        // A class whose every member was shed reports None, not 100%.
        let mut only_shed = rejected_job();
        only_shed.slo = Some(SloClass::Batch);
        let r = rr(vec![only_shed], 0.0);
        assert_eq!(r.slo_attainment(SloClass::Batch), None);
        assert_eq!(r.reject_rate(), 0.0, "counter not set -> rate 0");
    }

    #[test]
    fn per_class_turnaround_filters_crashes_and_classes() {
        let mut heavy = job(30.0, false, 0.0, 0.0);
        heavy.class = JobClass::Large;
        let mut crashed_heavy = job(2.0, true, 0.0, 0.0);
        crashed_heavy.class = JobClass::Large;
        let light = job(10.0, false, 0.0, 0.0); // Small
        let r = rr(vec![heavy, crashed_heavy, light], 30.0);
        assert!((r.mean_turnaround_of(JobClass::Large) - 30.0).abs() < 1e-12);
        assert!((r.mean_turnaround_of(JobClass::Small) - 10.0).abs() < 1e-12);
        assert_eq!(r.mean_turnaround_of(JobClass::Nn), 0.0, "empty class -> 0");
    }
}

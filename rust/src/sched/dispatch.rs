//! Cluster-level dispatch (beyond the paper's single node): route each
//! arriving job to a node; the per-node [`Policy`](super::Policy) then
//! places its tasks onto devices beneath the dispatcher.
//!
//! Dispatchers see only aggregate per-node load ([`NodeLoadView`]) and
//! a cheap estimate of the arriving job ([`JobInfo`]) — mirroring a
//! real cluster frontend, which knows queue depths and advertised
//! capacity but not the future. Under a nonzero `gpu::LatencyModel`
//! the view is additionally *stale*: it is snapshotted at probe time
//! ([`NodeLoadView::taken_at`]) while the job lands a round-trip plus
//! dispatch cost later, so decisions can differ from what an
//! instant-landing frontend would choose (by design — see the
//! stale-routing tests). [`LatencyAware`] is the dispatcher that
//! *prices* that staleness machinery instead of ignoring it, trading
//! each node's backlog against the job's landing delay there. All four
//! built-ins are deterministic (ties break toward the lower node
//! index) so batch runs replay exactly.
//!
//! Paper map: entirely beyond the paper, whose deployments are single
//! node (§V-A); this is the frontend a production cluster puts above N
//! instances of the paper's per-node scheduler. On heterogeneous
//! clusters, [`LeastLoaded`] normalises outstanding work by each node's
//! compute capability (ROADMAP "Heterogeneous-cluster dispatch") —
//! homogeneous clusters keep the original integer comparison and so
//! replay pre-existing runs exactly.
//!
//! Arriving jobs are not the only traffic: with cluster-wide
//! checkpoint migration on (`sched::PreemptConfig::migrate`), an
//! evicted victim's *restore job* re-enters this layer and is routed
//! by the same `route` call on a live snapshot — which is how victim
//! restore inherits every dispatcher here, including the
//! latency-aware scorer and the re-probe staleness guard.
//!
//! With interference modeling on (nonzero workload pressure vectors),
//! [`Partition`] is the alternative frontend from the
//! partition-then-allocate literature: the engine slices every device
//! into static MIG-style partitions
//! (`coordinator::placement::PARTITION_SLICES`) and this dispatcher
//! does contention-aware job-to-partition-group allocation, steering
//! each arriving job to the node whose aggregate pressure its own
//! vector worsens least. Isolation caps worst-case degradation at the
//! price of peak throughput — the trade the interference bench
//! measures.

use crate::gpu::InterferenceProfile;

/// Aggregate load of one node at dispatch time.
#[derive(Clone, Copy, Debug)]
pub struct NodeLoadView {
    /// Jobs dispatched to the node and still waiting for a worker.
    pub queued_jobs: usize,
    /// Estimated kernel + host microseconds of every job dispatched to
    /// the node and not yet finished.
    pub outstanding_work_us: u64,
    /// Estimated peak reserved bytes of every dispatched-but-unfinished
    /// job (dispatcher-level bookkeeping, not live device state).
    pub outstanding_mem_bytes: u64,
    /// Current free device memory summed over the node's GPUs.
    pub free_mem: u64,
    /// Total device memory summed over the node's GPUs.
    pub total_mem: u64,
    pub n_gpus: usize,
    /// Relative compute capability (sum of GPU speeds, V100 == 1.0; see
    /// `NodeSpec::compute_capacity`). Least-loaded divides outstanding
    /// work by this so a P100 node is not handed a V100 node's share.
    pub compute_capacity: f64,
    /// Virtual time this snapshot was taken — the *probe* time. Under a
    /// nonzero `gpu::LatencyModel` the routed job only lands on the node
    /// `probe RTT + dispatch cost` later, so every decision is made on
    /// load that is stale by exactly that interval (the engine never
    /// re-snapshots at landing time). 0.0 for batch dispatch at t = 0.
    pub taken_at: f64,
    /// Modeled probe round-trip to this node
    /// (`gpu::LatencyModel::probe_rtt`; 0 with the model off). Exposed
    /// so a latency-aware dispatcher can trade load against distance.
    pub probe_rtt_s: f64,
    /// Modeled cost of shipping *this* job to the node
    /// (`gpu::LatencyModel::dispatch_latency` of the job's payload; 0
    /// with the model off). Together with `probe_rtt_s` this is the
    /// job's landing delay were it routed here.
    pub dispatch_cost_s: f64,
    /// Summed interference profiles of every job dispatched to the
    /// node and not yet finished (dispatcher-level bookkeeping like
    /// `outstanding_work_us`, not live device state). All-zero when no
    /// outstanding job carries a pressure vector.
    pub pressure: InterferenceProfile,
}

/// What the dispatcher may know about the arriving job.
#[derive(Clone, Copy, Debug)]
pub struct JobInfo {
    /// Estimated kernel + host microseconds (from the compiled trace).
    pub est_work_us: u64,
    /// Estimated peak simultaneous reservation, bytes.
    pub peak_mem_bytes: u64,
    /// Componentwise-max interference profile over the job's task
    /// probes (`JobTrace::peak_interference`). All-zero for legacy
    /// workloads.
    pub iv: InterferenceProfile,
}

/// A cluster-level job router. Stateful (round-robin keeps a cursor);
/// one instance lives for the whole batch run.
pub trait Dispatcher: Send {
    fn name(&self) -> &'static str;

    /// Pick the node for an arriving job. `nodes` is never empty.
    fn route(&mut self, job: &JobInfo, nodes: &[NodeLoadView]) -> usize;

    /// Whether `route` decides from the load snapshot. The timeout +
    /// re-probe guard only arms over load-based dispatchers: a
    /// load-oblivious decision cannot go *stale*, and re-asking a
    /// stateful router (round-robin's cursor has moved on) would
    /// misread the fresh answer as a redirect on every firing —
    /// restarting the journey each time and skewing the cursor —
    /// when nothing about the cluster changed.
    fn load_based(&self) -> bool {
        true
    }
}

/// Ignore load entirely; cycle through the nodes.
#[derive(Debug, Default)]
pub struct RoundRobin {
    /// Invariant: kept reduced modulo the cluster size after every
    /// route. Incrementing a raw counter instead and reducing only at
    /// use would skip nodes after `usize` wraparound on clusters whose
    /// size does not divide 2^64 (`MAX % n` then `0 % n` repeats a
    /// node), silently breaking the fairness cycle.
    next: usize,
}

impl Dispatcher for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, _job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let n = self.next % nodes.len();
        self.next = (n + 1) % nodes.len();
        n
    }

    /// Round-robin never reads the snapshot: its decisions cannot go
    /// stale, so the re-probe guard must not re-ask it (the advanced
    /// cursor would fake a redirect every time).
    fn load_based(&self) -> bool {
        false
    }
}

/// Least outstanding estimated work, normalised by node compute
/// capability on heterogeneous clusters (a P100 node drains its queue
/// ~2.9x slower than a 4×V100 node, so equal raw microseconds are not
/// equal load); ties broken by queue depth, then node index. When all
/// capabilities are equal the raw integer comparison is used, keeping
/// homogeneous runs bit-identical to the pre-normalisation dispatcher.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Dispatcher for LeastLoaded {
    fn name(&self) -> &'static str {
        "least"
    }

    fn route(&mut self, _job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let homogeneous =
            nodes.windows(2).all(|w| w[0].compute_capacity == w[1].compute_capacity);
        let norm = |v: &NodeLoadView| {
            v.outstanding_work_us as f64 / v.compute_capacity.max(f64::MIN_POSITIVE)
        };
        let mut best = 0;
        for (i, v) in nodes.iter().enumerate().skip(1) {
            let b = &nodes[best];
            let better = if homogeneous {
                (v.outstanding_work_us, v.queued_jobs) < (b.outstanding_work_us, b.queued_jobs)
            } else {
                norm(v) < norm(b) || (norm(v) == norm(b) && v.queued_jobs < b.queued_jobs)
            };
            if better {
                best = i;
            }
        }
        best
    }
}

/// Largest memory headroom: total capacity minus the estimated peak
/// memory of dispatched-but-unfinished jobs. Sends memory-hungry
/// streams where they are least likely to wait on reservations.
///
/// The arriving job's own peak matters: on a heterogeneous cluster the
/// max-headroom node can be one whose total capacity the job's peak
/// *exceeds* — routed there it can never start, while a bigger (if
/// currently busier) node could hold it. Nodes rank lexicographically:
/// can the node *ever* hold [`JobInfo::peak_mem_bytes`]
/// (`total_mem >= peak`), does its current headroom cover the peak
/// *now* (no waiting), then raw headroom; ties keep the lower index.
/// For jobs no node can ever hold, this degrades to plain max headroom
/// (the old rule) and the engine's drain fallback reports the crash.
#[derive(Debug, Default)]
pub struct MemHeadroom;

impl Dispatcher for MemHeadroom {
    fn name(&self) -> &'static str {
        "mem"
    }

    fn route(&mut self, job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let headroom =
            |v: &NodeLoadView| v.total_mem.saturating_sub(v.outstanding_mem_bytes);
        let rank = |v: &NodeLoadView| {
            (
                v.total_mem >= job.peak_mem_bytes,
                headroom(v) >= job.peak_mem_bytes,
                headroom(v),
            )
        };
        let mut best = 0;
        for (i, v) in nodes.iter().enumerate().skip(1) {
            if rank(v) > rank(&nodes[best]) {
                best = i;
            }
        }
        best
    }
}

/// Latency-aware routing: minimise the job's *estimated completion
/// start*, not just the queue it joins. Each node is scored in
/// capability-normalised microseconds as
///
/// ```text
/// eta(node) = (probe_rtt_s + dispatch_cost_s) * 1e6        // landing delay
///           + (outstanding_work_us + est_work_us) / capacity
/// ```
///
/// so a distant idle node can lose to a near busy one exactly when its
/// extra round-trip + dispatch cost outweighs the near node's backlog.
/// [`JobInfo::est_work_us`] decides when distance matters: a long job's
/// own work term dominates the delay term (route by load/capability —
/// the delay is amortised), while for a short job the landing delay is
/// the bulk of its turnaround (route near). Ties break by queue depth,
/// then node index, like [`LeastLoaded`].
///
/// When every node's landing delay is zero (the latency model off, or
/// an all-zero row) the score degenerates to a constant shift of
/// least-loaded's, so the dispatcher *delegates* to [`LeastLoaded`] —
/// guaranteeing identical rankings, including the homogeneous
/// integer-comparison path (locked by tests).
#[derive(Debug, Default)]
pub struct LatencyAware {
    inner: LeastLoaded,
}

impl Dispatcher for LatencyAware {
    fn name(&self) -> &'static str {
        "latency"
    }

    fn route(&mut self, job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let delay = |v: &NodeLoadView| v.probe_rtt_s + v.dispatch_cost_s;
        if nodes.iter().all(|v| delay(v) == 0.0) {
            return self.inner.route(job, nodes);
        }
        let eta_us = |v: &NodeLoadView| {
            delay(v) * 1e6
                + (v.outstanding_work_us + job.est_work_us) as f64
                    / v.compute_capacity.max(f64::MIN_POSITIVE)
        };
        let mut best = 0;
        for (i, v) in nodes.iter().enumerate().skip(1) {
            let b = &nodes[best];
            let (ev, eb) = (eta_us(v), eta_us(b));
            if ev < eb || (ev == eb && v.queued_jobs < b.queued_jobs) {
                best = i;
            }
        }
        best
    }
}

/// Contention-aware allocation over statically partitioned devices —
/// the dispatch half of partition-then-allocate (the engine's slicing
/// of each device into `PARTITION_SLICES` isolation domains is the
/// other half, keyed off this dispatcher's canonical name).
///
/// Routing minimises the *post-placement* pressure hot-spot: the node
/// whose per-GPU-slice aggregate pressure, after adding the arriving
/// job's vector, has the smallest dominant component. Jobs thus spread
/// by the resource they actually contend on — two memory-bandwidth
/// hogs land on different nodes even when work-wise both fit on one —
/// which is what bounds worst-case per-kernel degradation. Ties break
/// by capability-normalised outstanding work, then queue depth, then
/// node index, so pressure-equal clusters degrade to sensible
/// load balancing.
///
/// With interference modeling off (the arriving job and every node
/// all-zero) there is no pressure signal at all; the dispatcher
/// delegates to [`LeastLoaded`], mirroring [`LatencyAware`]'s
/// zero-delay delegation (and locked by the same style of test).
#[derive(Debug, Default)]
pub struct Partition {
    inner: LeastLoaded,
}

impl Dispatcher for Partition {
    fn name(&self) -> &'static str {
        "partition"
    }

    fn route(&mut self, job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        if job.iv.is_zero() && nodes.iter().all(|v| v.pressure.is_zero()) {
            return self.inner.route(job, nodes);
        }
        // Dominant per-slice pressure component if the job landed here.
        let hot = |v: &NodeLoadView| {
            v.pressure.add(&job.iv).max_component() / (v.n_gpus as f64).max(1.0)
        };
        let work = |v: &NodeLoadView| {
            v.outstanding_work_us as f64 / v.compute_capacity.max(f64::MIN_POSITIVE)
        };
        let mut best = 0;
        for (i, v) in nodes.iter().enumerate().skip(1) {
            let b = &nodes[best];
            let better = hot(v) < hot(b)
                || (hot(v) == hot(b)
                    && (work(v) < work(b)
                        || (work(v) == work(b) && v.queued_jobs < b.queued_jobs)));
            if better {
                best = i;
            }
        }
        best
    }
}

/// Canonical short name for a dispatcher alias, or `None` if the name
/// is not recognised. The single alias table shared by the CLI parser
/// and [`make_dispatcher`].
pub fn canonical_dispatch(name: &str) -> Option<&'static str> {
    match name {
        "rr" | "round-robin" => Some("rr"),
        "least" | "least-loaded" => Some("least"),
        "mem" | "headroom" => Some("mem"),
        "latency" | "latency-aware" => Some("latency"),
        "partition" | "mig" => Some("partition"),
        _ => None,
    }
}

/// Construct a dispatcher by name:
/// "rr" | "least" | "mem" | "latency" | "partition".
pub fn make_dispatcher(name: &str) -> Box<dyn Dispatcher> {
    match canonical_dispatch(name) {
        Some("rr") => Box::new(RoundRobin::default()),
        Some("least") => Box::new(LeastLoaded),
        Some("mem") => Box::new(MemHeadroom),
        Some("latency") => Box::new(LatencyAware::default()),
        Some("partition") => Box::new(Partition::default()),
        _ => panic!("unknown dispatcher '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(outstanding_work_us: u64, queued: usize, outstanding_mem: u64) -> NodeLoadView {
        NodeLoadView {
            queued_jobs: queued,
            outstanding_work_us,
            outstanding_mem_bytes: outstanding_mem,
            free_mem: 64 << 30,
            total_mem: 64 << 30,
            n_gpus: 4,
            compute_capacity: 4.0,
            taken_at: 0.0,
            probe_rtt_s: 0.0,
            dispatch_cost_s: 0.0,
            pressure: InterferenceProfile::ZERO,
        }
    }

    fn hot_view(outstanding_work_us: u64, pressure: InterferenceProfile) -> NodeLoadView {
        NodeLoadView { pressure, ..view(outstanding_work_us, 0, 0) }
    }

    fn lat_view(outstanding_work_us: u64, rtt_s: f64, dispatch_s: f64) -> NodeLoadView {
        NodeLoadView {
            probe_rtt_s: rtt_s,
            dispatch_cost_s: dispatch_s,
            ..view(outstanding_work_us, 0, 0)
        }
    }

    fn het_view(outstanding_work_us: u64, compute_capacity: f64) -> NodeLoadView {
        NodeLoadView { compute_capacity, ..view(outstanding_work_us, 0, 0) }
    }

    fn job() -> JobInfo {
        JobInfo { est_work_us: 1_000_000, peak_mem_bytes: 1 << 30, iv: InterferenceProfile::ZERO }
    }

    fn hot_job(mem_bw: f64, l2: f64, sm: f64) -> JobInfo {
        JobInfo { iv: InterferenceProfile::new(mem_bw, l2, sm), ..job() }
    }

    #[test]
    fn round_robin_cycles() {
        let mut d = make_dispatcher("rr");
        let nodes = vec![view(0, 0, 0); 3];
        let picks: Vec<usize> = (0..6).map(|_| d.route(&job(), &nodes)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_cursor_survives_wraparound() {
        // The old raw counter skewed after usize wraparound: for a
        // 3-node cluster, MAX % 3 == 0 and the wrapped counter restarts
        // at 0 % 3 == 0, visiting node 0 twice and starving the cycle.
        // The reduced cursor can never reach the wraparound region.
        let mut d = RoundRobin { next: usize::MAX };
        let nodes = vec![view(0, 0, 0); 3];
        let picks: Vec<usize> = (0..4).map(|_| d.route(&job(), &nodes)).collect();
        assert_eq!(picks, vec![usize::MAX % 3, 1, 2, 0], "no node repeated");
        assert!(d.next < 3, "cursor stays reduced modulo the cluster size");
        // And it stays reduced from then on, whatever the history.
        for _ in 0..10 {
            d.route(&job(), &nodes);
            assert!(d.next < 3);
        }
    }

    #[test]
    fn least_loaded_picks_min_outstanding_work() {
        let mut d = make_dispatcher("least");
        let nodes = vec![view(30, 1, 0), view(10, 5, 0), view(20, 0, 0)];
        assert_eq!(d.route(&job(), &nodes), 1);
        // Equal work: fewer queued jobs wins, then lower index.
        let nodes = vec![view(10, 3, 0), view(10, 1, 0), view(10, 1, 0)];
        assert_eq!(d.route(&job(), &nodes), 1);
    }

    #[test]
    fn least_loaded_normalises_by_compute_capability() {
        let mut d = make_dispatcher("least");
        // Equal raw outstanding work on a 2xP100 (1.4) and a 4xV100
        // (4.0) node: per-capability load is 714ms vs 250ms, so the
        // V100 node is the genuinely less-loaded one.
        let p100 = 2.0 * (3584.0 / 5120.0);
        let nodes = vec![het_view(1_000_000, p100), het_view(1_000_000, 4.0)];
        assert_eq!(d.route(&job(), &nodes), 1);
        // But the slow node wins when its raw backlog is small enough:
        // 300ms/1.4 = 214ms < 1s/4 = 250ms.
        let nodes = vec![het_view(300_000, p100), het_view(1_000_000, 4.0)];
        assert_eq!(d.route(&job(), &nodes), 0);
        // Homogeneous capacities keep the original integer comparison.
        let nodes = vec![het_view(10, 4.0), het_view(9, 4.0)];
        assert_eq!(d.route(&job(), &nodes), 1);
    }

    #[test]
    fn mem_headroom_picks_max_capacity_minus_outstanding() {
        let mut d = make_dispatcher("mem");
        let nodes = vec![view(0, 0, 60 << 30), view(0, 0, 8 << 30), view(0, 0, 8 << 30)];
        assert_eq!(d.route(&job(), &nodes), 1, "lower index wins ties");
        // Outstanding beyond capacity saturates to zero headroom.
        let nodes = vec![view(0, 0, 100 << 30), view(0, 0, 63 << 30)];
        assert_eq!(d.route(&job(), &nodes), 1);
    }

    /// A view with explicit node capacity (heterogeneous clusters).
    fn cap_view(total_mem: u64, outstanding_mem: u64) -> NodeLoadView {
        NodeLoadView { total_mem, free_mem: total_mem, ..view(0, 0, outstanding_mem) }
    }

    #[test]
    fn mem_headroom_avoids_a_node_the_job_can_never_fit_on() {
        let mut d = make_dispatcher("mem");
        // Node 0: 16 GB total, idle -> 16 GB headroom (the max). Node 1:
        // 64 GB total, 52 GB outstanding -> 12 GB headroom. A 24 GB-peak
        // job can NEVER start on node 0; the old peak-blind rule routed
        // it there anyway, where it sat forever. Node 1 holds it once
        // its backlog drains.
        let big = JobInfo { peak_mem_bytes: 24 << 30, ..job() };
        let nodes = vec![cap_view(16 << 30, 0), cap_view(64 << 30, 52 << 30)];
        assert_eq!(d.route(&big, &nodes), 1, "capacity that can hold the peak wins");
        // Between two nodes that could both hold the peak eventually,
        // the one whose headroom covers it now (necessarily the larger
        // headroom) wins: the job starts without waiting.
        let nodes = vec![cap_view(64 << 30, 38 << 30), cap_view(32 << 30, 10 << 30)];
        assert_eq!(d.route(&big, &nodes), 0, "26 GB free now beats 22 GB that waits");
        // Among nodes that all cover the peak now, max headroom (then
        // lower index) still decides — the pre-fix behaviour.
        let nodes = vec![view(0, 0, 50 << 30), view(0, 0, 40 << 30), view(0, 0, 40 << 30)];
        let small = job();
        assert_eq!(d.route(&small, &nodes), 1);
    }

    #[test]
    fn mem_headroom_falls_back_to_max_headroom_when_nothing_can_hold_the_job() {
        let mut d = make_dispatcher("mem");
        // A 100 GB peak fits nowhere: degrade to the old max-headroom
        // rule (node 1 at 24 GB) and let the engine report the crash.
        let huge = JobInfo { peak_mem_bytes: 100 << 30, ..job() };
        let nodes = vec![cap_view(64 << 30, 60 << 30), cap_view(64 << 30, 40 << 30)];
        assert_eq!(d.route(&huge, &nodes), 1);
    }

    #[test]
    fn latency_aware_trades_load_against_distance() {
        let mut d = make_dispatcher("latency");
        // Node 0: busy (2 s of work on capacity 4 -> 0.5 s drain) but
        // near (free RPCs). Node 1: idle but 0.8 s away round-trip +
        // dispatch. The distant idle node LOSES: landing there costs
        // more than waiting out the near backlog.
        let nodes = vec![lat_view(2_000_000, 0.0, 0.0), lat_view(0, 0.5, 0.3)];
        assert_eq!(d.route(&job(), &nodes), 0, "near busy beats distant idle");
        // Grow the near backlog past the distance and the idle node
        // wins: 4 s of work (1 s drain) > 0.8 s of delay.
        let nodes = vec![lat_view(4_000_000, 0.0, 0.0), lat_view(0, 0.5, 0.3)];
        assert_eq!(d.route(&job(), &nodes), 1, "backlog now outweighs the distance");
    }

    #[test]
    fn latency_aware_amortises_distance_over_long_jobs() {
        let mut d = make_dispatcher("latency");
        // Heterogeneous: node 0 near but slow (capacity 1.4), node 1
        // 0.5 s away but fast (4.0), both idle. A short job routes near
        // (the RTT dominates its turnaround); a long job routes to the
        // fast distant node (its own work term dwarfs the delay).
        let p100 = 2.0 * (3584.0 / 5120.0);
        let near_slow = NodeLoadView { compute_capacity: p100, ..lat_view(0, 0.0, 0.0) };
        let far_fast = lat_view(0, 0.3, 0.2);
        let short = JobInfo { est_work_us: 100_000, ..job() };
        let long = JobInfo { est_work_us: 20_000_000, ..job() };
        // short: 0.1s/1.4 = 71 ms near vs 0.5 s + 25 ms far -> near.
        assert_eq!(d.route(&short, &[near_slow, far_fast]), 0);
        // long: 20s/1.4 = 14.3 s near vs 0.5 s + 5 s far -> far.
        assert_eq!(d.route(&long, &[near_slow, far_fast]), 1);
    }

    #[test]
    fn latency_aware_at_zero_delay_ranks_exactly_like_least_loaded() {
        // The satellite acceptance: with every landing delay zero the
        // dispatcher must delegate to least-loaded — same picks on the
        // homogeneous integer path, the heterogeneous normalised path,
        // and every tie-break.
        let cases: Vec<Vec<NodeLoadView>> = vec![
            vec![view(30, 1, 0), view(10, 5, 0), view(20, 0, 0)],
            vec![view(10, 3, 0), view(10, 1, 0), view(10, 1, 0)],
            vec![het_view(1_000_000, 1.4), het_view(1_000_000, 4.0)],
            vec![het_view(300_000, 1.4), het_view(1_000_000, 4.0)],
            vec![het_view(10, 4.0), het_view(9, 4.0)],
        ];
        let mut la = make_dispatcher("latency");
        let mut ll = make_dispatcher("least");
        for nodes in &cases {
            assert_eq!(la.route(&job(), nodes), ll.route(&job(), nodes));
        }
    }

    #[test]
    fn partition_spreads_by_dominant_pressure_component() {
        let mut d = make_dispatcher("partition");
        // Node 0 is memory-bandwidth hot, node 1 SM hot. A bandwidth
        // hog routes to the SM-hot node (its own dominant resource is
        // the one it avoids stacking), even though node 1 has MORE
        // outstanding work.
        let nodes = vec![
            hot_view(0, InterferenceProfile::new(0.9, 0.1, 0.1)),
            hot_view(5_000_000, InterferenceProfile::new(0.1, 0.1, 0.9)),
        ];
        assert_eq!(d.route(&hot_job(0.8, 0.1, 0.1), &nodes), 1);
        // And an SM hog makes the opposite choice on the same cluster.
        assert_eq!(d.route(&hot_job(0.1, 0.1, 0.8), &nodes), 0);
    }

    #[test]
    fn partition_normalises_pressure_by_slice_count() {
        let mut d = make_dispatcher("partition");
        // Same aggregate pressure, but node 1 has twice the GPU slices
        // to dilute it over: it is the cooler hot-spot.
        let hot = InterferenceProfile::new(0.8, 0.2, 0.2);
        let mut small = hot_view(0, hot);
        small.n_gpus = 4;
        let mut big = hot_view(0, hot);
        big.n_gpus = 8;
        assert_eq!(d.route(&hot_job(0.5, 0.1, 0.1), &[small, big]), 1);
    }

    #[test]
    fn partition_ties_break_by_normalised_work_then_queue() {
        let mut d = make_dispatcher("partition");
        let hot = InterferenceProfile::new(0.4, 0.4, 0.4);
        // Equal pressure everywhere: less outstanding work wins.
        let nodes = vec![hot_view(2_000_000, hot), hot_view(1_000_000, hot)];
        assert_eq!(d.route(&hot_job(0.2, 0.2, 0.2), &nodes), 1);
        // Equal pressure and work: fewer queued jobs, then lower index.
        let mut q0 = hot_view(1_000_000, hot);
        q0.queued_jobs = 3;
        let q1 = hot_view(1_000_000, hot);
        assert_eq!(d.route(&hot_job(0.2, 0.2, 0.2), &[q0, q1]), 1);
    }

    #[test]
    fn partition_with_zero_pressure_ranks_exactly_like_least_loaded() {
        // Interference off = no signal: the partition dispatcher must
        // delegate to least-loaded on every path (homogeneous integer,
        // heterogeneous normalised, tie-breaks) — the same contract
        // latency-aware honours at zero delay.
        let cases: Vec<Vec<NodeLoadView>> = vec![
            vec![view(30, 1, 0), view(10, 5, 0), view(20, 0, 0)],
            vec![view(10, 3, 0), view(10, 1, 0), view(10, 1, 0)],
            vec![het_view(1_000_000, 1.4), het_view(1_000_000, 4.0)],
            vec![het_view(300_000, 1.4), het_view(1_000_000, 4.0)],
            vec![het_view(10, 4.0), het_view(9, 4.0)],
        ];
        let mut pa = make_dispatcher("partition");
        let mut ll = make_dispatcher("least");
        for nodes in &cases {
            assert_eq!(pa.route(&job(), nodes), ll.route(&job(), nodes));
        }
    }

    #[test]
    fn only_round_robin_is_load_oblivious() {
        // The re-probe guard keys off this: it must stay dormant for
        // dispatchers whose decisions cannot go stale.
        assert!(!make_dispatcher("rr").load_based());
        assert!(make_dispatcher("least").load_based());
        assert!(make_dispatcher("mem").load_based());
        assert!(make_dispatcher("latency").load_based());
        assert!(make_dispatcher("partition").load_based());
    }

    #[test]
    fn aliases_normalise_to_canonical_names() {
        assert_eq!(canonical_dispatch("round-robin"), Some("rr"));
        assert_eq!(canonical_dispatch("least-loaded"), Some("least"));
        assert_eq!(canonical_dispatch("headroom"), Some("mem"));
        assert_eq!(canonical_dispatch("latency-aware"), Some("latency"));
        assert_eq!(canonical_dispatch("latency"), Some("latency"));
        assert_eq!(canonical_dispatch("mig"), Some("partition"));
        assert_eq!(canonical_dispatch("partition"), Some("partition"));
        assert_eq!(canonical_dispatch("nope"), None);
    }

    #[test]
    #[should_panic(expected = "unknown dispatcher")]
    fn unknown_name_panics() {
        make_dispatcher("nope");
    }
}

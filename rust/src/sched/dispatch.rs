//! Cluster-level dispatch (beyond the paper's single node): route each
//! arriving job to a node; the per-node [`Policy`](super::Policy) then
//! places its tasks onto devices beneath the dispatcher.
//!
//! Dispatchers see only aggregate per-node load ([`NodeLoadView`]) and
//! a cheap estimate of the arriving job ([`JobInfo`]) — mirroring a
//! real cluster frontend, which knows queue depths and advertised
//! capacity but not the future. Under a nonzero `gpu::LatencyModel`
//! the view is additionally *stale*: it is snapshotted at probe time
//! ([`NodeLoadView::taken_at`]) while the job lands a round-trip plus
//! dispatch cost later, so decisions can differ from what an
//! instant-landing frontend would choose (by design — see the
//! stale-routing tests). All three built-ins are deterministic (ties
//! break toward the lower node index) so batch runs replay exactly.
//!
//! Paper map: entirely beyond the paper, whose deployments are single
//! node (§V-A); this is the frontend a production cluster puts above N
//! instances of the paper's per-node scheduler. On heterogeneous
//! clusters, [`LeastLoaded`] normalises outstanding work by each node's
//! compute capability (ROADMAP "Heterogeneous-cluster dispatch") —
//! homogeneous clusters keep the original integer comparison and so
//! replay pre-existing runs exactly.

/// Aggregate load of one node at dispatch time.
#[derive(Clone, Copy, Debug)]
pub struct NodeLoadView {
    /// Jobs dispatched to the node and still waiting for a worker.
    pub queued_jobs: usize,
    /// Estimated kernel + host microseconds of every job dispatched to
    /// the node and not yet finished.
    pub outstanding_work_us: u64,
    /// Estimated peak reserved bytes of every dispatched-but-unfinished
    /// job (dispatcher-level bookkeeping, not live device state).
    pub outstanding_mem_bytes: u64,
    /// Current free device memory summed over the node's GPUs.
    pub free_mem: u64,
    /// Total device memory summed over the node's GPUs.
    pub total_mem: u64,
    pub n_gpus: usize,
    /// Relative compute capability (sum of GPU speeds, V100 == 1.0; see
    /// `NodeSpec::compute_capacity`). Least-loaded divides outstanding
    /// work by this so a P100 node is not handed a V100 node's share.
    pub compute_capacity: f64,
    /// Virtual time this snapshot was taken — the *probe* time. Under a
    /// nonzero `gpu::LatencyModel` the routed job only lands on the node
    /// `probe RTT + dispatch cost` later, so every decision is made on
    /// load that is stale by exactly that interval (the engine never
    /// re-snapshots at landing time). 0.0 for batch dispatch at t = 0.
    pub taken_at: f64,
    /// Modeled probe round-trip to this node
    /// (`gpu::LatencyModel::probe_rtt`; 0 with the model off). Exposed
    /// so a latency-aware dispatcher can trade load against distance.
    pub probe_rtt_s: f64,
}

/// What the dispatcher may know about the arriving job.
#[derive(Clone, Copy, Debug)]
pub struct JobInfo {
    /// Estimated kernel + host microseconds (from the compiled trace).
    pub est_work_us: u64,
    /// Estimated peak simultaneous reservation, bytes.
    pub peak_mem_bytes: u64,
}

/// A cluster-level job router. Stateful (round-robin keeps a cursor);
/// one instance lives for the whole batch run.
pub trait Dispatcher: Send {
    fn name(&self) -> &'static str;

    /// Pick the node for an arriving job. `nodes` is never empty.
    fn route(&mut self, job: &JobInfo, nodes: &[NodeLoadView]) -> usize;
}

/// Ignore load entirely; cycle through the nodes.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Dispatcher for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, _job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let n = self.next % nodes.len();
        self.next = self.next.wrapping_add(1);
        n
    }
}

/// Least outstanding estimated work, normalised by node compute
/// capability on heterogeneous clusters (a P100 node drains its queue
/// ~2.9x slower than a 4×V100 node, so equal raw microseconds are not
/// equal load); ties broken by queue depth, then node index. When all
/// capabilities are equal the raw integer comparison is used, keeping
/// homogeneous runs bit-identical to the pre-normalisation dispatcher.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Dispatcher for LeastLoaded {
    fn name(&self) -> &'static str {
        "least"
    }

    fn route(&mut self, _job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let homogeneous =
            nodes.windows(2).all(|w| w[0].compute_capacity == w[1].compute_capacity);
        let norm = |v: &NodeLoadView| {
            v.outstanding_work_us as f64 / v.compute_capacity.max(f64::MIN_POSITIVE)
        };
        let mut best = 0;
        for (i, v) in nodes.iter().enumerate().skip(1) {
            let b = &nodes[best];
            let better = if homogeneous {
                (v.outstanding_work_us, v.queued_jobs) < (b.outstanding_work_us, b.queued_jobs)
            } else {
                norm(v) < norm(b) || (norm(v) == norm(b) && v.queued_jobs < b.queued_jobs)
            };
            if better {
                best = i;
            }
        }
        best
    }
}

/// Largest memory headroom: total capacity minus the estimated peak
/// memory of dispatched-but-unfinished jobs. Sends memory-hungry
/// streams where they are least likely to wait on reservations.
#[derive(Debug, Default)]
pub struct MemHeadroom;

impl Dispatcher for MemHeadroom {
    fn name(&self) -> &'static str {
        "mem"
    }

    fn route(&mut self, _job: &JobInfo, nodes: &[NodeLoadView]) -> usize {
        let headroom =
            |v: &NodeLoadView| v.total_mem.saturating_sub(v.outstanding_mem_bytes);
        let mut best = 0;
        for (i, v) in nodes.iter().enumerate().skip(1) {
            if headroom(v) > headroom(&nodes[best]) {
                best = i;
            }
        }
        best
    }
}

/// Canonical short name for a dispatcher alias, or `None` if the name
/// is not recognised. The single alias table shared by the CLI parser
/// and [`make_dispatcher`].
pub fn canonical_dispatch(name: &str) -> Option<&'static str> {
    match name {
        "rr" | "round-robin" => Some("rr"),
        "least" | "least-loaded" => Some("least"),
        "mem" | "headroom" => Some("mem"),
        _ => None,
    }
}

/// Construct a dispatcher by name: "rr" | "least" | "mem".
pub fn make_dispatcher(name: &str) -> Box<dyn Dispatcher> {
    match canonical_dispatch(name) {
        Some("rr") => Box::new(RoundRobin::default()),
        Some("least") => Box::new(LeastLoaded),
        Some("mem") => Box::new(MemHeadroom),
        _ => panic!("unknown dispatcher '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(outstanding_work_us: u64, queued: usize, outstanding_mem: u64) -> NodeLoadView {
        NodeLoadView {
            queued_jobs: queued,
            outstanding_work_us,
            outstanding_mem_bytes: outstanding_mem,
            free_mem: 64 << 30,
            total_mem: 64 << 30,
            n_gpus: 4,
            compute_capacity: 4.0,
            taken_at: 0.0,
            probe_rtt_s: 0.0,
        }
    }

    fn het_view(outstanding_work_us: u64, compute_capacity: f64) -> NodeLoadView {
        NodeLoadView { compute_capacity, ..view(outstanding_work_us, 0, 0) }
    }

    fn job() -> JobInfo {
        JobInfo { est_work_us: 1_000_000, peak_mem_bytes: 1 << 30 }
    }

    #[test]
    fn round_robin_cycles() {
        let mut d = make_dispatcher("rr");
        let nodes = vec![view(0, 0, 0); 3];
        let picks: Vec<usize> = (0..6).map(|_| d.route(&job(), &nodes)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min_outstanding_work() {
        let mut d = make_dispatcher("least");
        let nodes = vec![view(30, 1, 0), view(10, 5, 0), view(20, 0, 0)];
        assert_eq!(d.route(&job(), &nodes), 1);
        // Equal work: fewer queued jobs wins, then lower index.
        let nodes = vec![view(10, 3, 0), view(10, 1, 0), view(10, 1, 0)];
        assert_eq!(d.route(&job(), &nodes), 1);
    }

    #[test]
    fn least_loaded_normalises_by_compute_capability() {
        let mut d = make_dispatcher("least");
        // Equal raw outstanding work on a 2xP100 (1.4) and a 4xV100
        // (4.0) node: per-capability load is 714ms vs 250ms, so the
        // V100 node is the genuinely less-loaded one.
        let p100 = 2.0 * (3584.0 / 5120.0);
        let nodes = vec![het_view(1_000_000, p100), het_view(1_000_000, 4.0)];
        assert_eq!(d.route(&job(), &nodes), 1);
        // But the slow node wins when its raw backlog is small enough:
        // 300ms/1.4 = 214ms < 1s/4 = 250ms.
        let nodes = vec![het_view(300_000, p100), het_view(1_000_000, 4.0)];
        assert_eq!(d.route(&job(), &nodes), 0);
        // Homogeneous capacities keep the original integer comparison.
        let nodes = vec![het_view(10, 4.0), het_view(9, 4.0)];
        assert_eq!(d.route(&job(), &nodes), 1);
    }

    #[test]
    fn mem_headroom_picks_max_capacity_minus_outstanding() {
        let mut d = make_dispatcher("mem");
        let nodes = vec![view(0, 0, 60 << 30), view(0, 0, 8 << 30), view(0, 0, 8 << 30)];
        assert_eq!(d.route(&job(), &nodes), 1, "lower index wins ties");
        // Outstanding beyond capacity saturates to zero headroom.
        let nodes = vec![view(0, 0, 100 << 30), view(0, 0, 63 << 30)];
        assert_eq!(d.route(&job(), &nodes), 1);
    }

    #[test]
    fn aliases_normalise_to_canonical_names() {
        assert_eq!(canonical_dispatch("round-robin"), Some("rr"));
        assert_eq!(canonical_dispatch("least-loaded"), Some("least"));
        assert_eq!(canonical_dispatch("headroom"), Some("mem"));
        assert_eq!(canonical_dispatch("nope"), None);
    }

    #[test]
    #[should_panic(expected = "unknown dispatcher")]
    fn unknown_name_panics() {
        make_dispatcher("nope");
    }
}

//! schedGPU (Reaño et al., TPDS'18) — the §V-E comparison baseline.
//!
//! Paper map: §V-E "Comparison with schedGPU" — the memory-only
//! intra-node scheduler the paper's compute-aware MGB policies are
//! measured against (and beat on the W1–W8 mixes).
//!
//! Memory capacity is the *only* resource criterion: a task is admitted
//! onto the first device whose free memory covers it, with no compute
//! awareness at all, and suspended (queued) when no memory is free.
//! schedGPU is a single-device design — it cannot reassign work across
//! GPUs — so admission is first-fit from device 0, which concentrates
//! co-located jobs exactly the way the paper describes ("schedGPU would
//! schedule all jobs to run on one device, since the memory capacity is
//! not exceeded").

use super::{DeviceView, Policy, TaskKey, TaskReq};
use std::collections::HashMap;

pub struct SchedGpu {
    placed: HashMap<TaskKey, usize>,
    n_devices: usize,
}

impl SchedGpu {
    pub fn new(n_devices: usize) -> Self {
        SchedGpu { placed: HashMap::new(), n_devices }
    }
}

impl Policy for SchedGpu {
    fn name(&self) -> &'static str {
        "schedgpu"
    }

    fn place(&mut self, key: TaskKey, req: &TaskReq, devices: &[DeviceView]) -> Option<usize> {
        let _ = self.n_devices;
        for (d, view) in devices.iter().enumerate() {
            if req.mem_bytes <= view.free_mem {
                self.placed.insert(key, d);
                return Some(d);
            }
        }
        None // suspend until memory frees up
    }

    fn release(&mut self, key: TaskKey) {
        self.placed.remove(&key);
    }

    fn load_warps(&self, _d: usize) -> u64 {
        0 // schedGPU tracks no compute state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuSpec, InterferenceProfile};

    fn views(frees: &[u64]) -> Vec<DeviceView> {
        frees
            .iter()
            .map(|&f| DeviceView { spec: GpuSpec::v100(), free_mem: f })
            .collect()
    }

    #[test]
    fn piles_onto_device0_while_memory_lasts() {
        let mut p = SchedGpu::new(4);
        let v = views(&[16 << 30; 4]);
        let r = TaskReq { mem_bytes: 1 << 30, tbs: 10_000, warps_per_tb: 8, slo: None, iv: InterferenceProfile::ZERO };
        for i in 0..8 {
            // 8 x 1.5GB-class NN jobs all fit on one V100: all on dev 0.
            assert_eq!(p.place((i, 0), &r, &v), Some(0));
        }
    }

    #[test]
    fn spills_only_on_memory_pressure() {
        let mut p = SchedGpu::new(2);
        let v = views(&[1 << 30, 16 << 30]);
        let r = TaskReq { mem_bytes: 2 << 30, tbs: 1, warps_per_tb: 1, slo: None, iv: InterferenceProfile::ZERO };
        assert_eq!(p.place((0, 0), &r, &v), Some(1));
    }

    #[test]
    fn suspends_with_no_memory_anywhere() {
        let mut p = SchedGpu::new(2);
        let v = views(&[1 << 20, 1 << 20]);
        let r = TaskReq { mem_bytes: 1 << 30, tbs: 1, warps_per_tb: 1, slo: None, iv: InterferenceProfile::ZERO };
        assert_eq!(p.place((0, 0), &r, &v), None);
    }
}

//! Cluster-frontend admission control — the overload-governance layer
//! (beyond the paper; ROADMAP "Frontend admission control + overload
//! governance for open-system traffic").
//!
//! The paper's scheduler already gates admission at the *node*: a task
//! blocks until a memory-safe placement exists (§III-B), and
//! arXiv 1712.04495 builds its co-scheduling guarantee on the same
//! memory-safety condition. The open-system cluster frontend had no
//! such gate, so at sustained arrival rate > capacity the queues grow
//! without bound and turnaround hockey-sticks. This module is the
//! frontend's gate:
//!
//! * **Admission policies** ([`AdmissionConfig`], `--admit`): a
//!   token-bucket rate limiter (`"token"` — arrivals spend tokens that
//!   refill at the configured sustainable rate, with a burst allowance)
//!   or a utilization threshold (`"util"` — arrivals are pressured when
//!   the cluster's outstanding backlog exceeds a bound in seconds of
//!   work per unit of compute capacity). `"off"` (the default) keeps
//!   every run bit-identical to the ungoverned engine.
//! * **Reject-or-degrade lattice** ([`decide_under_pressure`]): under
//!   pressure, latency-sensitive arrivals are *protected* (admitted,
//!   and never charged a token), batch arrivals are *degraded* one
//!   class to best-effort, and best-effort / classless arrivals are
//!   *rejected* — a new terminal state (`EvKind::AdmitReject`) that
//!   never holds a worker, a reservation, or frontend service time.
//! * **Per-class frontend queueing** ([`FrontendQueue`],
//!   `--frontend-q`): under a nonzero latency model the frontend is a
//!   single server; beyond the PR-3 FIFO it can serve the backlog
//!   tightest-class-first (`"prio"`) or by weighted fair queueing
//!   (`"wfq"`, stride scheduling with weights 4/2/1 for
//!   latency-sensitive/batch/best-effort). `"fifo"` keeps the PR-3
//!   path byte-identical.
//!
//! Everything here is deterministic (integer strides, index
//! tie-breaks), so governed runs replay exactly — the same contract the
//! preemption and latency layers honour.

use super::SloClass;
use std::collections::VecDeque;

/// Frontend admission configuration carried by
/// `coordinator::ClusterConfig`. `None` there — or `policy: "off"`
/// here — disables governance and keeps the engine bit-identical to
/// the ungoverned frontend.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionConfig {
    /// Admission policy: "off" | "token" | "util".
    pub policy: &'static str,
    /// Token-bucket refill rate, jobs/s (`--admit-rate`): the
    /// sustainable admitted rate for non-protected arrivals.
    pub rate_per_s: f64,
    /// Token-bucket depth, jobs (`--admit-burst`): how large a flash
    /// crowd is absorbed before the pressure lattice engages.
    pub burst: f64,
    /// Utilization-threshold bound, seconds (`--admit-util`): arrivals
    /// are pressured when outstanding backlog exceeds this many seconds
    /// of dedicated work per unit of cluster compute capacity.
    pub util_threshold_s: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            policy: "token",
            rate_per_s: 1.0,
            burst: 8.0,
            util_threshold_s: 30.0,
        }
    }
}

impl AdmissionConfig {
    /// Whether the controller gates anything at all.
    pub fn enabled(&self) -> bool {
        self.policy != "off"
    }

    /// Copy of the config with every knob forced valid — the
    /// construction-time guard `coordinator` applies (mirroring
    /// `PreemptConfig::sanitized`). A zero/negative/NaN rate would
    /// refill no tokens (rejecting everything forever) or poison the
    /// refill arithmetic; such values degrade to the defaults. Unknown
    /// policy aliases panic, exactly like `make_preempt_policy` on an
    /// unknown policy name.
    pub fn sanitized(&self) -> Self {
        let pos = |v: f64, default: f64| if v.is_finite() && v > 0.0 { v } else { default };
        let d = AdmissionConfig::default();
        AdmissionConfig {
            policy: canonical_admit(self.policy)
                .unwrap_or_else(|| panic!("unknown admission policy '{}'", self.policy)),
            rate_per_s: pos(self.rate_per_s, d.rate_per_s),
            burst: pos(self.burst, d.burst),
            util_threshold_s: pos(self.util_threshold_s, d.util_threshold_s),
        }
    }
}

/// Canonical admission-policy name, or `None` if unrecognised. Shared
/// by the CLI parser and [`AdmissionConfig::sanitized`]; "true" (a bare
/// `--admit` flag) selects the token bucket.
pub fn canonical_admit(name: &str) -> Option<&'static str> {
    match name {
        "off" | "none" => Some("off"),
        "token" | "token-bucket" | "tb" | "on" | "true" => Some("token"),
        "util" | "utilization" | "threshold" => Some("util"),
        _ => None,
    }
}

/// Canonical frontend-queue discipline name, or `None` if
/// unrecognised.
pub fn canonical_frontend_q(name: &str) -> Option<&'static str> {
    match name {
        "fifo" => Some("fifo"),
        "prio" | "priority" => Some("prio"),
        "wfq" | "fair" => Some("wfq"),
        _ => None,
    }
}

/// What the frontend does with one arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Route it (possibly after queueing for frontend service).
    Admit,
    /// Admit it demoted one SLO class (batch -> best-effort): it keeps
    /// running but yields its victim-selection and queueing priority.
    Degrade,
    /// Turn it away at the door: terminal, holds nothing, counted
    /// against goodput but never against a worker or reservation.
    Reject,
}

/// The reject-or-degrade lattice applied to a *pressured* arrival
/// (bucket empty / backlog over threshold). Latency-sensitive work is
/// protected — shedding the traffic whose turnaround is the product
/// would defeat the point of governing; batch demotes to best-effort;
/// best-effort (and classless — no SLO ranks loosest, as in victim
/// selection) is shed.
pub fn decide_under_pressure(slo: Option<SloClass>) -> AdmitDecision {
    match SloClass::looseness(slo) {
        0 => AdmitDecision::Admit,
        1 => AdmitDecision::Degrade,
        _ => AdmitDecision::Reject,
    }
}

/// A standard token bucket over virtual time: `tokens` refill at
/// `rate_per_s` up to `burst`. Protected (latency-sensitive) arrivals
/// never call [`TokenBucket::try_take`], so they neither starve the
/// bucket nor get shed by it.
#[derive(Clone, Copy, Debug)]
pub struct TokenBucket {
    tokens: f64,
    last_t: f64,
    rate: f64,
    burst: f64,
}

impl TokenBucket {
    /// A bucket that starts full (a cold frontend absorbs one burst).
    pub fn new(cfg: &AdmissionConfig) -> Self {
        TokenBucket { tokens: cfg.burst, last_t: 0.0, rate: cfg.rate_per_s, burst: cfg.burst }
    }

    /// Refill for the elapsed virtual time, then spend one token if one
    /// is available. `false` = the arrival is pressured.
    pub fn try_take(&mut self, t: f64) -> bool {
        if t > self.last_t {
            self.tokens = (self.tokens + (t - self.last_t) * self.rate).min(self.burst);
            self.last_t = t;
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently in the bucket (tests/telemetry).
    pub fn level(&self) -> f64 {
        self.tokens
    }
}

/// WFQ stride per class, indexed by `SloClass::looseness` (tightest
/// first). Strides are `LCM(weights) / weight` for weights 4/2/1, so a
/// latency-sensitive job is served for every two batch and four
/// best-effort jobs when all classes back up.
const WFQ_STRIDE: [u64; 3] = [1, 2, 4];

/// Per-class backlog at the cluster frontend, served one probe per
/// service time by the configured discipline. Only built for
/// `--frontend-q prio|wfq` under a nonzero latency model — FIFO (and
/// every zero-latency run, where no frontend queue can form) keeps the
/// PR-3 single-server path byte-identical.
///
/// Disciplines:
/// * `"prio"` — strict priority: tightest non-empty class first, FIFO
///   within a class. Starves loose classes under sustained tight load
///   (that is the point of offering wfq too).
/// * `"wfq"` — stride scheduling: each class carries a pass value
///   advanced by its stride per service; the lowest pass among backed-
///   up classes is served, ties to the tighter class. Deterministic
///   integer arithmetic, so governed runs replay exactly.
#[derive(Debug)]
pub struct FrontendQueue {
    discipline: &'static str,
    classes: [VecDeque<usize>; 3],
    /// WFQ pass per class (unused for "prio").
    pass: [u64; 3],
    /// Pass of the most recent service — newly-backed-up classes start
    /// here so an idle class cannot bank credit while empty.
    virtual_time: u64,
}

impl FrontendQueue {
    /// Build for a canonical non-FIFO discipline.
    pub fn new(discipline: &'static str) -> Self {
        debug_assert!(discipline == "prio" || discipline == "wfq");
        FrontendQueue {
            discipline,
            classes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            pass: [0; 3],
            virtual_time: 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.classes.iter().all(|q| q.is_empty())
    }

    pub fn len(&self) -> usize {
        self.classes.iter().map(|q| q.len()).sum()
    }

    /// Enqueue `job` under its (possibly already degraded) class.
    pub fn push(&mut self, job: usize, slo: Option<SloClass>) {
        let c = SloClass::looseness(slo) as usize;
        if self.classes[c].is_empty() {
            // Re-activating class: no banked credit from its idle span.
            self.pass[c] = self.pass[c].max(self.virtual_time);
        }
        self.classes[c].push_back(job);
    }

    /// Serve the next job by discipline, or `None` when idle.
    pub fn pop(&mut self) -> Option<usize> {
        let c = match self.discipline {
            "prio" => (0..3).find(|&c| !self.classes[c].is_empty())?,
            _ => {
                // wfq: lowest pass among backed-up classes, ties to the
                // tighter class (the iteration order).
                let c = (0..3)
                    .filter(|&c| !self.classes[c].is_empty())
                    .min_by_key(|&c| (self.pass[c], c))?;
                self.virtual_time = self.pass[c];
                self.pass[c] += WFQ_STRIDE[c];
                c
            }
        };
        self.classes[c].pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aliases_canonicalise() {
        assert_eq!(canonical_admit("off"), Some("off"));
        assert_eq!(canonical_admit("none"), Some("off"));
        assert_eq!(canonical_admit("true"), Some("token"), "bare --admit = token bucket");
        assert_eq!(canonical_admit("token-bucket"), Some("token"));
        assert_eq!(canonical_admit("utilization"), Some("util"));
        assert_eq!(canonical_admit("nope"), None);
        assert_eq!(canonical_frontend_q("fifo"), Some("fifo"));
        assert_eq!(canonical_frontend_q("priority"), Some("prio"));
        assert_eq!(canonical_frontend_q("fair"), Some("wfq"));
        assert_eq!(canonical_frontend_q("nope"), None);
    }

    #[test]
    fn sanitized_defends_every_knob() {
        let d = AdmissionConfig::default();
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let cfg = AdmissionConfig { rate_per_s: bad, ..d }.sanitized();
            assert_eq!(cfg.rate_per_s, d.rate_per_s, "rate degrades to the default");
            let cfg = AdmissionConfig { burst: bad, ..d }.sanitized();
            assert_eq!(cfg.burst, d.burst);
            let cfg = AdmissionConfig { util_threshold_s: bad, ..d }.sanitized();
            assert_eq!(cfg.util_threshold_s, d.util_threshold_s);
        }
        let cfg = AdmissionConfig { policy: "on", ..d }.sanitized();
        assert_eq!(cfg.policy, "token");
        assert!(cfg.enabled());
        assert!(!AdmissionConfig { policy: "off", ..d }.enabled());
        assert_eq!(d.sanitized(), d, "valid configs pass through unchanged");
    }

    #[test]
    #[should_panic(expected = "unknown admission policy")]
    fn sanitized_rejects_unknown_policy() {
        let _ = AdmissionConfig { policy: "sideways", ..Default::default() }.sanitized();
    }

    #[test]
    fn pressure_lattice_protects_tight_degrades_batch_sheds_loose() {
        assert_eq!(
            decide_under_pressure(Some(SloClass::LatencySensitive)),
            AdmitDecision::Admit,
            "latency-sensitive is protected"
        );
        assert_eq!(decide_under_pressure(Some(SloClass::Batch)), AdmitDecision::Degrade);
        assert_eq!(decide_under_pressure(Some(SloClass::BestEffort)), AdmitDecision::Reject);
        assert_eq!(decide_under_pressure(None), AdmitDecision::Reject, "classless ranks loosest");
    }

    #[test]
    fn token_bucket_admits_at_rate_and_absorbs_bursts() {
        let cfg = AdmissionConfig { rate_per_s: 2.0, burst: 3.0, ..Default::default() };
        let mut b = TokenBucket::new(&cfg);
        // Starts full: a 3-job flash crowd at t=0 is absorbed whole.
        assert!(b.try_take(0.0) && b.try_take(0.0) && b.try_take(0.0));
        assert!(!b.try_take(0.0), "the 4th same-instant arrival is pressured");
        // Refill is rate * elapsed: 0.5 s at 2 jobs/s = 1 token.
        assert!(b.try_take(0.5));
        assert!(!b.try_take(0.5));
        // At exactly-capacity spacing (1/rate) every arrival is
        // admitted forever — the satellite-4 edge case.
        let mut t = 1.0;
        for _ in 0..100 {
            t += 0.5;
            assert!(b.try_take(t), "exactly-capacity arrival at t={t} admitted");
        }
        // The bucket never exceeds its depth.
        assert!(TokenBucket::new(&cfg).level() <= cfg.burst);
        let mut b = TokenBucket::new(&cfg);
        let _ = b.try_take(1e6);
        assert!(b.level() <= cfg.burst);
    }

    #[test]
    fn token_bucket_ignores_time_running_backwards() {
        // Same-instant and out-of-order calls must not refill: the
        // engine's clock is monotone, but same-t arrivals are common.
        let cfg = AdmissionConfig { rate_per_s: 1.0, burst: 1.0, ..Default::default() };
        let mut b = TokenBucket::new(&cfg);
        assert!(b.try_take(5.0));
        assert!(!b.try_take(5.0));
        assert!(!b.try_take(4.0), "earlier t refills nothing");
    }

    #[test]
    fn prio_serves_tightest_first_fifo_within_class() {
        let mut q = FrontendQueue::new("prio");
        q.push(0, Some(SloClass::BestEffort));
        q.push(1, Some(SloClass::Batch));
        q.push(2, Some(SloClass::LatencySensitive));
        q.push(3, Some(SloClass::LatencySensitive));
        q.push(4, None); // classless queues with best-effort
        assert_eq!(q.len(), 5);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![2, 3, 1, 0, 4]);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn wfq_interleaves_by_weight() {
        let mut q = FrontendQueue::new("wfq");
        for j in 0..8 {
            q.push(j, Some(SloClass::LatencySensitive));
        }
        for j in 8..12 {
            q.push(j, Some(SloClass::Batch));
        }
        for j in 12..14 {
            q.push(j, Some(SloClass::BestEffort));
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order.len(), 14);
        // Weighted shares over the first 7 services (one full stride
        // cycle of 4+2+1): 4 latency-sensitive, 2 batch, 1 best-effort.
        let ls = order[..7].iter().filter(|&&j| j < 8).count();
        let batch = order[..7].iter().filter(|&&j| (8..12).contains(&j)).count();
        let be = order[..7].iter().filter(|&&j| j >= 12).count();
        assert_eq!((ls, batch, be), (4, 2, 1), "4:2:1 service shares: {order:?}");
        // Deterministic: the same pushes replay the same order.
        let mut q2 = FrontendQueue::new("wfq");
        for j in 0..8 {
            q2.push(j, Some(SloClass::LatencySensitive));
        }
        for j in 8..12 {
            q2.push(j, Some(SloClass::Batch));
        }
        for j in 12..14 {
            q2.push(j, Some(SloClass::BestEffort));
        }
        let order2: Vec<usize> = std::iter::from_fn(|| q2.pop()).collect();
        assert_eq!(order, order2);
    }

    #[test]
    fn wfq_reactivated_class_banks_no_credit() {
        let mut q = FrontendQueue::new("wfq");
        // Drain a long best-effort run to advance its pass.
        for j in 0..4 {
            q.push(j, Some(SloClass::BestEffort));
        }
        while q.pop().is_some() {}
        // A best-effort job arriving after the idle span must not be
        // owed the whole span as credit against a fresh tight backlog.
        q.push(100, Some(SloClass::BestEffort));
        q.push(101, Some(SloClass::LatencySensitive));
        assert_eq!(q.pop(), Some(101), "tight class served first despite the idle span");
    }
}

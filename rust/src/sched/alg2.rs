//! Algorithm 2: hardware-emulating placement, memory AND compute hard.
//!
//! Paper map: §IV Algorithm 2 ("MGB-Alg2"), evaluated in Fig. 4/5 and
//! Tables II–IV as the conservative MGB variant.
//!
//! Mirrors each device's per-SM occupancy (resident thread blocks and
//! warps, against the device's per-SM caps) and walks SMs round-robin
//! exactly like the hardware dispatcher. A task is placed only if *all*
//! of its (residency-capped) thread blocks fit right now; otherwise the
//! next device is tried, and if none fits the task waits. This is the
//! conservative end of the design space: no kernel ever oversubscribes
//! compute, at the price of longer queue waits (Fig. 4 / Table IV).
//!
//! Perf note (EXPERIMENTS.md §Perf): placement walks SMs, not thread
//! blocks — each SM absorbs `min(tb_slots_left, warps_left / wptb)` TBs
//! in one step, with deltas in a reusable scratch vector. The original
//! TB-at-a-time walk with hashed deltas cost ~21–57 µs per decision;
//! this form is ~50x cheaper while placing TBs in the same round-robin
//! order the hardware (and the paper's pseudo-code) uses.

use super::{DeviceView, Policy, TaskKey, TaskReq};
use std::collections::HashMap;

#[derive(Clone, Copy, Debug, Default)]
struct SmState {
    tbs: u32,
    warps: u32,
}

struct DevState {
    sms: Vec<SmState>,
    /// Round-robin cursor (persists across placements, like hardware).
    cursor: usize,
}

/// Per-placement record for undo at release: (sm index, tbs, warps).
type Placement = Vec<(u32, u32, u32)>;

pub struct MgbAlg2 {
    devs: Vec<DevState>,
    placed: HashMap<TaskKey, (usize, Placement)>,
    /// Scratch per-SM deltas, reused across placement attempts.
    scratch: Vec<(u32, u32)>,
}

impl MgbAlg2 {
    pub fn new(n_devices: usize) -> Self {
        MgbAlg2 {
            devs: (0..n_devices)
                .map(|_| DevState { sms: Vec::new(), cursor: 0 })
                .collect(),
            placed: HashMap::new(),
            scratch: Vec::new(),
        }
    }

    fn ensure_sms(&mut self, d: usize, view: &DeviceView) {
        if self.devs[d].sms.is_empty() {
            self.devs[d].sms = vec![SmState::default(); view.spec.sms as usize];
        }
    }

    /// Try to place all `tbs` thread blocks on device `d`, round-robin
    /// across SMs like the hardware dispatcher, but absorbing as many
    /// TBs per SM visit as its caps allow. Returns per-SM deltas, or
    /// None — with no state change — if the task does not fully fit.
    fn try_fit(
        &mut self,
        d: usize,
        view: &DeviceView,
        mut tbs: u64,
        warps_per_tb: u64,
    ) -> Option<Placement> {
        self.ensure_sms(d, view);
        let spec = view.spec;
        let dev = &mut self.devs[d];
        let n = dev.sms.len();
        self.scratch.clear();
        self.scratch.resize(n, (0, 0));
        let mut cursor = dev.cursor;
        // One TB per SM visit, exactly like the hardware dispatcher; a
        // full lap with no placement means the device cannot take the
        // task. Deltas accumulate in the flat scratch vector.
        let mut scanned_without_fit = 0usize;
        while tbs > 0 {
            if scanned_without_fit >= n {
                return None; // full lap, nothing placed: no capacity
            }
            let sm = &dev.sms[cursor];
            let extra = self.scratch[cursor];
            let tb_used = (sm.tbs + extra.0) as u64;
            let warp_used = (sm.warps + extra.1) as u64;
            let fits = tb_used < spec.tbs_per_sm as u64
                && warp_used + warps_per_tb <= spec.warps_per_sm as u64;
            if fits {
                self.scratch[cursor].0 += 1;
                self.scratch[cursor].1 += warps_per_tb as u32;
                tbs -= 1;
                scanned_without_fit = 0;
            } else {
                scanned_without_fit += 1;
            }
            cursor = (cursor + 1) % n;
        }
        dev.cursor = cursor;
        let placement: Placement = self
            .scratch
            .iter()
            .enumerate()
            .filter(|(_, &(t, _))| t > 0)
            .map(|(sm, &(t, w))| (sm as u32, t, w))
            .collect();
        for &(sm, t, w) in &placement {
            let s = &mut dev.sms[sm as usize];
            s.tbs += t;
            s.warps += w;
        }
        Some(placement)
    }
}

impl Policy for MgbAlg2 {
    fn name(&self) -> &'static str {
        "mgb-alg2"
    }

    fn place(&mut self, key: TaskKey, req: &TaskReq, devices: &[DeviceView]) -> Option<usize> {
        for (d, view) in devices.iter().enumerate() {
            // Memory: hard constraint, checked first (paper Alg. 2).
            if req.mem_bytes > view.free_mem {
                continue;
            }
            // Compute: demand capped at what an empty device could keep
            // resident (bigger kernels run in waves on real hardware;
            // requiring more than one wave's residency would never fit).
            let demand = req.tbs.min(view.spec.resident_tb_limit(req.warps_per_tb));
            if let Some(placement) = self.try_fit(d, view, demand, req.warps_per_tb) {
                self.placed.insert(key, (d, placement));
                return Some(d);
            }
        }
        None
    }

    fn release(&mut self, key: TaskKey) {
        if let Some((d, placement)) = self.placed.remove(&key) {
            for (sm, t, w) in placement {
                let s = &mut self.devs[d].sms[sm as usize];
                s.tbs -= t;
                s.warps -= w;
            }
        }
    }

    fn load_warps(&self, d: usize) -> u64 {
        self.devs[d].sms.iter().map(|s| s.warps as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuSpec, InterferenceProfile};

    fn views(n: usize, free: u64) -> Vec<DeviceView> {
        (0..n)
            .map(|_| DeviceView { spec: GpuSpec::v100(), free_mem: free })
            .collect()
    }

    fn req(mem: u64, tbs: u64, wptb: u64) -> TaskReq {
        TaskReq { mem_bytes: mem, tbs, warps_per_tb: wptb, slo: None, iv: InterferenceProfile::ZERO }
    }

    #[test]
    fn memory_is_a_hard_constraint() {
        let mut p = MgbAlg2::new(2);
        let mut v = views(2, 16 << 30);
        v[0].free_mem = 1 << 30;
        let r = req(2 << 30, 10, 8);
        assert_eq!(p.place((0, 0), &r, &v), Some(1), "dev0 lacks memory");
    }

    #[test]
    fn full_device_rejects_and_release_readmits() {
        let mut p = MgbAlg2::new(1);
        let v = views(1, 16 << 30);
        let cap_tbs = v[0].spec.resident_tb_limit(8); // 8 warps/tb
        let r = req(1 << 30, cap_tbs, 8);
        assert_eq!(p.place((0, 0), &r, &v), Some(0));
        assert_eq!(p.place((1, 0), &r, &v), None, "no compute left");
        p.release((0, 0));
        assert_eq!(p.place((1, 0), &r, &v), Some(0));
    }

    #[test]
    fn load_tracks_placed_warps_exactly() {
        let mut p = MgbAlg2::new(1);
        let v = views(1, 16 << 30);
        p.place((0, 0), &req(1 << 20, 100, 4), &v).unwrap();
        assert_eq!(p.load_warps(0), 400);
        p.place((0, 1), &req(1 << 20, 50, 2), &v).unwrap();
        assert_eq!(p.load_warps(0), 500);
        p.release((0, 0));
        assert_eq!(p.load_warps(0), 100);
        p.release((0, 1));
        assert_eq!(p.load_warps(0), 0);
    }

    #[test]
    fn never_exceeds_per_sm_caps() {
        let mut p = MgbAlg2::new(1);
        let v = views(1, 16 << 30);
        // Saturate with many medium tasks; per-SM caps must hold.
        let mut placed = 0;
        for i in 0..100 {
            if p.place((i, 0), &req(1 << 20, 200, 8), &v).is_some() {
                placed += 1;
            }
        }
        let spec = v[0].spec;
        for sm in &p.devs[0].sms {
            assert!(sm.tbs <= spec.tbs_per_sm);
            assert!(sm.warps <= spec.warps_per_sm);
        }
        // 80 SMs * 64 warps = 5120 warp slots; each task wants 1600.
        assert_eq!(placed, 3, "3*1600 = 4800 fits, 4th doesn't");
    }

    #[test]
    fn oversized_kernel_needs_empty_device() {
        let mut p = MgbAlg2::new(1);
        let v = views(1, 16 << 30);
        let cap = v[0].spec.warp_capacity();
        // A kernel demanding 4x device capacity is capped to one full wave.
        let huge = req(1 << 30, cap * 4 / 8, 8);
        assert_eq!(p.place((0, 0), &huge, &v), Some(0));
        // Device now completely full: even a 1-TB task fails.
        assert_eq!(p.place((1, 0), &req(1, 1, 1), &v), None);
    }

    #[test]
    fn failed_fit_leaves_no_residue() {
        let mut p = MgbAlg2::new(1);
        let v = views(1, 16 << 30);
        let cap_tbs = v[0].spec.resident_tb_limit(8);
        p.place((0, 0), &req(1, cap_tbs / 2, 8), &v).unwrap();
        let before = p.load_warps(0);
        // This cannot fully fit; state must be untouched afterwards.
        assert_eq!(p.place((1, 0), &req(1, cap_tbs, 8), &v), None);
        assert_eq!(p.load_warps(0), before);
        // And a task that does fit still goes through.
        assert_eq!(p.place((2, 0), &req(1, cap_tbs / 2, 8), &v), Some(0));
    }

    #[test]
    fn round_robin_spreads_across_sms() {
        let mut p = MgbAlg2::new(1);
        let v = views(1, 16 << 30);
        // 80 TBs of 1 warp each: exactly one per SM.
        p.place((0, 0), &req(1, 80, 1), &v).unwrap();
        assert!(p.devs[0].sms.iter().all(|s| s.tbs == 1));
    }
}

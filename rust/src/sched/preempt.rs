//! Checkpoint/restart preemption policies (beyond the paper, which can
//! only wait or admit — see ROADMAP "Job preemption").
//!
//! Paper map: §IV's policies answer "which device, or wait" for an
//! arriving task; this layer adds the third answer real-time GPU
//! partitioning work shows a scheduler needs — "evict victim V to admit
//! task T" — so a heavy late arrival is not starved behind a
//! long-running light kernel (the turnaround pathology behind the
//! paper's 4.9x claim).
//!
//! The engine builds one [`VictimView`] per *eligible* running job on
//! the blocked task's node (in-flight kernel, not already mid-
//! checkpoint, under its preemption budget, and whose eviction would
//! actually make the blocked request fit) and asks the
//! [`PreemptPolicy`] to pick a victim or decline. The victim's kernel
//! is killed (its partial progress is the wasted work), a checkpoint
//! image of its reservations is copied out at the configured cost
//! model, its memory is released to the waiters, and the job re-queues
//! to re-place its reservations and pay the symmetric restore cost
//! before resuming from the killed kernel.
//!
//! All built-ins are deterministic (ties break toward the lower job
//! index) so preemption-enabled runs replay exactly.
//!
//! Two beyond-paper refinements ride on the same contract (ROADMAP
//! "cross-node victim migration", "SLO-aware victim selection"):
//! [`SloClass`] threads an optional per-job SLO from the workload layer
//! through [`TaskReq`]/[`VictimView`] so [`SloAware`] can refuse to
//! evict tighter-class work for looser arrivals, and
//! [`PreemptConfig::migrate`] lets a checkpointed victim re-enter the
//! *cluster frontend* as a restore job instead of re-queuing on its
//! home node — the reservation contract travels with the job (Reaño et
//! al.'s memory-safe co-scheduling condition), priced by the image
//! transfer over [`PreemptConfig::migrate_bytes_per_s`].

use super::TaskReq;
use crate::gpu::{NIC_BYTES_PER_SEC, PCIE_BYTES_PER_SEC};

/// Service-level objective class a job may carry (beyond-paper; ROADMAP
/// "SLO-aware victim selection"). Declared tightest-first, so the
/// derived ordering is "tighter < looser". A job without a class
/// (`None` everywhere the option is threaded) has no SLO at all and is
/// treated as [`SloClass::BestEffort`] by the victim-selection lattice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    /// Interactive/serving traffic: turnaround is the product.
    LatencySensitive,
    /// Throughput jobs with a deadline measured in queue drains.
    Batch,
    /// Scavenger work: runs whenever capacity is spare.
    BestEffort,
}

impl SloClass {
    /// Every class, tightest first (stable iteration order for reports).
    pub const ALL: [SloClass; 3] =
        [SloClass::LatencySensitive, SloClass::Batch, SloClass::BestEffort];

    /// Canonical CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::LatencySensitive => "latency-sensitive",
            SloClass::Batch => "batch",
            SloClass::BestEffort => "best-effort",
        }
    }

    /// Looseness rank of an optional class: 0 = tightest. `None` (no
    /// SLO) ranks loosest — a job that never asked for a guarantee is
    /// the first to yield capacity.
    pub fn looseness(slo: Option<SloClass>) -> u8 {
        match slo {
            Some(SloClass::LatencySensitive) => 0,
            Some(SloClass::Batch) => 1,
            Some(SloClass::BestEffort) | None => 2,
        }
    }

    /// Turnaround-stretch bound defining SLO attainment: a completed
    /// job meets its SLO iff `turnaround <= bound * dedicated kernel
    /// seconds`. Best-effort has no bound (always attained when the
    /// job completes).
    pub fn stretch_bound(&self) -> f64 {
        match self {
            SloClass::LatencySensitive => 4.0,
            SloClass::Batch => 20.0,
            SloClass::BestEffort => f64::INFINITY,
        }
    }
}

/// Checkpoint/restart configuration carried by
/// `coordinator::ClusterConfig`. `None` there disables preemption and
/// keeps the engine bit-identical to the admit-or-wait scheduler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PreemptConfig {
    /// Victim-selection policy:
    /// "min-progress" | "max-mem" | "slo" | "never".
    pub policy: &'static str,
    /// Fixed per-checkpoint (and per-restore) latency, seconds — probe
    /// round-trip + image setup (`--ckpt-cost`).
    pub ckpt_base_s: f64,
    /// Image copy bandwidth, bytes/s: a checkpoint writes the victim's
    /// reserved bytes device-to-host (restore copies them back).
    pub ckpt_bytes_per_s: f64,
    /// Preemption budget per job. 1 (the default) disallows cascading
    /// preemption: a restarted job cannot be evicted again, bounding
    /// wasted work at one lost kernel per job.
    pub max_preemptions: u32,
    /// Restore routing after `CkptDone` (`--migrate`): "off" (the
    /// default) re-places the victim on its home node — the PR-2
    /// behaviour, byte-identical; "cluster" sends the victim's saved
    /// reservation set back through the cluster frontend as a
    /// first-class restore job, routed by the active dispatcher and
    /// paying the image-transfer term when it lands on another node.
    pub migrate: &'static str,
    /// Cross-node checkpoint-image transfer bandwidth, bytes/s
    /// (`--migrate-bw`): a migrating restore pays
    /// `held_bytes / migrate_bytes_per_s` on top of the probe RTT and
    /// dispatch cost when it lands away from its home node. Defaults to
    /// a 10 GbE node-to-node link ([`NIC_BYTES_PER_SEC`]).
    pub migrate_bytes_per_s: f64,
}

impl Default for PreemptConfig {
    fn default() -> Self {
        PreemptConfig {
            policy: "min-progress",
            ckpt_base_s: 0.05,
            ckpt_bytes_per_s: PCIE_BYTES_PER_SEC,
            max_preemptions: 1,
            migrate: "off",
            migrate_bytes_per_s: NIC_BYTES_PER_SEC,
        }
    }
}

impl PreemptConfig {
    /// Checkpoint (== restore) duration for a job holding `bytes`.
    /// Safe under any bandwidth only after [`PreemptConfig::sanitized`]
    /// — the engine applies it at construction, and the CLI hard-errors
    /// on invalid values before a config is ever built.
    pub fn ckpt_seconds(&self, bytes: u64) -> f64 {
        self.ckpt_base_s + bytes as f64 / self.ckpt_bytes_per_s
    }

    /// Whether restores may leave their home node.
    pub fn migrate_on(&self) -> bool {
        self.migrate == "cluster"
    }

    /// Copy of the config with every cost-model term forced valid, the
    /// construction-time guard `coordinator` applies (mirroring
    /// `LatencyModel::sanitized`). A zero/negative/NaN bandwidth would
    /// make `ckpt_seconds` return inf/NaN, scheduling `CkptDone` at a
    /// time that poisons the event heap's `total_cmp` ordering — such
    /// bandwidths degrade to the defaults instead, and a negative base
    /// cost (events in the past) degrades to zero. Unknown migrate
    /// aliases panic, exactly like `make_preempt_policy` on an unknown
    /// policy name.
    pub fn sanitized(&self) -> Self {
        let bw = |v: f64, default: f64| if v.is_finite() && v > 0.0 { v } else { default };
        PreemptConfig {
            policy: self.policy,
            ckpt_base_s: if self.ckpt_base_s.is_finite() && self.ckpt_base_s >= 0.0 {
                self.ckpt_base_s
            } else {
                0.0
            },
            ckpt_bytes_per_s: bw(self.ckpt_bytes_per_s, PCIE_BYTES_PER_SEC),
            max_preemptions: self.max_preemptions,
            migrate: canonical_migrate(self.migrate)
                .unwrap_or_else(|| panic!("unknown migrate mode '{}'", self.migrate)),
            migrate_bytes_per_s: bw(self.migrate_bytes_per_s, NIC_BYTES_PER_SEC),
        }
    }
}

/// Canonical migrate-mode name, or `None` if unrecognised. Shared by
/// the CLI parser and [`PreemptConfig::sanitized`]; "true" (a bare
/// `--migrate` flag) selects cluster-wide restore.
pub fn canonical_migrate(name: &str) -> Option<&'static str> {
    match name {
        "off" | "none" => Some("off"),
        "cluster" | "on" | "true" => Some("cluster"),
        _ => None,
    }
}

/// One eviction candidate, as the engine presents it to the policy.
/// Only *viable* victims appear: evicting the job would free enough
/// memory on some device of the node to fit the blocked request.
#[derive(Clone, Copy, Debug)]
pub struct VictimView {
    /// Batch index of the candidate job.
    pub job: usize,
    /// Device its in-flight kernel occupies.
    pub dev: usize,
    /// Bytes all its open reservations hold on the node.
    pub held_bytes: u64,
    /// Best post-eviction free memory across the node's devices.
    pub free_after_best: u64,
    /// Dedicated-work seconds the in-flight kernel has completed —
    /// lost (wasted) if this victim is checkpointed.
    pub progress_s: f64,
    /// Dedicated-work seconds the in-flight kernel still needs.
    pub remaining_s: f64,
    /// Wall-clock seconds until the kernel completes at its current
    /// (device-speed- and contention-adjusted) rate — comparable with
    /// `est_ckpt_s`, unlike the work-unit `remaining_s`.
    pub eta_s: f64,
    /// Estimated checkpoint duration under the active cost model
    /// (wall-clock seconds).
    pub est_ckpt_s: f64,
    /// Times this job has already been checkpointed.
    pub times_preempted: u32,
    /// SLO class of the candidate job (`None` = no SLO, treated as
    /// best-effort by [`SloAware`]).
    pub slo: Option<SloClass>,
}

/// A victim-selection policy: given the blocked task's resource vector
/// and the viable victims, pick one (index into `victims`) or decline.
pub trait PreemptPolicy: Send {
    fn name(&self) -> &'static str;

    /// `None` = do not preempt; the blocked task waits as before.
    fn select_victim(&mut self, blocked: &TaskReq, victims: &[VictimView]) -> Option<usize>;
}

/// Never preempt. Plumbing-identical to a preemption-enabled run in
/// which no eviction ever fires — the exact-equality regression tests
/// compare it against the disabled path.
#[derive(Debug, Default)]
pub struct NeverPreempt;

impl PreemptPolicy for NeverPreempt {
    fn name(&self) -> &'static str {
        "never"
    }

    fn select_victim(&mut self, _blocked: &TaskReq, _victims: &[VictimView]) -> Option<usize> {
        None
    }
}

/// Minimise wasted work: evict the victim whose in-flight kernel has
/// made the least progress, and only when killing it beats waiting it
/// out (remaining work must exceed the checkpoint cost itself).
#[derive(Debug, Default)]
pub struct MinProgress;

impl PreemptPolicy for MinProgress {
    fn name(&self) -> &'static str {
        "min-progress"
    }

    fn select_victim(&mut self, _blocked: &TaskReq, victims: &[VictimView]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, v) in victims.iter().enumerate() {
            if v.eta_s <= v.est_ckpt_s {
                continue; // finishes before a checkpoint would: wait
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let bv = &victims[b];
                    v.progress_s < bv.progress_s
                        || (v.progress_s == bv.progress_s && v.job < bv.job)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

/// Maximise freed memory: evict the victim holding the most reserved
/// bytes (ties toward the lower job index), skipping victims whose
/// kernel finishes before a checkpoint would complete — killing those
/// is strictly worse than waiting out the kernel, whatever memory they
/// hold (the same wall-clock guard [`MinProgress`] applies).
#[derive(Debug, Default)]
pub struct MaxMemory;

impl PreemptPolicy for MaxMemory {
    fn name(&self) -> &'static str {
        "max-mem"
    }

    fn select_victim(&mut self, _blocked: &TaskReq, victims: &[VictimView]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, v) in victims.iter().enumerate() {
            if v.eta_s <= v.est_ckpt_s {
                continue; // finishes before a checkpoint would: wait
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let bv = &victims[b];
                    v.held_bytes > bv.held_bytes
                        || (v.held_bytes == bv.held_bytes && v.job < bv.job)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

/// SLO-aware victim selection (ROADMAP "SLO-aware victim selection";
/// Zahaf et al. show real-time victim choice must respect deadline
/// classes). The lattice:
///
/// 1. **Never evict a tighter class for a looser one** — a victim
///    whose class is tighter than the blocked task's is untouchable
///    (jobs without a class rank loosest, so classless arrivals can
///    only evict other best-effort work).
/// 2. Among eligible victims, evict the **loosest class first** —
///    best-effort yields before batch, batch before
///    latency-sensitive.
/// 3. Within a class, break ties by **least SLO-slack damage**: the
///    turnaround the eviction inflicts on the victim, `progress_s`
///    (work re-done) plus `2 * est_ckpt_s` (checkpoint + restore);
///    then the lower job index.
///
/// The [`MinProgress`]/[`MaxMemory`] wall-clock guard applies too: a
/// victim whose kernel beats its own checkpoint is always spared.
#[derive(Debug, Default)]
pub struct SloAware;

impl PreemptPolicy for SloAware {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn select_victim(&mut self, blocked: &TaskReq, victims: &[VictimView]) -> Option<usize> {
        let blocked_loose = SloClass::looseness(blocked.slo);
        let damage = |v: &VictimView| v.progress_s + 2.0 * v.est_ckpt_s;
        let mut best: Option<usize> = None;
        for (i, v) in victims.iter().enumerate() {
            if v.eta_s <= v.est_ckpt_s {
                continue; // finishes before a checkpoint would: wait
            }
            let loose = SloClass::looseness(v.slo);
            if loose < blocked_loose {
                continue; // never evict a tighter class for a looser one
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let bv = &victims[b];
                    let bloose = SloClass::looseness(bv.slo);
                    loose > bloose
                        || (loose == bloose
                            && (damage(v) < damage(bv)
                                || (damage(v) == damage(bv) && v.job < bv.job)))
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

/// Canonical short name for a preemption-policy alias, or `None` if
/// unrecognised. Shared by the CLI parser and [`make_preempt_policy`];
/// "true" (a bare `--preempt` flag) selects the default policy.
pub fn canonical_preempt(name: &str) -> Option<&'static str> {
    match name {
        "min-progress" | "minprog" | "true" | "on" => Some("min-progress"),
        "max-mem" | "maxmem" | "mem" => Some("max-mem"),
        "slo" | "slo-aware" => Some("slo"),
        "never" | "off" => Some("never"),
        _ => None,
    }
}

/// Construct a victim-selection policy by canonical name.
pub fn make_preempt_policy(name: &str) -> Box<dyn PreemptPolicy> {
    match canonical_preempt(name) {
        Some("min-progress") => Box::new(MinProgress),
        Some("max-mem") => Box::new(MaxMemory),
        Some("slo") => Box::new(SloAware),
        Some("never") => Box::new(NeverPreempt),
        _ => panic!("unknown preemption policy '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::InterferenceProfile;

    fn req() -> TaskReq {
        TaskReq { mem_bytes: 8 << 30, tbs: 100, warps_per_tb: 4, slo: None, iv: InterferenceProfile::ZERO }
    }

    fn req_slo(slo: SloClass) -> TaskReq {
        TaskReq { slo: Some(slo), ..req() }
    }

    fn victim(job: usize, held: u64, progress: f64, remaining: f64) -> VictimView {
        VictimView {
            job,
            dev: 0,
            held_bytes: held,
            free_after_best: 16 << 30,
            progress_s: progress,
            remaining_s: remaining,
            eta_s: remaining, // V100-dedicated: wall == work units
            est_ckpt_s: 1.0,
            times_preempted: 0,
            slo: None,
        }
    }

    fn victim_slo(job: usize, slo: Option<SloClass>, progress: f64, remaining: f64) -> VictimView {
        VictimView { slo, ..victim(job, 8 << 30, progress, remaining) }
    }

    #[test]
    fn min_progress_picks_least_wasted_work() {
        let mut p = make_preempt_policy("min-progress");
        let vs = vec![
            victim(0, 8 << 30, 50.0, 50.0),
            victim(1, 8 << 30, 5.0, 95.0),
            victim(2, 8 << 30, 20.0, 80.0),
        ];
        assert_eq!(p.select_victim(&req(), &vs), Some(1));
    }

    #[test]
    fn min_progress_declines_nearly_finished_victims() {
        let mut p = make_preempt_policy("min-progress");
        // eta 0.5s < est_ckpt 1.0s: killing it is slower than waiting
        // for its natural completion.
        let vs = vec![victim(0, 8 << 30, 99.5, 0.5)];
        assert_eq!(p.select_victim(&req(), &vs), None);
        assert!(p.select_victim(&req(), &[]).is_none());
        // The guard is wall-clock: 0.9 work-seconds on a slow/contended
        // device (eta 1.3s) still lose to a 1.0s checkpoint — evict.
        let slow = VictimView { eta_s: 1.3, ..victim(0, 8 << 30, 99.1, 0.9) };
        assert_eq!(p.select_victim(&req(), &[slow]), Some(0));
    }

    #[test]
    fn max_mem_picks_largest_holder_ties_to_lower_job() {
        let mut p = make_preempt_policy("max-mem");
        let vs = vec![
            victim(3, 4 << 30, 1.0, 9.0),
            victim(5, 12 << 30, 8.0, 2.0),
            victim(7, 12 << 30, 1.0, 9.0),
        ];
        assert_eq!(p.select_victim(&req(), &vs), Some(1), "12GB, job 5 beats job 7");
    }

    #[test]
    fn max_mem_spares_a_nearly_finished_holder() {
        // The regression the bugfix sweep closes: a 12 GB holder 0.5 s
        // from completing its kernel must be spared — killing it costs
        // a 1.0 s checkpoint, strictly worse than waiting — even though
        // it holds the most memory. The next-largest *viable* holder is
        // taken instead.
        let mut p = make_preempt_policy("max-mem");
        let vs = vec![
            victim(0, 12 << 30, 99.5, 0.5), // eta 0.5 < ckpt 1.0: spare
            victim(1, 8 << 30, 10.0, 50.0),
        ];
        assert_eq!(p.select_victim(&req(), &vs), Some(1), "12 GB holder is spared");
        // Every victim nearly finished: decline outright (wait them out).
        let vs = vec![victim(0, 12 << 30, 99.5, 0.5), victim(1, 8 << 30, 99.9, 0.1)];
        assert_eq!(p.select_victim(&req(), &vs), None);
        // The guard is wall-clock, like min-progress: eta above the
        // checkpoint cost stays evictable.
        let vs = vec![victim(0, 12 << 30, 99.0, 1.5)];
        assert_eq!(p.select_victim(&req(), &vs), Some(0));
    }

    #[test]
    fn never_always_declines() {
        let mut p = make_preempt_policy("never");
        assert_eq!(p.select_victim(&req(), &[victim(0, 1 << 30, 0.0, 100.0)]), None);
    }

    #[test]
    fn slo_aware_never_evicts_a_tighter_class_for_a_looser_one() {
        let mut p = make_preempt_policy("slo");
        // A batch arrival may not evict latency-sensitive work, however
        // attractive the victim looks.
        let vs = vec![victim_slo(0, Some(SloClass::LatencySensitive), 1.0, 100.0)];
        assert_eq!(p.select_victim(&req_slo(SloClass::Batch), &vs), None);
        // Same class is fair game; a tighter arrival may evict looser.
        let vs = vec![victim_slo(0, Some(SloClass::Batch), 1.0, 100.0)];
        assert_eq!(p.select_victim(&req_slo(SloClass::Batch), &vs), Some(0));
        assert_eq!(p.select_victim(&req_slo(SloClass::LatencySensitive), &vs), Some(0));
        // A classless arrival ranks loosest: only best-effort (or
        // classless) victims are eligible.
        let vs = vec![
            victim_slo(0, Some(SloClass::Batch), 0.0, 100.0),
            victim_slo(1, Some(SloClass::BestEffort), 50.0, 100.0),
        ];
        assert_eq!(p.select_victim(&req(), &vs), Some(1), "classless evicts best-effort only");
    }

    #[test]
    fn slo_aware_prefers_loosest_class_then_least_slack_damage() {
        let mut p = make_preempt_policy("slo");
        // Loosest class first: best-effort yields before batch, even
        // when the batch victim would be cheaper to evict.
        let vs = vec![
            victim_slo(0, Some(SloClass::Batch), 0.0, 100.0),
            victim_slo(1, Some(SloClass::BestEffort), 80.0, 20.0),
        ];
        assert_eq!(p.select_victim(&req_slo(SloClass::LatencySensitive), &vs), Some(1));
        // Within a class: least damage (progress + 2x ckpt) wins...
        let vs = vec![
            victim_slo(3, Some(SloClass::Batch), 50.0, 50.0),
            victim_slo(5, Some(SloClass::Batch), 5.0, 95.0),
        ];
        assert_eq!(p.select_victim(&req_slo(SloClass::LatencySensitive), &vs), Some(1));
        // ...and equal damage ties to the lower job index.
        let vs = vec![
            victim_slo(7, Some(SloClass::Batch), 5.0, 95.0),
            victim_slo(4, Some(SloClass::Batch), 5.0, 95.0),
        ];
        assert_eq!(p.select_victim(&req_slo(SloClass::LatencySensitive), &vs), Some(1));
        // The wall-clock guard applies here too.
        let vs = vec![victim_slo(0, Some(SloClass::BestEffort), 99.5, 0.5)];
        assert_eq!(p.select_victim(&req_slo(SloClass::LatencySensitive), &vs), None);
    }

    #[test]
    fn slo_class_lattice_and_names() {
        assert_eq!(SloClass::looseness(Some(SloClass::LatencySensitive)), 0);
        assert_eq!(SloClass::looseness(Some(SloClass::Batch)), 1);
        assert_eq!(SloClass::looseness(Some(SloClass::BestEffort)), 2);
        assert_eq!(SloClass::looseness(None), 2, "no SLO ranks loosest");
        assert!(SloClass::LatencySensitive < SloClass::Batch, "tighter orders first");
        assert_eq!(SloClass::ALL.len(), 3);
        assert_eq!(SloClass::Batch.name(), "batch");
        assert!(SloClass::LatencySensitive.stretch_bound() < SloClass::Batch.stretch_bound());
        assert!(SloClass::BestEffort.stretch_bound().is_infinite());
    }

    #[test]
    fn aliases_and_cost_model() {
        assert_eq!(canonical_preempt("on"), Some("min-progress"));
        assert_eq!(canonical_preempt("mem"), Some("max-mem"));
        assert_eq!(canonical_preempt("slo-aware"), Some("slo"));
        assert_eq!(canonical_preempt("off"), Some("never"));
        assert_eq!(canonical_preempt("nope"), None);
        assert_eq!(canonical_migrate("off"), Some("off"));
        assert_eq!(canonical_migrate("true"), Some("cluster"), "bare --migrate = cluster");
        assert_eq!(canonical_migrate("cluster"), Some("cluster"));
        assert_eq!(canonical_migrate("nope"), None);
        let cfg = PreemptConfig::default();
        // 12 GiB at PCIe bandwidth + base latency.
        let want = 0.05 + (12u64 << 30) as f64 / PCIE_BYTES_PER_SEC;
        assert!((cfg.ckpt_seconds(12 << 30) - want).abs() < 1e-12);
        assert_eq!(cfg.max_preemptions, 1, "cascades disallowed by default");
        assert_eq!(cfg.migrate, "off", "same-node restore is the default");
        assert!(!cfg.migrate_on());
        assert_eq!(cfg.migrate_bytes_per_s, NIC_BYTES_PER_SEC);
    }

    #[test]
    fn sanitized_defends_the_cost_model_against_poison_bandwidths() {
        // The regression the bugfix sweep closes: a zero (or negative,
        // or NaN) bandwidth made ckpt_seconds return inf/NaN, and an
        // event scheduled at that time poisons the heap's total_cmp
        // ordering for the rest of the run.
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let cfg = PreemptConfig { ckpt_bytes_per_s: bad, ..Default::default() }.sanitized();
            assert_eq!(cfg.ckpt_bytes_per_s, PCIE_BYTES_PER_SEC, "degrades to the default");
            assert!(cfg.ckpt_seconds(12 << 30).is_finite());
            let cfg =
                PreemptConfig { migrate_bytes_per_s: bad, ..Default::default() }.sanitized();
            assert_eq!(cfg.migrate_bytes_per_s, NIC_BYTES_PER_SEC);
        }
        // Negative/NaN base cost would schedule events into the past.
        let cfg = PreemptConfig { ckpt_base_s: -3.0, ..Default::default() }.sanitized();
        assert_eq!(cfg.ckpt_base_s, 0.0);
        // Valid configs pass through unchanged, aliases canonicalise.
        let cfg = PreemptConfig { migrate: "on", ..Default::default() };
        assert_eq!(cfg.sanitized().migrate, "cluster");
        assert_eq!(PreemptConfig::default().sanitized(), PreemptConfig::default());
    }

    #[test]
    #[should_panic(expected = "unknown migrate mode")]
    fn sanitized_rejects_unknown_migrate_mode() {
        let _ = PreemptConfig { migrate: "sideways", ..Default::default() }.sanitized();
    }

    #[test]
    #[should_panic(expected = "unknown preemption policy")]
    fn unknown_policy_panics() {
        make_preempt_policy("nope");
    }
}

//! Checkpoint/restart preemption policies (beyond the paper, which can
//! only wait or admit — see ROADMAP "Job preemption").
//!
//! Paper map: §IV's policies answer "which device, or wait" for an
//! arriving task; this layer adds the third answer real-time GPU
//! partitioning work shows a scheduler needs — "evict victim V to admit
//! task T" — so a heavy late arrival is not starved behind a
//! long-running light kernel (the turnaround pathology behind the
//! paper's 4.9x claim).
//!
//! The engine builds one [`VictimView`] per *eligible* running job on
//! the blocked task's node (in-flight kernel, not already mid-
//! checkpoint, under its preemption budget, and whose eviction would
//! actually make the blocked request fit) and asks the
//! [`PreemptPolicy`] to pick a victim or decline. The victim's kernel
//! is killed (its partial progress is the wasted work), a checkpoint
//! image of its reservations is copied out at the configured cost
//! model, its memory is released to the waiters, and the job re-queues
//! to re-place its reservations and pay the symmetric restore cost
//! before resuming from the killed kernel.
//!
//! All built-ins are deterministic (ties break toward the lower job
//! index) so preemption-enabled runs replay exactly.

use super::TaskReq;
use crate::gpu::PCIE_BYTES_PER_SEC;

/// Checkpoint/restart configuration carried by
/// `coordinator::ClusterConfig`. `None` there disables preemption and
/// keeps the engine bit-identical to the admit-or-wait scheduler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PreemptConfig {
    /// Victim-selection policy: "min-progress" | "max-mem" | "never".
    pub policy: &'static str,
    /// Fixed per-checkpoint (and per-restore) latency, seconds — probe
    /// round-trip + image setup (`--ckpt-cost`).
    pub ckpt_base_s: f64,
    /// Image copy bandwidth, bytes/s: a checkpoint writes the victim's
    /// reserved bytes device-to-host (restore copies them back).
    pub ckpt_bytes_per_s: f64,
    /// Preemption budget per job. 1 (the default) disallows cascading
    /// preemption: a restarted job cannot be evicted again, bounding
    /// wasted work at one lost kernel per job.
    pub max_preemptions: u32,
}

impl Default for PreemptConfig {
    fn default() -> Self {
        PreemptConfig {
            policy: "min-progress",
            ckpt_base_s: 0.05,
            ckpt_bytes_per_s: PCIE_BYTES_PER_SEC,
            max_preemptions: 1,
        }
    }
}

impl PreemptConfig {
    /// Checkpoint (== restore) duration for a job holding `bytes`.
    pub fn ckpt_seconds(&self, bytes: u64) -> f64 {
        self.ckpt_base_s + bytes as f64 / self.ckpt_bytes_per_s
    }
}

/// One eviction candidate, as the engine presents it to the policy.
/// Only *viable* victims appear: evicting the job would free enough
/// memory on some device of the node to fit the blocked request.
#[derive(Clone, Copy, Debug)]
pub struct VictimView {
    /// Batch index of the candidate job.
    pub job: usize,
    /// Device its in-flight kernel occupies.
    pub dev: usize,
    /// Bytes all its open reservations hold on the node.
    pub held_bytes: u64,
    /// Best post-eviction free memory across the node's devices.
    pub free_after_best: u64,
    /// Dedicated-work seconds the in-flight kernel has completed —
    /// lost (wasted) if this victim is checkpointed.
    pub progress_s: f64,
    /// Dedicated-work seconds the in-flight kernel still needs.
    pub remaining_s: f64,
    /// Wall-clock seconds until the kernel completes at its current
    /// (device-speed- and contention-adjusted) rate — comparable with
    /// `est_ckpt_s`, unlike the work-unit `remaining_s`.
    pub eta_s: f64,
    /// Estimated checkpoint duration under the active cost model
    /// (wall-clock seconds).
    pub est_ckpt_s: f64,
    /// Times this job has already been checkpointed.
    pub times_preempted: u32,
}

/// A victim-selection policy: given the blocked task's resource vector
/// and the viable victims, pick one (index into `victims`) or decline.
pub trait PreemptPolicy: Send {
    fn name(&self) -> &'static str;

    /// `None` = do not preempt; the blocked task waits as before.
    fn select_victim(&mut self, blocked: &TaskReq, victims: &[VictimView]) -> Option<usize>;
}

/// Never preempt. Plumbing-identical to a preemption-enabled run in
/// which no eviction ever fires — the exact-equality regression tests
/// compare it against the disabled path.
#[derive(Debug, Default)]
pub struct NeverPreempt;

impl PreemptPolicy for NeverPreempt {
    fn name(&self) -> &'static str {
        "never"
    }

    fn select_victim(&mut self, _blocked: &TaskReq, _victims: &[VictimView]) -> Option<usize> {
        None
    }
}

/// Minimise wasted work: evict the victim whose in-flight kernel has
/// made the least progress, and only when killing it beats waiting it
/// out (remaining work must exceed the checkpoint cost itself).
#[derive(Debug, Default)]
pub struct MinProgress;

impl PreemptPolicy for MinProgress {
    fn name(&self) -> &'static str {
        "min-progress"
    }

    fn select_victim(&mut self, _blocked: &TaskReq, victims: &[VictimView]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, v) in victims.iter().enumerate() {
            if v.eta_s <= v.est_ckpt_s {
                continue; // finishes before a checkpoint would: wait
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let bv = &victims[b];
                    v.progress_s < bv.progress_s
                        || (v.progress_s == bv.progress_s && v.job < bv.job)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

/// Maximise freed memory: evict the victim holding the most reserved
/// bytes (ties toward the lower job index). No progress guard — useful
/// when the blocked request is memory-bound and urgency dominates.
#[derive(Debug, Default)]
pub struct MaxMemory;

impl PreemptPolicy for MaxMemory {
    fn name(&self) -> &'static str {
        "max-mem"
    }

    fn select_victim(&mut self, _blocked: &TaskReq, victims: &[VictimView]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, v) in victims.iter().enumerate() {
            let better = match best {
                None => true,
                Some(b) => {
                    let bv = &victims[b];
                    v.held_bytes > bv.held_bytes
                        || (v.held_bytes == bv.held_bytes && v.job < bv.job)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best
    }
}

/// Canonical short name for a preemption-policy alias, or `None` if
/// unrecognised. Shared by the CLI parser and [`make_preempt_policy`];
/// "true" (a bare `--preempt` flag) selects the default policy.
pub fn canonical_preempt(name: &str) -> Option<&'static str> {
    match name {
        "min-progress" | "minprog" | "true" | "on" => Some("min-progress"),
        "max-mem" | "maxmem" | "mem" => Some("max-mem"),
        "never" | "off" => Some("never"),
        _ => None,
    }
}

/// Construct a victim-selection policy by canonical name.
pub fn make_preempt_policy(name: &str) -> Box<dyn PreemptPolicy> {
    match canonical_preempt(name) {
        Some("min-progress") => Box::new(MinProgress),
        Some("max-mem") => Box::new(MaxMemory),
        Some("never") => Box::new(NeverPreempt),
        _ => panic!("unknown preemption policy '{name}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> TaskReq {
        TaskReq { mem_bytes: 8 << 30, tbs: 100, warps_per_tb: 4 }
    }

    fn victim(job: usize, held: u64, progress: f64, remaining: f64) -> VictimView {
        VictimView {
            job,
            dev: 0,
            held_bytes: held,
            free_after_best: 16 << 30,
            progress_s: progress,
            remaining_s: remaining,
            eta_s: remaining, // V100-dedicated: wall == work units
            est_ckpt_s: 1.0,
            times_preempted: 0,
        }
    }

    #[test]
    fn min_progress_picks_least_wasted_work() {
        let mut p = make_preempt_policy("min-progress");
        let vs = vec![
            victim(0, 8 << 30, 50.0, 50.0),
            victim(1, 8 << 30, 5.0, 95.0),
            victim(2, 8 << 30, 20.0, 80.0),
        ];
        assert_eq!(p.select_victim(&req(), &vs), Some(1));
    }

    #[test]
    fn min_progress_declines_nearly_finished_victims() {
        let mut p = make_preempt_policy("min-progress");
        // eta 0.5s < est_ckpt 1.0s: killing it is slower than waiting
        // for its natural completion.
        let vs = vec![victim(0, 8 << 30, 99.5, 0.5)];
        assert_eq!(p.select_victim(&req(), &vs), None);
        assert!(p.select_victim(&req(), &[]).is_none());
        // The guard is wall-clock: 0.9 work-seconds on a slow/contended
        // device (eta 1.3s) still lose to a 1.0s checkpoint — evict.
        let slow = VictimView { eta_s: 1.3, ..victim(0, 8 << 30, 99.1, 0.9) };
        assert_eq!(p.select_victim(&req(), &[slow]), Some(0));
    }

    #[test]
    fn max_mem_picks_largest_holder_ties_to_lower_job() {
        let mut p = make_preempt_policy("max-mem");
        let vs = vec![
            victim(3, 4 << 30, 1.0, 9.0),
            victim(5, 12 << 30, 8.0, 2.0),
            victim(7, 12 << 30, 1.0, 9.0),
        ];
        assert_eq!(p.select_victim(&req(), &vs), Some(1), "12GB, job 5 beats job 7");
    }

    #[test]
    fn never_always_declines() {
        let mut p = make_preempt_policy("never");
        assert_eq!(p.select_victim(&req(), &[victim(0, 1 << 30, 0.0, 100.0)]), None);
    }

    #[test]
    fn aliases_and_cost_model() {
        assert_eq!(canonical_preempt("on"), Some("min-progress"));
        assert_eq!(canonical_preempt("mem"), Some("max-mem"));
        assert_eq!(canonical_preempt("off"), Some("never"));
        assert_eq!(canonical_preempt("nope"), None);
        let cfg = PreemptConfig::default();
        // 12 GiB at PCIe bandwidth + base latency.
        let want = 0.05 + (12u64 << 30) as f64 / PCIE_BYTES_PER_SEC;
        assert!((cfg.ckpt_seconds(12 << 30) - want).abs() < 1e-12);
        assert_eq!(cfg.max_preemptions, 1, "cascades disallowed by default");
    }

    #[test]
    #[should_panic(expected = "unknown preemption policy")]
    fn unknown_policy_panics() {
        make_preempt_policy("nope");
    }
}

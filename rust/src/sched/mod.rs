//! Scheduling, in two layers: cluster-level dispatch and per-node
//! task-granular policies (paper §III-B, §IV; dispatch is beyond-paper).
//!
//! **Node layer.** Task-granular policies implement [`Policy`]: the
//! probe protocol hands them a [`TaskReq`] resource vector and the
//! current device memory views; they answer with a device or `None`
//! (the task waits until a release). [`MgbAlg2`] emulates the
//! hardware's per-SM round-robin placement with memory *and* compute as
//! hard constraints; [`MgbAlg3`] keeps memory hard but compute soft
//! (min-warp-load pick); [`SchedGpu`] reproduces Reaño et al.'s
//! memory-only intra-node scheduler. The process-granular baselines —
//! single-assignment (SA) and core-to-GPU (CG) — are worker-pinning
//! modes of the coordinator (`crate::coordinator`), matching how the
//! paper deploys them.
//!
//! **Cluster layer.** A [`Dispatcher`] routes each *arriving job* to a
//! node of a `gpu::ClusterSpec` (round-robin, least-loaded,
//! memory-headroom, or latency-aware — see [`dispatch`]); the chosen
//! node's own policy instance then places the job's tasks on its
//! devices. The two layers
//! are deliberately decoupled: dispatchers see only aggregate
//! [`NodeLoadView`]s, policies only their node's [`DeviceView`]s.
//!
//! **Preemption layer.** A [`PreemptPolicy`] (see [`preempt`]) extends
//! a node policy's wait/admit answers with "evict victim V": the
//! coordinator checkpoints the victim at a configurable cost, admits
//! the blocked task, and restores the victim later. Off by default —
//! with it disabled the engine is bit-identical to the two-layer stack.
//! Victim selection can be SLO-aware ([`SloAware`], with per-job
//! [`SloClass`]es threaded through [`TaskReq`]), and restores can
//! *migrate*: with `PreemptConfig::migrate = "cluster"` a checkpointed
//! victim re-enters the cluster layer as a restore job and is routed
//! by the active [`Dispatcher`] like any arrival.
//!
//! **Admission layer.** Above the dispatcher sits the cluster
//! frontend's overload governor (see [`admission`]): an
//! [`AdmissionConfig`] gates *arrivals* with a token bucket or a
//! utilization threshold, sheds or degrades best-effort/batch work
//! under pressure, and a [`FrontendQueue`] can serve the frontend
//! backlog by class instead of FIFO. Off by default — with it disabled
//! the engine is bit-identical to the ungoverned frontend.

pub mod admission;
pub mod alg2;
pub mod alg3;
pub mod dispatch;
pub mod preempt;
pub mod schedgpu;

pub use admission::{
    canonical_admit, canonical_frontend_q, decide_under_pressure, AdmissionConfig, AdmitDecision,
    FrontendQueue, TokenBucket,
};
pub use alg2::MgbAlg2;
pub use alg3::MgbAlg3;
pub use dispatch::{
    canonical_dispatch, make_dispatcher, Dispatcher, JobInfo, LatencyAware, LeastLoaded,
    MemHeadroom, NodeLoadView, Partition, RoundRobin,
};
pub use preempt::{
    canonical_migrate, canonical_preempt, make_preempt_policy, MaxMemory, MinProgress,
    NeverPreempt, PreemptConfig, PreemptPolicy, SloAware, SloClass, VictimView,
};
pub use schedgpu::SchedGpu;

use crate::gpu::{GpuSpec, InterferenceProfile};

/// Resource vector conveyed by a probe (`task_begin`).
#[derive(Clone, Copy, Debug)]
pub struct TaskReq {
    /// Memory to reserve (allocations + on-device heap), bytes.
    pub mem_bytes: u64,
    /// Thread blocks of the widest member kernel.
    pub tbs: u64,
    /// Warps per thread block.
    pub warps_per_tb: u64,
    /// SLO class of the owning job, threaded from the workload layer
    /// (`coordinator::JobSpec::slo`) so the SLO-aware preemption
    /// policy can weigh the blocked task's class against its victims'.
    /// `None` = no SLO (ranks loosest in the victim lattice). Placement
    /// policies ignore it.
    pub slo: Option<SloClass>,
    /// Resource-pressure profile of the task's kernels, threaded from
    /// the workload layer so contention-aware dispatchers and (future)
    /// interference-aware node policies can see what the probe is about
    /// to inflict on its co-residents. `ZERO` for legacy workloads.
    pub iv: InterferenceProfile,
}

impl TaskReq {
    pub fn warps(&self) -> u64 {
        self.tbs * self.warps_per_tb
    }
}

/// Key identifying a placed task for later release.
pub type TaskKey = (usize, usize); // (job id, runtime task id)

/// Scheduler's read-only view of one device at decision time.
#[derive(Clone, Copy, Debug)]
pub struct DeviceView {
    pub spec: GpuSpec,
    /// Free memory *after* existing reservations/allocations.
    pub free_mem: u64,
}

/// A task-granular scheduling policy. Implementations keep their own
/// compute bookkeeping (warp counts, SM mirrors); the coordinator owns
/// memory accounting and passes it in through [`DeviceView`].
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Choose a device for `req`, recording internal load under `key`.
    /// `None` = no device fits; the coordinator queues the task and
    /// retries after the next release.
    fn place(&mut self, key: TaskKey, req: &TaskReq, devices: &[DeviceView]) -> Option<usize>;

    /// A previously-placed task finished; drop its load.
    fn release(&mut self, key: TaskKey);

    /// Current compute load (warps) the policy believes device `d`
    /// carries — exposed for tests and metrics.
    fn load_warps(&self, d: usize) -> u64;
}

/// Construct the policy for a node.
pub fn make_policy(name: &str, n_devices: usize) -> Box<dyn Policy> {
    match name {
        "mgb2" | "alg2" => Box::new(MgbAlg2::new(n_devices)),
        "mgb3" | "alg3" | "mgb" => Box::new(MgbAlg3::new(n_devices)),
        "schedgpu" => Box::new(SchedGpu::new(n_devices)),
        other => panic!("unknown task-granular policy '{other}'"),
    }
}

//! Algorithm 3: memory-safe, least-warp-load quick placement.
//!
//! Paper map: §IV Algorithm 3 — the default MGB policy behind the
//! headline 4.9x mean-turnaround / throughput gains of §V (Fig. 4/5,
//! Tables II–IV).
//!
//! Memory stays a hard constraint; compute is soft — the policy just
//! tracks the *total* active warps per GPU (not per-SM) and, among the
//! devices with enough free memory, picks the one with the least load.
//! Decisions are O(devices) with no SM bookkeeping, which is why the
//! paper runs MGB with Alg. 3 by default: optimistic placement exploits
//! fast-completing jobs and MPS queueing (§V-B).
//!
//! NOTE: the paper's pseudo-code initialises `MinWarps <- 0` and updates
//! on `MinWarps < G.InUseWarps`, which as written selects the *most*
//! loaded device; the prose ("picks the GPU with the least load in terms
//! of the total number of warps") and every result in §V require the
//! minimum, so we implement the minimum.

use super::{DeviceView, Policy, TaskKey, TaskReq};
use std::collections::HashMap;

pub struct MgbAlg3 {
    in_use_warps: Vec<u64>,
    placed: HashMap<TaskKey, (usize, u64)>,
}

impl MgbAlg3 {
    pub fn new(n_devices: usize) -> Self {
        MgbAlg3 { in_use_warps: vec![0; n_devices], placed: HashMap::new() }
    }
}

impl Policy for MgbAlg3 {
    fn name(&self) -> &'static str {
        "mgb-alg3"
    }

    fn place(&mut self, key: TaskKey, req: &TaskReq, devices: &[DeviceView]) -> Option<usize> {
        let mut target: Option<usize> = None;
        for (d, view) in devices.iter().enumerate() {
            if req.mem_bytes > view.free_mem {
                continue; // memory: hard constraint
            }
            match target {
                None => target = Some(d),
                Some(t) if self.in_use_warps[d] < self.in_use_warps[t] => target = Some(d),
                _ => {}
            }
        }
        let d = target?;
        let warps = req.warps();
        self.in_use_warps[d] += warps;
        self.placed.insert(key, (d, warps));
        Some(d)
    }

    fn release(&mut self, key: TaskKey) {
        if let Some((d, warps)) = self.placed.remove(&key) {
            self.in_use_warps[d] -= warps;
        }
    }

    fn load_warps(&self, d: usize) -> u64 {
        self.in_use_warps[d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{GpuSpec, InterferenceProfile};

    fn views(n: usize, free: u64) -> Vec<DeviceView> {
        (0..n)
            .map(|_| DeviceView { spec: GpuSpec::v100(), free_mem: free })
            .collect()
    }

    fn req(mem: u64, tbs: u64, wptb: u64) -> TaskReq {
        TaskReq { mem_bytes: mem, tbs, warps_per_tb: wptb, slo: None, iv: InterferenceProfile::ZERO }
    }

    #[test]
    fn balances_by_warp_load() {
        let mut p = MgbAlg3::new(2);
        let v = views(2, 16 << 30);
        assert_eq!(p.place((0, 0), &req(1, 100, 8), &v), Some(0));
        assert_eq!(p.place((1, 0), &req(1, 10, 8), &v), Some(1), "dev1 is emptier");
        assert_eq!(p.place((2, 0), &req(1, 10, 8), &v), Some(1), "dev1 still emptier");
        assert_eq!(p.place((3, 0), &req(1, 200, 8), &v), Some(1), "160 < 800 warps");
        assert_eq!(p.place((4, 0), &req(1, 1, 1), &v), Some(0), "now dev0 emptier");
    }

    #[test]
    fn memory_gates_despite_low_load() {
        let mut p = MgbAlg3::new(2);
        let mut v = views(2, 16 << 30);
        p.place((0, 0), &req(1, 1000, 8), &v).unwrap(); // dev0 heavy compute
        v[1].free_mem = 1 << 20; // dev1 memory-starved
        // dev1 has least warps but lacks memory: must pick dev0.
        assert_eq!(p.place((1, 0), &req(1 << 30, 10, 8), &v), Some(0));
    }

    #[test]
    fn waits_when_no_device_has_memory() {
        let mut p = MgbAlg3::new(2);
        let v = views(2, 1 << 20);
        assert_eq!(p.place((0, 0), &req(1 << 30, 10, 8), &v), None);
    }

    #[test]
    fn compute_is_soft_never_blocks() {
        let mut p = MgbAlg3::new(1);
        let v = views(1, 16 << 30);
        // Pile arbitrarily many tasks: compute never rejects.
        for i in 0..50 {
            assert_eq!(p.place((i, 0), &req(1 << 20, 10_000, 8), &v), Some(0));
        }
        assert_eq!(p.load_warps(0), 50 * 80_000);
    }

    #[test]
    fn release_returns_load() {
        let mut p = MgbAlg3::new(1);
        let v = views(1, 16 << 30);
        p.place((7, 3), &req(1, 128, 4), &v);
        assert_eq!(p.load_warps(0), 512);
        p.release((7, 3));
        assert_eq!(p.load_warps(0), 0);
        p.release((7, 3)); // double release is a no-op
        assert_eq!(p.load_warps(0), 0);
    }
}

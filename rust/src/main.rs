//! `mgb` — leader binary for the MGB reproduction.
//!
//! Subcommands (hand-rolled parser; the offline crate set has no clap):
//!
//! ```text
//! mgb bench [--exp fig4|fig5|fig6|table2|table3|table4|nn128|ablation|cluster|preempt|latency|migrate|scale|interference|overload|all] [--seed N]
//! mgb run   --workload W1..W8 [--node p100x2|v100x4] [--sched sa|cg|mgb2|mgb3|schedgpu|static]
//!           [--nodes N] [--dispatch rr|least|mem|latency|partition] [--rate JOBS_PER_S]
//!           [--arrivals poisson|mmpp|flash]
//!           [--admit off|token|util] [--admit-rate JOBS_PER_S] [--admit-burst N]
//!           [--admit-util SECONDS] [--frontend-q fifo|prio|wfq]
//!           [--preempt [min-progress|max-mem|slo|never]] [--ckpt-cost SECONDS]
//!           [--migrate off|cluster] [--migrate-bw BYTES_PER_S] [--slo] [--interference]
//!           [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
//!           [--reprobe-after SECONDS] [--reprobe-budget N] [--coalesce-window SECONDS]
//!           [--workers N] [--seed N] [--compute real|modeled] [--artifacts DIR] [--sanitize]
//! mgb nn    [--task predict|train|detect|generate|mix] [--jobs N] [--sched ...] [--workers N]
//!           [--nodes N] [--dispatch rr|least|mem|latency|partition] [--rate JOBS_PER_S]
//!           [--arrivals poisson|mmpp|flash]
//!           [--admit off|token|util] [--admit-rate JOBS_PER_S] [--admit-burst N]
//!           [--admit-util SECONDS] [--frontend-q fifo|prio|wfq]
//!           [--preempt [min-progress|max-mem|slo|never]] [--ckpt-cost SECONDS]
//!           [--migrate off|cluster] [--migrate-bw BYTES_PER_S] [--slo] [--interference]
//!           [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
//!           [--reprobe-after SECONDS] [--reprobe-budget N] [--coalesce-window SECONDS]
//!           [--sanitize]
//! mgb compile <file.gir> — run the compiler pass on an IR file, print tasks + probes
//! mgb lint  [--builtin] [--json PATH] [file.gir ...] — static verifier over IR programs
//!           (memory-state dataflow + task-summary soundness); exit 1 on any error
//! mgb artifacts [--dir DIR] — list and smoke-execute the AOT artifacts
//! ```
//!
//! Unknown `--flags`, stray tokens, and invalid latency values are an
//! error, not a shrug: a typo'd `--probe-rt` (or a `--probe-rtt 5ms`)
//! used to silently run the zero-latency model; now every subcommand
//! validates its flag set and exits 2 naming the offender and the
//! valid ones.

use mgb::bench_harness;
use mgb::compiler::{compile, verify_compiled};
use mgb::coordinator::{
    run_cluster, run_cluster_sanitized, run_cluster_with_hook, AdmissionConfig, ClusterConfig,
    RunResult, SanitizerReport, SchedMode,
};
use mgb::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use mgb::ir::parse::parse_program;
use mgb::runtime::KernelRegistry;
use mgb::workloads::{
    flash_crowd_arrivals, mmpp_arrivals, nn_homogeneous, nn_mix, poisson_arrivals, NnTask,
    Workload, COMBOS, NN_TASKS,
};
use std::collections::HashMap;

/// Valid flags per subcommand — the single source the strict parser
/// checks against (and the error message prints).
const BENCH_FLAGS: &[&str] = &["exp", "seed"];
const RUN_FLAGS: &[&str] = &[
    "workload", "node", "sched", "nodes", "dispatch", "rate", "arrivals",
    "admit", "admit-rate", "admit-burst", "admit-util", "frontend-q",
    "preempt", "ckpt-cost",
    "migrate", "migrate-bw", "slo", "interference",
    "latency", "probe-rtt", "dispatch-cost", "reprobe-after", "reprobe-budget",
    "coalesce-window", "workers", "seed", "compute", "artifacts", "sanitize",
    "compile-traces",
];
const NN_FLAGS: &[&str] = &[
    "task", "jobs", "node", "sched", "nodes", "dispatch", "rate", "arrivals",
    "admit", "admit-rate", "admit-burst", "admit-util", "frontend-q",
    "preempt", "ckpt-cost",
    "migrate", "migrate-bw", "slo", "interference",
    "latency", "probe-rtt", "dispatch-cost", "reprobe-after", "reprobe-budget",
    "coalesce-window", "workers", "seed", "sanitize", "compile-traces",
];
const ARTIFACTS_FLAGS: &[&str] = &["dir"];
/// `lint` also takes positional `.gir` paths, parsed by `cmd_lint`
/// itself (the strict pair parser has no positional concept).
const LINT_FLAGS: &[&str] = &["builtin", "json"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some(cmd @ ("bench" | "run" | "nn" | "artifacts")) => {
            let valid = match cmd {
                "bench" => BENCH_FLAGS,
                "run" => RUN_FLAGS,
                "nn" => NN_FLAGS,
                _ => ARTIFACTS_FLAGS,
            };
            match flags(&args[1..], valid) {
                Err(e) => {
                    eprintln!("{cmd}: {e}");
                    2
                }
                Ok(f) => match cmd {
                    "bench" => cmd_bench(&f),
                    "run" => cmd_run(&f),
                    "nn" => cmd_nn(&f),
                    _ => cmd_artifacts(&f),
                },
            }
        }
        Some("compile") => cmd_compile(args.get(1).map(String::as_str)),
        Some("lint") => cmd_lint(&args[1..]),
        _ => {
            eprintln!("usage: mgb <bench|run|nn|compile|lint|artifacts> [flags]\n{}", HELP);
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
  bench --exp <fig4|fig5|fig6|table2|table3|table4|nn128|ablation|cluster|preempt|latency|migrate|scale|interference|overload|all> [--seed N]
  run   --workload W1..W8 [--node p100x2|v100x4] [--sched sa|cg|mgb2|mgb3|schedgpu|static]
        [--nodes N] [--dispatch rr|least|mem|latency|partition] [--rate JOBS_PER_S]
        [--arrivals poisson|mmpp|flash]
        [--admit off|token|util] [--admit-rate JOBS_PER_S] [--admit-burst N]
        [--admit-util SECONDS] [--frontend-q fifo|prio|wfq]
        [--preempt [min-progress|max-mem|slo|never]] [--ckpt-cost SECONDS]
        [--migrate off|cluster] [--migrate-bw BYTES_PER_S] [--slo] [--interference]
        [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
        [--reprobe-after SECONDS] [--reprobe-budget N] [--coalesce-window SECONDS]
        [--workers N] [--seed N] [--compute real] [--artifacts DIR] [--sanitize]
        [--compile-traces]
  nn    [--task predict|train|detect|generate|mix] [--jobs N] [--sched ..] [--workers N]
        [--nodes N] [--dispatch rr|least|mem|latency|partition] [--rate JOBS_PER_S]
        [--arrivals poisson|mmpp|flash]
        [--admit off|token|util] [--admit-rate JOBS_PER_S] [--admit-burst N]
        [--admit-util SECONDS] [--frontend-q fifo|prio|wfq]
        [--preempt [min-progress|max-mem|slo|never]] [--ckpt-cost SECONDS]
        [--migrate off|cluster] [--migrate-bw BYTES_PER_S] [--slo] [--interference]
        [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
        [--reprobe-after SECONDS] [--reprobe-budget N] [--coalesce-window SECONDS]
        [--sanitize] [--compile-traces]
  compile <file.gir>
  lint  [--builtin] [--json PATH] [file.gir ...]
  artifacts [--dir DIR]";

/// Parse `--key value` / bare `--key` pairs, rejecting any key not in
/// `valid`. Silently dropping a typo'd flag is how a `--probe-rt` run
/// quietly measures the wrong thing — unknown flags are an error
/// naming the flag and the subcommand's valid set instead.
fn flags(args: &[String], valid: &[&str]) -> Result<HashMap<String, String>, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if !valid.contains(&key) {
                return Err(format!(
                    "unknown flag '--{key}' (valid flags: {})",
                    valid.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
                ));
            }
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    m.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    m.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            // Anything here was neither a flag nor consumed as a flag's
            // value: a single-dash typo (`-probe-rtt`) or a stray
            // positional. Ignoring it is the same silent
            // misconfiguration as an unknown flag.
            return Err(format!(
                "unexpected argument '{}' (flags start with --)",
                args[i]
            ));
        }
    }
    Ok(m)
}

fn parse_node(f: &HashMap<String, String>) -> NodeSpec {
    match f.get("node").map(String::as_str) {
        Some("p100x2") => NodeSpec::p100x2(),
        Some("v100x4") | None => NodeSpec::v100x4(),
        Some(other) => {
            eprintln!("unknown node '{other}', using v100x4");
            NodeSpec::v100x4()
        }
    }
}

fn parse_sched(f: &HashMap<String, String>) -> SchedMode {
    match f.get("sched").map(String::as_str) {
        Some("sa") => SchedMode::Sa,
        Some("cg") => SchedMode::Cg,
        Some("mgb2") | Some("alg2") => SchedMode::Policy("mgb2"),
        Some("schedgpu") => SchedMode::Policy("schedgpu"),
        Some("static") => SchedMode::Static,
        _ => SchedMode::Policy("mgb3"),
    }
}

/// `--nodes N` scales the chosen node preset to an N-node cluster.
fn parse_cluster(f: &HashMap<String, String>) -> ClusterSpec {
    let node = parse_node(f);
    let n = f.get("nodes").and_then(|s| s.parse::<usize>().ok()).unwrap_or(1);
    if n <= 1 {
        ClusterSpec::single(node)
    } else {
        ClusterSpec::homogeneous(node, n)
    }
}

/// `--preempt [POLICY]` enables checkpoint/restart preemption (a bare
/// flag selects the default min-progress policy); `--ckpt-cost S` sets
/// the fixed per-checkpoint latency of the cost model; `--migrate
/// off|cluster` routes restores back through the cluster frontend
/// (bare flag = `cluster`) at `--migrate-bw BYTES/S` image bandwidth.
///
/// Invalid values — and preemption-dependent flags without `--preempt`
/// — are hard errors, like `parse_latency`: the old warn-and-default
/// (and the silently swallowed unparsable `--ckpt-cost`) measured a
/// *different* preemption model than the one asked for.
fn parse_preempt(f: &HashMap<String, String>) -> Result<Option<mgb::sched::PreemptConfig>, String> {
    let Some(name) = f.get("preempt") else {
        for dep in ["ckpt-cost", "migrate", "migrate-bw"] {
            if f.contains_key(dep) {
                return Err(format!("--{dep} requires --preempt"));
            }
        }
        return Ok(None);
    };
    let policy = mgb::sched::canonical_preempt(name).ok_or_else(|| {
        format!("unknown preemption policy '{name}' (valid: min-progress max-mem slo never)")
    })?;
    let mut cfg = mgb::sched::PreemptConfig { policy, ..Default::default() };
    if let Some(s) = f.get("ckpt-cost") {
        cfg.ckpt_base_s = match s.parse::<f64>() {
            Ok(v) if v >= 0.0 && v.is_finite() => v,
            _ => return Err(format!("invalid --ckpt-cost '{s}' (non-negative seconds expected)")),
        };
    }
    if let Some(s) = f.get("migrate") {
        cfg.migrate = mgb::sched::canonical_migrate(s)
            .ok_or_else(|| format!("unknown migrate mode '{s}' (valid: off cluster)"))?;
    }
    if let Some(s) = f.get("migrate-bw") {
        cfg.migrate_bytes_per_s = match s.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => v,
            _ => return Err(format!("invalid --migrate-bw '{s}' (positive bytes/s expected)")),
        };
    }
    Ok(Some(cfg))
}

/// `--slo` stamps SLO classes onto the generated jobs by workload
/// class (Large -> latency-sensitive, Small -> batch, NN ->
/// best-effort) so the `slo` victim policy and the per-class
/// attainment metrics have classes to act on. Off by default: jobs
/// carry no SLO and the run is unchanged.
fn parse_slo(f: &HashMap<String, String>) -> Result<bool, String> {
    match f.get("slo").map(String::as_str) {
        None | Some("off") => Ok(false),
        Some("true") | Some("on") => Ok(true),
        Some(other) => Err(format!("invalid --slo '{other}' (bare flag, on, or off)")),
    }
}

/// `--interference` stamps per-benchmark resource-pressure vectors
/// onto the generated jobs by the artifacts their launches bind
/// (`workloads::assign_interference`), turning on the device model's
/// contention response. Off by default: jobs keep all-zero vectors and
/// the run replays bit-identically to the pre-interference model.
fn parse_interference(f: &HashMap<String, String>) -> Result<bool, String> {
    match f.get("interference").map(String::as_str) {
        None | Some("off") => Ok(false),
        Some("true") | Some("on") => Ok(true),
        Some(other) => Err(format!("invalid --interference '{other}' (bare flag, on, or off)")),
    }
}

/// `--sanitize` arms the engine's debug sanitizer: after every fired
/// event the run re-checks its conservation invariants (device-memory
/// conservation, worker-slot uniqueness, clock monotonicity) and exits
/// nonzero on any violation. Observational only — results are
/// identical to an unarmed run. Same bare-flag convention as `--slo`.
fn parse_sanitize(f: &HashMap<String, String>) -> Result<bool, String> {
    match f.get("sanitize").map(String::as_str) {
        None | Some("off") => Ok(false),
        Some("true") | Some("on") => Ok(true),
        Some(other) => Err(format!("invalid --sanitize '{other}' (bare flag, on, or off)")),
    }
}

/// `--compile-traces` turns on compiled trace replay: steady-state
/// trace segments are compacted (`lazy::compile`) and macro-stepped as
/// one event each, decompiling back to fine-grained stepping at every
/// side-exit. Exactness, not approximation: metrics and the observable
/// event subset are byte-identical to an off run (enforced by
/// equivalence tests); only the event count changes. Off by default —
/// the engine then never consults the trace compiler. Same bare-flag
/// convention as `--slo`.
fn parse_compile_traces(f: &HashMap<String, String>) -> Result<bool, String> {
    match f.get("compile-traces").map(String::as_str) {
        None | Some("off") => Ok(false),
        Some("true") | Some("on") => Ok(true),
        Some(other) => {
            Err(format!("invalid --compile-traces '{other}' (bare flag, on, or off)"))
        }
    }
}

/// The validated run/nn option bundle — any invalid value is one
/// error naming it.
struct RunOpts {
    latency: LatencyModel,
    preempt: Option<mgb::sched::PreemptConfig>,
    slo: bool,
    interference: bool,
    admit: Option<AdmissionConfig>,
    frontend_q: &'static str,
    /// `Some((rate, shape))` when `--rate` asked for open-system
    /// traffic; the shape is one of "poisson" | "mmpp" | "flash".
    arrivals: Option<(f64, &'static str)>,
    sanitize: bool,
    compile_traces: bool,
}

fn parse_run_opts(f: &HashMap<String, String>) -> Result<RunOpts, String> {
    let latency = parse_latency(f)?;
    let (admit, frontend_q) = parse_admit(f)?;
    if frontend_q != "fifo" && latency.is_off() {
        // A frontend discipline with no frontend latency never queues
        // anything — the silent no-op misconfiguration this parser
        // family rejects everywhere else.
        return Err(format!(
            "--frontend-q {frontend_q} requires a frontend latency model \
             (--latency lan|wan, --probe-rtt, or --dispatch-cost)"
        ));
    }
    Ok(RunOpts {
        latency,
        preempt: parse_preempt(f)?,
        slo: parse_slo(f)?,
        interference: parse_interference(f)?,
        admit,
        frontend_q,
        arrivals: parse_arrivals(f)?,
        sanitize: parse_sanitize(f)?,
        compile_traces: parse_compile_traces(f)?,
    })
}

fn parse_dispatch(f: &HashMap<String, String>) -> &'static str {
    match f.get("dispatch") {
        None => "rr",
        Some(s) => mgb::sched::canonical_dispatch(s).unwrap_or_else(|| {
            eprintln!("unknown dispatcher '{s}', using rr");
            "rr"
        }),
    }
}

/// `--latency off|lan|wan` picks a frontend latency preset (`off`, the
/// default, is the paper's free-frontend idealisation; a bare
/// `--latency` selects `lan`). `--probe-rtt S` / `--dispatch-cost S`
/// override the probe round-trip and the dispatch base cost in seconds
/// — setting either on top of `off` turns the model on with only that
/// term. `--reprobe-after S` arms the timeout + re-probe protocol
/// (implying a budget of 1 unless `--reprobe-budget N` raises it);
/// `--coalesce-window S` turns on daemon-side reply batching.
///
/// Invalid values are errors, for the same reason unknown flags are: a
/// run that warns and then measures a *different* latency model than
/// the one asked for is the silent-misconfiguration failure mode this
/// parser exists to close.
fn parse_latency(f: &HashMap<String, String>) -> Result<LatencyModel, String> {
    let seconds = |flag: &str, s: &String| -> Result<f64, String> {
        match s.parse::<f64>() {
            Ok(v) if v >= 0.0 && v.is_finite() => Ok(v),
            _ => Err(format!("invalid --{flag} '{s}' (non-negative seconds expected)")),
        }
    };
    let mut m = match f.get("latency").map(String::as_str) {
        None | Some("off") => LatencyModel::off(),
        Some("on") | Some("true") | Some("lan") => LatencyModel::lan(),
        Some("wan") => LatencyModel::wan(),
        Some(other) => {
            return Err(format!("unknown latency preset '{other}' (valid: off lan wan)"))
        }
    };
    if let Some(s) = f.get("probe-rtt") {
        m.probe_rtt_s = seconds("probe-rtt", s)?;
    }
    if let Some(s) = f.get("dispatch-cost") {
        // "Fixed dispatch latency": the explicit override replaces the
        // preset's whole dispatch model, including wan's per-byte term.
        m.dispatch_base_s = seconds("dispatch-cost", s)?;
        m.dispatch_s_per_byte = 0.0;
    }
    if let Some(s) = f.get("reprobe-after") {
        let r = seconds("reprobe-after", s)?;
        if r <= 0.0 {
            return Err(format!("invalid --reprobe-after '{s}' (positive seconds expected)"));
        }
        m.reprobe_after_s = r;
        // A staleness bound without a budget would never fire; give the
        // flag its obvious meaning, overridable by --reprobe-budget.
        if m.reprobe_budget == 0 {
            m.reprobe_budget = 1;
        }
    }
    if let Some(s) = f.get("reprobe-budget") {
        m.reprobe_budget = s
            .parse::<u32>()
            .map_err(|_| format!("invalid --reprobe-budget '{s}' (count expected)"))?;
    }
    if let Some(s) = f.get("coalesce-window") {
        m.coalesce_window_s = seconds("coalesce-window", s)?;
    }
    Ok(m)
}

/// `--rate R` stamps open-system arrivals over the batch at an average
/// of R jobs/s; `--arrivals poisson|mmpp|flash` picks the process
/// shape (Poisson, two-phase diurnal MMPP, or clocked flash crowds —
/// see `workloads::mixes`; requires `--rate`).
///
/// Invalid rates are hard errors, not warn-and-batch: `--rate 0` (or
/// `--rate 12j/s`, which failed to parse) used to print a warning and
/// then quietly measure the closed batch-at-0 system — the same silent
/// misconfiguration `parse_latency` exists to close.
fn parse_arrivals(f: &HashMap<String, String>) -> Result<Option<(f64, &'static str)>, String> {
    let rate = match f.get("rate") {
        None => None,
        Some(s) => match s.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => Some(v),
            _ => return Err(format!("invalid --rate '{s}' (positive jobs/s expected)")),
        },
    };
    let shape: &'static str = match f.get("arrivals").map(String::as_str) {
        None | Some("poisson") => "poisson",
        Some("mmpp") | Some("diurnal") => "mmpp",
        Some("flash") | Some("burst") => "flash",
        Some(other) => {
            return Err(format!("unknown arrival process '{other}' (valid: poisson mmpp flash)"))
        }
    };
    if f.contains_key("arrivals") && rate.is_none() {
        return Err("--arrivals requires --rate".into());
    }
    Ok(rate.map(|r| (r, shape)))
}

/// Stamp the arrival process chosen by [`parse_arrivals`]. The mmpp
/// and flash shapes keep the same *average* rate as the plain Poisson
/// one (mmpp: equal-dwell 1.8R/0.2R phases of 30 s mean; flash: 0.5R
/// base with 5R bursts over 20% of a 30 s period = 1.4R offered in
/// burst regimes), so `--rate` means the same thing under every shape.
fn apply_arrivals(
    jobs: &mut [mgb::coordinator::JobSpec],
    rate: f64,
    shape: &str,
    seed: u64,
) {
    match shape {
        "poisson" => poisson_arrivals(jobs, rate, seed),
        "mmpp" => mmpp_arrivals(jobs, &[1.8 * rate, 0.2 * rate], 30.0, seed),
        "flash" => flash_crowd_arrivals(jobs, 0.5 * rate, 5.0 * rate, 30.0, 0.2, seed),
        other => unreachable!("parse_arrivals admitted shape '{other}'"),
    }
}

/// `--admit off|token|util` enables the cluster frontend's admission
/// controller (bare flag = token bucket; `off`, the default, replays
/// bit-identically to not passing the flag). `--admit-rate R` /
/// `--admit-burst B` tune the token bucket; `--admit-util S` sets the
/// utilization policy's backlog threshold in seconds. `--frontend-q
/// fifo|prio|wfq` picks the frontend queue discipline (needs a
/// latency model to have a queue at all — checked in
/// [`parse_run_opts`]). Tuning flags without an enabled `--admit`
/// policy are errors, like the preemption family.
fn parse_admit(
    f: &HashMap<String, String>,
) -> Result<(Option<AdmissionConfig>, &'static str), String> {
    let fq = match f.get("frontend-q") {
        None => "fifo",
        Some(s) => mgb::sched::canonical_frontend_q(s)
            .ok_or_else(|| format!("unknown frontend queue '{s}' (valid: fifo prio wfq)"))?,
    };
    let policy = match f.get("admit") {
        None => None,
        Some(s) => Some(mgb::sched::canonical_admit(s).ok_or_else(|| {
            format!("unknown admission policy '{s}' (valid: off token util)")
        })?),
    };
    if policy.is_none() || policy == Some("off") {
        for dep in ["admit-rate", "admit-burst", "admit-util"] {
            if f.contains_key(dep) {
                return Err(format!("--{dep} requires an enabled --admit policy"));
            }
        }
        return Ok((None, fq));
    }
    let mut cfg = AdmissionConfig { policy: policy.unwrap(), ..Default::default() };
    if let Some(s) = f.get("admit-rate") {
        cfg.rate_per_s = match s.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => v,
            _ => return Err(format!("invalid --admit-rate '{s}' (positive jobs/s expected)")),
        };
    }
    if let Some(s) = f.get("admit-burst") {
        cfg.burst = match s.parse::<f64>() {
            Ok(v) if v >= 1.0 && v.is_finite() => v,
            _ => return Err(format!("invalid --admit-burst '{s}' (burst of >= 1 job expected)")),
        };
    }
    if let Some(s) = f.get("admit-util") {
        cfg.util_threshold_s = match s.parse::<f64>() {
            Ok(v) if v > 0.0 && v.is_finite() => v,
            _ => return Err(format!("invalid --admit-util '{s}' (positive seconds expected)")),
        };
    }
    Ok((Some(cfg), fq))
}

fn seed_of(f: &HashMap<String, String>) -> u64 {
    f.get("seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(bench_harness::DEFAULT_SEED)
}

fn print_result(r: &RunResult) {
    let cluster = if r.n_nodes > 1 {
        format!(" nodes={} dispatch={}", r.n_nodes, r.dispatcher)
    } else {
        String::new()
    };
    println!(
        "scheduler={} node={}{} workers={} jobs={} completed={} crashed={} \
         makespan={:.1}s throughput={:.4}j/s mean_turnaround={:.1}s kernel_slowdown={:.2}%",
        r.scheduler,
        r.node,
        cluster,
        r.workers,
        r.jobs.len(),
        r.completed(),
        r.crashed(),
        r.makespan,
        r.throughput(),
        r.mean_turnaround(),
        r.kernel_slowdown_pct()
    );
    if r.preemptions > 0 {
        println!(
            "preemptions={} wasted_work={:.1}s ckpt_overhead={:.1}s",
            r.preemptions, r.wasted_work_s, r.ckpt_overhead_s
        );
    }
    if r.migrations > 0 {
        println!(
            "migrations={} migrate_bytes={:.2}GiB",
            r.migrations,
            r.migrate_bytes as f64 / (1u64 << 30) as f64
        );
    }
    if r.rejected > 0 || r.degraded > 0 {
        println!(
            "admission: rejected={} ({:.0}%) degraded={}",
            r.rejected,
            100.0 * r.reject_rate(),
            r.degraded
        );
    }
    for class in mgb::sched::SloClass::ALL {
        if let Some(a) = r.slo_attainment(class) {
            println!(
                "slo[{}] attainment={:.0}% mean_turnaround={:.1}s",
                class.name(),
                100.0 * a,
                r.mean_turnaround_of_slo(class)
            );
        }
    }
}

fn cmd_bench(f: &HashMap<String, String>) -> i32 {
    let seed = seed_of(f);
    match f.get("exp").map(String::as_str).unwrap_or("all") {
        "all" => {
            for r in bench_harness::run_all(seed) {
                r.print();
            }
            0
        }
        name => match bench_harness::run_experiment(name, seed) {
            Some(r) => {
                r.print();
                0
            }
            None => {
                eprintln!("unknown experiment '{name}'");
                2
            }
        },
    }
}

fn cmd_run(f: &HashMap<String, String>) -> i32 {
    let opts = match parse_run_opts(f) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("run: {e}");
            return 2;
        }
    };
    let cluster = parse_cluster(f);
    let mode = parse_sched(f);
    let seed = seed_of(f);
    let wl = f.get("workload").map(String::as_str).unwrap_or("W1");
    let Some(workload) = Workload::by_id(wl) else {
        eprintln!("unknown workload '{wl}' (W1..W8)");
        return 2;
    };
    let workers = f
        .get("workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| bench_harness::mgb_workers(&cluster.nodes[0]));
    let mut jobs = workload.jobs(seed);
    if opts.slo {
        mgb::workloads::assign_slo(&mut jobs);
    }
    if opts.interference {
        mgb::workloads::assign_interference(&mut jobs);
    }
    if let Some((rate, shape)) = opts.arrivals {
        apply_arrivals(&mut jobs, rate, shape, seed);
    }
    let cfg = ClusterConfig {
        cluster,
        mode,
        workers_per_node: workers,
        dispatch: parse_dispatch(f),
        preempt: opts.preempt,
        latency: opts.latency,
        admit: opts.admit,
        frontend_q: opts.frontend_q,
        compile_traces: opts.compile_traces,
    };
    let mut sanitizer: Option<SanitizerReport> = None;
    let r = if opts.sanitize {
        if f.get("compute").map(String::as_str) == Some("real") {
            // run_cluster_sanitized takes no launch hook; refusing beats
            // silently dropping the artifact executions.
            eprintln!("run: --sanitize is incompatible with --compute real");
            return 2;
        }
        let (r, rep) = run_cluster_sanitized(cfg, jobs);
        sanitizer = Some(rep);
        r
    } else if f.get("compute").map(String::as_str) == Some("real") {
        let dir = f.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
        let reg = match KernelRegistry::new(&dir) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("artifacts: {e}");
                return 1;
            }
        };
        let mut executed: u64 = 0;
        let mut hook = |artifact: &str| {
            if let Ok(exe) = reg.get(artifact) {
                let _ = exe; // compiled; numerics exercised by `mgb artifacts`
                executed += 1;
            }
        };
        let r = run_cluster_with_hook(cfg, jobs, Some(&mut hook));
        println!("real-compute launches resolved: {executed}");
        r
    } else {
        run_cluster(cfg, jobs)
    };
    print_result(&r);
    for j in &r.jobs {
        let node = if r.n_nodes > 1 { format!(" node={}", j.node) } else { String::new() };
        let preempted = if j.preemptions > 0 {
            format!(" preempted={} wasted={:.1}s", j.preemptions, j.wasted_s)
        } else {
            String::new()
        };
        println!(
            "  {:<24} {}{} start={:>7.1}s end={:>7.1}s kernels={} slowdown={:+.2}%{}",
            j.name,
            if j.crashed { "CRASH" } else { "ok   " },
            node,
            j.started,
            j.ended,
            j.n_kernels,
            100.0 * j.kernel_slowdown(),
            preempted
        );
    }
    print_sanitizer(sanitizer)
}

/// Print a `--sanitize` report (if one was produced): exit 0 on a
/// clean run, 1 on any violation — so CI can gate on the invariants.
fn print_sanitizer(report: Option<SanitizerReport>) -> i32 {
    let Some(rep) = report else { return 0 };
    let suppressed = if rep.suppressed > 0 {
        format!(" (+{} suppressed)", rep.suppressed)
    } else {
        String::new()
    };
    println!(
        "sanitizer: events_checked={} violations={}{}",
        rep.events_checked,
        rep.violations.len(),
        suppressed
    );
    for v in &rep.violations {
        println!("  t={:.6}s: {}", v.t, v.what);
    }
    if rep.is_clean() {
        0
    } else {
        1
    }
}

fn cmd_nn(f: &HashMap<String, String>) -> i32 {
    let opts = match parse_run_opts(f) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("nn: {e}");
            return 2;
        }
    };
    let cluster = parse_cluster(f);
    let mode = parse_sched(f);
    let seed = seed_of(f);
    let workers = f.get("workers").and_then(|s| s.parse().ok()).unwrap_or(8);
    let mut jobs = match f.get("task").map(String::as_str).unwrap_or("mix") {
        "predict" => nn_homogeneous(NnTask::Predict),
        "train" => nn_homogeneous(NnTask::Train),
        "detect" => nn_homogeneous(NnTask::Detect),
        "generate" => nn_homogeneous(NnTask::Generate),
        "mix" => {
            let n = f.get("jobs").and_then(|s| s.parse().ok()).unwrap_or(128);
            nn_mix(n, seed)
        }
        other => {
            eprintln!("unknown nn task '{other}'");
            return 2;
        }
    };
    if opts.slo {
        mgb::workloads::assign_slo(&mut jobs);
    }
    if opts.interference {
        mgb::workloads::assign_interference(&mut jobs);
    }
    if let Some((rate, shape)) = opts.arrivals {
        apply_arrivals(&mut jobs, rate, shape, seed);
    }
    let cfg = ClusterConfig {
        cluster,
        mode,
        workers_per_node: workers,
        dispatch: parse_dispatch(f),
        preempt: opts.preempt,
        latency: opts.latency,
        admit: opts.admit,
        frontend_q: opts.frontend_q,
        compile_traces: opts.compile_traces,
    };
    if opts.sanitize {
        let (r, rep) = run_cluster_sanitized(cfg, jobs);
        print_result(&r);
        return print_sanitizer(Some(rep));
    }
    let r = run_cluster(cfg, jobs);
    print_result(&r);
    0
}

fn cmd_compile(path: Option<&str>) -> i32 {
    let Some(path) = path else {
        eprintln!("usage: mgb compile <file.gir>");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    let program = match parse_program(&text) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("parse error: {e:#}");
            return 1;
        }
    };
    let compiled = compile(&program);
    println!("{} function(s), {} GPU task(s)", compiled.program.funcs.len(), compiled.tasks.len());
    for t in &compiled.tasks {
        println!(
            "task {}: launches={:?} mem_objs={:?} lazy={} probe_at={:?}",
            t.id, t.launches, t.mem_objs, t.lazy, t.probe_at
        );
        println!("  mem_bytes = {}", t.mem_bytes);
        println!("  grid = {}, block = {}, heap = {}", t.grid, t.block, t.heap_bytes);
        println!("  written_bytes = {}", t.written_bytes);
    }
    0
}

/// `mgb lint [--builtin] [--json PATH] [file.gir ...]` — run the
/// compiler-side verifier ([`verify_compiled`]) over IR programs:
/// explicit `.gir` files, and with `--builtin` every built-in Rodinia
/// combo and Darknet task program. Prints human-readable diagnostics
/// per program; `--json PATH` additionally writes one machine-readable
/// document covering all of them (the CI artifact). Exit 1 if any
/// program fails to parse or lints with errors, 2 on usage errors.
fn cmd_lint(args: &[String]) -> i32 {
    let mut paths: Vec<String> = Vec::new();
    let mut json_path: Option<String> = None;
    let mut builtin = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--builtin" => {
                builtin = true;
                i += 1;
            }
            "--json" => match args.get(i + 1) {
                Some(p) if !p.starts_with("--") => {
                    json_path = Some(p.clone());
                    i += 2;
                }
                _ => {
                    eprintln!("lint: --json requires a path");
                    return 2;
                }
            },
            s if s.starts_with("--") => {
                eprintln!(
                    "lint: unknown flag '{s}' (valid flags: {})",
                    LINT_FLAGS.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(" ")
                );
                return 2;
            }
            s => {
                paths.push(s.to_string());
                i += 1;
            }
        }
    }
    if !builtin && paths.is_empty() {
        eprintln!("usage: mgb lint [--builtin] [--json PATH] <file.gir>...");
        return 2;
    }
    let mut targets: Vec<(String, mgb::compiler::CompiledProgram)> = Vec::new();
    for p in &paths {
        let text = match std::fs::read_to_string(p) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("{p}: {e}");
                return 1;
            }
        };
        let program = match parse_program(&text) {
            Ok(prog) => prog,
            Err(e) => {
                eprintln!("{p}: parse error: {e:#}");
                return 1;
            }
        };
        targets.push((p.clone(), compile(&program)));
    }
    if builtin {
        for c in COMBOS.iter() {
            targets.push((format!("rodinia/{}", c.name), compile(&c.program())));
        }
        for t in NN_TASKS.iter() {
            targets.push((format!("darknet/{}", t.profile().name), compile(&t.program())));
        }
    }
    // Verify once per distinct program key: repeating a path (or a
    // builtin name colliding with one) must not re-run the verifier —
    // the same dedup contract the engine's trace cache gives job specs.
    let mut seen = std::collections::HashSet::new();
    targets.retain(|(name, _)| seen.insert(name.clone()));
    let mut failed = false;
    let mut json = String::from("{\n  \"programs\": [\n");
    let n_targets = targets.len();
    for (i, (name, compiled)) in targets.iter().enumerate() {
        let rep = verify_compiled(compiled);
        if rep.is_clean() {
            println!("{name}: clean");
        } else {
            println!("{name}:");
            for d in &rep.diagnostics {
                println!("  {d}");
            }
            println!("  {} error(s), {} warning(s)", rep.n_errors(), rep.n_warnings());
        }
        failed |= rep.n_errors() > 0;
        // One entry per program; the report's own JSON is indented in.
        let sep = if i + 1 == n_targets { "" } else { "," };
        let body = rep.to_json();
        let body = body.trim_end().replace('\n', "\n    ");
        json.push_str(&format!(
            "    {{\"program\": \"{}\", \"report\": {body}}}{sep}\n",
            name.replace('\\', "\\\\").replace('"', "\\\"")
        ));
    }
    json.push_str(&format!("  ],\n  \"failed\": {failed}\n}}\n"));
    if let Some(p) = json_path {
        if let Err(e) = std::fs::write(&p, &json) {
            eprintln!("lint: writing {p}: {e}");
            return 1;
        }
    }
    println!("{n_targets} program(s) linted");
    if failed {
        1
    } else {
        0
    }
}

fn cmd_artifacts(f: &HashMap<String, String>) -> i32 {
    let dir = f.get("dir").cloned().unwrap_or_else(|| "artifacts".into());
    let reg = match KernelRegistry::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let names = reg.available();
    if names.is_empty() {
        eprintln!("no artifacts in {dir} — run `make artifacts`");
        return 1;
    }
    for n in &names {
        match reg.get(n) {
            Ok(_) => println!("{n:<18} compiles OK"),
            Err(e) => {
                println!("{n:<18} FAILED: {e}");
                return 1;
            }
        }
    }
    println!("{} artifacts OK", names.len());
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn flags_parse_pairs_and_bare_flags() {
        let f = flags(&argv(&["--workload", "W5", "--preempt", "--nodes", "4"]), RUN_FLAGS)
            .expect("all flags valid");
        assert_eq!(f.get("workload").map(String::as_str), Some("W5"));
        assert_eq!(f.get("preempt").map(String::as_str), Some("true"), "bare flag");
        assert_eq!(f.get("nodes").map(String::as_str), Some("4"));
    }

    #[test]
    fn unknown_flag_is_an_error_naming_it_and_the_valid_set() {
        // The regression: a typo'd --probe-rt used to be dropped on the
        // floor and the run silently measured the zero-latency model.
        let e = flags(&argv(&["--probe-rt", "0.005"]), RUN_FLAGS).unwrap_err();
        assert!(e.contains("--probe-rt"), "names the offender: {e}");
        assert!(e.contains("--probe-rtt"), "offers the valid set: {e}");
        // Valid sets are per-subcommand: bench takes no --workload.
        assert!(flags(&argv(&["--workload", "W1"]), BENCH_FLAGS).is_err());
        assert!(flags(&argv(&["--exp", "latency"]), BENCH_FLAGS).is_ok());
        // Single-dash typos and stray positionals are the same silent
        // misconfiguration: rejected, not skipped.
        assert!(flags(&argv(&["-probe-rtt", "0.005"]), RUN_FLAGS).is_err());
        assert!(flags(&argv(&["--workload", "W1", "extra"]), RUN_FLAGS).is_err());
        // A flag's value may still look dash-ish (negative numbers).
        let f = flags(&argv(&["--rate", "-1"]), RUN_FLAGS).unwrap();
        assert_eq!(f.get("rate").map(String::as_str), Some("-1"));
    }

    #[test]
    fn every_documented_latency_flag_is_accepted() {
        let f = flags(
            &argv(&[
                "--dispatch", "latency", "--latency", "lan", "--reprobe-after", "0.5",
                "--reprobe-budget", "2", "--coalesce-window", "0.01",
            ]),
            RUN_FLAGS,
        )
        .expect("new flags are in the valid set");
        let m = parse_latency(&f).expect("valid values");
        assert_eq!(m.reprobe_after_s, 0.5);
        assert_eq!(m.reprobe_budget, 2);
        assert_eq!(m.coalesce_window_s, 0.01);
        assert_eq!(parse_dispatch(&f), "latency");
    }

    #[test]
    fn reprobe_after_alone_implies_a_budget_of_one() {
        let f = flags(&argv(&["--reprobe-after", "0.5"]), RUN_FLAGS).unwrap();
        let m = parse_latency(&f).expect("valid value");
        assert_eq!(m.reprobe_after_s, 0.5);
        assert_eq!(m.reprobe_budget, 1, "the flag's obvious meaning: re-probe once");
        assert!(m.reprobe_enabled());
    }

    #[test]
    fn preempt_flags_parse_and_validate_like_latency() {
        // Happy path: migration + SLO policy + explicit bandwidth.
        let f = flags(
            &argv(&["--preempt", "slo", "--migrate", "cluster", "--migrate-bw", "2.5e9",
                    "--ckpt-cost", "0.1", "--slo"]),
            RUN_FLAGS,
        )
        .expect("new flags are in the valid set");
        let cfg = parse_preempt(&f).expect("valid").expect("enabled");
        assert_eq!(cfg.policy, "slo");
        assert_eq!(cfg.migrate, "cluster");
        assert_eq!(cfg.migrate_bytes_per_s, 2.5e9);
        assert_eq!(cfg.ckpt_base_s, 0.1);
        assert!(parse_slo(&f).expect("bare --slo"), "bare flag enables classing");
        // Bare --migrate means cluster; bare --preempt the default policy.
        let f = flags(&argv(&["--preempt", "--migrate"]), RUN_FLAGS).unwrap();
        let cfg = parse_preempt(&f).unwrap().unwrap();
        assert_eq!((cfg.policy, cfg.migrate), ("min-progress", "cluster"));
        // No --preempt, no config — and no silent stamping either way.
        let f = flags(&argv(&["--workload", "W1"]), RUN_FLAGS).unwrap();
        assert!(parse_preempt(&f).unwrap().is_none());
        assert!(!parse_slo(&f).unwrap());
    }

    #[test]
    fn invalid_preempt_values_are_errors_not_warnings() {
        // The same closure parse_latency got in PR 4: warn-and-default
        // (unknown policy) and swallow-on-parse-failure (--ckpt-cost)
        // both measured a different preemption model than asked for.
        for args in [
            vec!["--preempt", "maxmemm"],
            vec!["--preempt", "--ckpt-cost", "fast"],
            vec!["--preempt", "--ckpt-cost", "-1"],
            vec!["--preempt", "--migrate", "sideways"],
            vec!["--preempt", "--migrate-bw", "0"],
            vec!["--preempt", "--migrate-bw", "-2e9"],
            vec!["--preempt", "--migrate-bw", "10GbE"],
        ] {
            let f = flags(&argv(&args), RUN_FLAGS).unwrap();
            let e = parse_preempt(&f).unwrap_err();
            assert!(e.contains(args[args.len() - 1]), "{args:?}: names the bad value: {e}");
        }
        // Preemption-dependent flags without --preempt are the silent
        // no-op misconfiguration — rejected, naming the dependency.
        for dep in [["--migrate", "cluster"], ["--migrate-bw", "1e9"], ["--ckpt-cost", "0.1"]] {
            let f = flags(&argv(&dep), RUN_FLAGS).unwrap();
            let e = parse_preempt(&f).unwrap_err();
            assert!(e.contains("requires --preempt"), "{dep:?}: {e}");
        }
        let f = flags(&argv(&["--slo", "tight"]), RUN_FLAGS).unwrap();
        assert!(parse_slo(&f).is_err(), "unknown --slo value rejected");
    }

    #[test]
    fn interference_flag_parses_like_slo() {
        // Bare flag, on, off — the same bare-flag convention as --slo.
        let f = flags(&argv(&["--interference"]), RUN_FLAGS).expect("flag in the valid set");
        assert!(parse_interference(&f).expect("bare flag"));
        let f = flags(&argv(&["--interference", "on"]), NN_FLAGS).unwrap();
        assert!(parse_interference(&f).unwrap());
        let f = flags(&argv(&["--interference", "off"]), RUN_FLAGS).unwrap();
        assert!(!parse_interference(&f).unwrap());
        // No flag, no stamping; unknown values are errors, not shrugs.
        let f = flags(&argv(&["--workload", "W1"]), RUN_FLAGS).unwrap();
        assert!(!parse_interference(&f).unwrap());
        let f = flags(&argv(&["--interference", "heavy"]), RUN_FLAGS).unwrap();
        assert!(parse_interference(&f).is_err());
        // The partition dispatcher is a valid --dispatch value (with
        // its "mig" alias), not a warn-and-default typo.
        let f = flags(&argv(&["--dispatch", "partition"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_dispatch(&f), "partition");
        let f = flags(&argv(&["--dispatch", "mig"]), NN_FLAGS).unwrap();
        assert_eq!(parse_dispatch(&f), "partition");
    }

    #[test]
    fn sanitize_flag_parses_like_slo() {
        // Bare flag, on, off — the same convention as --slo, in both
        // the run and nn flag sets.
        let f = flags(&argv(&["--sanitize"]), RUN_FLAGS).expect("flag in the valid set");
        assert!(parse_sanitize(&f).expect("bare flag"));
        let f = flags(&argv(&["--sanitize", "on"]), NN_FLAGS).unwrap();
        assert!(parse_sanitize(&f).unwrap());
        let f = flags(&argv(&["--sanitize", "off"]), RUN_FLAGS).unwrap();
        assert!(!parse_sanitize(&f).unwrap());
        // No flag, no sanitizer; unknown values are errors, not shrugs.
        let f = flags(&argv(&["--workload", "W1"]), RUN_FLAGS).unwrap();
        assert!(!parse_sanitize(&f).unwrap());
        let f = flags(&argv(&["--sanitize", "hard"]), RUN_FLAGS).unwrap();
        assert!(parse_sanitize(&f).is_err());
    }

    #[test]
    fn invalid_rate_values_are_errors_not_warnings() {
        // The regression: apply_rate used to warn on a non-positive
        // rate and silently swallow an unparsable one, then run the
        // closed batch-at-0 system either way.
        for args in [
            ["--rate", "0"],
            ["--rate", "-1"],
            ["--rate", "inf"],
            ["--rate", "NaN"],
            ["--rate", "12j/s"],
        ] {
            let f = flags(&argv(&args), RUN_FLAGS).unwrap();
            let e = parse_arrivals(&f).unwrap_err();
            assert!(e.contains(args[1]), "{args:?}: names the bad value: {e}");
        }
        // Happy paths: bare rate defaults to poisson; shapes select.
        let f = flags(&argv(&["--rate", "2.5"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_arrivals(&f).unwrap(), Some((2.5, "poisson")));
        let f = flags(&argv(&["--rate", "1", "--arrivals", "mmpp"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_arrivals(&f).unwrap(), Some((1.0, "mmpp")));
        let f = flags(&argv(&["--rate", "1", "--arrivals", "flash"]), NN_FLAGS).unwrap();
        assert_eq!(parse_arrivals(&f).unwrap(), Some((1.0, "flash")));
        // A shape without a rate is the silent no-op; unknown shapes
        // are typos.
        let f = flags(&argv(&["--arrivals", "flash"]), RUN_FLAGS).unwrap();
        assert!(parse_arrivals(&f).unwrap_err().contains("requires --rate"));
        let f = flags(&argv(&["--arrivals", "bursty", "--rate", "1"]), RUN_FLAGS).unwrap();
        assert!(parse_arrivals(&f).is_err());
        // No flag at all: closed batch, no process.
        let f = flags(&argv(&["--workload", "W1"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_arrivals(&f).unwrap(), None);
    }

    #[test]
    fn admit_flags_parse_and_validate_like_preempt() {
        // Happy path: explicit policy + tuned bucket.
        let f = flags(
            &argv(&["--admit", "token", "--admit-rate", "2", "--admit-burst", "4"]),
            RUN_FLAGS,
        )
        .expect("new flags are in the valid set");
        let (cfg, fq) = parse_admit(&f).unwrap();
        let cfg = cfg.expect("enabled");
        assert_eq!(cfg.policy, "token");
        assert_eq!(cfg.rate_per_s, 2.0);
        assert_eq!(cfg.burst, 4.0);
        assert_eq!(fq, "fifo");
        // Bare --admit means the token bucket; util takes a threshold.
        let f = flags(&argv(&["--admit"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_admit(&f).unwrap().0.unwrap().policy, "token");
        let f = flags(&argv(&["--admit", "util", "--admit-util", "10"]), NN_FLAGS).unwrap();
        let cfg = parse_admit(&f).unwrap().0.unwrap();
        assert_eq!((cfg.policy, cfg.util_threshold_s), ("util", 10.0));
        // --admit off is the default: no config, bit-identical replay.
        let f = flags(&argv(&["--admit", "off"]), RUN_FLAGS).unwrap();
        assert!(parse_admit(&f).unwrap().0.is_none());
        let f = flags(&argv(&["--workload", "W1"]), RUN_FLAGS).unwrap();
        assert!(parse_admit(&f).unwrap().0.is_none());
        // Tuning flags without an enabled policy are the silent no-op.
        for dep in [["--admit-rate", "2"], ["--admit-burst", "4"], ["--admit-util", "10"]] {
            let f = flags(&argv(&dep), RUN_FLAGS).unwrap();
            assert!(parse_admit(&f).unwrap_err().contains("requires an enabled --admit"));
            let mut with_off = vec!["--admit", "off"];
            with_off.extend_from_slice(&dep);
            let f = flags(&argv(&with_off), RUN_FLAGS).unwrap();
            assert!(parse_admit(&f).is_err(), "{dep:?} under --admit off");
        }
        // Bad values are errors naming the value; bad policies too.
        for args in [
            vec!["--admit", "strict"],
            vec!["--admit", "--admit-rate", "0"],
            vec!["--admit", "--admit-rate", "-1"],
            vec!["--admit", "--admit-rate", "fast"],
            vec!["--admit", "--admit-burst", "0.5"],
            vec!["--admit", "--admit-burst", "inf"],
            vec!["--admit", "util", "--admit-util", "0"],
            vec!["--admit", "util", "--admit-util", "NaN"],
        ] {
            let f = flags(&argv(&args), RUN_FLAGS).unwrap();
            let e = parse_admit(&f).unwrap_err();
            assert!(e.contains(args[args.len() - 1]), "{args:?}: names the bad value: {e}");
        }
        // Frontend disciplines canonicalise; typos are errors.
        let f = flags(&argv(&["--frontend-q", "priority"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_admit(&f).unwrap().1, "prio");
        let f = flags(&argv(&["--frontend-q", "lifo"]), RUN_FLAGS).unwrap();
        assert!(parse_admit(&f).is_err());
    }

    #[test]
    fn frontend_q_requires_a_latency_model() {
        // A discipline with no frontend latency never queues anything
        // — rejected as a silent no-op, not silently ignored.
        let f = flags(&argv(&["--frontend-q", "wfq"]), RUN_FLAGS).unwrap();
        let e = parse_run_opts(&f).unwrap_err();
        assert!(e.contains("--frontend-q"), "{e}");
        let f = flags(&argv(&["--frontend-q", "wfq", "--latency", "lan"]), RUN_FLAGS).unwrap();
        let opts = parse_run_opts(&f).expect("lan gives the frontend a queue to order");
        assert_eq!(opts.frontend_q, "wfq");
        // fifo (the default) is always fine — it IS the ungoverned path.
        let f = flags(&argv(&["--workload", "W1"]), RUN_FLAGS).unwrap();
        assert_eq!(parse_run_opts(&f).unwrap().frontend_q, "fifo");
    }

    #[test]
    fn invalid_latency_values_are_errors_not_warnings() {
        // A warned-and-ignored value measures a different model than
        // the one asked for — the same silent misconfiguration as an
        // unknown flag, and rejected the same way.
        for args in [
            ["--latency", "wna"],
            ["--probe-rtt", "0.005s"],
            ["--probe-rtt", "-1"],
            ["--dispatch-cost", "fast"],
            ["--reprobe-after", "0"],
            ["--reprobe-after", "-0.5"],
            ["--reprobe-budget", "-1"],
            ["--reprobe-budget", "1.5"],
            ["--coalesce-window", "10ms"],
        ] {
            let f = flags(&argv(&args), RUN_FLAGS).unwrap();
            let e = parse_latency(&f).unwrap_err();
            assert!(e.contains(args[1]), "{args:?}: error names the bad value: {e}");
        }
        // The happy paths still parse.
        let f = flags(&argv(&["--latency", "wan", "--probe-rtt", "0.25"]), RUN_FLAGS).unwrap();
        let m = parse_latency(&f).unwrap();
        assert_eq!(m.probe_rtt_s, 0.25);
        assert!(m.dispatch_base_s > 0.0, "wan preset survives the override");
    }
}

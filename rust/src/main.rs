//! `mgb` — leader binary for the MGB reproduction.
//!
//! Subcommands (hand-rolled parser; the offline crate set has no clap):
//!
//! ```text
//! mgb bench [--exp fig4|fig5|fig6|table2|table3|table4|nn128|ablation|cluster|preempt|latency|all] [--seed N]
//! mgb run   --workload W1..W8 [--node p100x2|v100x4] [--sched sa|cg|mgb2|mgb3|schedgpu|static]
//!           [--nodes N] [--dispatch rr|least|mem] [--rate JOBS_PER_S]
//!           [--preempt [min-progress|max-mem|never]] [--ckpt-cost SECONDS]
//!           [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
//!           [--workers N] [--seed N] [--compute real|modeled] [--artifacts DIR]
//! mgb nn    [--task predict|train|detect|generate|mix] [--jobs N] [--sched ...] [--workers N]
//!           [--nodes N] [--dispatch rr|least|mem] [--rate JOBS_PER_S]
//!           [--preempt [min-progress|max-mem|never]] [--ckpt-cost SECONDS]
//!           [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
//! mgb compile <file.gir> — run the compiler pass on an IR file, print tasks + probes
//! mgb artifacts [--dir DIR] — list and smoke-execute the AOT artifacts
//! ```

use mgb::bench_harness;
use mgb::compiler::compile;
use mgb::coordinator::{
    run_cluster, run_cluster_with_hook, ClusterConfig, RunResult, SchedMode,
};
use mgb::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use mgb::ir::parse::parse_program;
use mgb::runtime::KernelRegistry;
use mgb::workloads::{nn_homogeneous, nn_mix, poisson_arrivals, NnTask, Workload};
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("bench") => cmd_bench(&flags(&args[1..])),
        Some("run") => cmd_run(&flags(&args[1..])),
        Some("nn") => cmd_nn(&flags(&args[1..])),
        Some("compile") => cmd_compile(args.get(1).map(String::as_str)),
        Some("artifacts") => cmd_artifacts(&flags(&args[1..])),
        _ => {
            eprintln!("usage: mgb <bench|run|nn|compile|artifacts> [flags]\n{}", HELP);
            2
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
  bench --exp <fig4|fig5|fig6|table2|table3|table4|nn128|ablation|cluster|preempt|latency|all> [--seed N]
  run   --workload W1..W8 [--node p100x2|v100x4] [--sched sa|cg|mgb2|mgb3|schedgpu|static]
        [--nodes N] [--dispatch rr|least|mem] [--rate JOBS_PER_S]
        [--preempt [min-progress|max-mem|never]] [--ckpt-cost SECONDS]
        [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
        [--workers N] [--seed N] [--compute real] [--artifacts DIR]
  nn    [--task predict|train|detect|generate|mix] [--jobs N] [--sched ..] [--workers N]
        [--nodes N] [--dispatch rr|least|mem] [--rate JOBS_PER_S]
        [--preempt [min-progress|max-mem|never]] [--ckpt-cost SECONDS]
        [--latency off|lan|wan] [--probe-rtt SECONDS] [--dispatch-cost SECONDS]
  compile <file.gir>
  artifacts [--dir DIR]";

fn flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    m.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    m.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    m
}

fn parse_node(f: &HashMap<String, String>) -> NodeSpec {
    match f.get("node").map(String::as_str) {
        Some("p100x2") => NodeSpec::p100x2(),
        Some("v100x4") | None => NodeSpec::v100x4(),
        Some(other) => {
            eprintln!("unknown node '{other}', using v100x4");
            NodeSpec::v100x4()
        }
    }
}

fn parse_sched(f: &HashMap<String, String>) -> SchedMode {
    match f.get("sched").map(String::as_str) {
        Some("sa") => SchedMode::Sa,
        Some("cg") => SchedMode::Cg,
        Some("mgb2") | Some("alg2") => SchedMode::Policy("mgb2"),
        Some("schedgpu") => SchedMode::Policy("schedgpu"),
        Some("static") => SchedMode::Static,
        _ => SchedMode::Policy("mgb3"),
    }
}

/// `--nodes N` scales the chosen node preset to an N-node cluster.
fn parse_cluster(f: &HashMap<String, String>) -> ClusterSpec {
    let node = parse_node(f);
    let n = f.get("nodes").and_then(|s| s.parse::<usize>().ok()).unwrap_or(1);
    if n <= 1 {
        ClusterSpec::single(node)
    } else {
        ClusterSpec::homogeneous(node, n)
    }
}

/// `--preempt [POLICY]` enables checkpoint/restart preemption (a bare
/// flag selects the default min-progress policy); `--ckpt-cost S` sets
/// the fixed per-checkpoint latency of the cost model.
fn parse_preempt(f: &HashMap<String, String>) -> Option<mgb::sched::PreemptConfig> {
    let name = f.get("preempt")?;
    let policy = mgb::sched::canonical_preempt(name).unwrap_or_else(|| {
        eprintln!("unknown preemption policy '{name}', using min-progress");
        "min-progress"
    });
    let mut cfg = mgb::sched::PreemptConfig { policy, ..Default::default() };
    if let Some(c) = f.get("ckpt-cost").and_then(|s| s.parse::<f64>().ok()) {
        cfg.ckpt_base_s = c.max(0.0);
    }
    Some(cfg)
}

fn parse_dispatch(f: &HashMap<String, String>) -> &'static str {
    match f.get("dispatch") {
        None => "rr",
        Some(s) => mgb::sched::canonical_dispatch(s).unwrap_or_else(|| {
            eprintln!("unknown dispatcher '{s}', using rr");
            "rr"
        }),
    }
}

/// `--latency off|lan|wan` picks a frontend latency preset (`off`, the
/// default, is the paper's free-frontend idealisation; a bare
/// `--latency` selects `lan`). `--probe-rtt S` / `--dispatch-cost S`
/// override the probe round-trip and the dispatch base cost in seconds
/// — setting either on top of `off` turns the model on with only that
/// term.
fn parse_latency(f: &HashMap<String, String>) -> LatencyModel {
    let mut m = match f.get("latency").map(String::as_str) {
        None | Some("off") => LatencyModel::off(),
        Some("on") | Some("true") | Some("lan") => LatencyModel::lan(),
        Some("wan") => LatencyModel::wan(),
        Some(other) => {
            eprintln!("unknown latency preset '{other}', using off");
            LatencyModel::off()
        }
    };
    if let Some(s) = f.get("probe-rtt") {
        match s.parse::<f64>() {
            Ok(r) => m.probe_rtt_s = r.max(0.0),
            Err(_) => eprintln!("invalid --probe-rtt '{s}' (seconds expected), ignoring"),
        }
    }
    if let Some(s) = f.get("dispatch-cost") {
        match s.parse::<f64>() {
            Ok(c) => {
                // "Fixed dispatch latency": the explicit override
                // replaces the preset's whole dispatch model,
                // including wan's per-byte term.
                m.dispatch_base_s = c.max(0.0);
                m.dispatch_s_per_byte = 0.0;
            }
            Err(_) => eprintln!("invalid --dispatch-cost '{s}' (seconds expected), ignoring"),
        }
    }
    m
}

/// `--rate R` stamps Poisson arrivals over the batch (open system).
fn apply_rate(f: &HashMap<String, String>, jobs: &mut [mgb::coordinator::JobSpec], seed: u64) {
    if let Some(rate) = f.get("rate").and_then(|s| s.parse::<f64>().ok()) {
        if rate > 0.0 {
            poisson_arrivals(jobs, rate, seed);
        } else {
            eprintln!("--rate must be positive; running batch-at-0");
        }
    }
}

fn seed_of(f: &HashMap<String, String>) -> u64 {
    f.get("seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(bench_harness::DEFAULT_SEED)
}

fn print_result(r: &RunResult) {
    let cluster = if r.n_nodes > 1 {
        format!(" nodes={} dispatch={}", r.n_nodes, r.dispatcher)
    } else {
        String::new()
    };
    println!(
        "scheduler={} node={}{} workers={} jobs={} completed={} crashed={} \
         makespan={:.1}s throughput={:.4}j/s mean_turnaround={:.1}s kernel_slowdown={:.2}%",
        r.scheduler,
        r.node,
        cluster,
        r.workers,
        r.jobs.len(),
        r.completed(),
        r.crashed(),
        r.makespan,
        r.throughput(),
        r.mean_turnaround(),
        r.kernel_slowdown_pct()
    );
    if r.preemptions > 0 {
        println!(
            "preemptions={} wasted_work={:.1}s ckpt_overhead={:.1}s",
            r.preemptions, r.wasted_work_s, r.ckpt_overhead_s
        );
    }
}

fn cmd_bench(f: &HashMap<String, String>) -> i32 {
    let seed = seed_of(f);
    match f.get("exp").map(String::as_str).unwrap_or("all") {
        "all" => {
            for r in bench_harness::run_all(seed) {
                r.print();
            }
            0
        }
        name => match bench_harness::run_experiment(name, seed) {
            Some(r) => {
                r.print();
                0
            }
            None => {
                eprintln!("unknown experiment '{name}'");
                2
            }
        },
    }
}

fn cmd_run(f: &HashMap<String, String>) -> i32 {
    let cluster = parse_cluster(f);
    let mode = parse_sched(f);
    let seed = seed_of(f);
    let wl = f.get("workload").map(String::as_str).unwrap_or("W1");
    let Some(workload) = Workload::by_id(wl) else {
        eprintln!("unknown workload '{wl}' (W1..W8)");
        return 2;
    };
    let workers = f
        .get("workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| bench_harness::mgb_workers(&cluster.nodes[0]));
    let mut jobs = workload.jobs(seed);
    apply_rate(f, &mut jobs, seed);
    let cfg = ClusterConfig {
        cluster,
        mode,
        workers_per_node: workers,
        dispatch: parse_dispatch(f),
        preempt: parse_preempt(f),
        latency: parse_latency(f),
    };
    let r = if f.get("compute").map(String::as_str) == Some("real") {
        let dir = f.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
        let reg = match KernelRegistry::new(&dir) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("artifacts: {e}");
                return 1;
            }
        };
        let mut executed: u64 = 0;
        let mut hook = |artifact: &str| {
            if let Ok(exe) = reg.get(artifact) {
                let _ = exe; // compiled; numerics exercised by `mgb artifacts`
                executed += 1;
            }
        };
        let r = run_cluster_with_hook(cfg, jobs, Some(&mut hook));
        println!("real-compute launches resolved: {executed}");
        r
    } else {
        run_cluster(cfg, jobs)
    };
    print_result(&r);
    for j in &r.jobs {
        let node = if r.n_nodes > 1 { format!(" node={}", j.node) } else { String::new() };
        let preempted = if j.preemptions > 0 {
            format!(" preempted={} wasted={:.1}s", j.preemptions, j.wasted_s)
        } else {
            String::new()
        };
        println!(
            "  {:<24} {}{} start={:>7.1}s end={:>7.1}s kernels={} slowdown={:+.2}%{}",
            j.name,
            if j.crashed { "CRASH" } else { "ok   " },
            node,
            j.started,
            j.ended,
            j.n_kernels,
            100.0 * j.kernel_slowdown(),
            preempted
        );
    }
    0
}

fn cmd_nn(f: &HashMap<String, String>) -> i32 {
    let cluster = parse_cluster(f);
    let mode = parse_sched(f);
    let seed = seed_of(f);
    let workers = f.get("workers").and_then(|s| s.parse().ok()).unwrap_or(8);
    let mut jobs = match f.get("task").map(String::as_str).unwrap_or("mix") {
        "predict" => nn_homogeneous(NnTask::Predict),
        "train" => nn_homogeneous(NnTask::Train),
        "detect" => nn_homogeneous(NnTask::Detect),
        "generate" => nn_homogeneous(NnTask::Generate),
        "mix" => {
            let n = f.get("jobs").and_then(|s| s.parse().ok()).unwrap_or(128);
            nn_mix(n, seed)
        }
        other => {
            eprintln!("unknown nn task '{other}'");
            return 2;
        }
    };
    apply_rate(f, &mut jobs, seed);
    let cfg = ClusterConfig {
        cluster,
        mode,
        workers_per_node: workers,
        dispatch: parse_dispatch(f),
        preempt: parse_preempt(f),
        latency: parse_latency(f),
    };
    let r = run_cluster(cfg, jobs);
    print_result(&r);
    0
}

fn cmd_compile(path: Option<&str>) -> i32 {
    let Some(path) = path else {
        eprintln!("usage: mgb compile <file.gir>");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{path}: {e}");
            return 1;
        }
    };
    let program = match parse_program(&text) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("parse error: {e:#}");
            return 1;
        }
    };
    let compiled = compile(&program);
    println!("{} function(s), {} GPU task(s)", compiled.program.funcs.len(), compiled.tasks.len());
    for t in &compiled.tasks {
        println!(
            "task {}: launches={:?} mem_objs={:?} lazy={} probe_at={:?}",
            t.id, t.launches, t.mem_objs, t.lazy, t.probe_at
        );
        println!("  mem_bytes = {}", t.mem_bytes);
        println!("  grid = {}, block = {}, heap = {}", t.grid, t.block, t.heap_bytes);
    }
    0
}

fn cmd_artifacts(f: &HashMap<String, String>) -> i32 {
    let dir = f.get("dir").cloned().unwrap_or_else(|| "artifacts".into());
    let reg = match KernelRegistry::new(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let names = reg.available();
    if names.is_empty() {
        eprintln!("no artifacts in {dir} — run `make artifacts`");
        return 1;
    }
    for n in &names {
        match reg.get(n) {
            Ok(_) => println!("{n:<18} compiles OK"),
            Err(e) => {
                println!("{n:<18} FAILED: {e}");
                return 1;
            }
        }
    }
    println!("{} artifacts OK", names.len());
    0
}

//! Rodinia v3.1 benchmark analogues (§V-A) as mini-CUDA IR programs.
//!
//! Each combo reproduces the host-side *structure* of the CUDA
//! benchmark (buffer set, launch loop shape, kernel granularity) with
//! footprints/durations matching the paper's description: 7 combos at
//! 1–4 GB ("small", everything but lavaMD), 10 combos above 4 GB
//! ("large", everything but bfs; lavaMD tops out at ~13 GB), job wall
//! times in the tens of seconds so 16-job mixes run ~5 minutes under SA.
//!
//! `work_us` is dedicated-V100 microseconds; occupancy (via grid/block)
//! reflects the ~30% single-workload GPU utilisation the paper's
//! motivation cites, higher for the dense stencil/MD kernels, lower for
//! wavefront DP (needle) and memory-bound graph traversal (bfs).
//!
//! Every launch is bound to the PJRT artifact carrying the kernel's real
//! numerics (`--compute real` executes them; modeled runs skip).

use crate::compiler::compile;
use crate::coordinator::{JobClass, JobSpec};
use crate::ir::{Expr, FuncBuilder, Program, ProgramBuilder};
use crate::lazy::interpret;

/// V100 warp capacity, the occupancy reference (80 SMs x 64 warps).
const V100_WARPS: u64 = 80 * 64;

/// One benchmark-argument combination from the paper's pool.
#[derive(Clone, Copy, Debug)]
pub struct Combo {
    pub name: &'static str,
    pub bench: Bench,
    /// Device footprint in MiB (1–4 GB small, >4 GB large).
    pub mem_mib: u64,
    /// Total dedicated GPU seconds on a V100.
    pub gpu_s: f64,
    /// Host-side time (I/O, setup, post-processing), seconds.
    pub host_s: f64,
    /// Warp demand as a fraction of a V100's warp capacity; > 1 means the
    /// grid oversaturates the device (runs in waves, needs a full wave
    /// of residency under Alg. 2).
    pub occupancy: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bench {
    Backprop,
    SradV1,
    SradV2,
    LavaMd,
    Needle,
    Dwt2d,
    Bfs,
}

impl Bench {
    pub fn artifact(&self) -> &'static str {
        match self {
            Bench::Backprop => "backprop",
            Bench::SradV1 | Bench::SradV2 => "srad",
            Bench::LavaMd => "lavamd",
            Bench::Needle => "needle",
            Bench::Dwt2d => "dwt2d",
            Bench::Bfs => "bfs",
        }
    }

    /// Per-benchmark resource-pressure profile (memory-bandwidth share,
    /// L2 footprint class, SM occupancy), following the resource-
    /// specific contention characterisation of arXiv 2501.16909:
    /// bfs is a memory-bound irregular traversal (bandwidth-dominant,
    /// cache-hostile), needle's wavefront DP lives in the L2 tile
    /// window, srad is a dense bandwidth+compute stencil, lavaMD is
    /// compute-bound MD with a small working set, dwt2d and backprop
    /// sit mid-spectrum. Stamped onto traces only by
    /// `workloads::assign_interference` — plain `job_spec()` traces
    /// stay all-zero (bit-identical legacy behaviour).
    pub fn interference(&self) -> crate::gpu::InterferenceProfile {
        use crate::gpu::InterferenceProfile as P;
        match self {
            Bench::Bfs => P::new(0.85, 0.5, 0.2),
            Bench::Needle => P::new(0.35, 0.7, 0.15),
            Bench::SradV1 | Bench::SradV2 => P::new(0.65, 0.45, 0.85),
            Bench::Dwt2d => P::new(0.55, 0.35, 0.4),
            Bench::LavaMd => P::new(0.25, 0.3, 0.85),
            Bench::Backprop => P::new(0.45, 0.4, 0.35),
        }
    }
}

/// The paper's pool: 7 small (1–4 GB) + 10 large (>4 GB) combos.
pub const COMBOS: [Combo; 17] = [
    // ---- small (1..4 GB) — all but lavaMD ----
    Combo { name: "backprop-s", bench: Bench::Backprop, mem_mib: 1536, gpu_s: 4.9, host_s: 13.0, occupancy: 0.30 },
    Combo { name: "bfs-s", bench: Bench::Bfs, mem_mib: 1228, gpu_s: 3.5, host_s: 15.6, occupancy: 0.20 },
    Combo { name: "bfs-m", bench: Bench::Bfs, mem_mib: 3891, gpu_s: 7.0, host_s: 20.8, occupancy: 0.25 },
    Combo { name: "srad1-s", bench: Bench::SradV1, mem_mib: 2458, gpu_s: 5.5, host_s: 22.0, occupancy: 0.90 },
    Combo { name: "needle-s", bench: Bench::Needle, mem_mib: 2048, gpu_s: 5.6, host_s: 13.0, occupancy: 0.15 },
    Combo { name: "dwt2d-s", bench: Bench::Dwt2d, mem_mib: 1638, gpu_s: 4.2, host_s: 11.7, occupancy: 0.35 },
    Combo { name: "srad2-s", bench: Bench::SradV2, mem_mib: 3277, gpu_s: 6.0, host_s: 24.0, occupancy: 0.95 },
    // ---- large (>4 GB) — all but bfs ----
    Combo { name: "backprop-l", bench: Bench::Backprop, mem_mib: 6656, gpu_s: 8.4, host_s: 20.8, occupancy: 0.35 },
    Combo { name: "srad1-l", bench: Bench::SradV1, mem_mib: 8704, gpu_s: 9.0, host_s: 32.0, occupancy: 0.90 },
    Combo { name: "srad2-l", bench: Bench::SradV2, mem_mib: 7168, gpu_s: 8.0, host_s: 30.0, occupancy: 0.85 },
    Combo { name: "srad2-xl", bench: Bench::SradV2, mem_mib: 9728, gpu_s: 10.0, host_s: 34.0, occupancy: 0.95 },
    Combo { name: "lavamd-l", bench: Bench::LavaMd, mem_mib: 11264, gpu_s: 15.4, host_s: 33.8, occupancy: 0.80 },
    Combo { name: "lavamd-xl", bench: Bench::LavaMd, mem_mib: 13312, gpu_s: 19.6, host_s: 39.0, occupancy: 0.85 },
    Combo { name: "needle-l", bench: Bench::Needle, mem_mib: 7680, gpu_s: 9.8, host_s: 23.4, occupancy: 0.20 },
    Combo { name: "needle-xl", bench: Bench::Needle, mem_mib: 10240, gpu_s: 11.9, host_s: 26.0, occupancy: 0.25 },
    Combo { name: "dwt2d-l", bench: Bench::Dwt2d, mem_mib: 5632, gpu_s: 7.7, host_s: 18.2, occupancy: 0.40 },
    Combo { name: "dwt2d-xl", bench: Bench::Dwt2d, mem_mib: 8704, gpu_s: 9.8, host_s: 22.1, occupancy: 0.45 },
];

impl Combo {
    pub fn is_large(&self) -> bool {
        self.mem_mib > 4096
    }

    pub fn class(&self) -> JobClass {
        if self.is_large() {
            JobClass::Large
        } else {
            JobClass::Small
        }
    }

    /// Thread-block geometry hitting `occupancy` of a V100: 128-thread
    /// blocks (4 warps/TB) except needle's 32-thread wavefront cells.
    fn geometry(&self) -> (i64, i64) {
        let block: i64 = match self.bench {
            Bench::Needle => 32,
            _ => 128,
        };
        let wptb = (block as u64).div_ceil(32);
        let warps = (self.occupancy * V100_WARPS as f64) as u64;
        ((warps / wptb).max(1) as i64, block)
    }

    /// Build the IR program for this combo and run the compiler + lazy
    /// runtime to obtain the schedulable trace. The trace is built once
    /// per combo (all built-in programs take no interpreter arguments,
    /// so the combo name keys the process-wide cache) and cloned per
    /// job with its summary and compiled segments pre-warmed.
    pub fn job_spec(&self) -> JobSpec {
        let trace = super::cached_trace(self.name, || {
            let compiled = compile(&self.program());
            interpret(&compiled, &[]).expect("workload interprets")
        });
        JobSpec { name: self.name.to_string(), class: self.class(), trace, arrival: 0.0, slo: None }
    }

    /// The host-side IR mirroring the CUDA benchmark's structure.
    pub fn program(&self) -> Program {
        let mem_bytes = (self.mem_mib as i64) << 20;
        let (grid, block) = self.geometry();
        let gpu_us = (self.gpu_s * 1e6) as i64;
        let host_us = (self.host_s * 1e6) as i64;
        let artifact = self.bench.artifact();
        let mut pb = ProgramBuilder::new();
        match self.bench {
            Bench::SradV1 | Bench::SradV2 => {
                // I, dN/dS/dW/dE coeff buffers, c; iterative 2-kernel loop.
                let iters = 100i64;
                let n_bufs = if self.bench == Bench::SradV1 { 6 } else { 2 };
                let per_launch = gpu_us / (iters * 2);
                pb.func("main", 0, |f| {
                    host(f, host_us / 4);
                    let buf = (mem_bytes / n_bufs).max(1);
                    let sz = f.assign(Expr::c(buf));
                    let bufs: Vec<_> = (0..n_bufs).map(|_| f.malloc(sz)).collect();
                    f.h2d(bufs[0], sz);
                    let (g, b, w) = gbw(f, grid, block, per_launch);
                    let it = f.c(iters);
                    let args: Vec<_> = bufs.clone();
                    // Half the host time is the per-iteration reduction
                    // on the CPU (kernels are intermittent, which is
                    // what Alg. 3 exploits and Alg. 2's lifetime SM
                    // reservation wastes).
                    let inner = f.c((host_us / 2 / iters).max(1));
                    f.loop_n(it, |f| {
                        f.launch_artifact("srad_cuda_1", artifact, g, b, &args, w);
                        f.launch_artifact("srad_cuda_2", artifact, g, b, &args, w);
                        f.host_compute(inner);
                    });
                    f.d2h(bufs[0], sz);
                    for &bf in &bufs {
                        f.free(bf);
                    }
                    host(f, host_us / 4);
                });
            }
            Bench::Backprop => {
                // input/hidden/output units + weights; 2 kernels per epoch.
                let epochs = 40i64;
                let per_launch = gpu_us / (epochs * 2);
                pb.func("main", 0, |f| {
                    host(f, host_us / 2); // load + net_setup
                    let sz_in = f.assign(Expr::c(mem_bytes / 2));
                    let sz_w = f.assign(Expr::c(mem_bytes / 4));
                    let input = f.malloc(sz_in);
                    let w1 = f.malloc(sz_w);
                    let w2 = f.malloc(sz_w);
                    f.h2d(input, sz_in);
                    f.h2d(w1, sz_w);
                    let (g, b, w) = gbw(f, grid, block, per_launch);
                    let it = f.c(epochs);
                    f.loop_n(it, |f| {
                        f.launch_artifact("layerforward", artifact, g, b, &[input, w1, w2], w);
                        f.launch_artifact("adjust_weights", artifact, g, b, &[input, w1, w2], w);
                    });
                    f.d2h(w2, sz_w);
                    f.free(input);
                    f.free(w1);
                    f.free(w2);
                    host(f, host_us / 2);
                });
            }
            Bench::LavaMd => {
                // boxes of particles; one long force kernel per box batch.
                let batches = 20i64;
                let per_launch = gpu_us / batches;
                pb.func("main", 0, |f| {
                    host(f, host_us / 2);
                    let sz_pos = f.assign(Expr::c(mem_bytes / 2));
                    let sz_frc = f.assign(Expr::c(mem_bytes / 2));
                    let pos = f.malloc(sz_pos);
                    let frc = f.malloc(sz_frc);
                    f.h2d(pos, sz_pos);
                    f.memset(frc, sz_frc);
                    let (g, b, w) = gbw(f, grid, block, per_launch);
                    let it = f.c(batches);
                    f.loop_n(it, |f| {
                        f.launch_artifact("kernel_gpu_cuda", artifact, g, b, &[pos, frc], w);
                    });
                    f.d2h(frc, sz_frc);
                    f.free(pos);
                    f.free(frc);
                    host(f, host_us / 2);
                });
            }
            Bench::Needle => {
                // Wavefront DP: 2*(dim/tile) dependent launches. The
                // CUDA code allocates the score matrix + reference.
                let diags = 128i64;
                let per_launch = (gpu_us / (2 * diags)).max(1);
                pb.func("main", 0, |f| {
                    host(f, host_us / 4);
                    let sz = f.assign(Expr::c(mem_bytes / 2));
                    let m = f.malloc(sz);
                    let refm = f.malloc(sz);
                    f.h2d(m, sz);
                    f.h2d(refm, sz);
                    let (g, b, w) = gbw(f, grid, block, per_launch);
                    let it = f.c(diags);
                    let inner = f.c((host_us / 2 / diags).max(1));
                    f.loop_n(it, |f| {
                        f.launch_artifact("needle_cuda_1", artifact, g, b, &[m, refm], w);
                        f.launch_artifact("needle_cuda_2", artifact, g, b, &[m, refm], w);
                        f.host_compute(inner);
                    });
                    f.d2h(m, sz);
                    f.free(m);
                    f.free(refm);
                    host(f, host_us / 4);
                });
            }
            Bench::Dwt2d => {
                // Multi-level wavelet: one kernel per level per direction.
                let levels = 8i64;
                let per_launch = gpu_us / (levels * 2);
                pb.func("main", 0, |f| {
                    host(f, host_us / 2);
                    let sz = f.assign(Expr::c(mem_bytes / 2));
                    let src = f.malloc(sz);
                    let dst = f.malloc(sz);
                    f.h2d(src, sz);
                    let (g, b, w) = gbw(f, grid, block, per_launch);
                    let it = f.c(levels);
                    f.loop_n(it, |f| {
                        f.launch_artifact("fdwt", artifact, g, b, &[src, dst], w);
                        f.launch_artifact("fdwt", artifact, g, b, &[dst, src], w);
                    });
                    f.d2h(dst, sz);
                    f.free(src);
                    f.free(dst);
                    host(f, host_us / 2);
                });
            }
            Bench::Bfs => {
                // Level-synchronous traversal; graph + frontier masks.
                let levels = 24i64;
                let per_launch = gpu_us / (levels * 2);
                pb.func("main", 0, |f| {
                    host(f, host_us / 2); // graph load dominates
                    let sz_g = f.assign(Expr::c(mem_bytes * 3 / 4));
                    let sz_f = f.assign(Expr::c(mem_bytes / 4));
                    let graph = f.malloc(sz_g);
                    let frontier = f.malloc(sz_f);
                    f.h2d(graph, sz_g);
                    f.memset(frontier, sz_f);
                    let (g, b, w) = gbw(f, grid, block, per_launch);
                    let it = f.c(levels);
                    let inner = f.c((host_us / 4 / levels).max(1));
                    f.loop_n(it, |f| {
                        f.launch_artifact("Kernel", artifact, g, b, &[graph, frontier], w);
                        f.launch_artifact("Kernel2", artifact, g, b, &[graph, frontier], w);
                        f.host_compute(inner);
                    });
                    f.d2h(frontier, sz_f);
                    f.free(graph);
                    f.free(frontier);
                    host(f, host_us / 4);
                });
            }
        }
        pb.finish()
    }
}

/// Emit grid/block/work constants.
fn gbw(f: &mut FuncBuilder, grid: i64, block: i64, work_us: i64) -> (u32, u32, u32) {
    let g = f.c(grid);
    let b = f.c(block);
    let w = f.c(work_us.max(1));
    (g, b, w)
}

/// Host compute phase helper.
fn host(f: &mut FuncBuilder, micros: i64) {
    if micros > 0 {
        let us = f.c(micros);
        f.host_compute(us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_matches_paper_counts() {
        let small = COMBOS.iter().filter(|c| !c.is_large()).count();
        let large = COMBOS.iter().filter(|c| c.is_large()).count();
        assert_eq!(small, 7, "7 combos at 1-4 GB");
        assert_eq!(large, 10, "10 combos above 4 GB");
        assert!(COMBOS.iter().all(|c| c.mem_mib >= 1024), "nothing below 1 GB");
        let max = COMBOS.iter().map(|c| c.mem_mib).max().unwrap();
        assert_eq!(max, 13312, "lavaMD tops at ~13 GB");
        assert!(COMBOS.iter().filter(|c| !c.is_large()).all(|c| c.bench != Bench::LavaMd));
        assert!(COMBOS.iter().filter(|c| c.is_large()).all(|c| c.bench != Bench::Bfs));
    }

    #[test]
    fn every_combo_compiles_to_one_static_task() {
        for c in &COMBOS {
            let compiled = compile(&c.program());
            assert_eq!(compiled.tasks.len(), 1, "{}", c.name);
            assert!(!compiled.tasks[0].lazy, "{} should be static", c.name);
        }
    }

    #[test]
    fn traces_carry_paper_footprints_and_durations() {
        for c in &COMBOS {
            let spec = c.job_spec();
            spec.trace.check_well_formed().unwrap();
            let begin = spec.trace.events.iter().find_map(|e| match e {
                crate::lazy::TraceEvent::TaskBegin { res, .. } => Some(*res),
                _ => None,
            });
            let res = begin.expect("has a probe");
            let mib = res.mem_bytes >> 20;
            // buffer-count rounding loses < 8 bytes/buffer
            assert!(
                (mib as i64 - c.mem_mib as i64).abs() <= 1,
                "{}: {} vs {}",
                c.name,
                mib,
                c.mem_mib
            );
            let gpu_s = spec.trace.total_work_us() as f64 * 1e-6;
            assert!(
                (gpu_s - c.gpu_s).abs() / c.gpu_s < 0.05,
                "{}: gpu {} vs {}",
                c.name,
                gpu_s,
                c.gpu_s
            );
            let host_s = spec.trace.total_host_us() as f64 * 1e-6;
            assert!((host_s - c.host_s).abs() / c.host_s < 0.05, "{}", c.name);
        }
    }

    #[test]
    fn occupancy_mix_leaves_room_to_pack() {
        // The paper's motivation: a single workload typically uses ~30%
        // of GPU resources. Over half the pool sits at or below 50%
        // warp residency, and the mean stays well under saturation.
        let under: usize = COMBOS.iter().filter(|c| c.occupancy <= 0.5).count();
        assert!(under >= 9, "most combos leave room to pack, got {under}");
        let mean: f64 = COMBOS.iter().map(|c| c.occupancy).sum::<f64>() / COMBOS.len() as f64;
        assert!(mean < 0.6, "mean occupancy {mean}");
    }

    #[test]
    fn warps_match_occupancy_targets() {
        for c in &COMBOS {
            let spec = c.job_spec();
            let res = spec
                .trace
                .events
                .iter()
                .find_map(|e| match e {
                    crate::lazy::TraceEvent::TaskBegin { res, .. } => Some(*res),
                    _ => None,
                })
                .unwrap();
            let occ = res.warps() as f64 / V100_WARPS as f64;
            assert!(
                (occ - c.occupancy).abs() < 0.02,
                "{}: occ {} target {}",
                c.name,
                occ,
                c.occupancy
            );
        }
    }
}

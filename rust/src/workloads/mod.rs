//! Workload factories: Rodinia combos, Darknet NN tasks, and the
//! paper's W1–W8 / NN mixes. Every job is produced by authoring its
//! host-side IR, running the compiler pass, and interpreting it through
//! the lazy runtime — so each batch run exercises the whole front half
//! of the system before any scheduling happens.

pub mod darknet;
pub mod mixes;
pub mod rng;
pub mod rodinia;

pub use darknet::{NnTask, NN_TASKS};
pub use mixes::{
    assign_interference, assign_slo, flash_crowd_arrivals, heavy_tailed_mix, mmpp_arrivals,
    nn_homogeneous, nn_mix, open_system, poisson_arrivals, synthetic_job, synthetic_job_with_iv,
    MixRatio, Workload, RATIOS, WORKLOADS,
};
pub use rodinia::{Bench, Combo, COMBOS};

use crate::lazy::JobTrace;
use crate::runtime::ArcCache;

/// Process-wide trace cache keyed by (program, args). Every built-in
/// workload program takes no interpreter arguments, so the combo /
/// profile name alone is the key. A batch of N cloned jobs of one
/// benchmark compiles, interprets, and well-formedness-checks its
/// trace ONCE; each clone carries the memoized summary and compiled
/// segment program along (their `OnceLock`s clone initialized).
fn trace_cache() -> &'static ArcCache<JobTrace> {
    static CACHE: std::sync::OnceLock<ArcCache<JobTrace>> = std::sync::OnceLock::new();
    CACHE.get_or_init(ArcCache::new)
}

/// Hit-or-build `key`'s trace, warming every derived view so per-job
/// clones never recompute them: the well-formedness check (debug
/// builds), the summary walk, and the macro-segment compilation (the
/// clones then share one `Arc<TraceProgram>`).
pub(crate) fn cached_trace(key: &str, build: impl FnOnce() -> JobTrace) -> JobTrace {
    let arc = trace_cache().get_or_insert_with(key, || {
        let trace = build();
        debug_assert!(trace.check_well_formed().is_ok(), "workload trace well-formed");
        let _ = trace.summary();
        let _ = trace.compiled();
        trace
    });
    (*arc).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_job_specs_share_compiled_program() {
        // Two jobs of the same combo must come from one cache build:
        // their clones share a single Arc'd segment program, so the
        // compile/interpret/verify front half ran once, not per job.
        let a = COMBOS[0].job_spec();
        let b = COMBOS[0].job_spec();
        assert!(std::sync::Arc::ptr_eq(a.trace.compiled(), b.trace.compiled()));

        let nn_a = NN_TASKS[0].job_spec();
        let nn_b = NN_TASKS[0].job_spec();
        assert!(std::sync::Arc::ptr_eq(nn_a.trace.compiled(), nn_b.trace.compiled()));
        // Distinct keys stay distinct.
        assert!(!std::sync::Arc::ptr_eq(a.trace.compiled(), nn_a.trace.compiled()));
    }
}

//! Workload factories: Rodinia combos, Darknet NN tasks, and the
//! paper's W1–W8 / NN mixes. Every job is produced by authoring its
//! host-side IR, running the compiler pass, and interpreting it through
//! the lazy runtime — so each batch run exercises the whole front half
//! of the system before any scheduling happens.

pub mod darknet;
pub mod mixes;
pub mod rng;
pub mod rodinia;

pub use darknet::{NnTask, NN_TASKS};
pub use mixes::{
    assign_interference, assign_slo, flash_crowd_arrivals, heavy_tailed_mix, mmpp_arrivals,
    nn_homogeneous, nn_mix, open_system, poisson_arrivals, synthetic_job, synthetic_job_with_iv,
    MixRatio, Workload, RATIOS, WORKLOADS,
};
pub use rodinia::{Bench, Combo, COMBOS};

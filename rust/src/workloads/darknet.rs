//! Darknet neural-network workloads (§V-E) as mini-CUDA IR programs.
//!
//! Four task types, as in the paper: ImageNet classification with
//! pretrained Darknet19/Darknet53 (*predict*), CIFAR-10 training
//! (*train*), yolov3-tiny real-time object detection (*detect*), and
//! Shakespeare char-RNN text generation (*generate*). Networks are
//! 0.5–1.5 GB so 8 jobs always fit in one V100's memory — which is
//! exactly why memory-only scheduling (schedGPU) piles them on one
//! device. Compute demand separates the tasks: training nearly
//! saturates a device, detection uses ~25% or less (nvidia-smi per the
//! paper), so compute-aware spreading is where MGB wins.

use crate::compiler::compile;
use crate::coordinator::{JobClass, JobSpec};
use crate::ir::{Expr, Program, ProgramBuilder};
use crate::lazy::interpret;

const V100_WARPS: u64 = 80 * 64;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NnTask {
    Predict,
    Train,
    Detect,
    Generate,
}

pub const NN_TASKS: [NnTask; 4] = [NnTask::Predict, NnTask::Train, NnTask::Detect, NnTask::Generate];

/// Profile: (network bytes, gpu seconds, host seconds, occupancy,
/// batches, launches per batch, artifact).
#[derive(Clone, Copy, Debug)]
pub struct NnProfile {
    pub name: &'static str,
    pub mem_mib: u64,
    pub gpu_s: f64,
    pub host_s: f64,
    pub occupancy: f64,
    pub batches: i64,
    pub launches_per_batch: i64,
    pub artifact: &'static str,
}

impl NnTask {
    pub fn profile(&self) -> NnProfile {
        match self {
            // Darknet19/53 fwd over an image batch: moderate occupancy.
            NnTask::Predict => NnProfile {
                name: "nn-predict",
                mem_mib: 1024,
                gpu_s: 10.0,
                host_s: 4.0,
                occupancy: 0.30,
                batches: 60,
                launches_per_batch: 1,
                artifact: "darknet_predict",
            },
            // CIFAR train: fwd+bwd, compute-hungry.
            NnTask::Train => NnProfile {
                name: "nn-train",
                mem_mib: 1536,
                gpu_s: 20.0,
                host_s: 8.0,
                occupancy: 0.62,
                batches: 100,
                launches_per_batch: 2,
                artifact: "darknet_train",
            },
            // yolov3-tiny at 200+ FPS: GPU mostly idle (video I/O bound).
            NnTask::Detect => NnProfile {
                name: "nn-detect",
                mem_mib: 819,
                gpu_s: 4.0,
                host_s: 12.0,
                occupancy: 0.12,
                batches: 200,
                launches_per_batch: 1,
                artifact: "darknet_detect",
            },
            // char-RNN generation: sequential cell steps, mid occupancy.
            NnTask::Generate => NnProfile {
                name: "nn-generate",
                mem_mib: 614,
                gpu_s: 12.0,
                host_s: 3.0,
                occupancy: 0.42,
                batches: 250,
                launches_per_batch: 1,
                artifact: "darknet_rnn",
            },
        }
    }

    /// Host IR: load weights, one buffer set, batch loop of launches.
    pub fn program(&self) -> Program {
        let p = self.profile();
        let mem_bytes = (p.mem_mib as i64) << 20;
        let total_launches = p.batches * p.launches_per_batch;
        let per_launch = ((p.gpu_s * 1e6) as i64 / total_launches).max(1);
        let host_us = (p.host_s * 1e6) as i64;
        let block = 128i64;
        let warps = (p.occupancy * V100_WARPS as f64) as i64;
        let grid = (warps / 4).max(1);
        let mut pb = ProgramBuilder::new();
        pb.func("main", 0, |f| {
            let us = f.c(host_us / 2); // parse cfg + load weights
            f.host_compute(us);
            let sz_w = f.assign(Expr::c(mem_bytes * 3 / 4));
            let sz_a = f.assign(Expr::c(mem_bytes / 4));
            let weights = f.malloc(sz_w);
            let acts = f.malloc(sz_a);
            f.h2d(weights, sz_w);
            let g = f.c(grid);
            let b = f.c(block);
            let w = f.c(per_launch);
            let it = f.c(p.batches);
            let lpb = p.launches_per_batch;
            let art = p.artifact;
            f.loop_n(it, |f| {
                for i in 0..lpb {
                    let kname = if i == 0 { "forward" } else { "backward" };
                    f.launch_artifact(kname, art, g, b, &[weights, acts], w);
                }
            });
            f.d2h(acts, sz_a);
            f.free(weights);
            f.free(acts);
            let us2 = f.c(host_us / 2);
            f.host_compute(us2);
        });
        pb.finish()
    }

    /// Trace built once per task profile via the process-wide cache
    /// (programs take no interpreter arguments, so the profile name is
    /// the key) and cloned per job with derived views pre-warmed.
    pub fn job_spec(&self) -> JobSpec {
        let name = self.profile().name;
        let trace = super::cached_trace(name, || {
            let compiled = compile(&self.program());
            interpret(&compiled, &[]).expect("nn workload interprets")
        });
        JobSpec { name: name.to_string(), class: JobClass::Nn, trace, arrival: 0.0, slo: None }
    }

    /// Per-task resource-pressure profile (memory bandwidth / L2 / SM).
    /// Training is the all-round heavy hitter (fwd+bwd streams weights
    /// both ways), prediction streams weights through L2 at moderate
    /// compute, generation's sequential RNN cells are L2-resident, and
    /// detection barely touches the device (video-I/O bound). Stamped
    /// only by `workloads::assign_interference` — plain `job_spec()`
    /// traces stay all-zero.
    pub fn interference(&self) -> crate::gpu::InterferenceProfile {
        use crate::gpu::InterferenceProfile as P;
        match self {
            NnTask::Predict => P::new(0.4, 0.45, 0.3),
            NnTask::Train => P::new(0.55, 0.5, 0.65),
            NnTask::Detect => P::new(0.2, 0.25, 0.12),
            NnTask::Generate => P::new(0.3, 0.6, 0.4),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lazy::TraceEvent;

    #[test]
    fn networks_fit_eight_to_a_device() {
        // §V-E: "each task's network is between 0.5-1.5GB, so 8 jobs can
        // always fit within a single V100's memory".
        for t in NN_TASKS {
            let p = t.profile();
            assert!(p.mem_mib >= 512 && p.mem_mib <= 1536, "{}", p.name);
        }
        let worst: u64 = NN_TASKS.iter().map(|t| t.profile().mem_mib).max().unwrap();
        assert!(8 * worst < 16 * 1024, "8 x worst-case fits 16 GB");
    }

    #[test]
    fn train_is_compute_hungry_detect_is_not() {
        let occs: Vec<f64> = NN_TASKS.iter().map(|t| t.profile().occupancy).collect();
        let max = occs.iter().cloned().fold(0.0, f64::max);
        assert_eq!(max, NnTask::Train.profile().occupancy, "train dominates");
        assert!(NnTask::Detect.profile().occupancy <= 0.25);
        assert!(NnTask::Train.profile().occupancy / NnTask::Detect.profile().occupancy > 4.0);
    }

    #[test]
    fn every_task_compiles_static_and_well_formed() {
        for t in NN_TASKS {
            let c = compile(&t.program());
            assert_eq!(c.tasks.len(), 1);
            assert!(!c.tasks[0].lazy);
            let spec = t.job_spec();
            spec.trace.check_well_formed().unwrap();
        }
    }

    #[test]
    fn launch_counts_match_profiles() {
        for t in NN_TASKS {
            let p = t.profile();
            let spec = t.job_spec();
            let launches = spec
                .trace
                .events
                .iter()
                .filter(|e| matches!(e, TraceEvent::Launch { .. }))
                .count() as i64;
            assert_eq!(launches, p.batches * p.launches_per_batch, "{}", p.name);
        }
    }

    #[test]
    fn artifacts_reference_real_models() {
        for t in NN_TASKS {
            let spec = t.job_spec();
            let named = spec.trace.events.iter().any(|e| {
                matches!(e, TraceEvent::Launch { artifact: Some(a), .. } if a == t.profile().artifact)
            });
            assert!(named, "{} launches must bind artifacts", t.profile().name);
        }
    }
}

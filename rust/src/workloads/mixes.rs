//! Workload mixes: the paper's W1–W8 (Table I) and the NN mixes (§V-E).

use super::darknet::{NnTask, NN_TASKS};
use super::rng::Rng;
use super::rodinia::COMBOS;
use crate::coordinator::{JobClass, JobSpec};
use crate::gpu::InterferenceProfile;
use crate::lazy::{JobTrace, TaskResources, TraceEvent};
use crate::sched::SloClass;

/// A large:small mix ratio (Table I: 1:1, 2:1, 3:1, 5:1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MixRatio {
    pub large: u32,
    pub small: u32,
}

pub const RATIOS: [MixRatio; 4] = [
    MixRatio { large: 1, small: 1 },
    MixRatio { large: 2, small: 1 },
    MixRatio { large: 3, small: 1 },
    MixRatio { large: 5, small: 1 },
];

/// Table I: W1–W4 = 16 jobs at the four ratios, W5–W8 = 32 jobs.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub id: &'static str,
    pub n_jobs: usize,
    pub ratio: MixRatio,
}

pub const WORKLOADS: [Workload; 8] = [
    Workload { id: "W1", n_jobs: 16, ratio: RATIOS[0] },
    Workload { id: "W2", n_jobs: 16, ratio: RATIOS[1] },
    Workload { id: "W3", n_jobs: 16, ratio: RATIOS[2] },
    Workload { id: "W4", n_jobs: 16, ratio: RATIOS[3] },
    Workload { id: "W5", n_jobs: 32, ratio: RATIOS[0] },
    Workload { id: "W6", n_jobs: 32, ratio: RATIOS[1] },
    Workload { id: "W7", n_jobs: 32, ratio: RATIOS[2] },
    Workload { id: "W8", n_jobs: 32, ratio: RATIOS[3] },
];

impl Workload {
    pub fn by_id(id: &str) -> Option<Workload> {
        WORKLOADS.iter().copied().find(|w| w.id == id)
    }

    /// Generate the job batch: jobs drawn at the large:small ratio,
    /// uniformly from the respective pools, then shuffled (paper: "jobs
    /// are randomly chosen from their respective sets").
    pub fn jobs(&self, seed: u64) -> Vec<JobSpec> {
        let mut rng = Rng::new(seed ^ fxhash(self.id));
        let large_pool: Vec<usize> =
            (0..COMBOS.len()).filter(|&i| COMBOS[i].is_large()).collect();
        let small_pool: Vec<usize> =
            (0..COMBOS.len()).filter(|&i| !COMBOS[i].is_large()).collect();
        let cycle = (self.ratio.large + self.ratio.small) as usize;
        let mut picks = Vec::with_capacity(self.n_jobs);
        for j in 0..self.n_jobs {
            let in_cycle = j % cycle;
            let pool = if in_cycle < self.ratio.large as usize {
                &large_pool
            } else {
                &small_pool
            };
            picks.push(pool[rng.below(pool.len())]);
        }
        rng.shuffle(&mut picks);
        picks
            .into_iter()
            .enumerate()
            .map(|(j, i)| {
                let mut spec = COMBOS[i].job_spec();
                spec.name = format!("{}#{:02}-{}", self.id, j, spec.name);
                spec
            })
            .collect()
    }
}

/// A synthetic single-task job — reserve `mem_bytes`, transfer it in,
/// run one `work_us` kernel (100 x 32-thread blocks), transfer it
/// back. The minimal adversarial unit for contention/preemption
/// studies (`bench preempt`, `examples/preemption.rs`); real mixes
/// come from [`Workload`] instead.
pub fn synthetic_job(
    name: &str,
    class: JobClass,
    mem_bytes: u64,
    work_us: u64,
    arrival: f64,
) -> JobSpec {
    let res = TaskResources {
        static_dev: None,
        mem_bytes,
        heap_bytes: 0,
        grid: 100,
        block: 32,
        // One H2D of the buffer plus the kernel's stores into it.
        written_bytes: 2 * mem_bytes,
        iv: InterferenceProfile::ZERO,
    };
    JobSpec {
        name: name.into(),
        class,
        arrival,
        slo: None,
        trace: JobTrace::new(vec![
            TraceEvent::TaskBegin { task: 0, res },
            TraceEvent::Malloc { task: 0, bytes: mem_bytes },
            TraceEvent::H2D { task: 0, bytes: mem_bytes },
            TraceEvent::Launch {
                task: 0,
                kernel: "k".into(),
                artifact: None,
                grid: 100,
                block: 32,
                work_us,
            },
            TraceEvent::D2H { task: 0, bytes: mem_bytes },
            TraceEvent::Free { task: 0, bytes: mem_bytes },
            TraceEvent::TaskEnd { task: 0 },
        ]),
    }
}

/// A [`synthetic_job`] carrying an explicit interference vector — the
/// adversarial unit of the high-pressure interference bench mixes
/// (small footprints that fit MIG-style device slices, hot profiles
/// that fight over one resource).
pub fn synthetic_job_with_iv(
    name: &str,
    class: JobClass,
    mem_bytes: u64,
    work_us: u64,
    arrival: f64,
    iv: InterferenceProfile,
) -> JobSpec {
    let mut spec = synthetic_job(name, class, mem_bytes, work_us, arrival);
    stamp_iv(&mut spec, iv);
    spec
}

/// Overwrite every task probe's pressure vector in `spec`'s trace.
fn stamp_iv(spec: &mut JobSpec, iv: InterferenceProfile) {
    for e in spec.trace.events.iter_mut() {
        if let TraceEvent::TaskBegin { res, .. } = e {
            res.iv = iv.sanitized();
        }
    }
    // The trace's derived summaries may already have been read (and
    // memoized) off the pre-stamp events; drop them so the next read
    // sees the stamped vectors.
    spec.trace.invalidate_derived();
}

/// Stamp per-benchmark interference vectors onto a job mix — the
/// `--interference` CLI mapping, and the single place traces acquire
/// nonzero pressure. Each job's profile is looked up from the artifact
/// its launches bind (`Bench::interference` for the Rodinia combos,
/// `NnTask::interference` for the Darknet tasks); jobs whose launches
/// bind no known artifact (synthetic jobs, hand-built traces) are left
/// untouched. Jobs keep all-zero vectors unless this is called, so
/// every existing mix replays bit-identically.
pub fn assign_interference(jobs: &mut [JobSpec]) {
    use super::rodinia::Bench;
    for spec in jobs.iter_mut() {
        let artifact = spec.trace.events.iter().find_map(|e| match e {
            TraceEvent::Launch { artifact: Some(a), .. } => Some(a.clone()),
            _ => None,
        });
        let Some(artifact) = artifact else { continue };
        let iv = match artifact.as_str() {
            "backprop" => Bench::Backprop.interference(),
            "srad" => Bench::SradV1.interference(),
            "lavamd" => Bench::LavaMd.interference(),
            "needle" => Bench::Needle.interference(),
            "dwt2d" => Bench::Dwt2d.interference(),
            "bfs" => Bench::Bfs.interference(),
            "darknet_predict" => NnTask::Predict.interference(),
            "darknet_train" => NnTask::Train.interference(),
            "darknet_detect" => NnTask::Detect.interference(),
            "darknet_rnn" => NnTask::Generate.interference(),
            _ => continue,
        };
        stamp_iv(spec, iv);
    }
}

/// Stamp SLO classes onto a job mix by workload class — the `--slo`
/// CLI mapping: heavy (Large) jobs are latency-sensitive (they are the
/// turnaround story the paper's 4.9x targets), Small jobs batch, NN
/// jobs best-effort. Jobs keep `slo: None` (no SLO at all) unless this
/// is called, so existing mixes replay unchanged.
pub fn assign_slo(jobs: &mut [JobSpec]) {
    for j in jobs.iter_mut() {
        j.slo = Some(match j.class {
            JobClass::Large => SloClass::LatencySensitive,
            JobClass::Small => SloClass::Batch,
            JobClass::Nn => SloClass::BestEffort,
        });
    }
}

/// Open-system traffic: overwrite each job's `arrival` with a Poisson
/// process of `rate_per_s` jobs/second (i.i.d. exponential
/// inter-arrivals), in job order. Turns any batch mix into sustained
/// traffic for the cluster dispatcher; deterministic per seed.
pub fn poisson_arrivals(jobs: &mut [JobSpec], rate_per_s: f64, seed: u64) {
    assert!(rate_per_s > 0.0, "arrival rate must be positive");
    let mut rng = Rng::new(seed ^ 0xA11C0DE);
    let mut t = 0.0;
    for j in jobs.iter_mut() {
        t += rng.exp(1.0 / rate_per_s);
        j.arrival = t;
    }
}

/// A workload mix driven as open-system traffic rather than batch-at-0.
pub fn open_system(workload: &Workload, rate_per_s: f64, seed: u64) -> Vec<JobSpec> {
    let mut jobs = workload.jobs(seed);
    poisson_arrivals(&mut jobs, rate_per_s, seed);
    jobs
}

/// Markov-modulated Poisson (diurnal) traffic: overwrite each job's
/// `arrival` with a Poisson process whose rate cycles through
/// `rates_per_s` — each phase lasts an exponential holding time of
/// mean `phase_mean_s`. Two alternating rates give the classic
/// day/night diurnal shape; more give arbitrary regimes. Exponential
/// inter-arrivals are memoryless, so discarding the residual gap at a
/// phase boundary and redrawing at the new rate is exact, not an
/// approximation. Deterministic per seed.
pub fn mmpp_arrivals(jobs: &mut [JobSpec], rates_per_s: &[f64], phase_mean_s: f64, seed: u64) {
    assert!(!rates_per_s.is_empty(), "mmpp needs at least one phase rate");
    for &r in rates_per_s {
        assert!(r > 0.0 && r.is_finite(), "phase rates must be positive and finite");
    }
    assert!(
        phase_mean_s > 0.0 && phase_mean_s.is_finite(),
        "phase holding time must be positive and finite"
    );
    let mut rng = Rng::new(seed ^ 0xD1D4A1);
    let mut phase = 0usize;
    let mut t = 0.0;
    let mut phase_end = rng.exp(phase_mean_s);
    for j in jobs.iter_mut() {
        loop {
            let gap = rng.exp(1.0 / rates_per_s[phase]);
            if t + gap <= phase_end {
                t += gap;
                break;
            }
            t = phase_end;
            phase = (phase + 1) % rates_per_s.len();
            phase_end = t + rng.exp(phase_mean_s);
        }
        j.arrival = t;
    }
}

/// Flash-crowd traffic: a base-rate Poisson process with periodic burst
/// windows. Time is cut into periods of `period_s`; the first
/// `burst_frac` of each period arrives at `burst_rate_per_s`, the rest
/// at `base_rate_per_s`. Unlike [`mmpp_arrivals`] the regime switches
/// are *clocked*, not random — the overload bench wants the crowd to
/// hit at known instants so policies can be compared on the same
/// burst. Deterministic per seed.
pub fn flash_crowd_arrivals(
    jobs: &mut [JobSpec],
    base_rate_per_s: f64,
    burst_rate_per_s: f64,
    period_s: f64,
    burst_frac: f64,
    seed: u64,
) {
    assert!(
        base_rate_per_s > 0.0 && base_rate_per_s.is_finite(),
        "base rate must be positive and finite"
    );
    assert!(
        burst_rate_per_s >= base_rate_per_s && burst_rate_per_s.is_finite(),
        "burst rate must be >= base rate and finite"
    );
    assert!(period_s > 0.0 && period_s.is_finite(), "period must be positive and finite");
    assert!((0.0..1.0).contains(&burst_frac) && burst_frac > 0.0, "burst_frac must be in (0, 1)");
    let mut rng = Rng::new(seed ^ 0xF1A5C0D);
    let mut t = 0.0;
    for j in jobs.iter_mut() {
        loop {
            let into = t - (t / period_s).floor() * period_s;
            let burst_end = burst_frac * period_s;
            let (rate, seg_end) = if into < burst_end {
                (burst_rate_per_s, t - into + burst_end)
            } else {
                (base_rate_per_s, t - into + period_s)
            };
            let gap = rng.exp(1.0 / rate);
            if t + gap <= seg_end {
                t += gap;
                break;
            }
            // Memoryless: jump to the segment boundary and redraw.
            t = seg_end;
        }
        j.arrival = t;
    }
}

/// Heavy-tailed overload mix: `n_jobs` synthetic single-task jobs whose
/// service demand and footprint follow a bound-capped Pareto law
/// (shape `alpha`, 20 ms / 256 MiB scales, capped at 20 s / 4 GiB), so
/// a few elephants dominate total work while the mass of mice decides
/// attainment. Jobs are classed 20% latency-sensitive / 40% batch /
/// 40% best-effort — the class spread the admission lattice
/// (protect / degrade / shed) needs to differentiate on. Arrivals are
/// all 0; drive them with [`poisson_arrivals`], [`mmpp_arrivals`], or
/// [`flash_crowd_arrivals`].
pub fn heavy_tailed_mix(n_jobs: usize, alpha: f64, seed: u64) -> Vec<JobSpec> {
    assert!(
        alpha > 1.0 && alpha.is_finite(),
        "pareto shape must exceed 1 (finite mean) and be finite"
    );
    let mut rng = Rng::new(seed ^ 0x0E7A11);
    (0..n_jobs)
        .map(|j| {
            let work_us = (20_000.0 * rng.pareto(alpha, 1.0)).min(20_000_000.0) as u64;
            let mem_bytes = ((256u64 << 20) as f64 * rng.pareto(alpha, 1.0))
                .min((4u64 << 30) as f64) as u64;
            let (class, slo) = match rng.below(5) {
                0 => (JobClass::Large, SloClass::LatencySensitive),
                1 | 2 => (JobClass::Small, SloClass::Batch),
                _ => (JobClass::Small, SloClass::BestEffort),
            };
            let mut s = synthetic_job(&format!("ht#{j:03}"), class, mem_bytes, work_us, 0.0);
            s.slo = Some(slo);
            s
        })
        .collect()
}

/// §V-E first experiment: 8-job homogeneous workload per NN task type.
pub fn nn_homogeneous(task: NnTask) -> Vec<JobSpec> {
    (0..8)
        .map(|j| {
            let mut s = task.job_spec();
            s.name = format!("{}#{j}", s.name);
            s
        })
        .collect()
}

/// §V-E large-scale: a 128-job random mix of the 4 NN task types.
pub fn nn_mix(n_jobs: usize, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed);
    (0..n_jobs)
        .map(|j| {
            let t = NN_TASKS[rng.below(NN_TASKS.len())];
            let mut s = t.job_spec();
            s.name = format!("mix#{j:03}-{}", s.name);
            s
        })
        .collect()
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::JobClass;

    #[test]
    fn ratios_hold_exactly() {
        for w in WORKLOADS {
            let jobs = w.jobs(1);
            assert_eq!(jobs.len(), w.n_jobs);
            let large = jobs.iter().filter(|j| j.class == JobClass::Large).count();
            let cycle = (w.ratio.large + w.ratio.small) as usize;
            let want_large =
                (w.n_jobs / cycle) * w.ratio.large as usize + (w.n_jobs % cycle).min(w.ratio.large as usize);
            assert_eq!(large, want_large, "{}", w.id);
        }
    }

    #[test]
    fn same_seed_same_mix_different_seed_differs() {
        let a = WORKLOADS[0].jobs(7);
        let b = WORKLOADS[0].jobs(7);
        let c = WORKLOADS[0].jobs(8);
        let names = |v: &[JobSpec]| v.iter().map(|j| j.name.clone()).collect::<Vec<_>>();
        assert_eq!(names(&a), names(&b));
        assert_ne!(names(&a), names(&c));
    }

    #[test]
    fn poisson_arrivals_are_increasing_and_deterministic() {
        let mut a = WORKLOADS[0].jobs(5);
        poisson_arrivals(&mut a, 0.5, 42);
        let mut prev = 0.0;
        for j in &a {
            assert!(j.arrival > prev, "strictly increasing arrivals");
            prev = j.arrival;
        }
        let b = open_system(&WORKLOADS[0], 0.5, 42);
        // open_system with the same workload seed regenerates the same
        // jobs; poisson_arrivals with the same seed stamps the same
        // times... but here the workload seed differs (42 vs 5), so
        // only compare the arrival stamps on a fresh copy.
        let mut c = WORKLOADS[0].jobs(5);
        poisson_arrivals(&mut c, 0.5, 42);
        for (x, y) in a.iter().zip(&c) {
            assert_eq!(x.arrival, y.arrival);
        }
        // Different seed, different process.
        let mut d = WORKLOADS[0].jobs(5);
        poisson_arrivals(&mut d, 0.5, 43);
        assert!(a.iter().zip(&d).any(|(x, y)| x.arrival != y.arrival));
        assert_eq!(b.len(), a.len());
    }

    #[test]
    fn mmpp_arrivals_are_increasing_and_deterministic() {
        let mut a = WORKLOADS[4].jobs(3);
        mmpp_arrivals(&mut a, &[2.0, 0.2], 5.0, 9);
        let mut prev = 0.0;
        for j in &a {
            assert!(j.arrival > prev, "strictly increasing arrivals");
            prev = j.arrival;
        }
        let mut b = WORKLOADS[4].jobs(3);
        mmpp_arrivals(&mut b, &[2.0, 0.2], 5.0, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
        }
        // A different phase plan produces a different process.
        let mut c = WORKLOADS[4].jobs(3);
        mmpp_arrivals(&mut c, &[0.2, 2.0], 5.0, 9);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn flash_crowd_concentrates_arrivals_in_burst_windows() {
        let mut jobs = nn_mix(256, 1);
        let (period, frac) = (10.0, 0.2);
        flash_crowd_arrivals(&mut jobs, 0.5, 20.0, period, frac, 21);
        let mut prev = 0.0;
        let (mut in_burst, mut outside) = (0usize, 0usize);
        for j in &jobs {
            assert!(j.arrival > prev, "strictly increasing arrivals");
            prev = j.arrival;
            let into = j.arrival - (j.arrival / period).floor() * period;
            if into < frac * period {
                in_burst += 1;
            } else {
                outside += 1;
            }
        }
        // Burst windows cover 20% of the clock but a 40x rate ratio
        // means they should capture the vast majority of arrivals.
        assert!(
            in_burst > 3 * outside,
            "burst windows not dominant: {in_burst} in vs {outside} out"
        );
        // Deterministic replay.
        let mut again = nn_mix(256, 1);
        flash_crowd_arrivals(&mut again, 0.5, 20.0, period, frac, 21);
        for (x, y) in jobs.iter().zip(&again) {
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn heavy_tailed_mix_spans_classes_and_has_a_tail() {
        let jobs = heavy_tailed_mix(200, 1.5, 7);
        assert_eq!(jobs.len(), 200);
        for want in [SloClass::LatencySensitive, SloClass::Batch, SloClass::BestEffort] {
            assert!(jobs.iter().any(|j| j.slo == Some(want)), "{want:?} missing");
        }
        // Heavy tail: the biggest service demand dwarfs the median.
        let mut works: Vec<u64> = jobs
            .iter()
            .map(|j| {
                j.trace
                    .events
                    .iter()
                    .find_map(|e| match e {
                        TraceEvent::Launch { work_us, .. } => Some(*work_us),
                        _ => None,
                    })
                    .unwrap()
            })
            .collect();
        works.sort_unstable();
        let median = works[works.len() / 2];
        let max = *works.last().unwrap();
        assert!(works[0] >= 20_000, "scale floor: smallest {}", works[0]);
        assert!(max <= 20_000_000, "cap: largest {max}");
        assert!(max > 10 * median, "no tail: max {max} vs median {median}");
        // Deterministic replay.
        let again = heavy_tailed_mix(200, 1.5, 7);
        for (x, y) in jobs.iter().zip(&again) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.slo, y.slo);
            assert_eq!(x.class, y.class);
        }
    }

    #[test]
    fn assign_slo_maps_job_classes_and_default_is_none() {
        let mut jobs = WORKLOADS[0].jobs(1);
        jobs.extend(nn_mix(4, 1));
        assert!(jobs.iter().all(|j| j.slo.is_none()), "no SLO unless asked");
        assign_slo(&mut jobs);
        for j in &jobs {
            let want = match j.class {
                JobClass::Large => SloClass::LatencySensitive,
                JobClass::Small => SloClass::Batch,
                JobClass::Nn => SloClass::BestEffort,
            };
            assert_eq!(j.slo, Some(want), "{}", j.name);
        }
    }

    #[test]
    fn assign_interference_stamps_by_artifact_and_default_is_zero() {
        let mut jobs = WORKLOADS[0].jobs(1);
        jobs.extend(nn_mix(8, 1));
        let zero = |j: &JobSpec| j.trace.peak_interference().is_zero();
        assert!(jobs.iter().all(zero), "no pressure unless asked");
        assign_interference(&mut jobs);
        for j in &jobs {
            assert!(!zero(j), "{}: every rodinia/darknet job gains a vector", j.name);
        }
        // The vectors are the per-benchmark ones, not one blanket value.
        let bfs = jobs.iter().find(|j| j.name.contains("bfs"));
        if let Some(b) = bfs {
            assert_eq!(b.trace.peak_interference(), super::super::rodinia::Bench::Bfs.interference());
        }
        let train = jobs.iter().find(|j| j.name.contains("nn-train")).unwrap();
        assert_eq!(train.trace.peak_interference(), NnTask::Train.interference());
        // Synthetic (artifact-less) jobs pass through untouched.
        let mut synth = vec![synthetic_job("s", JobClass::Small, 1 << 30, 1000, 0.0)];
        assign_interference(&mut synth);
        assert!(zero(&synth[0]));
    }

    #[test]
    fn synthetic_job_with_iv_stamps_and_sanitizes() {
        let j = synthetic_job_with_iv(
            "hot",
            JobClass::Small,
            2 << 30,
            1000,
            0.0,
            InterferenceProfile::new(1.5, -0.3, 0.7),
        );
        // Components clamp into [0, 1] on the way in.
        assert_eq!(j.trace.peak_interference(), InterferenceProfile::new(1.0, 0.0, 0.7));
    }

    #[test]
    fn nn_mix_covers_all_types() {
        let jobs = nn_mix(128, 3);
        assert_eq!(jobs.len(), 128);
        for t in NN_TASKS {
            let name = t.profile().name;
            assert!(jobs.iter().any(|j| j.name.contains(name)), "{name} missing");
        }
    }
}

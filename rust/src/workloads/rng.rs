//! Deterministic PRNG (SplitMix64) — workload mixes must replay exactly
//! across runs and schedulers, so no OS entropy anywhere.

#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponentially distributed sample with the given `mean` (inverse
    /// CDF; the inter-arrival law of a Poisson process). Strictly
    /// positive.
    pub fn exp(&mut self, mean: f64) -> f64 {
        // Midpoint sample in (0, 1): never 0 (ln undefined) nor 1
        // (ln = 0), so the result can't collapse to zero.
        let u = ((self.next_u64() >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sequence() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_is_positive_with_the_requested_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.exp(2.0);
            assert!(x > 0.0 && x.is_finite());
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "sample mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}

//! Deterministic PRNG (SplitMix64) — workload mixes must replay exactly
//! across runs and schedulers, so no OS entropy anywhere.

#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sequence() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}

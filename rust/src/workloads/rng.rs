//! Deterministic PRNG (SplitMix64) — workload mixes must replay exactly
//! across runs and schedulers, so no OS entropy anywhere.

#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n), via bounded rejection sampling.
    ///
    /// A bare `next_u64() % n` is biased: the 2^64 % n values at the
    /// top of the u64 range map onto the low residues once more than
    /// the rest. Draws falling in that partial tail (probability
    /// < n / 2^64) are rejected and redrawn, so every accepted residue
    /// is exactly uniform. The redraw loop is *bounded* — after
    /// `MAX_REJECTS` consecutive tail hits (probability ~2^-64 per hit
    /// for any realistic `n`; the cap is unreachable in practice but
    /// keeps the sampler total) the last draw's residue is used as-is.
    /// Still fully deterministic per seed: how many draws are consumed
    /// depends only on the stream.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0) has no value to return");
        let n = n as u64;
        // Largest multiple of n that fits in a u64: draws at or above
        // it are the biased partial tail.
        let zone = u64::MAX - u64::MAX % n;
        const MAX_REJECTS: u32 = 128;
        let mut v = self.next_u64();
        for _ in 0..MAX_REJECTS {
            if v < zone {
                break;
            }
            v = self.next_u64();
        }
        (v % n) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponentially distributed sample with the given `mean` (inverse
    /// CDF; the inter-arrival law of a Poisson process). Strictly
    /// positive.
    pub fn exp(&mut self, mean: f64) -> f64 {
        // Midpoint sample in (0, 1): never 0 (ln undefined) nor 1
        // (ln = 0), so the result can't collapse to zero.
        let u = ((self.next_u64() >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
        -mean * u.ln()
    }

    /// Pareto-distributed sample (inverse CDF): scale `xm`, shape
    /// `alpha`. The heavy-tailed job-size law of the overload mixes —
    /// a few elephants carry most of the total work. Always >= `xm`;
    /// the mean is finite only for `alpha > 1` (callers wanting a
    /// stable sample mean should bound-cap the draw).
    pub fn pareto(&mut self, alpha: f64, xm: f64) -> f64 {
        // Same midpoint trick as `exp`: u in (0, 1), so the power is
        // finite and the sample strictly exceeds... well, reaches xm
        // only in the limit; concretely it is always finite and > 0.
        let u = ((self.next_u64() >> 11) as f64 + 0.5) / (1u64 << 53) as f64;
        xm * u.powf(-1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sequence() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_is_positive_with_the_requested_mean() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.exp(2.0);
            assert!(x > 0.0 && x.is_finite());
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "sample mean {mean}");
    }

    #[test]
    fn below_is_unbiased_at_the_tail_boundary() {
        // Deterministic replay across clones is what the workload mixes
        // rely on; rejection must not break it.
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for n in [1usize, 2, 3, 7, 10, 1000, usize::MAX] {
            for _ in 0..50 {
                assert_eq!(a.below(n), b.below(n));
            }
        }
        // The rejection zone is the largest multiple of n: a residue
        // histogram over a coarse modulus must be near-flat (the old
        // `% n` was provably skewed only in the extreme tail, so this
        // is a smoke check of the zone arithmetic, not a chi-square).
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "skewed counts {counts:?}");
        }
    }

    #[test]
    fn pareto_is_heavy_tailed_above_scale_and_deterministic() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        let n = 20_000;
        let mut sum = 0.0;
        let mut max = 0.0f64;
        for _ in 0..n {
            let x = a.pareto(3.0, 1.0);
            assert_eq!(x, b.pareto(3.0, 1.0), "replay must be exact");
            assert!(x >= 1.0 && x.is_finite(), "sample {x} below scale");
            sum += x;
            max = max.max(x);
        }
        // Pareto(alpha=3, xm=1) mean = alpha/(alpha-1) = 1.5.
        let mean = sum / n as f64;
        assert!((mean - 1.5).abs() < 0.1, "sample mean {mean}");
        // Heavy tail: the largest of 20k draws dwarfs the mean in a
        // way exponential samples with the same mean never would.
        assert!(max > 5.0, "no tail: max {max}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }
}

//! Beyond-paper: the probe/dispatch latency sweep (ROADMAP "Per-node
//! probe latency model"). The paper's probes are host-side RPCs to a
//! scheduler daemon; the free-frontend engine prices them at zero and
//! so overstates open-system throughput exactly where those RPCs bite.
//! Rows sweep the probe round-trip (with a proportional dispatch cost
//! and frontend service time) over the same open-system stream: mean
//! turnaround must grow monotonically with the RTT, and the preset
//! rows (`lan`, `wan`) bracket realistic deployments.

use super::{mgb_workers, Report};
use crate::coordinator::{run_cluster, ClusterConfig, RunResult, SchedMode};
use crate::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use crate::workloads::{poisson_arrivals, Workload};

/// The swept probe RTTs, seconds (0 = the free-frontend baseline).
/// Steps are spaced so each one's guaranteed per-job delay (admission
/// + task probes) dwarfs any co-residency jitter the shifted landings
/// could cause — what keeps the sweep's monotonicity assertable.
pub const RTT_SWEEP: [f64; 4] = [0.0, 0.05, 0.5, 2.0];

/// Latency model used by the sweep at a given probe RTT: dispatch
/// costs twice the RTT (the job hop is heavier than a probe) and the
/// frontend serves one RPC per RTT/10.
pub fn sweep_model(rtt_s: f64) -> LatencyModel {
    if rtt_s == 0.0 {
        LatencyModel::off()
    } else {
        LatencyModel {
            probe_rtt_s: rtt_s,
            dispatch_base_s: 2.0 * rtt_s,
            frontend_service_s: rtt_s / 10.0,
            ..LatencyModel::default()
        }
    }
}

fn sweep_cfg(latency: LatencyModel) -> ClusterConfig {
    let node = NodeSpec::v100x4();
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(node.clone(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: mgb_workers(&node),
        dispatch: "least",
        preempt: None,
        latency,
    }
}

/// The one job stream every row of the experiment runs: open-system
/// W2 at a deliberately low offered load (0.1 jobs/s onto 8 GPUs).
/// With contention out of the picture, every modeled delay lands in
/// turnaround instead of hiding behind queueing — which is what makes
/// the sweep's monotonicity a clean property to assert, and what keeps
/// the lan/wan preset rows comparable to the sweep rows.
fn sweep_stream(seed: u64) -> Vec<crate::coordinator::JobSpec> {
    let mut jobs = Workload::by_id("W2").expect("W2 exists").jobs(seed);
    poisson_arrivals(&mut jobs, 0.1, seed);
    jobs
}

/// Run the open-system W2 stream under each swept RTT. Exposed (rather
/// than inlined into the report) so the regression tests can assert
/// the monotonicity the report claims.
pub fn latency_sweep(seed: u64) -> Vec<(f64, RunResult)> {
    let jobs = sweep_stream(seed);
    RTT_SWEEP
        .iter()
        .map(|&rtt| (rtt, run_cluster(sweep_cfg(sweep_model(rtt)), jobs.clone())))
        .collect()
}

pub fn latency(seed: u64) -> Report {
    let mut lines = Vec::new();
    for (rtt, r) in latency_sweep(seed) {
        lines.push(format!(
            "probe_rtt={rtt:<6}s mean_turnaround={:.2}s makespan={:.1}s \
             throughput={:.4}j/s completed={} crashed={}",
            r.mean_turnaround(),
            r.makespan,
            r.throughput(),
            r.completed(),
            r.crashed()
        ));
    }
    let jobs = sweep_stream(seed);
    for (name, m) in [("lan", LatencyModel::lan()), ("wan", LatencyModel::wan())] {
        let r = run_cluster(sweep_cfg(m), jobs.clone());
        lines.push(format!(
            "preset={name:<9} mean_turnaround={:.2}s makespan={:.1}s throughput={:.4}j/s",
            r.mean_turnaround(),
            r.makespan,
            r.throughput()
        ));
    }
    Report {
        title: "Latency (beyond-paper): probe RTT sweep, open-system W2 on 2x 4xV100"
            .into(),
        lines,
    }
}

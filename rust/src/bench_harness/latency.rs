//! Beyond-paper: the probe/dispatch latency sweep (ROADMAP "Per-node
//! probe latency model"). The paper's probes are host-side RPCs to a
//! scheduler daemon; the free-frontend engine prices them at zero and
//! so overstates open-system throughput exactly where those RPCs bite.
//! Rows sweep the probe round-trip (with a proportional dispatch cost
//! and frontend service time) over the same open-system stream: mean
//! turnaround must grow monotonically with the RTT, and the preset
//! rows (`lan`, `wan`) bracket realistic deployments.
//!
//! A second section compares *dispatchers* at each swept RTT on the
//! same stream: the PR-3 `least` baseline, the latency-aware scorer
//! (`--dispatch latency`), and `least` guarded by the timeout +
//! re-probe protocol. On this uniform-RTT cluster latency-aware must
//! never lose to least-loaded (equal delays cancel out of its score,
//! so it degenerates to the same ranking — the acceptance bound); its
//! real edge needs RTT *asymmetry*, shown by the final near/far rows
//! where one node is 10x closer than the other.

use super::{mgb_workers, Report};
use crate::coordinator::{run_cluster, ClusterConfig, RunResult, SchedMode};
use crate::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use crate::workloads::{poisson_arrivals, Workload};

/// The swept probe RTTs, seconds (0 = the free-frontend baseline).
/// Steps are spaced so each one's guaranteed per-job delay (admission
/// + task probes) dwarfs any co-residency jitter the shifted landings
/// could cause — what keeps the sweep's monotonicity assertable.
pub const RTT_SWEEP: [f64; 4] = [0.0, 0.05, 0.5, 2.0];

/// Latency model used by the sweep at a given probe RTT: dispatch
/// costs twice the RTT (the job hop is heavier than a probe) and the
/// frontend serves one RPC per RTT/10.
pub fn sweep_model(rtt_s: f64) -> LatencyModel {
    if rtt_s == 0.0 {
        LatencyModel::off()
    } else {
        LatencyModel {
            probe_rtt_s: rtt_s,
            dispatch_base_s: 2.0 * rtt_s,
            frontend_service_s: rtt_s / 10.0,
            ..LatencyModel::default()
        }
    }
}

fn sweep_cfg_with(dispatch: &'static str, latency: LatencyModel) -> ClusterConfig {
    let node = NodeSpec::v100x4();
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(node.clone(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: mgb_workers(&node),
        dispatch,
        preempt: None,
        latency,
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

fn sweep_cfg(latency: LatencyModel) -> ClusterConfig {
    sweep_cfg_with("least", latency)
}

/// The sweep model plus the timeout + re-probe guard: staleness bound
/// of one RTT (every routing's landing delay is 3x RTT here, so the
/// guard always arms) with budget for two re-probes per job.
pub fn reprobe_model(rtt_s: f64) -> LatencyModel {
    LatencyModel { reprobe_after_s: rtt_s, reprobe_budget: 2, ..sweep_model(rtt_s) }
}

/// Dispatcher comparison at each swept RTT over the same open-system
/// stream: (rtt, [(dispatcher label, result)]). Exposed so the
/// regression tests can assert the acceptance bound (latency-aware
/// mean turnaround <= least-loaded at every nonzero RTT). The `least`
/// rows double as the plain sweep rows in the report (identical
/// configs), and at RTT 0 every variant *is* the free-frontend least
/// run (the model is off; zero-delay latency-aware delegates to least
/// and a zero bound never re-probes), so that row is simulated once
/// and cloned rather than re-run.
pub fn latency_dispatch_comparison(seed: u64) -> Vec<(f64, Vec<(&'static str, RunResult)>)> {
    let jobs = sweep_stream(seed);
    RTT_SWEEP
        .iter()
        .map(|&rtt| {
            let least = run_cluster(sweep_cfg(sweep_model(rtt)), jobs.clone());
            let rows = if rtt == 0.0 {
                let relabel = |dispatcher: &str| RunResult {
                    dispatcher: dispatcher.to_string(),
                    ..least.clone()
                };
                vec![
                    ("least", least.clone()),
                    ("latency", relabel("latency")),
                    ("least+reprobe", relabel("least")),
                ]
            } else {
                vec![
                    ("least", least.clone()),
                    ("latency", run_cluster(sweep_cfg_with("latency", sweep_model(rtt)), jobs.clone())),
                    ("least+reprobe", run_cluster(sweep_cfg(reprobe_model(rtt)), jobs.clone())),
                ]
            };
            (rtt, rows)
        })
        .collect()
}

/// The asymmetric-RTT scenario where latency awareness actually bites:
/// node 0 is near (RTT/10), node 1 far (the full RTT). Least-loaded
/// ping-pongs jobs to whichever node's backlog looks smaller, blind to
/// the far node's landing delay; the latency-aware scorer only pays
/// the distance when the near node's backlog outweighs it.
pub fn asymmetric_comparison(seed: u64, rtt_s: f64) -> Vec<(&'static str, RunResult)> {
    let jobs = sweep_stream(seed);
    let model = LatencyModel {
        per_node_rtt_s: vec![rtt_s / 10.0, rtt_s],
        ..sweep_model(rtt_s)
    };
    vec![
        ("least", run_cluster(sweep_cfg(model.clone()), jobs.clone())),
        ("latency", run_cluster(sweep_cfg_with("latency", model), jobs)),
    ]
}

/// The one job stream every row of the experiment runs: open-system
/// W2 at a deliberately low offered load (0.1 jobs/s onto 8 GPUs).
/// With contention out of the picture, every modeled delay lands in
/// turnaround instead of hiding behind queueing — which is what makes
/// the sweep's monotonicity a clean property to assert, and what keeps
/// the lan/wan preset rows comparable to the sweep rows.
fn sweep_stream(seed: u64) -> Vec<crate::coordinator::JobSpec> {
    let mut jobs = Workload::by_id("W2").expect("W2 exists").jobs(seed);
    poisson_arrivals(&mut jobs, 0.1, seed);
    jobs
}

/// Run the open-system W2 stream under each swept RTT. Exposed (rather
/// than inlined into the report) so the regression tests can assert
/// the monotonicity the report claims.
pub fn latency_sweep(seed: u64) -> Vec<(f64, RunResult)> {
    let jobs = sweep_stream(seed);
    RTT_SWEEP
        .iter()
        .map(|&rtt| (rtt, run_cluster(sweep_cfg(sweep_model(rtt)), jobs.clone())))
        .collect()
}

pub fn latency(seed: u64) -> Report {
    let mut lines = Vec::new();
    // One comparison pass supplies both report sections: its `least`
    // rows ARE the plain sweep rows (identical configs), so the sweep
    // is not simulated twice.
    let comparison = latency_dispatch_comparison(seed);
    for (rtt, rows) in &comparison {
        let (_, r) = rows.iter().find(|(n, _)| *n == "least").expect("least row");
        lines.push(format!(
            "probe_rtt={rtt:<6}s mean_turnaround={:.2}s makespan={:.1}s \
             throughput={:.4}j/s completed={} crashed={}",
            r.mean_turnaround(),
            r.makespan,
            r.throughput(),
            r.completed(),
            r.crashed()
        ));
    }
    let jobs = sweep_stream(seed);
    for (name, m) in [("lan", LatencyModel::lan()), ("wan", LatencyModel::wan())] {
        let r = run_cluster(sweep_cfg(m), jobs.clone());
        lines.push(format!(
            "preset={name:<9} mean_turnaround={:.2}s makespan={:.1}s throughput={:.4}j/s",
            r.mean_turnaround(),
            r.makespan,
            r.throughput()
        ));
    }
    for (rtt, rows) in &comparison {
        for (dispatch, r) in rows {
            lines.push(format!(
                "probe_rtt={rtt:<6}s dispatch={dispatch:<13} mean_turnaround={:.2}s \
                 makespan={:.1}s completed={}",
                r.mean_turnaround(),
                r.makespan,
                r.completed()
            ));
        }
    }
    let far_rtt = 0.5;
    for (dispatch, r) in asymmetric_comparison(seed, far_rtt) {
        lines.push(format!(
            "asymmetric_rtt={:.2}s/{far_rtt}s dispatch={dispatch:<13} \
             mean_turnaround={:.2}s makespan={:.1}s",
            far_rtt / 10.0,
            r.mean_turnaround(),
            r.makespan
        ));
    }
    Report {
        title: "Latency (beyond-paper): probe RTT sweep, open-system W2 on 2x 4xV100"
            .into(),
        lines,
    }
}

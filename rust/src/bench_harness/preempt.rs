//! Beyond-paper: checkpoint/restart preemption under the adversarial
//! pattern the ROADMAP names — long-running light "hog" jobs holding
//! most of a device's memory while short heavy jobs arrive late and,
//! without reclamation, starve behind them (the turnaround pathology
//! the paper's 4.9x claim targets, pushed one step further).
//!
//! Rows compare preemption off / never / min-progress / max-mem on the
//! same stream, then sweep the fixed checkpoint cost to show the
//! tradeoff stays bounded: heavy turnaround collapses by an order of
//! magnitude while wasted work stays a few seconds per eviction.

use super::Report;
use crate::coordinator::{run_cluster, ClusterConfig, JobClass, JobSpec, SchedMode};
use crate::gpu::{ClusterSpec, GpuSpec, NodeSpec};
use crate::sched::PreemptConfig;
use crate::workloads::rng::Rng;
use crate::workloads::synthetic_job;

/// The contended stream: per node, one 12 GB hog (light, 120s) at t=0
/// plus heavy late arrivals (12 GB, ~8s) staggered over the first
/// minute with seeded jitter.
fn stream(nodes: usize, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed);
    let mut jobs = Vec::new();
    for n in 0..nodes {
        jobs.push(synthetic_job(
            &format!("hog-n{n}"),
            JobClass::Small,
            12 << 30,
            120_000_000,
            0.0,
        ));
    }
    for i in 0..3 * nodes {
        let arrival = 4.0 + i as f64 * 14.0 + rng.f64() * 2.0;
        jobs.push(synthetic_job(
            &format!("heavy-{i}"),
            JobClass::Large,
            12 << 30,
            8_000_000,
            arrival,
        ));
    }
    jobs
}

fn cfg(nodes: usize, preempt: Option<PreemptConfig>) -> ClusterConfig {
    let node = NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
    ClusterConfig {
        cluster: if nodes == 1 {
            ClusterSpec::single(node)
        } else {
            ClusterSpec::homogeneous(node, nodes)
        },
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "least",
        preempt,
        latency: crate::gpu::LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

pub fn preempt(seed: u64) -> Report {
    const NODES: usize = 2;
    let jobs = stream(NODES, seed);
    let mut lines = Vec::new();
    let rows: Vec<(&str, Option<PreemptConfig>)> = vec![
        ("off", None),
        ("never", Some(PreemptConfig { policy: "never", ..Default::default() })),
        ("min-progress", Some(PreemptConfig::default())),
        ("max-mem", Some(PreemptConfig { policy: "max-mem", ..Default::default() })),
    ];
    for (label, p) in rows {
        let r = run_cluster(cfg(NODES, p), jobs.clone());
        lines.push(format!(
            "preempt={label:<12} heavy_turnaround={:.1}s light_turnaround={:.1}s \
             makespan={:.1}s preemptions={} wasted_work={:.1}s ckpt_overhead={:.1}s",
            r.mean_turnaround_of(JobClass::Large),
            r.mean_turnaround_of(JobClass::Small),
            r.makespan,
            r.preemptions,
            r.wasted_work_s,
            r.ckpt_overhead_s
        ));
    }
    // Cost sweep: preemption must stay profitable for the heavies until
    // the checkpoint itself rivals their runtime.
    for base in [0.05, 1.0, 5.0] {
        let p = PreemptConfig { ckpt_base_s: base, ..Default::default() };
        let r = run_cluster(cfg(NODES, Some(p)), jobs.clone());
        lines.push(format!(
            "ckpt_base={base:<5}s heavy_turnaround={:.1}s preemptions={} \
             wasted_work={:.1}s ckpt_overhead={:.1}s",
            r.mean_turnaround_of(JobClass::Large),
            r.preemptions,
            r.wasted_work_s,
            r.ckpt_overhead_s
        ));
    }
    Report {
        title: "Preemption (beyond-paper): checkpoint/restart vs admit-or-wait, heavy late arrivals"
            .into(),
        lines,
    }
}

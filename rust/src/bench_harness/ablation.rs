//! Ablations for the design choices DESIGN.md calls out.
//!
//! 1. **Worker-pool size** (§V-A): the paper reports that on a 2:1
//!    16-job mix on 2×P100, MGB with 6 workers matches 16 workers and
//!    10 workers is ~10% faster — the sweep that motivated their
//!    10-worker default.
//! 2. **Fig. 4 at scale** (§V-B): "we also scaled our experiments to 32
//!    workers on 32-, 64-, and 128-job mixes, and observed similar
//!    improvements" — Alg3/Alg2 ratios at those sizes.
//! 3. **Seed robustness**: the headline MGB/SA averages across 5 mix
//!    seeds (the paper draws jobs randomly; conclusions must not hinge
//!    on one draw).

use super::{mgb_workers, run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::{Workload, MixRatio, WORKLOADS};

pub fn ablation(seed: u64) -> Report {
    let mut lines = Vec::new();

    // --- 1. worker sweep --------------------------------------------
    lines.push("-- MGB worker-pool sweep, W2 (16-job 2:1) on 2xP100 --".into());
    let node = NodeSpec::p100x2();
    let jobs = Workload::by_id("W2").unwrap().jobs(seed);
    let sweep: Vec<(usize, f64)> = [2usize, 6, 10, 16]
        .into_iter()
        .map(|workers| {
            (workers, run(&node, SchedMode::Policy("mgb3"), workers, jobs.clone()).throughput())
        })
        .collect();
    let base6 = sweep.iter().find(|(w, _)| *w == 6).unwrap().1;
    for (workers, tp) in sweep {
        lines.push(format!(
            "  {workers:>2} workers: {tp:.4} j/s ({rel:+.1}% vs 6 workers)",
            rel = (tp / base6 - 1.0) * 100.0
        ));
    }
    lines.push("  (paper: 6 == 16 workers; 10 workers ~10% faster)".into());

    // --- 2. Fig. 4 at scale ------------------------------------------
    lines.push("".into());
    lines.push("-- Alg3/Alg2 at 32 workers, larger mixes (4xV100) --".into());
    let node = NodeSpec::v100x4();
    for (id, n_jobs) in [("X32", 32usize), ("X64", 64), ("X128", 128)] {
        let w = Workload { id, n_jobs, ratio: MixRatio { large: 2, small: 1 } };
        let jobs = w.jobs(seed);
        let a2 = run(&node, SchedMode::Policy("mgb2"), 32, jobs.clone());
        let a3 = run(&node, SchedMode::Policy("mgb3"), 32, jobs);
        lines.push(format!(
            "  {n_jobs:>3} jobs: alg3/alg2 = {:.2}x",
            a3.throughput() / a2.throughput()
        ));
    }
    lines.push("  (paper: 'similar improvements' to the 1.21x of Fig. 4)".into());

    // --- 3. seed robustness ------------------------------------------
    lines.push("".into());
    lines.push("-- MGB/SA average over W1-W8 across 5 seeds (4xV100) --".into());
    let workers = mgb_workers(&node);
    for s in 0..5u64 {
        let seed_s = seed.wrapping_add(s * 7919);
        let mut acc = 0.0;
        for w in WORKLOADS {
            let jobs = w.jobs(seed_s);
            let sa = run(&node, SchedMode::Sa, 0, jobs.clone());
            let mgb = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
            acc += mgb.throughput() / sa.throughput();
        }
        lines.push(format!("  seed {s}: MGB/SA avg {:.2}x", acc / WORKLOADS.len() as f64));
    }

    // --- 4. open system (extension beyond the paper's batch setup) ---
    lines.push("".into());
    lines.push("-- open system: Poisson arrivals, W2 job pool, 4xV100 --".into());
    for mean_gap_s in [12.0f64, 6.0, 3.0] {
        let jobs = arrivals_mix(seed, 32, mean_gap_s);
        let sa = run(&node, SchedMode::Sa, 0, jobs.clone());
        let mgb = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
        lines.push(format!(
            "  mean inter-arrival {mean_gap_s:>4.0}s: turnaround SA {:>6.1}s vs MGB {:>6.1}s ({:.1}x)",
            sa.mean_turnaround(),
            mgb.mean_turnaround(),
            sa.mean_turnaround() / mgb.mean_turnaround()
        ));
    }
    lines.push("  (batch at t=0 is the paper's setup; arrivals are our extension)".into());

    Report { title: "Ablations — workers / scale / seeds / arrivals".into(), lines }
}

/// 32 jobs from the W2 pool with exponential inter-arrival gaps.
fn arrivals_mix(seed: u64, n: usize, mean_gap_s: f64) -> Vec<crate::coordinator::JobSpec> {
    use crate::workloads::rng::Rng;
    let mut rng = Rng::new(seed ^ 0xa88a);
    let mut jobs = Workload { id: "OPEN", n_jobs: n, ratio: MixRatio { large: 2, small: 1 } }
        .jobs(seed);
    let mut t = 0.0;
    for j in &mut jobs {
        t += -mean_gap_s * (1.0 - rng.f64()).ln();
        j.arrival = t;
    }
    jobs
}

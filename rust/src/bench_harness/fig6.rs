//! Fig. 6: throughput of schedGPU vs MGB on homogeneous 8-job NN
//! workloads, 4×V100. Paper: predict 1.4×, generate 2.2×, train 3.1×,
//! detect ≈ 1× (MGB over schedGPU).

use super::{run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::{nn_homogeneous, NN_TASKS};

pub fn fig6() -> Report {
    let node = NodeSpec::v100x4();
    // §V-E: 32-core node, 1 in 4 cores creating GPU work -> 8 workers.
    let workers = 8;
    let mut lines = vec![format!(
        "{:<12} {:>14} {:>12} {:>12}",
        "task", "schedGPU (j/s)", "MGB (j/s)", "MGB/schedGPU"
    )];
    let paper = [("nn-predict", 1.4), ("nn-train", 3.1), ("nn-detect", 1.0), ("nn-generate", 2.2)];
    for t in NN_TASKS {
        let jobs = nn_homogeneous(t);
        let name = t.profile().name;
        let sg = run(&node, SchedMode::Policy("schedgpu"), workers, jobs.clone());
        let mgb = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
        let ratio = mgb.throughput() / sg.throughput();
        let p = paper.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or(0.0);
        lines.push(format!(
            "{:<12} {:>14.4} {:>12.4} {:>11.2}x  (paper {:.1}x)",
            name,
            sg.throughput(),
            mgb.throughput(),
            ratio,
            p
        ));
    }
    Report { title: "Fig. 6 — 8-job homogeneous NN workloads, 4xV100".into(), lines }
}

//! Table III: MGB average job-turnaround speedup over SA, per node /
//! job count / mix. Paper: avg 3.7× (P100s) and 2.8× (V100s), max 4.9×.

use super::{mgb_workers, run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::WORKLOADS;

pub fn table3(seed: u64) -> Report {
    let mut lines = vec![format!(
        "{:<8} {:<9} {:>8} {:>8} {:>8} {:>8}",
        "GPUs", "# jobs", "1:1", "2:1", "3:1", "5:1"
    )];
    let mut alls = Vec::new();
    for node in [NodeSpec::p100x2(), NodeSpec::v100x4()] {
        let workers = mgb_workers(&node);
        for n_jobs in [16usize, 32] {
            let mut cells = Vec::new();
            for w in WORKLOADS.iter().filter(|w| w.n_jobs == n_jobs) {
                let jobs = w.jobs(seed);
                let sa = run(&node, SchedMode::Sa, 0, jobs.clone());
                let mgb = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
                let speedup = sa.mean_turnaround() / mgb.mean_turnaround();
                cells.push(speedup);
                alls.push((node.n_gpus(), speedup));
            }
            lines.push(format!(
                "{:<8} {:<9} {:>7.1}x {:>7.1}x {:>7.1}x {:>7.1}x",
                node.name, format!("{n_jobs} jobs"), cells[0], cells[1], cells[2], cells[3]
            ));
        }
    }
    let avg = |n: usize| {
        let v: Vec<f64> = alls.iter().filter(|(g, _)| *g == n).map(|(_, s)| *s).collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    lines.push(format!(
        "avg: P100s {:.1}x (paper 3.7x), V100s {:.1}x (paper 2.8x), max {:.1}x (paper 4.9x)",
        avg(2),
        avg(4),
        alls.iter().map(|(_, s)| *s).fold(0.0, f64::max)
    ));
    Report { title: "Table III — MGB turnaround speedup over SA".into(), lines }
}

//! `bench overload` — the overload-governance sweep behind the
//! admission layer (ROADMAP "cluster frontend overload governance").
//!
//! The cluster's capacity for the committed heavy-tailed mix is
//! measured first (batch-at-0 is service-limited end to end, so its
//! throughput *is* the capacity — self-calibrating, no magic
//! constants). The sweep then offers Poisson traffic at multiples of
//! that capacity spanning the knee (0.5x under, 1x at, up to 3x past)
//! under each admission policy — `off` (the ungoverned frontend),
//! `token` (bucket refilled at the capacity rate), `util` (backlog
//! threshold) — and records goodput, per-class SLO attainment, and the
//! reject/degrade counts per row.
//!
//! The story the columns tell: past the knee the ungoverned frontend
//! keeps accepting work it can only queue, so latency-sensitive
//! attainment collapses while goodput plateaus at capacity; the
//! governed rows shed or degrade best-effort/batch work instead, keep
//! goodput on the same plateau (admission must not cost completions —
//! `bench_smoke` gates on it), and hold the latency-sensitive class's
//! attainment at or above the ungoverned row's.
//!
//! Like `bench scale` / `bench interference`, the full experiment
//! writes a machine-readable artifact (`BENCH_OVERLOAD.json` at the
//! repo root) and is kept out of `run_all` because of that side
//! effect.

use super::json::{float, float_g};
use super::{mgb_workers, Report};
use crate::coordinator::{run_cluster, ClusterConfig, RunResult, SchedMode};
use crate::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use crate::sched::{AdmissionConfig, SloClass};
use crate::workloads::{heavy_tailed_mix, poisson_arrivals};

/// Heavy-tailed jobs per node per row — enough that the elephants'
/// share of total work is stable across seeds, small enough that the
/// full sweep stays seconds, not minutes.
pub const OVERLOAD_JOBS_PER_NODE: usize = 80;
/// Pareto shape of the mix: 1.5 keeps the mean finite (just) while a
/// handful of elephants still carry most of the offered work.
pub const OVERLOAD_ALPHA: f64 = 1.5;
/// Offered-load multipliers of measured capacity: below, at, and past
/// the knee.
pub const MULTIPLIERS: [f64; 5] = [0.5, 1.0, 1.5, 2.0, 3.0];
/// The admission policies every multiplier is run under.
pub const POLICIES: [&str; 3] = ["off", "token", "util"];

/// One measured sweep row.
#[derive(Clone, Debug)]
pub struct OverloadRow {
    pub policy: &'static str,
    /// Offered load as a multiple of measured capacity.
    pub multiplier: f64,
    /// Offered Poisson rate, jobs/s.
    pub offered_rate: f64,
    pub jobs: usize,
    pub rejected: u64,
    pub degraded: u64,
    /// Completions (non-crashed, non-rejected) per second of makespan.
    pub goodput: f64,
    pub reject_rate: f64,
    /// Per-class SLO attainment; NaN when the class has no surviving
    /// jobs (renders as JSON `null` through the guarded formatter).
    pub ls_attainment: f64,
    pub batch_attainment: f64,
    pub be_attainment: f64,
    pub mean_turnaround_s: f64,
}

impl OverloadRow {
    fn from_result(policy: &'static str, multiplier: f64, offered_rate: f64, r: &RunResult) -> Self {
        let att = |c| r.slo_attainment(c).unwrap_or(f64::NAN);
        OverloadRow {
            policy,
            multiplier,
            offered_rate,
            jobs: r.jobs.len(),
            rejected: r.rejected,
            degraded: r.degraded,
            goodput: r.throughput(),
            reject_rate: r.reject_rate(),
            ls_attainment: att(SloClass::LatencySensitive),
            batch_attainment: att(SloClass::Batch),
            be_attainment: att(SloClass::BestEffort),
            mean_turnaround_s: r.mean_turnaround(),
        }
    }

    fn line(&self) -> String {
        format!(
            "{:<5} mult={:<4} offered={:.2}j/s jobs={:<4} rejected={:<3} degraded={:<3} \
             goodput={:.4}j/s reject_rate={:.3} ls_att={} batch_att={} be_att={} \
             mean_turnaround={:.1}s",
            self.policy,
            self.multiplier,
            self.offered_rate,
            self.jobs,
            self.rejected,
            self.degraded,
            self.goodput,
            self.reject_rate,
            float(self.ls_attainment, 3),
            float(self.batch_attainment, 3),
            float(self.be_attainment, 3),
            self.mean_turnaround_s
        )
    }
}

fn overload_cfg(node: &NodeSpec, nodes: usize, admit: Option<AdmissionConfig>) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(node.clone(), nodes),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: mgb_workers(node),
        dispatch: "least",
        preempt: None,
        latency: LatencyModel::off(),
        admit,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

/// Measured service capacity (jobs/s) of an `nodes`-node cluster for
/// the committed mix: every job arrives at t=0, so the run is
/// service-limited from the first event to the last and
/// completions/makespan is the capacity itself. Deterministic per
/// seed — the sweep's multipliers mean the same thing on every run.
pub fn capacity_rate(seed: u64, nodes: usize) -> f64 {
    let jobs = heavy_tailed_mix(nodes * OVERLOAD_JOBS_PER_NODE, OVERLOAD_ALPHA, seed);
    let r = run_cluster(overload_cfg(&NodeSpec::v100x4(), nodes, None), jobs);
    r.throughput()
}

fn admit_for(policy: &'static str, capacity: f64) -> Option<AdmissionConfig> {
    match policy {
        "off" => None,
        // Bucket refilled at the capacity rate: the frontend admits
        // (or degrades into the best-effort class) what the cluster
        // can serve and sheds the best-effort excess.
        "token" => Some(AdmissionConfig {
            policy: "token",
            rate_per_s: capacity,
            burst: 8.0,
            ..Default::default()
        }),
        // Backlog threshold: ten seconds of queued work per unit of
        // cluster capacity before the frontend starts shedding.
        "util" => Some(AdmissionConfig {
            policy: "util",
            util_threshold_s: 10.0,
            ..Default::default()
        }),
        other => panic!("unknown overload policy '{other}'"),
    }
}

/// Run one (policy, multiplier) sweep point.
pub fn overload_row(
    seed: u64,
    nodes: usize,
    policy: &'static str,
    multiplier: f64,
    capacity: f64,
) -> OverloadRow {
    let rate = multiplier * capacity;
    let mut jobs = heavy_tailed_mix(nodes * OVERLOAD_JOBS_PER_NODE, OVERLOAD_ALPHA, seed);
    poisson_arrivals(&mut jobs, rate, seed);
    let r = run_cluster(
        overload_cfg(&NodeSpec::v100x4(), nodes, admit_for(policy, capacity)),
        jobs,
    );
    OverloadRow::from_result(policy, multiplier, rate, &r)
}

/// The fixed small point `bench_smoke` gates on: a 2-node cluster at
/// 2x-capacity offered load, ungoverned vs token bucket. Returns
/// `(knee, off_row, token_row)` where the knee is the best ungoverned
/// goodput over {0.5x, 1x, 2x} — the capacity plateau the governed
/// row must stay on.
pub fn overload_smoke(seed: u64) -> (f64, OverloadRow, OverloadRow) {
    let nodes = 2;
    let cap = capacity_rate(seed, nodes);
    let knee = [0.5, 1.0, 2.0]
        .into_iter()
        .map(|m| overload_row(seed, nodes, "off", m, cap).goodput)
        .fold(f64::MIN, f64::max);
    let off = overload_row(seed, nodes, "off", 2.0, cap);
    let token = overload_row(seed, nodes, "token", 2.0, cap);
    (knee, off, token)
}

/// Render the machine-readable `BENCH_OVERLOAD.json` document
/// (hand-rolled like the rest of the crate's JSON; every float goes
/// through the guarded formatter — absent attainments are `null`, not
/// `NaN`).
pub fn bench_overload_json(
    provenance: &str,
    seed: u64,
    nodes: usize,
    capacity: f64,
    rows: &[OverloadRow],
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"mgb-bench-overload-v1\",\n");
    s.push_str(&format!("  \"provenance\": \"{provenance}\",\n"));
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"nodes\": {nodes},\n"));
    s.push_str(&format!("  \"capacity_jobs_per_s\": {},\n", float(capacity, 4)));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"policy\": \"{}\", \"multiplier\": {}, \"offered_rate\": {}, \
             \"jobs\": {}, \"rejected\": {}, \"degraded\": {}, \"goodput\": {}, \
             \"reject_rate\": {}, \"ls_attainment\": {}, \"batch_attainment\": {}, \
             \"be_attainment\": {}, \"mean_turnaround_s\": {}}}{}\n",
            r.policy,
            float_g(r.multiplier),
            float(r.offered_rate, 4),
            r.jobs,
            r.rejected,
            r.degraded,
            float(r.goodput, 6),
            float(r.reject_rate, 4),
            float(r.ls_attainment, 4),
            float(r.batch_attainment, 4),
            float(r.be_attainment, 4),
            float(r.mean_turnaround_s, 3),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// The `bench --exp overload` entry: measure capacity on a 4-node
/// cluster, sweep every (policy, multiplier) point, write
/// `BENCH_OVERLOAD.json` at the repo root. Deliberately not part of
/// `run_all` (the JSON write is a side effect).
pub fn overload(seed: u64) -> Report {
    let nodes = 4;
    let cap = capacity_rate(seed, nodes);
    let mut lines = vec![format!(
        "capacity={cap:.3}j/s ({nodes}n v100x4, {} heavy-tailed jobs batch-at-0)",
        nodes * OVERLOAD_JOBS_PER_NODE
    )];
    let mut rows = Vec::with_capacity(POLICIES.len() * MULTIPLIERS.len());
    for policy in POLICIES {
        for m in MULTIPLIERS {
            let row = overload_row(seed, nodes, policy, m, cap);
            lines.push(row.line());
            rows.push(row);
        }
    }
    let json = bench_overload_json("measured", seed, nodes, cap, &rows);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_OVERLOAD.json");
    match std::fs::write(&path, &json) {
        Ok(()) => lines.push(format!("wrote {}", path.display())),
        Err(e) => lines.push(format!("WARN: could not write {}: {e}", path.display())),
    }
    Report {
        title: "Overload governance sweep (admission off vs token bucket vs util threshold)"
            .into(),
        lines,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_document_is_well_formed_enough_to_gate_on() {
        let row = OverloadRow {
            policy: "token",
            multiplier: 2.0,
            offered_rate: 1.25,
            jobs: 160,
            rejected: 40,
            degraded: 12,
            goodput: 0.61,
            reject_rate: 0.25,
            ls_attainment: 0.875,
            batch_attainment: 0.5,
            // The class that shed every job: must land as null.
            be_attainment: f64::NAN,
            mean_turnaround_s: 42.5,
        };
        let s = bench_overload_json("measured", 7, 2, 0.62, &[row]);
        assert!(s.contains("\"schema\": \"mgb-bench-overload-v1\""));
        assert!(s.contains("\"policy\": \"token\""));
        assert!(s.contains("\"ls_attainment\": 0.8750"));
        assert!(s.contains("\"be_attainment\": null"));
        assert!(!s.contains("NaN") && !s.contains("inf"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn capacity_is_deterministic_and_positive() {
        let a = capacity_rate(7, 2);
        let b = capacity_rate(7, 2);
        assert_eq!(a, b, "capacity calibration must replay exactly");
        assert!(a > 0.0 && a.is_finite());
    }

    #[test]
    fn off_rows_reject_nothing_and_governed_rows_only_shed_under_pressure() {
        let cap = capacity_rate(7, 2);
        let off = overload_row(7, 2, "off", 2.0, cap);
        assert_eq!((off.rejected, off.degraded), (0, 0), "ungoverned frontend never sheds");
        let under = overload_row(7, 2, "token", 0.5, cap);
        let over = overload_row(7, 2, "token", 3.0, cap);
        assert!(
            over.rejected + over.degraded >= under.rejected + under.degraded,
            "shedding must not decrease with offered load \
             (under: {}+{}, over: {}+{})",
            under.rejected,
            under.degraded,
            over.rejected,
            over.degraded
        );
        assert!(over.rejected > 0, "3x capacity must trip the bucket");
    }
}

//! Table IV: per-kernel slowdown vs single-assignment for Alg. 2 and
//! Alg. 3 on W1–W8, 4×V100, in percent. Paper: Alg2 avg 1.8%, Alg3 avg
//! 2.5%, max 7%, occasionally negative (noise floor).

use super::{mgb_workers, run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::WORKLOADS;

pub fn table4(seed: u64) -> Report {
    let node = NodeSpec::v100x4();
    let workers = mgb_workers(&node);
    let mut rows: Vec<(&str, Vec<f64>)> = vec![("Alg2", Vec::new()), ("Alg3", Vec::new())];
    for w in WORKLOADS {
        let jobs = w.jobs(seed);
        let a2 = run(&node, SchedMode::Policy("mgb2"), workers, jobs.clone());
        let a3 = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
        rows[0].1.push(a2.kernel_slowdown_pct());
        rows[1].1.push(a3.kernel_slowdown_pct());
    }
    let mut lines = vec![{
        let mut h = format!("{:<6}", "Sched");
        for w in WORKLOADS {
            h.push_str(&format!("{:>7}", w.id));
        }
        h.push_str(&format!("{:>7}", "Avg"));
        h
    }];
    for (name, vals) in &rows {
        let mut l = format!("{name:<6}");
        for v in vals {
            l.push_str(&format!("{v:>6.1} "));
        }
        let avg = vals.iter().sum::<f64>() / vals.len() as f64;
        l.push_str(&format!("{avg:>6.1}"));
        lines.push(l);
    }
    lines.push("(percent slowdown; paper: Alg2 avg 1.8, Alg3 avg 2.5, max 7.0)".into());
    Report { title: "Table IV — kernel slowdown vs dedicated (%)".into(), lines }
}

//! Regenerates every table and figure of the paper's evaluation (§V).
//!
//! Each experiment builds the same workloads, runs the batch coordinator
//! under the schedulers the paper compares, and prints the same rows/
//! series the paper reports (normalised the same way). Absolute numbers
//! come from the simulator calibration (DESIGN.md §4); the *shape* —
//! who wins, by what factor, where the crossovers are — is the
//! reproduction target recorded in EXPERIMENTS.md.

mod ablation;
mod cluster_scale;
mod fig4;
mod fig5;
mod fig6;
mod interference;
pub mod json;
mod latency;
mod migrate;
mod nn128;
mod overload;
mod preempt;
mod scale;
mod table2;
mod table3;
mod table4;

use crate::coordinator::{run_batch, JobSpec, RunConfig, RunResult, SchedMode};
use crate::gpu::NodeSpec;

pub use ablation::ablation;
pub use cluster_scale::cluster_scale;
pub use fig4::fig4;
pub use fig5::fig5;
pub use fig6::fig6;
pub use interference::{
    bench_interference_json, hot_mix_comparison, hot_row, interference, w5_row, InterferenceRow,
};
pub use latency::{
    asymmetric_comparison, latency, latency_dispatch_comparison, latency_sweep, reprobe_model,
    sweep_model, RTT_SWEEP,
};
pub use migrate::{migrate, migrate_comparison, MIGRATE_RTT_SWEEP};
pub use nn128::nn128;
pub use overload::{
    bench_overload_json, capacity_rate, overload, overload_row, overload_smoke, OverloadRow,
    MULTIPLIERS, OVERLOAD_ALPHA, OVERLOAD_JOBS_PER_NODE, POLICIES,
};
pub use preempt::preempt;
pub use scale::{
    bench_scale_json, calibration_events_per_s, run_point, scale, scale_smoke_point, ScalePoint,
    ScaleRow, RATE_PER_NODE, SWEEP,
};
pub use table2::table2;
pub use table3::table3;
pub use table4::table4;

/// Default deterministic seed for workload mixes.
pub const DEFAULT_SEED: u64 = 20210521;

/// MGB worker-pool sizes the paper settled on (§V-A).
pub fn mgb_workers(node: &NodeSpec) -> usize {
    match node.n_gpus() {
        2 => 10,
        4 => 16,
        n => 4 * n,
    }
}

/// CG worker-count sweep per node (§V: 3–6 on the P100 node, 6–12 on
/// the V100 node — Table II's rows).
pub fn cg_worker_sweep(node: &NodeSpec) -> Vec<usize> {
    match node.n_gpus() {
        2 => vec![3, 4, 5, 6],
        _ => vec![6, 8, 10, 12],
    }
}

/// A text report: title + pre-formatted lines (also machine-parseable,
/// `key=value` style where it matters).
pub struct Report {
    pub title: String,
    pub lines: Vec<String>,
}

impl Report {
    pub fn print(&self) {
        println!("== {} ==", self.title);
        for l in &self.lines {
            println!("{l}");
        }
        println!();
    }

    pub fn to_string(&self) -> String {
        let mut s = format!("== {} ==\n", self.title);
        for l in &self.lines {
            s.push_str(l);
            s.push('\n');
        }
        s
    }
}

/// Run one batch under a mode.
pub fn run(node: &NodeSpec, mode: SchedMode, workers: usize, jobs: Vec<JobSpec>) -> RunResult {
    run_batch(RunConfig { node: node.clone(), mode, workers }, jobs)
}

/// CG at its best non-crashing worker count, as the paper does for
/// Fig. 5 ("we swept different worker pool sizes for the CG scheduler
/// and took the best performing runs that did not crash"). Returns the
/// chosen worker count alongside the result; if every swept size
/// crashes, the least-crashing one is returned (the paper notes CG
/// crashed in some configurations — those rows show up in Table II).
pub fn best_cg(node: &NodeSpec, jobs: &[JobSpec]) -> (usize, RunResult) {
    let mut best: Option<(usize, RunResult)> = None;
    for w in cg_worker_sweep(node) {
        let r = run(node, SchedMode::Cg, w, jobs.to_vec());
        let better = match &best {
            None => true,
            Some((_, b)) => {
                let (bc, rc) = (b.crashed(), r.crashed());
                (rc == 0 && bc > 0)
                    || (rc == 0 && bc == 0 && r.throughput() > b.throughput())
                    || (rc > 0 && bc > 0 && (rc < bc || (rc == bc && r.throughput() > b.throughput())))
            }
        };
        if better {
            best = Some((w, r));
        }
    }
    best.expect("non-empty sweep")
}

/// Run all experiments, returning reports in paper order.
pub fn run_all(seed: u64) -> Vec<Report> {
    vec![
        fig4(seed),
        fig5(seed),
        table2(seed),
        table3(seed),
        fig6(),
        nn128(seed),
        table4(seed),
        ablation(seed),
        cluster_scale(seed),
        preempt(seed),
        latency(seed),
        migrate(seed),
    ]
}

/// Dispatch by experiment id.
pub fn run_experiment(name: &str, seed: u64) -> Option<Report> {
    Some(match name {
        "fig4" => fig4(seed),
        "fig5" => fig5(seed),
        "fig6" => fig6(),
        "table2" => table2(seed),
        "table3" => table3(seed),
        "table4" => table4(seed),
        "nn128" => nn128(seed),
        "ablation" => ablation(seed),
        "cluster" => cluster_scale(seed),
        "preempt" => preempt(seed),
        "latency" => latency(seed),
        "migrate" => migrate(seed),
        // Not in `run_all`: the 1000-node rows take minutes, and the
        // sweep writes BENCH_SCALE.json at the repo root as a side
        // effect — run it deliberately (`bench --exp scale`).
        "scale" => scale(seed),
        // Not in `run_all` either: writes BENCH_INTERFERENCE.json at
        // the repo root as a side effect (`bench --exp interference`).
        "interference" => interference(seed),
        // Same contract: writes BENCH_OVERLOAD.json at the repo root
        // (`bench --exp overload`).
        "overload" => overload(seed),
        _ => return None,
    })
}

/// Minimal timing harness (no criterion in the offline crate set):
/// warm up, run `iters` timed iterations, report mean / min / max in a
/// criterion-like line. Returns mean seconds.
pub fn time_it<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters.div_ceil(10).max(1) {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let fmt = |s: f64| {
        if s < 1e-6 {
            format!("{:.1} ns", s * 1e9)
        } else if s < 1e-3 {
            format!("{:.2} us", s * 1e6)
        } else if s < 1.0 {
            format!("{:.2} ms", s * 1e3)
        } else {
            format!("{s:.3} s")
        }
    };
    println!(
        "{name:<44} mean {:>10}   min {:>10}   max {:>10}   ({iters} iters)",
        fmt(mean),
        fmt(samples[0]),
        fmt(*samples.last().unwrap())
    );
    mean
}

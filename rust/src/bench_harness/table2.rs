//! Table II: percentage of crashed jobs under CG, by worker count and
//! mix ratio, on both nodes. Paper: erratic, growing with workers, up to
//! 50% on V100s at 12 workers / 5:1.

use super::{cg_worker_sweep, run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::{Workload, WORKLOADS};

pub fn table2(seed: u64) -> Report {
    let mut lines = Vec::new();
    // Table II aggregates 16- and 32-job workloads per ratio; we report
    // the mean crash % of the two sizes, like the paper's single cell.
    for node in [NodeSpec::p100x2(), NodeSpec::v100x4()] {
        lines.push(format!("--- {} ---", node.name));
        lines.push(format!(
            "{:<10} {:>8} {:>8} {:>8} {:>8}",
            "# workers", "1:1", "2:1", "3:1", "5:1"
        ));
        for workers in cg_worker_sweep(&node) {
            let mut cells = Vec::new();
            for ratio_idx in 0..4 {
                let pair: Vec<&Workload> = WORKLOADS
                    .iter()
                    .filter(|w| w.ratio == crate::workloads::RATIOS[ratio_idx])
                    .collect();
                let mut pct = 0.0;
                for w in &pair {
                    let r = run(&node, SchedMode::Cg, workers, w.jobs(seed));
                    pct += r.crash_pct();
                }
                cells.push(pct / pair.len() as f64);
            }
            lines.push(format!(
                "{:<10} {:>7.0}% {:>7.0}% {:>7.0}% {:>7.0}%",
                workers, cells[0], cells[1], cells[2], cells[3]
            ));
        }
    }
    lines.push("(paper: 0-22% on P100s, 0-50% on V100s, rising with workers)".into());
    Report { title: "Table II — CG crashed-job percentage".into(), lines }
}

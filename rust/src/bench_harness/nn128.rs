//! §V-E large-scale: 128-job random NN mix, 32 workers, 4×V100.
//! Paper: MGB completes the batch 2.7× faster than single-assignment.

use super::{run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::nn_mix;

pub fn nn128(seed: u64) -> Report {
    let node = NodeSpec::v100x4();
    let jobs = nn_mix(128, seed);
    let sa = run(&node, SchedMode::Sa, 0, jobs.clone());
    let mgb = run(&node, SchedMode::Policy("mgb3"), 32, jobs);
    let speedup = sa.makespan / mgb.makespan;
    let lines = vec![
        format!("SA   : makespan {:>8.1}s, throughput {:.4} j/s", sa.makespan, sa.throughput()),
        format!("MGB  : makespan {:>8.1}s, throughput {:.4} j/s", mgb.makespan, mgb.throughput()),
        format!("MGB completes the batch {speedup:.1}x faster   (paper: 2.7x)"),
    ];
    Report { title: "§V-E — 128-job NN mix, 32 workers, 4xV100".into(), lines }
}

//! Interference-aware sharing vs MIG-style partitioning (beyond-paper;
//! ROADMAP "Interference-aware device model"). Two sections:
//!
//! * **W5 open-system rows** — the exact `bench cluster` 4-node
//!   construction (same seeds, same Poisson stamping), run with the
//!   per-benchmark interference vectors off and on. The off rows must
//!   reproduce `bench cluster`'s numbers to the bit (the zero-vector
//!   contract; `bench_smoke` gates on it), so the on rows isolate what
//!   modeled contention costs the sharing dispatchers.
//! * **High-pressure mix rows** — small-footprint synthetic jobs
//!   (2 GiB, so four fit an 8 GiB half-V100 slice) carrying hot
//!   profiles that fight over DRAM bandwidth, routed by the sharing
//!   dispatchers vs `--dispatch partition`. Partitioning bounds
//!   co-residency per isolation domain, so its worst-case per-kernel
//!   degradation must come in at or below the sharing dispatchers' —
//!   the predictability-for-peak-throughput trade the report's columns
//!   make visible.
//!
//! Like `bench scale`, the full experiment writes a machine-readable
//! artifact (`BENCH_INTERFERENCE.json` at the repo root) and is kept
//! out of `run_all` because of that side effect.

use super::json::float;
use super::{mgb_workers, Report};
use crate::coordinator::{run_cluster, ClusterConfig, JobClass, JobSpec, RunResult, SchedMode};
use crate::gpu::{ClusterSpec, InterferenceProfile, LatencyModel, NodeSpec};
use crate::workloads::{assign_interference, poisson_arrivals, synthetic_job_with_iv, Workload};

/// One measured row of the interference report.
#[derive(Clone, Debug)]
pub struct InterferenceRow {
    /// Which section produced the row: "w5" or "hot".
    pub section: &'static str,
    pub dispatch: &'static str,
    /// Whether the job mix carried nonzero interference vectors.
    pub interference: bool,
    pub nodes: usize,
    pub jobs: usize,
    pub completed: usize,
    pub crashed: usize,
    pub throughput: f64,
    pub mean_turnaround_s: f64,
    /// Time-weighted mean kernel slowdown vs dedicated execution (%).
    pub kernel_slowdown_pct: f64,
    /// Worst per-job kernel slowdown (%) — the predictability tail the
    /// partition dispatcher exists to bound.
    pub worst_kernel_slowdown_pct: f64,
}

impl InterferenceRow {
    fn from_result(
        section: &'static str,
        dispatch: &'static str,
        interference: bool,
        nodes: usize,
        r: &RunResult,
    ) -> Self {
        InterferenceRow {
            section,
            dispatch,
            interference,
            nodes,
            jobs: r.jobs.len(),
            completed: r.completed(),
            crashed: r.crashed(),
            throughput: r.throughput(),
            mean_turnaround_s: r.mean_turnaround(),
            kernel_slowdown_pct: r.kernel_slowdown_pct(),
            worst_kernel_slowdown_pct: r.worst_kernel_slowdown_pct(),
        }
    }

    fn line(&self) -> String {
        format!(
            "{:<4} nodes={} dispatch={:<9} interference={:<5} jobs={:<3} completed={:<3} \
             crashed={} throughput={:.4}j/s mean_turnaround={:.1}s \
             kernel_slowdown={:.2}% worst_kernel_slowdown={:.2}%",
            self.section,
            self.nodes,
            self.dispatch,
            self.interference,
            self.jobs,
            self.completed,
            self.crashed,
            self.throughput,
            self.mean_turnaround_s,
            self.kernel_slowdown_pct,
            self.worst_kernel_slowdown_pct
        )
    }
}

fn cluster_cfg(node: &NodeSpec, nodes: usize, dispatch: &'static str) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(node.clone(), nodes),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: mgb_workers(node),
        dispatch,
        preempt: None,
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

/// The `bench cluster` job stream, verbatim: `nodes` copies of the W5
/// mix drawn with distinct seeds, stamped with Poisson arrivals at
/// [`super::RATE_PER_NODE`] jobs/s per node. Keeping this construction
/// byte-for-byte identical to `cluster_scale` is what makes the
/// interference-off rows comparable to (and gated against) the
/// existing `bench cluster` numbers.
fn w5_jobs(seed: u64, nodes: usize) -> Vec<JobSpec> {
    let w5 = Workload::by_id("W5").expect("W5 exists");
    let mut jobs = Vec::new();
    for k in 0..nodes as u64 {
        jobs.extend(w5.jobs(seed.wrapping_add(k)));
    }
    poisson_arrivals(&mut jobs, super::RATE_PER_NODE * nodes as f64, seed);
    jobs
}

/// One W5 open-system row, with the per-benchmark vectors optionally
/// stamped on (`workloads::assign_interference` — the `--interference`
/// CLI mapping).
pub fn w5_row(seed: u64, nodes: usize, dispatch: &'static str, interference: bool) -> InterferenceRow {
    let node = NodeSpec::v100x4();
    let mut jobs = w5_jobs(seed, nodes);
    if interference {
        assign_interference(&mut jobs);
    }
    let r = run_cluster(cluster_cfg(&node, nodes, dispatch), jobs);
    InterferenceRow::from_result("w5", dispatch, interference, nodes, &r)
}

const HOT_JOBS_PER_NODE: usize = 24;
/// 2 GiB footprint: four jobs fit one 8 GiB half-V100 slice, eight fit
/// a whole V100 — partitioning halves the worst-case co-residency.
const HOT_MEM_BYTES: u64 = 2 << 30;
const HOT_WORK_US: u64 = 6_000_000;
/// Arrival rate per node (jobs/s). Above the dedicated-rate service
/// capacity, so devices actually co-schedule and the vectors bite.
const HOT_RATE_PER_NODE: f64 = 1.0;

/// The high-pressure mix: two in three jobs hammer DRAM bandwidth, the
/// third is SM-bound, all with footprints that fit a half-V100 slice.
fn hot_jobs(seed: u64, nodes: usize, interference: bool) -> Vec<JobSpec> {
    let n = HOT_JOBS_PER_NODE * nodes;
    let mut jobs: Vec<JobSpec> = (0..n)
        .map(|i| {
            let (tag, iv) = if i % 3 == 2 {
                ("sm", InterferenceProfile::new(0.3, 0.25, 0.8))
            } else {
                ("bw", InterferenceProfile::new(0.8, 0.45, 0.55))
            };
            let iv = if interference { iv } else { InterferenceProfile::ZERO };
            synthetic_job_with_iv(
                &format!("hot#{i:02}-{tag}"),
                JobClass::Small,
                HOT_MEM_BYTES,
                HOT_WORK_US,
                0.0,
                iv,
            )
        })
        .collect();
    poisson_arrivals(&mut jobs, HOT_RATE_PER_NODE * nodes as f64, seed);
    jobs
}

/// One high-pressure-mix row.
pub fn hot_row(seed: u64, nodes: usize, dispatch: &'static str, interference: bool) -> InterferenceRow {
    let node = NodeSpec::v100x4();
    let jobs = hot_jobs(seed, nodes, interference);
    let r = run_cluster(cluster_cfg(&node, nodes, dispatch), jobs);
    InterferenceRow::from_result("hot", dispatch, interference, nodes, &r)
}

/// The sharing-vs-partition comparison `bench_smoke` gates on: the
/// 2-node high-pressure mix with vectors on, under least-loaded,
/// memory-headroom, and partitioned dispatch. Partition's worst-case
/// per-kernel degradation must not exceed either sharing dispatcher's.
pub fn hot_mix_comparison(seed: u64) -> Vec<InterferenceRow> {
    ["least", "mem", "partition"]
        .into_iter()
        .map(|d| hot_row(seed, 2, d, true))
        .collect()
}

/// Render the machine-readable `BENCH_INTERFERENCE.json` document
/// (hand-rolled like the rest of the crate's JSON — the offline crate
/// set has no serde; floats go through the guarded `json` formatter so
/// a poisoned metric lands as `null`, not a NaN token).
pub fn bench_interference_json(provenance: &str, seed: u64, rows: &[InterferenceRow]) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"mgb-bench-interference-v1\",\n");
    s.push_str(&format!("  \"provenance\": \"{provenance}\",\n"));
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"section\": \"{}\", \"dispatch\": \"{}\", \"interference\": {}, \
             \"nodes\": {}, \"jobs\": {}, \"completed\": {}, \"crashed\": {}, \
             \"throughput\": {}, \"mean_turnaround_s\": {}, \
             \"kernel_slowdown_pct\": {}, \"worst_kernel_slowdown_pct\": {}}}{}\n",
            r.section,
            r.dispatch,
            r.interference,
            r.nodes,
            r.jobs,
            r.completed,
            r.crashed,
            float(r.throughput, 6),
            float(r.mean_turnaround_s, 6),
            float(r.kernel_slowdown_pct, 4),
            float(r.worst_kernel_slowdown_pct, 4),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// The `bench --exp interference` entry: W5 rows off/on under the
/// sharing dispatchers, the high-pressure mix under sharing vs
/// partition (off rows for the partition baseline ride along), then
/// write `BENCH_INTERFERENCE.json` at the repo root. Deliberately not
/// part of `run_all` (the JSON write is a side effect).
pub fn interference(seed: u64) -> Report {
    let mut rows = Vec::new();
    for interference in [false, true] {
        for dispatch in ["least", "mem"] {
            rows.push(w5_row(seed, 4, dispatch, interference));
        }
    }
    for dispatch in ["least", "partition"] {
        rows.push(hot_row(seed, 2, dispatch, false));
    }
    rows.extend(hot_mix_comparison(seed));

    let mut lines: Vec<String> = rows.iter().map(InterferenceRow::line).collect();
    let json = bench_interference_json("measured", seed, &rows);
    let path =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_INTERFERENCE.json");
    match std::fs::write(&path, &json) {
        Ok(()) => lines.push(format!("wrote {}", path.display())),
        Err(e) => lines.push(format!("WARN: could not write {}: {e}", path.display())),
    }
    Report {
        title: "Interference-aware sharing vs partitioned dispatch (W5 + high-pressure mixes)"
            .into(),
        lines,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_document_is_well_formed_enough_to_gate_on() {
        let row = InterferenceRow {
            section: "hot",
            dispatch: "partition",
            interference: true,
            nodes: 2,
            jobs: 48,
            completed: 48,
            crashed: 0,
            throughput: 0.5,
            mean_turnaround_s: 12.25,
            kernel_slowdown_pct: 8.5,
            worst_kernel_slowdown_pct: 30.125,
        };
        let s = bench_interference_json("measured", 7, &[row]);
        assert!(s.contains("\"schema\": \"mgb-bench-interference-v1\""));
        assert!(s.contains("\"dispatch\": \"partition\""));
        assert!(s.contains("\"interference\": true"));
        assert!(s.contains("\"worst_kernel_slowdown_pct\": 30.1250"));
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn hot_mix_construction_is_deterministic_and_slice_sized() {
        let a = hot_jobs(7, 2, true);
        let b = hot_jobs(7, 2, true);
        assert_eq!(a.len(), 2 * HOT_JOBS_PER_NODE);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.trace.peak_interference(), y.trace.peak_interference());
        }
        // Every job fits a half-V100 slice, and vectors follow the flag.
        for j in &a {
            assert!(
                j.trace.peak_reserved_bytes() <= 8 << 30,
                "{} must fit an 8 GiB slice",
                j.name
            );
            assert!(!j.trace.peak_interference().is_zero());
        }
        assert!(hot_jobs(7, 2, false).iter().all(|j| j.trace.peak_interference().is_zero()));
    }
}

//! Fig. 4: throughput of MGB Alg. 2 vs Alg. 3 on W1–W8, 4×V100,
//! normalised to Alg. 2. Paper: Alg. 3 averages 1.21× higher.

use super::{mgb_workers, run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::WORKLOADS;

pub fn fig4(seed: u64) -> Report {
    let node = NodeSpec::v100x4();
    let workers = mgb_workers(&node);
    let mut lines = vec![format!(
        "{:<4} {:>12} {:>12} {:>14}",
        "W", "alg2 (j/s)", "alg3 (j/s)", "alg3/alg2"
    )];
    let mut ratios = Vec::new();
    for w in WORKLOADS {
        let jobs = w.jobs(seed);
        let a2 = run(&node, SchedMode::Policy("mgb2"), workers, jobs.clone());
        let a3 = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
        let ratio = a3.throughput() / a2.throughput();
        ratios.push(ratio);
        lines.push(format!(
            "{:<4} {:>12.4} {:>12.4} {:>13.2}x",
            w.id,
            a2.throughput(),
            a3.throughput(),
            ratio
        ));
    }
    let avg = ratios.iter().sum::<f64>() / ratios.len() as f64;
    lines.push(format!("avg alg3/alg2 = {avg:.2}x   (paper: 1.21x)"));
    Report { title: "Fig. 4 — Alg2 vs Alg3 throughput, 4xV100".into(), lines }
}

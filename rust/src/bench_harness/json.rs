//! Guarded float formatting for the crate's hand-rolled JSON emitters
//! (the offline crate set has no serde).
//!
//! `format!("{x:.6}")` renders NaN and the infinities as the bare
//! tokens `NaN` / `inf` / `-inf`, which are not JSON — one poisoned
//! metric (a 0/0 rate on an empty row, a divide-by-zero speedup) used
//! to corrupt a whole `BENCH_*.json` artifact and take the CI gates
//! that parse it down with a JSON decode error instead of a named
//! regression. Every float in `BENCH_SCALE.json`,
//! `BENCH_INTERFERENCE.json`, and `BENCH_OVERLOAD.json` flows through
//! this module.
//!
//! **Convention:** non-finite values render as the JSON-legal `null`.
//! `null` round-trips through any JSON parser, is distinguishable from
//! a genuine `0.0`, and makes downstream gates fail on the *row* that
//! lost its metric rather than on the document. Emitters that have a
//! semantically absent value (e.g. per-class attainment when the class
//! shed every job) pass `f64::NAN` on purpose to get a `null`.

/// `x` to `prec` decimal places, or `null` when non-finite.
pub fn float(x: f64, prec: usize) -> String {
    if x.is_finite() {
        format!("{x:.prec$}")
    } else {
        "null".into()
    }
}

/// `x` in Rust's shortest round-trip form (no fixed precision), or
/// `null` when non-finite. For config-like values (rates, multipliers)
/// where trailing zeros would just be noise.
pub fn float_g(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_finite_floats_round_trip_as_json_null() {
        // The regression this module closes: every non-finite value
        // must land as the legal token `null`, never as NaN/inf text.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(float(bad, 4), "null");
            assert_eq!(float_g(bad), "null");
        }
        // Finite values keep their precision contract.
        assert_eq!(float(30.125, 4), "30.1250");
        assert_eq!(float(0.5, 6), "0.500000");
        assert_eq!(float(-1.0 / 3.0, 3), "-0.333");
        assert_eq!(float_g(0.35), "0.35");
        // A document assembled from poisoned metrics stays parseable:
        // no bare NaN/inf tokens, and every value slot is non-empty.
        let doc = format!(
            "{{\"a\": {}, \"b\": {}, \"c\": {}}}",
            float(0.0 / 0.0, 6),
            float(1.0 / 0.0, 2),
            float_g(2.5)
        );
        assert_eq!(doc, "{\"a\": null, \"b\": null, \"c\": 2.5}");
        assert!(!doc.contains("NaN") && !doc.contains("inf"));
    }
}

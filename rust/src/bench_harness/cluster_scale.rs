//! Beyond-paper scale-out: the same probe-driven per-node scheduling
//! (MGB Alg. 3), replicated across an N-node cluster and driven by
//! sustained Poisson traffic instead of a batch at t = 0. Rows compare
//! the cluster dispatchers (round-robin, least-loaded, memory-headroom)
//! at 1, 2, and 4 nodes; the arrival rate scales with cluster capacity
//! so per-node offered load stays comparable across rows.

use super::{mgb_workers, Report};
use crate::coordinator::{run_cluster, ClusterConfig, SchedMode};
use crate::gpu::{ClusterSpec, NodeSpec};
use crate::workloads::{poisson_arrivals, Workload};

pub fn cluster_scale(seed: u64) -> Report {
    let node = NodeSpec::v100x4();
    let w5 = Workload::by_id("W5").expect("W5 exists");
    let mut lines = Vec::new();
    for &n in &[1usize, 2, 4] {
        // n copies of the W5 mix, drawn with distinct seeds so the
        // stream stays heterogeneous, then stamped with Poisson
        // arrivals at 0.35 jobs/s per node.
        let mut jobs = Vec::new();
        for k in 0..n as u64 {
            jobs.extend(w5.jobs(seed.wrapping_add(k)));
        }
        poisson_arrivals(&mut jobs, 0.35 * n as f64, seed);
        // On one node every dispatcher routes identically (see the
        // single_node_cluster_matches_run_batch_exactly test); skip
        // the redundant rows.
        let dispatchers: &[&'static str] =
            if n == 1 { &["rr"] } else { &["rr", "least", "mem"] };
        for &dispatch in dispatchers {
            let cfg = ClusterConfig {
                cluster: ClusterSpec::homogeneous(node.clone(), n),
                mode: SchedMode::Policy("mgb3"),
                workers_per_node: mgb_workers(&node),
                dispatch,
                preempt: None,
                latency: crate::gpu::LatencyModel::off(),
                admit: None,
                frontend_q: "fifo",
                compile_traces: false,
            };
            let r = run_cluster(cfg, jobs.clone());
            lines.push(format!(
                "nodes={n} dispatch={dispatch:<5} jobs={} completed={} crashed={} \
                 makespan={:.1}s throughput={:.4}j/s mean_turnaround={:.1}s",
                r.jobs.len(),
                r.completed(),
                r.crashed(),
                r.makespan,
                r.throughput(),
                r.mean_turnaround()
            ));
        }
    }
    Report {
        title: "Cluster scale-out (beyond-paper): dispatch policy x node count, open-system W5 traffic"
            .into(),
        lines,
    }
}

//! `bench scale` — the fleet-scale event-core sweep (nodes x arrival
//! rate x {preempt, latency} on/off) behind the calendar-queue / slab
//! overhaul. Every row runs twice: once on the indexed calendar queue
//! (the default backend) and once on the reference `BinaryHeap`
//! backend, on the *same* engine build — so the recorded speedup is
//! the queue's contribution in isolation, a lower bound on the full
//! overhaul's gain over the pre-overhaul engine (which also paid
//! per-event `HashMap` lookups and per-dispatch allocations the slab
//! refactor removed for both backends).
//!
//! The sweep writes `BENCH_SCALE.json` at the repo root on every full
//! run; CI re-runs it and `scripts/check_bench_scale.py` gates on
//! (a) calendar >= 0.8x heap within the fresh run and (b) no >20%
//! regression of calibration-normalised events/sec against the
//! committed baseline. Wall-clock is measured by this harness only —
//! the engine itself never reads a host clock, so simulated results
//! stay bit-deterministic per seed.

use std::time::Instant;

use super::json::{float, float_g};
use super::{mgb_workers, Report};
use crate::coordinator::{run_cluster_on_backend, ClusterConfig, JobClass, JobSpec, SchedMode};
use crate::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use crate::sched::PreemptConfig;
use crate::workloads::{poisson_arrivals, synthetic_job, Workload};

/// Per-node Poisson arrival rate shared by every open-system row (the
/// `bench cluster` operating point, so rows differ only in scale and
/// in which engine features are on).
pub const RATE_PER_NODE: f64 = 0.35;

/// One sweep point, before it is run.
#[derive(Clone, Copy, Debug)]
pub struct ScalePoint {
    pub label: &'static str,
    pub nodes: usize,
    /// Synthetic jobs per node (0 = use the W5 mix replicated per
    /// node, the `bench cluster` workload).
    pub synth_jobs_per_node: usize,
    pub preempt: bool,
    pub latency: bool,
    /// Also run the row with `--compile-traces on` (both backends) and
    /// record the compile columns. On by the issue's contract for the
    /// 1000-node rows — the scale regime macro-stepping targets.
    pub compile: bool,
}

/// The committed sweep: small mixed-trace rows, a mid tier toggling
/// preemption and the latency model independently, and the 1000-node
/// open-system rows the overhaul targets (those also measure compiled
/// trace replay).
pub const SWEEP: [ScalePoint; 6] = [
    ScalePoint { label: "w5-4n", nodes: 4, synth_jobs_per_node: 0, preempt: false, latency: false, compile: false },
    ScalePoint { label: "open-32n", nodes: 32, synth_jobs_per_node: 100, preempt: false, latency: false, compile: false },
    ScalePoint { label: "preempt-32n", nodes: 32, synth_jobs_per_node: 100, preempt: true, latency: false, compile: false },
    ScalePoint { label: "latency-32n", nodes: 32, synth_jobs_per_node: 100, preempt: false, latency: true, compile: false },
    ScalePoint { label: "open-1000n", nodes: 1000, synth_jobs_per_node: 100, preempt: false, latency: false, compile: true },
    ScalePoint { label: "full-1000n", nodes: 1000, synth_jobs_per_node: 100, preempt: true, latency: true, compile: true },
];

/// One measured sweep row: simulated-event throughput on both queue
/// backends plus the run's event-queue pressure columns. The
/// `compile_*` columns are `None` on rows that did not run the
/// compiled-replay pass ([`ScalePoint::compile`] false) and serialise
/// as JSON `null`, keeping the `mgb-bench-scale-v1` schema row- and
/// column-additive over committed baselines.
#[derive(Clone, Debug)]
pub struct ScaleRow {
    pub label: String,
    pub nodes: usize,
    pub jobs: usize,
    pub rate_per_node: f64,
    pub preempt: bool,
    pub latency: bool,
    /// Discrete events the run fired (identical across backends by the
    /// determinism contract — asserted on every row).
    pub events: u64,
    /// Event-queue high-water mark (the peak-heap-size column).
    pub peak_events: usize,
    /// Fired events on the observable subset (`EvKind::is_observable`)
    /// — invariant under `--compile-traces`, asserted per row.
    pub observable_events: u64,
    /// events/sec on the reference `BinaryHeap` backend.
    pub baseline_events_per_s: f64,
    /// events/sec on the calendar-queue backend.
    pub events_per_s: f64,
    /// Total events the `--compile-traces on` run fired (calendar
    /// backend; cross-backend-asserted). Usually below `events` — macro
    /// segments collapse timer events and never add observable ones —
    /// but an interrupted segment costs one stale `MacroSegment`
    /// firing, so no strict inequality holds.
    pub compile_events: Option<u64>,
    /// *Effective* events/sec of the compile-on run on the calendar
    /// backend: the compile-OFF event count divided by the compile-on
    /// wall time. Keeping the numerator fixed makes the column a
    /// same-workload wall-clock measure — the raw fired count shrinks
    /// under macro-stepping, which would make a naive events/sec
    /// *drop* exactly when compilation works best.
    pub compile_events_per_s: Option<f64>,
    /// Effective events/sec of the compile-on run on the heap backend.
    pub compile_baseline_events_per_s: Option<f64>,
}

impl ScaleRow {
    pub fn speedup_vs_baseline(&self) -> f64 {
        if self.baseline_events_per_s <= 0.0 {
            0.0
        } else {
            self.events_per_s / self.baseline_events_per_s
        }
    }

    /// Same-backend, same-workload compile-on / compile-off throughput
    /// ratio (calendar): >= 1.0 means macro-stepping paid for itself.
    /// The CI gate (`scripts/check_bench_scale.py`) holds this at 1.0
    /// on the rows that record it.
    pub fn compile_ratio(&self) -> Option<f64> {
        let c = self.compile_events_per_s?;
        if self.events_per_s <= 0.0 {
            Some(0.0)
        } else {
            Some(c / self.events_per_s)
        }
    }
}

/// Deterministic synthetic open-system traffic: `per_node` single-task
/// jobs per node with a fixed small spread of footprints and kernel
/// lengths, stamped with Poisson arrivals at [`RATE_PER_NODE`] per
/// node. Synthetic traces keep per-job event counts flat, so the big
/// rows measure the event core rather than trace generation.
fn synth_open_jobs(nodes: usize, per_node: usize, seed: u64) -> Vec<JobSpec> {
    const GB: u64 = 1 << 30;
    let n = nodes * per_node;
    let mut jobs = Vec::with_capacity(n);
    for i in 0..n {
        // Footprints cycle 1/2/4/6 GB (6 GB rows are Large-class), and
        // kernel lengths sweep 50-450 ms on a coprime stride so
        // adjacent arrivals differ.
        let mem = [GB, 2 * GB, 4 * GB, 6 * GB][i % 4];
        let work_us = 50_000 + ((i * 37) % 400) as u64 * 1_000;
        let class = if mem > 4 * GB { JobClass::Large } else { JobClass::Small };
        jobs.push(synthetic_job(&format!("s{i:06}"), class, mem, work_us, 0.0));
    }
    poisson_arrivals(&mut jobs, RATE_PER_NODE * nodes as f64, seed);
    jobs
}

/// Build the job stream for one sweep point.
fn point_jobs(p: &ScalePoint, seed: u64) -> Vec<JobSpec> {
    if p.synth_jobs_per_node == 0 {
        // The `bench cluster` workload: one W5 mix per node, distinct
        // seeds, Poisson arrivals at the shared per-node rate.
        let w5 = Workload::by_id("W5").expect("W5 exists");
        let mut jobs = Vec::new();
        for k in 0..p.nodes as u64 {
            jobs.extend(w5.jobs(seed.wrapping_add(k)));
        }
        poisson_arrivals(&mut jobs, RATE_PER_NODE * p.nodes as f64, seed);
        jobs
    } else {
        synth_open_jobs(p.nodes, p.synth_jobs_per_node, seed)
    }
}

fn point_config(p: &ScalePoint, node: &NodeSpec, compile: bool) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(node.clone(), p.nodes),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: mgb_workers(node),
        dispatch: "rr",
        preempt: p.preempt.then(PreemptConfig::default),
        latency: if p.latency { LatencyModel::lan() } else { LatencyModel::off() },
        admit: None,
        frontend_q: "fifo",
        compile_traces: compile,
    }
}

/// Run one sweep point on both backends and cross-check determinism:
/// the calendar queue must fire exactly the events the heap fires, in
/// an order that produces identical outcomes. Points with
/// [`ScalePoint::compile`] set run a second `--compile-traces on`
/// pair and additionally cross-check the compiled-replay contract:
/// identical outcomes, bit-identical makespan, and an unchanged
/// observable event stream count.
pub fn run_point(p: &ScalePoint, seed: u64) -> ScaleRow {
    let node = NodeSpec::v100x4();
    let jobs = point_jobs(p, seed);
    let n_jobs = jobs.len();

    let t0 = Instant::now();
    let heap = run_cluster_on_backend(point_config(p, &node, false), jobs.clone(), "heap");
    let heap_s = t0.elapsed().as_secs_f64().max(1e-9);

    let t1 = Instant::now();
    let cal = run_cluster_on_backend(point_config(p, &node, false), jobs.clone(), "calendar");
    let cal_s = t1.elapsed().as_secs_f64().max(1e-9);

    // Determinism contract: the backends are interchangeable down to
    // the event stream. A mismatch is an ordering bug, not a perf
    // result — fail loudly rather than record garbage.
    assert_eq!(cal.events_fired, heap.events_fired, "{}: events diverged", p.label);
    assert_eq!(cal.peak_events, heap.peak_events, "{}: peak diverged", p.label);
    assert_eq!(cal.completed(), heap.completed(), "{}: outcomes diverged", p.label);
    assert!(
        (cal.makespan - heap.makespan).abs() < 1e-12,
        "{}: makespan diverged ({} vs {})",
        p.label,
        cal.makespan,
        heap.makespan
    );

    let (mut compile_events, mut compile_eps, mut compile_base_eps) = (None, None, None);
    if p.compile {
        let t2 = Instant::now();
        let cheap = run_cluster_on_backend(point_config(p, &node, true), jobs.clone(), "heap");
        let cheap_s = t2.elapsed().as_secs_f64().max(1e-9);

        let t3 = Instant::now();
        let ccal = run_cluster_on_backend(point_config(p, &node, true), jobs, "calendar");
        let ccal_s = t3.elapsed().as_secs_f64().max(1e-9);

        // Backend determinism holds under macro-stepping too.
        assert_eq!(ccal.events_fired, cheap.events_fired, "{}: compile events diverged", p.label);
        assert_eq!(ccal.completed(), cheap.completed(), "{}: compile outcomes diverged", p.label);
        // Compiled-replay contract vs the compile-off run: identical
        // outcomes, bit-identical virtual time, identical observable
        // stream. (Total fired events usually shrink — macro segments
        // collapse timer events — but an interrupted segment costs one
        // stale MacroSegment firing, so no inequality is asserted.)
        assert_eq!(ccal.completed(), cal.completed(), "{}: compile changed outcomes", p.label);
        assert!(
            ccal.makespan == cal.makespan,
            "{}: compile changed makespan ({} vs {})",
            p.label,
            ccal.makespan,
            cal.makespan
        );
        assert_eq!(
            ccal.observable_events, cal.observable_events,
            "{}: compile changed the observable event stream",
            p.label
        );

        compile_events = Some(ccal.events_fired);
        // Effective throughput: the compile-OFF event count over the
        // compile-on wall time (same simulated workload per second).
        compile_eps = Some(cal.events_fired as f64 / ccal_s);
        compile_base_eps = Some(heap.events_fired as f64 / cheap_s);
    }

    ScaleRow {
        label: p.label.to_string(),
        nodes: p.nodes,
        jobs: n_jobs,
        rate_per_node: RATE_PER_NODE,
        preempt: p.preempt,
        latency: p.latency,
        events: cal.events_fired,
        peak_events: cal.peak_events,
        observable_events: cal.observable_events,
        baseline_events_per_s: heap.events_fired as f64 / heap_s,
        events_per_s: cal.events_fired as f64 / cal_s,
        compile_events,
        compile_events_per_s: compile_eps,
        compile_baseline_events_per_s: compile_base_eps,
    }
}

/// The tiny fixed point `bench_smoke` and `scheduler_micro` exercise:
/// 2 nodes, 64 synthetic jobs, both features off. Fast enough for a
/// test, still multi-node and open-system. Compile is on so the smoke
/// path also exercises `run_point`'s compiled-replay cross-checks.
pub fn scale_smoke_point(seed: u64) -> ScaleRow {
    let p = ScalePoint {
        label: "smoke-2n",
        nodes: 2,
        synth_jobs_per_node: 32,
        preempt: false,
        latency: false,
        compile: true,
    };
    run_point(&p, seed)
}

/// Machine-speed calibration: events/sec of a fixed small row on the
/// *heap* backend. Committed-baseline comparisons divide each row's
/// events/sec by this, so the 20% regression gate compares code, not
/// host CPUs (see scripts/check_bench_scale.py).
pub fn calibration_events_per_s(seed: u64) -> f64 {
    let p = ScalePoint {
        label: "calibration",
        nodes: 4,
        synth_jobs_per_node: 64,
        preempt: false,
        latency: false,
        compile: false,
    };
    let node = NodeSpec::v100x4();
    let jobs = point_jobs(&p, seed);
    let t0 = Instant::now();
    let r = run_cluster_on_backend(point_config(&p, &node, false), jobs, "heap");
    r.events_fired as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Render the machine-readable `BENCH_SCALE.json` document (hand-
/// rolled like the rest of the crate's JSON — the offline crate set
/// has no serde; floats go through the guarded `json` formatter so a
/// poisoned metric lands as `null`, not a NaN token).
pub fn bench_scale_json(provenance: &str, seed: u64, calib: f64, rows: &[ScaleRow]) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"mgb-bench-scale-v1\",\n");
    s.push_str(&format!("  \"provenance\": \"{provenance}\",\n"));
    s.push_str(&format!("  \"seed\": {seed},\n"));
    s.push_str(&format!("  \"calibration_events_per_s\": {},\n", float(calib, 1)));
    s.push_str("  \"rows\": [\n");
    // Option columns serialise as `null` so rows that skipped the
    // compile pass keep every key (column-additive schema: readers
    // index by name, committed v1 baselines simply lack the keys).
    let opt_u64 = |v: Option<u64>| v.map_or("null".to_string(), |v| v.to_string());
    let opt_float = |v: Option<f64>, p: usize| v.map_or("null".to_string(), |v| float(v, p));
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"label\": \"{}\", \"nodes\": {}, \"jobs\": {}, \"rate_per_node\": {}, \
             \"preempt\": {}, \"latency\": {}, \"events\": {}, \"peak_events\": {}, \
             \"observable_events\": {}, \
             \"baseline_events_per_s\": {}, \"events_per_s\": {}, \
             \"speedup_vs_baseline\": {}, \
             \"compile_events\": {}, \"compile_events_per_s\": {}, \
             \"compile_baseline_events_per_s\": {}, \"compile_ratio\": {}}}{}\n",
            r.label,
            r.nodes,
            r.jobs,
            float_g(r.rate_per_node),
            r.preempt,
            r.latency,
            r.events,
            r.peak_events,
            r.observable_events,
            float(r.baseline_events_per_s, 1),
            float(r.events_per_s, 1),
            float(r.speedup_vs_baseline(), 3),
            opt_u64(r.compile_events),
            opt_float(r.compile_events_per_s, 1),
            opt_float(r.compile_baseline_events_per_s, 1),
            opt_float(r.compile_ratio(), 3),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Full sweep: run every committed point on both backends, write
/// `BENCH_SCALE.json` at the repo root, and return the human-readable
/// report. This is the `bench --exp scale` / `cargo bench` entry; it
/// is deliberately *not* part of `run_all` (the 1000-node rows take
/// minutes, not seconds).
pub fn scale(seed: u64) -> Report {
    let calib = calibration_events_per_s(seed);
    let mut rows = Vec::with_capacity(SWEEP.len());
    let mut lines = vec![format!("calibration_events_per_s={calib:.0} (heap backend, 4n x 256 jobs)")];
    for p in &SWEEP {
        let r = run_point(p, seed);
        let compile_col = match (r.compile_events, r.compile_ratio()) {
            (Some(ev), Some(ratio)) => {
                format!(" compile_events={ev} compile_ratio={ratio:.2}x")
            }
            _ => String::new(),
        };
        lines.push(format!(
            "{:<12} nodes={:<5} jobs={:<6} preempt={:<5} latency={:<5} events={:<9} \
             peak_events={:<7} heap={:.0}ev/s calendar={:.0}ev/s speedup={:.2}x{}",
            r.label,
            r.nodes,
            r.jobs,
            r.preempt,
            r.latency,
            r.events,
            r.peak_events,
            r.baseline_events_per_s,
            r.events_per_s,
            r.speedup_vs_baseline(),
            compile_col
        ));
        rows.push(r);
    }
    let json = bench_scale_json("measured", seed, calib, &rows);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_SCALE.json");
    match std::fs::write(&path, &json) {
        Ok(()) => lines.push(format!("wrote {}", path.display())),
        Err(e) => lines.push(format!("WARN: could not write {}: {e}", path.display())),
    }
    Report {
        title: "Fleet-scale event-core sweep (calendar queue vs BinaryHeap reference)".into(),
        lines,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_point_is_deterministic_and_backend_consistent() {
        // run_point itself asserts the cross-backend determinism
        // contract AND (the smoke point has `compile: true`) the
        // compiled-replay invariants; here we additionally pin the
        // simulated columns across repeated runs (wall-clock columns
        // may differ).
        let a = scale_smoke_point(7);
        let b = scale_smoke_point(7);
        assert_eq!(a.events, b.events);
        assert_eq!(a.peak_events, b.peak_events);
        assert_eq!(a.observable_events, b.observable_events);
        assert_eq!(a.compile_events, b.compile_events);
        assert_eq!(a.jobs, 64);
        assert_eq!(a.nodes, 2);
        assert!(a.events > 0 && a.peak_events > 0);
        assert!(a.observable_events > 0 && a.observable_events < a.events);
        assert!(a.events_per_s > 0.0 && a.baseline_events_per_s > 0.0);
        // The compile pass ran and recorded its columns.
        assert!(a.compile_events.is_some());
        assert!(a.compile_events.unwrap() > 0);
        assert!(a.compile_events_per_s.unwrap() > 0.0);
        assert!(a.compile_baseline_events_per_s.unwrap() > 0.0);
        assert!(a.compile_ratio().unwrap() > 0.0);
    }

    #[test]
    fn json_document_is_well_formed_enough_to_gate_on() {
        let row = ScaleRow {
            label: "x".into(),
            nodes: 2,
            jobs: 64,
            rate_per_node: 0.35,
            preempt: false,
            latency: true,
            events: 1234,
            peak_events: 99,
            observable_events: 300,
            baseline_events_per_s: 1000.0,
            events_per_s: 12000.0,
            compile_events: Some(900),
            compile_events_per_s: Some(24000.0),
            compile_baseline_events_per_s: Some(2000.0),
        };
        let no_compile = ScaleRow {
            label: "y".into(),
            compile_events: None,
            compile_events_per_s: None,
            compile_baseline_events_per_s: None,
            ..row.clone()
        };
        let s = bench_scale_json("measured", 7, 5e5, &[row, no_compile]);
        assert!(s.contains("\"schema\": \"mgb-bench-scale-v1\""));
        assert!(s.contains("\"provenance\": \"measured\""));
        assert!(s.contains("\"speedup_vs_baseline\": 12.000"));
        assert!(s.contains("\"latency\": true"));
        assert!(s.contains("\"observable_events\": 300"));
        assert!(s.contains("\"compile_events\": 900"));
        assert!(s.contains("\"compile_ratio\": 2.000"));
        // Rows without a compile pass serialise the columns as null so
        // every row carries every key.
        assert!(s.contains("\"compile_events\": null"));
        assert!(s.contains("\"compile_ratio\": null"));
        // Balanced braces/brackets — the cheap structural check the
        // hand-rolled emitter warrants.
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }
}

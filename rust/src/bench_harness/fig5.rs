//! Fig. 5: throughput of SA / CG / MGB on W1–W8, both nodes, normalised
//! to SA. Paper: MGB 1.8–2.5× (avg 2.2×) on P100s, 1.4–2.5× (avg 2×) on
//! V100s; MGB beats CG by 64% / 41% on average.

use super::{best_cg, mgb_workers, run, Report};
use crate::coordinator::SchedMode;
use crate::gpu::NodeSpec;
use crate::workloads::WORKLOADS;

pub fn fig5(seed: u64) -> Report {
    let mut lines = Vec::new();
    for node in [NodeSpec::p100x2(), NodeSpec::v100x4()] {
        lines.push(format!("--- {} ---", node.name));
        lines.push(format!(
            "{:<4} {:>10} {:>14} {:>10} {:>9} {:>9}",
            "W", "SA (j/s)", "CG(best w)", "MGB", "CG/SA", "MGB/SA"
        ));
        let workers = mgb_workers(&node);
        let (mut mgb_sum, mut cg_sum) = (0.0, 0.0);
        for w in WORKLOADS {
            let jobs = w.jobs(seed);
            let sa = run(&node, SchedMode::Sa, 0, jobs.clone());
            let (cg_w, cg) = best_cg(&node, &jobs);
            let mgb = run(&node, SchedMode::Policy("mgb3"), workers, jobs);
            let cg_n = cg.throughput() / sa.throughput();
            let mgb_n = mgb.throughput() / sa.throughput();
            cg_sum += cg_n;
            mgb_sum += mgb_n;
            lines.push(format!(
                "{:<4} {:>10.4} {:>9.4}(w{:<2}) {:>10.4} {:>8.2}x {:>8.2}x",
                w.id,
                sa.throughput(),
                cg.throughput(),
                cg_w,
                mgb.throughput(),
                cg_n,
                mgb_n,
            ));
        }
        let n = WORKLOADS.len() as f64;
        lines.push(format!(
            "avg: CG/SA {:.2}x, MGB/SA {:.2}x, MGB/CG {:.2}x   (paper {}: MGB/SA {}, MGB/CG {})",
            cg_sum / n,
            mgb_sum / n,
            (mgb_sum / n) / (cg_sum / n),
            node.name,
            if node.n_gpus() == 2 { "2.2x" } else { "2.0x" },
            if node.n_gpus() == 2 { "1.64x" } else { "1.41x" },
        ));
    }
    Report { title: "Fig. 5 — SA / CG / MGB throughput (normalised to SA)".into(), lines }
}

//! Beyond-paper: cross-node checkpoint migration under SLO-aware
//! victim selection (ROADMAP "cross-node victim migration",
//! "SLO-aware victim selection"). PR 2's preemption could only restore
//! a victim *on the same node* — on a loaded cluster the evicted job
//! re-queues behind the very contention that evicted it. Here the same
//! deterministic scenario is run with same-node-only restore
//! (`migrate: "off"`) against cluster-wide restore (`"cluster"`) at
//! each swept probe RTT, so the report shows both the win (the victim
//! escapes its contended home node) and the price (probe RTT +
//! dispatch cost + the 12 GiB image transfer over the migration link).
//!
//! The scenario is hand-computable under round-robin dispatch: node 0
//! hosts a 12 GB best-effort hog (120 s), node 1 only a 1 GB batch
//! filler (1 s); a latency-sensitive 12 GB heavy (100 s) arrives at
//! t = 5, lands on node 0 by cursor order, blocks, and evicts the hog.
//! Restored same-node the hog waits out the heavy's entire residency;
//! restored cluster-wide the rr cursor routes it to node 1, where it
//! re-places as soon as the image lands. A final contrast row swaps
//! the classes to show the SLO lattice refusing the eviction outright:
//! a best-effort arrival never displaces latency-sensitive work.

use super::{sweep_model, Report};
use crate::coordinator::{run_cluster, ClusterConfig, JobClass, JobSpec, RunResult, SchedMode};
use crate::gpu::{ClusterSpec, GpuSpec, LatencyModel, NodeSpec};
use crate::sched::{PreemptConfig, SloClass};
use crate::workloads::synthetic_job;

/// Swept probe RTTs, seconds (0 = free frontend; each row prices the
/// frontend with the same [`sweep_model`] `bench latency` uses, so the
/// two experiments stay comparable row-for-row).
pub const MIGRATE_RTT_SWEEP: [f64; 3] = [0.0, 0.05, 0.5];

fn slo_job(
    name: &str,
    class: JobClass,
    slo: SloClass,
    mem_bytes: u64,
    work_us: u64,
    arrival: f64,
) -> JobSpec {
    let mut j = synthetic_job(name, class, mem_bytes, work_us, arrival);
    j.slo = Some(slo);
    j
}

/// The migration stream (see the module docs for the exact dance).
fn stream() -> Vec<JobSpec> {
    vec![
        slo_job("hog", JobClass::Small, SloClass::BestEffort, 12 << 30, 120_000_000, 0.0),
        slo_job("filler", JobClass::Small, SloClass::Batch, 1 << 30, 1_000_000, 0.0),
        slo_job("heavy", JobClass::Large, SloClass::LatencySensitive, 12 << 30, 100_000_000, 5.0),
    ]
}

/// The class-swapped contrast stream: the hog is latency-sensitive,
/// the late heavy best-effort — the SLO lattice must refuse to evict.
fn protected_stream() -> Vec<JobSpec> {
    vec![
        slo_job("hog", JobClass::Small, SloClass::LatencySensitive, 12 << 30, 120_000_000, 0.0),
        slo_job("filler", JobClass::Small, SloClass::Batch, 1 << 30, 1_000_000, 0.0),
        slo_job("heavy", JobClass::Large, SloClass::BestEffort, 12 << 30, 100_000_000, 5.0),
    ]
}

fn cfg(migrate: &'static str, latency: LatencyModel) -> ClusterConfig {
    let node = NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(node, 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "rr",
        preempt: Some(PreemptConfig { policy: "slo", migrate, ..Default::default() }),
        latency,
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

/// Same-node-only vs cluster-wide restore on the same stream at each
/// swept RTT: `(rtt, [(restore label, result)])`. Exposed so the smoke
/// test can assert the acceptance bound — cluster-wide restore never
/// worsens mean turnaround vs same-node-only at zero RTT — and export
/// the rows as a JSON CI artifact.
pub fn migrate_comparison(_seed: u64) -> Vec<(f64, Vec<(&'static str, RunResult)>)> {
    MIGRATE_RTT_SWEEP
        .iter()
        .map(|&rtt| {
            (
                rtt,
                vec![
                    ("same-node", run_cluster(cfg("off", sweep_model(rtt)), stream())),
                    ("cluster", run_cluster(cfg("cluster", sweep_model(rtt)), stream())),
                ],
            )
        })
        .collect()
}

pub fn migrate(seed: u64) -> Report {
    let mut lines = Vec::new();
    for (rtt, rows) in migrate_comparison(seed) {
        for (label, r) in rows {
            let att = |c: SloClass| {
                r.slo_attainment(c).map_or_else(|| "n/a".into(), |a| format!("{:.0}%", 100.0 * a))
            };
            lines.push(format!(
                "probe_rtt={rtt:<5}s restore={label:<9} mean_turnaround={:.1}s \
                 heavy_turnaround={:.1}s hog_turnaround={:.1}s migrations={} \
                 migrate_bytes={:.1}GiB slo_ls={} slo_be={}",
                r.mean_turnaround(),
                r.mean_turnaround_of(JobClass::Large),
                r.mean_turnaround_of_slo(SloClass::BestEffort),
                r.migrations,
                r.migrate_bytes as f64 / (1u64 << 30) as f64,
                att(SloClass::LatencySensitive),
                att(SloClass::BestEffort),
            ));
        }
    }
    // The lattice contrast: with the classes swapped the best-effort
    // arrival may not evict the latency-sensitive hog at all — it
    // waits, whatever the migration mode.
    let r = run_cluster(cfg("cluster", sweep_model(0.0)), protected_stream());
    lines.push(format!(
        "slo-protected  restore=cluster   preemptions={} migrations={} \
         heavy_turnaround={:.1}s (best-effort arrival waits out the tighter hog)",
        r.preemptions,
        r.migrations,
        r.mean_turnaround_of(JobClass::Large),
    ));
    Report {
        title: "Migration (beyond-paper): same-node vs cluster-wide checkpoint restore, \
                SLO-aware victims"
            .into(),
        lines,
    }
}

//! Text parser for the IR — the inverse of `Program`'s `Display`.
//!
//! Accepts exactly the fully-parenthesised form the pretty-printer
//! emits, so `parse(program.to_string())` round-trips (property-tested
//! in `compiler::tests`). Used by the CLI (`mgb compile <file.gir>`) and
//! by tests that keep fixture programs as text.

use super::op::{CopyDir, Expr, Op, OpId, OpKind, Terminator, ValueId};
use super::program::{Block, FuncId, Function, Program};
use anyhow::{anyhow, bail, Context, Result};

pub fn parse_program(text: &str) -> Result<Program> {
    let mut funcs: Vec<Function> = Vec::new();
    let mut entry: Option<FuncId> = None;
    // First pass: collect function names so calls can resolve forward.
    let mut names: Vec<String> = Vec::new();
    for line in text.lines() {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix("func ") {
            let name = rest.split('(').next().unwrap_or("").trim().to_string();
            names.push(name);
        }
    }

    let mut lines = text.lines().peekable();
    while let Some(line) = lines.next() {
        let t = line.trim();
        if t.is_empty() || t.starts_with("//") {
            continue;
        }
        let Some(rest) = t.strip_prefix("func ") else {
            bail!("expected `func`, got: {t}");
        };
        let name = rest.split('(').next().unwrap().trim().to_string();
        let n_params: u32 = rest
            .split('(')
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .context("func header params")?
            .parse()
            .context("param count")?;
        if rest.contains("[entry]") {
            entry = Some(funcs.len() as FuncId);
        }
        let mut blocks: Vec<Block> = Vec::new();
        let mut cur: Option<Block> = None;
        let mut next_op: OpId = 0;
        let mut max_value: ValueId = n_params.saturating_sub(1);
        loop {
            let Some(line) = lines.next() else {
                bail!("unexpected EOF in func {name}")
            };
            let t = line.trim();
            if t == "}" {
                if let Some(b) = cur.take() {
                    blocks.push(b);
                }
                break;
            }
            if t.is_empty() || t.starts_with("//") {
                continue;
            }
            if t.starts_with('b') && t.ends_with(':') {
                if let Some(b) = cur.take() {
                    blocks.push(b);
                }
                cur = Some(Block { ops: Vec::new(), term: Terminator::Ret });
                continue;
            }
            let blk = cur.as_mut().context("op before first block label")?;
            if let Some(term) = parse_terminator(t)? {
                blk.term = term;
                continue;
            }
            let (op, vmax) = parse_op(t, next_op, &names)?;
            next_op += 1;
            max_value = max_value.max(vmax);
            blk.ops.push(op);
        }
        funcs.push(Function {
            name,
            n_params,
            n_values: max_value + 1,
            blocks,
        });
    }
    let entry = entry
        .or_else(|| {
            funcs
                .iter()
                .position(|f| f.name == "main")
                .map(|i| i as FuncId)
        })
        .context("no [entry] function and no `main`")?;
    let p = Program { funcs, entry };
    p.validate().map_err(|e| anyhow!("invalid program: {e}"))?;
    Ok(p)
}

fn parse_terminator(t: &str) -> Result<Option<Terminator>> {
    if t == "ret" {
        return Ok(Some(Terminator::Ret));
    }
    if let Some(rest) = t.strip_prefix("br ") {
        return Ok(Some(Terminator::Br(parse_block_ref(rest.trim())?)));
    }
    if let Some(rest) = t.strip_prefix("loop ") {
        let parts: Vec<&str> = rest.split_whitespace().collect();
        if parts.len() != 3 {
            bail!("loop needs `loop vN bT bF`: {t}");
        }
        return Ok(Some(Terminator::CondBr {
            trips: parse_value_ref(parts[0])?,
            taken: parse_block_ref(parts[1])?,
            fallthrough: parse_block_ref(parts[2])?,
        }));
    }
    Ok(None)
}

fn parse_op(t: &str, id: OpId, names: &[String]) -> Result<(Op, ValueId)> {
    let mut result = None;
    let mut body = t;
    if let Some(eq) = t.find(" = ") {
        result = Some(parse_value_ref(&t[..eq])?);
        body = &t[eq + 3..];
    }
    let mut max_v = result.unwrap_or(0);
    let mut track = |v: ValueId| {
        max_v = max_v.max(v);
        v
    };
    let kind = if let Some(rest) = body.strip_prefix("assign ") {
        let expr = ExprParser::new(rest.trim()).parse()?;
        let mut refs = Vec::new();
        expr.referenced_values(&mut refs);
        for r in refs {
            track(r);
        }
        OpKind::Assign { expr }
    } else if let Some(rest) = body.strip_prefix("malloc ") {
        OpKind::Malloc { bytes: track(parse_value_ref(rest.trim())?) }
    } else if let Some(rest) = body.strip_prefix("h2d ") {
        let (a, b) = two_values(rest)?;
        OpKind::Memcpy { obj: track(a), bytes: track(b), dir: CopyDir::HostToDevice }
    } else if let Some(rest) = body.strip_prefix("d2h ") {
        let (a, b) = two_values(rest)?;
        OpKind::Memcpy { obj: track(a), bytes: track(b), dir: CopyDir::DeviceToHost }
    } else if let Some(rest) = body.strip_prefix("memset ") {
        let (a, b) = two_values(rest)?;
        OpKind::Memset { obj: track(a), bytes: track(b) }
    } else if let Some(rest) = body.strip_prefix("free ") {
        OpKind::Free { obj: track(parse_value_ref(rest.trim())?) }
    } else if let Some(rest) = body.strip_prefix("set_heap_limit ") {
        OpKind::DeviceSetLimit { bytes: track(parse_value_ref(rest.trim())?) }
    } else if let Some(rest) = body.strip_prefix("set_device ") {
        OpKind::SetDevice { dev: track(parse_value_ref(rest.trim())?) }
    } else if let Some(rest) = body.strip_prefix("host_compute ") {
        OpKind::HostCompute { micros: track(parse_value_ref(rest.trim())?) }
    } else if let Some(rest) = body.strip_prefix("call ") {
        let (fname, args_s) = rest.split_once('[').context("call args")?;
        let callee = names
            .iter()
            .position(|n| n == fname.trim())
            .with_context(|| format!("unknown function {fname}"))? as FuncId;
        let args = parse_value_list(args_s.trim_end_matches(']'))?;
        for &a in &args {
            track(a);
        }
        OpKind::Call { callee, args }
    } else if let Some(rest) = body.strip_prefix("launch ") {
        let mut kernel = String::new();
        let (mut grid, mut block, mut work) = (None, None, None);
        let mut args = Vec::new();
        for (i, tok) in rest.split_whitespace().enumerate() {
            if i == 0 {
                kernel = tok.to_string();
            } else if let Some(v) = tok.strip_prefix("grid=") {
                grid = Some(parse_value_ref(v)?);
            } else if let Some(v) = tok.strip_prefix("block=") {
                block = Some(parse_value_ref(v)?);
            } else if let Some(v) = tok.strip_prefix("work=") {
                work = Some(parse_value_ref(v)?);
            } else if let Some(v) = tok.strip_prefix("args=[") {
                args = parse_value_list(v.trim_end_matches(']'))?;
            }
        }
        for &a in &args {
            track(a);
        }
        OpKind::Launch {
            kernel,
            grid: track(grid.context("launch grid")?),
            block: track(block.context("launch block")?),
            args,
            work: track(work.context("launch work")?),
            artifact: None,
        }
    } else {
        bail!("unknown op: {t}");
    };
    Ok((Op { id, result, kind }, max_v))
}

fn two_values(rest: &str) -> Result<(ValueId, ValueId)> {
    let parts: Vec<&str> = rest.split_whitespace().collect();
    if parts.len() != 2 {
        bail!("expected two values: {rest}");
    }
    Ok((parse_value_ref(parts[0])?, parse_value_ref(parts[1])?))
}

fn parse_value_list(s: &str) -> Result<Vec<ValueId>> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    s.split(',').map(|p| parse_value_ref(p.trim())).collect()
}

fn parse_value_ref(s: &str) -> Result<ValueId> {
    s.trim()
        .strip_prefix('v')
        .with_context(|| format!("expected vN, got {s}"))?
        .parse()
        .with_context(|| format!("bad value ref {s}"))
}

fn parse_block_ref(s: &str) -> Result<super::program::BlockId> {
    s.trim()
        .strip_prefix('b')
        .with_context(|| format!("expected bN, got {s}"))?
        .parse()
        .with_context(|| format!("bad block ref {s}"))
}

/// Recursive-descent parser for the fully-parenthesised Expr form.
struct ExprParser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> ExprParser<'a> {
    fn new(s: &'a str) -> Self {
        Self { s: s.as_bytes(), pos: 0 }
    }

    fn parse(&mut self) -> Result<Expr> {
        let e = self.expr()?;
        self.skip_ws();
        if self.pos != self.s.len() {
            bail!("trailing input in expr at {}", self.pos);
        }
        Ok(e)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn expr(&mut self) -> Result<Expr> {
        self.skip_ws();
        match self.peek() {
            Some(b'(') => {
                self.pos += 1;
                let lhs = self.expr()?;
                self.skip_ws();
                let op = self.next().context("binop")?;
                let rhs = self.expr()?;
                self.skip_ws();
                self.expect(b')')?;
                Ok(match op {
                    b'+' => lhs.add(rhs),
                    b'-' => lhs.sub(rhs),
                    b'*' => lhs.mul(rhs),
                    o => bail!("unknown binop '{}'", o as char),
                })
            }
            Some(b'c') if self.starts_with("ceil(") => {
                self.pos += 5;
                let a = self.expr()?;
                self.skip_ws();
                self.expect(b'/')?;
                let b = self.expr()?;
                self.skip_ws();
                self.expect(b')')?;
                Ok(a.ceil_div(b))
            }
            Some(b'm') if self.starts_with("max(") || self.starts_with("min(") => {
                let is_max = self.starts_with("max(");
                self.pos += 4;
                let a = self.expr()?;
                self.skip_ws();
                self.expect(b',')?;
                let b = self.expr()?;
                self.skip_ws();
                self.expect(b')')?;
                Ok(if is_max { a.max(b) } else { a.min(b) })
            }
            Some(b'v') => {
                self.pos += 1;
                Ok(Expr::v(self.number()? as ValueId))
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Expr::c(self.number()?)),
            other => bail!("unexpected expr start: {other:?}"),
        }
    }

    fn starts_with(&self, p: &str) -> bool {
        self.s[self.pos..].starts_with(p.as_bytes())
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        self.skip_ws();
        if self.next() != Some(c) {
            bail!("expected '{}' at {}", c as char, self.pos);
        }
        Ok(())
    }

    fn number(&mut self) -> Result<i64> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.s[start..self.pos])?
            .parse()
            .context("number")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expr_roundtrip() {
        let e = Expr::v(3).mul(Expr::c(4)).add(Expr::c(7).ceil_div(Expr::v(1)));
        let s = e.to_string();
        let p = ExprParser::new(&s).parse().unwrap();
        assert_eq!(p.to_string(), s);
    }

    #[test]
    fn parse_simple_program() {
        let text = "\
func main(1 params) [entry] {
b0:
  v1 = assign (v0 * 4)
  v2 = malloc v1
  h2d v2 v1
  v3 = assign ceil(v0 / 128)
  v4 = assign 256
  v5 = assign 1000
  launch vadd grid=v3 block=v4 args=[v2] work=v5
  d2h v2 v1
  free v2
  ret
}
";
        let p = parse_program(text).unwrap();
        assert_eq!(p.main().n_ops(), 9);
        // Round-trip through Display.
        let p2 = parse_program(&p.to_string()).unwrap();
        assert_eq!(p.to_string(), p2.to_string());
    }

    #[test]
    fn duplicate_definition_rejected_by_name() {
        let text = "\
func main(1 params) [entry] {
b0:
  v1 = assign 64
  v1 = assign 128
  ret
}
";
        let err = parse_program(text).unwrap_err().to_string();
        assert!(err.contains("duplicate definition of v1"), "{err}");
        assert!(err.contains("main"), "{err}");
    }

    #[test]
    fn use_of_never_defined_value_rejected_by_name() {
        // v9 is mentioned only as an operand, so the old max-value range
        // check accepted it; validate()'s definedness pass must not.
        let text = "\
func main(1 params) [entry] {
b0:
  v1 = assign 64
  v2 = malloc v9
  free v2
  ret
}
";
        let err = parse_program(text).unwrap_err().to_string();
        assert!(err.contains("uses v9, which no op defines"), "{err}");
    }

    #[test]
    fn loop_on_never_defined_trip_count_rejected() {
        let text = "\
func main(1 params) [entry] {
b0:
  v1 = assign 3
  br b1
b1:
  v2 = assign 10
  loop v7 b1 b2
b2:
  ret
}
";
        let err = parse_program(text).unwrap_err().to_string();
        assert!(err.contains("loop terminator uses v7"), "{err}");
    }
}

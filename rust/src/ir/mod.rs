//! Mini-CUDA host IR — the substrate the paper's compiler pass analyses.
//!
//! The paper's pass works on LLVM IR of the *host-side* code of CUDA
//! applications: kernel-launch configuration calls, `cudaMalloc`/
//! `cudaMemcpy`/`cudaFree`, and the scalar dataflow that feeds their
//! arguments. This module reproduces exactly that slice of LLVM IR:
//! SSA-ish scalar values with symbolic expressions, GPU API operations
//! over memory-object values, functions with basic blocks and branches,
//! and host compute phases (everything between GPU calls that costs
//! time). Workloads (`crate::workloads`) are authored against
//! [`build::ProgramBuilder`], tests and the CLI can also parse the
//! textual form (`parse`).

pub mod build;
pub mod op;
pub mod parse;
pub mod program;

pub use build::{FuncBuilder, ProgramBuilder};
pub use op::{CopyDir, EvalError, Expr, Op, OpId, OpKind, Terminator, ValueId};
pub use program::{op_operands, Block, BlockId, FuncId, Function, Program};

//! Functions, basic blocks and whole programs.

use super::op::{Op, OpId, OpKind, Terminator, ValueId};
use std::collections::HashMap;
use std::fmt;

pub type BlockId = u32;
pub type FuncId = u32;

/// A basic block: straight-line ops plus one terminator.
#[derive(Clone, Debug)]
pub struct Block {
    pub ops: Vec<Op>,
    pub term: Terminator,
}

/// A host function. Values `0..n_params` are parameters; further values
/// are op results. Block 0 is the entry.
#[derive(Clone, Debug)]
pub struct Function {
    pub name: String,
    pub n_params: u32,
    pub n_values: u32,
    pub blocks: Vec<Block>,
}

impl Function {
    /// Iterate `(block, position, &op)` in layout order.
    pub fn ops(&self) -> impl Iterator<Item = (BlockId, usize, &Op)> {
        self.blocks.iter().enumerate().flat_map(|(b, blk)| {
            blk.ops
                .iter()
                .enumerate()
                .map(move |(i, op)| (b as BlockId, i, op))
        })
    }

    /// Find an op by id.
    pub fn op(&self, id: OpId) -> Option<(&Op, BlockId, usize)> {
        for (b, i, op) in self.ops() {
            if op.id == id {
                return Some((op, b, i));
            }
        }
        None
    }

    /// Location (block, index) of an op id; panics if absent.
    pub fn loc(&self, id: OpId) -> (BlockId, usize) {
        let (_, b, i) = self.op(id).unwrap_or_else(|| panic!("no op {id}"));
        (b, i)
    }

    /// Total op count.
    pub fn n_ops(&self) -> usize {
        self.blocks.iter().map(|b| b.ops.len()).sum()
    }
}

/// A whole application: functions plus the entry (`main`) id.
#[derive(Clone, Debug)]
pub struct Program {
    pub funcs: Vec<Function>,
    pub entry: FuncId,
}

impl Program {
    pub fn main(&self) -> &Function {
        &self.funcs[self.entry as usize]
    }

    pub fn func_by_name(&self, name: &str) -> Option<(FuncId, &Function)> {
        self.funcs
            .iter()
            .enumerate()
            .find(|(_, f)| f.name == name)
            .map(|(i, f)| (i as FuncId, f))
    }

    /// Validate structural invariants: terminator targets in range,
    /// op ids unique, results in range and defined exactly once (SSA),
    /// and every non-parameter operand defined by *some* op in the
    /// function (flow-insensitive: branch-dependent definedness is the
    /// verifier's job, but a value no op ever defines can only ever
    /// misbehave downstream).
    pub fn validate(&self) -> Result<(), String> {
        for f in &self.funcs {
            let mut seen_ops = HashMap::new();
            // ValueId -> defining OpId, for the duplicate-definition and
            // never-defined checks below.
            let mut def_op: HashMap<ValueId, OpId> = HashMap::new();
            for (b, i, op) in f.ops() {
                if let Some(prev) = seen_ops.insert(op.id, (b, i)) {
                    return Err(format!("{}: duplicate op id {} at {:?}", f.name, op.id, prev));
                }
                if let Some(r) = op.result {
                    if r < f.n_params || r >= f.n_values {
                        return Err(format!("{}: op {} result v{} out of range", f.name, op.id, r));
                    }
                    if let Some(first) = def_op.insert(r, op.id) {
                        return Err(format!(
                            "{}: duplicate definition of v{r} (op {} redefines op {first}'s result)",
                            f.name, op.id
                        ));
                    }
                }
                for v in op_operands(&op.kind) {
                    if v >= f.n_values {
                        return Err(format!("{}: op {} reads undefined v{}", f.name, op.id, v));
                    }
                }
                if let OpKind::Call { callee, .. } = &op.kind {
                    if *callee as usize >= self.funcs.len() {
                        return Err(format!("{}: call to missing func {}", f.name, callee));
                    }
                }
            }
            // Second pass, after every definition is known: a use of a
            // value in `n_params..n_values` that no op defines anywhere
            // is an invalid program, not a latent interpreter fault.
            for (_, _, op) in f.ops() {
                for v in op_operands(&op.kind) {
                    if v >= f.n_params && !def_op.contains_key(&v) {
                        return Err(format!(
                            "{}: op {} uses v{v}, which no op defines",
                            f.name, op.id
                        ));
                    }
                }
            }
            for blk in &f.blocks {
                if let Terminator::CondBr { trips, .. } = &blk.term {
                    if *trips >= f.n_values
                        || (*trips >= f.n_params && !def_op.contains_key(trips))
                    {
                        return Err(format!(
                            "{}: loop terminator uses v{trips}, which no op defines",
                            f.name
                        ));
                    }
                }
            }
            for blk in &f.blocks {
                let targets: Vec<BlockId> = match &blk.term {
                    Terminator::Br(t) => vec![*t],
                    Terminator::CondBr { taken, fallthrough, .. } => vec![*taken, *fallthrough],
                    Terminator::Ret => vec![],
                };
                for t in targets {
                    if t as usize >= f.blocks.len() {
                        return Err(format!("{}: branch to missing block {t}", f.name));
                    }
                }
            }
        }
        Ok(())
    }
}

/// All scalar/memobj value operands an op reads (not its result).
pub fn op_operands(kind: &OpKind) -> Vec<ValueId> {
    match kind {
        OpKind::Assign { expr } => {
            let mut v = Vec::new();
            expr.referenced_values(&mut v);
            v
        }
        OpKind::Malloc { bytes } => vec![*bytes],
        OpKind::Memcpy { obj, bytes, .. } | OpKind::Memset { obj, bytes } => vec![*obj, *bytes],
        OpKind::Free { obj } => vec![*obj],
        OpKind::Launch { grid, block, args, work, .. } => {
            let mut v = vec![*grid, *block, *work];
            v.extend(args.iter().copied());
            v
        }
        OpKind::DeviceSetLimit { bytes } => vec![*bytes],
        OpKind::SetDevice { dev } => vec![*dev],
        OpKind::Call { args, .. } => args.clone(),
        OpKind::HostCompute { micros } => vec![*micros],
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (fi, func) in self.funcs.iter().enumerate() {
            let entry = if fi as FuncId == self.entry { " [entry]" } else { "" };
            writeln!(f, "func {}({} params){entry} {{", func.name, func.n_params)?;
            for (b, blk) in func.blocks.iter().enumerate() {
                writeln!(f, "b{b}:")?;
                for op in &blk.ops {
                    write!(f, "  ")?;
                    if let Some(r) = op.result {
                        write!(f, "v{r} = ")?;
                    }
                    match &op.kind {
                        OpKind::Assign { expr } => writeln!(f, "assign {expr}")?,
                        OpKind::Malloc { bytes } => writeln!(f, "malloc v{bytes}")?,
                        OpKind::Memcpy { obj, bytes, dir } => {
                            let d = match dir {
                                super::op::CopyDir::HostToDevice => "h2d",
                                super::op::CopyDir::DeviceToHost => "d2h",
                            };
                            writeln!(f, "{d} v{obj} v{bytes}")?
                        }
                        OpKind::Memset { obj, bytes } => writeln!(f, "memset v{obj} v{bytes}")?,
                        OpKind::Free { obj } => writeln!(f, "free v{obj}")?,
                        OpKind::Launch { kernel, grid, block, args, work, .. } => {
                            let a: Vec<String> = args.iter().map(|v| format!("v{v}")).collect();
                            writeln!(
                                f,
                                "launch {kernel} grid=v{grid} block=v{block} args=[{}] work=v{work}",
                                a.join(",")
                            )?
                        }
                        OpKind::DeviceSetLimit { bytes } => writeln!(f, "set_heap_limit v{bytes}")?,
                        OpKind::SetDevice { dev } => writeln!(f, "set_device v{dev}")?,
                        OpKind::Call { callee, args } => {
                            let a: Vec<String> = args.iter().map(|v| format!("v{v}")).collect();
                            writeln!(f, "call {} [{}]", self.funcs[*callee as usize].name, a.join(","))?
                        }
                        OpKind::HostCompute { micros } => writeln!(f, "host_compute v{micros}")?,
                    }
                }
                match &blk.term {
                    Terminator::Br(t) => writeln!(f, "  br b{t}")?,
                    Terminator::CondBr { trips, taken, fallthrough } => {
                        writeln!(f, "  loop v{trips} b{taken} b{fallthrough}")?
                    }
                    Terminator::Ret => writeln!(f, "  ret")?,
                }
            }
            writeln!(f, "}}")?;
        }
        Ok(())
    }
}

//! Fluent builders for authoring IR programs (used by `workloads` and
//! by tests; mirrors what clang would emit for the host-side code).

use super::op::{CopyDir, Expr, Op, OpId, OpKind, Terminator, ValueId};
use super::program::{Block, BlockId, FuncId, Function, Program};

/// Builds a whole program; functions are appended in creation order and
/// `main` must be created (it becomes the entry).
pub struct ProgramBuilder {
    funcs: Vec<Function>,
    entry: Option<FuncId>,
}

impl Default for ProgramBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self { funcs: Vec::new(), entry: None }
    }

    /// Reserve a function id before building it (for forward calls).
    pub fn declare(&mut self, name: &str, n_params: u32) -> FuncId {
        let id = self.funcs.len() as FuncId;
        self.funcs.push(Function {
            name: name.to_string(),
            n_params,
            n_values: n_params,
            blocks: vec![Block { ops: Vec::new(), term: Terminator::Ret }],
        });
        id
    }

    /// Build (or rebuild) the body of a declared function.
    pub fn define<Fb>(&mut self, id: FuncId, body: Fb)
    where
        Fb: FnOnce(&mut FuncBuilder),
    {
        let n_params = self.funcs[id as usize].n_params;
        let name = self.funcs[id as usize].name.clone();
        let mut fb = FuncBuilder::new(name, n_params);
        body(&mut fb);
        self.funcs[id as usize] = fb.finish();
        if self.funcs[id as usize].name == "main" {
            self.entry = Some(id);
        }
    }

    /// Declare + define in one step.
    pub fn func<Fb>(&mut self, name: &str, n_params: u32, body: Fb) -> FuncId
    where
        Fb: FnOnce(&mut FuncBuilder),
    {
        let id = self.declare(name, n_params);
        self.define(id, body);
        id
    }

    pub fn finish(self) -> Program {
        let entry = self.entry.expect("program has no `main`");
        let p = Program { funcs: self.funcs, entry };
        if let Err(e) = p.validate() {
            panic!("built invalid program: {e}");
        }
        p
    }
}

/// Builds one function. Keeps a current block; `loop_n` creates the
/// back-edge structure for bounded loops.
pub struct FuncBuilder {
    name: String,
    n_params: u32,
    next_value: ValueId,
    next_op: OpId,
    blocks: Vec<Block>,
    cur: BlockId,
}

impl FuncBuilder {
    fn new(name: String, n_params: u32) -> Self {
        Self {
            name,
            n_params,
            next_value: n_params,
            next_op: 0,
            blocks: vec![Block { ops: Vec::new(), term: Terminator::Ret }],
            cur: 0,
        }
    }

    pub fn param(&self, i: u32) -> ValueId {
        assert!(i < self.n_params, "param {i} out of range");
        i
    }

    fn push(&mut self, result: Option<ValueId>, kind: OpKind) -> OpId {
        let id = self.next_op;
        self.next_op += 1;
        self.blocks[self.cur as usize].ops.push(Op { id, result, kind });
        id
    }

    fn fresh(&mut self) -> ValueId {
        let v = self.next_value;
        self.next_value += 1;
        v
    }

    /// Define a scalar from an expression.
    pub fn assign(&mut self, expr: Expr) -> ValueId {
        let v = self.fresh();
        self.push(Some(v), OpKind::Assign { expr });
        v
    }

    /// Convenience: a constant scalar.
    pub fn c(&mut self, v: i64) -> ValueId {
        self.assign(Expr::c(v))
    }

    pub fn malloc(&mut self, bytes: ValueId) -> ValueId {
        let v = self.fresh();
        self.push(Some(v), OpKind::Malloc { bytes });
        v
    }

    pub fn h2d(&mut self, obj: ValueId, bytes: ValueId) {
        self.push(None, OpKind::Memcpy { obj, bytes, dir: CopyDir::HostToDevice });
    }

    pub fn d2h(&mut self, obj: ValueId, bytes: ValueId) {
        self.push(None, OpKind::Memcpy { obj, bytes, dir: CopyDir::DeviceToHost });
    }

    pub fn memset(&mut self, obj: ValueId, bytes: ValueId) {
        self.push(None, OpKind::Memset { obj, bytes });
    }

    pub fn free(&mut self, obj: ValueId) {
        self.push(None, OpKind::Free { obj });
    }

    pub fn launch(
        &mut self,
        kernel: &str,
        grid: ValueId,
        block: ValueId,
        args: &[ValueId],
        work: ValueId,
    ) {
        self.push(
            None,
            OpKind::Launch {
                kernel: kernel.to_string(),
                grid,
                block,
                args: args.to_vec(),
                work,
                artifact: None,
            },
        );
    }

    /// Launch bound to a PJRT artifact for `--compute real` runs.
    pub fn launch_artifact(
        &mut self,
        kernel: &str,
        artifact: &str,
        grid: ValueId,
        block: ValueId,
        args: &[ValueId],
        work: ValueId,
    ) {
        self.push(
            None,
            OpKind::Launch {
                kernel: kernel.to_string(),
                grid,
                block,
                args: args.to_vec(),
                work,
                artifact: Some(artifact.to_string()),
            },
        );
    }

    pub fn set_heap_limit(&mut self, bytes: ValueId) {
        self.push(None, OpKind::DeviceSetLimit { bytes });
    }

    /// cudaSetDevice(dev) — static device binding (§II-B).
    pub fn set_device(&mut self, dev: ValueId) {
        self.push(None, OpKind::SetDevice { dev });
    }

    pub fn call(&mut self, callee: FuncId, args: &[ValueId]) {
        self.push(None, OpKind::Call { callee, args: args.to_vec() });
    }

    pub fn host_compute(&mut self, micros: ValueId) {
        self.push(None, OpKind::HostCompute { micros });
    }

    /// A bounded loop executing `body` `trips` times: emits
    /// header -> body -> header, then continues in the exit block.
    pub fn loop_n<Fb>(&mut self, trips: ValueId, body: Fb)
    where
        Fb: FnOnce(&mut FuncBuilder),
    {
        let header = self.new_block();
        let body_b = self.new_block();
        let exit = self.new_block();
        self.blocks[self.cur as usize].term = Terminator::Br(header);
        self.blocks[header as usize].term =
            Terminator::CondBr { trips, taken: body_b, fallthrough: exit };
        self.cur = body_b;
        body(self);
        // `body` may have moved the current block (nested loops).
        self.blocks[self.cur as usize].term = Terminator::Br(header);
        self.cur = exit;
    }

    /// An if-like diamond that always executes `then` (the analyses see a
    /// two-way branch; used by compiler tests for non-trivial CFGs).
    pub fn diamond<Ft, Fe>(&mut self, cond_trips: ValueId, then_b: Ft, else_b: Fe)
    where
        Ft: FnOnce(&mut FuncBuilder),
        Fe: FnOnce(&mut FuncBuilder),
    {
        let t = self.new_block();
        let e = self.new_block();
        let join = self.new_block();
        self.blocks[self.cur as usize].term =
            Terminator::CondBr { trips: cond_trips, taken: t, fallthrough: e };
        self.cur = t;
        then_b(self);
        self.blocks[self.cur as usize].term = Terminator::Br(join);
        self.cur = e;
        else_b(self);
        self.blocks[self.cur as usize].term = Terminator::Br(join);
        self.cur = join;
    }

    fn new_block(&mut self) -> BlockId {
        let id = self.blocks.len() as BlockId;
        self.blocks.push(Block { ops: Vec::new(), term: Terminator::Ret });
        id
    }

    fn finish(mut self) -> Function {
        // The current block keeps its default Ret terminator.
        let _ = &mut self;
        Function {
            name: self.name,
            n_params: self.n_params,
            n_values: self.next_value,
            blocks: self.blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_program() -> Program {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let bytes = f.assign(Expr::v(n).mul(Expr::c(4)));
            let buf = f.malloc(bytes);
            let trips = f.c(3);
            f.loop_n(trips, |f| {
                let (g, b, w) = (f.c(8), f.c(128), f.c(1000));
                f.launch("k", g, b, &[buf], w);
            });
            f.free(buf);
        });
        pb.finish()
    }

    #[test]
    fn builder_output_passes_validate() {
        // finish() already validates (panicking on failure); re-check
        // explicitly so a future relaxation of finish() can't regress.
        assert!(small_program().validate().is_ok());
    }

    #[test]
    fn validate_names_duplicate_definition() {
        let mut p = small_program();
        let f = &mut p.funcs[0];
        // Clone the first defining op into the same block: two ops now
        // claim the same result value.
        let mut dup = f.blocks[0].ops[0].clone();
        dup.id = 99;
        f.blocks[0].ops.push(dup);
        let err = p.validate().unwrap_err();
        assert!(err.contains("duplicate definition of v1"), "{err}");
    }

    #[test]
    fn validate_names_never_defined_use() {
        let mut p = small_program();
        let f = &mut p.funcs[0];
        // Widen the value space and reference a value no op defines.
        f.n_values += 1;
        let ghost = f.n_values - 1;
        let id = f.n_ops() as OpId + 50;
        f.blocks[0].ops.push(Op { id, result: None, kind: OpKind::Free { obj: ghost } });
        let err = p.validate().unwrap_err();
        assert!(err.contains(&format!("uses v{ghost}, which no op defines")), "{err}");
    }
}

//! Operations, values and symbolic scalar expressions.

use std::fmt;

/// Index of an SSA value within its function. Values `0..nparams` are the
/// function parameters; the rest are defined by ops (`Op::result`).
pub type ValueId = u32;

/// Stable identity of an op within its function: (block, index-in-block)
/// flattened by the function at construction time.
pub type OpId = u32;

/// Symbolic scalar expression over parameters and previously-defined
/// values. This is what the compiler's probes carry: resource
/// requirements stay symbolic until the probe interprets them at runtime
/// (paper §III-A1: "all of the analyzed information is in the form of
/// symbols").
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Const(i64),
    /// Reference to a value (parameter or op result).
    Value(ValueId),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    /// Integer division, rounding up (grid-size math is ceil-div).
    CeilDiv(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
    Min(Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn c(v: i64) -> Self {
        Expr::Const(v)
    }
    pub fn v(id: ValueId) -> Self {
        Expr::Value(id)
    }
    pub fn add(self, rhs: Expr) -> Self {
        Expr::Add(Box::new(self), Box::new(rhs))
    }
    pub fn sub(self, rhs: Expr) -> Self {
        Expr::Sub(Box::new(self), Box::new(rhs))
    }
    pub fn mul(self, rhs: Expr) -> Self {
        Expr::Mul(Box::new(self), Box::new(rhs))
    }
    pub fn ceil_div(self, rhs: Expr) -> Self {
        Expr::CeilDiv(Box::new(self), Box::new(rhs))
    }
    pub fn max(self, rhs: Expr) -> Self {
        Expr::Max(Box::new(self), Box::new(rhs))
    }
    pub fn min(self, rhs: Expr) -> Self {
        Expr::Min(Box::new(self), Box::new(rhs))
    }

    /// Evaluate under an environment mapping value ids to concrete i64s.
    pub fn eval(&self, env: &dyn Fn(ValueId) -> i64) -> i64 {
        match self {
            Expr::Const(c) => *c,
            Expr::Value(v) => env(*v),
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Sub(a, b) => a.eval(env) - b.eval(env),
            Expr::Mul(a, b) => a.eval(env) * b.eval(env),
            Expr::CeilDiv(a, b) => {
                let (a, b) = (a.eval(env), b.eval(env));
                if b == 0 {
                    0
                } else {
                    (a + b - 1) / b
                }
            }
            Expr::Max(a, b) => a.eval(env).max(b.eval(env)),
            Expr::Min(a, b) => a.eval(env).min(b.eval(env)),
        }
    }

    /// Every value id this expression reads.
    pub fn referenced_values(&self, out: &mut Vec<ValueId>) {
        match self {
            Expr::Const(_) => {}
            Expr::Value(v) => out.push(*v),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::CeilDiv(a, b)
            | Expr::Max(a, b)
            | Expr::Min(a, b) => {
                a.referenced_values(out);
                b.referenced_values(out);
            }
        }
    }
}

/// Why a checked symbolic evaluation could not produce a usable byte
/// count. The unchecked [`Expr::eval`] keeps the legacy wrapping/CeilDiv
/// semantics the runtime relies on; the verifier uses
/// [`Expr::eval_checked`] so a corrupt size expression becomes a
/// diagnostic at its defining op instead of a panic (or a silently
/// wrapped reservation) downstream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalError {
    /// `ceil(a / b)` with `b == 0`.
    DivByZero,
    /// An intermediate or final value left the i64 range.
    Overflow,
    /// The final value is negative where a byte/geometry count is
    /// required (carries the offending value).
    Negative(i64),
}

impl fmt::Display for EvalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EvalError::DivByZero => write!(f, "division by zero in size expression"),
            EvalError::Overflow => write!(f, "size expression overflows i64"),
            EvalError::Negative(v) => write!(f, "size expression evaluates to negative {v}"),
        }
    }
}

impl std::error::Error for EvalError {}

impl Expr {
    /// [`Expr::eval`] with arithmetic faults surfaced as typed errors:
    /// checked add/sub/mul (overflow), an explicit divide-by-zero on
    /// `CeilDiv`, and no silent wrapping anywhere. Callers that require
    /// a non-negative result (byte sizes, grid geometry) should map a
    /// negative final value to [`EvalError::Negative`] themselves —
    /// negativity of intermediates is legal (e.g. `(a - b) + c`).
    pub fn eval_checked(&self, env: &dyn Fn(ValueId) -> i64) -> Result<i64, EvalError> {
        match self {
            Expr::Const(c) => Ok(*c),
            Expr::Value(v) => Ok(env(*v)),
            Expr::Add(a, b) => a
                .eval_checked(env)?
                .checked_add(b.eval_checked(env)?)
                .ok_or(EvalError::Overflow),
            Expr::Sub(a, b) => a
                .eval_checked(env)?
                .checked_sub(b.eval_checked(env)?)
                .ok_or(EvalError::Overflow),
            Expr::Mul(a, b) => a
                .eval_checked(env)?
                .checked_mul(b.eval_checked(env)?)
                .ok_or(EvalError::Overflow),
            Expr::CeilDiv(a, b) => {
                let (a, b) = (a.eval_checked(env)?, b.eval_checked(env)?);
                if b == 0 {
                    return Err(EvalError::DivByZero);
                }
                b.checked_sub(1)
                    .and_then(|bm1| a.checked_add(bm1))
                    .and_then(|n| n.checked_div(b))
                    .ok_or(EvalError::Overflow)
            }
            Expr::Max(a, b) => Ok(a.eval_checked(env)?.max(b.eval_checked(env)?)),
            Expr::Min(a, b) => Ok(a.eval_checked(env)?.min(b.eval_checked(env)?)),
        }
    }
}

/// Direction of a memcpy, relative to the device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyDir {
    HostToDevice,
    DeviceToHost,
}

/// GPU API / host operations. Memory objects are `ValueId`s defined by
/// `Malloc`; scalar operands are `ValueId`s defined by `Assign` or
/// parameters.
#[derive(Clone, Debug)]
pub enum OpKind {
    /// Define a scalar value from a symbolic expression.
    Assign { expr: Expr },
    /// cudaMalloc: defines a memory-object value; `bytes` is a scalar.
    Malloc { bytes: ValueId },
    /// cudaMemcpy / cudaMemset touching a device memory object.
    Memcpy { obj: ValueId, bytes: ValueId, dir: CopyDir },
    Memset { obj: ValueId, bytes: ValueId },
    /// cudaFree.
    Free { obj: ValueId },
    /// `__cudaPushCallConfiguration` + kernel stub call. `grid`/`block`
    /// are scalar values (blocks, threads-per-block); `args` are memory
    /// objects; `work` is a scalar in device work-units (1.0 unit == 1
    /// second dedicated on the reference V100 / 1e6 scale, see
    /// `workloads::calib`); `artifact` names the PJRT executable that
    /// carries this kernel's real numerics.
    Launch {
        kernel: String,
        grid: ValueId,
        block: ValueId,
        args: Vec<ValueId>,
        work: ValueId,
        artifact: Option<String>,
    },
    /// cudaDeviceSetLimit(cudaLimitMallocHeapSize, bytes).
    DeviceSetLimit { bytes: ValueId },
    /// cudaSetDevice: statically binds subsequent GPU operations to a
    /// device index (the paper's §II-B default programming model; MGB
    /// replaces these bindings with its own placement, the `static`
    /// scheduler mode honours them).
    SetDevice { dev: ValueId },
    /// Call a host function (may contain GPU ops — inlined or lazy).
    Call { callee: super::FuncId, args: Vec<ValueId> },
    /// Host-side compute phase taking `micros` microseconds of wall time
    /// (scalar value), e.g. file loading or CPU pre/post-processing.
    HostCompute { micros: ValueId },
}

/// One IR operation; `result` is the value it defines (Assign, Malloc,
/// Call-with-result unsupported — calls are void).
#[derive(Clone, Debug)]
pub struct Op {
    pub id: OpId,
    pub result: Option<ValueId>,
    pub kind: OpKind,
}

/// Block terminators.
#[derive(Clone, Debug)]
pub enum Terminator {
    /// Unconditional branch.
    Br(super::BlockId),
    /// Conditional: branch to `taken` while the scalar `cond` (re-evaluated
    /// each arrival, monotone counters modelled via `TripCount`) is
    /// non-zero. Used for bounded loops.
    CondBr {
        /// Remaining-trips counter: the interpreter decrements a trip
        /// budget seeded from this scalar; the analyses treat it as an
        /// opaque condition.
        trips: ValueId,
        taken: super::BlockId,
        fallthrough: super::BlockId,
    },
    Ret,
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(c) => write!(f, "{c}"),
            Expr::Value(v) => write!(f, "v{v}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a} * {b})"),
            Expr::CeilDiv(a, b) => write!(f, "ceil({a} / {b})"),
            Expr::Max(a, b) => write!(f, "max({a}, {b})"),
            Expr::Min(a, b) => write!(f, "min({a}, {b})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_checked_matches_eval_on_sane_expressions() {
        let env = |v: ValueId| (v as i64 + 1) * 10;
        for e in [
            Expr::c(4).mul(Expr::v(0)).add(Expr::c(3)),
            Expr::v(1).ceil_div(Expr::c(7)),
            Expr::v(2).sub(Expr::c(5)).max(Expr::c(0)).min(Expr::c(100)),
        ] {
            assert_eq!(e.eval_checked(&env).unwrap(), e.eval(&env));
        }
    }

    #[test]
    fn eval_checked_surfaces_div_by_zero_and_overflow() {
        let env = |_: ValueId| 0i64;
        // The unchecked legacy eval defines ceil(x/0) == 0 (the lazy
        // runtime's CUDA-ish shrug); the checked form names the fault.
        let div0 = Expr::c(42).ceil_div(Expr::c(0));
        assert_eq!(div0.eval(&env), 0);
        assert_eq!(div0.eval_checked(&env), Err(EvalError::DivByZero));
        let ovf = Expr::c(i64::MAX).mul(Expr::c(2));
        assert_eq!(ovf.eval_checked(&env), Err(EvalError::Overflow));
        let ovf2 = Expr::c(i64::MAX).add(Expr::c(1));
        assert_eq!(ovf2.eval_checked(&env), Err(EvalError::Overflow));
        // Negative intermediates are fine; only the caller's final
        // byte-count check turns negativity into EvalError::Negative.
        let neg_mid = Expr::c(1).sub(Expr::c(5)).add(Expr::c(10));
        assert_eq!(neg_mid.eval_checked(&env), Ok(6));
    }
}

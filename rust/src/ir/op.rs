//! Operations, values and symbolic scalar expressions.

use std::fmt;

/// Index of an SSA value within its function. Values `0..nparams` are the
/// function parameters; the rest are defined by ops (`Op::result`).
pub type ValueId = u32;

/// Stable identity of an op within its function: (block, index-in-block)
/// flattened by the function at construction time.
pub type OpId = u32;

/// Symbolic scalar expression over parameters and previously-defined
/// values. This is what the compiler's probes carry: resource
/// requirements stay symbolic until the probe interprets them at runtime
/// (paper §III-A1: "all of the analyzed information is in the form of
/// symbols").
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Const(i64),
    /// Reference to a value (parameter or op result).
    Value(ValueId),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    /// Integer division, rounding up (grid-size math is ceil-div).
    CeilDiv(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
    Min(Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn c(v: i64) -> Self {
        Expr::Const(v)
    }
    pub fn v(id: ValueId) -> Self {
        Expr::Value(id)
    }
    pub fn add(self, rhs: Expr) -> Self {
        Expr::Add(Box::new(self), Box::new(rhs))
    }
    pub fn sub(self, rhs: Expr) -> Self {
        Expr::Sub(Box::new(self), Box::new(rhs))
    }
    pub fn mul(self, rhs: Expr) -> Self {
        Expr::Mul(Box::new(self), Box::new(rhs))
    }
    pub fn ceil_div(self, rhs: Expr) -> Self {
        Expr::CeilDiv(Box::new(self), Box::new(rhs))
    }
    pub fn max(self, rhs: Expr) -> Self {
        Expr::Max(Box::new(self), Box::new(rhs))
    }
    pub fn min(self, rhs: Expr) -> Self {
        Expr::Min(Box::new(self), Box::new(rhs))
    }

    /// Evaluate under an environment mapping value ids to concrete i64s.
    pub fn eval(&self, env: &dyn Fn(ValueId) -> i64) -> i64 {
        match self {
            Expr::Const(c) => *c,
            Expr::Value(v) => env(*v),
            Expr::Add(a, b) => a.eval(env) + b.eval(env),
            Expr::Sub(a, b) => a.eval(env) - b.eval(env),
            Expr::Mul(a, b) => a.eval(env) * b.eval(env),
            Expr::CeilDiv(a, b) => {
                let (a, b) = (a.eval(env), b.eval(env));
                if b == 0 {
                    0
                } else {
                    (a + b - 1) / b
                }
            }
            Expr::Max(a, b) => a.eval(env).max(b.eval(env)),
            Expr::Min(a, b) => a.eval(env).min(b.eval(env)),
        }
    }

    /// Every value id this expression reads.
    pub fn referenced_values(&self, out: &mut Vec<ValueId>) {
        match self {
            Expr::Const(_) => {}
            Expr::Value(v) => out.push(*v),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::CeilDiv(a, b)
            | Expr::Max(a, b)
            | Expr::Min(a, b) => {
                a.referenced_values(out);
                b.referenced_values(out);
            }
        }
    }
}

/// Direction of a memcpy, relative to the device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyDir {
    HostToDevice,
    DeviceToHost,
}

/// GPU API / host operations. Memory objects are `ValueId`s defined by
/// `Malloc`; scalar operands are `ValueId`s defined by `Assign` or
/// parameters.
#[derive(Clone, Debug)]
pub enum OpKind {
    /// Define a scalar value from a symbolic expression.
    Assign { expr: Expr },
    /// cudaMalloc: defines a memory-object value; `bytes` is a scalar.
    Malloc { bytes: ValueId },
    /// cudaMemcpy / cudaMemset touching a device memory object.
    Memcpy { obj: ValueId, bytes: ValueId, dir: CopyDir },
    Memset { obj: ValueId, bytes: ValueId },
    /// cudaFree.
    Free { obj: ValueId },
    /// `__cudaPushCallConfiguration` + kernel stub call. `grid`/`block`
    /// are scalar values (blocks, threads-per-block); `args` are memory
    /// objects; `work` is a scalar in device work-units (1.0 unit == 1
    /// second dedicated on the reference V100 / 1e6 scale, see
    /// `workloads::calib`); `artifact` names the PJRT executable that
    /// carries this kernel's real numerics.
    Launch {
        kernel: String,
        grid: ValueId,
        block: ValueId,
        args: Vec<ValueId>,
        work: ValueId,
        artifact: Option<String>,
    },
    /// cudaDeviceSetLimit(cudaLimitMallocHeapSize, bytes).
    DeviceSetLimit { bytes: ValueId },
    /// cudaSetDevice: statically binds subsequent GPU operations to a
    /// device index (the paper's §II-B default programming model; MGB
    /// replaces these bindings with its own placement, the `static`
    /// scheduler mode honours them).
    SetDevice { dev: ValueId },
    /// Call a host function (may contain GPU ops — inlined or lazy).
    Call { callee: super::FuncId, args: Vec<ValueId> },
    /// Host-side compute phase taking `micros` microseconds of wall time
    /// (scalar value), e.g. file loading or CPU pre/post-processing.
    HostCompute { micros: ValueId },
}

/// One IR operation; `result` is the value it defines (Assign, Malloc,
/// Call-with-result unsupported — calls are void).
#[derive(Clone, Debug)]
pub struct Op {
    pub id: OpId,
    pub result: Option<ValueId>,
    pub kind: OpKind,
}

/// Block terminators.
#[derive(Clone, Debug)]
pub enum Terminator {
    /// Unconditional branch.
    Br(super::BlockId),
    /// Conditional: branch to `taken` while the scalar `cond` (re-evaluated
    /// each arrival, monotone counters modelled via `TripCount`) is
    /// non-zero. Used for bounded loops.
    CondBr {
        /// Remaining-trips counter: the interpreter decrements a trip
        /// budget seeded from this scalar; the analyses treat it as an
        /// opaque condition.
        trips: ValueId,
        taken: super::BlockId,
        fallthrough: super::BlockId,
    },
    Ret,
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(c) => write!(f, "{c}"),
            Expr::Value(v) => write!(f, "v{v}"),
            Expr::Add(a, b) => write!(f, "({a} + {b})"),
            Expr::Sub(a, b) => write!(f, "({a} - {b})"),
            Expr::Mul(a, b) => write!(f, "({a} * {b})"),
            Expr::CeilDiv(a, b) => write!(f, "ceil({a} / {b})"),
            Expr::Max(a, b) => write!(f, "max({a}, {b})"),
            Expr::Min(a, b) => write!(f, "min({a}, {b})"),
        }
    }
}

//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! One `PjrtRuntime` per process; executables are compiled once from HLO
//! text and can be executed repeatedly with `f32` buffers. All model
//! entry points are lowered with `return_tuple=True` on the python side,
//! so results are unwrapped from a 1..n tuple here.

use anyhow::{Context, Result};
use std::path::Path;

/// Process-wide PJRT client plus compile cache entry points.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// A compiled HLO module ready for repeated execution.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable name (artifact stem), for metrics/log lines.
    pub name: String,
}

impl PjrtRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Name of the underlying PJRT platform (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable PJRT devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_else(|| "anon".to_string());
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf-8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name })
    }
}

impl Executable {
    /// Execute with f32 inputs of the given shapes; returns each tuple
    /// element of the result flattened to a `Vec<f32>`.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .context("reshaping input literal")?;
            literals.push(lit);
        }
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        // python lowers with return_tuple=True: unpack every element.
        // `decompose_tuple` yields [] for non-tuple (array) results.
        let elems = result.decompose_tuple()?;
        let mut out = Vec::new();
        if elems.is_empty() {
            out.push(result.to_vec::<f32>()?);
        } else {
            for e in elems {
                out.push(e.to_vec::<f32>()?);
            }
        }
        Ok(out)
    }
}

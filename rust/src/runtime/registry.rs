//! Kernel registry: maps workload kernel names to compiled executables.
//!
//! The coordinator resolves each simulated kernel launch to an artifact
//! by name; artifacts are compiled lazily on first use and cached, so the
//! request path never recompiles.

use super::{Executable, PjrtRuntime};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard when a previous holder panicked. The
/// caches guarded here are insert-only maps of completed values, so a
/// poisoned lock never exposes a half-written entry — recovering beats
/// propagating an unrelated thread's panic into every later launch.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A name-addressed, insert-only cache of shared values: hits hand
/// back a clone of the *same* `Arc` (no recompile, no reallocation),
/// and lookups tolerate lock poisoning. Kept generic so the cache
/// contract is testable without a PJRT runtime behind it.
struct ArcCache<V>(Mutex<HashMap<String, Arc<V>>>);

impl<V> ArcCache<V> {
    fn new() -> Self {
        ArcCache(Mutex::new(HashMap::new()))
    }

    /// The cached value for `name`, if present (same `Arc` every hit).
    fn get(&self, name: &str) -> Option<Arc<V>> {
        lock_unpoisoned(&self.0).get(name).cloned()
    }

    /// Cache `value` under `name`. Last writer wins (benign for the
    /// compile cache: both writers built the same artifact).
    fn insert(&self, name: &str, value: Arc<V>) {
        lock_unpoisoned(&self.0).insert(name.to_string(), value);
    }
}

/// Lazily-compiled, name-addressed store of PJRT executables.
pub struct KernelRegistry {
    runtime: PjrtRuntime,
    dir: PathBuf,
    cache: ArcCache<Executable>,
}

impl KernelRegistry {
    /// A registry over `dir` (usually `artifacts/`).
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            runtime: PjrtRuntime::cpu()?,
            dir: dir.into(),
            cache: ArcCache::new(),
        })
    }

    /// Artifact names available on disk (sorted).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter_map(|e| {
                let p = e.path();
                let s = p.file_name()?.to_str()?;
                s.strip_suffix(".hlo.txt").map(str::to_string)
            })
            .collect();
        names.sort();
        names
    }

    /// Parse `manifest.txt` into (name -> input shapes).
    pub fn manifest(&self) -> Result<Vec<(String, Vec<Vec<usize>>)>> {
        let text = std::fs::read_to_string(self.dir.join("manifest.txt"))
            .context("reading manifest.txt (run `make artifacts`)")?;
        let mut out = Vec::new();
        for line in text.lines() {
            let mut parts = line.trim().split(';');
            let (Some(name), Some(ins)) = (parts.next(), parts.next()) else {
                continue;
            };
            let shapes: Vec<Vec<usize>> = ins
                .trim_start_matches("in=")
                .split(',')
                .map(|s| s.split('x').filter_map(|d| d.parse().ok()).collect())
                .collect();
            out.push((name.to_string(), shapes));
        }
        Ok(out)
    }

    /// Execute artifact `name` with synthetic (deterministic, smooth)
    /// inputs of the manifest's shapes; checks every output is finite.
    /// Returns the flattened outputs. This is the real-compute path the
    /// end-to-end example drives for every kernel the scheduler places.
    pub fn run_synthetic(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        let manifest = self.manifest()?;
        let shapes = manifest
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.clone())
            .with_context(|| format!("{name} not in manifest"))?;
        let exe = self.get(name)?;
        let data: Vec<Vec<f32>> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.iter().product();
                // Smooth, bounded, non-constant inputs; offset per arg.
                (0..n)
                    .map(|j| 0.55 + 0.4 * ((j as f32 * 0.137 + i as f32).sin()))
                    .collect()
            })
            .collect();
        let inputs: Vec<(&[f32], &[usize])> = data
            .iter()
            .zip(shapes.iter())
            .map(|(d, s)| (d.as_slice(), s.as_slice()))
            .collect();
        let outs = exe.run_f32(&inputs)?;
        for (k, o) in outs.iter().enumerate() {
            anyhow::ensure!(
                o.iter().all(|v| v.is_finite()),
                "{name}: output {k} contains non-finite values"
            );
        }
        Ok(outs)
    }

    /// Get (compiling on first use) the executable for `name`. Hits
    /// return the same `Arc` the first call cached.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e);
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "no artifact '{}' in {} (run `make artifacts`)",
                name,
                self.dir.display()
            );
        }
        let exe = Arc::new(self.runtime.load_hlo_text(&path)?);
        self.cache.insert(name, exe.clone());
        Ok(exe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hit_returns_the_same_arc() {
        let c: ArcCache<String> = ArcCache::new();
        assert!(c.get("k").is_none());
        let v = Arc::new("compiled".to_string());
        c.insert("k", v.clone());
        let a = c.get("k").expect("hit");
        let b = c.get("k").expect("hit");
        // Identity, not just equality: a hit must not rebuild anything.
        assert!(Arc::ptr_eq(&a, &v));
        assert!(Arc::ptr_eq(&a, &b));
        assert!(c.get("other").is_none());
    }

    #[test]
    fn cache_survives_a_poisoned_lock() {
        let c = std::sync::Arc::new(ArcCache::<u32>::new());
        c.insert("k", Arc::new(7));
        // Panic while holding the lock on another thread: the mutex is
        // now poisoned.
        let c2 = c.clone();
        let _ = std::thread::spawn(move || {
            let _guard = c2.0.lock().unwrap();
            panic!("poison the cache lock");
        })
        .join();
        assert!(c.0.lock().is_err(), "lock must actually be poisoned");
        // The poison-tolerant accessors keep working.
        assert_eq!(c.get("k").as_deref(), Some(&7));
        c.insert("j", Arc::new(9));
        assert_eq!(c.get("j").as_deref(), Some(&9));
    }
}

//! Kernel registry: maps workload kernel names to compiled executables.
//!
//! The coordinator resolves each simulated kernel launch to an artifact
//! by name; artifacts are compiled lazily on first use and cached, so the
//! request path never recompiles.

use super::cache::ArcCache;
use super::{Executable, PjrtRuntime};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Lazily-compiled, name-addressed store of PJRT executables.
pub struct KernelRegistry {
    runtime: PjrtRuntime,
    dir: PathBuf,
    cache: ArcCache<Executable>,
}

impl KernelRegistry {
    /// A registry over `dir` (usually `artifacts/`).
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            runtime: PjrtRuntime::cpu()?,
            dir: dir.into(),
            cache: ArcCache::new(),
        })
    }

    /// Artifact names available on disk (sorted).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .flatten()
            .filter_map(|e| {
                let p = e.path();
                let s = p.file_name()?.to_str()?;
                s.strip_suffix(".hlo.txt").map(str::to_string)
            })
            .collect();
        names.sort();
        names
    }

    /// Parse `manifest.txt` into (name -> input shapes).
    pub fn manifest(&self) -> Result<Vec<(String, Vec<Vec<usize>>)>> {
        let text = std::fs::read_to_string(self.dir.join("manifest.txt"))
            .context("reading manifest.txt (run `make artifacts`)")?;
        let mut out = Vec::new();
        for line in text.lines() {
            let mut parts = line.trim().split(';');
            let (Some(name), Some(ins)) = (parts.next(), parts.next()) else {
                continue;
            };
            let shapes: Vec<Vec<usize>> = ins
                .trim_start_matches("in=")
                .split(',')
                .map(|s| s.split('x').filter_map(|d| d.parse().ok()).collect())
                .collect();
            out.push((name.to_string(), shapes));
        }
        Ok(out)
    }

    /// Execute artifact `name` with synthetic (deterministic, smooth)
    /// inputs of the manifest's shapes; checks every output is finite.
    /// Returns the flattened outputs. This is the real-compute path the
    /// end-to-end example drives for every kernel the scheduler places.
    pub fn run_synthetic(&self, name: &str) -> Result<Vec<Vec<f32>>> {
        let manifest = self.manifest()?;
        let shapes = manifest
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.clone())
            .with_context(|| format!("{name} not in manifest"))?;
        let exe = self.get(name)?;
        let data: Vec<Vec<f32>> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let n: usize = s.iter().product();
                // Smooth, bounded, non-constant inputs; offset per arg.
                (0..n)
                    .map(|j| 0.55 + 0.4 * ((j as f32 * 0.137 + i as f32).sin()))
                    .collect()
            })
            .collect();
        let inputs: Vec<(&[f32], &[usize])> = data
            .iter()
            .zip(shapes.iter())
            .map(|(d, s)| (d.as_slice(), s.as_slice()))
            .collect();
        let outs = exe.run_f32(&inputs)?;
        for (k, o) in outs.iter().enumerate() {
            anyhow::ensure!(
                o.iter().all(|v| v.is_finite()),
                "{name}: output {k} contains non-finite values"
            );
        }
        Ok(outs)
    }

    /// Get (compiling on first use) the executable for `name`. Hits
    /// return the same `Arc` the first call cached.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e);
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "no artifact '{}' in {} (run `make artifacts`)",
                name,
                self.dir.display()
            );
        }
        let exe = Arc::new(self.runtime.load_hlo_text(&path)?);
        self.cache.insert(name, exe.clone());
        Ok(exe)
    }
}

// The cache contract tests (same-`Arc` hits, poison tolerance,
// capacity eviction) live with the promoted cache in `runtime/cache.rs`.

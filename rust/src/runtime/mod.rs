//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`), compile once
//! on the CPU PJRT client, execute from the coordinator's hot path.
//!
//! Interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids. See `/opt/xla-example`.

pub mod cache;
mod client;
pub mod registry;

pub use cache::ArcCache;
pub use client::{Executable, PjrtRuntime};
pub use registry::KernelRegistry;

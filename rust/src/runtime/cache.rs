//! Keyed, capacity-bounded `Arc` cache shared by the runtime layers.
//!
//! Promoted out of `runtime/registry.rs` (where it was a private
//! executable cache) so the lazy layer can reuse the exact same
//! contract for compiled job traces: hits hand back a clone of the
//! *same* `Arc` (no recompile, no reallocation), lookups tolerate lock
//! poisoning, and — new with the promotion — the cache is bounded, so
//! a long-lived process sweeping many distinct keys can no longer grow
//! it without limit. Eviction is insertion-ordered (FIFO): the oldest
//! *distinct* key is dropped when a new one would exceed capacity.
//! That is deliberately simpler than LRU — every caller here keys a
//! handful of hot artifacts or program traces, so recency tracking
//! would buy nothing over the bound itself.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Default capacity: comfortably above the distinct artifact / program
/// count of every built-in workload, small enough that a runaway key
/// sweep stays bounded.
pub const DEFAULT_CAPACITY: usize = 256;

/// Lock `m`, recovering the guard when a previous holder panicked. The
/// caches guarded here are maps of completed values, so a poisoned
/// lock never exposes a half-written entry — recovering beats
/// propagating an unrelated thread's panic into every later lookup.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

struct CacheState<V> {
    map: HashMap<String, Arc<V>>,
    /// Distinct keys in insertion order (front = oldest = next victim).
    order: VecDeque<String>,
}

/// A name-addressed cache of shared values. See the module docs for
/// the contract (same-`Arc` hits, poison tolerance, FIFO bound).
pub struct ArcCache<V> {
    inner: Mutex<CacheState<V>>,
    capacity: usize,
}

impl<V> ArcCache<V> {
    /// A cache bounded at [`DEFAULT_CAPACITY`] distinct keys.
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// A cache bounded at `capacity` distinct keys (min 1).
    pub fn with_capacity(capacity: usize) -> Self {
        ArcCache {
            inner: Mutex::new(CacheState { map: HashMap::new(), order: VecDeque::new() }),
            capacity: capacity.max(1),
        }
    }

    /// The cached value for `name`, if present (same `Arc` every hit).
    pub fn get(&self, name: &str) -> Option<Arc<V>> {
        lock_unpoisoned(&self.inner).map.get(name).cloned()
    }

    /// Cache `value` under `name`. Last writer wins (benign for every
    /// caller here: racing writers built the same value from the same
    /// key). Inserting a *new* key at capacity evicts the oldest key;
    /// overwriting an existing key keeps its original insertion slot.
    pub fn insert(&self, name: &str, value: Arc<V>) {
        let mut st = lock_unpoisoned(&self.inner);
        if !st.map.contains_key(name) {
            if st.order.len() >= self.capacity {
                if let Some(victim) = st.order.pop_front() {
                    st.map.remove(&victim);
                }
            }
            st.order.push_back(name.to_string());
        }
        st.map.insert(name.to_string(), value);
    }

    /// Hit-or-build: return the cached `Arc` for `name`, building and
    /// caching it with `build` on a miss. `build` runs *outside* the
    /// lock, so concurrent misses may build twice — last writer wins,
    /// and both callers hold a usable value either way.
    pub fn get_or_insert_with(&self, name: &str, build: impl FnOnce() -> V) -> Arc<V> {
        if let Some(v) = self.get(name) {
            return v;
        }
        let v = Arc::new(build());
        self.insert(name, v.clone());
        v
    }

    /// Distinct keys currently cached.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<V> Default for ArcCache<V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_hit_returns_the_same_arc() {
        let c: ArcCache<String> = ArcCache::new();
        assert!(c.get("k").is_none());
        let v = Arc::new("compiled".to_string());
        c.insert("k", v.clone());
        let a = c.get("k").expect("hit");
        let b = c.get("k").expect("hit");
        // Identity, not just equality: a hit must not rebuild anything.
        assert!(Arc::ptr_eq(&a, &v));
        assert!(Arc::ptr_eq(&a, &b));
        assert!(c.get("other").is_none());
    }

    #[test]
    fn cache_survives_a_poisoned_lock() {
        let c = std::sync::Arc::new(ArcCache::<u32>::new());
        c.insert("k", Arc::new(7));
        // Panic while holding the lock on another thread: the mutex is
        // now poisoned.
        let c2 = c.clone();
        let _ = std::thread::spawn(move || {
            let _guard = c2.inner.lock().unwrap();
            panic!("poison the cache lock");
        })
        .join();
        assert!(c.inner.lock().is_err(), "lock must actually be poisoned");
        // The poison-tolerant accessors keep working.
        assert_eq!(c.get("k").as_deref(), Some(&7));
        c.insert("j", Arc::new(9));
        assert_eq!(c.get("j").as_deref(), Some(&9));
        assert_eq!(*c.get_or_insert_with("k", || 0), 7, "hit, not a rebuild");
    }

    #[test]
    fn capacity_evicts_oldest_key_first() {
        let c: ArcCache<u32> = ArcCache::with_capacity(2);
        c.insert("a", Arc::new(1));
        c.insert("b", Arc::new(2));
        // Overwriting an existing key is not an insertion: nothing is
        // evicted and "a" keeps its (oldest) slot.
        c.insert("b", Arc::new(20));
        assert_eq!(c.len(), 2);
        assert_eq!(c.get("a").as_deref(), Some(&1));
        // A third distinct key evicts the oldest ("a"), not "b".
        c.insert("c", Arc::new(3));
        assert_eq!(c.len(), 2);
        assert!(c.get("a").is_none(), "oldest key evicted");
        assert_eq!(c.get("b").as_deref(), Some(&20));
        assert_eq!(c.get("c").as_deref(), Some(&3));
        // And the eviction order rolls forward: "b" is now oldest.
        c.insert("d", Arc::new(4));
        assert!(c.get("b").is_none());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn get_or_insert_builds_once_per_key() {
        let c: ArcCache<u32> = ArcCache::new();
        let a = c.get_or_insert_with("k", || 41);
        let b = c.get_or_insert_with("k", || panic!("hit must not rebuild"));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(*a, 41);
        assert_eq!(c.len(), 1);
    }
}

//! The lazy runtime (paper §III-A2) — and the bridge from compiled
//! programs to schedulable traces.
//!
//! The interpreter executes a [`CompiledProgram`] with concrete
//! parameters and produces a [`JobTrace`]: the exact stream of probe
//! firings and GPU operations the application would issue. Statically
//! bound tasks fire `TaskBegin` at their probe point with resources
//! interpreted from the compiler's symbolic expressions. Everything else
//! flows through the lazy machinery: GPU operations get *pseudo
//! addresses* and are queued per memory object; at the first kernel
//! launch touching those objects (`kernelLaunchPrepare`), the queues are
//! replayed behind a freshly-minted dynamic task whose resource vector
//! is computed from the replayed allocations — so the scheduler always
//! learns a task's full needs before any device op executes.

pub mod compile;
mod interp;
mod trace;

pub use compile::{compile_trace, Segment, TraceProgram};
pub use interp::{interpret, InterpError};
pub use trace::{JobTrace, TaskResources, TraceEvent, TraceSummary};

//! Trace compilation: compact steady-state segments of a [`JobTrace`]
//! into macro-step plans the engine can replay as one event each.
//!
//! A *segment* is a maximal run of events, inside one open task, that
//! the engine can step without consulting the scheduler: kernel
//! launches on the task's already-reserved device, host/transfer
//! sleeps, and reservation-covered `Malloc`/`Free`/`Memset` (which the
//! fine-grained stepper treats as pure `pc += 1` when the task holds a
//! probe reservation). Everything that can *block* or change placement
//! state is a side-exit boundary and never enters a segment:
//!
//! - `TaskBegin` — a probe that may block on placement (and an
//!   SLO-class boundary: admission/latency decisions hang off it);
//! - `TaskEnd` — releases the reservation and wakes waiters;
//! - any op on a different task than the segment's.
//!
//! Whether a `Malloc`/`Free` actually changes held bytes is a *runtime*
//! property (it depends on the task holding a reservation), so segments
//! containing them are marked `has_memops` and the engine only enters
//! such a segment when the reservation is live — otherwise it falls
//! back to fine-grained stepping, where the raw-allocation (crashable)
//! path runs exactly as before.
//!
//! The plan is static: event-index ranges plus precomputed totals
//! (dedicated work, host time, transfer bytes, written bytes). Exact
//! per-kernel timing is *not* precomputed here — the engine dry-runs
//! the segment against a scratch clone of the target device at entry
//! time, guaranteeing bit-identical float math with fine-grained
//! stepping by construction.
//!
//! Indices are in raw trace-event space, which the engine's compact
//! (`CEv`) stream mirrors 1:1, so the same plan drives both.

use super::trace::TraceEvent;

/// Sentinel in [`TraceProgram::starts`]: no segment starts here.
const NO_SEG: u32 = u32::MAX;

/// One compiled steady-state segment: events `[start, end)` of the
/// trace, all within open task `task`.
#[derive(Clone, Debug, PartialEq)]
pub struct Segment {
    /// First event index of the segment.
    pub start: usize,
    /// One past the last event index.
    pub end: usize,
    /// The single task whose ops the segment contains.
    pub task: usize,
    /// Kernel launches inside the segment.
    pub n_kernels: usize,
    /// Total dedicated kernel time (microseconds).
    pub work_us: u64,
    /// Total host-phase time (microseconds).
    pub host_us: u64,
    /// Total H2D + D2H transfer bytes (each occupies the PCIe link for
    /// `bytes / PCIE_BYTES_PER_SEC` seconds of the segment).
    pub xfer_bytes: u64,
    /// Device bytes written (H2D + Memset traffic) — the delta a
    /// checkpoint taken after the segment must account for.
    pub written_bytes: u64,
    /// Net resource deltas the segment would apply *without* a
    /// reservation: raw Malloc / Free byte totals. Under a live
    /// reservation both are absorbed by the up-front reserve and the
    /// segment is device-state-pure.
    pub alloc_bytes: u64,
    pub free_bytes: u64,
    /// Whether the segment contains Malloc/Free at all. If so, entering
    /// it requires the task's probe reservation to be live (the
    /// condition that makes those ops pure).
    pub has_memops: bool,
}

impl Segment {
    /// Events covered.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end == self.start
    }

    /// Nominal (dedicated, interference-free, reference-speed) duration
    /// of the segment given the simulator's PCIe bandwidth — a summary
    /// for reporting, not the replay clock (the engine's entry-time
    /// dry-run computes exact times).
    pub fn nominal_duration_s(&self, pcie_bytes_per_sec: f64) -> f64 {
        self.work_us as f64 * 1e-6
            + self.host_us as f64 * 1e-6
            + self.xfer_bytes as f64 / pcie_bytes_per_sec
    }
}

/// The compiled segment plan of one trace: the segments plus a dense
/// event-index → segment lookup for the engine's stepping loop.
#[derive(Clone, Debug, Default)]
pub struct TraceProgram {
    pub segments: Vec<Segment>,
    /// `starts[i]` = index into `segments` of the segment starting at
    /// event `i`, or `NO_SEG`.
    starts: Vec<u32>,
}

impl TraceProgram {
    /// The segment starting exactly at event index `pc`, if any.
    #[inline]
    pub fn segment_starting_at(&self, pc: usize) -> Option<&Segment> {
        match self.starts.get(pc) {
            Some(&s) if s != NO_SEG => Some(&self.segments[s as usize]),
            _ => None,
        }
    }

    /// Events covered by any segment (for reporting/tests).
    pub fn covered_events(&self) -> usize {
        self.segments.iter().map(Segment::len).sum()
    }
}

/// Compile `events` into a [`TraceProgram`]. A candidate run must span
/// at least two events and contain at least one kernel launch to
/// become a segment — shorter runs cost one calendar event either way,
/// so compacting them buys nothing.
pub fn compile_trace(events: &[TraceEvent]) -> TraceProgram {
    struct Run {
        start: usize,
        task: Option<usize>,
        n_kernels: usize,
        work_us: u64,
        host_us: u64,
        xfer_bytes: u64,
        written_bytes: u64,
        alloc_bytes: u64,
        free_bytes: u64,
        has_memops: bool,
    }
    impl Run {
        fn fresh(start: usize) -> Self {
            Run {
                start,
                task: None,
                n_kernels: 0,
                work_us: 0,
                host_us: 0,
                xfer_bytes: 0,
                written_bytes: 0,
                alloc_bytes: 0,
                free_bytes: 0,
                has_memops: false,
            }
        }
    }

    let mut prog = TraceProgram {
        segments: Vec::new(),
        starts: vec![NO_SEG; events.len()],
    };
    let mut run = Run::fresh(0);
    let mut flush = |run: &mut Run, end: usize, prog: &mut TraceProgram| {
        let qualifies = run.task.is_some() && run.n_kernels >= 1 && end - run.start >= 2;
        if qualifies {
            prog.starts[run.start] = prog.segments.len() as u32;
            prog.segments.push(Segment {
                start: run.start,
                end,
                task: run.task.expect("qualifying run has a task"),
                n_kernels: run.n_kernels,
                work_us: run.work_us,
                host_us: run.host_us,
                xfer_bytes: run.xfer_bytes,
                written_bytes: run.written_bytes,
                alloc_bytes: run.alloc_bytes,
                free_bytes: run.free_bytes,
                has_memops: run.has_memops,
            });
        }
        *run = Run::fresh(end);
    };

    for (i, e) in events.iter().enumerate() {
        // Boundary events: flush the open run, then skip past them.
        let task = match e {
            TraceEvent::TaskBegin { .. } | TraceEvent::TaskEnd { .. } => {
                flush(&mut run, i, &mut prog);
                run.start = i + 1;
                continue;
            }
            TraceEvent::Malloc { task, .. }
            | TraceEvent::H2D { task, .. }
            | TraceEvent::D2H { task, .. }
            | TraceEvent::Memset { task, .. }
            | TraceEvent::Launch { task, .. }
            | TraceEvent::Free { task, .. } => Some(*task),
            TraceEvent::Host { .. } => None,
        };
        // A different task's op ends the run and starts a new one here.
        if let (Some(t), Some(open)) = (task, run.task) {
            if t != open {
                flush(&mut run, i, &mut prog);
            }
        }
        if run.task.is_none() {
            run.task = task;
        }
        match e {
            TraceEvent::Malloc { bytes, .. } => {
                run.alloc_bytes += bytes;
                run.has_memops = true;
            }
            TraceEvent::Free { bytes, .. } => {
                run.free_bytes += bytes;
                run.has_memops = true;
            }
            TraceEvent::H2D { bytes, .. } => {
                run.xfer_bytes += bytes;
                run.written_bytes += bytes;
            }
            TraceEvent::D2H { bytes, .. } => run.xfer_bytes += bytes,
            TraceEvent::Memset { bytes, .. } => run.written_bytes += bytes,
            TraceEvent::Launch { work_us, .. } => {
                run.n_kernels += 1;
                run.work_us += work_us;
            }
            TraceEvent::Host { micros } => run.host_us += micros,
            TraceEvent::TaskBegin { .. } | TraceEvent::TaskEnd { .. } => unreachable!(),
        }
    }
    flush(&mut run, events.len(), &mut prog);
    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::InterferenceProfile;
    use crate::lazy::{JobTrace, TaskResources};

    fn res() -> TaskResources {
        TaskResources {
            static_dev: None,
            mem_bytes: 1 << 20,
            heap_bytes: 0,
            grid: 8,
            block: 128,
            written_bytes: 0,
            iv: InterferenceProfile::ZERO,
        }
    }

    fn launch(task: usize, work_us: u64) -> TraceEvent {
        TraceEvent::Launch {
            task,
            kernel: "k".into(),
            artifact: None,
            grid: 8,
            block: 128,
            work_us,
        }
    }

    #[test]
    fn steady_state_run_compacts_into_one_segment() {
        let events = vec![
            TraceEvent::TaskBegin { task: 0, res: res() },
            TraceEvent::Malloc { task: 0, bytes: 100 },
            TraceEvent::H2D { task: 0, bytes: 1000 },
            launch(0, 10),
            TraceEvent::Host { micros: 5 },
            launch(0, 20),
            TraceEvent::D2H { task: 0, bytes: 500 },
            TraceEvent::Free { task: 0, bytes: 100 },
            TraceEvent::TaskEnd { task: 0 },
        ];
        let p = compile_trace(&events);
        assert_eq!(p.segments.len(), 1);
        let s = &p.segments[0];
        assert_eq!((s.start, s.end), (1, 8), "everything between begin and end");
        assert_eq!(s.task, 0);
        assert_eq!(s.n_kernels, 2);
        assert_eq!(s.work_us, 30);
        assert_eq!(s.host_us, 5);
        assert_eq!(s.xfer_bytes, 1500);
        assert_eq!(s.written_bytes, 1000);
        assert_eq!((s.alloc_bytes, s.free_bytes), (100, 100));
        assert!(s.has_memops);
        assert!(p.segment_starting_at(1).is_some());
        assert!(p.segment_starting_at(2).is_none(), "only the start index maps");
        assert!(p.segment_starting_at(0).is_none());
        let nominal = s.nominal_duration_s(1e9);
        assert!((nominal - (30e-6 + 5e-6 + 1500.0 / 1e9)).abs() < 1e-15);
    }

    #[test]
    fn boundaries_and_short_runs_do_not_compact() {
        // A lone launch (1 event) and a probe boundary split: no segment
        // may cross TaskBegin/TaskEnd, and singletons don't qualify.
        let events = vec![
            TraceEvent::TaskBegin { task: 0, res: res() },
            launch(0, 10),
            TraceEvent::TaskEnd { task: 0 },
            TraceEvent::TaskBegin { task: 1, res: res() },
            launch(1, 10),
            launch(1, 20),
            TraceEvent::TaskEnd { task: 1 },
        ];
        let p = compile_trace(&events);
        assert_eq!(p.segments.len(), 1, "only the two-launch run qualifies");
        assert_eq!((p.segments[0].start, p.segments[0].end), (4, 6));
        assert_eq!(p.segments[0].task, 1);
        assert_eq!(p.covered_events(), 2);
    }

    #[test]
    fn kernel_free_runs_do_not_qualify() {
        // Pure transfer/host runs stay fine-grained: without a launch
        // there is no device residency to batch.
        let events = vec![
            TraceEvent::TaskBegin { task: 0, res: res() },
            TraceEvent::H2D { task: 0, bytes: 10 },
            TraceEvent::Host { micros: 5 },
            TraceEvent::D2H { task: 0, bytes: 10 },
            TraceEvent::TaskEnd { task: 0 },
        ];
        assert!(compile_trace(&events).segments.is_empty());
    }

    #[test]
    fn interleaved_tasks_split_segments_per_task() {
        // Ops of two concurrently-open tasks interleave: each maximal
        // same-task run is its own candidate.
        let events = vec![
            TraceEvent::TaskBegin { task: 0, res: res() },
            TraceEvent::TaskBegin { task: 1, res: res() },
            launch(0, 10),
            launch(0, 10),
            launch(1, 20),
            launch(1, 20),
            TraceEvent::TaskEnd { task: 0 },
            TraceEvent::TaskEnd { task: 1 },
        ];
        let p = compile_trace(&events);
        assert_eq!(p.segments.len(), 2);
        assert_eq!((p.segments[0].start, p.segments[0].end, p.segments[0].task), (2, 4, 0));
        assert_eq!((p.segments[1].start, p.segments[1].end, p.segments[1].task), (4, 6, 1));
    }

    #[test]
    fn job_trace_memoizes_the_program_across_clones() {
        let t = JobTrace::new(vec![
            TraceEvent::TaskBegin { task: 0, res: res() },
            launch(0, 10),
            launch(0, 20),
            TraceEvent::TaskEnd { task: 0 },
        ]);
        let a = t.compiled().clone();
        assert_eq!(a.segments.len(), 1);
        // Same Arc on every call, shared by clones (no recompile per job).
        assert!(std::sync::Arc::ptr_eq(&a, t.compiled()));
        let c = t.clone();
        assert!(std::sync::Arc::ptr_eq(&a, c.compiled()));
    }
}

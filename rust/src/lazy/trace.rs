//! Trace events: the device-independent operation stream of one job.

use crate::gpu::InterferenceProfile;

/// Resource vector a probe conveys to the scheduler (`task_begin`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskResources {
    /// Device the application statically bound this task to via
    /// cudaSetDevice, if any (honoured only by the `static` scheduler
    /// mode; MGB overrides it — that is the paper's point).
    pub static_dev: Option<u32>,
    /// Global-memory footprint in bytes (sum of the task's allocations).
    pub mem_bytes: u64,
    /// On-device malloc heap (DeviceSetLimit or the 8 MiB default).
    pub heap_bytes: u64,
    /// Thread blocks of the widest member launch.
    pub grid: u64,
    /// Threads per block of the widest member launch.
    pub block: u64,
    /// Upper bound on device bytes the task writes per execution
    /// (member H2D + Memset traffic plus one full store of every
    /// launch-argument buffer). Groundwork for delta checkpoints; `0`
    /// means "not tracked" (legacy/synthetic traces) and disables the
    /// conformance check on written traffic.
    pub written_bytes: u64,
    /// Resource-pressure profile of the task's kernels (memory
    /// bandwidth / L2 / SM occupancy). `ZERO` — the default for every
    /// trace source that predates interference modeling — means the
    /// task neither suffers nor causes contention beyond processor
    /// sharing.
    pub iv: InterferenceProfile,
}

impl TaskResources {
    /// Total device memory the scheduler must reserve.
    pub fn reserve_bytes(&self) -> u64 {
        self.mem_bytes + self.heap_bytes
    }

    /// Warps needed when fully resident: grid * ceil(block / 32).
    pub fn warps(&self) -> u64 {
        self.grid * self.block.div_ceil(32)
    }

    /// Thread blocks requested.
    pub fn thread_blocks(&self) -> u64 {
        self.grid
    }

    /// Warps per thread block.
    pub fn warps_per_tb(&self) -> u64 {
        self.block.div_ceil(32)
    }
}

/// One step of a job's execution, in issue order.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// Probe firing: the scheduler is asked to place task `task`.
    TaskBegin { task: usize, res: TaskResources },
    /// Device memory allocation (bytes) within the current placement.
    Malloc { task: usize, bytes: u64 },
    /// Host-to-device transfer.
    H2D { task: usize, bytes: u64 },
    /// Device-to-host transfer.
    D2H { task: usize, bytes: u64 },
    /// On-device memset.
    Memset { task: usize, bytes: u64 },
    /// Kernel launch. `work_us` is the dedicated-execution time on the
    /// reference device (V100) in microseconds; `artifact` optionally
    /// names a PJRT executable carrying the kernel's real numerics.
    Launch {
        task: usize,
        kernel: String,
        artifact: Option<String>,
        grid: u64,
        block: u64,
        work_us: u64,
    },
    /// Device memory release.
    Free { task: usize, bytes: u64 },
    /// Task complete: scheduler may hand the freed capacity to waiters.
    TaskEnd { task: usize },
    /// Host-side compute phase (no device involvement), microseconds.
    Host { micros: u64 },
}

/// The full trace of one job, plus derived summary numbers.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub events: Vec<TraceEvent>,
}

impl JobTrace {
    /// Number of distinct tasks in the trace.
    pub fn n_tasks(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::TaskBegin { .. }))
            .count()
    }

    /// Total dedicated kernel time (microseconds) across all launches.
    pub fn total_work_us(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                TraceEvent::Launch { work_us, .. } => *work_us,
                _ => 0,
            })
            .sum()
    }

    /// Total host time (microseconds).
    pub fn total_host_us(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                TraceEvent::Host { micros } => *micros,
                _ => 0,
            })
            .sum()
    }

    /// Componentwise-max interference profile over all task probes —
    /// the job-granularity pressure estimate the dispatcher charges a
    /// node with before any of the job's tasks have actually begun
    /// (the per-task vectors refine it at TaskBegin). All-zero for
    /// interference-free traces.
    pub fn peak_interference(&self) -> InterferenceProfile {
        let mut peak = InterferenceProfile::ZERO;
        for e in &self.events {
            if let TraceEvent::TaskBegin { res, .. } = e {
                peak = peak.max(&res.iv);
            }
        }
        peak
    }

    /// Peak simultaneous reserved memory implied by the trace, assuming
    /// each task's reservation is held from TaskBegin to TaskEnd.
    pub fn peak_reserved_bytes(&self) -> u64 {
        let mut cur = 0u64;
        let mut peak = 0u64;
        let mut held: std::collections::HashMap<usize, u64> = Default::default();
        for e in &self.events {
            match e {
                TraceEvent::TaskBegin { task, res } => {
                    held.insert(*task, res.reserve_bytes());
                    cur += res.reserve_bytes();
                    peak = peak.max(cur);
                }
                TraceEvent::TaskEnd { task } => {
                    cur -= held.remove(task).unwrap_or(0);
                }
                _ => {}
            }
        }
        peak
    }

    /// Structural sanity: every task begins once, ends once, and all its
    /// ops sit between the two. Used by tests and debug assertions.
    pub fn check_well_formed(&self) -> Result<(), String> {
        use std::collections::HashMap;
        #[derive(PartialEq)]
        enum S {
            Open,
            Closed,
        }
        let mut state: HashMap<usize, S> = HashMap::new();
        for (i, e) in self.events.iter().enumerate() {
            let t = match e {
                TraceEvent::TaskBegin { task, .. } => {
                    if state.contains_key(task) {
                        return Err(format!("event {i}: task {task} begins twice"));
                    }
                    state.insert(*task, S::Open);
                    continue;
                }
                TraceEvent::TaskEnd { task } => {
                    match state.get(task) {
                        Some(S::Open) => state.insert(*task, S::Closed),
                        _ => return Err(format!("event {i}: end of non-open task {task}")),
                    };
                    continue;
                }
                TraceEvent::Malloc { task, .. }
                | TraceEvent::H2D { task, .. }
                | TraceEvent::D2H { task, .. }
                | TraceEvent::Memset { task, .. }
                | TraceEvent::Launch { task, .. }
                | TraceEvent::Free { task, .. } => *task,
                TraceEvent::Host { .. } => continue,
            };
            if !matches!(state.get(&t), Some(S::Open)) {
                return Err(format!("event {i}: op on non-open task {t}"));
            }
        }
        for (t, s) in &state {
            if *s == S::Open {
                return Err(format!("task {t} never ends"));
            }
        }
        Ok(())
    }

    /// Dynamic conformance: replay the trace against each task's
    /// declared [`TaskResources`] and reject any event that outruns its
    /// declaration — the runtime counterpart of the static
    /// summary-soundness check in `compiler::verify`. Subsumes
    /// [`JobTrace::check_well_formed`] (so "event on an undeclared
    /// task" is also caught), then enforces per open task:
    ///
    /// - cumulative `Malloc` bytes never exceed `reserve_bytes()`
    /// - every `H2D`/`D2H` moves at most `mem_bytes`
    /// - every `Free` returns at most the outstanding allocation
    /// - launch geometry stays within the declared `grid`/`block`
    /// - cumulative written traffic (H2D + Memset) stays within
    ///   `written_bytes` when that bound is tracked (non-zero)
    pub fn check_conformance(&self) -> Result<(), String> {
        self.check_well_formed()?;
        struct Open {
            res: TaskResources,
            allocated: u64,
            outstanding: u64,
            written: u64,
        }
        let mut open: std::collections::HashMap<usize, Open> = Default::default();
        for (i, e) in self.events.iter().enumerate() {
            // check_well_formed proved every op sits in an open task.
            match e {
                TraceEvent::TaskBegin { task, res } => {
                    open.insert(
                        *task,
                        Open { res: *res, allocated: 0, outstanding: 0, written: 0 },
                    );
                }
                TraceEvent::Malloc { task, bytes } => {
                    let o = open.get_mut(task).expect("well-formed");
                    o.allocated += bytes;
                    o.outstanding += bytes;
                    if o.allocated > o.res.reserve_bytes() {
                        return Err(format!(
                            "event {i}: task {task} cumulative malloc {} exceeds \
                             declared reserve {}",
                            o.allocated,
                            o.res.reserve_bytes()
                        ));
                    }
                }
                TraceEvent::H2D { task, bytes } | TraceEvent::Memset { task, bytes } => {
                    let o = open.get_mut(task).expect("well-formed");
                    if *bytes > o.res.mem_bytes {
                        return Err(format!(
                            "event {i}: task {task} transfer of {bytes} bytes exceeds \
                             declared mem_bytes {}",
                            o.res.mem_bytes
                        ));
                    }
                    o.written += bytes;
                    if o.res.written_bytes > 0 && o.written > o.res.written_bytes {
                        return Err(format!(
                            "event {i}: task {task} cumulative written bytes {} exceed \
                             declared written_bytes {}",
                            o.written, o.res.written_bytes
                        ));
                    }
                }
                TraceEvent::D2H { task, bytes } => {
                    let o = open.get(task).expect("well-formed");
                    if *bytes > o.res.mem_bytes {
                        return Err(format!(
                            "event {i}: task {task} d2h of {bytes} bytes exceeds \
                             declared mem_bytes {}",
                            o.res.mem_bytes
                        ));
                    }
                }
                TraceEvent::Launch { task, grid, block, .. } => {
                    let o = open.get(task).expect("well-formed");
                    if *grid > o.res.grid || *block > o.res.block {
                        return Err(format!(
                            "event {i}: task {task} launch geometry {grid}x{block} \
                             exceeds declared {}x{}",
                            o.res.grid, o.res.block
                        ));
                    }
                }
                TraceEvent::Free { task, bytes } => {
                    let o = open.get_mut(task).expect("well-formed");
                    if *bytes > o.outstanding {
                        return Err(format!(
                            "event {i}: task {task} frees {bytes} bytes with only {} \
                             outstanding",
                            o.outstanding
                        ));
                    }
                    o.outstanding -= bytes;
                }
                TraceEvent::TaskEnd { task } => {
                    // Outstanding allocations here are an app-level leak;
                    // the static verifier reports those, and the engine
                    // reclaims the reservation wholesale at TaskEnd.
                    open.remove(task);
                }
                TraceEvent::Host { .. } => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(mem: u64) -> TaskResources {
        TaskResources {
            static_dev: None,
            mem_bytes: mem,
            heap_bytes: 0,
            grid: 8,
            block: 128,
            written_bytes: 2 * mem,
            iv: InterferenceProfile::ZERO,
        }
    }

    #[test]
    fn conformant_trace_passes() {
        let t = JobTrace {
            events: vec![
                TraceEvent::TaskBegin { task: 0, res: res(1024) },
                TraceEvent::Malloc { task: 0, bytes: 1024 },
                TraceEvent::H2D { task: 0, bytes: 1024 },
                TraceEvent::Launch {
                    task: 0,
                    kernel: "k".into(),
                    artifact: None,
                    grid: 8,
                    block: 128,
                    work_us: 10,
                },
                TraceEvent::Free { task: 0, bytes: 1024 },
                TraceEvent::TaskEnd { task: 0 },
            ],
        };
        assert!(t.check_conformance().is_ok());
    }

    #[test]
    fn over_reserve_malloc_is_rejected() {
        let t = JobTrace {
            events: vec![
                TraceEvent::TaskBegin { task: 0, res: res(1024) },
                TraceEvent::Malloc { task: 0, bytes: 4096 },
                TraceEvent::TaskEnd { task: 0 },
            ],
        };
        let err = t.check_conformance().unwrap_err();
        assert!(err.contains("exceeds declared reserve"), "{err}");
    }

    #[test]
    fn oversized_launch_geometry_is_rejected() {
        let t = JobTrace {
            events: vec![
                TraceEvent::TaskBegin { task: 0, res: res(1024) },
                TraceEvent::Launch {
                    task: 0,
                    kernel: "k".into(),
                    artifact: None,
                    grid: 9999,
                    block: 128,
                    work_us: 10,
                },
                TraceEvent::TaskEnd { task: 0 },
            ],
        };
        let err = t.check_conformance().unwrap_err();
        assert!(err.contains("launch geometry"), "{err}");
    }

    #[test]
    fn event_on_undeclared_task_is_rejected() {
        let t = JobTrace {
            events: vec![TraceEvent::Malloc { task: 7, bytes: 64 }],
        };
        assert!(t.check_conformance().is_err());
    }

    #[test]
    fn written_bound_enforced_only_when_tracked() {
        let mut r = res(1024);
        r.written_bytes = 1024; // one H2D's worth
        let t = JobTrace {
            events: vec![
                TraceEvent::TaskBegin { task: 0, res: r },
                TraceEvent::H2D { task: 0, bytes: 1024 },
                TraceEvent::Memset { task: 0, bytes: 1024 }, // over the bound
                TraceEvent::TaskEnd { task: 0 },
            ],
        };
        let err = t.check_conformance().unwrap_err();
        assert!(err.contains("written"), "{err}");
        // Untracked (0) disables the written check but keeps the rest.
        let mut r0 = res(1024);
        r0.written_bytes = 0;
        let t0 = JobTrace {
            events: vec![
                TraceEvent::TaskBegin { task: 0, res: r0 },
                TraceEvent::H2D { task: 0, bytes: 1024 },
                TraceEvent::Memset { task: 0, bytes: 1024 },
                TraceEvent::TaskEnd { task: 0 },
            ],
        };
        assert!(t0.check_conformance().is_ok());
    }
}

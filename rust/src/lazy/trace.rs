//! Trace events: the device-independent operation stream of one job.

use crate::gpu::InterferenceProfile;

/// Resource vector a probe conveys to the scheduler (`task_begin`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskResources {
    /// Device the application statically bound this task to via
    /// cudaSetDevice, if any (honoured only by the `static` scheduler
    /// mode; MGB overrides it — that is the paper's point).
    pub static_dev: Option<u32>,
    /// Global-memory footprint in bytes (sum of the task's allocations).
    pub mem_bytes: u64,
    /// On-device malloc heap (DeviceSetLimit or the 8 MiB default).
    pub heap_bytes: u64,
    /// Thread blocks of the widest member launch.
    pub grid: u64,
    /// Threads per block of the widest member launch.
    pub block: u64,
    /// Upper bound on device bytes the task writes per execution
    /// (member H2D + Memset traffic plus one full store of every
    /// launch-argument buffer). Groundwork for delta checkpoints; `0`
    /// means "not tracked" (legacy/synthetic traces) and disables the
    /// conformance check on written traffic.
    pub written_bytes: u64,
    /// Resource-pressure profile of the task's kernels (memory
    /// bandwidth / L2 / SM occupancy). `ZERO` — the default for every
    /// trace source that predates interference modeling — means the
    /// task neither suffers nor causes contention beyond processor
    /// sharing.
    pub iv: InterferenceProfile,
}

impl TaskResources {
    /// Total device memory the scheduler must reserve.
    pub fn reserve_bytes(&self) -> u64 {
        self.mem_bytes + self.heap_bytes
    }

    /// Warps needed when fully resident: grid * ceil(block / 32).
    pub fn warps(&self) -> u64 {
        self.grid * self.block.div_ceil(32)
    }

    /// Thread blocks requested.
    pub fn thread_blocks(&self) -> u64 {
        self.grid
    }

    /// Warps per thread block.
    pub fn warps_per_tb(&self) -> u64 {
        self.block.div_ceil(32)
    }
}

/// One step of a job's execution, in issue order.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// Probe firing: the scheduler is asked to place task `task`.
    TaskBegin { task: usize, res: TaskResources },
    /// Device memory allocation (bytes) within the current placement.
    Malloc { task: usize, bytes: u64 },
    /// Host-to-device transfer.
    H2D { task: usize, bytes: u64 },
    /// Device-to-host transfer.
    D2H { task: usize, bytes: u64 },
    /// On-device memset.
    Memset { task: usize, bytes: u64 },
    /// Kernel launch. `work_us` is the dedicated-execution time on the
    /// reference device (V100) in microseconds; `artifact` optionally
    /// names a PJRT executable carrying the kernel's real numerics.
    Launch {
        task: usize,
        kernel: String,
        artifact: Option<String>,
        grid: u64,
        block: u64,
        work_us: u64,
    },
    /// Device memory release.
    Free { task: usize, bytes: u64 },
    /// Task complete: scheduler may hand the freed capacity to waiters.
    TaskEnd { task: usize },
    /// Host-side compute phase (no device involvement), microseconds.
    Host { micros: u64 },
}

/// Derived summary numbers of one [`JobTrace`], computed in a single
/// walk and memoized. These feed the engine's per-job load estimates
/// and every dispatcher probe — paths hot enough that re-walking the
/// event vector per call (the old accessor behaviour) showed up at
/// fleet scale.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceSummary {
    /// Distinct tasks in the trace.
    pub n_tasks: usize,
    /// Total dedicated kernel time (microseconds) across all launches.
    pub total_work_us: u64,
    /// Total host time (microseconds).
    pub total_host_us: u64,
    /// Peak simultaneous reserved memory, assuming each task's
    /// reservation is held from TaskBegin to TaskEnd.
    pub peak_reserved_bytes: u64,
    /// Componentwise-max interference profile over all task probes.
    pub peak_interference: InterferenceProfile,
}

impl TraceSummary {
    fn compute(events: &[TraceEvent]) -> Self {
        let mut s = TraceSummary::default();
        let mut cur = 0u64;
        let mut held: std::collections::HashMap<usize, u64> = Default::default();
        for e in events {
            match e {
                TraceEvent::TaskBegin { task, res } => {
                    s.n_tasks += 1;
                    s.peak_interference = s.peak_interference.max(&res.iv);
                    held.insert(*task, res.reserve_bytes());
                    cur += res.reserve_bytes();
                    s.peak_reserved_bytes = s.peak_reserved_bytes.max(cur);
                }
                TraceEvent::TaskEnd { task } => {
                    cur -= held.remove(task).unwrap_or(0);
                }
                TraceEvent::Launch { work_us, .. } => s.total_work_us += work_us,
                TraceEvent::Host { micros } => s.total_host_us += micros,
                _ => {}
            }
        }
        s
    }
}

/// The full trace of one job, plus derived summary numbers.
///
/// The summary and the compiled segment plan are computed once and
/// memoized; clones carry the memo (job batches clone one cached
/// master trace per distinct program, so the walk happens once per
/// *program*, not once per job). `events` stays public for trace
/// builders and in-place stampers — any code that mutates it after a
/// summary may have been read must call
/// [`JobTrace::invalidate_derived`].
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub events: Vec<TraceEvent>,
    summary: std::sync::OnceLock<TraceSummary>,
    compiled: std::sync::OnceLock<std::sync::Arc<super::compile::TraceProgram>>,
}

impl JobTrace {
    /// A trace over `events` with empty (lazily computed) memos.
    pub fn new(events: Vec<TraceEvent>) -> Self {
        JobTrace { events, ..Default::default() }
    }

    /// The memoized one-walk summary.
    pub fn summary(&self) -> &TraceSummary {
        self.summary.get_or_init(|| TraceSummary::compute(&self.events))
    }

    /// The memoized compiled segment plan (see [`super::compile`]).
    /// Clones share it through the `Arc`.
    pub fn compiled(&self) -> &std::sync::Arc<super::compile::TraceProgram> {
        self.compiled
            .get_or_init(|| std::sync::Arc::new(super::compile::compile_trace(&self.events)))
    }

    /// Drop the memoized summary and segment plan after an in-place
    /// mutation of `events` (e.g. interference stamping), so the next
    /// accessor call recomputes from the current events.
    pub fn invalidate_derived(&mut self) {
        self.summary = std::sync::OnceLock::new();
        self.compiled = std::sync::OnceLock::new();
    }

    /// Number of distinct tasks in the trace.
    pub fn n_tasks(&self) -> usize {
        self.summary().n_tasks
    }

    /// Total dedicated kernel time (microseconds) across all launches.
    pub fn total_work_us(&self) -> u64 {
        self.summary().total_work_us
    }

    /// Total host time (microseconds).
    pub fn total_host_us(&self) -> u64 {
        self.summary().total_host_us
    }

    /// Componentwise-max interference profile over all task probes —
    /// the job-granularity pressure estimate the dispatcher charges a
    /// node with before any of the job's tasks have actually begun
    /// (the per-task vectors refine it at TaskBegin). All-zero for
    /// interference-free traces.
    pub fn peak_interference(&self) -> InterferenceProfile {
        self.summary().peak_interference
    }

    /// Peak simultaneous reserved memory implied by the trace, assuming
    /// each task's reservation is held from TaskBegin to TaskEnd.
    pub fn peak_reserved_bytes(&self) -> u64 {
        self.summary().peak_reserved_bytes
    }

    /// Structural sanity: every task begins once, ends once, and all its
    /// ops sit between the two. Used by tests and debug assertions.
    pub fn check_well_formed(&self) -> Result<(), String> {
        use std::collections::HashMap;
        #[derive(PartialEq)]
        enum S {
            Open,
            Closed,
        }
        let mut state: HashMap<usize, S> = HashMap::new();
        for (i, e) in self.events.iter().enumerate() {
            let t = match e {
                TraceEvent::TaskBegin { task, .. } => {
                    if state.contains_key(task) {
                        return Err(format!("event {i}: task {task} begins twice"));
                    }
                    state.insert(*task, S::Open);
                    continue;
                }
                TraceEvent::TaskEnd { task } => {
                    match state.get(task) {
                        Some(S::Open) => state.insert(*task, S::Closed),
                        _ => return Err(format!("event {i}: end of non-open task {task}")),
                    };
                    continue;
                }
                TraceEvent::Malloc { task, .. }
                | TraceEvent::H2D { task, .. }
                | TraceEvent::D2H { task, .. }
                | TraceEvent::Memset { task, .. }
                | TraceEvent::Launch { task, .. }
                | TraceEvent::Free { task, .. } => *task,
                TraceEvent::Host { .. } => continue,
            };
            if !matches!(state.get(&t), Some(S::Open)) {
                return Err(format!("event {i}: op on non-open task {t}"));
            }
        }
        for (t, s) in &state {
            if *s == S::Open {
                return Err(format!("task {t} never ends"));
            }
        }
        Ok(())
    }

    /// Dynamic conformance: replay the trace against each task's
    /// declared [`TaskResources`] and reject any event that outruns its
    /// declaration — the runtime counterpart of the static
    /// summary-soundness check in `compiler::verify`. Subsumes
    /// [`JobTrace::check_well_formed`] (so "event on an undeclared
    /// task" is also caught), then enforces per open task:
    ///
    /// - cumulative `Malloc` bytes never exceed `reserve_bytes()`
    /// - every `H2D`/`D2H` moves at most `mem_bytes`
    /// - every `Free` returns at most the outstanding allocation
    /// - launch geometry stays within the declared `grid`/`block`
    /// - cumulative written traffic (H2D + Memset) stays within
    ///   `written_bytes` when that bound is tracked (non-zero)
    pub fn check_conformance(&self) -> Result<(), String> {
        self.check_well_formed()?;
        struct Open {
            res: TaskResources,
            allocated: u64,
            outstanding: u64,
            written: u64,
        }
        let mut open: std::collections::HashMap<usize, Open> = Default::default();
        for (i, e) in self.events.iter().enumerate() {
            // check_well_formed proved every op sits in an open task.
            match e {
                TraceEvent::TaskBegin { task, res } => {
                    open.insert(
                        *task,
                        Open { res: *res, allocated: 0, outstanding: 0, written: 0 },
                    );
                }
                TraceEvent::Malloc { task, bytes } => {
                    let o = open.get_mut(task).expect("well-formed");
                    o.allocated += bytes;
                    o.outstanding += bytes;
                    if o.allocated > o.res.reserve_bytes() {
                        return Err(format!(
                            "event {i}: task {task} cumulative malloc {} exceeds \
                             declared reserve {}",
                            o.allocated,
                            o.res.reserve_bytes()
                        ));
                    }
                }
                TraceEvent::H2D { task, bytes } | TraceEvent::Memset { task, bytes } => {
                    let o = open.get_mut(task).expect("well-formed");
                    if *bytes > o.res.mem_bytes {
                        return Err(format!(
                            "event {i}: task {task} transfer of {bytes} bytes exceeds \
                             declared mem_bytes {}",
                            o.res.mem_bytes
                        ));
                    }
                    o.written += bytes;
                    if o.res.written_bytes > 0 && o.written > o.res.written_bytes {
                        return Err(format!(
                            "event {i}: task {task} cumulative written bytes {} exceed \
                             declared written_bytes {}",
                            o.written, o.res.written_bytes
                        ));
                    }
                }
                TraceEvent::D2H { task, bytes } => {
                    let o = open.get(task).expect("well-formed");
                    if *bytes > o.res.mem_bytes {
                        return Err(format!(
                            "event {i}: task {task} d2h of {bytes} bytes exceeds \
                             declared mem_bytes {}",
                            o.res.mem_bytes
                        ));
                    }
                }
                TraceEvent::Launch { task, grid, block, .. } => {
                    let o = open.get(task).expect("well-formed");
                    if *grid > o.res.grid || *block > o.res.block {
                        return Err(format!(
                            "event {i}: task {task} launch geometry {grid}x{block} \
                             exceeds declared {}x{}",
                            o.res.grid, o.res.block
                        ));
                    }
                }
                TraceEvent::Free { task, bytes } => {
                    let o = open.get_mut(task).expect("well-formed");
                    if *bytes > o.outstanding {
                        return Err(format!(
                            "event {i}: task {task} frees {bytes} bytes with only {} \
                             outstanding",
                            o.outstanding
                        ));
                    }
                    o.outstanding -= bytes;
                }
                TraceEvent::TaskEnd { task } => {
                    // Outstanding allocations here are an app-level leak;
                    // the static verifier reports those, and the engine
                    // reclaims the reservation wholesale at TaskEnd.
                    open.remove(task);
                }
                TraceEvent::Host { .. } => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(mem: u64) -> TaskResources {
        TaskResources {
            static_dev: None,
            mem_bytes: mem,
            heap_bytes: 0,
            grid: 8,
            block: 128,
            written_bytes: 2 * mem,
            iv: InterferenceProfile::ZERO,
        }
    }

    #[test]
    fn conformant_trace_passes() {
        let t = JobTrace::new(vec![
                TraceEvent::TaskBegin { task: 0, res: res(1024) },
                TraceEvent::Malloc { task: 0, bytes: 1024 },
                TraceEvent::H2D { task: 0, bytes: 1024 },
                TraceEvent::Launch {
                    task: 0,
                    kernel: "k".into(),
                    artifact: None,
                    grid: 8,
                    block: 128,
                    work_us: 10,
                },
                TraceEvent::Free { task: 0, bytes: 1024 },
                TraceEvent::TaskEnd { task: 0 },
            ]);
        assert!(t.check_conformance().is_ok());
    }

    #[test]
    fn over_reserve_malloc_is_rejected() {
        let t = JobTrace::new(vec![
                TraceEvent::TaskBegin { task: 0, res: res(1024) },
                TraceEvent::Malloc { task: 0, bytes: 4096 },
                TraceEvent::TaskEnd { task: 0 },
            ]);
        let err = t.check_conformance().unwrap_err();
        assert!(err.contains("exceeds declared reserve"), "{err}");
    }

    #[test]
    fn oversized_launch_geometry_is_rejected() {
        let t = JobTrace::new(vec![
                TraceEvent::TaskBegin { task: 0, res: res(1024) },
                TraceEvent::Launch {
                    task: 0,
                    kernel: "k".into(),
                    artifact: None,
                    grid: 9999,
                    block: 128,
                    work_us: 10,
                },
                TraceEvent::TaskEnd { task: 0 },
            ]);
        let err = t.check_conformance().unwrap_err();
        assert!(err.contains("launch geometry"), "{err}");
    }

    #[test]
    fn event_on_undeclared_task_is_rejected() {
        let t = JobTrace::new(vec![TraceEvent::Malloc { task: 7, bytes: 64 }]);
        assert!(t.check_conformance().is_err());
    }

    #[test]
    fn written_bound_enforced_only_when_tracked() {
        let mut r = res(1024);
        r.written_bytes = 1024; // one H2D's worth
        let t = JobTrace::new(vec![
                TraceEvent::TaskBegin { task: 0, res: r },
                TraceEvent::H2D { task: 0, bytes: 1024 },
                TraceEvent::Memset { task: 0, bytes: 1024 }, // over the bound
                TraceEvent::TaskEnd { task: 0 },
            ]);
        let err = t.check_conformance().unwrap_err();
        assert!(err.contains("written"), "{err}");
        // Untracked (0) disables the written check but keeps the rest.
        let mut r0 = res(1024);
        r0.written_bytes = 0;
        let t0 = JobTrace::new(vec![
                TraceEvent::TaskBegin { task: 0, res: r0 },
                TraceEvent::H2D { task: 0, bytes: 1024 },
                TraceEvent::Memset { task: 0, bytes: 1024 },
                TraceEvent::TaskEnd { task: 0 },
            ]);
        assert!(t0.check_conformance().is_ok());
    }

    #[test]
    fn summary_is_one_walk_and_matches_accessors() {
        let t = JobTrace::new(vec![
            TraceEvent::TaskBegin { task: 0, res: res(1024) },
            TraceEvent::Launch {
                task: 0,
                kernel: "k".into(),
                artifact: None,
                grid: 8,
                block: 128,
                work_us: 10,
            },
            TraceEvent::Host { micros: 5 },
            TraceEvent::TaskEnd { task: 0 },
            TraceEvent::TaskBegin { task: 1, res: res(2048) },
            TraceEvent::TaskEnd { task: 1 },
        ]);
        let s = *t.summary();
        assert_eq!(s.n_tasks, 2);
        assert_eq!(s.total_work_us, 10);
        assert_eq!(s.total_host_us, 5);
        // Tasks do not overlap: peak is the larger single reservation.
        assert_eq!(s.peak_reserved_bytes, 2048);
        assert_eq!(t.n_tasks(), s.n_tasks);
        assert_eq!(t.total_work_us(), s.total_work_us);
        assert_eq!(t.total_host_us(), s.total_host_us);
        assert_eq!(t.peak_reserved_bytes(), s.peak_reserved_bytes);
        assert_eq!(t.peak_interference(), s.peak_interference);
        // The memo is stable (same pointer on every call)...
        assert!(std::ptr::eq(t.summary(), t.summary()));
        // ...and clones carry it without recomputing.
        let c = t.clone();
        assert_eq!(*c.summary(), s);
    }

    #[test]
    fn invalidate_derived_recomputes_after_mutation() {
        let mut t = JobTrace::new(vec![
            TraceEvent::TaskBegin { task: 0, res: res(1024) },
            TraceEvent::TaskEnd { task: 0 },
        ]);
        assert!(t.peak_interference().is_zero());
        // In-place stamp (what workloads::assign_interference does).
        if let TraceEvent::TaskBegin { res, .. } = &mut t.events[0] {
            res.iv = InterferenceProfile::new(0.5, 0.1, 0.2);
        }
        t.invalidate_derived();
        assert!(!t.peak_interference().is_zero(), "memo must not go stale");
    }
}

//! Trace events: the device-independent operation stream of one job.

use crate::gpu::InterferenceProfile;

/// Resource vector a probe conveys to the scheduler (`task_begin`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskResources {
    /// Device the application statically bound this task to via
    /// cudaSetDevice, if any (honoured only by the `static` scheduler
    /// mode; MGB overrides it — that is the paper's point).
    pub static_dev: Option<u32>,
    /// Global-memory footprint in bytes (sum of the task's allocations).
    pub mem_bytes: u64,
    /// On-device malloc heap (DeviceSetLimit or the 8 MiB default).
    pub heap_bytes: u64,
    /// Thread blocks of the widest member launch.
    pub grid: u64,
    /// Threads per block of the widest member launch.
    pub block: u64,
    /// Resource-pressure profile of the task's kernels (memory
    /// bandwidth / L2 / SM occupancy). `ZERO` — the default for every
    /// trace source that predates interference modeling — means the
    /// task neither suffers nor causes contention beyond processor
    /// sharing.
    pub iv: InterferenceProfile,
}

impl TaskResources {
    /// Total device memory the scheduler must reserve.
    pub fn reserve_bytes(&self) -> u64 {
        self.mem_bytes + self.heap_bytes
    }

    /// Warps needed when fully resident: grid * ceil(block / 32).
    pub fn warps(&self) -> u64 {
        self.grid * self.block.div_ceil(32)
    }

    /// Thread blocks requested.
    pub fn thread_blocks(&self) -> u64 {
        self.grid
    }

    /// Warps per thread block.
    pub fn warps_per_tb(&self) -> u64 {
        self.block.div_ceil(32)
    }
}

/// One step of a job's execution, in issue order.
#[derive(Clone, Debug)]
pub enum TraceEvent {
    /// Probe firing: the scheduler is asked to place task `task`.
    TaskBegin { task: usize, res: TaskResources },
    /// Device memory allocation (bytes) within the current placement.
    Malloc { task: usize, bytes: u64 },
    /// Host-to-device transfer.
    H2D { task: usize, bytes: u64 },
    /// Device-to-host transfer.
    D2H { task: usize, bytes: u64 },
    /// On-device memset.
    Memset { task: usize, bytes: u64 },
    /// Kernel launch. `work_us` is the dedicated-execution time on the
    /// reference device (V100) in microseconds; `artifact` optionally
    /// names a PJRT executable carrying the kernel's real numerics.
    Launch {
        task: usize,
        kernel: String,
        artifact: Option<String>,
        grid: u64,
        block: u64,
        work_us: u64,
    },
    /// Device memory release.
    Free { task: usize, bytes: u64 },
    /// Task complete: scheduler may hand the freed capacity to waiters.
    TaskEnd { task: usize },
    /// Host-side compute phase (no device involvement), microseconds.
    Host { micros: u64 },
}

/// The full trace of one job, plus derived summary numbers.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub events: Vec<TraceEvent>,
}

impl JobTrace {
    /// Number of distinct tasks in the trace.
    pub fn n_tasks(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TraceEvent::TaskBegin { .. }))
            .count()
    }

    /// Total dedicated kernel time (microseconds) across all launches.
    pub fn total_work_us(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                TraceEvent::Launch { work_us, .. } => *work_us,
                _ => 0,
            })
            .sum()
    }

    /// Total host time (microseconds).
    pub fn total_host_us(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                TraceEvent::Host { micros } => *micros,
                _ => 0,
            })
            .sum()
    }

    /// Componentwise-max interference profile over all task probes —
    /// the job-granularity pressure estimate the dispatcher charges a
    /// node with before any of the job's tasks have actually begun
    /// (the per-task vectors refine it at TaskBegin). All-zero for
    /// interference-free traces.
    pub fn peak_interference(&self) -> InterferenceProfile {
        let mut peak = InterferenceProfile::ZERO;
        for e in &self.events {
            if let TraceEvent::TaskBegin { res, .. } = e {
                peak = peak.max(&res.iv);
            }
        }
        peak
    }

    /// Peak simultaneous reserved memory implied by the trace, assuming
    /// each task's reservation is held from TaskBegin to TaskEnd.
    pub fn peak_reserved_bytes(&self) -> u64 {
        let mut cur = 0u64;
        let mut peak = 0u64;
        let mut held: std::collections::HashMap<usize, u64> = Default::default();
        for e in &self.events {
            match e {
                TraceEvent::TaskBegin { task, res } => {
                    held.insert(*task, res.reserve_bytes());
                    cur += res.reserve_bytes();
                    peak = peak.max(cur);
                }
                TraceEvent::TaskEnd { task } => {
                    cur -= held.remove(task).unwrap_or(0);
                }
                _ => {}
            }
        }
        peak
    }

    /// Structural sanity: every task begins once, ends once, and all its
    /// ops sit between the two. Used by tests and debug assertions.
    pub fn check_well_formed(&self) -> Result<(), String> {
        use std::collections::HashMap;
        #[derive(PartialEq)]
        enum S {
            Open,
            Closed,
        }
        let mut state: HashMap<usize, S> = HashMap::new();
        for (i, e) in self.events.iter().enumerate() {
            let t = match e {
                TraceEvent::TaskBegin { task, .. } => {
                    if state.contains_key(task) {
                        return Err(format!("event {i}: task {task} begins twice"));
                    }
                    state.insert(*task, S::Open);
                    continue;
                }
                TraceEvent::TaskEnd { task } => {
                    match state.get(task) {
                        Some(S::Open) => state.insert(*task, S::Closed),
                        _ => return Err(format!("event {i}: end of non-open task {task}")),
                    };
                    continue;
                }
                TraceEvent::Malloc { task, .. }
                | TraceEvent::H2D { task, .. }
                | TraceEvent::D2H { task, .. }
                | TraceEvent::Memset { task, .. }
                | TraceEvent::Launch { task, .. }
                | TraceEvent::Free { task, .. } => *task,
                TraceEvent::Host { .. } => continue,
            };
            if !matches!(state.get(&t), Some(S::Open)) {
                return Err(format!("event {i}: op on non-open task {t}"));
            }
        }
        for (t, s) in &state {
            if *s == S::Open {
                return Err(format!("task {t} never ends"));
            }
        }
        Ok(())
    }
}

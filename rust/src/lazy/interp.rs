//! The interpreter + lazy runtime proper.

use super::trace::{JobTrace, TaskResources, TraceEvent};
use crate::gpu::InterferenceProfile;
use crate::compiler::CompiledProgram;
use crate::ir::{CopyDir, Expr, Function, Op, OpKind, Terminator, ValueId};
use std::collections::HashMap;

/// Default on-device heap (matches `compiler::tasks::DEFAULT_DEVICE_HEAP`).
const DEFAULT_HEAP: u64 = 8 << 20;

#[derive(Debug)]
pub enum InterpError {
    /// A scalar expression referenced a memory object or vice versa.
    TypeConfusion(String),
    /// Value read before any definition executed (invalid program).
    Undefined(ValueId),
    /// Run-away execution guard tripped.
    StepLimit,
}

impl std::fmt::Display for InterpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InterpError::TypeConfusion(s) => write!(f, "type confusion: {s}"),
            InterpError::Undefined(v) => write!(f, "undefined value v{v}"),
            InterpError::StepLimit => write!(f, "step limit exceeded"),
        }
    }
}

impl std::error::Error for InterpError {}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Value {
    Scalar(i64),
    Obj(usize),
}

/// Queued (lazily bound) GPU operation on one pseudo-addressed object.
#[derive(Clone, Debug)]
enum Queued {
    Malloc { bytes: u64 },
    H2D { bytes: u64 },
    D2H { bytes: u64 },
    Memset { bytes: u64 },
}

#[derive(Debug, Default)]
struct ObjState {
    bytes: u64,
    queued: Vec<Queued>,
    /// Owning runtime task once bound (static task id or dynamic id).
    task: Option<usize>,
    allocated: bool,
    freed: bool,
}

#[derive(Debug, Default)]
struct TaskState {
    began: bool,
    launches: usize,
    open_objs: usize,
    ended: bool,
}

struct Interp<'a> {
    c: &'a CompiledProgram,
    trace: JobTrace,
    objs: Vec<ObjState>,
    tasks: HashMap<usize, TaskState>,
    next_dyn_task: usize,
    heap_limit: u64,
    /// Last cudaSetDevice value (None until the app calls it).
    cur_device: Option<u32>,
    steps: usize,
    /// op id -> static task id, for non-lazy tasks only.
    static_op_task: HashMap<u32, usize>,
    /// probe location (block, idx) -> static task id (entry function).
    probes: HashMap<(u32, usize), usize>,
}

const STEP_LIMIT: usize = 50_000_000;

/// Execute the compiled program's entry with `params`, producing the
/// job's device-independent operation trace.
pub fn interpret(c: &CompiledProgram, params: &[i64]) -> Result<JobTrace, InterpError> {
    let mut static_op_task = HashMap::new();
    let mut probes = HashMap::new();
    for t in &c.tasks {
        if t.lazy {
            continue;
        }
        for &o in &t.ops {
            static_op_task.insert(o, t.id);
        }
        if let Some(loc) = t.probe_at {
            probes.insert(loc, t.id);
        }
    }
    let mut it = Interp {
        c,
        trace: JobTrace::default(),
        objs: Vec::new(),
        tasks: HashMap::new(),
        next_dyn_task: c.tasks.len(),
        heap_limit: DEFAULT_HEAP,
        cur_device: None,
        steps: 0,
        static_op_task,
        probes,
    };
    let main = c.program.main();
    let env: Vec<Option<Value>> = params
        .iter()
        .map(|&p| Some(Value::Scalar(p)))
        .chain((params.len()..main.n_values as usize).map(|_| None))
        .collect();
    it.run_function(main, env, true)?;
    it.finish();
    Ok(it.trace)
}

impl<'a> Interp<'a> {
    fn run_function(
        &mut self,
        f: &Function,
        mut env: Vec<Option<Value>>,
        is_entry: bool,
    ) -> Result<(), InterpError> {
        let mut block = 0u32;
        // Loop trip budgets keyed by block; re-initialised after exit.
        let mut trips: HashMap<u32, i64> = HashMap::new();
        loop {
            let blk = &f.blocks[block as usize];
            for (i, op) in blk.ops.iter().enumerate() {
                self.steps += 1;
                if self.steps > STEP_LIMIT {
                    return Err(InterpError::StepLimit);
                }
                if is_entry {
                    if let Some(&task) = self.probes.get(&(block, i)) {
                        self.fire_probe(f, &env, task)?;
                    }
                }
                self.exec_op(f, &mut env, op)?;
            }
            match &blk.term {
                Terminator::Br(t) => block = *t,
                Terminator::CondBr { trips: tv, taken, fallthrough } => {
                    let remaining = match trips.get(&block) {
                        Some(&r) => r,
                        None => {
                            let n = self.eval_scalar(f, &env, &Expr::v(*tv))?;
                            trips.insert(block, n);
                            n
                        }
                    };
                    if remaining > 0 {
                        trips.insert(block, remaining - 1);
                        block = *taken;
                    } else {
                        trips.remove(&block);
                        block = *fallthrough;
                    }
                }
                Terminator::Ret => return Ok(()),
            }
        }
    }

    fn exec_op(
        &mut self,
        f: &Function,
        env: &mut Vec<Option<Value>>,
        op: &Op,
    ) -> Result<(), InterpError> {
        match &op.kind {
            OpKind::Assign { expr } => {
                let v = self.eval_expr(f, env, expr)?;
                env[op.result.unwrap() as usize] = Some(Value::Scalar(v));
            }
            OpKind::Malloc { bytes } => {
                let bytes = self.eval_scalar(f, env, &Expr::v(*bytes))? as u64;
                let obj = self.objs.len();
                self.objs.push(ObjState { bytes, ..Default::default() });
                env[op.result.unwrap() as usize] = Some(Value::Obj(obj));
                if let Some(&task) = self.static_op_task.get(&op.id) {
                    self.bind_obj(obj, task);
                    self.objs[obj].allocated = true;
                    self.tasks.entry(task).or_default().open_objs += 1;
                    self.emit(TraceEvent::Malloc { task, bytes });
                } else {
                    self.objs[obj].queued.push(Queued::Malloc { bytes });
                }
            }
            OpKind::Memcpy { obj, bytes, dir } => {
                let o = self.obj_of(env, *obj)?;
                let bytes = self.eval_scalar(f, env, &Expr::v(*bytes))? as u64;
                let ev = |task| match dir {
                    CopyDir::HostToDevice => TraceEvent::H2D { task, bytes },
                    CopyDir::DeviceToHost => TraceEvent::D2H { task, bytes },
                };
                match self.owning_task(op.id, o) {
                    Some(task) => self.emit(ev(task)),
                    None => self.objs[o].queued.push(match dir {
                        CopyDir::HostToDevice => Queued::H2D { bytes },
                        CopyDir::DeviceToHost => Queued::D2H { bytes },
                    }),
                }
            }
            OpKind::Memset { obj, bytes } => {
                let o = self.obj_of(env, *obj)?;
                let bytes = self.eval_scalar(f, env, &Expr::v(*bytes))? as u64;
                match self.owning_task(op.id, o) {
                    Some(task) => self.emit(TraceEvent::Memset { task, bytes }),
                    None => self.objs[o].queued.push(Queued::Memset { bytes }),
                }
            }
            OpKind::Free { obj } => {
                let o = self.obj_of(env, *obj)?;
                match self.owning_task(op.id, o) {
                    Some(task) => {
                        let bytes = self.objs[o].bytes;
                        if self.objs[o].allocated && !self.objs[o].freed {
                            self.objs[o].freed = true;
                            self.emit(TraceEvent::Free { task, bytes });
                            let st = self.tasks.entry(task).or_default();
                            st.open_objs = st.open_objs.saturating_sub(1);
                            if st.open_objs == 0 && st.launches > 0 && st.began && !st.ended {
                                st.ended = true;
                                self.emit(TraceEvent::TaskEnd { task });
                            }
                        }
                    }
                    None => {
                        // Freed before any launch bound it: drop the
                        // queued ops — the computation never touched a
                        // device (dead allocation).
                        self.objs[o].queued.clear();
                        self.objs[o].freed = true;
                    }
                }
            }
            OpKind::Launch { kernel, grid, block, args, work, artifact } => {
                let grid_v = self.eval_scalar(f, env, &Expr::v(*grid))? as u64;
                let block_v = self.eval_scalar(f, env, &Expr::v(*block))? as u64;
                let work_v = self.eval_scalar(f, env, &Expr::v(*work))? as u64;
                let task = if let Some(&t) = self.static_op_task.get(&op.id) {
                    t
                } else {
                    self.kernel_launch_prepare(env, args, grid_v, block_v)?
                };
                let st = self.tasks.entry(task).or_default();
                st.launches += 1;
                self.emit(TraceEvent::Launch {
                    task,
                    kernel: kernel.clone(),
                    artifact: artifact.clone(),
                    grid: grid_v,
                    block: block_v,
                    work_us: work_v,
                });
            }
            OpKind::DeviceSetLimit { bytes } => {
                self.heap_limit = self.eval_scalar(f, env, &Expr::v(*bytes))? as u64;
            }
            OpKind::SetDevice { dev } => {
                self.cur_device = Some(self.eval_scalar(f, env, &Expr::v(*dev))? as u32);
            }
            OpKind::Call { callee, args } => {
                let callee_f = &self.c.program.funcs[*callee as usize];
                let mut cenv: Vec<Option<Value>> = Vec::with_capacity(callee_f.n_values as usize);
                for &a in args {
                    cenv.push(Some(self.value(env, a)?));
                }
                cenv.resize(callee_f.n_values as usize, None);
                self.run_function(callee_f, cenv, false)?;
            }
            OpKind::HostCompute { micros } => {
                let us = self.eval_scalar(f, env, &Expr::v(*micros))? as u64;
                self.emit(TraceEvent::Host { micros: us });
            }
        }
        Ok(())
    }

    /// kernelLaunchPrepare: bind queued ops of the launch's memory
    /// objects to a task, emitting TaskBegin + the replayed queue.
    fn kernel_launch_prepare(
        &mut self,
        env: &[Option<Value>],
        args: &[ValueId],
        grid: u64,
        block: u64,
    ) -> Result<usize, InterpError> {
        let mut objs = Vec::new();
        for &a in args {
            objs.push(self.obj_of(env, a)?);
        }
        // Reuse an open task already owning one of the objects.
        let existing = objs.iter().find_map(|&o| {
            self.objs[o]
                .task
                .filter(|t| self.tasks.get(t).map(|s| !s.ended).unwrap_or(false))
        });
        let task = existing.unwrap_or_else(|| {
            let t = self.next_dyn_task;
            self.next_dyn_task += 1;
            t
        });
        if existing.is_none() {
            // Resource vector from the pending allocations.
            let mem: u64 = objs
                .iter()
                .map(|&o| {
                    self.objs[o]
                        .queued
                        .iter()
                        .map(|q| match q {
                            Queued::Malloc { bytes } => *bytes,
                            _ => 0,
                        })
                        .sum::<u64>()
                })
                .sum();
            // Written bound: queued stores (H2D/Memset) plus one full
            // write of every argument buffer — mirrors the static
            // written-bytes analysis in `compiler::tasks`.
            let stores: u64 = objs
                .iter()
                .map(|&o| {
                    self.objs[o]
                        .queued
                        .iter()
                        .map(|q| match q {
                            Queued::H2D { bytes } | Queued::Memset { bytes } => *bytes,
                            _ => 0,
                        })
                        .sum::<u64>()
                })
                .sum();
            let res = TaskResources {
                static_dev: self.cur_device,
                mem_bytes: mem,
                heap_bytes: self.heap_limit,
                grid,
                block,
                written_bytes: mem + stores,
                iv: InterferenceProfile::ZERO,
            };
            self.emit(TraceEvent::TaskBegin { task, res });
            self.tasks.entry(task).or_default().began = true;
        }
        // Replay queues of newly-bound objects.
        for &o in &objs {
            if self.objs[o].task.is_some() {
                continue;
            }
            self.bind_obj(o, task);
            let queued = std::mem::take(&mut self.objs[o].queued);
            for q in queued {
                match q {
                    Queued::Malloc { bytes } => {
                        self.objs[o].allocated = true;
                        self.tasks.entry(task).or_default().open_objs += 1;
                        self.emit(TraceEvent::Malloc { task, bytes });
                    }
                    Queued::H2D { bytes } => self.emit(TraceEvent::H2D { task, bytes }),
                    Queued::D2H { bytes } => self.emit(TraceEvent::D2H { task, bytes }),
                    Queued::Memset { bytes } => self.emit(TraceEvent::Memset { task, bytes }),
                }
            }
        }
        Ok(task)
    }

    /// The task an op on object `o` belongs to right now, if bound.
    fn owning_task(&mut self, op_id: u32, o: usize) -> Option<usize> {
        if let Some(&t) = self.static_op_task.get(&op_id) {
            // Static op: its object is (or will be) bound to this task.
            if self.objs[o].task.is_none() {
                self.bind_obj(o, t);
            }
            return Some(t);
        }
        self.objs[o]
            .task
            .filter(|t| self.tasks.get(t).map(|s| !s.ended).unwrap_or(false))
    }

    fn bind_obj(&mut self, o: usize, task: usize) {
        self.objs[o].task = Some(task);
    }

    /// Fire a static probe: interpret the task's symbolic resources.
    fn fire_probe(
        &mut self,
        f: &Function,
        env: &[Option<Value>],
        task: usize,
    ) -> Result<(), InterpError> {
        let st = self.tasks.entry(task).or_default();
        if st.began {
            return Ok(());
        }
        st.began = true;
        let t = &self.c.tasks[task];
        let res = TaskResources {
            static_dev: self.cur_device,
            mem_bytes: self.eval_expr(f, env, &t.mem_bytes)? as u64,
            heap_bytes: self.eval_expr(f, env, &t.heap_bytes)? as u64,
            grid: self.eval_expr(f, env, &t.grid)? as u64,
            block: self.eval_expr(f, env, &t.block)? as u64,
            written_bytes: self.eval_expr(f, env, &t.written_bytes)? as u64,
            iv: InterferenceProfile::ZERO,
        };
        self.emit(TraceEvent::TaskBegin { task, res });
        Ok(())
    }

    /// Close any still-open tasks at process exit (CUDA frees device
    /// state when the process ends).
    fn finish(&mut self) {
        let mut open: Vec<usize> = self
            .tasks
            .iter()
            .filter(|(_, s)| s.began && !s.ended)
            .map(|(&t, _)| t)
            .collect();
        open.sort_unstable();
        for t in open {
            self.tasks.get_mut(&t).unwrap().ended = true;
            self.emit(TraceEvent::TaskEnd { task: t });
        }
    }

    fn emit(&mut self, e: TraceEvent) {
        self.trace.events.push(e);
    }

    fn value(&self, env: &[Option<Value>], v: ValueId) -> Result<Value, InterpError> {
        env.get(v as usize)
            .copied()
            .flatten()
            .ok_or(InterpError::Undefined(v))
    }

    fn obj_of(&self, env: &[Option<Value>], v: ValueId) -> Result<usize, InterpError> {
        match self.value(env, v)? {
            Value::Obj(o) => Ok(o),
            Value::Scalar(_) => Err(InterpError::TypeConfusion(format!(
                "v{v} used as memory object but holds a scalar"
            ))),
        }
    }

    /// Evaluate an expression; values not yet executed are computed
    /// on demand through their (pure Assign) defs — this is exactly the
    /// probe "interpreting symbols" (§III-A1).
    fn eval_expr(
        &self,
        f: &Function,
        env: &[Option<Value>],
        e: &Expr,
    ) -> Result<i64, InterpError> {
        Ok(match e {
            Expr::Const(c) => *c,
            Expr::Value(v) => match env.get(*v as usize).copied().flatten() {
                Some(Value::Scalar(s)) => s,
                Some(Value::Obj(_)) => {
                    return Err(InterpError::TypeConfusion(format!(
                        "v{v} used as scalar but holds an object"
                    )))
                }
                None => {
                    // Hoisted evaluation through the pure def.
                    let (op, _, _) = f
                        .ops()
                        .find(|(_, _, o)| o.result == Some(*v))
                        .map(|(_, _, o)| (o, 0, 0))
                        .ok_or(InterpError::Undefined(*v))?;
                    match &op.kind {
                        OpKind::Assign { expr } => self.eval_expr(f, env, expr)?,
                        _ => return Err(InterpError::Undefined(*v)),
                    }
                }
            },
            Expr::Add(a, b) => self.eval_expr(f, env, a)? + self.eval_expr(f, env, b)?,
            Expr::Sub(a, b) => self.eval_expr(f, env, a)? - self.eval_expr(f, env, b)?,
            Expr::Mul(a, b) => self.eval_expr(f, env, a)? * self.eval_expr(f, env, b)?,
            Expr::CeilDiv(a, b) => {
                let (a, b) = (self.eval_expr(f, env, a)?, self.eval_expr(f, env, b)?);
                if b == 0 {
                    0
                } else {
                    (a + b - 1) / b
                }
            }
            Expr::Max(a, b) => self.eval_expr(f, env, a)?.max(self.eval_expr(f, env, b)?),
            Expr::Min(a, b) => self.eval_expr(f, env, a)?.min(self.eval_expr(f, env, b)?),
        })
    }

    fn eval_scalar(
        &self,
        f: &Function,
        env: &[Option<Value>],
        e: &Expr,
    ) -> Result<i64, InterpError> {
        self.eval_expr(f, env, e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::ir::{Expr, ProgramBuilder};

    fn vecadd() -> CompiledProgram {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let da = f.malloc(sz);
            let db = f.malloc(sz);
            let dc = f.malloc(sz);
            f.h2d(da, sz);
            f.h2d(db, sz);
            let grid = f.assign(Expr::v(n).ceil_div(Expr::c(128)));
            let block = f.c(128);
            let work = f.c(1_000);
            f.launch("VecAdd", grid, block, &[da, db, dc], work);
            f.d2h(dc, sz);
            f.free(da);
            f.free(db);
            f.free(dc);
        });
        compile(&pb.finish())
    }

    #[test]
    fn static_vecadd_trace_is_well_formed() {
        let trace = interpret(&vecadd(), &[1 << 20]).unwrap();
        trace.check_well_formed().unwrap();
        assert_eq!(trace.n_tasks(), 1);
        // probe fires before any device op
        assert!(matches!(trace.events[0], TraceEvent::TaskBegin { .. }));
        let TraceEvent::TaskBegin { res, .. } = trace.events[0] else {
            unreachable!()
        };
        assert_eq!(res.mem_bytes, 3 * 4 * (1 << 20));
        assert_eq!(res.grid, (1 << 20) / 128);
        assert_eq!(res.block, 128);
        assert_eq!(res.warps(), ((1 << 20) / 128) * 4);
        // 3 mallocs, 2 h2d, 1 launch, 1 d2h, 3 free, end
        assert_eq!(trace.events.len(), 1 + 3 + 2 + 1 + 1 + 3 + 1);
        assert!(matches!(trace.events.last(), Some(TraceEvent::TaskEnd { .. })));
    }

    #[test]
    fn lazy_branch_guarded_task_binds_at_launch() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            f.launch("k", g, b, &[a], w);
            let cond = f.c(1);
            f.diamond(cond, |f| f.d2h(a, sz), |_| {});
            f.free(a);
        });
        let c = compile(&pb.finish());
        assert!(c.tasks[0].lazy);
        let trace = interpret(&c, &[4096]).unwrap();
        trace.check_well_formed().unwrap();
        assert_eq!(trace.n_tasks(), 1);
        // TaskBegin arrives before the launch, carrying the malloc bytes
        let begin_pos = trace
            .events
            .iter()
            .position(|e| matches!(e, TraceEvent::TaskBegin { .. }))
            .unwrap();
        let launch_pos = trace
            .events
            .iter()
            .position(|e| matches!(e, TraceEvent::Launch { .. }))
            .unwrap();
        assert!(begin_pos < launch_pos);
        let TraceEvent::TaskBegin { res, .. } = trace.events[begin_pos] else {
            unreachable!()
        };
        assert_eq!(res.mem_bytes, 4096 * 4);
        // the branch-guarded d2h executed and landed in the open task
        assert!(trace.events.iter().any(|e| matches!(e, TraceEvent::D2H { .. })));
    }

    #[test]
    fn loop_task_launches_per_iteration() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 2, |f| {
            let n = f.param(0);
            let iters = f.param(1);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let img = f.malloc(sz);
            f.h2d(img, sz);
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            f.loop_n(iters, |f| {
                f.launch("srad1", g, b, &[img], w);
                f.launch("srad2", g, b, &[img], w);
            });
            f.d2h(img, sz);
            f.free(img);
        });
        let trace = interpret(&compile(&pb.finish()), &[4096, 10]).unwrap();
        trace.check_well_formed().unwrap();
        assert_eq!(trace.n_tasks(), 1);
        let launches = trace
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Launch { .. }))
            .count();
        assert_eq!(launches, 20);
        assert_eq!(trace.total_work_us(), 20 * 500);
    }

    #[test]
    fn gpu_ops_inside_uninlined_callee_go_lazy_and_bind() {
        // A looping helper that mallocs + launches internally: inlining
        // skips it, the lazy runtime binds everything at launch time.
        let mut pb = ProgramBuilder::new();
        let helper = pb.declare("helper", 2);
        pb.define(helper, |f| {
            let sz = f.param(0);
            let iters = f.param(1);
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let g = f.c(32);
            let b = f.c(128);
            let w = f.c(250);
            f.loop_n(iters, |f| {
                f.launch("inner", g, b, &[a], w);
            });
            f.free(a);
        });
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(8)));
            let it = f.c(3);
            f.call(helper, &[sz, it]);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 0, "no static task visible in main");
        let trace = interpret(&c, &[1024]).unwrap();
        trace.check_well_formed().unwrap();
        assert_eq!(trace.n_tasks(), 1, "dynamic task formed at launch");
        let TraceEvent::TaskBegin { res, .. } = trace.events[0] else {
            panic!("expected dynamic TaskBegin first, got {:?}", trace.events[0])
        };
        assert_eq!(res.mem_bytes, 1024 * 8);
        assert_eq!(
            trace
                .events
                .iter()
                .filter(|e| matches!(e, TraceEvent::Launch { .. }))
                .count(),
            3
        );
    }

    #[test]
    fn two_disjoint_tasks_end_independently() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            let a = f.malloc(sz);
            f.launch("k1", g, b, &[a], w);
            f.free(a);
            let x = f.malloc(sz);
            f.launch("k2", g, b, &[x], w);
            f.free(x);
        });
        let trace = interpret(&compile(&pb.finish()), &[4096]).unwrap();
        trace.check_well_formed().unwrap();
        assert_eq!(trace.n_tasks(), 2);
        // first task must END before the second BEGINS
        let end1 = trace
            .events
            .iter()
            .position(|e| matches!(e, TraceEvent::TaskEnd { .. }))
            .unwrap();
        let begin2 = trace
            .events
            .iter()
            .enumerate()
            .filter(|(_, e)| matches!(e, TraceEvent::TaskBegin { .. }))
            .nth(1)
            .unwrap()
            .0;
        assert!(end1 < begin2);
    }

    #[test]
    fn peak_reserved_accounts_heap() {
        let trace = interpret(&vecadd(), &[1024]).unwrap();
        let expected = 3 * 4 * 1024 + super::DEFAULT_HEAP;
        assert_eq!(trace.peak_reserved_bytes(), expected);
    }

    #[test]
    fn host_compute_passes_through() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let us = f.c(12_345);
            f.host_compute(us);
        });
        let trace = interpret(&compile(&pb.finish()), &[0]).unwrap();
        assert_eq!(trace.total_host_us(), 12_345);
    }
}

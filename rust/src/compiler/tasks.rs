//! GPU-task construction — Algorithm 1 of the paper, plus the
//! dominance-based static-bindability test and resource analysis.
//!
//! A *unit task* is built around each kernel launch: the memory objects
//! it touches, their allocs, and the grid/block configuration. Unit
//! tasks sharing memory objects are merged into one GPU task (it would
//! be incorrect — or at least require cross-device copies — to schedule
//! them apart). Ops that cannot be placed relative to the launch by
//! dominance (malloc/H2D must dominate, free/D2H must post-dominate) or
//! whose memory objects are not visible intra-procedurally make the task
//! *lazy*: the lazy runtime binds them at `kernelLaunchPrepare` time.

use super::cfg::Cfg;
use super::defuse::DefUse;
use super::dominators::{op_dominates, op_post_dominates, Dominators};
use crate::ir::{BlockId, CopyDir, Expr, Function, OpId, OpKind, ValueId};

pub use crate::ir::op::CopyDir as Dir;

/// Default CUDA on-device malloc heap (8 MiB on the devices the paper
/// tested; overridden by `DeviceSetLimit`).
pub const DEFAULT_DEVICE_HEAP: i64 = 8 << 20;

/// One kernel launch plus its related GPU operations (pre-merge).
#[derive(Clone, Debug)]
pub struct UnitTask {
    pub launch: OpId,
    pub mem_objs: Vec<ValueId>,
    pub ops: Vec<OpId>,
    pub grid: ValueId,
    pub block: ValueId,
    /// Ops (or whole-object bindings) that failed the dominance test.
    pub lazy: bool,
}

/// A schedulable GPU task (post-merge) with symbolic resource needs.
#[derive(Clone, Debug)]
pub struct GpuTask {
    pub id: usize,
    pub launches: Vec<OpId>,
    pub mem_objs: Vec<ValueId>,
    /// Every member GPU op, sorted by op id (== program order here).
    pub ops: Vec<OpId>,
    /// Total device-memory requirement (sum of member malloc sizes),
    /// symbolic until the probe interprets it.
    pub mem_bytes: Expr,
    /// On-device heap requirement (DeviceSetLimit or the 8 MiB default).
    pub heap_bytes: Expr,
    /// Max thread-blocks over member launches.
    pub grid: Expr,
    /// Max threads-per-block over member launches.
    pub block: Expr,
    /// Upper bound on device bytes this task *writes* per execution:
    /// member Memset + H2D byte expressions, plus one full write of every
    /// launch-argument object (kernels may store to any buffer they are
    /// passed; def-use gives no finer grain here). Symbolic like
    /// `mem_bytes`; groundwork for delta checkpoints (dirty-page sizing).
    pub written_bytes: Expr,
    /// Probe insertion point: (block, op-index) immediately before which
    /// `task_begin` runs. `None` when the task is lazy (the lazy runtime
    /// conveys resources at kernelLaunchPrepare instead).
    pub probe_at: Option<(BlockId, usize)>,
    pub lazy: bool,
}

/// Build unit tasks for every launch in `f` (paper Alg. 1, first loop).
pub fn build_unit_tasks(f: &Function, du: &DefUse, dom: &Dominators, pdom: &Dominators) -> Vec<UnitTask> {
    let mut units = Vec::new();
    for (_, _, op) in f.ops() {
        let OpKind::Launch { args, grid, block, .. } = &op.kind else {
            continue;
        };
        let launch_loc = f.loc(op.id);
        let mut mem_objs = Vec::new();
        let mut ops = vec![op.id];
        let mut lazy = false;
        for &a in args {
            // GETMEMARGS: launch args must be malloc-defined to be
            // statically analyzable.
            if !du.mem_objs.contains(&a) {
                lazy = true;
                continue;
            }
            mem_objs.push(a);
            for o in du.gpu_ops_of(f, a) {
                let loc = f.loc(o);
                let (Some((o_op, _, _)),) = (f.op(o),) else { continue };
                let ok = match &o_op.kind {
                    OpKind::Malloc { .. } | OpKind::Memset { .. } => op_dominates(dom, loc, launch_loc),
                    OpKind::Memcpy { dir: CopyDir::HostToDevice, .. } => {
                        op_dominates(dom, loc, launch_loc)
                    }
                    OpKind::Memcpy { dir: CopyDir::DeviceToHost, .. } | OpKind::Free { .. } => {
                        op_post_dominates(pdom, loc, launch_loc)
                    }
                    OpKind::Launch { .. } => true, // co-member launch; merged below
                    _ => true,
                };
                if ok {
                    ops.push(o);
                } else {
                    // Operation exists on the object but can't be bound
                    // to this launch statically (e.g. branch-guarded
                    // memcpy): defer the whole object to the lazy runtime.
                    lazy = true;
                }
            }
        }
        ops.sort_unstable();
        ops.dedup();
        units.push(UnitTask {
            launch: op.id,
            mem_objs,
            ops,
            grid: *grid,
            block: *block,
            lazy,
        });
    }
    units
}

/// Merge unit tasks sharing memory objects (paper Alg. 1, second loop —
/// run to a fixpoint: the paper's single pass misses transitive overlap
/// chains like {A,B}, {B,C}, {C,D}).
pub fn merge_unit_tasks(units: Vec<UnitTask>) -> Vec<Vec<UnitTask>> {
    let n = units.len();
    // Union-find over unit indices.
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, i: usize) -> usize {
        if parent[i] != i {
            let r = find(parent, parent[i]);
            parent[i] = r;
        }
        parent[i]
    }
    for i in 0..n {
        for j in i + 1..n {
            if units[i].mem_objs.iter().any(|m| units[j].mem_objs.contains(m)) {
                let (ri, rj) = (find(&mut parent, i), find(&mut parent, j));
                if ri != rj {
                    parent[ri] = rj;
                }
            }
        }
    }
    let mut groups: std::collections::BTreeMap<usize, Vec<UnitTask>> = Default::default();
    for (i, u) in units.into_iter().enumerate() {
        let r = find(&mut parent, i);
        groups.entry(r).or_default().push(u);
    }
    groups.into_values().collect()
}

/// Resource analysis + probe placement for one merged group.
pub fn finalize_task(
    id: usize,
    f: &Function,
    du: &DefUse,
    dom: &Dominators,
    _pdom: &Dominators,
    group: Vec<UnitTask>,
) -> GpuTask {
    let mut lazy = group.iter().any(|u| u.lazy);
    let mut launches: Vec<OpId> = group.iter().map(|u| u.launch).collect();
    launches.sort_unstable();
    let mut mem_objs: Vec<ValueId> = group.iter().flat_map(|u| u.mem_objs.clone()).collect();
    mem_objs.sort_unstable();
    mem_objs.dedup();
    let mut ops: Vec<OpId> = group.iter().flat_map(|u| u.ops.clone()).collect();
    ops.sort_unstable();
    ops.dedup();

    // Memory requirement: sum of the byte expressions of member mallocs.
    let mut mem_expr: Option<Expr> = None;
    for &obj in &mem_objs {
        if let Some(&d) = du.def.get(&obj) {
            if let Some((op, _, _)) = f.op(d) {
                if let OpKind::Malloc { bytes } = op.kind {
                    let e = Expr::v(bytes);
                    mem_expr = Some(match mem_expr.take() {
                        None => e,
                        Some(acc) => acc.add(e),
                    });
                }
            }
        }
    }
    let mem_bytes = mem_expr.unwrap_or(Expr::Const(0));

    // Written-bytes bound: explicit stores (Memset, H2D) by the member
    // ops, plus one full write of each launch-argument object — the
    // def-use chain proves the kernel *can* reach those buffers, and
    // without per-kernel store analysis a full overwrite is the sound
    // assumption. Launch args are exactly `mem_objs`, whose malloc sizes
    // already sum to `mem_bytes`.
    let mut written = mem_bytes.clone();
    for &o in &ops {
        if let Some((op, _, _)) = f.op(o) {
            match &op.kind {
                OpKind::Memset { bytes, .. }
                | OpKind::Memcpy { bytes, dir: CopyDir::HostToDevice, .. } => {
                    written = written.add(Expr::v(*bytes));
                }
                _ => {}
            }
        }
    }

    // Grid/block: max over member launches.
    let (mut grid_expr, mut block_expr): (Option<Expr>, Option<Expr>) = (None, None);
    for u in &group {
        let g = Expr::v(u.grid);
        let b = Expr::v(u.block);
        grid_expr = Some(match grid_expr.take() {
            None => g,
            Some(acc) => acc.max(g),
        });
        block_expr = Some(match block_expr.take() {
            None => b,
            Some(acc) => acc.max(b),
        });
    }

    // Heap: any DeviceSetLimit dominating a member launch.
    let mut heap = Expr::Const(DEFAULT_DEVICE_HEAP);
    for (_, _, op) in f.ops() {
        if let OpKind::DeviceSetLimit { bytes } = op.kind {
            let loc = f.loc(op.id);
            if launches
                .iter()
                .all(|&l| op_dominates(dom, loc, f.loc(l)))
            {
                heap = Expr::v(bytes);
            }
        }
    }

    // Probe placement: immediately before the first member op, if that
    // point dominates every member op and every symbol definition the
    // resource expressions read dominates *it*.
    let probe_at = if lazy {
        None
    } else {
        let first = ops
            .iter()
            .map(|&o| f.loc(o))
            .min_by_key(|&(b, i)| (b, i))
            .expect("task with no ops");
        let dominates_all = ops.iter().all(|&o| op_dominates(dom, first, f.loc(o)));
        // post-dominate all symbol defs == all defs dominate the probe
        // (defs are straight-line Assigns in practice; dominance is the
        // executable condition).
        let mut symbols = Vec::new();
        for e in [&mem_bytes, grid_expr.as_ref().unwrap(), block_expr.as_ref().unwrap(), &heap] {
            e.referenced_values(&mut symbols);
        }
        let mut sym_scalars = Vec::new();
        for &s in &symbols {
            du.scalar_deps(f, s, &mut sym_scalars);
        }
        // Pure scalar Assigns are hoistable: the probe *interprets* the
        // symbolic expressions (paper Fig. 3: `task_begin(N*3, 128,
        // N/128)` precedes the ops that would define those temps), so
        // only non-pure defs must actually dominate the probe point.
        let defs_ok = sym_scalars.iter().all(|&v| match du.def.get(&v) {
            None => true, // parameter: defined at entry
            Some(&d) => {
                let (op, _, _) = f.op(d).unwrap();
                matches!(op.kind, crate::ir::OpKind::Assign { .. })
                    || op_dominates(dom, f.loc(d), first)
            }
        });
        if dominates_all && defs_ok {
            Some(first)
        } else {
            lazy = true;
            None
        }
    };

    GpuTask {
        id,
        launches,
        mem_objs,
        ops,
        mem_bytes,
        heap_bytes: heap,
        grid: grid_expr.unwrap_or(Expr::Const(0)),
        block: block_expr.unwrap_or(Expr::Const(0)),
        written_bytes: written,
        probe_at,
        lazy,
    }
}

/// BUILDGPUTASKS (paper Alg. 1): unit construction, merge, finalize.
pub fn build_gpu_tasks(f: &Function) -> Vec<GpuTask> {
    let cfg = Cfg::build(f);
    let dom = Dominators::dominators(f, &cfg);
    let pdom = Dominators::post_dominators(f, &cfg);
    let du = DefUse::build(f);
    let units = build_unit_tasks(f, &du, &dom, &pdom);
    merge_unit_tasks(units)
        .into_iter()
        .enumerate()
        .map(|(i, g)| finalize_task(i, f, &du, &dom, &pdom, g))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Program, ProgramBuilder};

    fn build(program: fn(&mut crate::ir::FuncBuilder)) -> Program {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, program);
        pb.finish()
    }

    #[test]
    fn launch_arg_not_malloc_defined_makes_unit_lazy() {
        // GETMEMARGS failure: passing a scalar Assign result where a
        // memory object is expected defeats static binding.
        let p = build(|f| {
            let n = f.param(0);
            let not_a_buf = f.assign(Expr::v(n).mul(Expr::c(4)));
            let (g, b, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, b, &[not_a_buf], w);
        });
        let tasks = build_gpu_tasks(p.main());
        assert_eq!(tasks.len(), 1);
        assert!(tasks[0].lazy, "non-malloc launch arg must defer to lazy runtime");
        assert!(tasks[0].probe_at.is_none(), "lazy tasks carry no probe point");
        assert!(tasks[0].mem_objs.is_empty());
    }

    #[test]
    fn branch_guarded_free_fails_post_dominance_and_goes_lazy() {
        let p = build(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let buf = f.malloc(sz);
            f.h2d(buf, sz);
            let (g, b, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, b, &[buf], w);
            let cond = f.c(1);
            // Free on only one arm: it neither dominates nor
            // post-dominates the launch.
            f.diamond(cond, |f| f.free(buf), |_| {});
        });
        let tasks = build_gpu_tasks(p.main());
        assert_eq!(tasks.len(), 1);
        assert!(tasks[0].lazy);
        assert!(tasks[0].probe_at.is_none());
    }

    #[test]
    fn shared_object_merges_units_and_dedups_member_ops() {
        let p = build(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let shared = f.malloc(sz);
            let only2 = f.malloc(sz);
            f.h2d(shared, sz);
            let (g, b, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k1", g, b, &[shared], w);
            f.launch("k2", g, b, &[shared, only2], w);
            f.free(shared);
            f.free(only2);
        });
        let f = p.main();
        let cfg = Cfg::build(f);
        let dom = Dominators::dominators(f, &cfg);
        let pdom = Dominators::post_dominators(f, &cfg);
        let du = DefUse::build(f);
        let units = build_unit_tasks(f, &du, &dom, &pdom);
        assert_eq!(units.len(), 2);
        let groups = merge_unit_tasks(units);
        assert_eq!(groups.len(), 1, "shared object must merge the units");
        let t = finalize_task(0, f, &du, &dom, &pdom, groups.into_iter().next().unwrap());
        assert_eq!(t.launches.len(), 2);
        assert_eq!(t.mem_objs.len(), 2);
        // The shared object's malloc/h2d/free appear once despite being
        // members of both pre-merge units.
        let n_unique = t.ops.len();
        let mut sorted = t.ops.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), n_unique);
        assert!(!t.lazy);
        assert!(t.probe_at.is_some());
    }

    #[test]
    fn written_bytes_counts_h2d_memset_and_arg_objects() {
        let p = build(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let b_obj = f.malloc(sz);
            f.h2d(a, sz);
            f.memset(b_obj, sz);
            let (g, b, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, b, &[a, b_obj], w);
            f.free(a);
            f.free(b_obj);
        });
        let tasks = build_gpu_tasks(p.main());
        assert_eq!(tasks.len(), 1);
        let t = &tasks[0];
        // N = 100: sz = 400. mem = 2 objects = 800; written = mem (two
        // arg-object overwrites) + one H2D (400) + one Memset (400).
        let env = |v: ValueId| if v == 0 { 100 } else if v == 1 { 400 } else { 0 };
        assert_eq!(t.mem_bytes.eval(&env), 800);
        assert_eq!(t.written_bytes.eval(&env), 1600);
    }

    #[test]
    fn task_with_no_h2d_writes_only_arg_objects() {
        // srad-style: buffers allocated but never copied in still count
        // as written (the kernel stores into them).
        let p = build(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let (g, b, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, b, &[a], w);
            f.free(a);
        });
        let tasks = build_gpu_tasks(p.main());
        let t = &tasks[0];
        let env = |v: ValueId| if v == 1 { 400 } else { 0 };
        assert_eq!(t.written_bytes.eval(&env), t.mem_bytes.eval(&env));
    }
}

//! Inlining pass: merge callee bodies into callers so the (intra-
//! procedural) task analyses see whole def-use chains.
//!
//! The paper: "an inlining pass is first leveraged. If it cannot address
//! the problem, the compiler will defer the bindings ... through the
//! lazy runtime." We inline *straight-line* (single-block) callees
//! bottom-up — the common `init()/run()/teardown()` decomposition the
//! paper motivates — and leave call sites whose callees have control
//! flow or recursion; GPU ops inside those run under the lazy runtime.

use crate::ir::{Expr, Function, Op, OpId, OpKind, Program, ValueId};
use std::collections::HashSet;

const MAX_INLINE_DEPTH: usize = 8;

/// Inline eligible calls everywhere reachable from the entry.
pub fn inline_program(p: &Program) -> Program {
    let mut new = p.clone();
    let entry = p.entry as usize;
    let mut stack = HashSet::new();
    stack.insert(p.entry);
    new.funcs[entry] = inline_function(p, &p.funcs[entry], &mut stack, 0);
    new
}

fn inline_function(p: &Program, f: &Function, in_progress: &mut HashSet<u32>, depth: usize) -> Function {
    let mut out = f.clone();
    let mut next_op: OpId = f.ops().map(|(_, _, o)| o.id).max().map(|m| m + 1).unwrap_or(0);
    for blk in &mut out.blocks {
        let mut ops = Vec::with_capacity(blk.ops.len());
        for op in blk.ops.drain(..) {
            let OpKind::Call { callee, args } = &op.kind else {
                ops.push(op);
                continue;
            };
            if in_progress.contains(callee) || depth >= MAX_INLINE_DEPTH {
                ops.push(op); // recursion / depth cap: keep the call
                continue;
            }
            in_progress.insert(*callee);
            let callee_f = inline_function(p, &p.funcs[*callee as usize], in_progress, depth + 1);
            in_progress.remove(callee);
            if callee_f.blocks.len() != 1 {
                ops.push(op); // control flow in callee: lazy runtime path
                continue;
            }
            // Splice: params -> args, locals -> fresh values.
            let mut remap: Vec<ValueId> = Vec::with_capacity(callee_f.n_values as usize);
            for i in 0..callee_f.n_values {
                if i < callee_f.n_params {
                    remap.push(args[i as usize]);
                } else {
                    remap.push(out.n_values + (i - callee_f.n_params));
                }
            }
            out.n_values += callee_f.n_values - callee_f.n_params;
            for cop in &callee_f.blocks[0].ops {
                ops.push(remap_op(cop, &remap, &mut next_op));
            }
        }
        blk.ops = ops;
    }
    out
}

fn remap_op(op: &Op, remap: &[ValueId], next_op: &mut OpId) -> Op {
    let id = *next_op;
    *next_op += 1;
    let r = |v: ValueId| remap[v as usize];
    let kind = match &op.kind {
        OpKind::Assign { expr } => OpKind::Assign { expr: remap_expr(expr, remap) },
        OpKind::Malloc { bytes } => OpKind::Malloc { bytes: r(*bytes) },
        OpKind::Memcpy { obj, bytes, dir } => {
            OpKind::Memcpy { obj: r(*obj), bytes: r(*bytes), dir: *dir }
        }
        OpKind::Memset { obj, bytes } => OpKind::Memset { obj: r(*obj), bytes: r(*bytes) },
        OpKind::Free { obj } => OpKind::Free { obj: r(*obj) },
        OpKind::Launch { kernel, grid, block, args, work, artifact } => OpKind::Launch {
            kernel: kernel.clone(),
            grid: r(*grid),
            block: r(*block),
            args: args.iter().map(|&a| r(a)).collect(),
            work: r(*work),
            artifact: artifact.clone(),
        },
        OpKind::DeviceSetLimit { bytes } => OpKind::DeviceSetLimit { bytes: r(*bytes) },
        OpKind::SetDevice { dev } => OpKind::SetDevice { dev: r(*dev) },
        OpKind::Call { callee, args } => OpKind::Call {
            callee: *callee,
            args: args.iter().map(|&a| r(a)).collect(),
        },
        OpKind::HostCompute { micros } => OpKind::HostCompute { micros: r(*micros) },
    };
    Op { id, result: op.result.map(|v| remap[v as usize]), kind }
}

fn remap_expr(e: &Expr, remap: &[ValueId]) -> Expr {
    match e {
        Expr::Const(c) => Expr::Const(*c),
        Expr::Value(v) => Expr::Value(remap[*v as usize]),
        Expr::Add(a, b) => remap_expr(a, remap).add(remap_expr(b, remap)),
        Expr::Sub(a, b) => remap_expr(a, remap).sub(remap_expr(b, remap)),
        Expr::Mul(a, b) => remap_expr(a, remap).mul(remap_expr(b, remap)),
        Expr::CeilDiv(a, b) => remap_expr(a, remap).ceil_div(remap_expr(b, remap)),
        Expr::Max(a, b) => remap_expr(a, remap).max(remap_expr(b, remap)),
        Expr::Min(a, b) => remap_expr(a, remap).min(remap_expr(b, remap)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;

    #[test]
    fn straight_line_callee_is_inlined() {
        let mut pb = ProgramBuilder::new();
        let init = pb.declare("init", 1);
        pb.define(init, |f| {
            let sz = f.param(0);
            let a = f.malloc(sz);
            f.h2d(a, sz);
        });
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            f.call(init, &[sz]);
            let g = f.c(80);
            let b = f.c(256);
            let w = f.c(1000);
            // NOTE: the launch arg is inside init() pre-inline; after
            // inlining the malloc is visible in main. This test only
            // checks call elimination + op counts.
            f.launch("k", g, b, &[], w);
        });
        let p = pb.finish();
        let inlined = inline_program(&p);
        let main = inlined.main();
        assert!(
            !main.ops().any(|(_, _, o)| matches!(o.kind, OpKind::Call { .. })),
            "call should be gone"
        );
        // main gained malloc + h2d
        assert!(main.ops().any(|(_, _, o)| matches!(o.kind, OpKind::Malloc { .. })));
        assert!(inlined.validate().is_ok(), "{:?}", inlined.validate());
    }

    #[test]
    fn recursive_callee_is_kept() {
        let mut pb = ProgramBuilder::new();
        let rec = pb.declare("rec", 1);
        pb.define(rec, |f| {
            let n = f.param(0);
            f.call(rec, &[n]);
        });
        pb.func("main", 1, |f| {
            let n = f.param(0);
            f.call(rec, &[n]);
        });
        let p = pb.finish();
        let inlined = inline_program(&p);
        // The recursive call bottoms out at the depth cap but calls remain.
        assert!(inlined
            .main()
            .ops()
            .any(|(_, _, o)| matches!(o.kind, OpKind::Call { .. })));
    }

    #[test]
    fn looping_callee_is_kept_for_lazy_runtime() {
        let mut pb = ProgramBuilder::new();
        let looper = pb.declare("looper", 1);
        pb.define(looper, |f| {
            let n = f.param(0);
            f.loop_n(n, |f| {
                f.c(1);
            });
        });
        pb.func("main", 1, |f| {
            let n = f.param(0);
            f.call(looper, &[n]);
        });
        let p = pb.finish();
        let inlined = inline_program(&p);
        assert!(inlined
            .main()
            .ops()
            .any(|(_, _, o)| matches!(o.kind, OpKind::Call { .. })));
    }
}

//! Static verification of compiled programs — the `lint` pass.
//!
//! The scheduler's memory-safety guarantee rests on the compiler's
//! per-task resource summaries being *sound*: a task that under-declares
//! `mem_bytes` can OOM a device the placement proved safe. Nothing in
//! the pipeline checked that until this pass. Three layers:
//!
//! 1. **Memory-state dataflow** ([`verify_compiled`], first pass): a
//!    forward may-analysis over the entry function's CFG tracking each
//!    malloc-defined object through the lattice `{Unallocated, Live,
//!    Freed}` (join = set union — a state is possible if it is possible
//!    on *any* path). Reports use-after-free, double-free,
//!    use/launch-before-malloc, and allocations still live on some path
//!    to `ret` (leaks). Because the join over-approximates, a clean
//!    report is a proof: no execution order permitted by the CFG can
//!    reach a flagged state that the pass did not flag.
//! 2. **Task-claim check**: every GPU op the compiler assigned to a
//!    static task must only touch objects that task claims in
//!    `mem_objs` — otherwise the probe's reservation does not cover the
//!    op's footprint.
//! 3. **Summary soundness**: each static task's declared
//!    `mem_bytes`/`heap_bytes`/`grid`/`block`/`written_bytes` must
//!    *dominate* (≥ on every path) the recomputed per-member-op
//!    requirements. Domination is proved by syntactic equality of
//!    Assign-resolved expressions, or by symbolic interval bounds
//!    (`min(declared) ≥ max(actual)` with unresolved scalars widened to
//!    `[0, i64::MAX]`). What cannot be proved is reported — the pass
//!    never assumes soundness it cannot show.
//!
//! Size expressions are additionally evaluated with
//! [`Expr::eval_checked`] wherever they resolve to constants, turning
//! division-by-zero / overflow / negative byte counts into located
//! diagnostics instead of downstream panics or wrapped reservations.
//!
//! Diagnostics carry `(function, block, op)` locations and render both
//! human-readable ([`std::fmt::Display`]) and as JSON
//! ([`VerifyReport::to_json`], same hand-rolled-JSON conventions as
//! `bench_harness::json`).

use super::cfg::Cfg;
use super::defuse::DefUse;
use super::dominators::{op_dominates, Dominators};
use super::tasks::DEFAULT_DEVICE_HEAP;
use super::CompiledProgram;
use crate::ir::{
    op_operands, BlockId, CopyDir, Expr, Function, OpId, OpKind, ValueId,
};
use std::collections::{HashMap, HashSet};

/// How bad a finding is. `Error` findings make `lint` exit nonzero.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding, anchored to `(function, block, op)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable machine code, e.g. `use-after-free` (what the corpus tests
    /// match on).
    pub code: &'static str,
    pub func: String,
    pub block: BlockId,
    pub op: OpId,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}[{}] {} b{} op{}: {}",
            self.severity.as_str(),
            self.code,
            self.func,
            self.block,
            self.op,
            self.msg
        )
    }
}

/// Everything one lint run found, in (block, op) order.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VerifyReport {
    pub fn n_errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn n_warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// The distinct diagnostic codes present (sorted; for corpus tests).
    pub fn codes(&self) -> Vec<&'static str> {
        let mut c: Vec<&'static str> = self.diagnostics.iter().map(|d| d.code).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    fn push(
        &mut self,
        severity: Severity,
        code: &'static str,
        func: &str,
        loc: (BlockId, usize),
        op: OpId,
        msg: String,
    ) {
        let _ = loc.1; // op index is implied by the op id; kept for call-site clarity
        self.diagnostics.push(Diagnostic {
            severity,
            code,
            func: func.to_string(),
            block: loc.0,
            op,
            msg,
        });
    }

    /// JSON document (hand-rolled like every other emitter in the crate:
    /// no serde offline). Strings are escaped; the layout is stable so
    /// CI artifacts diff cleanly.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"diagnostics\": [\n");
        for (i, d) in self.diagnostics.iter().enumerate() {
            let sep = if i + 1 == self.diagnostics.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"severity\": \"{}\", \"code\": \"{}\", \"func\": \"{}\", \"block\": {}, \"op\": {}, \"msg\": \"{}\"}}{sep}\n",
                d.severity.as_str(),
                d.code,
                json_escape(&d.func),
                d.block,
                d.op,
                json_escape(&d.msg)
            ));
        }
        s.push_str(&format!(
            "  ],\n  \"errors\": {},\n  \"warnings\": {}\n}}\n",
            self.n_errors(),
            self.n_warnings()
        ));
        s
    }
}

impl std::fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for d in &self.diagnostics {
            writeln!(f, "{d}")?;
        }
        write!(
            f,
            "{} error(s), {} warning(s)",
            self.n_errors(),
            self.n_warnings()
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Memory-object dataflow lattice.

const UNALLOC: u8 = 1;
const LIVE: u8 = 2;
const FREED: u8 = 4;

/// Run every check over a compiled program. All tasks live in the
/// (inlined) entry function, so that is the function analysed; helper
/// bodies left behind by inlining are dead copies and would only
/// duplicate findings.
pub fn verify_compiled(c: &CompiledProgram) -> VerifyReport {
    let f = c.program.main();
    let cfg = Cfg::build(f);
    let du = DefUse::build(f);
    let mut rep = VerifyReport::default();
    memory_state_pass(f, &cfg, &du, &mut rep);
    claim_pass(f, c, &du, &mut rep);
    eval_pass(f, &du, &mut rep);
    summary_pass(f, &cfg, &du, c, &mut rep);
    rep.diagnostics.sort_by(|a, b| {
        (a.block, a.op, a.code, a.severity).cmp(&(b.block, b.op, b.code, b.severity))
    });
    rep
}

/// Forward may-analysis over malloc-defined objects. `in[entry]` is
/// all-UNALLOC, join is bitwise union, transfer is `Malloc → {LIVE}`,
/// `Free → {FREED}` (strong updates: an SSA object value names exactly
/// one allocation site). Iterated to fixpoint, then one reporting sweep
/// per block using the converged entry states.
fn memory_state_pass(f: &Function, cfg: &Cfg, du: &DefUse, rep: &mut VerifyReport) {
    let obj_ix: HashMap<ValueId, usize> =
        du.mem_objs.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    let n_objs = obj_ix.len();
    let n_blocks = f.blocks.len();
    let mut input: Vec<Vec<u8>> = vec![vec![0u8; n_objs]; n_blocks];
    input[0] = vec![UNALLOC; n_objs];
    let reachable = cfg.reachable();
    // Fixpoint: monotone over a finite lattice (3 bits per object), so
    // termination is immediate.
    let mut changed = true;
    while changed {
        changed = false;
        for &b in &reachable {
            let mut state = input[b as usize].clone();
            transfer_block(f, &obj_ix, b, &mut state, None);
            for &s in &cfg.succs[b as usize] {
                let succ_in = &mut input[s as usize];
                let mut grew = false;
                for (si, &v) in succ_in.iter_mut().zip(&state) {
                    let merged = *si | v;
                    if merged != *si {
                        *si = merged;
                        grew = true;
                    }
                }
                if grew {
                    changed = true;
                }
            }
        }
    }
    // Reporting sweep (each op visited exactly once → no duplicates).
    let mut sink = Reporter { rep: &mut *rep, exit_live: vec![false; n_objs] };
    for &b in &reachable {
        let mut state = input[b as usize].clone();
        transfer_block(f, &obj_ix, b, &mut state, Some(&mut sink));
        if cfg.exits.contains(&b) {
            for (i, &s) in state.iter().enumerate() {
                if s & LIVE != 0 {
                    sink.exit_live[i] = true;
                }
            }
        }
    }
    let exit_live = sink.exit_live.clone();
    for (i, leaked) in exit_live.into_iter().enumerate() {
        if leaked {
            let obj = du.mem_objs[i];
            let def = du.def[&obj];
            let loc = f.loc(def);
            rep.push(
                Severity::Error,
                "leak",
                &f.name,
                loc,
                def,
                format!("v{obj} may still be allocated at function exit (device memory leak)"),
            );
        }
    }
}

/// Diagnostic sink for the reporting sweep of the dataflow.
struct Reporter<'a> {
    rep: &'a mut VerifyReport,
    exit_live: Vec<bool>,
}

/// A use-site check shared by memcpy/memset: warn on non-malloc objects,
/// error when the freed/unallocated state is possible.
fn check_obj_use(
    f: &Function,
    obj_ix: &HashMap<ValueId, usize>,
    state: &[u8],
    report: &mut Option<&mut Reporter<'_>>,
    loc: (BlockId, usize),
    op: OpId,
    obj: ValueId,
    verb: &str,
) {
    let Some(r) = report.as_deref_mut() else { return };
    let Some(&i) = obj_ix.get(&obj) else {
        r.rep.push(
            Severity::Warning,
            "not-mem-obj",
            &f.name,
            loc,
            op,
            format!("{verb} on v{obj}, which no malloc defines"),
        );
        return;
    };
    if state[i] & FREED != 0 {
        r.rep.push(
            Severity::Error,
            "use-after-free",
            &f.name,
            loc,
            op,
            format!("{verb} on v{obj} may follow its free"),
        );
    }
    if state[i] & UNALLOC != 0 {
        r.rep.push(
            Severity::Error,
            "use-before-malloc",
            &f.name,
            loc,
            op,
            format!("{verb} on v{obj} may precede its malloc"),
        );
    }
}

/// Apply one block's ops to `state`; with a `Reporter`, emit diagnostics
/// for every possibly-bad state encountered.
fn transfer_block(
    f: &Function,
    obj_ix: &HashMap<ValueId, usize>,
    b: BlockId,
    state: &mut [u8],
    mut report: Option<&mut Reporter<'_>>,
) {
    for (bi, op) in f.blocks[b as usize].ops.iter().enumerate() {
        let loc = (b, bi);
        match &op.kind {
            OpKind::Malloc { .. } => {
                let Some(&i) = op.result.as_ref().and_then(|r| obj_ix.get(r)) else {
                    continue;
                };
                if let Some(r) = report.as_deref_mut() {
                    if state[i] & LIVE != 0 {
                        let obj = op.result.unwrap();
                        r.rep.push(
                            Severity::Error,
                            "leak",
                            &f.name,
                            loc,
                            op.id,
                            format!(
                                "v{obj} re-allocated while possibly still live \
                                 (previous allocation leaks)"
                            ),
                        );
                    }
                }
                state[i] = LIVE;
            }
            OpKind::Memcpy { obj, dir, .. } => {
                let verb = match dir {
                    CopyDir::HostToDevice => "h2d",
                    CopyDir::DeviceToHost => "d2h",
                };
                check_obj_use(f, obj_ix, state, &mut report, loc, op.id, *obj, verb);
            }
            OpKind::Memset { obj, .. } => {
                check_obj_use(f, obj_ix, state, &mut report, loc, op.id, *obj, "memset");
            }
            OpKind::Free { obj } => {
                let Some(&i) = obj_ix.get(obj) else {
                    if let Some(r) = report.as_deref_mut() {
                        r.rep.push(
                            Severity::Warning,
                            "not-mem-obj",
                            &f.name,
                            loc,
                            op.id,
                            format!("free of v{obj}, which no malloc defines"),
                        );
                    }
                    continue;
                };
                if let Some(r) = report.as_deref_mut() {
                    if state[i] & FREED != 0 {
                        r.rep.push(
                            Severity::Error,
                            "double-free",
                            &f.name,
                            loc,
                            op.id,
                            format!("v{obj} may already be freed (double free)"),
                        );
                    }
                    if state[i] & UNALLOC != 0 {
                        r.rep.push(
                            Severity::Error,
                            "use-before-malloc",
                            &f.name,
                            loc,
                            op.id,
                            format!("free of v{obj} may precede its malloc"),
                        );
                    }
                }
                state[i] = FREED;
            }
            OpKind::Launch { args, .. } => {
                for a in args {
                    let Some(&i) = obj_ix.get(a) else { continue };
                    if let Some(r) = report.as_deref_mut() {
                        if state[i] & FREED != 0 {
                            r.rep.push(
                                Severity::Error,
                                "use-after-free",
                                &f.name,
                                loc,
                                op.id,
                                format!("launch argument v{a} may follow its free"),
                            );
                        }
                        if state[i] & UNALLOC != 0 {
                            r.rep.push(
                                Severity::Error,
                                "launch-before-malloc",
                                &f.name,
                                loc,
                                op.id,
                                format!("launch argument v{a} may precede its malloc"),
                            );
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

/// Every op owned by a *static* task may only touch objects the task
/// claims in `mem_objs` — the probe reserves exactly those, so an
/// unclaimed object escapes the reservation the scheduler trusts.
fn claim_pass(f: &Function, c: &CompiledProgram, du: &DefUse, rep: &mut VerifyReport) {
    let claimed: Vec<HashSet<ValueId>> = c
        .tasks
        .iter()
        .map(|t| t.mem_objs.iter().copied().collect())
        .collect();
    for t in &c.tasks {
        if t.lazy {
            continue; // the lazy runtime binds objects at launch-prepare
        }
        for &o in &t.ops {
            let Some((op, b, i)) = f.op(o) else { continue };
            for v in op_operands(&op.kind) {
                if du.mem_objs.contains(&v) && !claimed[t.id].contains(&v) {
                    rep.push(
                        Severity::Error,
                        "unclaimed-obj",
                        &f.name,
                        (b, i),
                        o,
                        format!(
                            "op touches v{v}, which task {} does not claim in mem_objs",
                            t.id
                        ),
                    );
                }
            }
        }
    }
}

/// Substitute pure Assign definitions into an expression until only
/// parameters / non-Assign results remain. Cycles (a self-referential
/// Assign would pass `validate`'s flow-insensitive check) and deep
/// chains give up and keep the `Value` node — callers fall back to
/// interval widening.
fn resolve(e: &Expr, f: &Function, du: &DefUse, depth: usize) -> Expr {
    if depth == 0 {
        return e.clone();
    }
    let bin = |a: &Expr, b: &Expr| {
        (
            Box::new(resolve(a, f, du, depth - 1)),
            Box::new(resolve(b, f, du, depth - 1)),
        )
    };
    match e {
        Expr::Const(c) => Expr::Const(*c),
        Expr::Value(v) => {
            if let Some(&d) = du.def.get(v) {
                if let Some((op, _, _)) = f.op(d) {
                    if let OpKind::Assign { expr } = &op.kind {
                        return resolve(expr, f, du, depth - 1);
                    }
                }
            }
            Expr::Value(*v)
        }
        Expr::Add(a, b) => {
            let (a, b) = bin(a, b);
            Expr::Add(a, b)
        }
        Expr::Sub(a, b) => {
            let (a, b) = bin(a, b);
            Expr::Sub(a, b)
        }
        Expr::Mul(a, b) => {
            let (a, b) = bin(a, b);
            Expr::Mul(a, b)
        }
        Expr::CeilDiv(a, b) => {
            let (a, b) = bin(a, b);
            Expr::CeilDiv(a, b)
        }
        Expr::Max(a, b) => {
            let (a, b) = bin(a, b);
            Expr::Max(a, b)
        }
        Expr::Min(a, b) => {
            let (a, b) = bin(a, b);
            Expr::Min(a, b)
        }
    }
}

const RESOLVE_DEPTH: usize = 64;

/// Symbolic interval bounds in saturating i128 (so i64-overflowing
/// constants stay ordered instead of wrapping). Unresolved scalars —
/// parameters, malloc results abused as scalars — widen to
/// `[0, i64::MAX]`: byte counts and launch geometry are non-negative by
/// the IR's conventions, and the verifier only *proves* with what it can
/// pin down.
fn interval(e: &Expr) -> (i128, i128) {
    const WIDE: (i128, i128) = (0, i64::MAX as i128);
    match e {
        Expr::Const(c) => (*c as i128, *c as i128),
        Expr::Value(_) => WIDE,
        Expr::Add(a, b) => {
            let (al, ah) = interval(a);
            let (bl, bh) = interval(b);
            (al.saturating_add(bl), ah.saturating_add(bh))
        }
        Expr::Sub(a, b) => {
            let (al, ah) = interval(a);
            let (bl, bh) = interval(b);
            (al.saturating_sub(bh), ah.saturating_sub(bl))
        }
        Expr::Mul(a, b) => {
            let (al, ah) = interval(a);
            let (bl, bh) = interval(b);
            let products = [
                al.saturating_mul(bl),
                al.saturating_mul(bh),
                ah.saturating_mul(bl),
                ah.saturating_mul(bh),
            ];
            (
                products.iter().copied().min().unwrap(),
                products.iter().copied().max().unwrap(),
            )
        }
        Expr::CeilDiv(a, b) => {
            let (al, ah) = interval(a);
            let (bl, bh) = interval(b);
            if bl == bh && bl > 0 {
                // Exact positive divisor: ceil is monotone in the dividend.
                let ceil = |x: i128| x.saturating_add(bl - 1).div_euclid(bl);
                (ceil(al), ceil(ah))
            } else {
                // Unknown or zero-spanning divisor: no useful bound
                // (the legacy eval defines x/0 == 0, so 0 stays in range).
                (0.min(al), ah.max(0))
            }
        }
        Expr::Max(a, b) => {
            let (al, ah) = interval(a);
            let (bl, bh) = interval(b);
            (al.max(bl), ah.max(bh))
        }
        Expr::Min(a, b) => {
            let (al, ah) = interval(a);
            let (bl, bh) = interval(b);
            (al.min(bl), ah.min(bh))
        }
    }
}

/// Can we prove `declared >= actual` on every path? Syntactic equality
/// of Assign-resolved forms first (covers every builder idiom where the
/// same size value feeds malloc and memcpy), then interval separation.
fn dominates(declared: &Expr, actual: &Expr, f: &Function, du: &DefUse) -> bool {
    if declared == actual {
        return true;
    }
    let rd = resolve(declared, f, du, RESOLVE_DEPTH);
    let ra = resolve(actual, f, du, RESOLVE_DEPTH);
    if rd == ra {
        return true;
    }
    let (dlo, _) = interval(&rd);
    let (_, ahi) = interval(&ra);
    dlo >= ahi
}

/// Whether a resolved expression contains no `Value` leaves (so
/// `eval_checked` under a dummy environment is exact).
fn is_const(e: &Expr) -> bool {
    match e {
        Expr::Const(_) => true,
        Expr::Value(_) => false,
        Expr::Add(a, b)
        | Expr::Sub(a, b)
        | Expr::Mul(a, b)
        | Expr::CeilDiv(a, b)
        | Expr::Max(a, b)
        | Expr::Min(a, b) => is_const(a) && is_const(b),
    }
}

/// Concretely evaluate every constant-resolvable size/geometry operand
/// with `eval_checked`; faults land at the *defining* op of the scalar
/// (satellite: typed eval errors become located diagnostics).
fn eval_pass(f: &Function, du: &DefUse, rep: &mut VerifyReport) {
    let mut seen: HashSet<ValueId> = HashSet::new();
    for (b, i, op) in f.ops() {
        let sizes: Vec<(ValueId, &'static str)> = match &op.kind {
            OpKind::Malloc { bytes } => vec![(*bytes, "malloc size")],
            OpKind::Memcpy { bytes, .. } => vec![(*bytes, "memcpy size")],
            OpKind::Memset { bytes, .. } => vec![(*bytes, "memset size")],
            OpKind::DeviceSetLimit { bytes } => vec![(*bytes, "heap limit")],
            OpKind::Launch { grid, block, .. } => {
                vec![(*grid, "grid size"), (*block, "block size")]
            }
            _ => vec![],
        };
        for (v, what) in sizes {
            if !seen.insert(v) {
                continue; // one report per scalar, however many uses
            }
            let resolved = resolve(&Expr::v(v), f, du, RESOLVE_DEPTH);
            if !is_const(&resolved) {
                continue;
            }
            // Anchor at the defining Assign when there is one, else at
            // the using op (a const-resolvable value always has a def,
            // but stay defensive).
            let (anchor_op, anchor_loc) = match du.def.get(&v) {
                Some(&d) => (d, f.loc(d)),
                None => (op.id, (b, i)),
            };
            match resolved.eval_checked(&|_| 0) {
                Err(e) => rep.push(
                    Severity::Error,
                    "eval-error",
                    &f.name,
                    anchor_loc,
                    anchor_op,
                    format!("{what} v{v}: {e}"),
                ),
                Ok(n) if n < 0 => rep.push(
                    Severity::Error,
                    "eval-error",
                    &f.name,
                    anchor_loc,
                    anchor_op,
                    format!("{what} v{v}: size expression evaluates to negative {n}"),
                ),
                Ok(_) => {}
            }
        }
    }
}

/// Prove every static task's declared resource vector covers its member
/// ops (the soundness the probe-driven reservation depends on), and that
/// no member copy outruns its buffer.
fn summary_pass(
    f: &Function,
    cfg: &Cfg,
    du: &DefUse,
    c: &CompiledProgram,
    rep: &mut VerifyReport,
) {
    let dom = Dominators::dominators(f, cfg);
    for t in &c.tasks {
        if t.lazy {
            continue; // lazy tasks declare exact resources at launch-prepare
        }
        let anchor = *t.launches.first().expect("task with no launches");
        let anchor_loc = f.loc(anchor);

        // Recompute what the summaries must cover, straight from the IR.
        let mut expected_mem: Option<Expr> = None;
        for &obj in &t.mem_objs {
            if let Some(&d) = du.def.get(&obj) {
                if let Some((op, _, _)) = f.op(d) {
                    if let OpKind::Malloc { bytes } = op.kind {
                        let e = Expr::v(bytes);
                        expected_mem = Some(match expected_mem.take() {
                            None => e,
                            Some(acc) => acc.add(e),
                        });
                    }
                }
            }
        }
        let expected_mem = expected_mem.unwrap_or(Expr::Const(0));
        let (mut expected_grid, mut expected_block): (Option<Expr>, Option<Expr>) = (None, None);
        for &l in &t.launches {
            if let Some((op, _, _)) = f.op(l) {
                if let OpKind::Launch { grid, block, .. } = &op.kind {
                    let g = Expr::v(*grid);
                    let b = Expr::v(*block);
                    expected_grid = Some(match expected_grid.take() {
                        None => g,
                        Some(acc) => acc.max(g),
                    });
                    expected_block = Some(match expected_block.take() {
                        None => b,
                        Some(acc) => acc.max(b),
                    });
                }
            }
        }
        let mut expected_heap = Expr::Const(DEFAULT_DEVICE_HEAP);
        for (_, _, op) in f.ops() {
            if let OpKind::DeviceSetLimit { bytes } = op.kind {
                let loc = f.loc(op.id);
                if t.launches.iter().all(|&l| op_dominates(&dom, loc, f.loc(l))) {
                    expected_heap = Expr::v(bytes);
                }
            }
        }
        let mut expected_written = expected_mem.clone();
        for &o in &t.ops {
            if let Some((op, _, _)) = f.op(o) {
                match &op.kind {
                    OpKind::Memset { bytes, .. }
                    | OpKind::Memcpy { bytes, dir: CopyDir::HostToDevice, .. } => {
                        expected_written = expected_written.add(Expr::v(*bytes));
                    }
                    _ => {}
                }
            }
        }

        let expected_grid = expected_grid.unwrap_or(Expr::Const(0));
        let expected_block = expected_block.unwrap_or(Expr::Const(0));
        let checks: [(&'static str, &Expr, &Expr); 5] = [
            ("mem_bytes", &t.mem_bytes, &expected_mem),
            ("heap_bytes", &t.heap_bytes, &expected_heap),
            ("grid", &t.grid, &expected_grid),
            ("block", &t.block, &expected_block),
            ("written_bytes", &t.written_bytes, &expected_written),
        ];
        for (field, declared, actual) in checks {
            if !dominates(declared, actual, f, du) {
                rep.push(
                    Severity::Error,
                    "under-declared-summary",
                    &f.name,
                    anchor_loc,
                    anchor,
                    format!(
                        "task {} declares {field} = {declared}, which may under-cover \
                         its member ops (requires {actual})",
                        t.id
                    ),
                );
            }
        }

        // Per-member-op bound: a copy/set larger than its buffer means
        // the footprint the probe reserved (the malloc sum) cannot
        // contain the bytes this op moves.
        for &o in &t.ops {
            let Some((op, b, i)) = f.op(o) else { continue };
            let (obj, bytes, verb) = match &op.kind {
                OpKind::Memcpy { obj, bytes, dir } => (
                    *obj,
                    *bytes,
                    match dir {
                        CopyDir::HostToDevice => "h2d",
                        CopyDir::DeviceToHost => "d2h",
                    },
                ),
                OpKind::Memset { obj, bytes } => (*obj, *bytes, "memset"),
                _ => continue,
            };
            let Some(&d) = du.def.get(&obj) else { continue };
            let Some((def_op, _, _)) = f.op(d) else { continue };
            let OpKind::Malloc { bytes: alloc_bytes } = def_op.kind else {
                continue;
            };
            if !dominates(&Expr::v(alloc_bytes), &Expr::v(bytes), f, du) {
                rep.push(
                    Severity::Error,
                    "under-declared-summary",
                    &f.name,
                    (b, i),
                    o,
                    format!(
                        "{verb} of v{bytes} bytes into v{obj} may exceed its \
                         allocation (v{alloc_bytes})"
                    ),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::ir::{Program, ProgramBuilder};

    fn build(body: fn(&mut crate::ir::FuncBuilder)) -> Program {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, body);
        pb.finish()
    }

    fn lint(body: fn(&mut crate::ir::FuncBuilder)) -> VerifyReport {
        verify_compiled(&compile(&build(body)))
    }

    #[test]
    fn clean_vecadd_lints_clean() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let b = f.malloc(sz);
            f.h2d(a, sz);
            f.h2d(b, sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("vadd", g, blk, &[a, b], w);
            f.d2h(b, sz);
            f.free(a);
            f.free(b);
        });
        assert!(rep.is_clean(), "{rep}");
    }

    #[test]
    fn use_after_free_detected() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            f.free(a);
            f.d2h(a, sz); // bug
        });
        assert_eq!(rep.codes(), vec!["use-after-free"], "{rep}");
    }

    #[test]
    fn double_free_detected() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            f.free(a);
            f.free(a); // bug
        });
        assert_eq!(rep.codes(), vec!["double-free"], "{rep}");
    }

    #[test]
    fn leak_on_one_branch_detected() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            let cond = f.c(1);
            f.diamond(cond, |f| f.free(a), |_| {}); // else-arm leaks
        });
        // The branch-guarded free also defeats static binding (lazy
        // task), but the leak must still surface.
        assert!(rep.codes().contains(&"leak"), "{rep}");
    }

    #[test]
    fn loop_reallocation_without_free_is_a_leak() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let trips = f.c(3);
            f.loop_n(trips, |f| {
                let a = f.malloc(sz);
                let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
                f.launch("k", g, blk, &[a], w);
                // no free: next iteration re-allocates over a live object
            });
        });
        assert!(rep.codes().contains(&"leak"), "{rep}");
    }

    #[test]
    fn loop_with_balanced_malloc_free_is_clean() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let trips = f.c(3);
            f.loop_n(trips, |f| {
                let a = f.malloc(sz);
                let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
                f.launch("k", g, blk, &[a], w);
                f.free(a);
            });
        });
        assert!(rep.is_clean(), "{rep}");
    }

    #[test]
    fn oversized_copy_is_under_declared() {
        let rep = lint(|f| {
            let small = f.assign(Expr::c(1024));
            let big = f.assign(Expr::c(4096));
            let a = f.malloc(small);
            f.h2d(a, big); // copies past the end of the buffer
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            f.free(a);
        });
        assert_eq!(rep.codes(), vec!["under-declared-summary"], "{rep}");
    }

    #[test]
    fn tampered_task_summary_is_under_declared() {
        let mut c = compile(&build(|f| {
            let sz = f.assign(Expr::c(1 << 20));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            f.free(a);
        }));
        assert!(verify_compiled(&c).is_clean());
        c.tasks[0].mem_bytes = Expr::Const(16); // probe now under-reserves
        let rep = verify_compiled(&c);
        assert!(rep.codes().contains(&"under-declared-summary"), "{rep}");
    }

    #[test]
    fn unclaimed_object_in_static_task_detected() {
        let mut c = compile(&build(|f| {
            let sz = f.assign(Expr::c(4096));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            f.free(a);
        }));
        c.tasks[0].mem_objs.clear(); // compiler "forgot" the claim
        let rep = verify_compiled(&c);
        assert!(rep.codes().contains(&"unclaimed-obj"), "{rep}");
    }

    #[test]
    fn const_div_by_zero_and_negative_sizes_become_eval_errors() {
        let rep = lint(|f| {
            let bad = f.assign(Expr::c(4096).ceil_div(Expr::c(0)));
            let neg = f.assign(Expr::c(0).sub(Expr::c(64)));
            let a = f.malloc(bad);
            let b = f.malloc(neg);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a, b], w);
            f.free(a);
            f.free(b);
        });
        let evals: Vec<_> = rep
            .diagnostics
            .iter()
            .filter(|d| d.code == "eval-error")
            .collect();
        assert_eq!(evals.len(), 2, "{rep}");
        assert!(evals[0].msg.contains("division by zero"), "{rep}");
        assert!(evals[1].msg.contains("negative"), "{rep}");
    }

    #[test]
    fn json_rendering_is_escaped_and_structured() {
        let rep = lint(|f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let (g, blk, w) = (f.c(8), f.c(128), f.c(100));
            f.launch("k", g, blk, &[a], w);
            f.free(a);
            f.free(a);
        });
        let js = rep.to_json();
        assert!(js.contains("\"code\": \"double-free\""), "{js}");
        assert!(js.contains("\"errors\": 1"), "{js}");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn interval_bounds_are_conservative() {
        // (v0 * 4) with v0 unknown: [0, MAX] scaled.
        let e = Expr::v(0).mul(Expr::c(4));
        let (lo, hi) = interval(&e);
        assert_eq!(lo, 0);
        assert!(hi >= i64::MAX as i128);
        // Exact constants stay exact through ceil-div.
        let c = Expr::c(1000).ceil_div(Expr::c(128));
        assert_eq!(interval(&c), (8, 8));
        // min() pins the upper bound even with an unknown side.
        let m = Expr::v(0).min(Expr::c(512));
        assert_eq!(interval(&m).1, 512);
    }
}

//! Control-flow graph over a function's basic blocks.

use crate::ir::{BlockId, Function, Terminator};

/// Successor/predecessor lists for each block.
#[derive(Debug)]
pub struct Cfg {
    pub succs: Vec<Vec<BlockId>>,
    pub preds: Vec<Vec<BlockId>>,
    /// Blocks ending in `Ret` (the exits).
    pub exits: Vec<BlockId>,
}

impl Cfg {
    pub fn build(f: &Function) -> Self {
        let n = f.blocks.len();
        let mut succs = vec![Vec::new(); n];
        let mut preds = vec![Vec::new(); n];
        let mut exits = Vec::new();
        for (b, blk) in f.blocks.iter().enumerate() {
            let b = b as BlockId;
            match &blk.term {
                Terminator::Br(t) => succs[b as usize].push(*t),
                Terminator::CondBr { taken, fallthrough, .. } => {
                    succs[b as usize].push(*taken);
                    if taken != fallthrough {
                        succs[b as usize].push(*fallthrough);
                    }
                }
                Terminator::Ret => exits.push(b),
            }
        }
        for (b, ss) in succs.iter().enumerate() {
            for &s in ss {
                preds[s as usize].push(b as BlockId);
            }
        }
        Cfg { succs, preds, exits }
    }

    pub fn n_blocks(&self) -> usize {
        self.succs.len()
    }

    /// Blocks reachable from the entry (block 0), in RPO-ish DFS order.
    pub fn reachable(&self) -> Vec<BlockId> {
        let mut seen = vec![false; self.n_blocks()];
        let mut order = Vec::new();
        let mut stack = vec![0 as BlockId];
        seen[0] = true;
        while let Some(b) = stack.pop() {
            order.push(b);
            for &s in &self.succs[b as usize] {
                if !seen[s as usize] {
                    seen[s as usize] = true;
                    stack.push(s);
                }
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Expr, ProgramBuilder};

    #[test]
    fn diamond_cfg_shape() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let c = f.assign(Expr::c(1));
            f.diamond(c, |_| {}, |_| {});
        });
        let p = pb.finish();
        let cfg = Cfg::build(p.main());
        assert_eq!(cfg.succs[0], vec![1, 2]); // entry -> then, else
        assert_eq!(cfg.succs[1], vec![3]); // then -> join
        assert_eq!(cfg.succs[2], vec![3]); // else -> join
        assert_eq!(cfg.preds[3], vec![1, 2]);
        assert_eq!(cfg.exits, vec![3]);
    }

    #[test]
    fn loop_cfg_has_backedge() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            f.loop_n(n, |_| {});
        });
        let p = pb.finish();
        let cfg = Cfg::build(p.main());
        // entry(0) -> header(1); header -> {body(2), exit(3)}; body -> header
        assert_eq!(cfg.succs[0], vec![1]);
        assert_eq!(cfg.succs[1], vec![2, 3]);
        assert_eq!(cfg.succs[2], vec![1]);
        assert!(cfg.preds[1].contains(&0) && cfg.preds[1].contains(&2));
    }
}

//! The paper's compiler pass: task construction + probe instrumentation.
//!
//! Pipeline (§III-A): inline → CFG/dominators/def-use over the entry →
//! Algorithm 1 unit-task construction and merge → resource analysis →
//! probe placement. The output is a [`CompiledProgram`]: the inlined IR
//! plus one [`tasks::GpuTask`] per schedulable unit, each carrying its
//! symbolic resource vector and probe point. The lazy runtime
//! (`crate::lazy`) consumes this to drive execution; GPU ops that could
//! not be statically bound (lazy tasks, ops inside un-inlined calls) are
//! bound there at `kernelLaunchPrepare` time.

pub mod cfg;
pub mod defuse;
pub mod dominators;
pub mod inline;
pub mod tasks;
pub mod verify;

pub use tasks::{build_gpu_tasks, GpuTask};
pub use verify::{verify_compiled, Diagnostic, Severity, VerifyReport};

use crate::ir::{OpId, Program};
use std::collections::HashMap;

/// Result of compiling one application.
#[derive(Clone, Debug)]
pub struct CompiledProgram {
    /// The program after inlining (what the analyses ran over).
    pub program: Program,
    /// GPU tasks over the entry function, in discovery order.
    pub tasks: Vec<GpuTask>,
    /// op id (in the inlined entry) -> owning task index.
    pub op_task: HashMap<OpId, usize>,
}

/// Run the full pass.
pub fn compile(p: &Program) -> CompiledProgram {
    let inlined = inline::inline_program(p);
    let tasks = build_gpu_tasks(inlined.main());
    let mut op_task = HashMap::new();
    for t in &tasks {
        for &o in &t.ops {
            op_task.insert(o, t.id);
        }
    }
    CompiledProgram { program: inlined, tasks, op_task }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Expr, OpKind, ProgramBuilder};

    /// vecadd from the paper's Fig. 3: three mallocs, two H2D copies, a
    /// launch, a D2H, three frees — one task.
    fn vecadd() -> Program {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let da = f.malloc(sz);
            let db = f.malloc(sz);
            let dc = f.malloc(sz);
            f.h2d(da, sz);
            f.h2d(db, sz);
            let grid = f.assign(Expr::v(n).ceil_div(Expr::c(128)));
            let block = f.c(128);
            let work = f.c(1_000);
            f.launch("VecAdd", grid, block, &[da, db, dc], work);
            f.d2h(dc, sz);
            f.free(da);
            f.free(db);
            f.free(dc);
        });
        pb.finish()
    }

    #[test]
    fn vecadd_forms_one_static_task() {
        let c = compile(&vecadd());
        assert_eq!(c.tasks.len(), 1);
        let t = &c.tasks[0];
        assert!(!t.lazy);
        assert_eq!(t.mem_objs.len(), 3);
        assert_eq!(t.ops.len(), 10); // 3 malloc + 2 h2d + launch + d2h + 3 free
        // probe lands on the first malloc
        let probe = t.probe_at.expect("static probe");
        let f = c.program.main();
        let (op, _, _) = f.op(t.ops[0]).unwrap();
        assert!(matches!(op.kind, OpKind::Malloc { .. }));
        assert_eq!(probe, f.loc(t.ops[0]));
        // resource expressions evaluate correctly: N=1024 -> 3*4096 bytes
        let env = |v: u32| match v {
            0 => 1024,
            1 => 4096,  // sz
            5 => 8,     // grid
            6 => 128,   // block
            _ => 0,
        };
        assert_eq!(t.mem_bytes.eval(&env), 3 * 4096);
        assert_eq!(t.grid.eval(&env), 8);
        assert_eq!(t.block.eval(&env), 128);
        assert_eq!(t.heap_bytes.eval(&env), tasks::DEFAULT_DEVICE_HEAP);
    }

    #[test]
    fn shared_memobj_merges_launches_into_one_task() {
        // k1 writes C, k2 reads C: paper's motivating merge example.
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            let c = f.malloc(sz);
            let d = f.malloc(sz);
            f.h2d(a, sz);
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            f.launch("k1", g, b, &[a, c], w);
            f.launch("k2", g, b, &[c, d], w);
            f.d2h(d, sz);
            f.free(a);
            f.free(c);
            f.free(d);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 1, "k1/k2 share C and must merge");
        assert_eq!(c.tasks[0].launches.len(), 2);
        assert_eq!(c.tasks[0].mem_objs.len(), 3);
    }

    #[test]
    fn disjoint_launches_form_separate_tasks() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            let a = f.malloc(sz);
            f.h2d(a, sz);
            f.launch("k1", g, b, &[a], w);
            f.free(a);
            let x = f.malloc(sz);
            f.h2d(x, sz);
            f.launch("k2", g, b, &[x], w);
            f.free(x);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 2);
        assert!(c.tasks.iter().all(|t| !t.lazy));
    }

    #[test]
    fn transitive_sharing_merges_chain() {
        // {A,B}, {B,C}, {C,D} must merge into one task (fixpoint).
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            let va = f.malloc(sz);
            let vb = f.malloc(sz);
            let vc = f.malloc(sz);
            let vd = f.malloc(sz);
            f.launch("k1", g, b, &[va, vb], w);
            f.launch("k2", g, b, &[vb, vc], w);
            f.launch("k3", g, b, &[vc, vd], w);
            f.free(va);
            f.free(vb);
            f.free(vc);
            f.free(vd);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 1);
        assert_eq!(c.tasks[0].launches.len(), 3);
    }

    #[test]
    fn branch_guarded_copy_makes_task_lazy() {
        // A D2H in only one arm of a diamond neither dominates nor
        // post-dominates the launch: static binding must fail.
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            f.launch("k", g, b, &[a], w);
            let cond = f.c(1);
            f.diamond(cond, |f| f.d2h(a, sz), |_| {});
            f.free(a);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 1);
        assert!(c.tasks[0].lazy);
        assert!(c.tasks[0].probe_at.is_none());
    }

    #[test]
    fn launch_inside_loop_with_hoisted_buffers_stays_static() {
        // srad-style: malloc outside, launches in a loop, free after.
        let mut pb = ProgramBuilder::new();
        pb.func("main", 2, |f| {
            let n = f.param(0);
            let iters = f.param(1);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let img = f.malloc(sz);
            f.h2d(img, sz);
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            f.loop_n(iters, |f| {
                f.launch("srad1", g, b, &[img], w);
                f.launch("srad2", g, b, &[img], w);
            });
            f.d2h(img, sz);
            f.free(img);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 1);
        let t = &c.tasks[0];
        assert!(!t.lazy, "hoisted buffers are statically bindable");
        assert_eq!(t.launches.len(), 2);
        // probe precedes the malloc, outside the loop
        let f = c.program.main();
        let malloc_loc = f.loc(t.ops[0]);
        assert_eq!(t.probe_at, Some(malloc_loc));
        assert_eq!(malloc_loc.0, 0, "probe in entry block");
    }

    #[test]
    fn device_heap_limit_is_picked_up() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let heap = f.c(64 << 20);
            f.set_heap_limit(heap);
            let a = f.malloc(sz);
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            f.launch("k", g, b, &[a], w);
            f.free(a);
        });
        let c = compile(&pb.finish());
        let t = &c.tasks[0];
        let f = c.program.main();
        let heap_vid = f
            .ops()
            .find_map(|(_, _, o)| match &o.kind {
                OpKind::DeviceSetLimit { bytes } => Some(*bytes),
                _ => None,
            })
            .unwrap();
        assert_eq!(t.heap_bytes, Expr::v(heap_vid));
    }

    #[test]
    fn gpu_ops_in_helper_functions_bind_after_inline() {
        // Paper's init()/execute() split: malloc+h2d in init, launch in
        // execute. After inlining, one static task.
        let mut pb = ProgramBuilder::new();
        let init = pb.declare("init", 2);
        let exec = pb.declare("execute", 4);
        pb.define(exec, |f| {
            let obj = f.param(0);
            let g = f.param(1);
            let b = f.param(2);
            let w = f.param(3);
            f.launch("k", g, b, &[obj], w);
        });
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            f.call(init, &[sz, sz]);
            let _ = init; // init allocates internally; see note below
            let g = f.c(64);
            let b = f.c(256);
            let w = f.c(500);
            // In real code the pointer flows out of init; our IR has no
            // out-params, so model the common pattern where main owns the
            // object and helpers operate on it:
            let a = f.malloc(sz);
            f.h2d(a, sz);
            f.call(exec, &[a, g, b, w]);
            f.free(a);
        });
        pb.define(init, |f| {
            let micros = f.param(0);
            f.host_compute(micros);
        });
        let c = compile(&pb.finish());
        assert_eq!(c.tasks.len(), 1);
        assert!(!c.tasks[0].lazy);
    }
}


//! Dominator / post-dominator analysis (iterative bit-set dataflow).
//!
//! The paper places `cudaMalloc`/H2D ops in a task using *dominator*
//! information relative to the kernel launch, and `cudaFree`/D2H using
//! *post-dominator* information (§III-A1); probes are inserted at a point
//! that post-dominates all symbol definitions and dominates every op of
//! the task. Op-granular queries are derived from the block-level sets.

use super::cfg::Cfg;
use crate::ir::{BlockId, Function};

/// Block-level dominator sets as bit vectors (`doms[b]` = set of blocks
/// dominating `b`, including `b` itself).
#[derive(Debug)]
pub struct Dominators {
    doms: Vec<Vec<u64>>,
    words: usize,
}

fn bit_get(set: &[u64], i: usize) -> bool {
    set[i / 64] >> (i % 64) & 1 == 1
}

impl Dominators {
    /// Forward dominators from the entry block.
    pub fn dominators(f: &Function, cfg: &Cfg) -> Self {
        let n = f.blocks.len();
        Self::solve(n, 0, &cfg.preds, &cfg.reachable())
    }

    /// Post-dominators: dominators on the reversed CFG from a virtual
    /// exit that joins every `Ret` block. Block indices are unchanged;
    /// the virtual exit is index `n`.
    pub fn post_dominators(f: &Function, cfg: &Cfg) -> Self {
        let n = f.blocks.len();
        // Reversed edges, plus virtual exit n with preds = exits.
        let mut preds: Vec<Vec<BlockId>> = vec![Vec::new(); n + 1];
        for (b, ss) in cfg.succs.iter().enumerate() {
            for &s in ss {
                preds[b].push(s); // reversed: pred of b in reverse graph = succ of b
            }
        }
        for &e in &cfg.exits {
            preds[e as usize].push(n as BlockId); // exit blocks are preceded by virtual exit
        }
        // Reachability in the reverse graph from the virtual exit.
        let mut seen = vec![false; n + 1];
        let mut stack = vec![n];
        seen[n] = true;
        let mut order = vec![n as BlockId];
        // successors in reverse graph = preds in forward graph
        let mut rev_succs: Vec<Vec<BlockId>> = vec![Vec::new(); n + 1];
        for &e in &cfg.exits {
            rev_succs[n].push(e);
        }
        for (b, ps) in cfg.preds.iter().enumerate() {
            for &p in ps {
                rev_succs[b].push(p);
            }
        }
        while let Some(b) = stack.pop() {
            for &s in &rev_succs[b] {
                if !seen[s as usize] {
                    seen[s as usize] = true;
                    stack.push(s as usize);
                    order.push(s);
                }
            }
        }
        Self::solve(n + 1, n, &preds, &order)
    }

    /// Standard iterative intersection: dom(entry) = {entry};
    /// dom(b) = {b} ∪ ⋂ dom(preds). Unreachable blocks keep full sets.
    fn solve(n: usize, entry: usize, preds: &[Vec<BlockId>], reachable: &[BlockId]) -> Self {
        let words = n.div_ceil(64);
        let full = vec![u64::MAX; words];
        let mut doms = vec![full; n];
        doms[entry] = vec![0u64; words];
        doms[entry][entry / 64] |= 1 << (entry % 64);
        let mut changed = true;
        while changed {
            changed = false;
            for &b in reachable {
                let b = b as usize;
                if b == entry {
                    continue;
                }
                let mut new = vec![u64::MAX; words];
                for &p in &preds[b] {
                    for (w, d) in new.iter_mut().zip(&doms[p as usize]) {
                        *w &= d;
                    }
                }
                new[b / 64] |= 1 << (b % 64);
                if new != doms[b] {
                    doms[b] = new;
                    changed = true;
                }
            }
        }
        Dominators { doms, words }
    }

    /// Does block `a` dominate block `b`?
    pub fn dominates(&self, a: BlockId, b: BlockId) -> bool {
        let _ = self.words;
        bit_get(&self.doms[b as usize], a as usize)
    }
}

/// Op-granular dominance built on block dominance: op at (ba, ia)
/// dominates op at (bb, ib) iff (same block and ia <= ib) or
/// (ba != bb and ba dominates bb).
pub fn op_dominates(doms: &Dominators, a: (BlockId, usize), b: (BlockId, usize)) -> bool {
    if a.0 == b.0 {
        a.1 <= b.1
    } else {
        doms.dominates(a.0, b.0)
    }
}

/// Op-granular post-dominance: op at `a` post-dominates op at `b` iff
/// (same block and a comes at-or-after b) or block(a) post-dominates
/// block(b).
pub fn op_post_dominates(pdoms: &Dominators, a: (BlockId, usize), b: (BlockId, usize)) -> bool {
    if a.0 == b.0 {
        a.1 >= b.1
    } else {
        pdoms.dominates(a.0, b.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Expr, ProgramBuilder};

    fn diamond() -> crate::ir::Program {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let c = f.assign(Expr::c(1));
            f.diamond(c, |f| { f.c(10); }, |f| { f.c(20); });
            f.c(30);
        });
        pb.finish()
    }

    #[test]
    fn diamond_dominators() {
        let p = diamond();
        let f = p.main();
        let cfg = Cfg::build(f);
        let dom = Dominators::dominators(f, &cfg);
        // entry (0) dominates everything
        for b in 0..4 {
            assert!(dom.dominates(0, b));
        }
        // branches don't dominate the join
        assert!(!dom.dominates(1, 3));
        assert!(!dom.dominates(2, 3));
        // every block dominates itself
        for b in 0..4 {
            assert!(dom.dominates(b, b));
        }
    }

    #[test]
    fn diamond_post_dominators() {
        let p = diamond();
        let f = p.main();
        let cfg = Cfg::build(f);
        let pdom = Dominators::post_dominators(f, &cfg);
        // join (3) post-dominates everything
        for b in 0..4 {
            assert!(pdom.dominates(3, b));
        }
        // branches don't post-dominate the entry
        assert!(!pdom.dominates(1, 0));
        assert!(!pdom.dominates(2, 0));
    }

    #[test]
    fn loop_dominance() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            f.loop_n(n, |f| { f.c(1); });
            f.c(2);
        });
        let p = pb.finish();
        let f = p.main();
        let cfg = Cfg::build(f);
        let dom = Dominators::dominators(f, &cfg);
        let pdom = Dominators::post_dominators(f, &cfg);
        // header (1) dominates body (2) and exit (3)
        assert!(dom.dominates(1, 2));
        assert!(dom.dominates(1, 3));
        // body doesn't dominate exit
        assert!(!dom.dominates(2, 3));
        // exit post-dominates header and body... body is on a path that
        // must re-enter the header, and the only Ret is in exit.
        assert!(pdom.dominates(3, 1));
        assert!(pdom.dominates(3, 2));
        // body does NOT post-dominate the header (can skip on zero trips)
        assert!(!pdom.dominates(2, 1));
    }

    #[test]
    fn op_level_same_block_ordering() {
        let p = diamond();
        let f = p.main();
        let cfg = Cfg::build(f);
        let dom = Dominators::dominators(f, &cfg);
        assert!(op_dominates(&dom, (0, 0), (0, 0)));
        assert!(op_dominates(&dom, (0, 0), (0, 1)));
        assert!(!op_dominates(&dom, (0, 1), (0, 0)));
    }
}
